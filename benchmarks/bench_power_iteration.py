"""Paper Experiment 8 (Figures 14-16): distributed power iteration with
quantized u_i exchange; LQ/RLQ vs QSGD convergence to the principal
eigenvector, 2 and 8 workers."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.compressors import (LatticeQ, RotatedLatticeQ, QSGD,
                                    CompressorCtx)
from repro.core import rotation as R


def make_X(S=4096, d=128, seed=0):
    key = jax.random.PRNGKey(seed)
    evals = jnp.array([10.0, 8.0] + [1.0] * (d - 2))
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
    C = Q @ jnp.diag(evals) @ Q.T
    Lc = jnp.linalg.cholesky(C + 1e-6 * jnp.eye(d))
    X = jax.random.normal(jax.random.fold_in(key, 1), (S, d)) @ Lc.T
    v1 = Q[:, 0]
    return X, v1


def run(comp_name, n=2, iters=30, d=128):
    X, v1 = make_X(d=d)
    S = X.shape[0]
    parts = jnp.arange(S).reshape(n, -1)
    x = jax.random.normal(jax.random.PRNGKey(7), (d,))
    x = x / jnp.linalg.norm(x)
    diag = R.rotation_keypair(jax.random.PRNGKey(8), d)
    y = None
    for t in range(iters):
        us = jnp.stack([X[parts[i]].T @ (X[parts[i]] @ x) / S
                        for i in range(n)])
        if comp_name == "fp32":
            u = us.sum(0)
        else:
            comp = {"lq": LatticeQ(q=64), "rlq": RotatedLatticeQ(q=64),
                    "qsgd": QSGD(qlevel=64)}[comp_name]
            if y is None:
                y = 2.0 * float(jnp.max(jnp.abs(us - us.mean(0)))) * 2 + 1e-9
                yr = 2.0 * float(jnp.max(jnp.abs(R.rotate(us - us.mean(0),
                                                          diag)))) * 2 + 1e-9
            ctx = CompressorCtx(y=(yr if comp_name == "rlq" else y), diag=diag)
            zs = [comp.roundtrip(us[i], ctx,
                                 jax.random.PRNGKey(t * n + i),
                                 anchor=us[(i + 1) % n]) for i in range(n)]
            u = jnp.stack(zs).sum(0)
            y = 2.0 * float(jnp.max(jnp.abs(us - us.mean(0)))) * 2 + 1e-9
            yr = 2.0 * float(jnp.max(jnp.abs(R.rotate(us - us.mean(0),
                                                      diag)))) * 2 + 1e-9
        x = u / jnp.linalg.norm(u)
    return float(jnp.abs(jnp.dot(x, v1)))


def main():
    for n in (2, 8):
        res = {name: run(name, n=n) for name in ("fp32", "lq", "rlq", "qsgd")}
        emit(f"exp8_power_iter_n{n}", 0.0,
             ";".join(f"{k}={v:.4f}" for k, v in res.items()))
        assert res["lq"] > 0.9, res
        assert res["lq"] >= res["qsgd"] - 0.05, res


if __name__ == "__main__":
    main()
