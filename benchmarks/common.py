"""Shared benchmark utilities: timing, CSV emission, least-squares setup."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def least_squares_problem(S=8192, d=100, seed=0):
    """Paper §9.2 setup: A ~ N(0,1), b = A w*, w* ~ N(0,1)."""
    kw, ka = jax.random.split(jax.random.PRNGKey(seed))
    w_star = jax.random.normal(kw, (d,))
    A = jax.random.normal(ka, (S, d))
    b = A @ w_star
    return A, b, w_star


def batch_grads(A, b, w, n_workers: int, key):
    """Random split of rows into n equal batches; per-worker LS gradients."""
    S = A.shape[0]
    perm = jax.random.permutation(key, S)
    batches = perm.reshape(n_workers, S // n_workers)
    gs = []
    for i in range(n_workers):
        Ai, bi = A[batches[i]], b[batches[i]]
        gs.append(2 * Ai.T @ (Ai @ w - bi) / Ai.shape[0])
    return jnp.stack(gs)


def full_grad(A, b, w):
    return 2 * A.T @ (A @ w - b) / A.shape[0]
