"""Run paper-table benchmarks.  Prints ``name,us_per_call,derived`` CSV rows
plus one machine-readable ``BENCH_JSON {...}`` summary line (parsed by
scripts/bench_ci.py for the CI regression gate), and exits nonzero when any
module fails.

    PYTHONPATH=src python benchmarks/run.py [--modules bench_kernels,bench_dme]
"""
import argparse
import json
import sys
import traceback

MODULES = [
    "bench_norms", "bench_variance", "bench_convergence", "bench_sublinear",
    "bench_multimachine", "bench_localsgd", "bench_nn",
    "bench_power_iteration", "bench_lower_bound", "bench_dme",
    "bench_kernels", "bench_agg",
]


def run_modules(names: "list[str]") -> dict:
    """Run the named benchmark modules; returns the BENCH_JSON summary."""
    import importlib

    from benchmarks import common

    failed = []
    results = {}
    print("name,us_per_call,derived")
    for name in names:
        before = len(common.ROWS)
        try:
            importlib.import_module(f"benchmarks.{name}").main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        for row in common.ROWS[before:]:
            rname, us, derived = row.split(",", 2)
            results[rname] = {"module": name, "us_per_call": float(us),
                              "derived": derived}
    return {"ok": not failed, "failed": failed, "results": results}


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--modules", default=",".join(MODULES),
                   help="comma-separated benchmark module names")
    args = p.parse_args(argv)
    names = [m for m in args.modules.split(",") if m]
    summary = run_modules(names)
    if set(names) == set(MODULES):
        # roofline table (requires dry-run results; skipped gracefully
        # otherwise; not part of the machine-readable summary)
        try:
            from benchmarks import roofline
            roofline.main()
        except Exception:
            traceback.print_exc()
    print("BENCH_JSON " + json.dumps(summary))
    if summary["failed"]:
        print(f"FAILED: {summary['failed']}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
