"""Run every paper-table benchmark.  Prints ``name,us_per_call,derived``."""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_norms, bench_variance, bench_convergence,
                            bench_sublinear, bench_multimachine,
                            bench_localsgd, bench_nn, bench_power_iteration,
                            bench_lower_bound, bench_dme, bench_kernels)
    mods = [bench_norms, bench_variance, bench_convergence, bench_sublinear,
            bench_multimachine, bench_localsgd, bench_nn,
            bench_power_iteration, bench_lower_bound, bench_dme,
            bench_kernels]
    print("name,us_per_call,derived")
    failed = []
    for m in mods:
        try:
            m.main()
        except Exception:
            failed.append(m.__name__)
            traceback.print_exc()
    # roofline table (requires dry-run results; skipped gracefully otherwise)
    try:
        from benchmarks import roofline
        roofline.main()
    except Exception:
        traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
