"""Paper Experiment 2 (Figures 3-4): output variance of each quantizer at
3 bits/coord during distributed least-squares SGD.  LQSGD should be the only
method achieving variance *reduction* (output var < single-input var)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, least_squares_problem, batch_grads,
                               full_grad)
from repro.core.compressors import (LatticeQ, RotatedLatticeQ, QSGD,
                                    HadamardUniform, CompressorCtx)
from repro.core import rotation as R


def main():
    A, b, w_star = least_squares_problem()
    d = A.shape[1]
    diag = R.rotation_keypair(jax.random.PRNGKey(9), d)
    w = jnp.zeros((d,))
    comps = {
        "lq": LatticeQ(q=8),
        "rlq": RotatedLatticeQ(q=8),
        "qsgd_l2": QSGD(qlevel=8, norm="l2"),
        "qsgd_linf": QSGD(qlevel=8, norm="linf"),
        "hadamard": HadamardUniform(levels=8),
    }
    out_var = {k: [] for k in comps}
    out_var["naive_fp32"] = []
    in_var = []
    y = None
    for t in range(25):
        key = jax.random.PRNGKey(100 + t)
        gs = batch_grads(A, b, w, 2, key)
        g0, g1 = gs[0], gs[1]
        nabla = full_grad(A, b, w)
        in_var.append(float(jnp.sum((g0 - nabla) ** 2)))
        if y is None:
            y = 1.5 * float(jnp.max(jnp.abs(g0 - g1))) + 1e-9
        yr = 1.5 * float(jnp.max(jnp.abs(R.rotate(g0 - g1, diag)))) + 1e-9
        for name, comp in comps.items():
            ctx = CompressorCtx(y=(yr if name == "rlq" else y), diag=diag)
            z0 = comp.roundtrip(g0, ctx, jax.random.fold_in(key, 1), anchor=g1)
            z1 = comp.roundtrip(g1, ctx, jax.random.fold_in(key, 2), anchor=g0)
            est = (z0 + z1) / 2
            out_var[name].append(float(jnp.sum((est - nabla) ** 2)))
        out_var["naive_fp32"].append(float(jnp.sum(((g0 + g1) / 2 - nabla) ** 2)))
        # dynamic y update (paper §9.2)
        y = 1.5 * float(jnp.max(jnp.abs(g0 - g1))) + 1e-9
        w = w - 0.05 * nabla
    iv = np.mean(in_var)
    for name in out_var:
        v = np.mean(out_var[name])
        emit(f"exp2_variance_{name}", 0.0,
             f"out_var={v:.5f};in_var={iv:.5f};reduction={iv/max(v,1e-12):.2f}x")
    # paper claim: LQ achieves variance reduction; norm-based methods don't
    assert np.mean(out_var["lq"]) < iv, "LQ must reduce variance"
    assert np.mean(out_var["lq"]) < np.mean(out_var["qsgd_l2"])


if __name__ == "__main__":
    main()
