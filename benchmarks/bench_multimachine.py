"""Paper Experiment 5 (Figures 9-10): Algorithm 3 (star) with n=8/16 machines
on a regression problem with far-from-origin optimum (w0 = -1000)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, least_squares_problem, batch_grads
from repro.core import mean_estimation_star, LatticeQ, CompressorCtx
from repro.core.compressors import QSGD


def run(n, quantizer, steps=40):
    d = 12
    A, b, _ = least_squares_problem(S=8192, d=d, seed=2)
    w = jnp.full((d,), -1000.0)     # paper: start far from the optimum
    y = None
    lr = 0.1 / float(jnp.linalg.norm(A, ord=2) ** 2 / A.shape[0])
    for t in range(steps):
        gs = batch_grads(A, b, w, n, jax.random.PRNGKey(t))
        if quantizer == "fp32":
            g = gs.mean(0)
        elif quantizer == "lq":
            if y is None:
                y = 3.0 * float(jnp.max(jnp.abs(gs - gs.mean(0)))) * 2 + 1e-9
            res = mean_estimation_star(gs, y, LatticeQ(q=16),
                                       jax.random.PRNGKey(500 + t),
                                       CompressorCtx(y=y))
            g = res.est[0]
            y = 3.0 * float(jnp.max(jnp.abs(gs - gs.mean(0)))) * 2 + 1e-9
        else:
            comp = QSGD(qlevel=16)
            zs = [comp.roundtrip(gs[i], CompressorCtx(),
                                 jax.random.PRNGKey(900 + t * n + i))
                  for i in range(n)]
            g = jnp.stack(zs).mean(0)
        w = w - lr * g
    return float(jnp.mean((A @ w - b) ** 2))


def main():
    for n in (8, 16):
        f_fp, f_lq, f_q = run(n, "fp32"), run(n, "lq"), run(n, "qsgd")
        emit(f"exp5_n{n}", 0.0,
             f"fp32={f_fp:.3e};lq={f_lq:.3e};qsgd={f_q:.3e}")
        assert f_lq < f_q, f"LQ should converge better than QSGD at n={n}"


if __name__ == "__main__":
    main()
