"""Aggregation-service benchmarks: receive-path throughput, round latency vs
client count, wire bytes per client, and the chunked-transport scenario at
LLM-gradient d (the repro.agg protocol over the packed lattice wire format;
interpret-mode kernel timings on CPU).

The chunked rows additionally assert the ISSUE 5 acceptance bound: the
transport's peak reassembly staging (bytes buffered before a CRC vouched
for them) is bounded by ``mtu * inflight_clients`` — in fact by ONE frame,
header + mtu — and is independent of d, while v2's monolithic frame staged
the whole payload.

The ``agg_engine_openloop`` row (ISSUE 6) drives the continuous-round
engine and the lockstep coordinator over the IDENTICAL Poisson arrival
trace on a virtual clock and asserts the engine's rounds/sec is strictly
higher; the virtual-clock metrics (rounds_per_s, speedup, p50/p99 round
latency, anchor staleness) are machine-independent and gated
unconditionally by scripts/bench_ci.py, while us_per_call (the wall cost
of simulating the whole trace) gets the usual same-machine timing gate.

The ``agg_tree_fanout*`` rows (ISSUE 7) run the hierarchical
sum-without-decode AggTree against the flat server on the same fleet and
assert the acceptance bounds (bit-identical mean, root ingress <= fanout
combined payloads per round)."""
import time

import numpy as np

import repro.obs as obs
from benchmarks.common import emit
from repro.agg.transport import frame as wire
from repro.agg.server import AggServer
from repro.agg.tree import AggTree
from repro.agg.sim import (OpenLoopConfig, fleet_frames, fleet_payloads,
                           run_lockstep, run_open_loop)
from repro.core import wire_accounting as WA
from repro.dist.collectives import QSyncConfig

D = 4096
CLIENT_COUNTS = (64, 256, 512)
# chunked scenario: large-d payloads split at a fixed MTU, all clients in
# flight at once (chunk-interleaved fan-in)
CHUNK_DS = (1 << 16, 1 << 17)
CHUNK_MTU = 8192
CHUNK_CLIENTS = 16


def _make_round(n_clients: int, seed: int = 0):
    spec = wire.RoundSpec(round_id=seed + 1, d=D,
                          cfg=QSyncConfig(q=16, bucket=512), y0=0.5,
                          seed=seed)
    rng = np.random.RandomState(seed)
    base = rng.randn(D).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(n_clients, D).astype(np.float32)
    return spec, base, fleet_payloads(spec, xs)


def _time_round(spec, base, payloads, iters: int = 3) -> "tuple[float, float]":
    """(us per full round, us per receive call); first round warms the jit
    caches for this client count."""
    rx_us, round_us = [], []
    for it in range(iters + 1):
        server = AggServer(spec, base)
        t0 = time.perf_counter()
        for p in payloads:
            server.receive(p)
        t1 = time.perf_counter()
        server.drain()
        server.finalize()
        t2 = time.perf_counter()
        if it == 0:
            continue
        rx_us.append((t1 - t0) / len(payloads) * 1e6)
        round_us.append((t2 - t0) * 1e6)
    return float(obs.quantile(round_us, 50)), float(obs.quantile(rx_us, 50))


def _make_chunked_round(d: int, seed: int = 0):
    spec = wire.RoundSpec(round_id=seed + 1, d=d,
                          cfg=QSyncConfig(q=16, bucket=512), y0=0.5,
                          seed=seed, mtu=CHUNK_MTU)
    rng = np.random.RandomState(seed)
    base = rng.randn(d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(CHUNK_CLIENTS, d).astype(np.float32)
    return spec, base, fleet_frames(spec, xs)


def _time_chunked_round(spec, base, frames, iters: int = 3
                        ) -> "tuple[float, int, int]":
    """(us per full chunked round, peak pre-CRC staging bytes, peak
    open-stream reassembly buffer bytes); the fan-in is chunk-interleaved
    so every client's session is open at once (inflight_clients = the
    whole fleet)."""
    nc = len(frames[0])
    order = [(c, k) for k in range(nc) for c in range(len(frames))]
    round_us, staging, buf = [], 0, 0
    for it in range(iters + 1):
        server = AggServer(spec, base)
        t0 = time.perf_counter()
        for c, k in order:
            server.receive(frames[c][k])
        server.drain()
        server.finalize()
        t1 = time.perf_counter()
        assert len(server.accepted_clients) == len(frames)
        staging = max(staging, server.stats.peak_unvalidated_bytes)
        buf = max(buf, server.transport_stats.peak_buffer_bytes)
        if it > 0:
            round_us.append((t1 - t0) * 1e6)
    return float(obs.quantile(round_us, 50)), staging, buf


def chunked_rounds():
    """Large-d chunked scenario: bytes/client, chunk-header overhead %, the
    peak pre-CRC staging bound (one frame <= mtu * inflight, independent of
    d), and the reassembly-buffer amplification (open-stream bodies vs the
    pending payload store the drain needs anyway — must be exactly 1.0:
    the transport adds no buffering of its own)."""
    peaks = {}
    for d in CHUNK_DS:
        spec, base, frames = _make_chunked_round(d)
        nc = len(frames[0])
        assert nc >= 4, (d, nc)
        us_round, staging, buf = _time_chunked_round(spec, base, frames)
        peaks[d] = staging
        body = spec.body_bytes()
        bpc = wire.payload_bytes(spec)
        assert bpc == sum(len(f) for f in frames[0])
        overhead = WA.chunk_overhead_pct(body, CHUNK_MTU)
        fp32 = 4 * d
        # the acceptance bound: transport staging (bytes held before a CRC
        # vouched for them) never exceeds one frame per in-flight receive —
        # far under mtu * inflight_clients, and (asserted below)
        # independent of d.  v2 staged the whole d-sized payload.
        bound = CHUNK_MTU * CHUNK_CLIENTS
        assert staging <= WA.FRAME_HEADER_BYTES + CHUNK_MTU <= bound, \
            (staging, bound)
        # open-stream reassembly buffers ARE the pending payload store
        # (every in-flight client's body, exactly once — zero-copy into
        # the drain): amplification 1.0, same memory as the v2 server
        assert buf == CHUNK_CLIENTS * body, (buf, CHUNK_CLIENTS, body)
        emit(f"agg_chunked_d{d}", us_round,
             f"d={d};clients={CHUNK_CLIENTS};mtu={CHUNK_MTU};n_chunks={nc};"
             f"bytes_per_client={bpc};chunk_overhead_pct={overhead:.3f};"
             f"peak_staging_bytes={staging};"
             f"reassembly_amplification={buf / (CHUNK_CLIENTS * body):.3f};"
             f"wire_compression={fp32 / bpc:.1f}x")
    assert len(set(peaks.values())) == 1, \
        f"peak transport staging must be independent of d: {peaks}"


STREAM_WINDOW = 4


def streaming_rounds():
    """Chunk-pipelined streaming decode under windowed flow control (v5):
    the same large-d fleet as the chunked rows, but clients pace themselves
    with a ``window``-chunk credit and the server residual-folds each
    validated chunk range on arrival instead of staging whole bodies for
    the sealed drain.

    Asserts the acceptance bounds: the published mean is bit-identical to
    the sealed batched-decode server over the same fleet; peak transport
    staging stays one frame, independent of d; and the pending-store
    high-water — staged bodies plus reassembly buffers — sits far below
    one body per in-flight client (< 0.5x, vs exactly 1.0x for the sealed
    path), because chunk bytes are freed the moment their range is folded."""
    import dataclasses as _dc

    peaks, stores = {}, {}
    for d in CHUNK_DS:
        spec0, base, _ = _make_chunked_round(d)
        spec = _dc.replace(spec0, window=STREAM_WINDOW)
        rng = np.random.RandomState(7)
        xs = base[None] + 0.02 * rng.randn(CHUNK_CLIENTS, d).astype(np.float32)
        from repro.agg.client import AggClient
        body = spec.body_bytes()

        # sealed reference: same windowed spec, streaming forced off
        ref = AggServer(spec, base, streaming=False)
        ref_clients = [AggClient(spec, c, xs[c]) for c in range(CHUNK_CLIENTS)]
        for c in ref_clients:
            for f in c.frames():
                ref.ingest_frame(f)
        mean_ref, _ = ref.finalize()

        nc, round_us, store, stalls = 0, [], 0, 0
        for it in range(4):
            server = AggServer(spec, base)
            clients = [AggClient(spec, c, xs[c])
                       for c in range(CHUNK_CLIENTS)]
            nc = len(clients[0].frames())
            t0 = time.perf_counter()
            outbox = [(c, f) for c in clients for f in c.send_frames()]
            while outbox:
                nxt = []
                for c, f in outbox:
                    for rb in server.ingest_frame(f):
                        nxt.extend((c, g) for g in c.handle_response(rb))
                outbox = nxt
            server.drain()
            mean_s, _ = server.finalize()
            t1 = time.perf_counter()
            assert all(c.acked for c in clients)
            assert np.array_equal(mean_s.view(np.uint32),
                                  mean_ref.view(np.uint32)), \
                "streaming mean != sealed batched-decode mean"
            store = max(store, server.stats.peak_pending_store_bytes)
            peaks[d] = max(peaks.get(d, 0),
                           server.stats.peak_unvalidated_bytes)
            stalls = sum(c.window_stalls for c in clients)
            if it > 0:
                round_us.append((t1 - t0) * 1e6)
        stores[d] = store
        us = float(obs.quantile(round_us, 50))
        sealed_store = CHUNK_CLIENTS * body
        ratio = store / sealed_store
        # the tentpole acceptance: the streaming server never holds
        # anything near the sealed path's one-body-per-pending-client
        assert ratio < 0.5, (d, store, sealed_store)
        emit(f"agg_streaming_d{d}", us,
             f"d={d};clients={CHUNK_CLIENTS};mtu={CHUNK_MTU};"
             f"window={STREAM_WINDOW};n_chunks={nc};"
             f"pending_store_bytes={store};sealed_store_bytes={sealed_store};"
             f"store_vs_sealed={ratio:.3f};"
             f"peak_staging_bytes={peaks[d]};window_stalls={stalls};"
             f"bit_identical=1")
    assert len(set(peaks.values())) == 1, \
        f"peak transport staging must be independent of d: {peaks}"


def engine_openloop():
    """Continuous-round engine vs lockstep on the identical arrival trace.

    All throughput/latency/staleness numbers are VIRTUAL-clock (event-time)
    quantities — deterministic for a fixed trace, identical on any machine
    — so bench_ci gates them unconditionally.  The first (untimed) run
    warms the jit caches for the open-loop shapes; the timed run measures
    the wall cost of pushing the whole trace through the engine."""
    cfg = OpenLoopConfig()
    run_open_loop(cfg, check_parity=False)        # warm the jit caches
    # the ISSUE 8 acceptance: full tracing+metrics+recording enabled must
    # stay a small constant cost on the identical trace (gated by
    # bench_ci at <= 10%, intrinsic ~2-5%), and every published round's
    # span tree must be causally complete.  The
    # overhead is a small intrinsic cost estimated under ~10% co-located
    # scheduler noise on a 2-cpu container, so run 5 interleaved
    # plain/traced pairs and take the MINIMUM per-pair overhead: adjacent
    # runs share the box's momentary speed (common-mode drift cancels
    # within a pair), the min discards pairs a co-tenant burst landed on,
    # and a real tracing regression raises every pair so the gate still
    # fires.
    plain_us, traced_us = [], []
    rep = rep_t = None
    try:
        for _ in range(5):
            t0 = time.perf_counter()
            rep = run_open_loop(cfg, check_parity=False)
            plain_us.append((time.perf_counter() - t0) * 1e6)
            obs.enable()
            obs.reset()
            t0 = time.perf_counter()
            rep_t = run_open_loop(cfg, check_parity=False)
            traced_us.append((time.perf_counter() - t0) * 1e6)
            obs.disable()
        obs.enable()                     # audited traced run (untimed)
        obs.reset()
        rep_t = run_open_loop(cfg, check_parity=False)
        tr = obs.tracer()
        for pr in rep_t.published:
            problems = obs.check_round(tr, pr.round_id,
                                       accepted=pr.accepted)
            assert not problems, problems
    finally:
        obs.disable()
        obs.reset()
    wall_us = float(obs.quantile(plain_us, 50))
    obs_overhead_pct = min((t - p) / p for p, t in zip(plain_us, traced_us)) \
        * 100.0
    lock = run_lockstep(cfg)
    speedup = rep.rounds_per_s / lock.rounds_per_s
    # the ISSUE 6 acceptance: overlap must buy real throughput
    assert speedup > 1.0, (rep.rounds_per_s, lock.rounds_per_s)
    assert rep.max_live_rounds >= 3, rep.max_live_rounds
    emit("agg_engine_openloop", wall_us,
         f"clients={rep.clients_arrived};rounds={rep.rounds};"
         f"rounds_per_s={rep.rounds_per_s:.2f};"
         f"lockstep_rounds_per_s={lock.rounds_per_s:.2f};"
         f"speedup={speedup:.2f}x;"
         f"p50_round_ms={rep.p50_latency * 1e3:.1f};"
         f"p99_round_ms={rep.p99_latency * 1e3:.1f};"
         f"staleness_ms={rep.mean_staleness * 1e3:.1f};"
         f"max_live_rounds={rep.max_live_rounds};"
         f"obs_overhead_pct={obs_overhead_pct:.1f}")


TREE_FANOUTS = (4, 16)
TREE_CLIENTS = 64


def tree_fanout():
    """Hierarchical sum-without-decode tree vs the flat server on the same
    round (ISSUE 7): one edge layer of ``fanout`` tiers in front of the
    root.  Asserts the acceptance bounds — bit-identical mean, root ingress
    <= fanout combined payloads however many clients arrive — and emits the
    full-round wall cost next to flat's for the same fleet."""
    for fanout in TREE_FANOUTS:
        spec, base, payloads = _make_round(TREE_CLIENTS, seed=fanout)
        flat_us, _ = _time_round(spec, base, payloads)
        ref = AggServer(spec, base)
        for p in payloads:
            ref.ingest_frame(p)
        ref.tick()
        ref.seal()
        pf = ref.published()[0]
        round_us, ingress = [], 0
        for it in range(4):
            tree = AggTree(spec, base, fanout=fanout, tiers=1)
            t0 = time.perf_counter()
            for p in payloads:
                tree.ingest_frame(p)
            tree.tick()
            tree.seal()
            for _ in range(8):
                tree.tick()
                if tree.published():
                    break
            t1 = time.perf_counter()
            pt = tree.published()[0]
            assert pt.accepted == pf.accepted
            assert np.array_equal(pt.mean.view(np.uint32),
                                  pf.mean.view(np.uint32))
            ingress = tree.root_ingress_payloads
            assert ingress <= fanout, (ingress, fanout)
            if it > 0:
                round_us.append((t1 - t0) * 1e6)
        us = float(obs.quantile(round_us, 50))
        emit(f"agg_tree_fanout{fanout}", us,
             f"d={D};clients={TREE_CLIENTS};tiers=1;"
             f"root_ingress_payloads={ingress};fanout_bound={fanout};"
             f"rounds_per_s={1e6 / us:.1f};flat_round_us={flat_us:.0f};"
             f"tree_vs_flat={us / flat_us:.2f}x;bit_identical=1")


def main():
    spec0, _, _ = _make_round(8)
    bpc = wire.payload_bytes(spec0)
    fp32 = 4 * D
    for n in CLIENT_COUNTS:
        spec, base, payloads = _make_round(n)
        us_round, us_rx = _time_round(spec, base, payloads)
        pps = n / (us_round / 1e6)
        emit(f"agg_round_c{n}", us_round,
             f"d={D};payloads_per_s={pps:.0f};bytes_per_client={bpc};"
             f"wire_compression={fp32 / bpc:.1f}x")
        if n == CLIENT_COUNTS[-1]:
            emit(f"agg_receive_c{n}", us_rx,
                 f"d={D};receive_only_per_payload")
    chunked_rounds()
    streaming_rounds()
    tree_fanout()
    engine_openloop()


if __name__ == "__main__":
    main()
