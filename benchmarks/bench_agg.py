"""Aggregation-service benchmarks: receive-path throughput, round latency vs
client count, and wire bytes per client (the repro.agg protocol over the
packed lattice wire format; interpret-mode kernel timings on CPU)."""
import time

import numpy as np

from benchmarks.common import emit
from repro.agg import wire
from repro.agg.server import AggServer
from repro.agg.sim import fleet_payloads
from repro.dist.collectives import QSyncConfig

D = 4096
CLIENT_COUNTS = (64, 256, 512)


def _make_round(n_clients: int, seed: int = 0):
    spec = wire.RoundSpec(round_id=seed + 1, d=D,
                          cfg=QSyncConfig(q=16, bucket=512), y0=0.5,
                          seed=seed)
    rng = np.random.RandomState(seed)
    base = rng.randn(D).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(n_clients, D).astype(np.float32)
    return spec, base, fleet_payloads(spec, xs)


def _time_round(spec, base, payloads, iters: int = 3) -> "tuple[float, float]":
    """(us per full round, us per receive call); first round warms the jit
    caches for this client count."""
    rx_us, round_us = [], []
    for it in range(iters + 1):
        server = AggServer(spec, base)
        t0 = time.perf_counter()
        for p in payloads:
            server.receive(p)
        t1 = time.perf_counter()
        server.drain()
        server.finalize()
        t2 = time.perf_counter()
        if it == 0:
            continue
        rx_us.append((t1 - t0) / len(payloads) * 1e6)
        round_us.append((t2 - t0) * 1e6)
    return float(np.median(round_us)), float(np.median(rx_us))


def main():
    spec0, _, _ = _make_round(8)
    bpc = wire.payload_bytes(spec0)
    fp32 = 4 * D
    for n in CLIENT_COUNTS:
        spec, base, payloads = _make_round(n)
        us_round, us_rx = _time_round(spec, base, payloads)
        pps = n / (us_round / 1e6)
        emit(f"agg_round_c{n}", us_round,
             f"d={D};payloads_per_s={pps:.0f};bytes_per_client={bpc};"
             f"wire_compression={fp32 / bpc:.1f}x")
        if n == CLIENT_COUNTS[-1]:
            emit(f"agg_receive_c{n}", us_rx,
                 f"d={D};receive_only_per_payload")


if __name__ == "__main__":
    main()
