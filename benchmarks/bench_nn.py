"""Paper Experiment 7 (Figures 12-13 analogue): NN training with compressed
gradients.  Offline container: a 2-layer MLP classifier on a synthetic
10-class problem at 4 bits/coord (the claim validated is the *ordering*:
LQ competitive with QSGD, far above EFSign at 1 bit).

Also hosts the ``fsdp_overlap`` row: serial vs prefetched FSDP trainer step
time on an emulated 8-device CPU mesh, plus the HLO overlap auditor's
``collective_exposed_fraction`` for both programs.  That probe needs its own
process (XLA device-count flag must be set before jax initializes), so it is
run via subprocess — see benchmarks/fsdp_overlap_probe.py."""
import json
import os
import subprocess
import sys

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.compressors import (LatticeQ, QSGD, EFSign, CompressorCtx,
                                    ef_roundtrip)


def make_data(n=2048, d=24, classes=10, seed=0, center_seed=0):
    centers = jax.random.normal(jax.random.PRNGKey(center_seed),
                                (classes, d)) * 0.42
    key = jax.random.PRNGKey(seed + 1000)
    ys = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, classes)
    xs = centers[ys] + 1.3 * jax.random.normal(jax.random.fold_in(key, 2), (n, d))
    return xs, ys


def mlp_init(key, d=24, h=64, classes=10):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (d, h)) * 0.1,
            "w2": jax.random.normal(k2, (h, classes)) * 0.1}


def loss_fn(p, xs, ys):
    h = jax.nn.relu(xs @ p["w1"])
    logits = h @ p["w2"]
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(ys)), ys])


def accuracy(p, xs, ys):
    h = jax.nn.relu(xs @ p["w1"])
    return float(jnp.mean(jnp.argmax(h @ p["w2"], -1) == ys))


def run(comp_name, steps=120, n=2, lr=0.15):
    xs, ys = make_data()
    xv, yv = make_data(512, seed=9)
    p = mlp_init(jax.random.PRNGKey(0))
    flat0, tree = jax.flatten_util.ravel_pytree(p)
    ef_err = jnp.zeros_like(flat0)
    grad = jax.jit(jax.grad(loss_fn))
    y = None
    for t in range(steps):
        key = jax.random.PRNGKey(10_000 + t)
        perm = jax.random.permutation(key, len(ys))[:512]
        halves = perm.reshape(n, -1)
        gs = []
        for i in range(n):
            g = grad(p, xs[halves[i]], ys[halves[i]])
            gs.append(jax.flatten_util.ravel_pytree(g)[0])
        gs = jnp.stack(gs)
        if comp_name == "fp32":
            gm = gs.mean(0)
        elif comp_name == "efsign":
            gm, ef_err = ef_roundtrip(EFSign(), gs.mean(0), ef_err,
                                      CompressorCtx())
        else:
            comp = LatticeQ(q=16) if comp_name == "lq" else QSGD(qlevel=16)
            if y is None:
                y = 3.0 * float(jnp.max(jnp.abs(gs[0] - gs[1]))) + 1e-9
            ctx = CompressorCtx(y=y)
            zs = [comp.roundtrip(gs[i], ctx, jax.random.fold_in(key, i),
                                 anchor=gs[1 - i]) for i in range(n)]
            gm = jnp.stack(zs).mean(0)
            y = 3.0 * float(jnp.max(jnp.abs(gs[0] - gs[1]))) + 1e-9
        p = tree(jax.flatten_util.ravel_pytree(p)[0] - lr * gm)
    return accuracy(p, xv, yv)


def run_fsdp_overlap():
    """Serial vs prefetched FSDP step on an 8-device CPU mesh (subprocess —
    the probe sets XLA_FLAGS before importing jax).  Returns the probe's
    RESULT dict; the probe itself asserts bit-identity, exposed-fraction
    improvement, and zero sharded-anchor state bytes."""
    probe = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "fsdp_overlap_probe.py")
    proc = subprocess.run([sys.executable, probe, "--check"],
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"fsdp_overlap probe failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line from probe:\n{proc.stdout}")


def main():
    accs = {}
    for name in ("fp32", "lq", "qsgd", "efsign"):
        accs[name] = run(name)
        emit(f"exp7_nn_{name}", 0.0, f"val_acc={accs[name]:.3f}")
    assert accs["lq"] > accs["fp32"] - 0.08, accs
    assert accs["lq"] >= accs["efsign"] - 0.02, accs

    r = run_fsdp_overlap()
    assert r["exposed_prefetch"] < r["exposed_serial"], r
    assert r["anchor_state_bytes"] == 0, r
    emit("fsdp_overlap", r["prefetch_us"],
         f"serial_us={r['serial_us']:.1f};step_ratio={r['step_ratio']:.3f};"
         f"exposed_serial={r['exposed_serial']:.3f};"
         f"exposed_prefetch={r['exposed_prefetch']:.3f};"
         f"anchor_state_bytes={r['anchor_state_bytes']}")


if __name__ == "__main__":
    main()
