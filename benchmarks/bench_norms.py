"""Paper Experiment 1 (Figures 1-2): input distance vs input norm during
least-squares GD — the quantities that drive each scheme's error."""
import jax.numpy as jnp
import jax

from benchmarks.common import (emit, least_squares_problem, batch_grads,
                               full_grad)


def main():
    A, b, w_star = least_squares_problem()
    w = jnp.zeros_like(w_star)
    rows = []
    for t in range(30):
        gs = batch_grads(A, b, w, 2, jax.random.PRNGKey(t))
        g0, g1 = gs[0], gs[1]
        rows.append((
            float(jnp.linalg.norm(g0 - g1)),          # ||g0-g1||_2  (ours, y)
            float(jnp.max(jnp.abs(g0 - g1))),         # ||g0-g1||_inf (cubic)
            float(jnp.linalg.norm(g0)),               # ||g0||_2  (QSGD-L2)
            float(jnp.max(g0) - jnp.min(g0)),         # max-min   (QSGD impl)
        ))
        w = w - 0.05 * full_grad(A, b, w)
    import numpy as np
    r = np.array(rows)
    means = r.mean(axis=0)
    # headline: distance-based quantities are far below norm-based ones
    ratio_l2 = means[2] / means[0]
    emit("exp1_norms_dist_l2", 0.0, f"mean={means[0]:.4f}")
    emit("exp1_norms_dist_linf", 0.0, f"mean={means[1]:.4f}")
    emit("exp1_norms_grad_l2", 0.0, f"mean={means[2]:.4f}")
    emit("exp1_norms_maxmin", 0.0, f"mean={means[3]:.4f}")
    emit("exp1_norm_over_distance", 0.0, f"ratio={ratio_l2:.1f}x")
    assert ratio_l2 > 3, "paper claim: distance << norm in this regime"


if __name__ == "__main__":
    main()
