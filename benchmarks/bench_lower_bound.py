"""Paper §8 (Theorems 6/8): empirical error-vs-bits against the
information-theoretic wall Var >= Omega(y^2 * 2^(-2b/d))."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.compressors import LatticeQ, CompressorCtx
from repro.core import mean_estimation_star


def main():
    d, n, y = 256, 4, 1.0
    mu = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 50
    xs = mu + (y / 4) * jax.random.normal(jax.random.PRNGKey(1), (n, d))
    yb = float(2 * jnp.max(jnp.abs(xs - xs.mean(0))))
    for q in (4, 16, 64, 256):
        bits_per_coord = int(np.log2(q))
        mses = []
        for t in range(5):
            res = mean_estimation_star(xs, yb, LatticeQ(q=q),
                                       jax.random.PRNGKey(10 + t),
                                       CompressorCtx(y=yb))
            mses.append(float(jnp.mean((res.est[0] - xs.mean(0)) ** 2)))
        mse = np.mean(mses)
        # lower bound per coordinate: c * y^2 * 2^(-2b) (b bits per coord)
        wall = (yb ** 2) * 2.0 ** (-2 * bits_per_coord) / 48
        emit(f"lb_q{q}", 0.0,
             f"mse={mse:.3e};wall={wall:.3e};gap={mse/max(wall,1e-15):.1f}x")
        assert mse > wall * 0.8, "no scheme may beat the lower bound"


if __name__ == "__main__":
    main()
