"""Roofline table from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs        / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes        / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw              (per chip)

HLO_FLOPs / bytes / collective bytes are already *per device* (the dry-run
lowers the shard_map-local program and hlo_analysis expands loop trip
counts), so the "/(chips x ...)" in the assignment's formulas is applied by
construction.  Hardware constants: TPU v5e-class — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (dense train; N = params, D = tokens) or 6*N_active*D
(MoE); serve steps use 2*N*D_new + attention cache reads.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one new token per sequence
    "long_500k": 1,
}


def model_flops(rec: dict) -> float:
    """Global model flops for the step (then divided by chips)."""
    n_active = rec["active_params_B"] * 1e9
    toks = SHAPE_TOKENS[rec["shape"]]
    if rec["shape"] == "train_4k":
        return 6.0 * n_active * toks
    return 2.0 * n_active * toks


def chips(rec: dict) -> int:
    m = rec["mesh"]
    c = 1
    for v in m.values():
        c *= v
    return c


def load(results_dir: str = "results/dryrun", tag: str = "") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        r = json.load(open(p))
        # tag filter applies uniformly — skipped records from other tags
        # used to leak into every report
        if (r.get("tag") or "") != tag:
            continue
        r["_file"] = p
        out.append(r)
    return out


def terms(rec: dict) -> dict:
    coll_bytes = sum(v for k, v in rec["collectives"].items()
                     if not k.endswith("_count"))
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["traffic_bytes"] / HBM_BW
    t_coll = coll_bytes / LINK_BW
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(rec) / chips(rec)
    useful = mf / rec["flops"] if rec["flops"] else 0.0
    bound = max(t_compute, t_memory, t_coll)
    ideal = mf / PEAK_FLOPS
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom[0],
        "model_flops_per_chip": mf, "useful_ratio": useful,
        "roofline_fraction": (ideal / bound) if bound > 0 else 0.0,
        "step_lower_bound_s": bound,
        # fraction of loop-collective bytes on the critical path (HLO
        # overlap auditor); None for records predating the field
        "exposed_fraction": rec.get("collective_exposed_fraction"),
    }


def _fmt_exposed(t: dict) -> str:
    e = t.get("exposed_fraction")
    return "-" if e is None else f"{e:.2f}"


def fmt_row(rec: dict) -> str:
    mesh = "2pod" if rec["multi_pod"] else "1pod"
    if rec.get("skipped"):
        return (f"| {rec['arch']} | {rec['shape']} | {mesh} | — | — | — | "
                f"skip | — | — | — | {rec['reason'][:40]} |")
    t = terms(rec)
    peak = rec["memory"]["peak_bytes"] / 2 ** 30
    return (f"| {rec['arch']} | {rec['shape']} | {mesh} "
            f"| {t['t_compute_s']*1e3:.2f} | {t['t_memory_s']*1e3:.2f} "
            f"| {t['t_collective_s']*1e3:.2f} | {t['dominant']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']*100:.1f}% "
            f"| {_fmt_exposed(t)} | peak {peak:.1f} GiB |")


HEADER = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
          "collective (ms) | dominant | MODEL/HLO | roofline frac | "
          "exposed frac | note |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def main(results_dir: str = "results/dryrun", tag: str = ""):
    recs = load(results_dir, tag)
    if not recs:
        print("roofline: no dry-run results found; run "
              "`python -m repro.launch.dryrun --both-meshes` first")
        return
    print("\n# Roofline (from dry-run)\n")
    print(HEADER)
    for r in recs:
        print(fmt_row(r))
    # CSV for EXPERIMENTS.md tooling
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.csv", "w") as f:
        f.write("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
                "dominant,useful_ratio,roofline_fraction,exposed_fraction,"
                "peak_gib,skipped\n")
        for r in recs:
            mesh = "2pod" if r["multi_pod"] else "1pod"
            if r.get("skipped"):
                f.write(f"{r['arch']},{r['shape']},{mesh},,,,,,,,,1\n")
                continue
            t = terms(r)
            e = t.get("exposed_fraction")
            f.write(f"{r['arch']},{r['shape']},{mesh},{t['t_compute_s']:.6e},"
                    f"{t['t_memory_s']:.6e},{t['t_collective_s']:.6e},"
                    f"{t['dominant']},{t['useful_ratio']:.4f},"
                    f"{t['roofline_fraction']:.4f},"
                    f"{'' if e is None else f'{e:.4f}'},"
                    f"{r['memory']['peak_bytes']/2**30:.2f},0\n")
    print("\nwrote results/roofline.csv")


if __name__ == "__main__":
    import sys
    main(*(sys.argv[1:] or []))
