"""Pallas kernel timings (interpret mode on CPU — correctness-representative,
not TPU wall-clock) + derived wire-compression factors."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref
from repro.core import lattice as L


def main():
    n = 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    u = jax.random.uniform(jax.random.PRNGKey(1), (n,), minval=-.5, maxval=.5)
    for q in (16, 256):
        bits = L.bits_for_q(q)
        t_enc = time_fn(lambda: ops.lattice_encode(x, u, 0.01, q=q), iters=5)
        w = ops.lattice_encode(x, u, 0.01, q=q)
        t_dec = time_fn(lambda: ops.lattice_decode(w, x, u, 0.01, q=q), iters=5)
        comp = 32 / bits
        emit(f"kernel_lattice_encode_q{q}", t_enc,
             f"n={n};wire_compression={comp:.0f}x")
        emit(f"kernel_lattice_decode_q{q}", t_dec, f"n={n}")
    for d in (1024, 8192):
        xb = jax.random.normal(jax.random.PRNGKey(2), (64, d))
        t_k = time_fn(lambda: ops.fwht(xb), iters=5)
        t_r = time_fn(lambda: ref.fwht_ref(xb), iters=5)
        emit(f"kernel_fwht_d{d}", t_k, f"ref_us={t_r:.1f}")


if __name__ == "__main__":
    main()
