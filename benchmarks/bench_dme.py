"""Theorems 2/3 trade-off: bits/coordinate vs achieved variance for
star / tree / butterfly topologies (the paper's communication-variance
frontier)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (LatticeQ, CompressorCtx, mean_estimation_star,
                        mean_estimation_tree, butterfly_mean)


def main():
    d, n = 512, 8
    mu = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 100
    xs = mu + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (n, d))
    y = float(2 * jnp.max(jnp.abs(xs - xs.mean(0))))
    for q in (4, 16, 64):
        comp = LatticeQ(q=q)
        star = mean_estimation_star(xs, y, comp, jax.random.PRNGKey(2),
                                    CompressorCtx(y=y))
        bfly = butterfly_mean(xs, y, comp, jax.random.PRNGKey(3),
                              CompressorCtx(y=y))
        mse_s = float(jnp.mean((star.est[0] - xs.mean(0)) ** 2))
        mse_b = float(jnp.mean((bfly.est[0] - xs.mean(0)) ** 2))
        bits = int(np.log2(q))
        emit(f"dme_tradeoff_q{q}", 0.0,
             f"bits/coord={bits};star_mse={mse_s:.3e};butterfly_mse={mse_b:.3e}")
    tree = mean_estimation_tree(xs, y, m=n, key=jax.random.PRNGKey(4))
    emit("dme_tree_m8", 0.0,
         f"mse={float(jnp.mean((tree.est[0]-xs.mean(0))**2)):.3e}")


if __name__ == "__main__":
    main()
