"""Theorems 2/3 trade-off: bits/coordinate vs achieved variance for
star / tree / butterfly topologies (the paper's communication-variance
frontier) — plus the drifting-mean scenario (ISSUE 4): a large-norm
population mean advancing each round, aggregated over the real multi-round
agg protocol with and without the anchored QState at identical wire bytes.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (LatticeQ, CompressorCtx, mean_estimation_star,
                        mean_estimation_tree, butterfly_mean)


def drifting_mean():
    """Anchored vs unanchored multi-round MSE at equal wire bytes.

    |mu| ~ 1e6 >> spread = 0.05 — the exact regime the paper's distance-
    dependent bounds target: the unanchored path's raw-space coordinates
    (x/s ~ 1e7) blow past f32's mantissa, losing the dither; the anchored
    path (encode x - mean_{k-1}) stays at the lattice floor.  Both run the
    same q/bucket/per-bucket-y, so attempt-0 payloads are byte-identical in
    size.  anchored-strictly-below-unanchored is asserted here (a violation
    fails the module and with it the CI gate); the drift_*_mse values are
    additionally ratcheted against the committed baseline by
    scripts/bench_ci.py's bench_dme MSE gate.
    """
    from repro.agg.sim import MultiRoundConfig, run_rounds
    kw = dict(clients=24, d=2048, bucket=256, rounds=3, norm_scale=1e6,
              y0=0.5, spread0=0.05, concentrate=0.7, seed=0)
    anchored = run_rounds(MultiRoundConfig(anchored=True, **kw))
    plain = run_rounds(MultiRoundConfig(anchored=False, **kw))
    a_mse = float(np.mean([o.mse for o in anchored[1:]]))
    u_mse = float(np.mean([o.mse for o in plain[1:]]))
    bytes_a = anchored[-1].bytes_per_client
    bytes_u = plain[-1].bytes_per_client
    assert bytes_a == bytes_u, (bytes_a, bytes_u)
    assert a_mse < u_mse, (a_mse, u_mse)   # the acceptance criterion
    emit("dme_drift_anchored", 0.0,
         f"drift_anchored_mse={a_mse:.3e};bytes_per_client={bytes_a:.0f};"
         f"rounds={kw['rounds']}")
    emit("dme_drift_unanchored", 0.0,
         f"drift_unanchored_mse={u_mse:.3e};bytes_per_client={bytes_u:.0f};"
         f"rounds={kw['rounds']}")
    emit("dme_drift_gain", 0.0,
         f"anchored_over_unanchored={u_mse / a_mse:.2f}x")


def main():
    d, n = 512, 8
    mu = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 100
    xs = mu + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (n, d))
    y = float(2 * jnp.max(jnp.abs(xs - xs.mean(0))))
    for q in (4, 16, 64):
        comp = LatticeQ(q=q)
        star = mean_estimation_star(xs, y, comp, jax.random.PRNGKey(2),
                                    CompressorCtx(y=y))
        bfly = butterfly_mean(xs, y, comp, jax.random.PRNGKey(3),
                              CompressorCtx(y=y))
        mse_s = float(jnp.mean((star.est[0] - xs.mean(0)) ** 2))
        mse_b = float(jnp.mean((bfly.est[0] - xs.mean(0)) ** 2))
        bits = int(np.log2(q))
        emit(f"dme_tradeoff_q{q}", 0.0,
             f"bits/coord={bits};star_mse={mse_s:.3e};butterfly_mse={mse_b:.3e}")
    tree = mean_estimation_tree(xs, y, m=n, key=jax.random.PRNGKey(4))
    emit("dme_tree_m8", 0.0,
         f"mse={float(jnp.mean((tree.est[0]-xs.mean(0))**2)):.3e}")
    drifting_mean()


if __name__ == "__main__":
    main()
