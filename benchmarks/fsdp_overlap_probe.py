"""Serial vs prefetched FSDP trainer probe (8 emulated CPU devices).

Runs the tiny anchored trainer twice — serial layer scan vs the
double-buffered prefetch scan (``ShardCtx.prefetch``) — and proves three
things in one process:

  1. bit-identity: the per-step losses (and final parameters) of the two
     formulations are bitwise equal for 3 steps;
  2. the overlap is structural, not aspirational: the HLO overlap auditor
     (repro.launch.hlo_analysis.audit_overlap) reports a strictly lower
     ``collective_exposed_fraction`` for the prefetched program;
  3. the sharded anchor moves zero extra state bytes per step
     (fsdp.anchor_bytes_step == 0 vs the legacy replicated equivalent).

Prints one ``RESULT {json}`` line consumed by benchmarks/bench_nn.py and
scripts/bench_ci.py.  Standalone:

  python benchmarks/fsdp_overlap_probe.py [--check]

(--check is implied — every invariant is always asserted; the flag exists
for symmetry with the other CI smoke entrypoints.)

NOTE: must set XLA_FLAGS before jax initializes — keep this module free of
top-level jax-importing imports above the os.environ mutation.
"""
import argparse
import json
import os
import sys
import time

_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import repro  # noqa: F401  (jax compat shims)
import jax
import numpy as np

from repro.dist import fsdp as F
from repro.dist.collectives import QSyncConfig
from repro.launch.hlo_analysis import audit_overlap
from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx, shard_len
from repro.models import transformer as T
from repro.train import data as D
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig, init_state, make_train_step

STEPS = 3
TIMED = 3


def _cfg():
    return ModelConfig(arch="tiny", family="dense", n_layers=4, d_model=64,
                       n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=128)


def _ctx(prefetch: bool) -> ShardCtx:
    return ShardCtx(tp=1, dp=8, qcfg=QSyncConfig(q=16, bucket=128),
                    grad_sync="lq", anchor_grads=True, anchor_sharded=True,
                    prefetch=prefetch)


def run_one(mesh, prefetch: bool):
    cfg, ctx = _cfg(), _ctx(prefetch)
    tc = TrainConfig(steps=STEPS, y0=1.0)
    step_fn, _, _ = make_train_step(cfg, ctx, mesh, OptConfig(lr=1e-2, warmup=5,
                                                              decay_steps=100),
                                    tc)
    dcfg = D.DataConfig(vocab=128, seq_len=32, global_batch=8)
    state = init_state(cfg, ctx, OptConfig(), tc, jax.random.PRNGKey(0))
    losses = []
    for step in range(STEPS):
        state, metrics = step_fn(state, D.batch_at(dcfg, step))
        losses.append(np.asarray(metrics["loss"]).copy())
    # step time: compiled by now; min over TIMED repeats of the same step
    batch = D.batch_at(dcfg, STEPS)
    times = []
    for _ in range(TIMED):
        t0 = time.perf_counter()
        s2, m2 = step_fn(state, batch)
        jax.block_until_ready(m2)
        times.append(time.perf_counter() - t0)
    hlo = step_fn.lower(state, batch).compile().as_text()
    exposed = audit_overlap(hlo).exposed_fraction
    return losses, state, min(times) * 1e6, exposed, (cfg, ctx)


def main():
    argparse.ArgumentParser().parse_known_args()   # accepts --check
    mesh = jax.make_mesh((8, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    losses_s, state_s, us_s, exp_s, _ = run_one(mesh, prefetch=False)
    losses_p, state_p, us_p, exp_p, (cfg, ctx) = run_one(mesh, prefetch=True)

    # 1. bit-identity: losses and final params
    for i, (a, b) in enumerate(zip(losses_s, losses_p)):
        assert a.tobytes() == b.tobytes(), \
            f"step {i} loss differs: serial={a!r} prefetch={b!r}"
    ps, pp = jax.tree.leaves(state_s["params"]), jax.tree.leaves(state_p["params"])
    for a, b in zip(ps, pp):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), \
            "final params differ between serial and prefetched training"

    # 2. the prefetched program's loop collectives are overlapped
    assert exp_p < exp_s, \
        f"exposed fraction did not improve: serial={exp_s} prefetch={exp_p}"

    # 3. sharded anchor: zero extra anchor-state bytes per step
    fcfg = ctx.fsdp_config()
    metas = T.all_metas(cfg, ctx)
    sizes = [8]
    anchor_b = sum(F.anchor_bytes_step(shard_len(m, ctx) * ctx.dp, sizes, fcfg)
                   for grp in metas.values() for m in grp.values())
    assert anchor_b == 0, anchor_b
    import dataclasses
    legacy = dataclasses.replace(fcfg, anchor_sharded=False)
    legacy_b = sum(F.anchor_bytes_step(shard_len(m, ctx) * ctx.dp, sizes,
                                       legacy)
                   for grp in metas.values() for m in grp.values())
    assert legacy_b > 0, legacy_b

    result = {
        "serial_us": round(us_s, 1),
        "prefetch_us": round(us_p, 1),
        "step_ratio": round(us_p / us_s, 4),
        "exposed_serial": round(exp_s, 4),
        "exposed_prefetch": round(exp_p, 4),
        "anchor_state_bytes": anchor_b,
        "anchor_state_bytes_replicated": legacy_b,
        "losses": [float(l) for l in losses_s],
    }
    print("RESULT " + json.dumps(result), flush=True)
    print("FSDP_OVERLAP_OK", flush=True)


if __name__ == "__main__":
    main()
