"""Paper Experiment 6 (Figure 11): Local SGD with compressed model deltas —
RLQSGD on the (non-zero-centered) model differences."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, least_squares_problem, batch_grads, full_grad
from repro.core.compressors import (RotatedLatticeQ, QSGD, CompressorCtx)
from repro.core import rotation as R


def run(comp_name, rounds=8, local_steps=10, n=2):
    d = 128
    A, b, _ = least_squares_problem(S=4096, d=d, seed=3)
    diag = R.rotation_keypair(jax.random.PRNGKey(4), d)
    lr = 0.08 / float(jnp.linalg.norm(A, ord=2) ** 2 / A.shape[0])
    w_global = jnp.zeros((d,))
    qerr = []
    for r in range(rounds):
        deltas = []
        for i in range(n):
            w = w_global
            for s in range(local_steps):
                gs = batch_grads(A, b, w, n, jax.random.PRNGKey(r * 100 + s))
                w = w - lr * gs[i]
            deltas.append(w - w_global)
        deltas = jnp.stack(deltas)
        if comp_name == "fp32":
            mean_d = deltas.mean(0)
        else:
            comp = (RotatedLatticeQ(q=16) if comp_name == "rlq"
                    else QSGD(qlevel=16))
            yr = 2.0 * float(jnp.max(jnp.abs(R.rotate(deltas[0] - deltas[1],
                                                      diag)))) + 1e-9
            ctx = CompressorCtx(y=yr, diag=diag)
            zs = [comp.roundtrip(deltas[i], ctx,
                                 jax.random.PRNGKey(r * 7 + i),
                                 anchor=deltas[1 - i]) for i in range(n)]
            mean_d = jnp.stack(zs).mean(0)
            qerr.append(float(jnp.linalg.norm(jnp.stack(zs) - deltas)))
        w_global = w_global + mean_d
    return float(jnp.mean((A @ w_global - b) ** 2)), (np.mean(qerr) if qerr else 0.0)


def main():
    f_fp, _ = run("fp32")
    f_rlq, e_rlq = run("rlq")
    f_q, e_q = run("qsgd")
    emit("exp6_localsgd", 0.0,
         f"fp32={f_fp:.3e};rlq={f_rlq:.3e};qsgd={f_q:.3e};"
         f"qerr_rlq={e_rlq:.3e};qerr_qsgd={e_q:.3e}")
    assert e_rlq < e_q, "RLQ delta-compression error must beat QSGD"


if __name__ == "__main__":
    main()
