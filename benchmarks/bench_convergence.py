"""Paper Experiment 3 (Figures 5-6): convergence of distributed SGD under
each quantizer (lr=0.8, 3 bits/coord)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, least_squares_problem, batch_grads
from repro.core.compressors import (LatticeQ, QSGD, HadamardUniform,
                                    CompressorCtx)
from repro.core import rotation as R


def run(comp_name, A, b, steps=60, lr=0.8):
    d = A.shape[1]
    diag = R.rotation_keypair(jax.random.PRNGKey(9), d)
    comps = {
        "lq": LatticeQ(q=8), "qsgd_l2": QSGD(qlevel=8),
        "hadamard": HadamardUniform(levels=8), "fp32": None,
    }
    comp = comps[comp_name]
    w = jnp.zeros((d,))
    y = None
    losses = []
    for t in range(steps):
        key = jax.random.PRNGKey(1000 + t)
        gs = batch_grads(A, b, w, 2, key)
        g0, g1 = gs[0], gs[1]
        if comp is None:
            g = (g0 + g1) / 2
        else:
            if y is None:
                y = 1.5 * float(jnp.max(jnp.abs(g0 - g1))) + 1e-9
            ctx = CompressorCtx(y=y, diag=diag)
            z0 = comp.roundtrip(g0, ctx, jax.random.fold_in(key, 1), anchor=g1)
            z1 = comp.roundtrip(g1, ctx, jax.random.fold_in(key, 2), anchor=g0)
            g = (z0 + z1) / 2
            y = 1.5 * float(jnp.max(jnp.abs(z0 - z1))) + 1e-9
        w = w - lr * g / (2 * jnp.linalg.norm(A, ord=2) ** 2 / A.shape[0])
        losses.append(float(jnp.mean((A @ w - b) ** 2)))
    return losses


def main():
    A, b, _ = least_squares_problem(S=2048, d=100)
    finals = {}
    for name in ("fp32", "lq", "qsgd_l2", "hadamard"):
        losses = run(name, A, b)
        finals[name] = losses[-1]
        emit(f"exp3_convergence_{name}", 0.0, f"final_mse={losses[-1]:.3e}")
    assert finals["lq"] < 10 * finals["fp32"] + 1e-6
    assert finals["lq"] <= finals["qsgd_l2"] * 1.5 + 1e-9


if __name__ == "__main__":
    main()
