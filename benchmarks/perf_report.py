"""§Perf report: compare tagged hillclimb variants against baselines."""
from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.roofline import terms, PEAK_FLOPS, HBM_BW, LINK_BW


def load_all(results="results/dryrun"):
    out = {}
    for p in sorted(glob.glob(os.path.join(results, "*.json"))):
        r = json.load(open(p))
        if r.get("skipped"):
            continue
        key = (r["arch"], r["shape"], "2pod" if r["multi_pod"] else "1pod",
               r.get("tag") or "")
        out[key] = r
    return out


def coll_total(r):
    return sum(v for k, v in r["collectives"].items()
               if not k.endswith("_count"))


def grad_sync_bytes(r):
    """collective-permute bytes = the quantized grad RS payload."""
    return r["collectives"].get("collective-permute", 0.0)


def row(r, base=None):
    t = terms(r)
    c = coll_total(r)
    extras = ""
    if base is not None:
        tb = terms(base)
        cb = coll_total(base)
        extras = (f" | Δmem {t['t_memory_s']/max(tb['t_memory_s'],1e-12):.2f}x"
                  f" Δcoll {c/max(cb,1):.2f}x"
                  f" Δpeak {r['memory']['peak_bytes']/max(base['memory']['peak_bytes'],1):.2f}x")
    return (f"compute {t['t_compute_s']*1e3:9.2f} ms | mem {t['t_memory_s']*1e3:9.2f} ms | "
            f"coll {c/LINK_BW*1e3:9.2f} ms | gradwire {grad_sync_bytes(r)/2**20:9.1f} MiB | "
            f"peak {r['memory']['peak_bytes']/2**30:6.2f} GiB | "
            f"roofline {t['roofline_fraction']*100:5.1f}%{extras}")


def main(results="results/dryrun"):
    all_ = load_all(results)
    cells = [
        ("qwen3-32b", "train_4k", "1pod",
         ["fp32sync", "", "q4", "rlq", "mb4", "nosp_mb4"]),
        ("granite-moe-1b-a400m", "train_4k", "1pod",
         ["fp32sync", "", "nosp"]),
        ("glm4-9b", "decode_32k", "1pod", ["", "gqa", "gqa_kvq8"]),
        ("qwen3-32b", "decode_32k", "1pod", ["", "gqa", "gqa_kvq8"]),
        ("nemotron-4-340b", "decode_32k", "1pod", ["", "gqa", "gqa_kvq8"]),
    ]
    for arch, shape, mesh, tags in cells:
        base = all_.get((arch, shape, mesh, tags[0] if tags[0] else ""))
        baseline = all_.get((arch, shape, mesh, ""))
        print(f"\n## {arch} {shape} {mesh}")
        for tag in tags:
            r = all_.get((arch, shape, mesh, tag))
            if r is None:
                print(f"  {tag or 'baseline':12s}: (missing)")
                continue
            ref = baseline if tag else (base if tag == "" else None)
            print(f"  {tag or 'baseline':12s}: {row(r, baseline if tag else None)}")


if __name__ == "__main__":
    main(*sys.argv[1:])
