"""Paper Experiment 4 (Figures 7-8): sublinear-bit variance — our scheme's
simulated variance vs vQSGD cross-polytope at 0.5 bits/coord."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, least_squares_problem, batch_grads
from repro.core.sublinear import simulated_variance, vqsgd_cross_polytope_variance


def main():
    for (S, d) in ((8192, 256), (32768, 256)):
        A, b, _ = least_squares_problem(S=S, d=d, seed=1)
        w = jnp.zeros((d,))
        v_ours, v_vq = [], []
        for t in range(20):
            gs = batch_grads(A, b, w, 2, jax.random.PRNGKey(t))
            g0, g1 = gs[0], gs[1]
            y = 1.6 * float(jnp.max(jnp.abs(g0 - g1))) + 1e-12
            bits_per_coord = 0.5
            v_ours.append(simulated_variance(d, y, bits_per_coord))
            reps = max(1, int(0.5 * d / np.ceil(np.log2(2 * d))))
            v_vq.append(vqsgd_cross_polytope_variance(
                d, float(jnp.linalg.norm(g0)), reps))
            from benchmarks.common import full_grad
            w = w - 0.05 * full_grad(A, b, w)
        emit(f"exp4_sublinear_S{S}", 0.0,
             f"ours={np.mean(v_ours):.4f};vqsgd={np.mean(v_vq):.4f};"
             f"ratio={np.mean(v_vq)/np.mean(v_ours):.2f}")


if __name__ == "__main__":
    main()
