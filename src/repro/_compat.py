"""Compatibility shims for the pinned jax in the CI image.

The codebase is written against the current public JAX API surface:

  * ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
  * ``jax.sharding.AxisType`` (passed to ``jax.make_mesh(axis_types=...)``)

Older jaxlib images (0.4.x) ship ``shard_map`` under ``jax.experimental``
with the ``check_rep`` spelling and a ``make_mesh`` without ``axis_types``.
Importing :mod:`repro` installs the forward-compatible aliases below so the
same sources run on both.  Every shim is a no-op when the host jax already
provides the API.
"""
from __future__ import annotations

import enum
import inspect

import jax


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return
    _orig = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        # axis_types only selects Auto vs Explicit sharding-in-types mode;
        # pre-0.5 jax has Auto-only semantics, so dropping it is faithful.
        return _orig(axis_shapes, axis_names, devices=devices)

    make_mesh.__doc__ = _orig.__doc__
    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        sig = inspect.signature(jax.shard_map)
        if "check_vma" in sig.parameters:
            return
        _sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as _sm

    sm_params = inspect.signature(_sm).parameters
    check_kw = "check_vma" if "check_vma" in sm_params else "check_rep"

    def shard_map(f=None, *, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, check_rep=None, **kw):
        check = check_vma if check_vma is not None else check_rep
        if check is not None:
            kw[check_kw] = check

        def wrap(fn):
            return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       **kw)

        return wrap if f is None else wrap(f)

    jax.shard_map = shard_map


_install_axis_type()
_install_make_mesh()
_install_shard_map()
