"""Multi-round aggregation service: anchored QState + round life-cycle.

Two layers live here (ISSUE 6 split):

**QState keeper** — :class:`AggService` owns everything that persists
ACROSS rounds: round k+1's protocol contract is *derived from the latest
published round's outcome*.

  * **anchor** — round k+1's anchor is the latest published mean (the
    paper's distance-dependent regime: clients encode ``x - mean``, so the
    wire cost depends on how far the population moved, never on ``|mean|``).
    The anchor is pinned in the RoundSpec by its CRC-32 digest; a client
    encoding against a stale anchor is REJECTed rather than silently
    mis-decoded.  Under the continuous-round engine, round k+1 opens while
    round k is still draining, so its anchor is round k-1's mean — the
    anchor lags by exactly the number of concurrently-live rounds minus
    one, and :attr:`Round.anchor_round` records the lag for the staleness
    telemetry.
  * **per-bucket y** — distance bounds come from published decode telemetry
    through :func:`repro.core.qstate.update_y`: buckets implicated in
    decode failures escalate (RobustAgreement per bucket), clean buckets
    relax toward the observed distances.
  * **per-round seed** — every round's wire seed is
    ``rounds.fold_seed(cfg.seed, round_id)``, so no two rounds ever share a
    dither draw while a replay of the same round stays bit-stable.

**Round life-cycle state machine** — :class:`Round` walks one round through

    OPEN ──seal──> SEALING ──all admitted resolved──> DRAINED ──> PUBLISHED

  * ``OPEN``      — admitting new clients (intake).
  * ``SEALING``   — closed to NEW clients at cutover (quorum or deadline,
    the engine's policy); already-admitted clients keep full service:
    outstanding chunks, selective retransmits and escalation retries all
    still land (the overlapping drain).
  * ``DRAINED``   — every admitted client resolved (accepted /
    escalation-exhausted / expired by the straggler deadline); the round
    mean is now determined.
  * ``PUBLISHED`` — finalized; the mean fed back into the QState.  Rounds
    publish strictly in round-id order (the anchor chain is sequential).

Transitions are one-way and guarded — an illegal transition raises, so a
driver bug cannot silently publish a half-drained round.

Lockstep usage (one round at a time, the historical API)::

    svc = AggService(ServiceConfig(d=4096, bucket=512, y0=0.5))
    for _ in range(rounds):
        spec, anchor = svc.begin_round()
        server = svc.make_server()
        ... feed payloads from AggClient(spec, cid, x, anchor=anchor) ...
        mean, stats = svc.end_round(server)

Continuous usage (overlapping rounds) goes through
:class:`repro.agg.engine.AggEngine`, which drives ``open_round`` /
``publish_round`` directly off quorum, deadline and straggler events.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

import repro.obs as _obs
from repro.agg import rounds
from repro.agg.transport import frame as wire
from repro.agg.server import AggServer, RoundStats
from repro.core import qstate as QS
from repro.dist.collectives import QSyncConfig, flat_size_padded


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static config of a multi-round aggregation service."""
    d: int
    q: int = 16
    bucket: int = 512
    rotate: bool = False
    y0: float = 1.0
    seed: int = 0
    max_attempts: int = 4
    anchored: bool = True       # False: every round keeps the zero anchor
                                # (the historical raw-input protocol)
    mtu: int = 0                # transport chunk size in bytes (0: one
                                # frame per payload; see agg.transport)
    window: int = 0             # send-window credit in chunks (0: blast;
                                # >0 needs mtu>0 and turns on the server's
                                # streaming decode — see agg.transport)
    y_decay: float = 0.75       # per-round relaxation toward measured dist
    y_escalate: float = 2.0     # per-bucket escalation on decode failure
    y_floor: float = 1e-6

    @property
    def qcfg(self) -> QSyncConfig:
        return QSyncConfig(q=self.q, bucket=self.bucket, rotate=self.rotate)

    @property
    def nb(self) -> int:
        return flat_size_padded(self.d, self.qcfg) // self.bucket


class RoundState(enum.Enum):
    OPEN = "open"            # admitting new clients
    SEALING = "sealing"      # cut over: draining admitted clients only
    DRAINED = "drained"      # every admitted client resolved
    PUBLISHED = "published"  # mean finalized and fed into the QState


class Round:
    """One aggregation round's life-cycle around its :class:`AggServer`.

    Created by :meth:`AggService.open_round`; the engine (or the legacy
    lockstep wrappers) drives the transitions.  Timestamps are whatever
    clock the driver passes (the sim uses virtual seconds) and feed the
    p50/p99 round-latency and staleness telemetry.
    """

    def __init__(self, spec: wire.RoundSpec, anchor: np.ndarray,
                 server: AggServer, anchor_round: int, opened_at: float = 0.0):
        self.spec = spec
        self.anchor = anchor              # the server's reference vector
        self.server = server
        self.anchor_round = anchor_round  # round whose published mean this
                                          # round anchors against (0 = warm
                                          # start / zero anchor)
        self.state = RoundState.OPEN
        self.opened_at = opened_at
        self.sealed_at: Optional[float] = None
        self.drained_at: Optional[float] = None
        self.published_at: Optional[float] = None
        self.mean: Optional[np.ndarray] = None
        self.stats: Optional[RoundStats] = None

    @property
    def round_id(self) -> int:
        return self.spec.round_id

    @property
    def client_anchor(self) -> "Optional[np.ndarray]":
        """What clients must encode against (None in unanchored rounds)."""
        return self.anchor if self.spec.anchored else None

    def _expect(self, state: RoundState) -> None:
        if self.state is not state:
            raise RuntimeError(
                f"round {self.round_id}: illegal transition from "
                f"{self.state.value} (expected {state.value})")

    def seal(self, now: float = 0.0, next_round_id: int = 0) -> None:
        """OPEN -> SEALING: stop admitting new clients (cutover).

        ``next_round_id`` is the round now open for admission — late
        newcomers' non-terminal RETRY responses point there."""
        self._expect(RoundState.OPEN)
        self.server.seal(next_round_id)
        self.state = RoundState.SEALING
        self.sealed_at = now
        self._trace_state(now)

    def mark_drained(self, now: float = 0.0) -> None:
        """SEALING -> DRAINED: every admitted client has an outcome."""
        self._expect(RoundState.SEALING)
        if self.server.unresolved:
            raise RuntimeError(
                f"round {self.round_id}: {len(self.server.unresolved)} "
                f"admitted clients still unresolved")
        self.state = RoundState.DRAINED
        self.drained_at = now
        self._trace_state(now)

    def publish(self, now: float = 0.0) -> "tuple[np.ndarray, RoundStats]":
        """Walk whatever remains of the life-cycle and finalize.

        From OPEN/SEALING this is the forced path (legacy lockstep end, or
        the engine's drain deadline): still-unresolved stragglers are
        expired WITHOUT a verdict — their state is dropped, they were never
        accepted, and they may enroll in a later round — then the mean over
        the accepted clients is finalized.  Idempotent once PUBLISHED."""
        if self.state is RoundState.PUBLISHED:
            return self.mean, self.stats
        if self.state is RoundState.OPEN:
            self.seal(now)
        if self.state is RoundState.SEALING:
            # staged payloads get decoded (and their senders a verdict)
            # before anyone is written off as a straggler
            self.server.drain()
            for cid in self.server.unresolved:
                self.server.expire_client(cid)
            self.mark_drained(now)
        self._expect(RoundState.DRAINED)
        self.mean, self.stats = self.server.finalize()
        self.state = RoundState.PUBLISHED
        self.published_at = now
        self._trace_state(now)
        return self.mean, self.stats

    def _trace_state(self, now: float) -> None:
        if _obs.tracing_enabled():
            _obs.tracer().event("state", parent=("round", self.round_id),
                                t=now, round=self.round_id,
                                state=self.state.value)


class AggService:
    """Coordinates successive anchored rounds of federated DME."""

    def __init__(self, cfg: ServiceConfig, anchor0=None):
        """``anchor0``: optional warm-start reference for round 1 (e.g. the
        previous model state in a federated-learning deployment); None
        starts from the zero anchor."""
        self.cfg = cfg
        self.round_id = 0               # last round OPENED
        self.published_id = 0           # last round PUBLISHED (in order)
        self.y = np.full((cfg.nb,), cfg.y0, np.float32)
        self.anchor: Optional[np.ndarray] = (
            None if anchor0 is None else np.asarray(anchor0, np.float32))
        self.anchor_round = 0           # round that produced self.anchor
        self.history: list[RoundStats] = []
        self._legacy: Optional[Round] = None

    # ------------------------------------------------------ LIFECYCLE API
    def open_round(self, now: float = 0.0,
                   max_pending: "int | None" = None) -> Round:
        """Open round k+1 against the CURRENT QState and return its Round.

        May be called while earlier rounds are still sealing/draining (the
        engine's overlapping intake) — the new round simply anchors against
        the latest *published* mean, and :attr:`Round.anchor_round` records
        the lag.  ``max_pending`` bounds the server's pending store
        (admission control)."""
        self.round_id += 1
        digest = (rounds.anchor_digest(self.anchor)
                  if self.cfg.anchored and self.anchor is not None else 0)
        spec = wire.RoundSpec(
            round_id=self.round_id, d=self.cfg.d, cfg=self.cfg.qcfg,
            y0=float(self.y.max()),
            # per-round seed: fold the round id in (no cross-round dither
            # reuse; replays of the same round stay bit-stable)
            seed=rounds.fold_seed(self.cfg.seed, self.round_id),
            max_attempts=self.cfg.max_attempts,
            y_buckets=tuple(float(v) for v in self.y),
            anchor_digest=digest, mtu=self.cfg.mtu,
            window=self.cfg.window)
        # anchored: decode in anchor-relative space.  Unanchored: the last
        # published mean still serves as the *decode reference* (clients
        # encode raw x; the reference realizes the distance bound server-
        # side), so anchored-vs-unanchored isolates encode-side anchoring.
        ref = (self.anchor if self.anchor is not None
               else np.zeros((self.cfg.d,), np.float32))
        server = AggServer(spec, ref, max_pending=max_pending)
        return Round(spec, ref, server, anchor_round=self.anchor_round,
                     opened_at=now)

    def publish_round(self, rnd: Round, now: float = 0.0
                      ) -> "tuple[np.ndarray, RoundStats]":
        """Publish a round and advance the QState.

        anchor <- the round mean (when anchored); y <- per-bucket update
        from the round's decode telemetry (escalate failed buckets, relax
        clean ones toward the measured distances).  Rounds MUST publish in
        round-id order — the anchor chain is sequential, and an
        out-of-order publish would silently re-anchor later rounds against
        an older mean than their spec digest promises."""
        if rnd.round_id != self.published_id + 1:
            raise RuntimeError(
                f"round {rnd.round_id} published out of order (last "
                f"published {self.published_id})")
        mean, stats = rnd.publish(now)
        # the published mean always becomes the next reference; with
        # cfg.anchored it is additionally pinned (digest) and subtracted
        # client-side
        self.anchor = np.asarray(mean, np.float32)
        self.anchor_round = rnd.round_id
        self.y = np.asarray(QS.update_y(
            self.y, stats.fails_b, stats.dist_b, decay=self.cfg.y_decay,
            escalate=self.cfg.y_escalate, floor=self.cfg.y_floor), np.float32)
        self.history.append(stats)
        self.published_id = rnd.round_id
        return mean, stats

    # ------------------------------------------- LOCKSTEP (one-round) API
    def begin_round(self) -> "tuple[wire.RoundSpec, Optional[np.ndarray]]":
        """Open round k+1 lockstep-style: returns (spec, anchor or None).

        The spec carries the per-bucket sides derived from the tracked y
        state and the digest of the anchor — both published out of band to
        the fleet along with the anchor itself."""
        self._legacy = self.open_round()
        return self._legacy.spec, self._legacy.client_anchor

    def make_server(self) -> AggServer:
        """The open lockstep round's server."""
        assert self._legacy is not None, "begin_round() first"
        return self._legacy.server

    def end_round(self, server: AggServer
                  ) -> "tuple[np.ndarray, RoundStats]":
        """Close the lockstep round: finalize, advance the QState."""
        assert self._legacy is not None, "begin_round() first"
        assert server is self._legacy.server, \
            "end_round() got a server from a different round"
        rnd, self._legacy = self._legacy, None
        return self.publish_round(rnd)
