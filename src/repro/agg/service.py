"""Multi-round aggregation service: anchored QState threaded across rounds.

The missing piece between single-round :class:`repro.agg.server.AggServer`
and a deployable service: round k+1's protocol contract is *derived from
round k's outcome*.

  * **anchor** — round k+1's anchor is round k's published mean (the
    paper's distance-dependent regime: clients encode ``x - mean_{k-1}``,
    so the wire cost depends on how far the population moved, never on
    ``|mean|``).  The anchor is pinned in the RoundSpec by its CRC-32
    digest; a client encoding against a stale anchor is REJECTed rather
    than silently mis-decoded.
  * **per-bucket y** — round k+1's distance bounds come from round k's
    decode telemetry through :func:`repro.core.qstate.update_y`: buckets
    implicated in decode failures escalate (RobustAgreement per bucket),
    clean buckets relax toward the observed distances — so the granularity
    tightens as the population concentrates, round over round, without any
    out-of-band tuning.

Usage::

    svc = AggService(ServiceConfig(d=4096, bucket=512, y0=0.5))
    for _ in range(rounds):
        spec, anchor = svc.begin_round()
        server = svc.make_server()
        ... feed payloads from AggClient(spec, cid, x, anchor=anchor) ...
        mean, stats = svc.end_round(server)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.agg import rounds, wire
from repro.agg.server import AggServer, RoundStats
from repro.core import qstate as QS
from repro.dist.collectives import QSyncConfig, flat_size_padded


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static config of a multi-round aggregation service."""
    d: int
    q: int = 16
    bucket: int = 512
    rotate: bool = False
    y0: float = 1.0
    seed: int = 0
    max_attempts: int = 4
    anchored: bool = True       # False: every round keeps the zero anchor
                                # (the historical raw-input protocol)
    mtu: int = 0                # transport chunk size in bytes (0: one
                                # frame per payload; see agg.transport)
    y_decay: float = 0.75       # per-round relaxation toward measured dist
    y_escalate: float = 2.0     # per-bucket escalation on decode failure
    y_floor: float = 1e-6

    @property
    def qcfg(self) -> QSyncConfig:
        return QSyncConfig(q=self.q, bucket=self.bucket, rotate=self.rotate)

    @property
    def nb(self) -> int:
        return flat_size_padded(self.d, self.qcfg) // self.bucket


class AggService:
    """Coordinates successive anchored rounds of federated DME."""

    def __init__(self, cfg: ServiceConfig, anchor0=None):
        """``anchor0``: optional warm-start reference for round 1 (e.g. the
        previous model state in a federated-learning deployment); None
        starts from the zero anchor."""
        self.cfg = cfg
        self.round_id = 0
        self.y = np.full((cfg.nb,), cfg.y0, np.float32)
        self.anchor: Optional[np.ndarray] = (
            None if anchor0 is None else np.asarray(anchor0, np.float32))
        self.history: list[RoundStats] = []
        self._spec: Optional[wire.RoundSpec] = None

    # ----------------------------------------------------------- ROUND API
    def begin_round(self) -> "tuple[wire.RoundSpec, Optional[np.ndarray]]":
        """Open round k+1: returns (spec, anchor vector or None).

        The spec (RoundSpec v2) carries the per-bucket sides derived from
        the tracked y state and the digest of the anchor — both published
        out of band to the fleet along with the anchor itself.
        """
        self.round_id += 1
        digest = (rounds.anchor_digest(self.anchor)
                  if self.cfg.anchored and self.anchor is not None else 0)
        self._spec = wire.RoundSpec(
            round_id=self.round_id, d=self.cfg.d, cfg=self.cfg.qcfg,
            y0=float(self.y.max()), seed=self.cfg.seed,
            max_attempts=self.cfg.max_attempts,
            y_buckets=tuple(float(v) for v in self.y),
            anchor_digest=digest, mtu=self.cfg.mtu)
        return self._spec, (self.anchor if digest else None)

    def make_server(self) -> AggServer:
        """The round's server.

        Anchored: decodes in anchor-relative space (the round anchor,
        digest-checked).  Unanchored: the previous round's mean still serves
        as the *decode reference* (the historical protocol — clients encode
        raw x and the reference realizes the distance bound server-side),
        so an anchored-vs-unanchored comparison isolates the encode-side
        anchoring.
        """
        assert self._spec is not None, "begin_round() first"
        ref = (self.anchor if self.anchor is not None
               else np.zeros((self.cfg.d,), np.float32))
        return AggServer(self._spec, ref)

    def end_round(self, server: AggServer
                  ) -> "tuple[np.ndarray, RoundStats]":
        """Close the round: finalize, advance the QState.

        anchor <- the round mean (when anchored); y <- per-bucket update
        from the round's decode telemetry (escalate failed buckets, relax
        clean ones toward the measured distances).
        """
        assert self._spec is not None, "begin_round() first"
        mean, stats = server.finalize()
        # the published mean always becomes the next reference; with
        # cfg.anchored it is additionally pinned (digest) and subtracted
        # client-side
        self.anchor = np.asarray(mean, np.float32)
        self.y = np.asarray(QS.update_y(
            self.y, stats.fails_b, stats.dist_b, decay=self.cfg.y_decay,
            escalate=self.cfg.y_escalate, floor=self.cfg.y_floor), np.float32)
        self.history.append(stats)
        self._spec = None
        return mean, stats
