"""Event-driven continuous-round aggregation engine (ISSUE 6 tentpole).

The lockstep coordinator treated a round as a roster: a fixed set of
clients, drained when everyone landed — so one straggler stalled the mean
every other client was waiting to anchor against.  Here a round is a
*time/quorum window over whoever shows up* (the JetStream continuous-
batching shape: interleaved intake and drain slots; the client-sampling
regime of Suresh et al. 2017): the engine keeps several live
:class:`~repro.agg.service.Round` instances keyed by ``round_id``, routes
every arriving frame by its self-describing header
(:func:`repro.agg.transport.frame.peek_route` — no trust needed, a lying
header just fails its CRC at the server it routes to), and turns rounds
over on **quorum-or-deadline** instead of client count:

* the OPEN round admits newcomers; the moment ``quorum`` distinct clients
  are admitted — or ``round_deadline`` elapses with at least
  ``min_clients`` — it **seals** and the next round opens immediately, so
  frames addressed to round k+1 are accepted while round k is still
  sealing/draining;
* SEALING rounds serve only their admitted clients (outstanding chunks,
  selective retransmits, escalation retries — the overlapping drain); an
  admitted client idle past ``straggler_deadline`` consumes one unit of a
  per-client ``STATUS_RESEND`` budget (``max_resends``), after which it is
  **expired**: its state is dropped without a verdict and the round can
  drain without it;
* rounds **publish strictly in round-id order** — when every admitted
  client resolves, or at ``drain_deadline`` after the seal, whichever
  comes first — and each published mean feeds the service QState (the
  anchor chain);
* **admission control + backpressure**: the per-round pending store is
  bounded (``max_pending``), and the live-round window is bounded
  (``max_live_rounds`` — the oldest round is force-published rather than
  letting the window grow).  A frame that cannot be admitted — new client
  after the seal, store full, or a round no longer (or not yet) live —
  draws a non-terminal ``STATUS_RETRY`` naming the round currently open
  for admission.  No admission decision is ever a terminal verdict: a
  client can only reach ``gave_up`` by exhausting its own escalation
  ladder (PR 5's invariant, extended to time).

The correctness gate is unchanged since PR 3: every published round mean
is bit-identical to ``allgather_allreduce_mean`` over that round's
accepted clients, under any arrival order, chunking, loss and
overlapping-round interleaving — the engine only decides *which* clients
make a round, never *how* they are summed (integer accumulation stays
exact and order-free).

Streaming rounds (v5, ``ServiceConfig.window > 0``) change what a SEALING
round *holds*, not how the engine drives it: each server folds validated
chunk ranges on arrival and ACKs clients at stream completion, so by the
time a round reaches DRAINED there is no body-sized backlog waiting on the
batched decode — the overlapping-drain phase carries only incomplete
streams' held chunks plus the fixed-size fold records, and the pending
store the admission control bounds (``max_pending``) stays near-empty.

The engine is clock-agnostic: every entry point takes ``now`` (the sim
passes virtual seconds, a deployment would pass a monotonic wall clock),
and all policy fires from ``receive``/``advance`` — there are no threads
and no timers, so behavior is deterministic and replayable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import repro.obs as _obs
from repro.agg.api import PublishedLog, PublishedRound  # noqa: F401 (the
#           dataclass moved to repro.agg.api with the AggNode protocol; it
#           is re-exported here for its historical importers)
from repro.agg.transport import frame as wire
from repro.agg.service import AggService, Round, RoundState


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Cutover / drain / admission policy of the continuous-round engine."""
    quorum: int = 64              # seal the open round at this many distinct
                                  # admitted clients (the fast path)
    round_deadline: float = 1.0   # ... or after this long open (the slow
                                  # path), whichever comes first
    min_clients: int = 1          # a deadline cutover needs at least this
                                  # many admitted clients; an emptier round
                                  # re-arms instead of spinning
    straggler_deadline: float = 0.25  # per-client idle time in a sealing
                                      # round before the RESEND budget is
                                      # tapped (and, exhausted, the client
                                      # expires)
    max_resends: int = 2          # deadline-driven STATUS_RESEND budget per
                                  # client per round
    drain_deadline: float = 1.0   # max time a round may seal/drain before
                                  # it is force-published without its
                                  # unresolved stragglers
    max_pending: Optional[int] = None  # per-round pending-store cap
                                       # (admission backpressure)
    max_live_rounds: int = 3      # live (unpublished) round window; the
                                  # oldest is force-published past this


class AggEngine:
    """The continuous-round event loop over an :class:`AggService`.

    Usage (the sim's open-loop driver)::

        eng = AggEngine(AggService(cfg), EngineConfig(...), now=0.0)
        for event_time, frame in arrivals:
            responses += eng.receive(frame, now=event_time)
        responses += eng.advance(now)       # fire time-based policy
        ... eng.published holds the in-order PublishedRound record ...
    """

    def __init__(self, svc: AggService, cfg: EngineConfig, now: float = 0.0):
        if cfg.max_live_rounds < 2:
            raise ValueError("max_live_rounds must be >= 2 (one sealing + "
                             "one open) for overlapping intake")
        self.svc = svc
        self.cfg = cfg
        self.live: "dict[int, Round]" = {}
        self._order: "list[Round]" = []      # oldest ... newest (== open)
        # PublishedLog: a list (``eng.published[k]``, the historical
        # surface) that is also the AggNode verb (``eng.published()``)
        self.published: PublishedLog = PublishedLog()
        self.max_live_seen = 1
        self.retried_unknown_round = 0       # engine-level RETRYs (frames
                                             # for dead/future rounds)
        self._activity: "dict[tuple[int, int], float]" = {}
        self._resends: "dict[tuple[int, int], int]" = {}
        self._publish_times: "dict[int, float]" = {}
        self._open_new(now)

    # ------------------------------------------------------------- STATE
    @property
    def open_round(self) -> Round:
        """The single round currently admitting new clients."""
        return self._order[-1]

    @property
    def live_rounds(self) -> int:
        return len(self._order)

    def _open_new(self, now: float) -> None:
        rnd = self.svc.open_round(now=now, max_pending=self.cfg.max_pending)
        self.live[rnd.round_id] = rnd
        self._order.append(rnd)
        if _obs.tracing_enabled():
            _obs.tracer().begin("round", key=("round", rnd.round_id),
                                t=now, round=rnd.round_id)

    # ------------------------------------------------------------ AggNode
    # The engine's native verbs (receive/advance/published) predate the
    # protocol; these aliases make it a drop-in AggNode so the sim and the
    # examples can drive a flat engine and a tree root interchangeably.
    def ingest_frame(self, data: bytes, now: float = 0.0) -> "list[bytes]":
        """AggNode verb: route one frame (alias of :meth:`receive`)."""
        return self.receive(data, now)

    def tick(self, now: float = 0.0) -> "list[bytes]":
        """AggNode verb: fire due events (alias of :meth:`advance`)."""
        return self.advance(now)

    # ---------------------------------------------------------------- RX
    def receive(self, data: bytes, now: float) -> "list[bytes]":
        """Route one frame; returns every response generated (the frame's
        own, plus any cutover/drain verdicts the event fired)."""
        out = self.advance(now)     # advance() feeds the tracer's clock
        peek = wire.peek_route(data)
        if peek is None:
            # not even a v3 frame prefix: let the open round's server
            # produce the proper wire REJECT (and count it)
            out.append(self.open_round.server.receive(data))
            return out
        round_id, client_id = peek
        rnd = self.live.get(round_id)
        if rnd is None:
            # a round already published (straggler outliving its round) or
            # not yet opened (reordered future traffic): non-terminal —
            # point the client at the round open for admission
            self.retried_unknown_round += 1
            if _obs.metrics_enabled():
                _obs.counter("engine_retried_unknown_round").inc()
            out.append(wire.encode_response(wire.Response(
                status=wire.STATUS_RETRY, round_id=round_id,
                client_id=client_id, attempt_next=0,
                q_next=self.open_round.round_id, y_next=0.0)))
            return out
        out.append(rnd.server.receive(data))
        self._activity[(round_id, client_id)] = now
        if (rnd is self.open_round
                and rnd.server.admitted_count >= self.cfg.quorum):
            out.extend(self.cutover(now, cause="quorum"))
        return out

    # ------------------------------------------------------------ EVENTS
    def advance(self, now: float) -> "list[bytes]":
        """Fire every due time-based event: straggler deadlines and drains
        on sealing rounds, in-order publishing, and deadline cutover."""
        if _obs.tracing_enabled():
            _obs.tracer().feed_time(now)
        out = self._service_sealing(now)
        self._publish_pass(now)
        rnd = self.open_round
        if now - rnd.opened_at >= self.cfg.round_deadline:
            if rnd.server.admitted_count >= self.cfg.min_clients:
                out.extend(self.cutover(now, cause="deadline"))
            else:
                rnd.opened_at = now          # nobody showed up: re-arm
        return out

    def cutover(self, now: float, cause: str = "quorum") -> "list[bytes]":
        """Seal the open round (quorum or deadline met) and open the next.

        The seal-time drain pushes every decodable payload into the
        accumulator and sends the escalation NACKs / chunk RESENDs that
        start the overlapping-drain phase."""
        rnd = self.open_round
        rnd.seal(now, next_round_id=rnd.round_id + 1)
        if _obs.metrics_enabled():
            _obs.counter("engine_cutovers", cause=cause).inc()
        if _obs.tracing_enabled():
            _obs.tracer().event("cutover", parent=("round", rnd.round_id),
                                t=now, round=rnd.round_id, cause=cause,
                                admitted=rnd.server.admitted_count)
        out = rnd.server.drain()
        self._publish_pass(now)
        while len(self._order) >= self.cfg.max_live_rounds:
            # window full: the oldest round leaves now, resolved or not
            head = self._order[0]
            _obs.trigger("forced_publish_window_full", at=now,
                         round=head.round_id,
                         unresolved=len(head.server.unresolved))
            self._publish(head, now, forced=bool(head.server.unresolved))
        self._open_new(now)
        # earlier sealed rounds' RETRY hints follow the admission window
        for r in self._order[:-1]:
            r.server.seal(self.open_round.round_id)
        self.max_live_seen = max(self.max_live_seen, len(self._order))
        return out

    def _service_sealing(self, now: float) -> "list[bytes]":
        """Drains + straggler deadlines for every sealing round."""
        out = []
        for rnd in self._order[:-1]:
            if rnd.state is not RoundState.SEALING:
                continue
            if rnd.server.pending:
                # straggler payloads that completed since the last event:
                # decode them now so their verdicts (and any escalation)
                # go out before the drain deadline
                out.extend(rnd.server.drain())
            for cid in sorted(rnd.server.unresolved):
                key = (rnd.round_id, cid)
                last = self._activity.get(key, rnd.sealed_at)
                if now - last < self.cfg.straggler_deadline:
                    continue
                spent = self._resends.get(key, 0)
                if spent >= self.cfg.max_resends:
                    rnd.server.expire_client(cid)     # no verdict: the
                    continue                          # client may re-enroll
                self._resends[key] = spent + 1
                self._activity[key] = now
                rr = rnd.server.resend_request(cid)
                if rr is not None:
                    out.append(rr)
        return out

    def _publish_pass(self, now: float) -> None:
        """Publish every head-of-line round that is drained (or past its
        drain deadline) — strictly in round-id order."""
        while self._order:
            head = self._order[0]
            if head.state is RoundState.OPEN:
                break
            if not head.server.unresolved:
                if head.state is RoundState.SEALING:
                    head.mark_drained(now)
                self._publish(head, now)
            elif now - head.sealed_at >= self.cfg.drain_deadline:
                # force: expires stragglers
                _obs.trigger("forced_publish_drain_deadline", at=now,
                             round=head.round_id,
                             unresolved=len(head.server.unresolved))
                self._publish(head, now, forced=True)
            else:
                break

    def _publish(self, rnd: Round, now: float, forced: bool = False) -> None:
        anchor = rnd.client_anchor
        mean, stats = self.svc.publish_round(rnd, now)
        self.live.pop(rnd.round_id)
        self._order.remove(rnd)
        self._publish_times[rnd.round_id] = now
        stale = (now - self._publish_times[rnd.anchor_round]
                 if rnd.anchor_round in self._publish_times else 0.0)
        if _obs.metrics_enabled():
            _obs.counter("engine_rounds_published",
                         forced="1" if forced else "0").inc()
            _obs.histogram("round_latency_s").observe(now - rnd.opened_at)
            _obs.gauge("anchor_staleness_s").set(stale)
        self.published.append(PublishedRound(
            round_id=rnd.round_id, spec=rnd.spec, anchor=anchor, mean=mean,
            stats=stats, accepted=rnd.server.accepted_clients,
            opened_at=rnd.opened_at, sealed_at=rnd.sealed_at,
            published_at=now, anchor_round=rnd.anchor_round,
            staleness=stale))
        for key in [k for k in self._activity if k[0] == rnd.round_id]:
            del self._activity[key]
        for key in [k for k in self._resends if k[0] == rnd.round_id]:
            del self._resends[key]

    # ---------------------------------------------------------- SHUTDOWN
    def flush(self, now: float) -> "list[PublishedRound]":
        """End of traffic: seal + force-publish every live round, in order
        (the open round included — its admitted clients get one last
        drain).  Returns the full published history."""
        if _obs.tracing_enabled():
            _obs.tracer().feed_time(now)
        rnd = self.open_round
        if rnd.server.admitted_count:
            rnd.seal(now, next_round_id=rnd.round_id + 1)
            rnd.server.drain()
        for r in list(self._order):
            if r.state is not RoundState.OPEN:
                self._publish(r, now, forced=bool(r.server.unresolved))
        return self.published
