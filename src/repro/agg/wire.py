"""Back-compat facade over the layered transport stack.

The monolithic v2 codec that used to live here was refactored into
:mod:`repro.agg.transport` (ISSUE 5):

* :mod:`repro.agg.transport.frame`   — v3 header/CRC codec, RoundSpec,
  responses, escalation math (the old ``wire`` API, now chunk-aware);
* :mod:`repro.agg.transport.chunks`  — fixed-MTU splitting + selective
  retransmit;
* :mod:`repro.agg.transport.session` — out-of-order server-side reassembly;

with all byte arithmetic delegated to :mod:`repro.core.wire_accounting`.
This facade is **deprecated** (ISSUE 7): every in-repo caller now imports
:mod:`repro.agg.transport` (or ``repro.agg.transport.frame`` directly), and
importing this module raises a :class:`DeprecationWarning`.  The name table
is frozen — nothing added since v3 — and the module will be removed once
out-of-tree callers have migrated (see the README's migration table).
"""
import warnings as _warnings

_warnings.warn(
    "repro.agg.wire is a deprecated facade; import repro.agg.transport "
    "(layered API) or repro.agg.transport.frame (this exact surface) "
    "instead — see README 'Migrating off repro.agg.wire'",
    DeprecationWarning, stacklevel=2)

from repro.agg.transport.frame import (  # noqa: F401,E402
    MAGIC_PAYLOAD, MAGIC_RESPONSE, WIRE_VERSION, Q_CAP, FLAG_ROTATE,
    FLAG_ANCHORED, FRAME_HEADER_BYTES, STATUS_QUEUED, STATUS_ACK,
    STATUS_NACK, STATUS_REJECT, STATUS_RESEND, STATUS_RETRY, WireError,
    TruncatedPayloadError, BadMagicError, VersionMismatchError,
    CorruptPayloadError, HeaderMismatchError, RoundSpec, FrameHeader,
    Payload, Response, q_at_attempt, y_at_attempt, y_buckets_at_attempt,
    payload_bytes, encode_frame, decode_frame, peek_route, payload_from_body,
    build_payload, encode_payload, decode_payload, check_frame_against_spec,
    check_against_spec, check_sides_against_spec, encode_response,
    decode_response)

__all__ = [
    "MAGIC_PAYLOAD", "MAGIC_RESPONSE", "WIRE_VERSION", "Q_CAP",
    "FLAG_ROTATE", "FLAG_ANCHORED", "FRAME_HEADER_BYTES", "STATUS_QUEUED",
    "STATUS_ACK", "STATUS_NACK", "STATUS_REJECT", "STATUS_RESEND",
    "STATUS_RETRY", "peek_route",
    "WireError", "TruncatedPayloadError", "BadMagicError",
    "VersionMismatchError", "CorruptPayloadError", "HeaderMismatchError",
    "RoundSpec", "FrameHeader", "Payload", "Response", "q_at_attempt",
    "y_at_attempt", "y_buckets_at_attempt", "payload_bytes", "encode_frame",
    "decode_frame", "payload_from_body", "build_payload", "encode_payload",
    "decode_payload", "check_frame_against_spec", "check_against_spec",
    "check_sides_against_spec", "encode_response", "decode_response",
]
