"""Versioned byte-level codec for the federated-DME aggregation protocol.

Client payload layout (little-endian):

    offset  size  field
    0       4     magic         b"DMEA"
    4       2     version       WIRE_VERSION
    6       2     flags         bit 0: rotate (HD pre-rotation, paper §6)
    8       4     round_id
    12      4     client_id
    16      4     attempt       escalation level (0 on first send)
    20      4     q             color classes at this attempt (q0^(2^attempt))
    24      4     d             unpadded vector length
    28      4     bucket        coordinates per bucket (power of two)
    32      4     seed          round's shared-randomness seed (dither u)
    36      4     rot_seed      shared Hadamard-diagonal seed
    40      4     n_words       packed uint32 word count
    44      4     nb            bucket count (= padded d / bucket)
    48      4     check         coordinate checksum h(k) (core.error_detect)
    52      4     crc           CRC-32 of header (crc field zeroed) + body
    56      4*n_words   packed color words (bits_for_q(q) bits/coordinate)
    ...     4*nb        f32 sides sidecar (one lattice side per bucket)

The payload body is exactly the packed wire format of the shard_map
collectives (repro.dist.collectives): uint32 words from the fused Pallas
encode plus the per-bucket sides sidecar.  The header adds what a real
transport needs — versioning, round/client identity, integrity (CRC) and
the §5-style decode-failure detection checksum over the integer lattice
coordinates (h(k) = <a, k> mod 2^32, shared odd weights; see
repro.core.error_detect).

Server responses reuse the framing:

    magic b"DMER" | version u16 | status u16 | round_id u32 | client_id u32
    | attempt_next u32 | q_next u32 | y_next f32 | crc u32

Escalation follows RobustAgreement (paper Alg. 5) with the *lattice
granularity held fixed*: the round pins the side s0 = 2*y0/(q0-1) and each
retry squares the color space, q <- q^2 (capped at 2^16), which widens the
decode margin y_a = s0*(q_a-1)/2 without moving the lattice — so integer
coordinates from different attempts remain summable and the server's
integer-space accumulation stays bit-deterministic.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

from repro.core import lattice as L
from repro.dist.collectives import (QSyncConfig, flat_size_padded,
                                    _ROTATION_SEED)

MAGIC_PAYLOAD = b"DMEA"
MAGIC_RESPONSE = b"DMER"
WIRE_VERSION = 1
Q_CAP = 1 << 16                   # largest packable color space (16 bits)

FLAG_ROTATE = 1 << 0

_HEADER = struct.Struct("<4sHH11I")
_RESPONSE = struct.Struct("<4sHHIIIIfI")

# response statuses
STATUS_QUEUED = 0     # payload buffered; verdict at the next drain
STATUS_ACK = 1        # payload decoded and accumulated
STATUS_NACK = 2       # decode failure detected: retry at (attempt+1, q_next)
STATUS_REJECT = 3     # malformed/mismatched payload: not retryable as-is


class WireError(ValueError):
    """Base class for payload parse/validation failures."""


class TruncatedPayloadError(WireError):
    pass


class BadMagicError(WireError):
    pass


class VersionMismatchError(WireError):
    pass


class CorruptPayloadError(WireError):
    pass


class HeaderMismatchError(WireError):
    """Payload is well-formed but does not match the round's spec."""


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static per-round protocol contract (distributed out of band).

    The lattice granularity of the round is pinned by (y0, cfg.q):
    s0 = 2*y0/(cfg.q - 1).  Escalation squares q with s0 fixed, so the
    attempt-a decode margin is y_a = s0*(q_a - 1)/2.
    """
    round_id: int
    d: int
    cfg: QSyncConfig = QSyncConfig()
    y0: float = 1.0
    seed: int = 0
    # defaulting to the collectives' shared diagonal seed keeps the agg
    # bucket pipeline bit-identical to the shard_map star collective
    rot_seed: int = _ROTATION_SEED
    max_attempts: int = 4

    @property
    def padded(self) -> int:
        return flat_size_padded(self.d, self.cfg)

    @property
    def nb(self) -> int:
        return self.padded // self.cfg.bucket

    @property
    def side(self) -> float:
        """The round's fixed lattice side s0 (granularity never escalates)."""
        return 2.0 * self.y0 / (self.cfg.q - 1)


def q_at_attempt(q0: int, attempt: int) -> int:
    """RobustAgreement color-space schedule: q0^(2^attempt), capped at 2^16."""
    q = q0
    for _ in range(attempt):
        if q >= Q_CAP:
            return Q_CAP
        q = q * q
    return min(q, Q_CAP)


def y_at_attempt(spec: RoundSpec, attempt: int) -> float:
    """Decode margin at an escalation level: y_a = s0 * (q_a - 1) / 2."""
    return spec.side * (q_at_attempt(spec.cfg.q, attempt) - 1) / 2.0


@dataclasses.dataclass(frozen=True)
class Payload:
    """Parsed client payload (validated framing; numpy views of the body)."""
    round_id: int
    client_id: int
    attempt: int
    q: int
    d: int
    bucket: int
    seed: int
    rot_seed: int
    rotate: bool
    check: int
    words: np.ndarray          # (n_words,) uint32
    sides: np.ndarray          # (nb,) f32

    @property
    def nb(self) -> int:
        return self.sides.shape[0]


@dataclasses.dataclass(frozen=True)
class Response:
    status: int
    round_id: int
    client_id: int
    attempt_next: int
    q_next: int
    y_next: float


def payload_bytes(spec: RoundSpec, attempt: int = 0) -> int:
    """Exact on-the-wire size of one client payload at an attempt level
    (header + CRC word + packed words + sides sidecar)."""
    q = q_at_attempt(spec.cfg.q, attempt)
    return (_HEADER.size + 4 + 4 * L.packed_len(spec.padded, L.bits_for_q(q))
            + 4 * spec.nb)


def encode_payload(spec: RoundSpec, client_id: int, attempt: int, q: int,
                   words: np.ndarray, sides: np.ndarray, check: int) -> bytes:
    """Serialize one client message to transportable bytes."""
    words = np.ascontiguousarray(np.asarray(words, dtype=np.uint32))
    sides = np.ascontiguousarray(np.asarray(sides, dtype=np.float32))
    flags = FLAG_ROTATE if spec.cfg.rotate else 0
    body = words.tobytes() + sides.tobytes()
    head0 = _HEADER.pack(MAGIC_PAYLOAD, WIRE_VERSION, flags, spec.round_id,
                         client_id, attempt, q, spec.d, spec.cfg.bucket,
                         spec.seed, spec.rot_seed, words.shape[0],
                         sides.shape[0], int(check) & 0xFFFFFFFF)
    crc = zlib.crc32(body, zlib.crc32(head0))
    return head0 + struct.pack("<I", crc) + body


def decode_payload(data: bytes) -> Payload:
    """Parse + integrity-check a payload; raises WireError subclasses."""
    hsize = _HEADER.size + 4                       # header + crc word
    if len(data) < hsize:
        raise TruncatedPayloadError(
            f"payload of {len(data)} bytes is shorter than the "
            f"{hsize}-byte header")
    (magic, version, flags, round_id, client_id, attempt, q, d, bucket,
     seed, rot_seed, n_words, nb, check) = _HEADER.unpack_from(data, 0)
    if magic != MAGIC_PAYLOAD:
        raise BadMagicError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise VersionMismatchError(
            f"wire version {version} != supported {WIRE_VERSION}")
    (crc,) = struct.unpack_from("<I", data, _HEADER.size)
    body = data[hsize:]
    want = 4 * n_words + 4 * nb
    if len(body) < want:
        raise TruncatedPayloadError(
            f"body has {len(body)} bytes, header promises {want}")
    if len(body) != want:
        raise CorruptPayloadError(
            f"body has {len(body)} bytes, header promises {want}")
    if zlib.crc32(body, zlib.crc32(data[:_HEADER.size])) != crc:
        raise CorruptPayloadError("CRC mismatch")
    # header self-consistency (cheap sanity; spec matching is the server's)
    if q < 2 or q > Q_CAP or bucket < 1 or (bucket & (bucket - 1)):
        raise CorruptPayloadError(f"inconsistent header: q={q} "
                                  f"bucket={bucket}")
    padded = nb * bucket
    if d > padded or padded - d >= bucket:
        raise CorruptPayloadError(
            f"inconsistent header: d={d} vs nb*bucket={padded}")
    if n_words != L.packed_len(padded, L.bits_for_q(q)):
        raise CorruptPayloadError(
            f"inconsistent header: {n_words} words for {padded} coords "
            f"at q={q}")
    words = np.frombuffer(body, dtype="<u4", count=n_words)
    sides = np.frombuffer(body, dtype="<f4", offset=4 * n_words, count=nb)
    return Payload(round_id=round_id, client_id=client_id, attempt=attempt,
                   q=q, d=d, bucket=bucket, seed=seed, rot_seed=rot_seed,
                   rotate=bool(flags & FLAG_ROTATE), check=check,
                   words=words, sides=sides)


def check_against_spec(p: Payload, spec: RoundSpec) -> None:
    """Raise HeaderMismatchError when a payload doesn't belong to a round."""
    if p.round_id != spec.round_id:
        raise HeaderMismatchError(
            f"round {p.round_id} != current {spec.round_id}")
    want_q = q_at_attempt(spec.cfg.q, p.attempt)
    mism = [
        f"{k}: got {got}, want {want}" for k, got, want in (
            ("d", p.d, spec.d),
            ("bucket", p.bucket, spec.cfg.bucket),
            ("rotate", p.rotate, spec.cfg.rotate),
            ("seed", p.seed, spec.seed),
            ("rot_seed", p.rot_seed, spec.rot_seed),
            ("q", p.q, want_q),
        ) if got != want]
    if p.attempt >= spec.max_attempts:
        mism.append(f"attempt {p.attempt} >= max {spec.max_attempts}")
    # the sidecar must carry the round's pinned granularity s0: a client
    # built against a different y0 would otherwise be accepted (its checksum
    # is self-consistent) yet scaled by the *round's* sides at finalize,
    # silently corrupting the mean
    s0 = np.float32(spec.side)
    if not np.all(p.sides == s0):
        mism.append(f"sides sidecar != round side {float(s0):.6g} "
                    f"(y0 mismatch)")
    if mism:
        raise HeaderMismatchError("; ".join(mism))


def encode_response(r: Response) -> bytes:
    head0 = _RESPONSE.pack(MAGIC_RESPONSE, WIRE_VERSION, r.status,
                           r.round_id, r.client_id, r.attempt_next,
                           r.q_next, r.y_next, 0)
    crc = zlib.crc32(head0[:-4])
    return head0[:-4] + struct.pack("<I", crc)


def decode_response(data: bytes) -> Response:
    if len(data) < _RESPONSE.size:
        raise TruncatedPayloadError(
            f"response of {len(data)} bytes < {_RESPONSE.size}")
    (magic, version, status, round_id, client_id, attempt_next, q_next,
     y_next, crc) = _RESPONSE.unpack_from(data, 0)
    if magic != MAGIC_RESPONSE:
        raise BadMagicError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise VersionMismatchError(
            f"wire version {version} != supported {WIRE_VERSION}")
    if zlib.crc32(data[:_RESPONSE.size - 4]) != crc:
        raise CorruptPayloadError("response CRC mismatch")
    return Response(status=status, round_id=round_id, client_id=client_id,
                    attempt_next=attempt_next, q_next=q_next, y_next=y_next)
