"""Versioned byte-level codec for the federated-DME aggregation protocol.

Client payload layout, RoundSpec v2 (little-endian):

    offset  size  field
    0       4     magic         b"DMEA"
    4       2     version       WIRE_VERSION (2)
    6       2     flags         bit 0: rotate (HD pre-rotation, paper §6)
                                bit 1: anchored (encoded x - anchor)
    8       4     round_id
    12      4     client_id
    16      4     attempt       escalation level (0 on first send)
    20      4     q             color classes at this attempt (q0^(2^attempt))
    24      4     d             unpadded vector length
    28      4     bucket        coordinates per bucket (power of two)
    32      4     seed          round's shared-randomness seed (dither u)
    36      4     rot_seed      shared Hadamard-diagonal seed
    40      4     n_words       packed uint32 word count
    44      4     nb            bucket count (= padded d / bucket)
    48      4     check         coordinate checksum h(k) (core.error_detect)
    52      4     anchor_digest CRC-32 of the round anchor (0 = unanchored)
    56      4     crc           CRC-32 of header (crc field zeroed) + body
    60      4*n_words   packed color words (bits_for_q(q) bits/coordinate)
    ...     4*nb        f32 sides sidecar (one lattice side per bucket)

The payload body is exactly the packed wire format of the shard_map
collectives (repro.dist.collectives): uint32 words from the fused Pallas
encode plus the per-bucket sides sidecar — with v2 the sides may differ
*per bucket* (the round's per-bucket ``y`` state from the previous round's
telemetry).  The header adds what a real transport needs — versioning,
round/client identity, integrity (CRC), the §5-style decode-failure
detection checksum over the integer lattice coordinates (h(k) = <a, k> mod
2^32, shared odd weights; see repro.core.error_detect), and the anchor
digest: anchored clients encode ``x - anchor`` (the anchor being round k-1's
published mean) inside the fused Pallas kernel, and a payload whose digest
does not match the round's anchor is REJECTed — a client quantizing against
a stale anchor would otherwise decode to garbage lattice points that still
pass framing checks.

Server responses (v2) carry the per-bucket decode margins:

    magic b"DMER" | version u16 | status u16 | round_id u32 | client_id u32
    | attempt_next u32 | q_next u32 | y_next f32 | nb u32
    | y_buckets f32*nb | crc u32

A NACK's ``y_buckets`` is the per-bucket margin at the directed escalation
level; the client validates its length against the round's ``nb`` and treats
a mismatch as a corrupt response (re-sends the current payload) instead of
truncating or broadcasting it.

Escalation follows RobustAgreement (paper Alg. 5) with the *lattice
granularity held fixed*: the round pins the side s0 = 2*y0/(q0-1) and each
retry squares the color space, q <- q^2 (capped at 2^16), which widens the
decode margin y_a = s0*(q_a-1)/2 without moving the lattice — so integer
coordinates from different attempts remain summable and the server's
integer-space accumulation stays bit-deterministic.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

from repro.core import lattice as L
from repro.dist.collectives import (QSyncConfig, flat_size_padded,
                                    _ROTATION_SEED)

MAGIC_PAYLOAD = b"DMEA"
MAGIC_RESPONSE = b"DMER"
WIRE_VERSION = 2
Q_CAP = 1 << 16                   # largest packable color space (16 bits)

FLAG_ROTATE = 1 << 0
FLAG_ANCHORED = 1 << 1

_HEADER = struct.Struct("<4sHH12I")
# response header up to and including nb; followed by nb f32 margins + crc
_RESPONSE_HEAD = struct.Struct("<4sHHIIIIfI")

# response statuses
STATUS_QUEUED = 0     # payload buffered; verdict at the next drain
STATUS_ACK = 1        # payload decoded and accumulated
STATUS_NACK = 2       # decode failure detected: retry at (attempt+1, q_next)
STATUS_REJECT = 3     # malformed/mismatched payload: not retryable as-is


class WireError(ValueError):
    """Base class for payload parse/validation failures."""


class TruncatedPayloadError(WireError):
    pass


class BadMagicError(WireError):
    pass


class VersionMismatchError(WireError):
    pass


class CorruptPayloadError(WireError):
    pass


class HeaderMismatchError(WireError):
    """Payload is well-formed but does not match the round's spec."""


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static per-round protocol contract (distributed out of band).

    The lattice granularity of the round is pinned per bucket by
    (y_buckets, cfg.q): s_b = 2*y_b/(cfg.q - 1) (uniformly y0 when
    ``y_buckets`` is None — the v1-compatible case).  Escalation squares q
    with the sides fixed, so the attempt-a decode margin per bucket is
    y_a,b = s_b*(q_a - 1)/2.

    v2 additions: ``y_buckets`` — the round's per-bucket distance bounds
    (the multi-round service feeds the previous round's telemetry through
    repro.core.qstate.update_y); ``anchor_digest`` — CRC-32 of the round
    anchor vector (round k-1's published mean; 0 = unanchored).  Clients
    encode ``x - anchor`` and the server REJECTs payloads whose digest does
    not match (stale-anchor clients are not silently mis-decoded).
    """
    round_id: int
    d: int
    cfg: QSyncConfig = QSyncConfig()
    y0: float = 1.0
    seed: int = 0
    # defaulting to the collectives' shared diagonal seed keeps the agg
    # bucket pipeline bit-identical to the shard_map star collective
    rot_seed: int = _ROTATION_SEED
    max_attempts: int = 4
    y_buckets: "tuple[float, ...] | None" = None
    anchor_digest: int = 0

    def __post_init__(self):
        if self.y_buckets is not None and len(self.y_buckets) != self.nb:
            raise ValueError(
                f"y_buckets has {len(self.y_buckets)} entries for "
                f"{self.nb} buckets")

    @property
    def padded(self) -> int:
        return flat_size_padded(self.d, self.cfg)

    @property
    def nb(self) -> int:
        return self.padded // self.cfg.bucket

    @property
    def anchored(self) -> bool:
        return self.anchor_digest != 0

    @property
    def side(self) -> float:
        """The uniform lattice side s0 (granularity never escalates).  With
        per-bucket bounds this is the *largest* side (y0 is kept as the
        uniform summary; sides_np() is the authoritative per-bucket array).
        """
        return 2.0 * self.y0 / (self.cfg.q - 1)

    def y_np(self) -> np.ndarray:
        """(nb,) f32 per-bucket distance bounds of the round."""
        if self.y_buckets is None:
            return np.full((self.nb,), self.y0, np.float32)
        return np.asarray(self.y_buckets, np.float32)

    def sides_np(self) -> np.ndarray:
        """(nb,) f32 per-bucket lattice sides s_b = 2*y_b/(q-1)."""
        return (self.y_np() * np.float32(2.0 / (self.cfg.q - 1))
                ).astype(np.float32)


def q_at_attempt(q0: int, attempt: int) -> int:
    """RobustAgreement color-space schedule: q0^(2^attempt), capped at 2^16."""
    q = q0
    for _ in range(attempt):
        if q >= Q_CAP:
            return Q_CAP
        q = q * q
    return min(q, Q_CAP)


def y_at_attempt(spec: RoundSpec, attempt: int) -> float:
    """Largest decode margin at an escalation level: y_a = s0*(q_a - 1)/2
    (the scalar summary; per-bucket margins via y_buckets_at_attempt)."""
    return spec.side * (q_at_attempt(spec.cfg.q, attempt) - 1) / 2.0


def y_buckets_at_attempt(spec: RoundSpec, attempt: int) -> np.ndarray:
    """(nb,) per-bucket decode margins at an escalation level."""
    q = q_at_attempt(spec.cfg.q, attempt)
    return (spec.sides_np() * np.float32((q - 1) / 2.0)).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class Payload:
    """Parsed client payload (validated framing; numpy views of the body)."""
    round_id: int
    client_id: int
    attempt: int
    q: int
    d: int
    bucket: int
    seed: int
    rot_seed: int
    rotate: bool
    check: int
    words: np.ndarray          # (n_words,) uint32
    sides: np.ndarray          # (nb,) f32
    anchor_digest: int = 0
    anchored: bool = False

    @property
    def nb(self) -> int:
        return self.sides.shape[0]


@dataclasses.dataclass(frozen=True)
class Response:
    status: int
    round_id: int
    client_id: int
    attempt_next: int
    q_next: int
    y_next: float
    y_buckets: "tuple[float, ...]" = ()    # per-bucket margins (NACK/QUEUED)


def payload_bytes(spec: RoundSpec, attempt: int = 0) -> int:
    """Exact on-the-wire size of one client payload at an attempt level
    (header + CRC word + packed words + sides sidecar)."""
    q = q_at_attempt(spec.cfg.q, attempt)
    return (_HEADER.size + 4 + 4 * L.packed_len(spec.padded, L.bits_for_q(q))
            + 4 * spec.nb)


def encode_payload(spec: RoundSpec, client_id: int, attempt: int, q: int,
                   words: np.ndarray, sides: np.ndarray, check: int) -> bytes:
    """Serialize one client message to transportable bytes."""
    words = np.ascontiguousarray(np.asarray(words, dtype=np.uint32))
    sides = np.ascontiguousarray(np.asarray(sides, dtype=np.float32))
    flags = (FLAG_ROTATE if spec.cfg.rotate else 0) \
        | (FLAG_ANCHORED if spec.anchored else 0)
    body = words.tobytes() + sides.tobytes()
    head0 = _HEADER.pack(MAGIC_PAYLOAD, WIRE_VERSION, flags, spec.round_id,
                         client_id, attempt, q, spec.d, spec.cfg.bucket,
                         spec.seed, spec.rot_seed, words.shape[0],
                         sides.shape[0], int(check) & 0xFFFFFFFF,
                         spec.anchor_digest & 0xFFFFFFFF)
    crc = zlib.crc32(body, zlib.crc32(head0))
    return head0 + struct.pack("<I", crc) + body


def decode_payload(data: bytes) -> Payload:
    """Parse + integrity-check a payload; raises WireError subclasses."""
    hsize = _HEADER.size + 4                       # header + crc word
    if len(data) < hsize:
        raise TruncatedPayloadError(
            f"payload of {len(data)} bytes is shorter than the "
            f"{hsize}-byte header")
    (magic, version, flags, round_id, client_id, attempt, q, d, bucket,
     seed, rot_seed, n_words, nb, check,
     anchor_digest) = _HEADER.unpack_from(data, 0)
    if magic != MAGIC_PAYLOAD:
        raise BadMagicError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise VersionMismatchError(
            f"wire version {version} != supported {WIRE_VERSION}")
    (crc,) = struct.unpack_from("<I", data, _HEADER.size)
    body = data[hsize:]
    want = 4 * n_words + 4 * nb
    if len(body) < want:
        raise TruncatedPayloadError(
            f"body has {len(body)} bytes, header promises {want}")
    if len(body) != want:
        raise CorruptPayloadError(
            f"body has {len(body)} bytes, header promises {want}")
    if zlib.crc32(body, zlib.crc32(data[:_HEADER.size])) != crc:
        raise CorruptPayloadError("CRC mismatch")
    # header self-consistency (cheap sanity; spec matching is the server's)
    if q < 2 or q > Q_CAP or bucket < 1 or (bucket & (bucket - 1)):
        raise CorruptPayloadError(f"inconsistent header: q={q} "
                                  f"bucket={bucket}")
    padded = nb * bucket
    if d > padded or padded - d >= bucket:
        raise CorruptPayloadError(
            f"inconsistent header: d={d} vs nb*bucket={padded}")
    if n_words != L.packed_len(padded, L.bits_for_q(q)):
        raise CorruptPayloadError(
            f"inconsistent header: {n_words} words for {padded} coords "
            f"at q={q}")
    anchored = bool(flags & FLAG_ANCHORED)
    if anchored != (anchor_digest != 0):
        raise CorruptPayloadError(
            f"inconsistent header: anchored flag {anchored} vs "
            f"digest {anchor_digest}")
    words = np.frombuffer(body, dtype="<u4", count=n_words)
    sides = np.frombuffer(body, dtype="<f4", offset=4 * n_words, count=nb)
    return Payload(round_id=round_id, client_id=client_id, attempt=attempt,
                   q=q, d=d, bucket=bucket, seed=seed, rot_seed=rot_seed,
                   rotate=bool(flags & FLAG_ROTATE), check=check,
                   words=words, sides=sides, anchor_digest=anchor_digest,
                   anchored=anchored)


def check_against_spec(p: Payload, spec: RoundSpec) -> None:
    """Raise HeaderMismatchError when a payload doesn't belong to a round."""
    if p.round_id != spec.round_id:
        raise HeaderMismatchError(
            f"round {p.round_id} != current {spec.round_id}")
    want_q = q_at_attempt(spec.cfg.q, p.attempt)
    mism = [
        f"{k}: got {got}, want {want}" for k, got, want in (
            ("d", p.d, spec.d),
            ("bucket", p.bucket, spec.cfg.bucket),
            ("rotate", p.rotate, spec.cfg.rotate),
            ("seed", p.seed, spec.seed),
            ("rot_seed", p.rot_seed, spec.rot_seed),
            ("q", p.q, want_q),
        ) if got != want]
    if p.attempt >= spec.max_attempts:
        mism.append(f"attempt {p.attempt} >= max {spec.max_attempts}")
    # anchor agreement: a client that encoded against a stale/foreign anchor
    # produced coordinates on a shifted lattice — its checksum is self-
    # consistent, so only the digest stops it from corrupting the mean
    if p.anchor_digest != (spec.anchor_digest & 0xFFFFFFFF):
        mism.append(f"anchor digest {p.anchor_digest:#x} != round "
                    f"{spec.anchor_digest:#x}")
    # the sidecar must carry the round's pinned per-bucket granularity: a
    # client built against different bounds would otherwise be accepted (its
    # checksum is self-consistent) yet scaled by the *round's* sides at
    # finalize, silently corrupting the mean
    if not np.array_equal(p.sides, spec.sides_np()):
        mism.append("sides sidecar != round per-bucket sides (y mismatch)")
    if mism:
        raise HeaderMismatchError("; ".join(mism))


def encode_response(r: Response) -> bytes:
    yb = np.asarray(r.y_buckets, np.float32)
    head0 = _RESPONSE_HEAD.pack(MAGIC_RESPONSE, WIRE_VERSION, r.status,
                                r.round_id, r.client_id, r.attempt_next,
                                r.q_next, r.y_next, yb.shape[0])
    body = head0 + yb.tobytes()
    return body + struct.pack("<I", zlib.crc32(body))


def decode_response(data: bytes) -> Response:
    hsize = _RESPONSE_HEAD.size
    if len(data) < hsize + 4:
        raise TruncatedPayloadError(
            f"response of {len(data)} bytes < {hsize + 4}")
    (magic, version, status, round_id, client_id, attempt_next, q_next,
     y_next, nb) = _RESPONSE_HEAD.unpack_from(data, 0)
    if magic != MAGIC_RESPONSE:
        raise BadMagicError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise VersionMismatchError(
            f"wire version {version} != supported {WIRE_VERSION}")
    if len(data) != hsize + 4 * nb + 4:
        raise CorruptPayloadError(
            f"response has {len(data)} bytes, header promises "
            f"{hsize + 4 * nb + 4}")
    (crc,) = struct.unpack_from("<I", data, hsize + 4 * nb)
    if zlib.crc32(data[:hsize + 4 * nb]) != crc:
        raise CorruptPayloadError("response CRC mismatch")
    yb = np.frombuffer(data, dtype="<f4", offset=hsize, count=nb)
    return Response(status=status, round_id=round_id, client_id=client_id,
                    attempt_next=attempt_next, q_next=q_next, y_next=y_next,
                    y_buckets=tuple(float(v) for v in yb))
