"""Back-compat facade over the layered transport stack.

The monolithic v2 codec that used to live here was refactored into
:mod:`repro.agg.transport` (ISSUE 5):

* :mod:`repro.agg.transport.frame`   — v3 header/CRC codec, RoundSpec,
  responses, escalation math (the old ``wire`` API, now chunk-aware);
* :mod:`repro.agg.transport.chunks`  — fixed-MTU splitting + selective
  retransmit;
* :mod:`repro.agg.transport.session` — out-of-order server-side reassembly;

with all byte arithmetic delegated to :mod:`repro.core.wire_accounting`.
Every name the v2 module exported is re-exported here unchanged, so
``from repro.agg import wire`` call sites keep working; new transport-aware
code should import :mod:`repro.agg.transport` directly.
"""
from repro.agg.transport.frame import (  # noqa: F401
    MAGIC_PAYLOAD, MAGIC_RESPONSE, WIRE_VERSION, Q_CAP, FLAG_ROTATE,
    FLAG_ANCHORED, FRAME_HEADER_BYTES, STATUS_QUEUED, STATUS_ACK,
    STATUS_NACK, STATUS_REJECT, STATUS_RESEND, STATUS_RETRY, WireError,
    TruncatedPayloadError, BadMagicError, VersionMismatchError,
    CorruptPayloadError, HeaderMismatchError, RoundSpec, FrameHeader,
    Payload, Response, q_at_attempt, y_at_attempt, y_buckets_at_attempt,
    payload_bytes, encode_frame, decode_frame, peek_route, payload_from_body,
    build_payload, encode_payload, decode_payload, check_frame_against_spec,
    check_against_spec, check_sides_against_spec, encode_response,
    decode_response)

__all__ = [
    "MAGIC_PAYLOAD", "MAGIC_RESPONSE", "WIRE_VERSION", "Q_CAP",
    "FLAG_ROTATE", "FLAG_ANCHORED", "FRAME_HEADER_BYTES", "STATUS_QUEUED",
    "STATUS_ACK", "STATUS_NACK", "STATUS_REJECT", "STATUS_RESEND",
    "STATUS_RETRY", "peek_route",
    "WireError", "TruncatedPayloadError", "BadMagicError",
    "VersionMismatchError", "CorruptPayloadError", "HeaderMismatchError",
    "RoundSpec", "FrameHeader", "Payload", "Response", "q_at_attempt",
    "y_at_attempt", "y_buckets_at_attempt", "payload_bytes", "encode_frame",
    "decode_frame", "payload_from_body", "build_payload", "encode_payload",
    "decode_payload", "check_frame_against_spec", "check_against_spec",
    "check_sides_against_spec", "encode_response", "decode_response",
]
