"""In-process federated-DME simulation: many clients, one server, failures.

Drives hundreds-to-thousands of simulated clients through an
:class:`repro.agg.server.AggServer` over the real byte protocol, with the
failure modes a deployment sees:

* **stragglers** — a fraction of payloads arrive only after the first
  drain (the server's integer-space accumulator makes the result invariant
  to this);
* **dropped clients** — never deliver; the round mean is over the arrived
  subset;
* **duplicate deliveries** — retransmits of already-accepted payloads are
  ACKed idempotently and never double-counted;
* **corrupt / truncated frames** — byte-level damage, REJECTed by the wire
  codec's CRC/length checks;
* **out-of-bound adversarial inputs** — vectors violating the round's
  distance bound; detected by the §5 coordinate checksum
  (repro.core.error_detect) and recovered through the r <- r^2 escalation
  handshake, or dropped when even the q-cap margin cannot cover them;
* **chunked transport** (``SimConfig.mtu > 0``) — every payload is split
  into MTU-sized chunk frames delivered interleaved across clients; the
  server reassembles out of order and the round mean is bit-identical to
  the single-frame round.  :func:`run_chunked_lossy` drops/corrupts
  individual chunks and asserts the wire-byte delta of recovery is exactly
  the lost chunks' frames — selective retransmit, never a payload resend.

The attempt-0 fleet is encoded in ONE fused kernel launch
(:func:`fleet_payloads` stacks all clients into a single flat vector), so a
512-client round is fast enough for the CI suite; retries go through the
per-client :class:`AggClient` path (bit-identical payloads — asserted in
tests/test_agg.py).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.agg import rounds
from repro.agg.client import AggClient
from repro.agg.server import AggServer, RoundStats
from repro.agg.service import AggService, ServiceConfig
from repro.agg.transport import chunks as C
from repro.agg.transport import frame as wire
from repro.core import error_detect as ED
from repro.core import lattice as L
from repro.core import rotation as R
from repro.dist.collectives import QSyncConfig
from repro.kernels import ops as K


@dataclasses.dataclass(frozen=True)
class SimConfig:
    clients: int = 512
    d: int = 1 << 12
    q: int = 16
    bucket: int = 512
    rotate: bool = False
    y0: float = 0.5
    spread: float = 0.02       # client noise scale around the base vector
    base_scale: float = 5.0
    drop: float = 0.02         # fraction of clients never delivered
    duplicate: float = 0.05    # fraction delivered twice
    straggle: float = 0.25     # fraction arriving after the first drain
    corrupt: int = 2           # extra deliveries with a flipped byte
    truncate: int = 1          # extra deliveries cut short
    adversarial: int = 4       # out-of-bound inputs recoverable by escalation
    extreme: int = 1           # beyond the q-cap margin: must be dropped
    max_attempts: int = 4
    seed: int = 0
    round_id: int = 1
    mtu: int = 0               # chunked transport when > 0 (bytes per chunk)

    def spec(self) -> wire.RoundSpec:
        return wire.RoundSpec(
            round_id=self.round_id, d=self.d,
            cfg=QSyncConfig(q=self.q, bucket=self.bucket, rotate=self.rotate),
            y0=self.y0, seed=self.seed, max_attempts=self.max_attempts,
            mtu=self.mtu)


@dataclasses.dataclass
class SimReport:
    stats: RoundStats
    mean: np.ndarray
    expected: np.ndarray          # exact mean over the accepted clients
    max_err: float
    accepted_clients: frozenset
    escalated_clients: frozenset  # accepted only after >= 1 NACK
    dropped_clients: frozenset    # never delivered or escalation-exhausted
    drains: int
    bytes_per_client: float       # attempt-0 payload size incl. header


def fleet_encode(spec: wire.RoundSpec, xs: np.ndarray, anchor=None
                 ) -> "tuple[np.ndarray, np.ndarray, np.ndarray]":
    """Encode all S clients' attempt-0 bodies in one fused kernel launch.

    Stacks the bucketized fleet into a single flat vector (per-client word
    segments stay uint32-aligned because padded d is a multiple of the
    bucket size), encodes once — with the round anchor subtracted in-kernel
    for anchored rounds — and splits words/checksums per client.  Returns
    (words (S, nw) uint32, sides (nb,) f32, checks (S,) uint32).
    """
    rounds.check_anchor(spec, anchor)
    S = xs.shape[0]
    pad = spec.padded - spec.d
    v = jnp.pad(jnp.asarray(xs, jnp.float32), ((0, 0), (0, pad)))
    v = v.reshape(S * spec.nb, spec.cfg.bucket)
    if spec.cfg.rotate:
        v = R.rotate(v, rounds.rotation_diag(spec),
                     use_kernel=spec.cfg.packed)
    sides = rounds.sides(spec)
    s_coord = jnp.repeat(sides, spec.cfg.bucket)
    u = rounds.dither(spec).reshape(-1)
    flat = v.reshape(-1)
    a_tiled = None
    if spec.anchored:
        a_flat = rounds.bucketize(jnp.asarray(anchor), spec).reshape(-1)
        a_tiled = jnp.tile(a_flat, S)
    words, k = K.lattice_encode(flat, jnp.tile(u, S), jnp.tile(s_coord, S),
                                q=spec.cfg.q, return_coords=True,
                                anchor=a_tiled)
    nw = L.packed_len(spec.padded, spec.cfg.bits)
    words = np.asarray(words).reshape(S, nw)
    weights = rounds.checksum_weights(spec)
    checks = np.asarray(ED.coord_checksum(k.reshape(S, spec.padded),
                                          weights, axis=-1))
    return words, np.asarray(sides), checks


def fleet_frames(spec: wire.RoundSpec, xs: np.ndarray,
                 anchor=None) -> "list[list[bytes]]":
    """Every client's attempt-0 chunk-frame sequence (one frame per client
    when the round is unchunked), bit-identical to AggClient.frames()."""
    words, sides_np, checks = fleet_encode(spec, xs, anchor)
    return [C.encode_chunks(spec, i, 0, spec.cfg.q, words[i], sides_np,
                            int(checks[i])) for i in range(xs.shape[0])]


def fleet_payloads(spec: wire.RoundSpec, xs: np.ndarray,
                   anchor=None) -> list[bytes]:
    """Single-frame attempt-0 payloads (rounds whose body fits one frame).

    Refuses a spec whose MTU chunks the payload — a single frame would be
    silently REJECTed by every server (n_chunks mismatch); use
    :func:`fleet_frames`."""
    if spec.n_chunks() != 1:
        raise ValueError(
            f"spec chunks payloads into {spec.n_chunks()} frames at mtu "
            f"{spec.mtu}; use fleet_frames()")
    words, sides_np, checks = fleet_encode(spec, xs, anchor)
    return [wire.encode_payload(spec, i, 0, spec.cfg.q, words[i], sides_np,
                                int(checks[i])) for i in range(xs.shape[0])]


def run_round(cfg: SimConfig = SimConfig()) -> SimReport:
    """One full aggregation round under the configured failure mix."""
    rng = np.random.RandomState(cfg.seed)
    spec = cfg.spec()
    S, d = cfg.clients, cfg.d

    base = cfg.base_scale * rng.randn(d).astype(np.float32)
    xs = base[None] + cfg.spread * rng.randn(S, d).astype(np.float32)
    # adversarial tail: offsets past the attempt-0 margin (random signs so
    # the §6 rotation cannot concentrate them into one coordinate)
    adv = list(range(S - cfg.adversarial - cfg.extreme, S - cfg.extreme))
    for i in adv:
        xs[i] += (10.0 * cfg.y0
                  * rng.choice([-1.0, 1.0], d).astype(np.float32))
    extreme = list(range(S - cfg.extreme, S))
    for i in extreme:
        xs[i] += 1e6 * cfg.y0 * rng.choice([-1.0, 1.0], d).astype(np.float32)

    server = AggServer(spec, base)
    frames = fleet_frames(spec, xs)

    # delivery plan: drops / stragglers / duplicates over the benign fleet
    benign = [i for i in range(S) if i not in set(adv + extreme)]
    rng.shuffle(benign)
    n_drop = int(round(cfg.drop * S))
    dropped = set(benign[:n_drop])
    rest = [i for i in range(S) if i not in dropped]
    n_straggle = int(round(cfg.straggle * S))
    stragglers = set(x for x in benign[n_drop:n_drop + n_straggle])
    wave1 = [i for i in rest if i not in stragglers]
    rng.shuffle(wave1)
    dup = rng.choice(wave1, size=int(round(cfg.duplicate * S)),
                     replace=False) if wave1 else []

    def deliver(clients) -> None:
        """Chunk-interleaved delivery: chunk k of every client goes out
        before chunk k+1 of any (the arrival pattern a real fan-in sees);
        unchunked rounds degenerate to one frame per client."""
        k = 0
        while True:
            sent = False
            for i in clients:
                if k < len(frames[i]):
                    server.receive(frames[i][k])
                    sent = True
            if not sent:
                return
            k += 1

    def damaged(data: bytes, kind: str) -> bytes:
        if kind == "corrupt":
            b = bytearray(data)
            b[rng.randint(len(b))] ^= 0xFF
            return bytes(b)
        return data[: rng.randint(8, len(data) - 1)]

    def any_frame(i: int) -> bytes:
        return frames[i][rng.randint(len(frames[i]))]

    # wave 1: the bulk of the fleet, shuffled, plus damaged frames
    deliver(wave1)
    for _ in range(cfg.corrupt):
        server.receive(damaged(any_frame(rng.choice(wave1)), "corrupt"))
    for _ in range(cfg.truncate):
        server.receive(damaged(any_frame(rng.choice(wave1)), "truncate"))

    retry_clients: dict[int, AggClient] = {}
    escalated: set[int] = set()

    def route(responses: list[bytes]) -> list[bytes]:
        out = []
        for rb in responses:
            r = wire.decode_response(rb)
            if r.status not in (wire.STATUS_NACK, wire.STATUS_RESEND):
                continue
            c = retry_clients.setdefault(
                r.client_id, AggClient(spec, r.client_id, xs[r.client_id]))
            if r.status == wire.STATUS_NACK:
                escalated.add(r.client_id)
            out.extend(c.handle_response(rb))
        return out

    retries = route(server.drain())
    # wave 2: stragglers, duplicates and first-round escalation retries
    deliver(stragglers)
    for i in dup:
        for f in frames[i]:
            server.receive(f)
    for p in retries:
        server.receive(p)
    retries = route(server.drain())
    while retries:                         # escalation ladder, bounded by
        for p in retries:                  # max_attempts / the q cap
            server.receive(p)
        retries = route(server.drain())

    mean, stats = server.finalize()
    acc = sorted(server.accepted_clients)
    expected = (xs[acc].astype(np.float64).mean(0)
                if acc else np.zeros(d))
    max_err = float(np.max(np.abs(mean - expected))) if acc else 0.0
    return SimReport(
        stats=stats, mean=mean, expected=expected.astype(np.float32),
        max_err=max_err, accepted_clients=frozenset(acc),
        escalated_clients=frozenset(escalated & set(acc)),
        dropped_clients=frozenset(set(range(S)) - set(acc)),
        drains=stats.drains,
        bytes_per_client=float(wire.payload_bytes(spec)))


# ---------------------------------------------------------------------------
# Lossy chunked transport: selective retransmit, byte-for-byte
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LossyReport:
    """Wire accounting of a chunked round that lost/corrupted chunks."""
    n_chunks_per_client: int
    bytes_clean: int           # client->server bytes of the lossless round
    bytes_total: int           # ... of the lossy round incl. retransmits
    retransmit_bytes: int      # RESEND-directed chunk frames only
    lost_frame_bytes: int      # the frames that were dropped/corrupted
    full_resend_bytes: int     # what v2 would have paid (whole payloads)
    mean: np.ndarray
    mean_clean: np.ndarray
    stats: RoundStats


def run_chunked_lossy(clients: int = 8, d: int = 4096, bucket: int = 512,
                      mtu: int = 512, n_drop: int = 2, n_corrupt: int = 1,
                      seed: int = 0) -> LossyReport:
    """One chunked round where individual chunks are dropped or corrupted.

    Asserts the tentpole's retransmit-cost contract: recovery costs exactly
    the lost chunks' frames on the wire (per-chunk NACK + selective
    retransmit) — never a full-payload resend — and the recovered round
    mean is bit-identical to the lossless round's.
    """
    rng = np.random.RandomState(seed)
    spec = wire.RoundSpec(round_id=1, d=d,
                          cfg=QSyncConfig(q=16, bucket=bucket), y0=0.5,
                          seed=seed, mtu=mtu)
    base = rng.randn(d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(clients, d).astype(np.float32)
    frames = fleet_frames(spec, xs)
    nc = len(frames[0])
    assert nc >= 2, f"mtu {mtu} does not chunk a {spec.body_bytes()}B body"
    bytes_clean = sum(len(f) for fs in frames for f in fs)

    # the reference lossless round
    ref = AggServer(spec, base)
    for fs in frames:
        for f in fs:
            ref.receive(f)
    mean_clean, _ = ref.finalize()

    # loss plan: distinct (client, chunk) victims; corrupt frames are
    # delivered damaged (same length), dropped frames never arrive
    victims = [(int(c), int(k)) for c, k in
               zip(rng.choice(clients, n_drop + n_corrupt, replace=False),
                   rng.randint(0, nc, n_drop + n_corrupt))]
    drop, corrupt = set(victims[:n_drop]), set(victims[n_drop:])
    lost_frame_bytes = sum(len(frames[c][k]) for c, k in drop | corrupt)

    server = AggServer(spec, base)
    bytes_total = 0
    for k in range(nc):                     # chunk-interleaved fan-in
        for c in range(clients):
            f = frames[c][k]
            if (c, k) in drop:
                continue
            if (c, k) in corrupt:
                b = bytearray(f)
                b[rng.randint(len(b))] ^= 0xFF
                f = bytes(b)
            bytes_total += len(f)
            server.receive(f)

    # drain: complete clients decode; incomplete ones get chunk NACKs
    # naming exactly the missing indices
    retransmit_bytes = 0
    clients_obj: dict[int, AggClient] = {}
    resps = server.drain()
    while True:
        resend = []
        for rb in resps:
            r = wire.decode_response(rb)
            if r.status != wire.STATUS_RESEND:
                continue
            c = clients_obj.setdefault(
                r.client_id, AggClient(spec, r.client_id, xs[r.client_id]))
            out = c.handle_response(rb)
            assert [wire.decode_frame(f)[0].chunk_index for f in out] == \
                list(r.missing), "retransmit is not the missing set"
            resend.extend(out)
        if not resend:
            break
        for f in resend:
            retransmit_bytes += len(f)
            bytes_total += len(f)
            server.receive(f)
        resps = server.drain()

    mean, stats = server.finalize()
    affected = {c for c, _ in drop | corrupt}
    full_resend_bytes = len(affected) * sum(len(f) for f in frames[0])
    rep = LossyReport(
        n_chunks_per_client=nc, bytes_clean=bytes_clean,
        bytes_total=bytes_total, retransmit_bytes=retransmit_bytes,
        lost_frame_bytes=lost_frame_bytes,
        full_resend_bytes=full_resend_bytes, mean=mean,
        mean_clean=mean_clean, stats=stats)
    # the wire-byte contract: what recovery cost is exactly the lost
    # chunks' frames — and strictly less than v2's whole-payload resends
    assert rep.retransmit_bytes == rep.lost_frame_bytes, rep
    dropped_bytes = sum(len(frames[c][k]) for c, k in drop)
    assert rep.bytes_total == \
        rep.bytes_clean - dropped_bytes + rep.retransmit_bytes, rep
    assert rep.retransmit_bytes < rep.full_resend_bytes, rep
    assert stats.accepted == clients, stats
    assert np.array_equal(rep.mean, rep.mean_clean), "chunked != lossless"
    return rep


# ---------------------------------------------------------------------------
# Multi-round simulation: drifting large-norm mean, anchored QState
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultiRoundConfig:
    """A drifting population aggregated over several anchored rounds.

    Round k's population mean is ``mu_k = mu_{k-1} + drift_k`` with
    ``|mu| ~ norm_scale >> spread`` — exactly the regime where the paper's
    distance-dependent bounds beat input-norm-dependent schemes: the
    *movement* between rounds is small even though the mean itself is huge.
    ``concentrate`` shrinks the client spread each round (inputs
    concentrate), so the tracked per-bucket y — and with it the achievable
    MSE — tightens round over round.
    """
    clients: int = 256
    d: int = 1 << 12
    q: int = 16
    bucket: int = 512
    rounds: int = 8
    y0: float = 0.5
    norm_scale: float = 1e6    # |mu_0| scale (>> spread: the hard regime)
    drift: float = 0.05        # per-round movement of the mean
    spread0: float = 0.05      # round-0 client noise around the mean
    concentrate: float = 0.7   # spread multiplier per round (< 1: converge)
    anchored: bool = True
    mtu: int = 0               # chunked transport when > 0 (bytes per chunk)
    y_decay: float = 0.75
    seed: int = 0


@dataclasses.dataclass
class RoundOutcome:
    round_id: int
    mse: float                 # vs the exact f64 population mean
    max_err: float
    accepted: int
    rejected: int
    decode_failures: int
    y_mean: float              # mean per-bucket bound entering the round
    bytes_per_client: float
    anchor_digest: int


def run_rounds(cfg: MultiRoundConfig = MultiRoundConfig()
               ) -> list[RoundOutcome]:
    """Drive the multi-round service over a drifting population.

    Every round: derive the spec from the service's QState (anchor = last
    round's mean, per-bucket y from telemetry), encode the fleet in one
    fused launch, stream payloads, finalize, advance the state.
    """
    rng = np.random.RandomState(cfg.seed)
    mu = cfg.norm_scale * rng.randn(cfg.d).astype(np.float32)
    # warm-start reference: deployments bootstrap round 1 from the known
    # previous model state (both the anchored and unanchored services get
    # the same head start — the comparison isolates encode-side anchoring)
    anchor0 = mu + (cfg.y0 / 4) * rng.randn(cfg.d).astype(np.float32)
    svc = AggService(ServiceConfig(
        d=cfg.d, q=cfg.q, bucket=cfg.bucket, y0=cfg.y0, seed=cfg.seed,
        anchored=cfg.anchored, mtu=cfg.mtu, y_decay=cfg.y_decay),
        anchor0=anchor0)
    outcomes = []
    spread = cfg.spread0
    for _ in range(cfg.rounds):
        mu = mu + cfg.drift * rng.randn(cfg.d).astype(np.float32)
        xs = mu[None] + spread * rng.randn(cfg.clients,
                                           cfg.d).astype(np.float32)
        spec, anchor = svc.begin_round()
        y_mean = float(np.mean(spec.y_np()))
        server = svc.make_server()
        frames = fleet_frames(spec, xs, anchor=anchor)
        for i in rng.permutation(cfg.clients):
            for f in frames[i]:
                server.receive(f)
        # escalation ladder: route NACKs through the per-client protocol
        # object (q <- q^2, per-bucket granularity fixed) until quiescent
        retry_clients: dict[int, AggClient] = {}
        resps = server.drain()
        while True:
            retries = []
            for rb in resps:
                r = wire.decode_response(rb)
                if r.status not in (wire.STATUS_NACK, wire.STATUS_RESEND):
                    continue
                c = retry_clients.setdefault(
                    r.client_id,
                    AggClient(spec, r.client_id, xs[r.client_id],
                              anchor=anchor))
                retries.extend(c.handle_response(rb))
            if not retries:
                break
            for p in retries:
                server.receive(p)
            resps = server.drain()
        mean, stats = svc.end_round(server)
        exact = xs.astype(np.float64).mean(0)
        err = np.abs(mean.astype(np.float64) - exact)
        outcomes.append(RoundOutcome(
            round_id=spec.round_id, mse=float(np.mean(err ** 2)),
            max_err=float(err.max()), accepted=stats.accepted,
            rejected=stats.rejected_spec + stats.rejected_wire,
            decode_failures=stats.decode_failures, y_mean=y_mean,
            bytes_per_client=float(wire.payload_bytes(spec)),
            anchor_digest=spec.anchor_digest))
        spread *= cfg.concentrate
    return outcomes
