"""In-process federated-DME simulation: many clients, one server, failures.

Drives hundreds-to-thousands of simulated clients through an
:class:`repro.agg.server.AggServer` over the real byte protocol, with the
failure modes a deployment sees:

* **stragglers** — a fraction of payloads arrive only after the first
  drain (the server's integer-space accumulator makes the result invariant
  to this);
* **dropped clients** — never deliver; the round mean is over the arrived
  subset;
* **duplicate deliveries** — retransmits of already-accepted payloads are
  ACKed idempotently and never double-counted;
* **corrupt / truncated frames** — byte-level damage, REJECTed by the wire
  codec's CRC/length checks;
* **out-of-bound adversarial inputs** — vectors violating the round's
  distance bound; detected by the §5 coordinate checksum
  (repro.core.error_detect) and recovered through the r <- r^2 escalation
  handshake, or dropped when even the q-cap margin cannot cover them;
* **chunked transport** (``SimConfig.mtu > 0``) — every payload is split
  into MTU-sized chunk frames delivered interleaved across clients; the
  server reassembles out of order and the round mean is bit-identical to
  the single-frame round.  :func:`run_chunked_lossy` drops/corrupts
  individual chunks and asserts the wire-byte delta of recovery is exactly
  the lost chunks' frames — selective retransmit, never a payload resend.

The continuous-round engine gets its own **open-loop driver**
(:func:`run_open_loop`): client arrivals are a Poisson process at a
configured offered load (plus flash crowds and churn) on a virtual clock,
frames travel with per-frame network delays and loss, and the engine's
quorum/deadline/straggler policy runs purely off event times — so the
p50/p99 round latency, rounds/sec and published-mean staleness it reports
are machine-independent and CI-gateable.  Every published round is
replayed through a fresh lockstep server (streaming forced off) over
exactly its accepted clients and asserted bit-identical (arrival order,
chunk interleaving, loss, windowed pacing and overlapping-round
interleaving all provably cannot move the mean).  With
``OpenLoopConfig.window > 0`` the driver models per-client in-flight
chunk caps: each client sends only its credit-limited burst, later
chunks ride the cumulative acks in the responses, and the configured
loss rate makes clients sit on a blocked window (the ``window_stalls``
count the report surfaces).  :func:`run_lockstep` runs the SAME arrival trace through the
legacy one-round-at-a-time coordinator on the same virtual clock — the
rounds/sec baseline the engine's overlap is measured against.

The attempt-0 fleet is encoded in ONE fused kernel launch
(:func:`fleet_payloads` stacks all clients into a single flat vector), so a
512-client round is fast enough for the CI suite; retries go through the
per-client :class:`AggClient` path (bit-identical payloads — asserted in
tests/test_agg.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import jax.numpy as jnp
import numpy as np

import repro.obs as _obs
from repro.agg import rounds
from repro.agg.api import AggConfig
from repro.agg.client import AggClient
from repro.agg.engine import AggEngine, EngineConfig, PublishedRound
from repro.agg.server import AggServer, RoundStats
from repro.agg.service import AggService, ServiceConfig
from repro.agg.transport import chunks as C
from repro.agg.transport import frame as wire
from repro.core import error_detect as ED
from repro.core import lattice as L
from repro.core import rotation as R
from repro.dist.collectives import QSyncConfig
from repro.kernels import ops as K


@dataclasses.dataclass(frozen=True)
class SimConfig:
    clients: int = 512
    d: int = 1 << 12
    q: int = 16
    bucket: int = 512
    rotate: bool = False
    y0: float = 0.5
    spread: float = 0.02       # client noise scale around the base vector
    base_scale: float = 5.0
    drop: float = 0.02         # fraction of clients never delivered
    duplicate: float = 0.05    # fraction delivered twice
    straggle: float = 0.25     # fraction arriving after the first drain
    corrupt: int = 2           # extra deliveries with a flipped byte
    truncate: int = 1          # extra deliveries cut short
    adversarial: int = 4       # out-of-bound inputs recoverable by escalation
    extreme: int = 1           # beyond the q-cap margin: must be dropped
    max_attempts: int = 4
    seed: int = 0
    round_id: int = 1
    mtu: int = 0               # chunked transport when > 0 (bytes per chunk)

    def spec(self) -> wire.RoundSpec:
        return wire.RoundSpec(
            round_id=self.round_id, d=self.d,
            cfg=QSyncConfig(q=self.q, bucket=self.bucket, rotate=self.rotate),
            y0=self.y0, seed=self.seed, max_attempts=self.max_attempts,
            mtu=self.mtu)


@dataclasses.dataclass
class SimReport:
    stats: RoundStats
    mean: np.ndarray
    expected: np.ndarray          # exact mean over the accepted clients
    max_err: float
    accepted_clients: frozenset
    escalated_clients: frozenset  # accepted only after >= 1 NACK
    dropped_clients: frozenset    # never delivered or escalation-exhausted
    drains: int
    bytes_per_client: float       # attempt-0 payload size incl. header


def fleet_encode(spec: wire.RoundSpec, xs: np.ndarray, anchor=None
                 ) -> "tuple[np.ndarray, np.ndarray, np.ndarray]":
    """Encode all S clients' attempt-0 bodies in one fused kernel launch.

    Stacks the bucketized fleet into a single flat vector (per-client word
    segments stay uint32-aligned because padded d is a multiple of the
    bucket size), encodes once — with the round anchor subtracted in-kernel
    for anchored rounds — and splits words/checksums per client.  Returns
    (words (S, nw) uint32, sides (nb,) f32, checks (S,) uint32).
    """
    rounds.check_anchor(spec, anchor)
    S = xs.shape[0]
    pad = spec.padded - spec.d
    v = jnp.pad(jnp.asarray(xs, jnp.float32), ((0, 0), (0, pad)))
    v = v.reshape(S * spec.nb, spec.cfg.bucket)
    if spec.cfg.rotate:
        v = R.rotate(v, rounds.rotation_diag(spec),
                     use_kernel=spec.cfg.packed)
    sides = rounds.sides(spec)
    s_coord = jnp.repeat(sides, spec.cfg.bucket)
    u = rounds.dither(spec).reshape(-1)
    flat = v.reshape(-1)
    a_tiled = None
    if spec.anchored:
        a_flat = rounds.bucketize(jnp.asarray(anchor), spec).reshape(-1)
        a_tiled = jnp.tile(a_flat, S)
    words, k = K.lattice_encode(flat, jnp.tile(u, S), jnp.tile(s_coord, S),
                                q=spec.cfg.q, return_coords=True,
                                anchor=a_tiled)
    nw = L.packed_len(spec.padded, spec.cfg.bits)
    words = np.asarray(words).reshape(S, nw)
    weights = rounds.checksum_weights(spec)
    checks = np.asarray(ED.coord_checksum(k.reshape(S, spec.padded),
                                          weights, axis=-1))
    return words, np.asarray(sides), checks


def fleet_frames(spec: wire.RoundSpec, xs: np.ndarray,
                 anchor=None) -> "list[list[bytes]]":
    """Every client's attempt-0 chunk-frame sequence (one frame per client
    when the round is unchunked), bit-identical to AggClient.frames()."""
    words, sides_np, checks = fleet_encode(spec, xs, anchor)
    trace = _obs.tracing_enabled()
    out = []
    for i in range(xs.shape[0]):
        if trace:
            _obs.tracer().begin("encode",
                                key=("client", spec.round_id, i),
                                parent=("round", spec.round_id),
                                round=spec.round_id, client=i, attempt=0)
        fr = C.encode_chunks(spec, i, 0, spec.cfg.q, words[i], sides_np,
                             int(checks[i]))
        if trace:
            _obs.tracer().end(("client", spec.round_id, i), n_chunks=len(fr))
        out.append(fr)
    return out


def fleet_payloads(spec: wire.RoundSpec, xs: np.ndarray,
                   anchor=None) -> list[bytes]:
    """Single-frame attempt-0 payloads (rounds whose body fits one frame).

    Refuses a spec whose MTU chunks the payload — a single frame would be
    silently REJECTed by every server (n_chunks mismatch); use
    :func:`fleet_frames`."""
    if spec.n_chunks() != 1:
        raise ValueError(
            f"spec chunks payloads into {spec.n_chunks()} frames at mtu "
            f"{spec.mtu}; use fleet_frames()")
    words, sides_np, checks = fleet_encode(spec, xs, anchor)
    trace = _obs.tracing_enabled()
    out = []
    for i in range(xs.shape[0]):
        if trace:
            _obs.tracer().begin("encode",
                                key=("client", spec.round_id, i),
                                parent=("round", spec.round_id),
                                round=spec.round_id, client=i, attempt=0)
        pl = wire.encode_payload(spec, i, 0, spec.cfg.q, words[i], sides_np,
                                 int(checks[i]))
        if trace:
            _obs.tracer().end(("client", spec.round_id, i), n_chunks=1)
        out.append(pl)
    return out


def run_round(cfg: SimConfig = SimConfig()) -> SimReport:
    """One full aggregation round under the configured failure mix."""
    rng = np.random.RandomState(cfg.seed)
    spec = cfg.spec()
    S, d = cfg.clients, cfg.d

    base = cfg.base_scale * rng.randn(d).astype(np.float32)
    xs = base[None] + cfg.spread * rng.randn(S, d).astype(np.float32)
    # adversarial tail: offsets past the attempt-0 margin (random signs so
    # the §6 rotation cannot concentrate them into one coordinate)
    adv = list(range(S - cfg.adversarial - cfg.extreme, S - cfg.extreme))
    for i in adv:
        xs[i] += (10.0 * cfg.y0
                  * rng.choice([-1.0, 1.0], d).astype(np.float32))
    extreme = list(range(S - cfg.extreme, S))
    for i in extreme:
        xs[i] += 1e6 * cfg.y0 * rng.choice([-1.0, 1.0], d).astype(np.float32)

    server = AggServer(spec, base)
    frames = fleet_frames(spec, xs)

    # delivery plan: drops / stragglers / duplicates over the benign fleet
    benign = [i for i in range(S) if i not in set(adv + extreme)]
    rng.shuffle(benign)
    n_drop = int(round(cfg.drop * S))
    dropped = set(benign[:n_drop])
    rest = [i for i in range(S) if i not in dropped]
    n_straggle = int(round(cfg.straggle * S))
    stragglers = set(x for x in benign[n_drop:n_drop + n_straggle])
    wave1 = [i for i in rest if i not in stragglers]
    rng.shuffle(wave1)
    dup = rng.choice(wave1, size=int(round(cfg.duplicate * S)),
                     replace=False) if wave1 else []

    def deliver(clients) -> None:
        """Chunk-interleaved delivery: chunk k of every client goes out
        before chunk k+1 of any (the arrival pattern a real fan-in sees);
        unchunked rounds degenerate to one frame per client."""
        k = 0
        while True:
            sent = False
            for i in clients:
                if k < len(frames[i]):
                    server.ingest_frame(frames[i][k])
                    sent = True
            if not sent:
                return
            k += 1

    def damaged(data: bytes, kind: str) -> bytes:
        if kind == "corrupt":
            b = bytearray(data)
            b[rng.randint(len(b))] ^= 0xFF
            return bytes(b)
        return data[: rng.randint(8, len(data) - 1)]

    def any_frame(i: int) -> bytes:
        return frames[i][rng.randint(len(frames[i]))]

    # wave 1: the bulk of the fleet, shuffled, plus damaged frames
    deliver(wave1)
    for _ in range(cfg.corrupt):
        server.ingest_frame(damaged(any_frame(rng.choice(wave1)), "corrupt"))
    for _ in range(cfg.truncate):
        server.ingest_frame(damaged(any_frame(rng.choice(wave1)), "truncate"))

    retry_clients: dict[int, AggClient] = {}
    escalated: set[int] = set()

    def route(responses: list[bytes]) -> list[bytes]:
        out = []
        for rb in responses:
            r = wire.decode_response(rb)
            if r.status not in (wire.STATUS_NACK, wire.STATUS_RESEND):
                continue
            c = retry_clients.setdefault(
                r.client_id, AggClient(spec, r.client_id, xs[r.client_id]))
            if r.status == wire.STATUS_NACK:
                escalated.add(r.client_id)
            out.extend(c.handle_response(rb))
        return out

    retries = route(server.tick())
    # wave 2: stragglers, duplicates and first-round escalation retries
    deliver(stragglers)
    for i in dup:
        for f in frames[i]:
            server.ingest_frame(f)
    for p in retries:
        server.ingest_frame(p)
    retries = route(server.tick())
    while retries:                         # escalation ladder, bounded by
        for p in retries:                  # max_attempts / the q cap
            server.ingest_frame(p)
        retries = route(server.tick())

    mean, stats = server.finalize()
    acc = sorted(server.accepted_clients)
    expected = (xs[acc].astype(np.float64).mean(0)
                if acc else np.zeros(d))
    max_err = float(np.max(np.abs(mean - expected))) if acc else 0.0
    return SimReport(
        stats=stats, mean=mean, expected=expected.astype(np.float32),
        max_err=max_err, accepted_clients=frozenset(acc),
        escalated_clients=frozenset(escalated & set(acc)),
        dropped_clients=frozenset(set(range(S)) - set(acc)),
        drains=stats.drains,
        bytes_per_client=float(wire.payload_bytes(spec)))


# ---------------------------------------------------------------------------
# Lossy chunked transport: selective retransmit, byte-for-byte
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LossyReport:
    """Wire accounting of a chunked round that lost/corrupted chunks."""
    n_chunks_per_client: int
    bytes_clean: int           # client->server bytes of the lossless round
    bytes_total: int           # ... of the lossy round incl. retransmits
    retransmit_bytes: int      # RESEND-directed chunk frames only
    lost_frame_bytes: int      # the frames that were dropped/corrupted
    full_resend_bytes: int     # what v2 would have paid (whole payloads)
    mean: np.ndarray
    mean_clean: np.ndarray
    stats: RoundStats


def run_chunked_lossy(clients: int = 8, d: int = 4096, bucket: int = 512,
                      mtu: int = 512, n_drop: int = 2, n_corrupt: int = 1,
                      seed: int = 0) -> LossyReport:
    """One chunked round where individual chunks are dropped or corrupted.

    Asserts the tentpole's retransmit-cost contract: recovery costs exactly
    the lost chunks' frames on the wire (per-chunk NACK + selective
    retransmit) — never a full-payload resend — and the recovered round
    mean is bit-identical to the lossless round's.
    """
    rng = np.random.RandomState(seed)
    spec = wire.RoundSpec(round_id=1, d=d,
                          cfg=QSyncConfig(q=16, bucket=bucket), y0=0.5,
                          seed=seed, mtu=mtu)
    base = rng.randn(d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(clients, d).astype(np.float32)
    frames = fleet_frames(spec, xs)
    nc = len(frames[0])
    assert nc >= 2, f"mtu {mtu} does not chunk a {spec.body_bytes()}B body"
    bytes_clean = sum(len(f) for fs in frames for f in fs)

    # the reference lossless round
    ref = AggServer(spec, base)
    for fs in frames:
        for f in fs:
            ref.ingest_frame(f)
    mean_clean, _ = ref.finalize()

    # loss plan: distinct (client, chunk) victims; corrupt frames are
    # delivered damaged (same length), dropped frames never arrive
    victims = [(int(c), int(k)) for c, k in
               zip(rng.choice(clients, n_drop + n_corrupt, replace=False),
                   rng.randint(0, nc, n_drop + n_corrupt))]
    drop, corrupt = set(victims[:n_drop]), set(victims[n_drop:])
    lost_frame_bytes = sum(len(frames[c][k]) for c, k in drop | corrupt)

    server = AggServer(spec, base)
    bytes_total = 0
    for k in range(nc):                     # chunk-interleaved fan-in
        for c in range(clients):
            f = frames[c][k]
            if (c, k) in drop:
                continue
            if (c, k) in corrupt:
                b = bytearray(f)
                b[rng.randint(len(b))] ^= 0xFF
                f = bytes(b)
            bytes_total += len(f)
            server.ingest_frame(f)

    # drain: complete clients decode; incomplete ones get chunk NACKs
    # naming exactly the missing indices
    retransmit_bytes = 0
    clients_obj: dict[int, AggClient] = {}
    resps = server.tick()
    while True:
        resend = []
        for rb in resps:
            r = wire.decode_response(rb)
            if r.status != wire.STATUS_RESEND:
                continue
            c = clients_obj.setdefault(
                r.client_id, AggClient(spec, r.client_id, xs[r.client_id]))
            out = c.handle_response(rb)
            assert [wire.decode_frame(f)[0].chunk_index for f in out] == \
                list(r.missing), "retransmit is not the missing set"
            resend.extend(out)
        if not resend:
            break
        for f in resend:
            retransmit_bytes += len(f)
            bytes_total += len(f)
            server.ingest_frame(f)
        resps = server.tick()

    mean, stats = server.finalize()
    affected = {c for c, _ in drop | corrupt}
    full_resend_bytes = len(affected) * sum(len(f) for f in frames[0])
    rep = LossyReport(
        n_chunks_per_client=nc, bytes_clean=bytes_clean,
        bytes_total=bytes_total, retransmit_bytes=retransmit_bytes,
        lost_frame_bytes=lost_frame_bytes,
        full_resend_bytes=full_resend_bytes, mean=mean,
        mean_clean=mean_clean, stats=stats)
    # the wire-byte contract: what recovery cost is exactly the lost
    # chunks' frames — and strictly less than v2's whole-payload resends
    assert rep.retransmit_bytes == rep.lost_frame_bytes, rep
    dropped_bytes = sum(len(frames[c][k]) for c, k in drop)
    assert rep.bytes_total == \
        rep.bytes_clean - dropped_bytes + rep.retransmit_bytes, rep
    assert rep.retransmit_bytes < rep.full_resend_bytes, rep
    assert stats.accepted == clients, stats
    assert np.array_equal(rep.mean, rep.mean_clean), "chunked != lossless"
    return rep


# ---------------------------------------------------------------------------
# Multi-round simulation: drifting large-norm mean, anchored QState
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultiRoundConfig:
    """A drifting population aggregated over several anchored rounds.

    Round k's population mean is ``mu_k = mu_{k-1} + drift_k`` with
    ``|mu| ~ norm_scale >> spread`` — exactly the regime where the paper's
    distance-dependent bounds beat input-norm-dependent schemes: the
    *movement* between rounds is small even though the mean itself is huge.
    ``concentrate`` shrinks the client spread each round (inputs
    concentrate), so the tracked per-bucket y — and with it the achievable
    MSE — tightens round over round.
    """
    clients: int = 256
    d: int = 1 << 12
    q: int = 16
    bucket: int = 512
    rounds: int = 8
    y0: float = 0.5
    norm_scale: float = 1e6    # |mu_0| scale (>> spread: the hard regime)
    drift: float = 0.05        # per-round movement of the mean
    spread0: float = 0.05      # round-0 client noise around the mean
    concentrate: float = 0.7   # spread multiplier per round (< 1: converge)
    anchored: bool = True
    mtu: int = 0               # chunked transport when > 0 (bytes per chunk)
    y_decay: float = 0.75
    seed: int = 0

    def agg_config(self) -> AggConfig:
        """Composed config; :func:`run_rounds` projects the service slice."""
        return AggConfig(d=self.d, q=self.q, bucket=self.bucket, y0=self.y0,
                         seed=self.seed, anchored=self.anchored,
                         mtu=self.mtu, y_decay=self.y_decay)


@dataclasses.dataclass
class RoundOutcome:
    round_id: int
    mse: float                 # vs the exact f64 population mean
    max_err: float
    accepted: int
    rejected: int
    decode_failures: int
    y_mean: float              # mean per-bucket bound entering the round
    bytes_per_client: float
    anchor_digest: int


def run_rounds(cfg: MultiRoundConfig = MultiRoundConfig()
               ) -> list[RoundOutcome]:
    """Drive the multi-round service over a drifting population.

    Every round: derive the spec from the service's QState (anchor = last
    round's mean, per-bucket y from telemetry), encode the fleet in one
    fused launch, stream payloads, finalize, advance the state.
    """
    rng = np.random.RandomState(cfg.seed)
    mu = cfg.norm_scale * rng.randn(cfg.d).astype(np.float32)
    # warm-start reference: deployments bootstrap round 1 from the known
    # previous model state (both the anchored and unanchored services get
    # the same head start — the comparison isolates encode-side anchoring)
    anchor0 = mu + (cfg.y0 / 4) * rng.randn(cfg.d).astype(np.float32)
    svc = AggService(cfg.agg_config().service_config(), anchor0=anchor0)
    outcomes = []
    spread = cfg.spread0
    for _ in range(cfg.rounds):
        mu = mu + cfg.drift * rng.randn(cfg.d).astype(np.float32)
        xs = mu[None] + spread * rng.randn(cfg.clients,
                                           cfg.d).astype(np.float32)
        spec, anchor = svc.begin_round()
        y_mean = float(np.mean(spec.y_np()))
        server = svc.make_server()
        frames = fleet_frames(spec, xs, anchor=anchor)
        for i in rng.permutation(cfg.clients):
            for f in frames[i]:
                server.ingest_frame(f)
        # escalation ladder: route NACKs through the per-client protocol
        # object (q <- q^2, per-bucket granularity fixed) until quiescent
        retry_clients: dict[int, AggClient] = {}
        resps = server.tick()
        while True:
            retries = []
            for rb in resps:
                r = wire.decode_response(rb)
                if r.status not in (wire.STATUS_NACK, wire.STATUS_RESEND):
                    continue
                c = retry_clients.setdefault(
                    r.client_id,
                    AggClient(spec, r.client_id, xs[r.client_id],
                              anchor=anchor))
                retries.extend(c.handle_response(rb))
            if not retries:
                break
            for p in retries:
                server.ingest_frame(p)
            resps = server.tick()
        mean, stats = svc.end_round(server)
        exact = xs.astype(np.float64).mean(0)
        err = np.abs(mean.astype(np.float64) - exact)
        outcomes.append(RoundOutcome(
            round_id=spec.round_id, mse=float(np.mean(err ** 2)),
            max_err=float(err.max()), accepted=stats.accepted,
            rejected=stats.rejected_spec + stats.rejected_wire,
            decode_failures=stats.decode_failures, y_mean=y_mean,
            bytes_per_client=float(wire.payload_bytes(spec)),
            anchor_digest=spec.anchor_digest))
        spread *= cfg.concentrate
    return outcomes


# ---------------------------------------------------------------------------
# Open-loop continuous rounds: Poisson arrivals driving the AggEngine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpenLoopConfig:
    """Offered-load model + engine policy for the open-loop driver.

    Times are virtual seconds: the sim's clock is an event queue, so the
    latency/staleness/throughput metrics depend only on the trace and the
    policy — never on the machine running the sim.
    """
    d: int = 256
    q: int = 16
    bucket: int = 64
    y0: float = 0.5
    mtu: int = 64                  # small MTU: payloads chunk into ~3 frames
    window: int = 0                # per-client in-flight chunk cap (0:
                                   # blast; >0 turns on windowed send +
                                   # streaming decode, v5)
    max_attempts: int = 4
    # offered load
    rate: float = 250.0            # Poisson arrivals per virtual second
    duration: float = 0.5          # arrival window
    flash_at: "tuple[float, ...]" = (0.25,)   # flash-crowd instants
    flash_size: int = 32           # simultaneous arrivals per flash
    churn_frac: float = 0.06      # clients that vanish after one chunk
    straggle_frac: float = 0.12   # clients whose chunks trickle in late
    adversarial: int = 3          # out-of-bound inputs (escalate to recover)
    spread: float = 0.02
    base_scale: float = 2.0
    # network model
    net_delay: float = 0.004       # one-way frame latency scale
    straggle_delay: float = 0.12   # extra per-chunk delay for stragglers
    loss: float = 0.03             # per-frame loss probability
    nudge_delay: float = 0.06      # client-side full-resend timer (covers
                                   # the all-chunks-lost corner)
    # engine policy
    quorum: int = 24
    round_deadline: float = 0.08
    straggler_deadline: float = 0.04
    drain_deadline: float = 0.2
    max_resends: int = 2
    max_pending: "int | None" = None
    max_live_rounds: int = 4
    tick: float = 0.01             # advance() cadence between arrivals
    max_enrolls: int = 3           # per-client re-enrollment budget after
                                   # non-terminal RETRYs
    seed: int = 0

    def agg_config(self) -> AggConfig:
        """The composed knob surface; the layer configs are projections of
        this one object, so a knob cannot drift between service and engine."""
        return AggConfig(
            d=self.d, q=self.q, bucket=self.bucket, y0=self.y0,
            seed=self.seed, anchored=True, mtu=self.mtu,
            window=self.window, max_attempts=self.max_attempts,
            quorum=self.quorum, round_deadline=self.round_deadline,
            min_clients=1, straggler_deadline=self.straggler_deadline,
            max_resends=self.max_resends, drain_deadline=self.drain_deadline,
            max_pending=self.max_pending,
            max_live_rounds=self.max_live_rounds)

    def engine_config(self) -> EngineConfig:
        return self.agg_config().engine_config()

    def service_config(self) -> ServiceConfig:
        return self.agg_config().service_config()


@dataclasses.dataclass
class _Trace:
    """One offered-load realization, shared by the engine and lockstep
    drivers so their throughput is compared on identical traffic."""
    xs: np.ndarray                       # (N, d) client vectors by cid
    arrivals: "list[tuple[float, int]]"  # (t, cid), time-sorted
    straggler: frozenset
    churn: frozenset
    adversarial: frozenset


def _make_trace(cfg: OpenLoopConfig) -> _Trace:
    rng = np.random.RandomState(cfg.seed)
    times = []
    t = float(rng.exponential(1.0 / cfg.rate))
    while t < cfg.duration:
        times.append(t)
        t += float(rng.exponential(1.0 / cfg.rate))
    for t0 in cfg.flash_at:
        # a flash crowd: flash_size arrivals inside ~one network delay
        times.extend(t0 + cfg.net_delay * rng.rand(cfg.flash_size))
    times.sort()
    n = len(times)
    base = cfg.base_scale * rng.randn(cfg.d).astype(np.float32)
    xs = base[None] + cfg.spread * rng.randn(n, cfg.d).astype(np.float32)
    perm = rng.permutation(n)
    adv = frozenset(int(i) for i in perm[:cfg.adversarial])
    rest = [int(i) for i in perm[cfg.adversarial:]]
    n_churn = int(round(cfg.churn_frac * n))
    n_strag = int(round(cfg.straggle_frac * n))
    churn = frozenset(rest[:n_churn])
    strag = frozenset(rest[n_churn:n_churn + n_strag])
    for i in adv:
        # past the attempt-0 margin, recoverable by one escalation
        xs[i] += (10.0 * cfg.y0
                  * rng.choice([-1.0, 1.0], cfg.d).astype(np.float32))
    return _Trace(xs=xs, arrivals=[(float(t), i) for i, t in enumerate(times)],
                  straggler=strag, churn=churn, adversarial=adv)


@dataclasses.dataclass
class OpenLoopReport:
    """Virtual-clock outcome of one open-loop run (all times in virtual
    seconds — machine-independent, CI-gateable)."""
    rounds: int                   # rounds published
    clients_arrived: int
    accepted_total: int
    expired_total: int            # straggler-deadline expiries
    retried_total: int            # non-terminal RETRY responses clients saw
    resends_total: int            # STATUS_RESEND responses sent
    max_live_rounds: int          # peak concurrently-live rounds observed
    p50_latency: float            # open -> published round latency
    p99_latency: float
    mean_staleness: float         # anchor age at publish, averaged
    max_staleness_rounds: int     # worst anchor lag in rounds
    makespan: float               # first open -> last publish
    rounds_per_s: float
    window_stalls: int            # responses that unblocked no send while
                                  # chunks remained (windowed rounds only)
    published: "list[PublishedRound]"


def replay_published_round(trace: _Trace, pr: PublishedRound) -> np.ndarray:
    """Re-aggregate a published round lockstep-style over EXACTLY its
    accepted clients (sorted ids, in-order chunks, no loss) and assert the
    mean is bit-identical — the engine's arrival order, chunk interleaving,
    loss pattern and overlapping-round interleaving provably did not move
    the published mean."""
    ref = (pr.anchor if pr.anchor is not None
           else np.zeros((pr.spec.d,), np.float32))
    # streaming forced OFF: a windowed engine round is checked against the
    # SEALED batched-decode drain, not against another streaming server
    server = AggServer(pr.spec, ref, streaming=False)
    clis = {}
    for cid in sorted(pr.accepted):
        c = AggClient(pr.spec, cid, trace.xs[cid], anchor=pr.anchor)
        clis[cid] = c
        for f in c.frames():
            server.ingest_frame(f)
    resps = server.tick()
    while True:
        retries = []
        for rb in resps:
            r = wire.decode_response(rb)
            if r.status not in (wire.STATUS_NACK, wire.STATUS_RESEND):
                continue
            retries.extend(clis[r.client_id].handle_response(rb))
        if not retries:
            break
        for f in retries:
            server.ingest_frame(f)
        resps = server.tick()
    mean, _ = server.finalize()
    assert server.accepted_clients == pr.accepted, \
        (server.accepted_clients, pr.accepted)
    assert np.array_equal(mean, pr.mean), \
        f"round {pr.round_id}: engine mean != lockstep replay"
    return mean


def run_open_loop(cfg: OpenLoopConfig = OpenLoopConfig(),
                  check_parity: bool = True) -> OpenLoopReport:
    """Drive the continuous-round engine with open-loop Poisson arrivals.

    Clients enroll against whatever round is open when they arrive, their
    chunk frames travel with per-frame delays (stragglers trickle), frames
    are lost at the configured rate, and every response is routed back to
    the sender's protocol object — NACK escalation, selective retransmit
    and non-terminal RETRY re-enrollment all run over the real bytes.  The
    engine's cutover/straggler/publish policy fires purely off event
    times.  Asserts, for every published round, bit-identical replay
    parity, and that no benign client ever drew a terminal verdict.
    """
    trace = _make_trace(cfg)
    svc = AggService(cfg.service_config())
    eng = AggEngine(svc, cfg.engine_config(), now=0.0)
    rng = np.random.RandomState(cfg.seed + 1)
    heap: list = []
    seq = itertools.count()

    def push(t: float, kind: str, data) -> None:
        heapq.heappush(heap, (t, next(seq), kind, data))

    for t, cid in trace.arrivals:
        push(t, "enroll", cid)
    last_arrival = trace.arrivals[-1][0]
    horizon = (last_arrival + cfg.round_deadline + cfg.drain_deadline
               + cfg.straggler_deadline * (cfg.max_resends + 2) + 0.2)
    k = 1
    while k * cfg.tick < horizon:           # bounded tick train: advance()
        push(k * cfg.tick, "tick", None)    # fires even in arrival gaps
        k += 1

    active: "dict[int, AggClient]" = {}
    enrolls: "dict[int, int]" = {}
    retried_seen = 0
    benign_rejects = 0

    def send_frames(t: float, cid: int, frs: "list[bytes]") -> None:
        extra = cfg.straggle_delay if cid in trace.straggler else 0.0
        for kf, f in enumerate(frs):
            dt = cfg.net_delay * (0.5 + rng.rand()) + extra * (kf + rng.rand())
            push(t + dt, "frame", f)

    def enroll(t: float, cid: int) -> None:
        if enrolls.get(cid, 0) >= cfg.max_enrolls:
            return
        enrolls[cid] = enrolls.get(cid, 0) + 1
        rnd = eng.open_round
        c = AggClient(rnd.spec, cid, trace.xs[cid], anchor=rnd.client_anchor)
        active[cid] = c
        # windowed rounds: only the first credit-limited burst goes out
        # now; the rest rides the ack path in route() (blast when window=0)
        frs = c.send_frames()
        if cid in trace.churn:
            frs = frs[:1]                   # vanish after the first chunk
        send_frames(t, cid, frs)
        if cid not in trace.churn:
            push(t + cfg.nudge_delay, "nudge", cid)

    def route(t: float, resps: "list[bytes]") -> None:
        nonlocal retried_seen, benign_rejects
        for rb in resps:
            r = wire.decode_response(rb)
            c = active.get(r.client_id)
            if c is None or r.round_id != c.spec.round_id:
                continue                    # stale round: client moved on
            if r.client_id in trace.churn:
                continue                    # churned: never responds
            if r.status == wire.STATUS_RETRY:
                retried_seen += 1
            if (r.status == wire.STATUS_REJECT
                    and r.client_id not in trace.adversarial):
                benign_rejects += 1
            out = c.handle_response(rb)
            if out:
                send_frames(t, r.client_id, out)
            if c.retry_round is not None:
                # non-terminal admission verdict: back off one tick, then
                # re-enroll wherever admission is open by then
                c.retry_round = None
                push(t + cfg.tick, "enroll", r.client_id)

    t_last = 0.0
    while heap:
        t, _, kind, data = heapq.heappop(heap)
        t_last = max(t_last, t)
        if _obs.tracing_enabled():
            _obs.tracer().feed_time(t)   # virtual sim clock drives spans
        if kind == "enroll":
            enroll(t, data)
        elif kind == "frame":
            if rng.rand() < cfg.loss:
                continue                    # lost on the wire
            route(t, eng.ingest_frame(data, t))
        elif kind == "tick":
            route(t, eng.tick(t))
        elif kind == "nudge":
            c = active.get(data)
            if (c is not None and not c.acked and not c.gave_up
                    and c.retry_round is None):
                # timeout recovery: the unacked in-flight window (windowed
                # rounds — the all-copies-lost corner where the server has
                # no stream to RESEND from) or the full sequence (blast)
                send_frames(t, data, c.retransmit_frames())
                if c.spec.window and t + cfg.nudge_delay < horizon:
                    push(t + cfg.nudge_delay, "nudge", data)
    t_end = max(horizon, t_last) + cfg.tick
    eng.tick(t_end)
    eng.flush(t_end)

    assert benign_rejects == 0, \
        f"{benign_rejects} terminal verdicts reached benign clients"
    for cid, c in active.items():
        if cid not in trace.adversarial:
            assert not c.gave_up, f"benign client {cid} gave up"
    if check_parity:
        for pr in eng.published:
            replay_published_round(trace, pr)

    pubs = eng.published
    lat_h = _obs.Histogram.from_values(
        [pr.latency for pr in pubs] or [0.0])
    stale = np.array([pr.staleness for pr in pubs]) if pubs else np.zeros(1)
    makespan = (pubs[-1].published_at - pubs[0].opened_at) if pubs else 0.0
    return OpenLoopReport(
        rounds=len(pubs), clients_arrived=len(trace.arrivals),
        accepted_total=sum(len(pr.accepted) for pr in pubs),
        expired_total=sum(pr.stats.expired for pr in pubs),
        retried_total=(retried_seen
                       + sum(pr.stats.retried for pr in pubs)),
        resends_total=sum(pr.stats.resends_sent for pr in pubs),
        max_live_rounds=eng.max_live_seen,
        p50_latency=float(lat_h.quantile(50)),
        p99_latency=float(lat_h.quantile(99)),
        mean_staleness=float(stale.mean()),
        max_staleness_rounds=max((pr.staleness_rounds for pr in pubs),
                                 default=0),
        makespan=float(makespan),
        rounds_per_s=(len(pubs) / makespan if makespan > 0 else 0.0),
        window_stalls=sum(c.window_stalls for c in active.values()),
        published=pubs)


@dataclasses.dataclass
class LockstepReport:
    """The same offered load through the one-round-at-a-time coordinator."""
    rounds: int
    makespan: float
    rounds_per_s: float
    mean_round_time: float
    queue_delay_max: float     # worst arrival-to-admission wait


def run_lockstep(cfg: OpenLoopConfig = OpenLoopConfig()) -> LockstepReport:
    """The lockstep baseline over the SAME arrival trace, same policy knobs.

    One round at a time: while round k drains, arrivals QUEUE — nobody can
    enroll until k publishes (the structural cost the engine's overlapping
    intake removes).  The round seals at quorum-or-deadline like the
    engine, but then must wait for its slowest enrolled client — a churned
    client costs the full ``drain_deadline`` timeout with every other
    client's admission blocked behind it.  Aggregation itself runs the real
    byte protocol (lossless in-order delivery; delivery *times* model the
    same per-chunk network delays as the open-loop driver), so the two
    drivers' rounds/sec differ by coordination structure only.
    """
    trace = _make_trace(cfg)
    svc = AggService(cfg.service_config())
    arrivals = trace.arrivals
    n = len(arrivals)
    t_of = {cid: t for t, cid in arrivals}
    i = 0
    t = 0.0
    round_times: "list[float]" = []
    queue_delay_max = 0.0
    nf = None
    while i < n:
        t_open = max(t, arrivals[i][0])
        roster = []
        j = i
        while (j < n and len(roster) < cfg.quorum
               and arrivals[j][0] <= t_open + cfg.round_deadline):
            roster.append(arrivals[j][1])
            j += 1
        t_seal = (max(t_open, arrivals[j - 1][0]) if len(roster) == cfg.quorum
                  else t_open + cfg.round_deadline)
        spec, anchor = svc.begin_round()
        server = svc.make_server()
        if nf is None:
            nf = spec.n_chunks()
        # virtual drain time: every enrolled client must land (or time out)
        t_drain = t_seal
        for cid in roster:
            queue_delay_max = max(queue_delay_max, t_open - t_of[cid])
            if cid in trace.churn:
                done = t_seal + cfg.drain_deadline     # waited out in full
            else:
                done = t_of[cid] + nf * cfg.net_delay
                if cid in trace.straggler:
                    done += nf * cfg.straggle_delay
                if cid in trace.adversarial:
                    # one escalation handshake: NACK out, full resend back
                    done += 2 * cfg.net_delay + nf * cfg.net_delay
                done = min(done, t_seal + cfg.drain_deadline)
            t_drain = max(t_drain, done)
        # the actual aggregation (instantaneous on the virtual clock —
        # compute cost is measured separately, in wall time, by the bench)
        clis: "dict[int, AggClient]" = {}
        for cid in sorted(roster):
            if cid in trace.churn:
                continue
            c = AggClient(spec, cid, trace.xs[cid], anchor=anchor)
            clis[cid] = c
            for f in c.frames():
                server.ingest_frame(f)
        resps = server.tick()
        while True:
            retries = []
            for rb in resps:
                r = wire.decode_response(rb)
                if r.status not in (wire.STATUS_NACK, wire.STATUS_RESEND):
                    continue
                retries.extend(clis[r.client_id].handle_response(rb))
            if not retries:
                break
            for f in retries:
                server.ingest_frame(f)
            resps = server.tick()
        svc.end_round(server)
        round_times.append(t_drain - t_open)
        t = t_drain
        i = j
    makespan = t - arrivals[0][0] if round_times else 0.0
    return LockstepReport(
        rounds=len(round_times), makespan=float(makespan),
        rounds_per_s=(len(round_times) / makespan if makespan > 0 else 0.0),
        mean_round_time=float(np.mean(round_times)) if round_times else 0.0,
        queue_delay_max=float(queue_delay_max))
