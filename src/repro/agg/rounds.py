"""Shared per-round randomness + bucket-space helpers (client & server).

Everything a round's participants must agree on is derived deterministically
from the :class:`repro.agg.transport.frame.RoundSpec`: the dither ``u`` (one draw per
round from ``seed``/``round_id``), the §5 checksum weights, the §6 Hadamard
rotation diagonal (``rot_seed``), the per-bucket sides, and — in anchored
rounds — the anchor vector itself, pinned by its CRC-32 digest in the spec.
The defaults make the bucket pipeline bit-identical to
:mod:`repro.dist.collectives` — the acceptance test pins the server's round
mean to ``allgather_allreduce_mean``; the bucket layout itself is the one
definition in :mod:`repro.core.bucketing` (shared with the collectives).
"""
from __future__ import annotations

import struct
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg.transport import frame as W
from repro.core import bucketing as B
from repro.core import error_detect as ED
from repro.core import lattice as L
from repro.core import rotation as R

Array = jax.Array


def fold_seed(seed: int, round_id: int) -> int:
    """Round k's wire seed: ``fold(service seed, round_id)``.

    The multi-round service pins this into ``RoundSpec.seed`` so no two
    rounds ever share a dither draw even if a driver replays round ids into
    fresh specs, while a replay of the SAME (seed, round_id) pair stays
    bit-stable.  Masked to 31 bits: the wire field is u32 and
    ``jax.random.PRNGKey`` must accept it without x64.
    """
    return zlib.crc32(struct.pack("<II", seed & 0xFFFFFFFF,
                                  round_id & 0xFFFFFFFF)) & 0x7FFFFFFF


def round_key(spec: W.RoundSpec) -> Array:
    """The round's shared-randomness key (dither + checksum weights)."""
    return jax.random.fold_in(jax.random.PRNGKey(spec.seed), spec.round_id)


def dither(spec: W.RoundSpec) -> Array:
    """Shared lattice offset u ~ U[-1/2, 1/2), shaped (nb, bucket)."""
    return L.shared_offset(round_key(spec), (spec.nb, spec.cfg.bucket))


def checksum_weights(spec: W.RoundSpec) -> Array:
    """Shared odd uint32 weights of the §5 coordinate checksum, (padded,)."""
    return ED.checksum_weights(jax.random.fold_in(round_key(spec), 1),
                               spec.padded)


def rotation_diag(spec: W.RoundSpec) -> Array:
    """Shared ±1 Hadamard diagonal for the per-bucket HD rotation."""
    return R.rotation_keypair(jax.random.PRNGKey(spec.rot_seed),
                              spec.cfg.bucket)


def bucketize(x: Array, spec: W.RoundSpec) -> Array:
    """Flat (d,) -> (nb, bucket) f32, zero-padded, HD-rotated if configured.

    The same repro.core.bucketing layout the collectives use (identical
    rotation kernel path), parameterized by the round's rot_seed.
    """
    diag = rotation_diag(spec) if spec.cfg.rotate else None
    return B.bucketize(x, spec.cfg.bucket, diag=diag,
                       use_kernel=spec.cfg.packed)


def unbucketize(b: Array, spec: W.RoundSpec) -> Array:
    """Inverse of :func:`bucketize`: (nb, bucket) -> flat (d,)."""
    diag = rotation_diag(spec) if spec.cfg.rotate else None
    return B.unbucketize(b, spec.d, diag=diag, use_kernel=spec.cfg.packed)


def sides(spec: W.RoundSpec) -> Array:
    """(nb,) f32 sides sidecar — the round's fixed per-bucket granularity,
    pinned behind an optimization barrier exactly like the collectives'
    _sides (a compile-time-constant divisor is rewritten into a non-exactly-
    rounded reciprocal multiply, which would break bit-parity)."""
    s = jnp.asarray(spec.sides_np())
    return jax.lax.optimization_barrier(s)


def decode_ref_coords(spec: W.RoundSpec,
                      anchor: Optional[np.ndarray] = None) -> Array:
    """(padded,) int32 reference coordinates ``k0`` of the round's decode.

    These are the ``k_a = round(ref/s - u)`` the server's proximity decode
    snaps colors to — computed here with the *same float ops in the same
    order* as :func:`repro.core.lattice.decode_coords`, so the result is
    bit-identical to what the batched decode derives internally.  Anchored
    rounds decode residuals against zero, so ``k0`` depends only on the
    dither; unanchored rounds use the bucketized server anchor.

    A tree tier (:mod:`repro.agg.tree`) lifts every child payload to
    ``k0 + centered_mod(c - k0, q)`` — exactly the root's decode output —
    which lets it verify §5 checksums and sum coordinates in pure integer
    math, never dispatching a decode of its own.
    """
    if spec.anchored or anchor is None:
        ref_flat = jnp.zeros((spec.padded,), jnp.float32)
    else:
        ref_flat = bucketize(jnp.asarray(anchor, jnp.float32),
                             spec).reshape(-1)
    s_coord = jnp.repeat(sides(spec), spec.cfg.bucket)
    t = ref_flat / s_coord
    t = t - dither(spec).reshape(-1)
    return jnp.round(t).astype(jnp.int32)


def anchor_digest(anchor) -> int:
    """CRC-32 of the anchor's little-endian f32 bytes (nonzero: 0 is the
    wire's 'unanchored' sentinel)."""
    raw = np.ascontiguousarray(np.asarray(anchor, np.float32))
    return (zlib.crc32(raw.tobytes()) & 0xFFFFFFFF) or 1


def check_anchor(spec: W.RoundSpec, anchor: Optional[np.ndarray]) -> None:
    """Validate a party's anchor vector against the round contract."""
    if not spec.anchored:
        return
    if anchor is None:
        raise ValueError(f"round {spec.round_id} is anchored "
                         f"(digest {spec.anchor_digest:#x}) but no anchor "
                         f"vector was provided")
    got = anchor_digest(anchor)
    if got != spec.anchor_digest:
        raise ValueError(f"anchor digest {got:#x} != round's "
                         f"{spec.anchor_digest:#x} (stale anchor?)")
