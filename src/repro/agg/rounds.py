"""Shared per-round randomness + bucket-space helpers (client & server).

Everything a round's participants must agree on is derived deterministically
from the :class:`repro.agg.wire.RoundSpec`: the dither ``u`` (one draw per
round from ``seed``/``round_id``), the §5 checksum weights, and the §6
Hadamard rotation diagonal (``rot_seed``).  The defaults make the bucket
pipeline bit-identical to :mod:`repro.dist.collectives` — the acceptance
test pins the server's round mean to ``allgather_allreduce_mean``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.agg import wire as W
from repro.core import error_detect as ED
from repro.core import lattice as L
from repro.core import rotation as R

Array = jax.Array


def round_key(spec: W.RoundSpec) -> Array:
    """The round's shared-randomness key (dither + checksum weights)."""
    return jax.random.fold_in(jax.random.PRNGKey(spec.seed), spec.round_id)


def dither(spec: W.RoundSpec) -> Array:
    """Shared lattice offset u ~ U[-1/2, 1/2), shaped (nb, bucket)."""
    return L.shared_offset(round_key(spec), (spec.nb, spec.cfg.bucket))


def checksum_weights(spec: W.RoundSpec) -> Array:
    """Shared odd uint32 weights of the §5 coordinate checksum, (padded,)."""
    return ED.checksum_weights(jax.random.fold_in(round_key(spec), 1),
                               spec.padded)


def rotation_diag(spec: W.RoundSpec) -> Array:
    """Shared ±1 Hadamard diagonal for the per-bucket HD rotation."""
    return R.rotation_keypair(jax.random.PRNGKey(spec.rot_seed),
                              spec.cfg.bucket)


def bucketize(x: Array, spec: W.RoundSpec) -> Array:
    """Flat (d,) -> (nb, bucket) f32, zero-padded, HD-rotated if configured.

    Mirrors repro.dist.collectives._bucketize (same rotation kernel path),
    parameterized by the round's rot_seed.
    """
    pad = spec.padded - x.shape[0]
    v = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, spec.cfg.bucket)
    if spec.cfg.rotate:
        v = R.rotate(v, rotation_diag(spec), use_kernel=spec.cfg.packed)
    return v


def unbucketize(b: Array, spec: W.RoundSpec) -> Array:
    """Inverse of :func:`bucketize`: (nb, bucket) -> flat (d,)."""
    if spec.cfg.rotate:
        b = R.unrotate(b, rotation_diag(spec), spec.cfg.bucket,
                       use_kernel=spec.cfg.packed)
    return b.reshape(-1)[: spec.d]


def sides(spec: W.RoundSpec) -> Array:
    """(nb,) f32 sides sidecar — the round's fixed granularity s0 per bucket,
    pinned behind an optimization barrier exactly like the collectives'
    _sides (a compile-time-constant divisor is rewritten into a non-exactly-
    rounded reciprocal multiply, which would break bit-parity)."""
    s = jnp.full((spec.nb,), spec.side, jnp.float32)
    return jax.lax.optimization_barrier(s)
