"""Client side of the aggregation protocol: encode + escalation retries.

A client holds one local vector for one round.  Encoding runs the same
fused Pallas path as the shard_map collectives (repro.kernels.ops
lattice_encode): bucketize (+ optional §6 HD rotation), subtract the round
anchor *inside the kernel* when the round is anchored (RoundSpec v2:
``anchor_digest != 0`` — the anchor is round k-1's published mean, so the
integer coordinates stay ~y/s-sized however large the drifting mean grows),
dither with the round's shared offset, round to integer lattice
coordinates, pack the mod-q colors into uint32 words.  The integer
coordinates ``k = round((x - anchor)/s_b - u)`` are *independent of the
attempt level* — escalation only widens the color space (q <- q^2, the
per-bucket granularity fixed), so a retry re-packs the same coordinates at
more bits per coordinate and the §5 checksum h(k) never changes.

NACK hygiene (v2): a NACK's per-bucket ``y_buckets`` must have exactly
``spec.nb`` entries.  A length mismatch means the response was corrupted or
belongs to a different round config — the client treats it as corrupt and
re-sends its current-attempt payload instead of truncating or broadcasting
the vector (which would silently desync its escalation state).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.agg import rounds, wire
from repro.core import error_detect as ED
from repro.core import lattice as L
from repro.kernels import ops as K


class AggClient:
    """One client's state for one aggregation round."""

    def __init__(self, spec: wire.RoundSpec, client_id: int, x,
                 anchor=None):
        if np.shape(x) != (spec.d,):
            raise ValueError(f"x has shape {np.shape(x)}, spec.d={spec.d}")
        rounds.check_anchor(spec, anchor)
        self.spec = spec
        self.client_id = client_id
        self.attempt = 0
        self.acked = False
        self.gave_up = False
        self._xflat = rounds.bucketize(jnp.asarray(x), spec).reshape(-1)
        self._aflat = (rounds.bucketize(jnp.asarray(anchor), spec).reshape(-1)
                       if spec.anchored else None)
        self._u = rounds.dither(spec).reshape(-1)
        self._sides = rounds.sides(spec)
        # per-coordinate sides for the fused kernel (one s_b per bucket)
        self._s_coord = jnp.repeat(self._sides, spec.cfg.bucket)
        self._check: Optional[int] = None

    def payload(self, attempt: Optional[int] = None) -> bytes:
        """Serialize this client's message at an escalation level."""
        if attempt is None:
            attempt = self.attempt
        q = wire.q_at_attempt(self.spec.cfg.q, attempt)
        if self._check is None:
            words, k = K.lattice_encode(self._xflat, self._u, self._s_coord,
                                        q=q, return_coords=True,
                                        anchor=self._aflat)
            self._check = int(ED.coord_checksum(
                k, rounds.checksum_weights(self.spec)))
        else:
            words = K.lattice_encode(self._xflat, self._u, self._s_coord,
                                     q=q, anchor=self._aflat)
        nw = L.packed_len(self.spec.padded, L.bits_for_q(q))
        words = np.asarray(words[:nw])
        return wire.encode_payload(self.spec, self.client_id, attempt, q,
                                   words, np.asarray(self._sides),
                                   self._check)

    def handle_response(self, data: bytes) -> Optional[bytes]:
        """Process a server response; returns the next payload to send.

        Returns None when no further send is needed (ACK/QUEUED, terminal
        REJECT, or escalation exhausted — ``gave_up`` is set in the latter
        two cases).  A NACK directing escalation returns the re-encoded
        payload at the server-directed attempt; a NACK whose per-bucket y
        vector does not match the round's bucket count is treated as
        corrupt: the current-attempt payload is re-sent unchanged.
        """
        r = wire.decode_response(data)
        if r.client_id != self.client_id or r.round_id != self.spec.round_id:
            return None
        if r.status in (wire.STATUS_ACK, wire.STATUS_QUEUED):
            self.acked = r.status == wire.STATUS_ACK
            return None
        if r.status == wire.STATUS_REJECT:
            self.gave_up = True
            return None
        # NACK: escalate to the server-directed attempt (RobustAgreement:
        # the color space squares, the per-bucket granularity stays fixed)
        if self.acked or self.gave_up:
            return None                    # late NACK after a verdict
        if len(r.y_buckets) != self.spec.nb:
            # corrupt/foreign NACK (wrong per-bucket margin count): do not
            # escalate off it — retransmit and let the server re-judge
            return self.payload(self.attempt)
        if r.attempt_next >= self.spec.max_attempts:
            self.gave_up = True
            return None
        if r.attempt_next <= self.attempt:
            return None                    # duplicate/stale NACK: the retry
        self.attempt = r.attempt_next      # it asks for is already in flight
        return self.payload(self.attempt)
