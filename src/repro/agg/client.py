"""Client side of the aggregation protocol: encode, chunk, retransmit.

A client holds one local vector for one round.  Encoding runs the same
fused Pallas path as the shard_map collectives (repro.kernels.ops
lattice_encode): bucketize (+ optional §6 HD rotation), subtract the round
anchor *inside the kernel* when the round is anchored (the anchor being
round k-1's published mean, so the integer coordinates stay ~y/s-sized
however large the drifting mean grows), dither with the round's shared
offset, round to integer lattice coordinates, pack the mod-q colors into
uint32 words.  The integer coordinates ``k = round((x - anchor)/s_b - u)``
are *independent of the attempt level* — escalation only widens the color
space (q <- q^2, the per-bucket granularity fixed), so a retry re-packs the
same coordinates at more bits per coordinate and the §5 checksum h(k) never
changes.

Transport (v3): :meth:`AggClient.frames` serializes the payload through the
chunk layer — one frame when the body fits the round's MTU (or the round is
unchunked), else ``ceil(body/mtu)`` independently-CRC'd chunk frames.
Frames are cached per attempt, so a retransmit re-sends byte-identical
chunks (idempotent at the server).  :meth:`handle_response` returns the
list of frames to send next:

* ``STATUS_RESEND`` — the server's reassembly is missing specific chunks;
  only those frames are returned (selective retransmit — a lost chunk never
  costs the whole payload again);
* ``STATUS_NACK`` — decode failure: escalate to the server-directed attempt
  and return its full chunk sequence.  A NACK whose per-bucket ``y_buckets``
  length does not match the round's ``nb`` is treated as corrupt — the
  current-attempt frames are re-sent instead of escalating off it (which
  would silently desync the escalation state);
* ``STATUS_RETRY`` — non-terminal admission verdict (sealed round, full
  pending store, or a rolled-over round): nothing is sent now, but
  ``retry_round`` records where admission is currently open so the driver
  can back off and re-send, or re-enroll in the named round with a fresh
  ``AggClient`` built from that round's spec;
* ``STATUS_ACK`` / ``STATUS_QUEUED`` / terminal ``STATUS_REJECT`` — nothing
  to send.

Windowed rounds (v5, ``spec.window > 0``): the client paces itself with a
credit-based send window (:class:`repro.agg.transport.chunks.SendWindow`)
— at most ``window`` chunks in flight, where in-flight means sent but not
covered by the server's cumulative contiguous ack riding every response
(``Response.ack``/``Response.credit``, the v5 additive fields).  Use
:meth:`AggClient.send_frames` for the opening burst; ``handle_response``
then returns each newly-credited frame as acks arrive, so window advance,
selective retransmit and escalation all share the one response path.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

import repro.obs as _obs
from repro.agg import rounds
from repro.agg.transport import chunks as C
from repro.agg.transport import frame as wire
from repro.core import error_detect as ED
from repro.core import lattice as L
from repro.kernels import ops as K


class AggClient:
    """One client's state for one aggregation round."""

    def __init__(self, spec: wire.RoundSpec, client_id: int, x,
                 anchor=None):
        if np.shape(x) != (spec.d,):
            raise ValueError(f"x has shape {np.shape(x)}, spec.d={spec.d}")
        rounds.check_anchor(spec, anchor)
        self.spec = spec
        self.client_id = client_id
        self.attempt = 0
        self.acked = False
        self.gave_up = False
        # round-rollover handling (v3 continuous rounds): set by a
        # non-terminal STATUS_RETRY — the round id currently open for
        # admission (self.spec.round_id: re-send the same frames after
        # backoff; a different round: this round is over for us, re-enroll
        # there with a fresh AggClient built from that round's spec;
        # 0/None: no hint).  Never terminal: gave_up stays False.
        self.retry_round: Optional[int] = None
        self._xflat = rounds.bucketize(jnp.asarray(x), spec).reshape(-1)
        self._aflat = (rounds.bucketize(jnp.asarray(anchor), spec).reshape(-1)
                       if spec.anchored else None)
        self._u = rounds.dither(spec).reshape(-1)
        self._sides = rounds.sides(spec)
        # per-coordinate sides for the fused kernel (one s_b per bucket)
        self._s_coord = jnp.repeat(self._sides, spec.cfg.bucket)
        self._check: Optional[int] = None
        self._frames: "dict[int, list[bytes]]" = {}
        self._win: "dict[int, C.SendWindow]" = {}   # attempt -> window

    def _encode(self, attempt: int) -> "tuple[int, np.ndarray]":
        """(q, packed words) at an escalation level; the §5 checksum over
        the integer coordinates is computed once (it never changes)."""
        q = wire.q_at_attempt(self.spec.cfg.q, attempt)
        if self._check is None:
            words, k = K.lattice_encode(self._xflat, self._u, self._s_coord,
                                        q=q, return_coords=True,
                                        anchor=self._aflat)
            self._check = int(ED.coord_checksum(
                k, rounds.checksum_weights(self.spec)))
        else:
            words = K.lattice_encode(self._xflat, self._u, self._s_coord,
                                     q=q, anchor=self._aflat)
        nw = L.packed_len(self.spec.padded, L.bits_for_q(q))
        return q, np.asarray(words[:nw])

    def frames(self, attempt: Optional[int] = None) -> "list[bytes]":
        """This client's chunk-frame sequence at an escalation level
        (cached: a retransmit is byte-identical)."""
        if attempt is None:
            attempt = self.attempt
        cached = self._frames.get(attempt)
        if cached is None:
            trace = _obs.tracing_enabled()
            if trace:
                _obs.tracer().begin(
                    "encode",
                    key=("client", self.spec.round_id, self.client_id),
                    parent=("round", self.spec.round_id),
                    round=self.spec.round_id, client=self.client_id,
                    attempt=attempt)
            q, words = self._encode(attempt)
            cached = C.encode_chunks(self.spec, self.client_id, attempt, q,
                                     words, np.asarray(self._sides),
                                     self._check)
            self._frames[attempt] = cached
            if trace:
                _obs.tracer().end(
                    ("client", self.spec.round_id, self.client_id),
                    n_chunks=len(cached))
        return list(cached)

    def _window(self, attempt: int) -> "C.SendWindow":
        w = self._win.get(attempt)
        if w is None:
            w = self._win[attempt] = C.SendWindow(self.frames(attempt),
                                                  self.spec.window)
        return w

    def send_frames(self, attempt: Optional[int] = None) -> "list[bytes]":
        """The frames to put on the wire NOW: the whole chunk sequence in
        an unwindowed round, else the first credit-limited burst
        (subsequent bursts ride :meth:`handle_response` as acks arrive)."""
        if attempt is None:
            attempt = self.attempt
        if not self.spec.window:
            return self.frames(attempt)
        return self._window(attempt).sendable()

    def retransmit_frames(self) -> "list[bytes]":
        """Timeout recovery when the round has gone quiet: the unacked
        in-flight window (windowed rounds) or the full chunk sequence
        (unwindowed).  Idempotent — the server dedupes; empty once a
        verdict landed."""
        if self.acked or self.gave_up:
            return []
        if not self.spec.window:
            return self.frames(self.attempt)
        w = self._window(self.attempt)
        return w.unacked() or w.sendable()

    @property
    def window_stalls(self) -> int:
        """Responses that unblocked nothing while chunks remained unsent —
        how often this client sat blocked on its credit window."""
        return sum(w.stalls for w in self._win.values())

    def payload(self, attempt: Optional[int] = None) -> bytes:
        """The single-frame serialization (unchunked rounds, and chunked
        rounds whose body fits one MTU)."""
        frames = self.frames(attempt)
        if len(frames) != 1:
            raise ValueError(
                f"payload spans {len(frames)} chunks at mtu "
                f"{self.spec.mtu}; use frames()")
        return frames[0]

    def handle_response(self, data: bytes) -> "list[bytes]":
        """Process a server response; returns the frames to send next.

        Empty when no further send is needed (ACK/QUEUED, terminal REJECT,
        or escalation exhausted — ``gave_up`` is set in the latter two
        cases)."""
        r = wire.decode_response(data)
        if r.client_id != self.client_id or r.round_id != self.spec.round_id:
            return []
        if r.status in (wire.STATUS_ACK, wire.STATUS_QUEUED):
            # set on ACK only — a reordered/late chunk QUEUED must never
            # clear an ACK verdict (it would re-arm the late-NACK guard)
            self.acked = self.acked or r.status == wire.STATUS_ACK
            if (self.acked or not self.spec.window
                    or r.status != wire.STATUS_QUEUED
                    or r.attempt_next != self.attempt):
                return []
            # windowed round: the QUEUED's cumulative ack is the credit
            # return — send whatever the window now allows
            w = self._window(self.attempt)
            w.note_ack(r.ack)
            return w.sendable()
        if r.status == wire.STATUS_RETRY:
            # admission backpressure / round rollover: non-terminal.  The
            # driver decides when to re-send (same round) or where to
            # re-enroll (q_next names the round open for admission).
            self.retry_round = r.q_next or None
            return []
        if r.status == wire.STATUS_REJECT:
            self.gave_up = True
            return []
        if self.acked or self.gave_up:
            return []                      # late NACK/RESEND after a verdict
        if r.status == wire.STATUS_RESEND:
            if r.attempt_next != self.attempt:
                return []                  # stale: that attempt is gone
            frames = self.frames(self.attempt)
            if self.spec.window:
                # the server names every chunk it is missing, but only the
                # ones below the contiguous sent prefix were actually LOST
                # — the rest are chunks the credit window hasn't released
                # yet, and they ride the normal ack path.  Retransmits are
                # not credit-capped (the server asked for them by name);
                # the RESEND's cumulative ack doubles as window advance.
                w = self._window(self.attempt)
                w.note_ack(r.ack)
                lost = tuple(i for i in r.missing if i < w.next)
                out = C.select(frames, lost) if lost else []
                return out + w.sendable()
            return C.select(frames, r.missing)
        # NACK: escalate to the server-directed attempt (RobustAgreement:
        # the color space squares, the per-bucket granularity stays fixed)
        if len(r.y_buckets) != self.spec.nb:
            # corrupt/foreign NACK (wrong per-bucket margin count): do not
            # escalate off it — retransmit and let the server re-judge
            return self.frames(self.attempt)
        if r.attempt_next >= self.spec.max_attempts:
            self.gave_up = True
            return []
        if r.attempt_next <= self.attempt:
            return []                      # duplicate/stale NACK: the retry
        self.attempt = r.attempt_next      # it asks for is already in flight
        if self.spec.window:
            return self._window(self.attempt).sendable()
        return self.frames(self.attempt)
