"""Hierarchical sum-without-decode aggregation tree (ISSUE 7 tentpole).

Topology: clients -> edge tiers -> (regional tiers ->) root, every hop the
ordinary v4 transport (chunking, selective retransmit, escalation — the
identical stack a flat client/server pair uses):

    client 0 ─┐
    client 1 ─┼─> TierAggregator ─┐
    client 2 ─┘        (edge)     │
                                  ├─> TierAggregator ──> root AggServer
    client 3 ─┐        (edge)     │      (regional)      (ONE batched
    client 4 ─┼─> TierAggregator ─┘                       Pallas decode
    client 5 ─┘                                           per color space)

A :class:`TierAggregator` accepts chunked client frames through the
unchanged session/reassembly layer, validates CRCs and the sides sidecar
against the round's pinned spec, and **sums accepted payloads' packed
integer coordinates without ever decoding**:

* The round's decode-reference coordinates ``k0 = round(ref/s - u)``
  (:func:`repro.agg.rounds.decode_ref_coords`) are bit-identical to the
  ``k_a`` inside the root's batched proximity decode.  Each accepted child
  payload's colors lift to the residual ``r_i = centered_mod(c_i - k0, q)``
  (:func:`repro.kernels.ops.lattice_residuals` — integer-only, deliberately
  NOT a decode dispatch), so ``k0 + r_i`` IS the root's decode output for
  that payload, obtained in pure int math.
* The §5 checksum is verified per child in uint32 arithmetic:
  ``h(k0 + r_i) == check_i``.  A mismatch draws the same NACK escalation
  schedule as the flat server (q <- q^2, terminal REJECT at the cap), so
  the tier's accepted set equals the flat server's for the same traffic.
* Accepted residuals fold in place: ``R += r_i`` (int64 headroom),
  ``m += n_summed_i`` (children may themselves be tiers).  Admission is
  saturation-checked: a child whose fold would push ``max|R|`` past the
  coordinate range ``q_max/2`` implied by the escalation cap is REJECTed
  (counted in :attr:`TierStats.saturated`) instead of silently wrapping.
* Upstream, the tier is an ordinary client of the next tier: it forwards
  ONE combined payload ``K' = k0 + R`` packed as mod-q' colors at the
  smallest escalation attempt whose color space holds ``R``, with checksum
  ``h(K')`` and the additive header field ``n_summed = m``; retransmits
  reuse the chunk layer's cached frames and ``STATUS_RESEND`` selection,
  NACKs escalate by repacking the SAME coordinates at the next q.

The root corrects each combined payload by ``(m-1) * k0`` inside its
drain (see ``_drain_math`` in :mod:`repro.agg.server`):
``K' + (m-1)*k0 = sum_i (k0 + r_i)`` — exactly the integer sum the m
clients would have contributed individually, so the tree-published mean is
bit-identical to a flat drain over the same accepted clients, and the root
still performs exactly one batched Pallas decode per color space.

:class:`AggTree` wires tiers into the fanout^j topology behind the
:class:`repro.agg.api.AggNode` protocol: a driver cannot tell a tree from
a flat server — ``ingest_frame`` routes client frames to edge tiers,
``tick`` pumps the internal tier<->parent exchanges until quiescent, and
``published()`` reports the root's outcome with the accepted set mapped
back to real client ids.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

import repro.obs as _obs
from repro.agg import rounds
from repro.agg.api import PublishedRound
from repro.agg.server import AggServer, _StreamFold, _reject, _retry
from repro.agg.transport import chunks as C
from repro.agg.transport import frame as wire
from repro.agg.transport import session as S
from repro.core import lattice as L
from repro.kernels import ops as K

# tier node ids live far above any realistic client id so the two can share
# the transport's u32 client_id field without collisions; layer index and
# position are recoverable from the id for debugging
TIER_ID_BASE = 0xF0000000

# upper bound on tick-internal message exchange iterations (a persistent
# loss hook could otherwise ping-pong RESENDs forever within one tick)
_MAX_PUMP = 64

# a sealed-and-forwarded tier with no verdict after this many consecutive
# ticks re-sends its full upstream frame sequence (recovers total loss of
# the combined payload, where no reassembly exists upstream to RESEND)
_UP_RESEND_TICKS = 2


@dataclasses.dataclass
class TierStats:
    """One tier's child-side + upstream telemetry."""
    received: int = 0
    queued: int = 0
    accepted: int = 0            # child payloads folded into R
    clients_summed: int = 0      # sum of folded n_summed (== forwarded m)
    duplicates: int = 0
    rejected_wire: int = 0
    rejected_spec: int = 0
    decode_failures: int = 0     # §5 checksum mismatches (integer-verified)
    nacks_sent: int = 0
    resends_sent: int = 0
    retried: int = 0
    saturated: int = 0           # children REJECTed by the overflow guard
    gave_up: int = 0
    expired: int = 0
    drains: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    up_frames_sent: int = 0      # upstream chunk frames (incl. retransmits)
    up_escalations: int = 0      # upstream NACKs honored (repack at next q)
    up_resends: int = 0          # upstream RESEND/timer retransmissions


class TierAggregator:
    """One aggregation tier: a server to its children, a client upstream.

    Implements the :class:`repro.agg.api.AggNode` protocol.  ``anchor`` is
    the same out-of-band reference the root holds (digest-validated for
    anchored rounds); ``node_id`` is this tier's client id on the upstream
    wire.
    """

    def __init__(self, spec: wire.RoundSpec, anchor, node_id: int,
                 max_pending: "int | None" = None,
                 streaming: "bool | None" = None):
        """``streaming`` mirrors :class:`~repro.agg.server.AggServer`:
        ``None`` resolves to ``spec.window > 0`` — a windowed round folds
        each child stream's validated word ranges as they land (the tier
        never decoded anyway, so streaming only moves the residual lift
        from drain time to arrival time and frees the chunk bytes early);
        commit into ``R`` still happens only at stream completion, after
        the §5 checksum and the saturation guard (which needs the full
        residual vector) pass."""
        rounds.check_anchor(spec, anchor if spec.anchored else None)
        self.spec = spec
        self.node_id = node_id
        self.max_pending = max_pending
        self._sealed = False
        self._next_round_id = 0
        # the integer-space lift reference: bit-identical to the k_a inside
        # the root's batched decode (both anchored and unanchored rounds)
        self._k0 = np.asarray(rounds.decode_ref_coords(
            spec, None if spec.anchored else anchor), np.int32)
        self._weights = np.asarray(rounds.checksum_weights(spec), np.uint32)
        self._sides_np = spec.sides_np()
        # escalation headroom: the widest color space any attempt may use;
        # |R| must stay inside its centered range or the repacked colors
        # would alias and the root's decode would silently wrap
        self._q_max = wire.q_at_attempt(spec.cfg.q, spec.max_attempts - 1)
        # ---- child side (mirrors AggServer's intake) ----
        self._admitted: set[int] = set()
        self._accepted: set[int] = set()
        self._gave_up: set[int] = set()
        self._pending: dict[int, wire.Payload] = {}
        self._attempt_floor: dict[int, int] = {}
        self._folds: "dict[tuple, _StreamFold]" = {}
        self._streaming = ((spec.window > 0) if streaming is None
                           else bool(streaming)) and spec.mtu > 0
        if self._streaming:
            self._k0_j = jnp.asarray(self._k0)
            self._rx = S.Reassembler(spec,
                                     on_range_validated=self._fold_range,
                                     on_stream_discarded=self._drop_stream)
        else:
            self._rx = S.Reassembler(spec)
        self._margins: dict[int, tuple] = {}
        # ---- the sum-without-decode accumulator ----
        self._R = np.zeros((spec.padded,), np.int64)
        self._m = 0
        # ---- upstream (client-of-the-next-tier) state ----
        self._up_attempt: Optional[int] = None
        self._up_frames: "dict[int, list[bytes]]" = {}
        self._up_sent = False
        self._up_acked = False
        self._up_gave_up = False
        self._up_idle_ticks = 0
        self.retry_round: Optional[int] = None
        # tier accounting lives in an obs scope (exported registry counters
        # when metrics are on, a detached registry otherwise); the TierStats
        # dataclass callers read is filled from it on access
        self._obs = _obs.scope("agg_tier", round=spec.round_id,
                               node=node_id)
        self._stats = TierStats()

    @property
    def stats(self) -> TierStats:
        """This tier's telemetry, materialized from the obs scope."""
        self._obs.fill(self._stats)
        return self._stats

    @property
    def tier_index(self) -> int:
        """(layer, position) packed in the node id — for labels/debug."""
        return self.node_id & ~TIER_ID_BASE

    # ------------------------------------------------------------ AggNode
    def ingest_frame(self, data: bytes, now: float = 0.0) -> "list[bytes]":
        """One transport message in: a child's frame (returns its response)
        or an upstream response (returns the frames to send next)."""
        if data[:4] == wire.MAGIC_RESPONSE:
            return self.handle_upstream(data)
        return [self.ingest_child(data)]

    def tick(self, now: float = 0.0) -> "list[bytes]":
        """Fold staged children, chase missing chunks, forward upstream."""
        out = self.drain_children()
        out.extend(self._upstream_tick())
        return out

    def published(self) -> "list[PublishedRound]":
        """Tiers never publish — the root owns the round outcome."""
        return []

    # ---------------------------------------------------------- CHILD SIDE
    def ingest_child(self, data: bytes) -> bytes:
        """Handle one arriving child frame; returns the response bytes.

        Identical admission/session behavior to :meth:`AggServer.receive`:
        framing and spec violations draw wire/spec REJECTs, chunked bodies
        reassemble out of order through the session layer, duplicates ACK
        idempotently, and a sealed tier or full pending store answers a
        non-terminal RETRY.
        """
        self._obs.inc("received")
        self._obs.inc("bytes_in", len(data))
        try:
            h, chunk = wire.decode_frame(data)
        except wire.WireError:
            self._obs.inc("rejected_wire")
            return self._respond(_reject(self.spec, 0xFFFFFFFF))
        try:
            wire.check_frame_against_spec(h, self.spec, len(chunk))
        except wire.HeaderMismatchError:
            self._obs.inc("rejected_spec")
            return self._respond(_reject(self.spec, h.client_id,
                                         round_id=h.round_id))
        if _obs.tracing_enabled():
            _obs.tracer().event("chunk",
                                parent=("client", h.round_id, h.client_id),
                                round=h.round_id, client=h.client_id,
                                tier=self.node_id, chunk=h.chunk_index,
                                n_chunks=h.n_chunks)
        if h.client_id in self._gave_up:
            return self._respond(_reject(self.spec, h.client_id))
        if h.client_id in self._accepted:
            self._obs.inc("duplicates")
            return self._respond(self._ack(h.client_id))
        if h.client_id not in self._admitted:
            if self._sealed:
                self._obs.inc("retried")
                return self._respond(_retry(h.round_id, h.client_id,
                                            h.attempt, self._next_round_id))
            if (self.max_pending is not None
                    and self.occupancy >= self.max_pending):
                self._obs.inc("retried")
                return self._respond(_retry(h.round_id, h.client_id,
                                            h.attempt, self.spec.round_id))
            self._admitted.add(h.client_id)
        if h.n_chunks == 1:
            p = wire.payload_from_body(h, chunk)
        else:
            if h.attempt < self._attempt_floor.get(h.client_id, 0):
                # stale chunk of an attempt this tier already NACKed must
                # not re-open a dead reassembly stream
                self._obs.inc("duplicates")
                return self._respond(self._queued(h, slim=True))
            event, p = self._rx.add(h, chunk)
            if event == S.REJECT:
                self._obs.inc("resends_sent")
                return self._respond(wire.Response(
                    status=wire.STATUS_RESEND,
                    round_id=self.spec.round_id, client_id=h.client_id,
                    attempt_next=h.attempt, q_next=h.q,
                    y_next=wire.y_at_attempt(self.spec, h.attempt),
                    missing=tuple(range(h.n_chunks)),
                    credit=self.spec.window))
            if p is None:                   # PROGRESS / DUPLICATE / STALE
                if event in (S.DUPLICATE, S.STALE):
                    self._obs.inc("duplicates")
                return self._respond(self._queued(h, slim=True))
            if p.streamed:
                # stream complete + sealed: verify the incremental fold and
                # commit into R now (the tier's per-child drain)
                return self._finish_streamed(h, p)
        try:
            wire.check_sides_against_spec(p, self.spec)
        except wire.HeaderMismatchError:
            self._obs.inc("rejected_spec")
            return self._respond(_reject(self.spec, p.client_id))
        prev = self._pending.get(p.client_id)
        if prev is not None and prev.attempt >= p.attempt:
            self._obs.inc("duplicates")
        else:
            self._pending[p.client_id] = p
            self._obs.inc("queued")
            if _obs.tracing_enabled():
                _obs.tracer().event(
                    "seal", parent=("client", h.round_id, p.client_id),
                    round=h.round_id, client=p.client_id,
                    tier=self.node_id, attempt=p.attempt)
        return self._respond(self._queued(h))

    def drain_children(self) -> "list[bytes]":
        """Verify + fold every staged child payload; returns verdicts.

        The sum-without-decode core: per payload, residual-lift the packed
        colors about ``k0`` (integer-only), verify the §5 checksum over
        ``k0 + r`` in uint32 math, saturation-check the fold against the
        escalation cap's coordinate range, and add the residuals into the
        int64 accumulator.  No decode dispatch is issued — asserted via
        ``ops.DISPATCH_COUNTS`` in the tests.
        """
        if not self._pending:
            return self._resend_requests()
        self._obs.inc("drains")
        fold_sp = _obs.tracer().begin(
            "fold", parent=("round", self.spec.round_id),
            round=self.spec.round_id, tier=self.node_id,
            payloads=len(self._pending)) if _obs.tracing_enabled() else None
        staged = sorted(self._pending.values(), key=lambda p: p.client_id)
        self._pending.clear()
        responses = []
        for p in staged:
            r = np.asarray(K.lattice_residuals(
                jnp.asarray(p.words), jnp.asarray(self._k0), q=p.q),
                np.int64)
            k_hat = self._k0.astype(np.int64) + r
            chk = int(np.sum(
                k_hat.astype(np.int32).view(np.uint32) * self._weights,
                dtype=np.uint32))
            if chk != (p.check & 0xFFFFFFFF):
                responses.append(self._decode_failure(p))
                continue
            cand = self._R + r
            half = self._q_max // 2
            if cand.max() >= half or cand.min() < -half:
                # folding this child would push the combined coordinates
                # outside the widest escalation attempt's centered range —
                # the repacked colors would alias.  Terminal for the child
                # at THIS tier (it may enroll flat in a later round).
                self._obs.inc("saturated")
                self._obs.inc("gave_up")
                self._gave_up.add(p.client_id)
                self._rx.discard(p.client_id)
                if fold_sp is not None:
                    _obs.tracer().event(
                        "saturation_reject", parent=fold_sp.span_id,
                        round=self.spec.round_id, tier=self.node_id,
                        client=p.client_id)
                _obs.trigger("saturation_reject", at=_obs.tracer().now(),
                             round=self.spec.round_id, tier=self.node_id,
                             client=p.client_id)
                responses.append(self._respond(_reject(self.spec,
                                                       p.client_id)))
                continue
            self._R = cand
            self._m += p.n_summed
            self._obs.inc("accepted")
            self._obs.inc("clients_summed", p.n_summed)
            self._accepted.add(p.client_id)
            self._rx.discard(p.client_id)
            responses.append(self._respond(self._ack(p.client_id)))
        if fold_sp is not None:
            _obs.tracer().end(fold_sp, folded=self._m)
        return responses + self._resend_requests()

    # ------------------------------------------------------- STREAMING RX
    def _fold_range(self, h: wire.FrameHeader, word_start: int,
                    words: np.ndarray) -> None:
        """``on_range_validated``: residual-lift one validated word range
        into the stream's speculative record (same integer identity the
        batched fold uses); the session frees the chunk bytes after this."""
        key = (h.client_id, h.attempt, h.payload_crc)
        rec = self._folds.get(key)
        if rec is None:
            rec = self._folds[key] = _StreamFold(self.spec.padded,
                                                 self.spec.nb)
        c0 = word_start * (32 // L.bits_for_q(h.q))
        r = np.asarray(K.lattice_residuals_range(
            jnp.asarray(words), self._k0_j, q=h.q, word_start=word_start))
        n = r.shape[0]
        rec.r[c0:c0 + n] = r.astype(np.int16)
        rec.coords += n
        k = r.astype(np.int64) + self._k0.astype(np.int64)[c0:c0 + n]
        part = np.sum(k.astype(np.uint32) * self._weights[c0:c0 + n],
                      dtype=np.uint32)
        rec.check = (rec.check + int(part)) & 0xFFFFFFFF

    def _drop_stream(self, h: wire.FrameHeader) -> None:
        """``on_stream_discarded``: drop the speculative record — nothing
        was committed to R, so this IS the rollback."""
        self._folds.pop((h.client_id, h.attempt, h.payload_crc), None)

    def _finish_streamed(self, h: wire.FrameHeader,
                         p: wire.Payload) -> bytes:
        """A child stream completed and its payload-CRC seal held: verify
        the incremental §5 checksum and the saturation guard (which needs
        the FULL residual vector — the record has it), then fold into R."""
        rec = self._folds.pop((h.client_id, h.attempt, h.payload_crc), None)
        try:
            wire.check_sides_against_spec(p, self.spec)
        except wire.HeaderMismatchError:
            self._obs.inc("rejected_spec")
            return self._respond(_reject(self.spec, p.client_id))
        if rec is None or rec.coords != self.spec.padded:
            self._obs.inc("resends_sent")
            return self._respond(wire.Response(
                status=wire.STATUS_RESEND, round_id=self.spec.round_id,
                client_id=h.client_id, attempt_next=h.attempt, q_next=h.q,
                y_next=wire.y_at_attempt(self.spec, h.attempt),
                missing=tuple(range(h.n_chunks)), credit=self.spec.window))
        if rec.check != (h.check & 0xFFFFFFFF):
            return self._decode_failure(p)
        cand = self._R + rec.r.astype(np.int64)
        half = self._q_max // 2
        if cand.max() >= half or cand.min() < -half:
            self._obs.inc("saturated")
            self._obs.inc("gave_up")
            self._gave_up.add(h.client_id)
            if _obs.tracing_enabled():
                _obs.tracer().event(
                    "saturation_reject",
                    parent=("round", self.spec.round_id),
                    round=self.spec.round_id, tier=self.node_id,
                    client=h.client_id)
            _obs.trigger("saturation_reject", at=_obs.tracer().now(),
                         round=self.spec.round_id, tier=self.node_id,
                         client=h.client_id)
            return self._respond(_reject(self.spec, h.client_id))
        self._R = cand
        self._m += h.n_summed
        self._obs.inc("accepted")
        self._obs.inc("clients_summed", h.n_summed)
        self._accepted.add(h.client_id)
        return self._respond(self._ack(h.client_id, ack=h.n_chunks))

    def _decode_failure(self, p: wire.Payload) -> bytes:
        """The flat server's escalation schedule, verbatim: NACK to the
        next attempt, terminal REJECT at the color-space cap."""
        self._obs.inc("decode_failures")
        nxt = p.attempt + 1
        if p.q >= wire.Q_CAP or nxt >= self.spec.max_attempts:
            self._gave_up.add(p.client_id)
            self._rx.discard(p.client_id)
            self._obs.inc("gave_up")
            return self._respond(_reject(self.spec, p.client_id))
        self._obs.inc("nacks_sent")
        self._attempt_floor[p.client_id] = nxt
        return self._respond(wire.Response(
            status=wire.STATUS_NACK, round_id=self.spec.round_id,
            client_id=p.client_id, attempt_next=nxt,
            q_next=wire.q_at_attempt(self.spec.cfg.q, nxt),
            y_next=wire.y_at_attempt(self.spec, nxt),
            y_buckets=self._margin_tuple(nxt), credit=self.spec.window))

    def _margin_tuple(self, attempt: int) -> tuple:
        t = self._margins.get(attempt)
        if t is None:
            t = tuple(float(v) for v in
                      wire.y_buckets_at_attempt(self.spec, attempt))
            self._margins[attempt] = t
        return t

    def _queued(self, h: wire.FrameHeader,
                slim: bool = False) -> wire.Response:
        return wire.Response(
            status=wire.STATUS_QUEUED, round_id=self.spec.round_id,
            client_id=h.client_id, attempt_next=h.attempt, q_next=h.q,
            y_next=wire.y_at_attempt(self.spec, h.attempt),
            y_buckets=() if slim else self._margin_tuple(h.attempt),
            ack=self._rx.high_water(h.client_id) if self.spec.window else 0,
            credit=self.spec.window)

    def _ack(self, client_id: int, ack: int = 0) -> wire.Response:
        return wire.Response(status=wire.STATUS_ACK,
                             round_id=self.spec.round_id,
                             client_id=client_id, attempt_next=0, q_next=0,
                             y_next=0.0, ack=ack, credit=self.spec.window)

    def _respond(self, r: wire.Response) -> bytes:
        out = wire.encode_response(r)
        self._obs.inc("bytes_out", len(out))
        return out

    def _resend_requests(self) -> "list[bytes]":
        out = []
        for cid, (attempt, missing) in self._rx.incomplete().items():
            self._obs.inc("resends_sent")
            out.append(self._respond(wire.Response(
                status=wire.STATUS_RESEND, round_id=self.spec.round_id,
                client_id=cid, attempt_next=attempt,
                q_next=wire.q_at_attempt(self.spec.cfg.q, attempt),
                y_next=wire.y_at_attempt(self.spec, attempt),
                y_buckets=self._margin_tuple(attempt), missing=missing,
                ack=self._rx.high_water(cid) if self.spec.window else 0,
                credit=self.spec.window)))
        return out

    # ----------------------------------------------------------- LIFECYCLE
    def seal(self, next_round_id: int = 0) -> None:
        """Stop admitting NEW children (cutover); admitted children keep
        full service.  Once every admitted child resolves, the next tick
        forwards the combined payload upstream."""
        self._sealed = True
        self._next_round_id = next_round_id

    @property
    def sealed(self) -> bool:
        return self._sealed

    @property
    def admitted_count(self) -> int:
        return len(self._admitted)

    @property
    def unresolved(self) -> frozenset:
        return frozenset(self._admitted - self._accepted - self._gave_up)

    @property
    def occupancy(self) -> int:
        return len(set(self._pending) | self._rx.open_clients())

    @property
    def accepted_clients(self) -> frozenset:
        return frozenset(self._accepted)

    @property
    def n_summed(self) -> int:
        """Clients folded into the accumulator so far."""
        return self._m

    def expire_client(self, client_id: int) -> None:
        """Drop an unresolved straggler's state without a verdict."""
        if (client_id not in self._admitted or client_id in self._accepted
                or client_id in self._gave_up):
            return
        self._pending.pop(client_id, None)
        self._rx.discard(client_id)
        self._admitted.discard(client_id)
        self._obs.inc("expired")

    @property
    def forwarded_q(self) -> "int | None":
        """Color space of the forwarded combined payload (None: not yet
        forwarded).  The root issues one batched decode per distinct value
        of this across its children."""
        if self._up_attempt is None:
            return None
        return wire.q_at_attempt(self.spec.cfg.q, self._up_attempt)

    @property
    def upstream_done(self) -> bool:
        """This tier needs nothing more from its parent: combined payload
        accepted, escalation exhausted, or nothing to forward at all."""
        if self._up_acked or self._up_gave_up:
            return True
        return self._sealed and not self.unresolved and self._m == 0

    # ------------------------------------------------------------ UPSTREAM
    def _fits(self, q: int) -> bool:
        """Would the accumulated R survive a round trip through mod-q
        colors?  centered_mod maps onto [-q//2, q//2)."""
        half = q // 2
        return bool(self._R.max() < half and self._R.min() >= -half)

    def _forward_attempt(self) -> int:
        """Smallest escalation attempt whose color space holds R (exists by
        the saturation guard, which pinned |R| under q_max/2)."""
        for a in range(self.spec.max_attempts):
            if self._fits(wire.q_at_attempt(self.spec.cfg.q, a)):
                return a
        raise AssertionError("saturation guard violated: R exceeds q_max/2")

    def _frames_at(self, attempt: int) -> "list[bytes]":
        """The combined payload's chunk frames at an escalation level
        (cached: retransmits are byte-identical).  ``K' = k0 + R`` packs as
        mod-q' colors; the checksum is ``h(K')`` so the root's verification
        passes by construction; ``n_summed`` carries the fold count."""
        cached = self._up_frames.get(attempt)
        if cached is None:
            q = wire.q_at_attempt(self.spec.cfg.q, attempt)
            k_fwd = (self._k0.astype(np.int64) + self._R).astype(np.int32)
            words = np.asarray(K.lattice_pack_coords(jnp.asarray(k_fwd),
                                                     q=q))
            check = int(np.sum(k_fwd.view(np.uint32) * self._weights,
                               dtype=np.uint32))
            cached = C.encode_chunks(self.spec, self.node_id, attempt, q,
                                     words, self._sides_np, check,
                                     n_summed=self._m)
            self._up_frames[attempt] = cached
        return list(cached)

    def _send_up(self, frames: "list[bytes]") -> "list[bytes]":
        self._obs.inc("up_frames_sent", len(frames))
        self._obs.inc("bytes_out", sum(len(f) for f in frames))
        return frames

    def _upstream_tick(self) -> "list[bytes]":
        """Forward once everything below is resolved; re-send the full
        sequence if the parent has stayed silent (total-loss recovery —
        a partially-received payload is chased by the parent's RESEND)."""
        if (not self._sealed or self.unresolved or self._m == 0
                or self._up_acked or self._up_gave_up):
            return []
        if not self._up_sent:
            self._up_sent = True
            self._up_attempt = self._forward_attempt()
            self._up_idle_ticks = 0
            return self._send_up(self._frames_at(self._up_attempt))
        self._up_idle_ticks += 1
        if self._up_idle_ticks >= _UP_RESEND_TICKS:
            self._up_idle_ticks = 0
            self._obs.inc("up_resends")
            return self._send_up(self._frames_at(self._up_attempt))
        return []

    def handle_upstream(self, data: bytes) -> "list[bytes]":
        """Process the parent's response; returns the frames to send next
        (the :class:`repro.agg.client.AggClient` state machine, acting for
        the combined payload)."""
        try:
            r = wire.decode_response(data)
        except wire.WireError:
            return []
        if (r.client_id != self.node_id
                or r.round_id != self.spec.round_id):
            return []
        self._up_idle_ticks = 0
        if r.status in (wire.STATUS_ACK, wire.STATUS_QUEUED):
            self._up_acked = self._up_acked or r.status == wire.STATUS_ACK
            return []
        if r.status == wire.STATUS_RETRY:
            self.retry_round = r.q_next or None
            return []
        if r.status == wire.STATUS_REJECT:
            self._up_gave_up = True
            return []
        if self._up_acked or self._up_gave_up or self._up_attempt is None:
            return []
        if r.status == wire.STATUS_RESEND:
            if r.attempt_next != self._up_attempt:
                return []
            self._obs.inc("up_resends")
            return self._send_up(C.select(self._frames_at(self._up_attempt),
                                          r.missing))
        # NACK: escalate — repack the SAME coordinates at the directed q
        if r.attempt_next >= self.spec.max_attempts:
            self._up_gave_up = True
            return []
        if r.attempt_next <= self._up_attempt:
            return []
        self._obs.inc("up_escalations")
        self._up_attempt = r.attempt_next
        return self._send_up(self._frames_at(self._up_attempt))


# response client_id offset: magic 4s | version u16 | status u16 | round u32
_RESP_CID_OFF = 12


class AggTree:
    """A fanout^j tier tree behind one :class:`~repro.agg.api.AggNode`.

    ``tiers`` tier layers sit between the clients and the root: the layer
    feeding the root has ``fanout`` tiers, the next one down
    ``fanout**2``, and so on; clients hash onto the leaf layer by
    ``client_id % n_leaf`` and every internal hop is ordinary transport.
    ``root`` defaults to a flat :class:`~repro.agg.server.AggServer` and
    may be any AggNode-shaped server of the same round.

    ``loss`` (tests/bench): ``loss(src_id, dst_id, data) -> bytes | None``
    applied to every INTERNAL message (tier->parent frames and
    parent->tier responses); ``None`` drops the message.  Client-facing
    traffic is the driver's to mangle.
    """

    def __init__(self, spec: wire.RoundSpec, anchor, *, fanout: int = 8,
                 tiers: int = 1, max_pending: "int | None" = None,
                 root=None,
                 loss: "Optional[Callable]" = None):
        if fanout < 2:
            raise ValueError(f"fanout must be >= 2, got {fanout}")
        if tiers < 1:
            raise ValueError(f"tiers must be >= 1, got {tiers}")
        self.spec = spec
        self.fanout = fanout
        self.tiers = tiers
        self._loss = loss
        self.root = (root if root is not None
                     else AggServer(spec, anchor, max_pending=max_pending))
        # layers[0] feeds the root (fanout nodes), layers[-1] is the leaf
        # layer (fanout**tiers nodes) the clients talk to
        self.layers: "list[list[TierAggregator]]" = []
        self._by_node_id: "dict[int, TierAggregator]" = {}
        self._parent: "dict[int, object]" = {}      # node_id -> parent node
        for depth in range(tiers):
            n = fanout ** (depth + 1)
            layer = []
            for i in range(n):
                nid = TIER_ID_BASE | (depth << 20) | i
                t = TierAggregator(spec, anchor, nid,
                                   max_pending=max_pending)
                layer.append(t)
                self._by_node_id[nid] = t
                self._parent[nid] = (self.root if depth == 0
                                     else self.layers[depth - 1][i // fanout])
            self.layers.append(layer)
        self._leaf = self.layers[-1]
        self._sealing = False

    # ------------------------------------------------------------ ROUTING
    def _leaf_for(self, client_id: int) -> TierAggregator:
        return self._leaf[client_id % len(self._leaf)]

    def _route(self, src, msg: bytes):
        """None = external (a real client's response); else the internal
        destination node."""
        if msg[:4] == wire.MAGIC_PAYLOAD:
            # only tiers emit frames; they go to that tier's parent
            return self._parent[src.node_id]
        if len(msg) >= _RESP_CID_OFF + 4:
            cid = int.from_bytes(msg[_RESP_CID_OFF:_RESP_CID_OFF + 4],
                                 "little")
            return self._by_node_id.get(cid)
        return None

    def _deliver(self, src, dest, msg: bytes, now: float):
        if self._loss is not None:
            src_id = getattr(src, "node_id", 0)
            dst_id = getattr(dest, "node_id", 0)
            msg = self._loss(src_id, dst_id, msg)
            if msg is None:
                return []
        return [(dest, r) for r in dest.ingest_frame(msg, now)]

    # ------------------------------------------------------------ AggNode
    def ingest_frame(self, data: bytes, now: float = 0.0) -> "list[bytes]":
        """Route one client frame to its edge tier; returns the tier's
        response (the client's QUEUED/ACK/RESEND/... — edge tiers answer
        clients directly, the root never sees individual client traffic)."""
        peek = wire.peek_route(data)
        leaf = self._leaf_for(peek[1]) if peek else self._leaf[0]
        return [leaf.ingest_child(data)]

    def tick(self, now: float = 0.0) -> "list[bytes]":
        """Fire every node's policy and pump internal traffic until
        quiescent; returns only the EXTERNAL messages (client verdicts and
        chunk RESENDs), deduplicated within the call — one tick emits each
        distinct external message once, the flat server's cadence.

        Layer-synchronized sealing keeps the root's intake a single wave
        (all tiers of a layer forward in the same pump iteration), so a
        loss-free round costs exactly one root drain — one batched decode
        dispatch per color space."""
        self._advance_seal()
        out: "list[bytes]" = []
        seen: "set[bytes]" = set()
        msgs = []
        for node in self._all_nodes():
            msgs.extend((node, m) for m in node.tick(now))
        for _ in range(_MAX_PUMP):
            internal = []
            routed_any = False
            for src, m in msgs:
                dest = self._route(src, m)
                if dest is None:
                    if m not in seen:
                        seen.add(m)
                        out.append(m)
                    continue
                routed_any = True
                internal.extend(self._deliver(src, dest, m, now))
            if not routed_any:
                break
            self._advance_seal()
            # re-fire every node's policy after the delivery wave: drains
            # fold the new payloads, newly-sealed layers forward, verdicts
            # flow back down
            msgs = internal
            for node in self._all_nodes():
                msgs.extend((node, m) for m in node.tick(now))
        return out

    def published(self) -> "list[PublishedRound]":
        """The root's outcome with ``accepted`` mapped from tier node ids
        back to the real client ids their chains folded in."""
        prs = self.root.published()
        return [dataclasses.replace(pr,
                                    accepted=self._map_accepted(pr.accepted))
                for pr in prs]

    # ----------------------------------------------------------- LIFECYCLE
    def seal(self, next_round_id: int = 0) -> None:
        """Cut the round over: leaf tiers stop admitting new clients now;
        each internal layer (and finally the root) seals automatically once
        everything below it has forwarded — so a tier is never refused
        admission by its own parent."""
        self._next_round_id = next_round_id
        self._sealing = True
        for t in self._leaf:
            t.seal(next_round_id)

    def _advance_seal(self) -> None:
        if not self._sealing:
            return
        # layer barrier: a layer seals only when the WHOLE layer below is
        # done with its upstream — so all of a layer's tiers forward in the
        # same pump iteration and the parent (ultimately the root) folds
        # their payloads in a single drain wave
        for depth in range(self.tiers - 2, -1, -1):      # above-leaf layers
            below = self.layers[depth + 1]
            if all(k.upstream_done for k in below):
                for t in self.layers[depth]:
                    if not t.sealed:
                        t.seal(self._next_round_id)
        if (not self.root_sealed
                and all(t.upstream_done for t in self.layers[0])):
            self.root.seal(self._next_round_id)

    @property
    def root_sealed(self) -> bool:
        return bool(getattr(self.root, "sealed", False))

    @property
    def accepted_clients(self) -> frozenset:
        """Real client ids in the (to-be-)published mean: a client counts
        iff its edge tier accepted it AND every combined payload on its
        path to the root was accepted."""
        accepted = getattr(self.root, "accepted_clients", frozenset())
        return self._map_accepted(accepted)

    def _map_accepted(self, accepted: frozenset) -> frozenset:
        out: set = set()
        for cid in accepted:
            tier = self._by_node_id.get(cid)
            if tier is None:
                out.add(cid)                 # a real client at the root
                continue
            out |= self._tier_clients(tier)
        return frozenset(out)

    def _tier_clients(self, tier: TierAggregator) -> set:
        out: set = set()
        for cid in tier.accepted_clients:
            child = self._by_node_id.get(cid)
            if child is None:
                out.add(cid)
            else:
                out |= self._tier_clients(child)
        return out

    def _all_nodes(self):
        """Leaf -> top -> root: children act before their parents so one
        tick moves data a full level upward."""
        for layer in reversed(self.layers):
            yield from layer
        yield self.root

    # ---------------------------------------------------------- TELEMETRY
    @property
    def root_ingress_payloads(self) -> int:
        """Complete payloads the root has staged+folded — the acceptance
        bound is <= fanout (one combined payload per top tier)."""
        st = getattr(self.root, "stats", None)
        return (st.queued if st is not None else 0)

    def tier_stats(self) -> "list[TierStats]":
        return [t.stats for layer in self.layers for t in layer]
