"""repro.agg — streaming federated-DME aggregation on the packed lattice wire.

The canonical DME deployment (Suresh et al. 2017): many clients ship
compressed vectors to a coordinator that estimates their mean.  This package
lifts the repo's packed lattice wire format (repro.kernels lattice encode/
decode, repro.dist.collectives payload layout) from shard_map collectives to
an actual request/response protocol over real ``bytes``:

* :mod:`repro.agg.wire`   — versioned byte-level codec (header + packed
  uint32 words + f32 sides sidecar + coordinate checksum + CRC);
* :mod:`repro.agg.client` — encodes a local vector against a round's shared
  randomness and handles escalation retries;
* :mod:`repro.agg.server` — streaming accumulator: buffers arriving
  payloads, drains them through ONE batched Pallas decode, sums in integer
  coordinate space (bit-deterministic under any arrival order), and NACKs
  undecodable clients with an escalated bound (RobustAgreement r <- r^2,
  lattice granularity fixed so retried coordinates stay summable);
* :mod:`repro.agg.service` — multi-round coordinator: round k+1's anchor is
  round k's published mean (digest-pinned in the RoundSpec) and its
  per-bucket y comes from round k's decode telemetry
  (repro.core.qstate.update_y) — the anchored QState, threaded across
  rounds;
* :mod:`repro.agg.sim`    — in-process harness driving hundreds of simulated
  clients through a server with stragglers, drops, duplicates, corruption
  and out-of-bound adversarial inputs; :func:`repro.agg.sim.run_rounds`
  drives the multi-round service over a drifting large-norm population.
"""
from repro.agg.wire import (RoundSpec, Payload, Response, WireError,
                            TruncatedPayloadError, BadMagicError,
                            VersionMismatchError, CorruptPayloadError,
                            HeaderMismatchError, encode_payload,
                            decode_payload, encode_response, decode_response,
                            q_at_attempt, y_at_attempt, y_buckets_at_attempt,
                            payload_bytes,
                            STATUS_QUEUED, STATUS_NACK, STATUS_REJECT,
                            STATUS_ACK)
from repro.agg.client import AggClient
from repro.agg.server import AggServer, RoundStats
from repro.agg.service import AggService, ServiceConfig

__all__ = [
    "RoundSpec", "Payload", "Response", "WireError",
    "TruncatedPayloadError", "BadMagicError", "VersionMismatchError",
    "CorruptPayloadError", "HeaderMismatchError", "encode_payload",
    "decode_payload", "encode_response", "decode_response", "q_at_attempt",
    "y_at_attempt", "y_buckets_at_attempt", "payload_bytes", "AggClient",
    "AggServer", "RoundStats", "AggService", "ServiceConfig",
    "STATUS_QUEUED", "STATUS_NACK", "STATUS_REJECT", "STATUS_ACK",
]
