"""repro.agg — streaming federated-DME aggregation on the packed lattice wire.

The canonical DME deployment (Suresh et al. 2017): many clients ship
compressed vectors to a coordinator that estimates their mean.  This package
lifts the repo's packed lattice wire format (repro.kernels lattice encode/
decode, repro.dist.collectives payload layout) from shard_map collectives to
an actual request/response protocol over real ``bytes``:

* :mod:`repro.agg.transport` — the layered transport stack: ``frame`` (v3
  self-describing header + per-frame CRC + the RoundSpec contract),
  ``chunks`` (fixed-MTU splitting, idempotent chunk frames, selective
  retransmit), ``session`` (out-of-order duplicate-tolerant reassembly with
  transport staging bounded by one frame, independent of d);
* :mod:`repro.agg.api`    — the unified :class:`AggNode` protocol
  (``ingest_frame`` / ``tick`` / ``published``) every aggregation endpoint
  implements, plus the one composed :class:`AggConfig` knob surface;
* :mod:`repro.agg.client` — encodes a local vector against a round's shared
  randomness, chunks it per the round MTU, and handles escalation +
  selective-retransmit responses;
* :mod:`repro.agg.server` — streaming accumulator: validates/reassembles
  arriving frames, drains payloads through ONE batched Pallas decode per
  color space, sums in integer coordinate space (bit-deterministic under
  any arrival order), NACKs undecodable clients with an escalated bound
  (RobustAgreement r <- r^2, lattice granularity fixed) and incomplete
  reassemblies with their missing chunk indices;
* :mod:`repro.agg.service` — multi-round coordinator: round k+1's anchor is
  round k's published mean (digest-pinned in the RoundSpec) and its
  per-bucket y comes from round k's decode telemetry
  (repro.core.qstate.update_y) — the anchored QState, threaded across
  rounds — plus the round life-cycle state machine
  (OPEN -> SEALING -> DRAINED -> PUBLISHED);
* :mod:`repro.agg.engine` — the event-driven continuous-round loop over the
  service: several live rounds at once (frames routed by their
  self-describing header), quorum-or-deadline cutover, overlapping drain,
  straggler deadlines feeding the RESEND budget, and admission
  control/backpressure via non-terminal ``STATUS_RETRY``;
* :mod:`repro.agg.sim`    — in-process harness driving hundreds of simulated
  clients through a server with stragglers, drops, duplicates, corruption,
  out-of-bound adversarial inputs and chunk-level loss
  (:func:`repro.agg.sim.run_chunked_lossy` pins the selective-retransmit
  wire cost byte-for-byte); :func:`repro.agg.sim.run_rounds` drives the
  multi-round service over a drifting large-norm population.
"""
from repro.agg.transport import (RoundSpec, FrameHeader, Payload, Response,
                                 WireError, TruncatedPayloadError,
                                 BadMagicError, VersionMismatchError,
                                 CorruptPayloadError, HeaderMismatchError,
                                 encode_payload, decode_payload,
                                 encode_frame, decode_frame,
                                 encode_response, decode_response,
                                 q_at_attempt, y_at_attempt,
                                 y_buckets_at_attempt, payload_bytes,
                                 STATUS_QUEUED, STATUS_NACK, STATUS_REJECT,
                                 STATUS_ACK, STATUS_RESEND, STATUS_RETRY,
                                 peek_route, Reassembler, ReassemblyStats)
from repro.agg.api import AggConfig, AggNode, PublishedLog, PublishedRound
from repro.agg.client import AggClient
from repro.agg.server import AggServer, RoundStats
from repro.agg.service import (AggService, Round, RoundState, ServiceConfig)
from repro.agg.engine import AggEngine, EngineConfig
from repro.agg.tree import AggTree, TierAggregator, TierStats

__all__ = [
    "RoundSpec", "FrameHeader", "Payload", "Response", "WireError",
    "TruncatedPayloadError", "BadMagicError", "VersionMismatchError",
    "CorruptPayloadError", "HeaderMismatchError", "encode_payload",
    "decode_payload", "encode_frame", "decode_frame", "encode_response",
    "decode_response", "q_at_attempt", "y_at_attempt",
    "y_buckets_at_attempt", "payload_bytes", "AggClient", "AggServer",
    "RoundStats", "AggService", "Round", "RoundState", "ServiceConfig",
    "AggEngine", "EngineConfig", "PublishedRound", "Reassembler",
    "ReassemblyStats", "STATUS_QUEUED", "STATUS_NACK", "STATUS_REJECT",
    "STATUS_ACK", "STATUS_RESEND", "STATUS_RETRY", "peek_route",
    "AggConfig", "AggNode", "PublishedLog", "AggTree", "TierAggregator",
    "TierStats",
]
