"""Frame layer: the versioned byte codec of the aggregation protocol (v5).

One transport frame carries one *chunk* of a client's packed payload body
(the whole body when it fits the round's MTU) behind a fixed self-describing
header.  Frame layout, little-endian (header arithmetic pinned in
:mod:`repro.core.wire_accounting`):

    offset  size  field
    0       4     magic         b"DMEA"
    4       2     version       WIRE_VERSION (5)
    6       2     flags         bit 0: rotate (HD pre-rotation, paper §6)
                                bit 1: anchored (encoded x - anchor)
    8       4     round_id
    12      4     client_id
    16      4     attempt       escalation level (0 on first send)
    20      4     q             color classes at this attempt (q0^(2^attempt))
    24      4     d             unpadded vector length
    28      4     bucket        coordinates per bucket (power of two)
    32      4     seed          round's shared-randomness seed (dither u)
    36      4     rot_seed      shared Hadamard-diagonal seed
    40      4     n_words       packed uint32 word count of the FULL body
    44      4     nb            bucket count (= padded d / bucket)
    48      4     check         coordinate checksum h(k) (core.error_detect)
    52      4     anchor_digest CRC-32 of the round anchor (0 = unanchored)
    56      4     n_chunks      chunks the body was split into (1 = unchunked)
    60      4     chunk_index   which chunk this frame carries
    64      4     payload_crc   CRC-32 of the FULL body (all chunks joined)
    68      4     n_summed      ADDITIVE client count this payload sums
                                (1 = an ordinary client; a tree tier
                                forwarding a combined payload carries how
                                many accepted clients it folded in)
    72      4     crc           CRC-32 of this frame (header zero-crc + chunk)
    76      ...   chunk bytes   body[chunk_index*mtu : +mtu] (packed words
                                then the f32 sides sidecar; the MTU is the
                                round's, pinned in RoundSpec)

Every frame repeats the full header, so any chunk alone identifies its
round, client, attempt, lattice geometry and position — a receiver can
validate and place chunk k without having seen chunks 0..k-1, and a
retransmitted chunk is byte-identical (idempotent).  The per-frame ``crc``
protects each chunk independently — a corrupt byte costs one chunk
retransmit, never the payload — while ``payload_crc`` seals the reassembled
body end to end.

The payload body is exactly the packed wire format of the shard_map
collectives (repro.dist.collectives): uint32 words from the fused Pallas
encode plus the per-bucket sides sidecar.  Escalation follows
RobustAgreement (paper Alg. 5) with the lattice granularity held fixed: the
round pins the sides s_b = 2*y_b/(q0-1) and each retry squares the color
space, q <- q^2 (capped at 2^16), so integer coordinates from different
attempts remain summable.

Server responses (v5) carry the per-bucket decode margins, the streaming
flow-control state (cumulative ack + send-window credit) and — for
``STATUS_RESEND`` — the missing chunk indices of an incomplete reassembly:

    magic b"DMER" | version u16 | status u16 | round_id u32 | client_id u32
    | attempt_next u32 | q_next u32 | y_next f32 | nb u32 | n_missing u32
    | ack u32 | credit u32 | y_buckets f32*nb | missing u32*n_missing
    | crc u32

v2 -> v3 migration: the v2 single-frame header (56 bytes + CRC) grew the
three chunk fields (n_chunks / chunk_index / payload_crc, +12 bytes); a v2
payload is exactly a v3 frame with n_chunks=1, chunk_index=0 and
payload_crc over the same body.  v2 frames are refused with
VersionMismatchError — there is no silent fallback, because a v2 sender
cannot participate in chunked reassembly or selective retransmit.

v3 -> v4 migration: one additive field, ``n_summed``, appended after
``payload_crc`` (header 68 -> 72 bytes before the CRC word).  Every field
keeps its v3 offset; an ordinary client always sends n_summed=1, and a v3
payload is exactly a v4 payload with n_summed=1.  A tree tier
(:mod:`repro.agg.tree`) that folded m accepted clients into one combined
payload forwards it with n_summed=m, so the root can weight its integer
coordinate sum by the true client count without decoding anything at the
tier.  v3 frames are refused with VersionMismatchError, same policy as
v2 -> v3.

v4 -> v5 migration: same additive-field policy, on the RESPONSE side this
time (the frame layout is unchanged).  Two u32 fields, ``ack`` and
``credit``, are appended to the response head after ``n_missing`` (head
36 -> 44 bytes); every earlier field keeps its v4 offset.  ``ack`` is the
cumulative count of contiguous-from-zero chunks the server holds for the
client's live stream (a TCP-style cumulative ack: chunks received out of
order beyond a gap are buffered but not acked), and ``credit`` is how many
chunks the client may have in flight beyond ``ack`` (the round's
``RoundSpec.window``; 0 = unwindowed, send freely — the v4 behaviour).
RESEND and window advance share this one response path: a RESEND names the
gap chunks while ack/credit tell the sender how far its fresh-data window
has slid.  v4 responses are refused with VersionMismatchError, same policy
as the frame-side bumps.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

import repro.obs as _obs
from repro.core import lattice as L
from repro.core import wire_accounting as WA
from repro.dist.collectives import (QSyncConfig, flat_size_padded,
                                    _ROTATION_SEED)

MAGIC_PAYLOAD = b"DMEA"
MAGIC_RESPONSE = b"DMER"
WIRE_VERSION = 5
Q_CAP = 1 << 16                   # largest packable color space (16 bits)

FLAG_ROTATE = 1 << 0
FLAG_ANCHORED = 1 << 1

_HEADER = struct.Struct("<4sHH16I")
# response header up to and including the v5 ack/credit pair; followed by
# nb f32 margins, n_missing u32 chunk indices, and the crc
_RESPONSE_HEAD = struct.Struct("<4sHHIIIIfIIII")

FRAME_HEADER_BYTES = WA.FRAME_HEADER_BYTES
# the agg header sizes delegate to core.wire_accounting (the one wire-byte
# definition); a drifting struct layout fails loudly at import
assert _HEADER.size + 4 == WA.FRAME_HEADER_BYTES
assert _RESPONSE_HEAD.size == WA.RESPONSE_HEAD_BYTES

# response statuses
STATUS_QUEUED = 0     # payload buffered; verdict at the next drain
STATUS_ACK = 1        # payload decoded and accumulated
STATUS_NACK = 2       # decode failure detected: retry at (attempt+1, q_next)
STATUS_REJECT = 3     # malformed/mismatched payload: not retryable as-is
STATUS_RESEND = 4     # reassembly incomplete: retransmit the missing chunks
STATUS_RETRY = 5      # NON-terminal "not now": the round is sealed to new
                      # clients, the pending store is full, or the frame's
                      # round is no longer (or not yet) live.  round_id
                      # echoes the offending frame's round so the sender's
                      # protocol object sees it; q_next carries the round id
                      # currently open for admission (0 = unknown) — re-send
                      # after backoff, or re-enroll there.  The response
                      # wire format is unchanged from v3; the status value
                      # is additive.


class WireError(ValueError):
    """Base class for payload parse/validation failures."""


class TruncatedPayloadError(WireError):
    pass


class BadMagicError(WireError):
    pass


class VersionMismatchError(WireError):
    pass


class CorruptPayloadError(WireError):
    pass


class HeaderMismatchError(WireError):
    """Frame is well-formed but does not match the round's spec."""


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static per-round protocol contract (distributed out of band).

    The lattice granularity of the round is pinned per bucket by
    (y_buckets, cfg.q): s_b = 2*y_b/(cfg.q - 1) (uniformly y0 when
    ``y_buckets`` is None).  Escalation squares q with the sides fixed, so
    the attempt-a decode margin per bucket is y_a,b = s_b*(q_a - 1)/2.

    v3 addition: ``mtu`` — the round's chunk size in bytes.  0 keeps the
    single-frame protocol; a positive MTU makes every client split its
    payload body into ceil(body/mtu) independently-framed chunks (the
    transport chunk layer), and the server reassembles them out of order.
    The MTU is part of the contract so chunk geometry is checkable from any
    one frame (offset = chunk_index * mtu).

    v5 addition: ``window`` — the credit-based send window, in chunks.  0
    keeps the v4 blast-all-chunks behaviour; a positive window caps every
    client at ``window`` chunks in flight (sent but not covered by the
    server's cumulative ack) and switches the server to the streaming
    drain: validated contiguous chunk runs are residual-folded into the
    round sum as they land and their bytes freed, instead of being staged
    until the payload-CRC seal.  The published mean is bit-identical either
    way; the window only bounds sender burstiness and server pending-store
    memory.

    v2 carried ``y_buckets`` (per-bucket distance bounds from the previous
    round's telemetry) and ``anchor_digest`` (CRC-32 of the round anchor —
    round k-1's published mean; 0 = unanchored).  Clients encode
    ``x - anchor`` and the server REJECTs payloads whose digest does not
    match (stale-anchor clients are not silently mis-decoded).
    """
    round_id: int
    d: int
    cfg: QSyncConfig = QSyncConfig()
    y0: float = 1.0
    seed: int = 0
    # defaulting to the collectives' shared diagonal seed keeps the agg
    # bucket pipeline bit-identical to the shard_map star collective
    rot_seed: int = _ROTATION_SEED
    max_attempts: int = 4
    y_buckets: "tuple[float, ...] | None" = None
    anchor_digest: int = 0
    mtu: int = 0
    window: int = 0

    def __post_init__(self):
        if self.y_buckets is not None and len(self.y_buckets) != self.nb:
            raise ValueError(
                f"y_buckets has {len(self.y_buckets)} entries for "
                f"{self.nb} buckets")
        if self.mtu != 0 and self.mtu < 64:
            raise ValueError(f"mtu must be 0 (unchunked) or >= 64 bytes, "
                             f"got {self.mtu}")
        if self.window < 0:
            raise ValueError(f"window must be >= 0 chunks, "
                             f"got {self.window}")
        if self.window > 0 and self.mtu == 0:
            raise ValueError("window > 0 needs a chunked round (mtu > 0): "
                             "credit is granted per chunk")

    @property
    def padded(self) -> int:
        return flat_size_padded(self.d, self.cfg)

    @property
    def nb(self) -> int:
        return self.padded // self.cfg.bucket

    @property
    def anchored(self) -> bool:
        return self.anchor_digest != 0

    @property
    def side(self) -> float:
        """The uniform lattice side s0 (granularity never escalates).  With
        per-bucket bounds this is the *largest* side (y0 is kept as the
        uniform summary; sides_np() is the authoritative per-bucket array).
        """
        return 2.0 * self.y0 / (self.cfg.q - 1)

    def y_np(self) -> np.ndarray:
        """(nb,) f32 per-bucket distance bounds of the round."""
        if self.y_buckets is None:
            return np.full((self.nb,), self.y0, np.float32)
        return np.asarray(self.y_buckets, np.float32)

    def sides_np(self) -> np.ndarray:
        """(nb,) f32 per-bucket lattice sides s_b = 2*y_b/(q-1)."""
        return (self.y_np() * np.float32(2.0 / (self.cfg.q - 1))
                ).astype(np.float32)

    def body_bytes(self, attempt: int = 0) -> int:
        """Packed-words + sides body size at an escalation level."""
        q = q_at_attempt(self.cfg.q, attempt)
        return WA.packed_body_bytes(self.padded, L.bits_for_q(q), self.nb)

    def n_chunks(self, attempt: int = 0) -> int:
        """Chunks per client payload at an escalation level."""
        return WA.n_chunks(self.body_bytes(attempt), self.mtu)


def q_at_attempt(q0: int, attempt: int) -> int:
    """RobustAgreement color-space schedule: q0^(2^attempt), capped at 2^16."""
    q = q0
    for _ in range(attempt):
        if q >= Q_CAP:
            return Q_CAP
        q = q * q
    return min(q, Q_CAP)


def y_at_attempt(spec: RoundSpec, attempt: int) -> float:
    """Largest decode margin at an escalation level: y_a = s0*(q_a - 1)/2
    (the scalar summary; per-bucket margins via y_buckets_at_attempt)."""
    return spec.side * (q_at_attempt(spec.cfg.q, attempt) - 1) / 2.0


def y_buckets_at_attempt(spec: RoundSpec, attempt: int) -> np.ndarray:
    """(nb,) per-bucket decode margins at an escalation level."""
    q = q_at_attempt(spec.cfg.q, attempt)
    return (spec.sides_np() * np.float32((q - 1) / 2.0)).astype(np.float32)


def payload_bytes(spec: RoundSpec, attempt: int = 0) -> int:
    """Exact on-the-wire size of one client payload at an attempt level:
    the packed body plus one frame header per chunk (core.wire_accounting
    is the authoritative arithmetic, cross-checked against ``len()`` of the
    actual frames in the tests)."""
    q = q_at_attempt(spec.cfg.q, attempt)
    return WA.agg_payload_bytes(spec.padded, L.bits_for_q(q), spec.nb,
                                spec.mtu)


@dataclasses.dataclass(frozen=True)
class FrameHeader:
    """Parsed v4 frame header (framing validated; chunk body separate)."""
    round_id: int
    client_id: int
    attempt: int
    q: int
    d: int
    bucket: int
    seed: int
    rot_seed: int
    n_words: int
    nb: int
    check: int
    anchor_digest: int
    n_chunks: int
    chunk_index: int
    payload_crc: int
    rotate: bool
    anchored: bool
    n_summed: int = 1          # additive client count (tree tiers > 1)

    @property
    def body_len(self) -> int:
        """Byte length of the FULL payload body this frame belongs to."""
        return 4 * self.n_words + 4 * self.nb


@dataclasses.dataclass(frozen=True)
class Payload:
    """Complete client payload (validated framing; numpy views of the body)."""
    round_id: int
    client_id: int
    attempt: int
    q: int
    d: int
    bucket: int
    seed: int
    rot_seed: int
    rotate: bool
    check: int
    words: np.ndarray          # (n_words,) uint32
    sides: np.ndarray          # (nb,) f32
    anchor_digest: int = 0
    anchored: bool = False
    n_summed: int = 1          # additive client count (tree tiers > 1)
    # True when the words were already residual-folded range-by-range as
    # the chunks landed (streaming drain): ``words`` is empty — the body
    # bytes are gone — and only the retained sides sidecar remains for the
    # spec check at completion.  Streamed payloads never enter the batched
    # pending store.
    streamed: bool = False

    @property
    def nb(self) -> int:
        return self.sides.shape[0]


@dataclasses.dataclass(frozen=True)
class Response:
    status: int
    round_id: int
    client_id: int
    attempt_next: int
    q_next: int
    y_next: float
    y_buckets: "tuple[float, ...]" = ()    # per-bucket margins (NACK/QUEUED)
    missing: "tuple[int, ...]" = ()        # chunk indices (STATUS_RESEND)
    ack: int = 0                           # cumulative contiguous chunks held
    credit: int = 0                        # chunks allowed in flight past ack


def _pack_header(h: FrameHeader) -> bytes:
    flags = (FLAG_ROTATE if h.rotate else 0) \
        | (FLAG_ANCHORED if h.anchored else 0)
    return _HEADER.pack(MAGIC_PAYLOAD, WIRE_VERSION, flags, h.round_id,
                        h.client_id, h.attempt, h.q, h.d, h.bucket, h.seed,
                        h.rot_seed, h.n_words, h.nb, h.check & 0xFFFFFFFF,
                        h.anchor_digest & 0xFFFFFFFF, h.n_chunks,
                        h.chunk_index, h.payload_crc & 0xFFFFFFFF,
                        h.n_summed)


def encode_frame(h: FrameHeader, chunk: bytes) -> bytes:
    """Serialize one chunk-carrying frame (header + CRC + chunk bytes)."""
    head0 = _pack_header(h)
    crc = zlib.crc32(chunk, zlib.crc32(head0))
    return head0 + struct.pack("<I", crc) + chunk


_PEEK = struct.Struct("<4sHHII")      # magic | version | flags | round | cid


def peek_route(data: bytes) -> "tuple[int, int] | None":
    """Cheap (round_id, client_id) peek for event-loop routing — no CRC.

    Returns None when the prefix cannot even be a v3 frame (short / bad
    magic / wrong version); the caller then falls through to the full
    decoder, which produces the proper wire REJECT.  A corrupted-but-
    plausible round_id merely routes the frame to a server that will fail
    its CRC — routing never needs to be trusted, only cheap.
    """
    if len(data) < _PEEK.size:
        return None
    magic, version, _, round_id, client_id = _PEEK.unpack_from(data, 0)
    if magic != MAGIC_PAYLOAD or version != WIRE_VERSION:
        return None
    return round_id, client_id


def decode_frame(data: bytes) -> "tuple[FrameHeader, bytes]":
    """Parse + integrity-check one frame; raises WireError subclasses.

    Validates everything checkable from the frame alone: magic, version,
    per-frame CRC, header self-consistency (lattice geometry, flag/digest
    agreement, chunk coordinates), and — for single-frame payloads, whose
    body is fully present — the body length and payload CRC.  Chunk length
    against the round's MTU is the spec's business
    (:func:`check_frame_against_spec`).
    """
    try:
        return _decode_frame(data)
    except WireError as e:
        _count_decode_error("frame", e)
        raise


def _count_decode_error(path: str, e: WireError) -> None:
    if _obs.metrics_enabled():
        _obs.counter("wire_decode_errors", path=path,
                     kind=type(e).__name__).inc()


def _decode_frame(data: bytes) -> "tuple[FrameHeader, bytes]":
    hsize = _HEADER.size + 4                       # header + crc word
    if len(data) < hsize:
        raise TruncatedPayloadError(
            f"frame of {len(data)} bytes is shorter than the "
            f"{hsize}-byte header")
    (magic, version, flags, round_id, client_id, attempt, q, d, bucket,
     seed, rot_seed, n_words, nb, check, anchor_digest, n_chunks,
     chunk_index, payload_crc, n_summed) = _HEADER.unpack_from(data, 0)
    if magic != MAGIC_PAYLOAD:
        raise BadMagicError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise VersionMismatchError(
            f"wire version {version} != supported {WIRE_VERSION}")
    (crc,) = struct.unpack_from("<I", data, _HEADER.size)
    chunk = data[hsize:]
    # header self-consistency (cheap sanity; spec matching is the server's)
    if q < 2 or q > Q_CAP or bucket < 1 or (bucket & (bucket - 1)):
        raise CorruptPayloadError(f"inconsistent header: q={q} "
                                  f"bucket={bucket}")
    padded = nb * bucket
    if d > padded or padded - d >= bucket:
        raise CorruptPayloadError(
            f"inconsistent header: d={d} vs nb*bucket={padded}")
    if n_words != L.packed_len(padded, L.bits_for_q(q)):
        raise CorruptPayloadError(
            f"inconsistent header: {n_words} words for {padded} coords "
            f"at q={q}")
    anchored = bool(flags & FLAG_ANCHORED)
    if anchored != (anchor_digest != 0):
        raise CorruptPayloadError(
            f"inconsistent header: anchored flag {anchored} vs "
            f"digest {anchor_digest}")
    body_len = 4 * n_words + 4 * nb
    if n_chunks < 1 or chunk_index >= n_chunks:
        raise CorruptPayloadError(
            f"inconsistent header: chunk {chunk_index} of {n_chunks}")
    if n_summed < 1:
        raise CorruptPayloadError(
            f"inconsistent header: n_summed={n_summed} (must be >= 1)")
    if n_chunks == 1 and len(chunk) < body_len:
        raise TruncatedPayloadError(
            f"body has {len(chunk)} bytes, header promises {body_len}")
    if len(chunk) == 0 or len(chunk) > body_len:
        raise CorruptPayloadError(
            f"chunk has {len(chunk)} bytes for a {body_len}-byte body")
    if zlib.crc32(chunk, zlib.crc32(data[:_HEADER.size])) != crc:
        raise CorruptPayloadError("frame CRC mismatch")
    if n_chunks == 1 and zlib.crc32(chunk) != payload_crc:
        raise CorruptPayloadError("payload CRC mismatch")
    h = FrameHeader(round_id=round_id, client_id=client_id, attempt=attempt,
                    q=q, d=d, bucket=bucket, seed=seed, rot_seed=rot_seed,
                    n_words=n_words, nb=nb, check=check,
                    anchor_digest=anchor_digest, n_chunks=n_chunks,
                    chunk_index=chunk_index, payload_crc=payload_crc,
                    rotate=bool(flags & FLAG_ROTATE), anchored=anchored,
                    n_summed=n_summed)
    return h, chunk


def payload_from_body(h: FrameHeader, body) -> Payload:
    """Assemble the Payload view over a complete (reassembled) body."""
    words = np.frombuffer(body, dtype="<u4", count=h.n_words)
    sides = np.frombuffer(body, dtype="<f4", offset=4 * h.n_words,
                          count=h.nb)
    return Payload(round_id=h.round_id, client_id=h.client_id,
                   attempt=h.attempt, q=h.q, d=h.d, bucket=h.bucket,
                   seed=h.seed, rot_seed=h.rot_seed, rotate=h.rotate,
                   check=h.check, words=words, sides=sides,
                   anchor_digest=h.anchor_digest, anchored=h.anchored,
                   n_summed=h.n_summed)


def streamed_payload(h: FrameHeader, sides_bytes: bytes) -> Payload:
    """Assemble the words-free Payload of a stream whose word ranges were
    already folded incrementally (the streaming drain's completion record:
    header identity + the retained sides sidecar)."""
    sides = np.frombuffer(sides_bytes, dtype="<f4", count=h.nb)
    return Payload(round_id=h.round_id, client_id=h.client_id,
                   attempt=h.attempt, q=h.q, d=h.d, bucket=h.bucket,
                   seed=h.seed, rot_seed=h.rot_seed, rotate=h.rotate,
                   check=h.check, words=np.empty((0,), np.uint32),
                   sides=sides, anchor_digest=h.anchor_digest,
                   anchored=h.anchored, n_summed=h.n_summed, streamed=True)


def build_payload(spec: RoundSpec, client_id: int, attempt: int, q: int,
                  words: np.ndarray, sides: np.ndarray, check: int,
                  n_summed: int = 1) -> "tuple[FrameHeader, bytes]":
    """Assemble (header, body) of one client message — the ONE place the
    payload-level header fields are filled in (the chunk layer re-derives
    only the chunk coordinates, so the chunked and unchunked encoders can
    never desync).  ``n_summed`` > 1 marks a tree tier's combined payload
    (the additive client count it folded in)."""
    if n_summed < 1:
        raise ValueError(f"n_summed must be >= 1, got {n_summed}")
    words = np.ascontiguousarray(np.asarray(words, dtype=np.uint32))
    sides = np.ascontiguousarray(np.asarray(sides, dtype=np.float32))
    body = words.tobytes() + sides.tobytes()
    h = FrameHeader(round_id=spec.round_id, client_id=client_id,
                    attempt=attempt, q=q, d=spec.d, bucket=spec.cfg.bucket,
                    seed=spec.seed, rot_seed=spec.rot_seed,
                    n_words=words.shape[0], nb=sides.shape[0],
                    check=int(check) & 0xFFFFFFFF,
                    anchor_digest=spec.anchor_digest & 0xFFFFFFFF,
                    n_chunks=1, chunk_index=0, payload_crc=zlib.crc32(body),
                    rotate=spec.cfg.rotate, anchored=spec.anchored,
                    n_summed=int(n_summed))
    return h, body


def encode_payload(spec: RoundSpec, client_id: int, attempt: int, q: int,
                   words: np.ndarray, sides: np.ndarray, check: int) -> bytes:
    """Serialize one client message as a SINGLE frame (the unchunked path;
    the chunk layer splits bigger-than-MTU bodies into many frames)."""
    h, body = build_payload(spec, client_id, attempt, q, words, sides, check)
    return encode_frame(h, body)


def decode_payload(data: bytes) -> Payload:
    """Parse + integrity-check a complete single-frame payload."""
    h, body = decode_frame(data)
    if h.n_chunks != 1:
        raise CorruptPayloadError(
            f"multi-chunk frame ({h.chunk_index}/{h.n_chunks}) where a "
            f"complete payload was expected")
    return payload_from_body(h, body)


def _spec_mismatches(round_id, attempt, q, d, bucket, seed, rot_seed,
                     rotate, anchor_digest, spec: RoundSpec) -> "list[str]":
    if round_id != spec.round_id:
        raise HeaderMismatchError(
            f"round {round_id} != current {spec.round_id}")
    want_q = q_at_attempt(spec.cfg.q, attempt)
    mism = [
        f"{k}: got {got}, want {want}" for k, got, want in (
            ("d", d, spec.d),
            ("bucket", bucket, spec.cfg.bucket),
            ("rotate", rotate, spec.cfg.rotate),
            ("seed", seed, spec.seed),
            ("rot_seed", rot_seed, spec.rot_seed),
            ("q", q, want_q),
        ) if got != want]
    if attempt >= spec.max_attempts:
        mism.append(f"attempt {attempt} >= max {spec.max_attempts}")
    # anchor agreement: a client that encoded against a stale/foreign anchor
    # produced coordinates on a shifted lattice — its checksum is self-
    # consistent, so only the digest stops it from corrupting the mean
    if anchor_digest != (spec.anchor_digest & 0xFFFFFFFF):
        mism.append(f"anchor digest {anchor_digest:#x} != round "
                    f"{spec.anchor_digest:#x}")
    return mism


def check_frame_against_spec(h: FrameHeader, spec: RoundSpec,
                             chunk_len: int) -> None:
    """Raise HeaderMismatchError when a frame doesn't belong to a round.

    Runs per chunk, before any reassembly state is touched — a cross-round
    stale chunk, a foreign-config chunk, or a chunk whose geometry violates
    the round's MTU contract never enters a session.
    """
    mism = _spec_mismatches(h.round_id, h.attempt, h.q, h.d, h.bucket,
                            h.seed, h.rot_seed, h.rotate, h.anchor_digest,
                            spec)
    want_chunks = WA.n_chunks(h.body_len, spec.mtu)
    if h.n_chunks != want_chunks:
        mism.append(f"n_chunks {h.n_chunks} != {want_chunks} for a "
                    f"{h.body_len}-byte body at mtu {spec.mtu}")
    elif h.n_chunks > 1:
        _, want_len = WA.chunk_span(h.body_len, spec.mtu, h.chunk_index)
        if chunk_len != want_len:
            mism.append(f"chunk {h.chunk_index} has {chunk_len} bytes, "
                        f"mtu geometry wants {want_len}")
    if mism:
        raise HeaderMismatchError("; ".join(mism))


def check_sides_against_spec(p: Payload, spec: RoundSpec) -> None:
    """The body-level spec check: the sides sidecar must carry the round's
    pinned per-bucket granularity — a client built against different bounds
    would otherwise be accepted (its checksum is self-consistent) yet
    scaled by the *round's* sides at finalize, silently corrupting the
    mean.  This is the ONLY check the header-level
    :func:`check_frame_against_spec` (already run once per frame) cannot
    do, so it is all the server re-runs at payload completion."""
    if not np.array_equal(p.sides, spec.sides_np()):
        raise HeaderMismatchError(
            "sides sidecar != round per-bucket sides (y mismatch)")


def check_against_spec(p: Payload, spec: RoundSpec) -> None:
    """Raise HeaderMismatchError when a complete payload doesn't belong to
    a round: every header-level check plus the sides sidecar."""
    mism = _spec_mismatches(p.round_id, p.attempt, p.q, p.d, p.bucket,
                            p.seed, p.rot_seed, p.rotate, p.anchor_digest,
                            spec)
    if not np.array_equal(p.sides, spec.sides_np()):
        mism.append("sides sidecar != round per-bucket sides (y mismatch)")
    if mism:
        raise HeaderMismatchError("; ".join(mism))


def encode_response(r: Response) -> bytes:
    yb = np.asarray(r.y_buckets, np.float32)
    miss = np.asarray(r.missing, np.uint32)
    head0 = _RESPONSE_HEAD.pack(MAGIC_RESPONSE, WIRE_VERSION, r.status,
                                r.round_id, r.client_id, r.attempt_next,
                                r.q_next, r.y_next, yb.shape[0],
                                miss.shape[0], r.ack, r.credit)
    body = head0 + yb.tobytes() + miss.tobytes()
    return body + struct.pack("<I", zlib.crc32(body))


def decode_response(data: bytes) -> Response:
    try:
        return _decode_response(data)
    except WireError as e:
        _count_decode_error("response", e)
        raise


def _decode_response(data: bytes) -> Response:
    hsize = _RESPONSE_HEAD.size
    if len(data) < hsize + 4:
        raise TruncatedPayloadError(
            f"response of {len(data)} bytes < {hsize + 4}")
    (magic, version, status, round_id, client_id, attempt_next, q_next,
     y_next, nb, n_missing, ack, credit) = _RESPONSE_HEAD.unpack_from(data, 0)
    if magic != MAGIC_RESPONSE:
        raise BadMagicError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise VersionMismatchError(
            f"wire version {version} != supported {WIRE_VERSION}")
    want = hsize + 4 * nb + 4 * n_missing + 4
    if len(data) != want:
        raise CorruptPayloadError(
            f"response has {len(data)} bytes, header promises {want}")
    (crc,) = struct.unpack_from("<I", data, want - 4)
    if zlib.crc32(data[:want - 4]) != crc:
        raise CorruptPayloadError("response CRC mismatch")
    yb = np.frombuffer(data, dtype="<f4", offset=hsize, count=nb)
    miss = np.frombuffer(data, dtype="<u4", offset=hsize + 4 * nb,
                         count=n_missing)
    return Response(status=status, round_id=round_id, client_id=client_id,
                    attempt_next=attempt_next, q_next=q_next, y_next=y_next,
                    y_buckets=tuple(float(v) for v in yb),
                    missing=tuple(int(v) for v in miss),
                    ack=ack, credit=credit)
