"""Chunk layer: fixed-MTU splitting of a packed payload body into frames.

A payload body larger than the round's MTU is split into
``ceil(body/mtu)`` chunks; every chunk except the last carries exactly
``mtu`` bytes, so chunk k always covers ``body[k*mtu : k*mtu + mtu]`` and a
receiver can place any chunk without having seen the others.  Each chunk is
wrapped in its own self-describing v3 frame (full header + per-frame CRC):
independently validatable, idempotently re-sendable, and individually
retransmittable — a corrupt or dropped byte costs ONE chunk frame on the
wire, never the payload (the server's STATUS_RESEND response names exactly
the missing chunk indices; see :mod:`repro.agg.transport.session`).

The byte geometry (chunk count, spans, per-frame overhead) delegates to
:mod:`repro.core.wire_accounting`.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

import repro.obs as _obs
from repro.agg.transport import frame as F
from repro.core import wire_accounting as WA


def chunk_frames(h0: F.FrameHeader, body: bytes, mtu: int) -> "list[bytes]":
    """Frame a complete body as its chunk sequence under an MTU.

    ``h0`` supplies the payload-level header fields; n_chunks, chunk_index
    and payload_crc are (re)derived here so the chunk coordinates can never
    disagree with the body actually framed.
    """
    nc = WA.n_chunks(len(body), mtu)
    pcrc = zlib.crc32(body)
    frames = []
    for i in range(nc):
        off, ln = WA.chunk_span(len(body), mtu, i)
        h = dataclasses.replace(h0, n_chunks=nc, chunk_index=i,
                                payload_crc=pcrc)
        frames.append(F.encode_frame(h, body[off:off + ln]))
    return frames


def encode_chunks(spec: F.RoundSpec, client_id: int, attempt: int, q: int,
                  words: np.ndarray, sides: np.ndarray,
                  check: int, n_summed: int = 1) -> "list[bytes]":
    """Serialize one client message as its chunk-frame sequence (one frame
    when the body fits the MTU or the round is unchunked — in which case
    the single frame is byte-identical to :func:`frame.encode_payload`,
    whose header builder this delegates to).  ``n_summed`` > 1 marks a tree
    tier's combined payload (how many accepted clients it folded in)."""
    h0, body = F.build_payload(spec, client_id, attempt, q, words, sides,
                               check, n_summed=n_summed)
    return chunk_frames(h0, body, spec.mtu)


def select(frames: "list[bytes]", missing: "tuple[int, ...]"
           ) -> "list[bytes]":
    """The selective-retransmit set: only the frames a STATUS_RESEND names.

    Out-of-range indices mean the response is corrupt or belongs to a
    different attempt's geometry — fall back to re-sending everything
    (idempotent, so over-sending is safe; under-sending would deadlock)."""
    if not missing or any(i >= len(frames) for i in missing):
        out = list(frames)
    else:
        out = [frames[i] for i in missing]
    if _obs.metrics_enabled():
        _obs.counter("chunk_retransmit_frames").inc(len(out))
    return out
