"""Chunk layer: fixed-MTU splitting of a packed payload body into frames.

A payload body larger than the round's MTU is split into
``ceil(body/mtu)`` chunks; every chunk except the last carries exactly
``mtu`` bytes, so chunk k always covers ``body[k*mtu : k*mtu + mtu]`` and a
receiver can place any chunk without having seen the others.  Each chunk is
wrapped in its own self-describing v3 frame (full header + per-frame CRC):
independently validatable, idempotently re-sendable, and individually
retransmittable — a corrupt or dropped byte costs ONE chunk frame on the
wire, never the payload (the server's STATUS_RESEND response names exactly
the missing chunk indices; see :mod:`repro.agg.transport.session`).

The byte geometry (chunk count, spans, per-frame overhead) delegates to
:mod:`repro.core.wire_accounting`.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

import repro.obs as _obs
from repro.agg.transport import frame as F
from repro.core import wire_accounting as WA


def chunk_frames(h0: F.FrameHeader, body: bytes, mtu: int) -> "list[bytes]":
    """Frame a complete body as its chunk sequence under an MTU.

    ``h0`` supplies the payload-level header fields; n_chunks, chunk_index
    and payload_crc are (re)derived here so the chunk coordinates can never
    disagree with the body actually framed.
    """
    nc = WA.n_chunks(len(body), mtu)
    pcrc = zlib.crc32(body)
    frames = []
    for i in range(nc):
        off, ln = WA.chunk_span(len(body), mtu, i)
        h = dataclasses.replace(h0, n_chunks=nc, chunk_index=i,
                                payload_crc=pcrc)
        frames.append(F.encode_frame(h, body[off:off + ln]))
    return frames


def encode_chunks(spec: F.RoundSpec, client_id: int, attempt: int, q: int,
                  words: np.ndarray, sides: np.ndarray,
                  check: int, n_summed: int = 1) -> "list[bytes]":
    """Serialize one client message as its chunk-frame sequence (one frame
    when the body fits the MTU or the round is unchunked — in which case
    the single frame is byte-identical to :func:`frame.encode_payload`,
    whose header builder this delegates to).  ``n_summed`` > 1 marks a tree
    tier's combined payload (how many accepted clients it folded in)."""
    h0, body = F.build_payload(spec, client_id, attempt, q, words, sides,
                               check, n_summed=n_summed)
    return chunk_frames(h0, body, spec.mtu)


class SendWindow:
    """Credit-based pacing of one attempt's chunk-frame sequence (v5).

    The sender keeps at most ``window`` chunks in flight — sent but not yet
    covered by the server's cumulative contiguous ack (``Response.ack``,
    the v5 additive flow-control field; the static grant rides
    ``Response.credit``).  ``sendable()`` returns the next frames the
    credit allows and every response's ack feeds :meth:`note_ack` — RESEND
    recovery re-sends only chunks below the sent prefix (``next``), so a
    drain-time RESEND that names credit-blocked chunks never defeats the
    window.  A response that unblocks nothing while frames remain is a
    *window stall* (counted here and exported as the ``window_stalls`` obs
    counter): the sender is blocked on in-flight chunks — the backpressure
    signal the open-loop driver models (:mod:`repro.agg.sim`)."""

    def __init__(self, frames: "list[bytes]", window: int):
        self.frames = frames
        self.window = window
        self.next = 0       # lowest chunk index never sent
        self.ack = 0        # server's cumulative contiguous-chunk ack
        self.stalls = 0

    @property
    def done(self) -> bool:
        return self.next >= len(self.frames)

    @property
    def in_flight(self) -> int:
        return max(self.next - self.ack, 0)

    def note_ack(self, ack: int) -> None:
        """Fold in a response's cumulative ack (monotonic; never rewinds)."""
        if ack > self.ack:
            self.ack = min(ack, len(self.frames))

    def unacked(self) -> "list[bytes]":
        """The in-flight (sent, unacked) frames — the timeout-retransmit
        set: when every copy was lost the server has no stream to RESEND
        from, so recovery must come from the sender's own timer."""
        return list(self.frames[self.ack:self.next])

    def sendable(self) -> "list[bytes]":
        """The frames the current credit allows on the wire now."""
        end = min(self.ack + self.window, len(self.frames))
        out = self.frames[self.next:end]
        if out:
            self.next = end
        elif not self.done:
            self.stalls += 1
            if _obs.metrics_enabled():
                _obs.counter("window_stalls").inc()
        return out


def select(frames: "list[bytes]", missing: "tuple[int, ...]"
           ) -> "list[bytes]":
    """The selective-retransmit set: only the frames a STATUS_RESEND names.

    Out-of-range indices mean the response is corrupt or belongs to a
    different attempt's geometry — fall back to re-sending everything
    (idempotent, so over-sending is safe; under-sending would deadlock)."""
    if not missing or any(i >= len(frames) for i in missing):
        out = list(frames)
    else:
        out = [frames[i] for i in missing]
    if _obs.metrics_enabled():
        _obs.counter("chunk_retransmit_frames").inc(len(out))
    return out
