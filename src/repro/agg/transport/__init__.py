"""Layered streaming transport for the aggregation protocol.

Three layers, lowest first:

* :mod:`repro.agg.transport.frame` — the versioned byte codec: one
  self-describing v3 frame header (round/client identity, lattice geometry,
  §5 checksum, anchor digest, chunk coordinates ``n_chunks``/``chunk_index``
  and the whole-payload ``payload_crc``) + per-frame CRC-32.  Also the
  round's protocol contract (:class:`RoundSpec`) and the response codec.
* :mod:`repro.agg.transport.chunks` — splits a packed payload body into
  fixed-MTU chunks, each independently framed, CRC'd and idempotently
  re-sendable; selective retransmit re-sends *only* the chunks a
  ``STATUS_RESEND`` response names.
* :mod:`repro.agg.transport.session` — out-of-order, duplicate-tolerant
  server-side reassembly: validated chunks are committed in place into a
  preallocated body buffer (no reorder stash), so the transport's own
  staging memory is bounded by one frame (header + MTU) per in-flight
  receive, independent of the vector length d.

The byte arithmetic of every layer delegates to
:mod:`repro.core.wire_accounting` — the repo's single wire-byte definition.
"""
from repro.agg.transport.frame import (  # noqa: F401
    FrameHeader, Payload, Response, RoundSpec, WireError,
    TruncatedPayloadError, BadMagicError, VersionMismatchError,
    CorruptPayloadError, HeaderMismatchError, MAGIC_PAYLOAD, MAGIC_RESPONSE,
    WIRE_VERSION, Q_CAP, FLAG_ROTATE, FLAG_ANCHORED,
    FRAME_HEADER_BYTES, STATUS_QUEUED, STATUS_ACK, STATUS_NACK,
    STATUS_REJECT, STATUS_RESEND, STATUS_RETRY, encode_frame, decode_frame,
    decode_payload, peek_route, payload_from_body,
    build_payload, encode_payload, encode_response, decode_response,
    check_against_spec, check_frame_against_spec, check_sides_against_spec,
    payload_bytes, q_at_attempt, y_at_attempt, y_buckets_at_attempt)
from repro.agg.transport.chunks import (  # noqa: F401
    encode_chunks, chunk_frames, select)
from repro.agg.transport.session import Reassembler, ReassemblyStats  # noqa: F401
