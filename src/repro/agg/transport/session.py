"""Session layer: out-of-order, duplicate-tolerant chunk reassembly.

One :class:`Reassembler` serves one round.  Within a client, concurrent
chunk streams are keyed by ``(attempt, payload_crc)`` — every frame of one
payload carries the same body seal, so chunks of different payloads can
NEVER be spliced together, and a forged or cross-wired frame for a client
id opens (at worst) its own doomed sub-session instead of capturing the
honest client's: first-writer-wins livelock is structurally impossible.
At most :data:`MAX_SESSIONS_PER_CLIENT` sub-sessions are kept per client
(the honest stream plus one interloper); beyond that the least-complete,
oldest stream is evicted — an evicted honest stream rebuilds through the
drain's RESEND retransmits, which always follow the client's
most-complete open stream.

Each CRC-validated chunk is committed *in place* into its stream's
preallocated body buffer (chunk k always lives at ``k * mtu``), so the
transport keeps NO reorder stash: the only bytes ever staged before
validation are the single frame currently being processed (<= frame header
+ MTU), independent of the vector length d.  The body buffer itself is not
transport overhead — it is byte-for-byte the packed payload the server
must hold for the batched drain anyway (the completed
:class:`~repro.agg.transport.frame.Payload` views the same buffer,
zero-copy), exactly like the v2 single-frame pending store; under
impersonation the cap bounds it at MAX_SESSIONS_PER_CLIENT bodies.

Reassembly state machine, per (client, attempt, payload_crc) stream:

    EMPTY --chunk--> PARTIAL --last chunk + payload_crc ok--> COMPLETE
      PARTIAL --duplicate index-->        PARTIAL   [counted, dropped]
      PARTIAL --higher-attempt stream-->  evicted   [escalation resets]
      PARTIAL --foreign payload_crc-->    (separate stream)  [conflict]
      PARTIAL --group over cap-->         least-complete evicted
      COMPLETE --payload CRC mismatch-->  EMPTY     [retryable: RESEND all]

A completed stream retires the client's whole group (any other partial is
an interloper or a superseded duplicate; the server's pending-payload
dedupe absorbs re-deliveries).  A completed body that fails its end-to-end
``payload_crc`` seal (only reachable when a forged chunk shared an honest
stream's exact header) is dropped and reported retryable — the caller
answers ``STATUS_RESEND`` for every chunk rather than a terminal REJECT,
so a forged frame can never flip an honest client to gave-up.
Missing-chunk NACKs are derived from :meth:`Reassembler.incomplete` at
drain time, so retransmits carry *only* the absent indices.

**Streaming mode** (v5, enabled by passing ``on_range_validated``): instead
of committing chunks into a preallocated body buffer, each stream tracks
its contiguous-from-zero validated prefix (the cumulative-ack high-water
mark).  As the prefix advances, the packed-word region it newly covers is
emitted to the callback in whole uint32 words — ``on_range_validated(h,
word_start, words)`` — and the chunk bytes are FREED; only out-of-order
chunks beyond a gap (bounded by the send window), a sub-word carry
(< 4 bytes), and the tail sides sidecar are retained.  The end-to-end
``payload_crc`` seal is computed incrementally over the prefix, so at
completion it equals the full-body CRC bit for bit.  Because ranges are
folded *speculatively* before the seal verdict, every stream dropped after
emitting anything (seal failure, escalation reset, eviction, conflict,
discard) notifies ``on_stream_discarded(h)`` so the consumer rolls back
its per-stream partial; a stream that completes with the seal intact is
the one case that does NOT notify.  The event vocabulary, duplicate
semantics (first write wins per index), eviction policy and missing-index
arithmetic are identical to the sealed mode — only where bytes live
changes.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Optional

import numpy as np

import repro.obs as _obs
from repro.agg.transport import frame as F

# honest stream + one interloper; beyond that, evict the least complete
MAX_SESSIONS_PER_CLIENT = 2

# add() events
COMPLETE = "complete"      # last chunk landed; payload verified + returned
PROGRESS = "progress"      # chunk committed; more outstanding
DUPLICATE = "duplicate"    # chunk index already committed (idempotent)
STALE = "stale"            # below the client's newest in-flight attempt:
                           # dropped — a stale stream must never exist (it
                           # would capture RESEND targeting / a cap slot)
REJECT = "reject"          # reassembled body failed the payload-CRC seal
                           # (stream dropped; retryable via RESEND-all)


@dataclasses.dataclass
class ReassemblyStats:
    """Transport-layer telemetry of one round's reassembly."""
    chunks: int = 0              # chunk frames fed to add()
    completed: int = 0           # payloads fully reassembled + verified
    duplicates: int = 0
    stale: int = 0               # chunks below the client's newest attempt
    conflicts: int = 0           # foreign streams opened alongside another
    evictions: int = 0           # streams dropped by the per-client cap
    rejects: int = 0             # payload-CRC seal failures at completion
    resets: int = 0              # streams superseded by a higher attempt
    buffer_bytes: int = 0        # bytes currently held by open streams
    peak_buffer_bytes: int = 0   # high-water mark of open-stream bytes


@dataclasses.dataclass
class _Stream:
    header: F.FrameHeader        # first-seen header (chunk_index-normalized)
    buf: bytearray
    have: set
    born: int                    # arrival order, for eviction tie-breaks
    prefix: int = 0              # contiguous-from-zero chunks committed (the
                                 # stream's cumulative-ack high-water mark)
    # streaming-mode state (unused, and empty, in sealed mode)
    crc: int = 0                 # incremental payload CRC over the prefix
    carry: bytearray = dataclasses.field(default_factory=bytearray)
    held: dict = dataclasses.field(default_factory=dict)   # idx -> bytes
    held_bytes: int = 0
    sides: bytearray = dataclasses.field(default_factory=bytearray)
    words_emitted: int = 0
    emitted: bool = False        # any range handed to on_range_validated
    completed: bool = False      # seal verified; suppress rollback notify

    # a chunk belongs to this stream iff it agrees on every header field
    # except its own position — payload_crc keys the body, so two
    # different payloads can never merge
    def matches(self, h: F.FrameHeader) -> bool:
        return dataclasses.replace(h, chunk_index=0) == self.header

    @property
    def progress(self) -> int:
        return len(self.have)

    @property
    def store_bytes(self) -> int:
        """Bytes this stream currently retains (the pending-store share):
        the whole body buffer in sealed mode; just the out-of-order stash,
        sub-word carry and sides sidecar in streaming mode."""
        return (len(self.buf) + self.held_bytes + len(self.carry)
                + len(self.sides))


class Reassembler:
    """Per-round chunk reassembly keyed by client id.

    ``on_range_validated(h, word_start, words)`` switches the round to
    streaming mode (see the module docstring); ``on_stream_discarded(h)``
    is the matching rollback notification for speculatively-folded streams
    that die before their seal verifies.
    """

    def __init__(self, spec: F.RoundSpec,
                 on_range_validated: "Optional[Callable]" = None,
                 on_stream_discarded: "Optional[Callable]" = None):
        self.spec = spec
        self._groups: "dict[int, list[_Stream]]" = {}
        self._born = 0
        self._on_range = on_range_validated
        self._on_discard = on_stream_discarded
        self.streaming = on_range_validated is not None
        self.stats = ReassemblyStats()

    def _drop(self, client_id: int, s: _Stream) -> None:
        self._groups[client_id].remove(s)
        self.stats.buffer_bytes -= s.store_bytes
        if not self._groups[client_id]:
            del self._groups[client_id]
        # rollback notification: this stream's ranges were folded
        # speculatively and its seal will now never verify
        if s.emitted and not s.completed and self._on_discard is not None:
            self._on_discard(s.header)

    def _open(self, h: F.FrameHeader) -> _Stream:
        group = self._groups.setdefault(h.client_id, [])
        # escalation supersedes: a new attempt's stream retires all
        # lower-attempt partials of this client
        for s in [s for s in group if s.header.attempt < h.attempt]:
            self.stats.resets += 1
            self._drop(h.client_id, s)
        group = self._groups.setdefault(h.client_id, [])
        if group:
            self.stats.conflicts += 1
        if len(group) >= MAX_SESSIONS_PER_CLIENT:
            victim = min(group, key=lambda s: (s.progress, s.born))
            self.stats.evictions += 1
            self._drop(h.client_id, victim)
            group = self._groups.setdefault(h.client_id, [])
        self._born += 1
        if self.streaming:
            # no body buffer: the prefix folds away as it validates; only
            # the sides tail (needed whole for the spec check) is staged
            s = _Stream(header=dataclasses.replace(h, chunk_index=0),
                        buf=bytearray(0), have=set(), born=self._born,
                        sides=bytearray(4 * h.nb))
        else:
            s = _Stream(header=dataclasses.replace(h, chunk_index=0),
                        buf=bytearray(h.body_len), have=set(),
                        born=self._born)
        group.append(s)
        self.stats.buffer_bytes += s.store_bytes
        self._note_peak(h.round_id)
        if _obs.tracing_enabled():
            _obs.tracer().begin(
                "reassembly", key=("reassembly", h.round_id, h.client_id),
                parent=("client", h.round_id, h.client_id),
                round=h.round_id, client=h.client_id, attempt=h.attempt,
                n_chunks=h.n_chunks)
        return s

    def _note_peak(self, round_id: int) -> None:
        self.stats.peak_buffer_bytes = max(self.stats.peak_buffer_bytes,
                                           self.stats.buffer_bytes)
        if _obs.metrics_enabled():
            _obs.gauge("peak_staging_bytes", round=round_id).set_max(
                self.stats.buffer_bytes)

    def add(self, h: F.FrameHeader, chunk: bytes
            ) -> "tuple[str, Optional[F.Payload]]":
        """Commit one validated chunk; returns (event, payload-or-None).

        The caller has already run :func:`frame.decode_frame` (per-frame
        CRC) and :func:`frame.check_frame_against_spec` (round membership +
        MTU geometry), so everything arriving here is a well-formed chunk of
        *some* payload of this round.
        """
        self.stats.chunks += 1
        group = self._groups.get(h.client_id, [])
        if any(s.header.attempt > h.attempt for s in group):
            # drop, don't open: a lower-attempt stream alongside the
            # escalated one could out-progress it, capture the client's
            # single RESEND slot (incomplete() is per client) and deadlock
            # the escalation — and it would burn a cap slot
            self.stats.stale += 1
            return STALE, None
        s = next((s for s in group if s.matches(h)), None)
        if s is None:
            s = self._open(h)
        if h.chunk_index in s.have:
            self.stats.duplicates += 1
            return DUPLICATE, None
        if self.streaming:
            return self._add_streaming(h, s, chunk)
        # only multi-chunk frames reach the session (single frames bypass
        # it in the server), and those exist only under a positive MTU
        off = h.chunk_index * self.spec.mtu
        s.buf[off:off + len(chunk)] = chunk
        s.have.add(h.chunk_index)
        while s.prefix in s.have:        # cumulative-ack high-water mark
            s.prefix += 1
        if len(s.have) < h.n_chunks:
            return PROGRESS, None
        # complete: seal the body end to end before it can reach the drain
        # (crc32 hashes the bytearray in place — no body-sized copy)
        if zlib.crc32(s.buf) != h.payload_crc:
            return self._seal_reject(h, s)
        self.stats.completed += 1
        if _obs.tracing_enabled():
            _obs.tracer().end(("reassembly", h.round_id, h.client_id))
        self.discard(h.client_id)        # retire the whole group
        return COMPLETE, F.payload_from_body(s.header, s.buf)

    def _seal_reject(self, h: F.FrameHeader, s: _Stream):
        self.stats.rejects += 1
        self._drop(h.client_id, s)       # retryable: caller RESENDs all
        if _obs.metrics_enabled():
            _obs.counter("payload_crc_seal_failures",
                         round=h.round_id).inc()
        if _obs.tracing_enabled():
            _obs.tracer().end(
                ("reassembly", h.round_id, h.client_id), rejected=True)
        _obs.trigger("payload_crc_seal_failure",
                     at=_obs.tracer().now(),
                     round=h.round_id, client=h.client_id)
        return REJECT, None

    def _add_streaming(self, h: F.FrameHeader, s: _Stream, chunk: bytes):
        """Streaming-mode commit: advance the validated prefix (emitting +
        freeing the word ranges it covers) or stash an out-of-order chunk
        until its gap fills."""
        idx = h.chunk_index
        s.have.add(idx)
        if idx == s.prefix:
            self._advance(s, chunk)
            while s.prefix in s.held:
                nxt = s.held.pop(s.prefix)
                s.held_bytes -= len(nxt)
                self.stats.buffer_bytes -= len(nxt)
                self._advance(s, nxt)
        else:
            s.held[idx] = bytes(chunk)
            s.held_bytes += len(chunk)
            self.stats.buffer_bytes += len(chunk)
            self._note_peak(h.round_id)
        if s.prefix < h.n_chunks:
            return PROGRESS, None
        # complete: the incremental CRC over the in-order prefix IS the
        # end-to-end body seal (the prefix is the whole body here)
        if s.crc != h.payload_crc:
            return self._seal_reject(h, s)
        s.completed = True               # suppress the rollback notify
        self.stats.completed += 1
        if _obs.tracing_enabled():
            _obs.tracer().end(("reassembly", h.round_id, h.client_id))
        p = F.streamed_payload(s.header, bytes(s.sides))
        self.discard(h.client_id)        # retire the whole group
        return COMPLETE, p

    def _advance(self, s: _Stream, chunk: bytes) -> None:
        """Fold one frontier chunk into the prefix: emit the whole words it
        completes, stage any sides-tail portion, free the rest."""
        h = s.header
        off = s.prefix * self.spec.mtu
        s.crc = zlib.crc32(chunk, s.crc)
        wb = 4 * h.n_words
        mv = memoryview(chunk)
        carry0 = len(s.carry)
        w_end = max(0, min(len(chunk), wb - off))
        if w_end:
            s.carry += mv[:w_end]
            n_emit = len(s.carry) // 4
            if n_emit:
                words = np.frombuffer(bytes(s.carry[:4 * n_emit]),
                                      dtype="<u4")
                s.emitted = True
                self._on_range(h, s.words_emitted, words)
                s.words_emitted += n_emit
                del s.carry[:4 * n_emit]
        if w_end < len(chunk):
            so = off + w_end - wb
            s.sides[so:so + len(chunk) - w_end] = mv[w_end:]
        self.stats.buffer_bytes += len(s.carry) - carry0
        s.prefix += 1

    def missing(self, client_id: int) -> "tuple[int, ...]":
        """Outstanding chunk indices across ALL of a client's open streams
        (they share one attempt — stale ones are dropped, higher ones
        evict).  The union matters: following only the most-complete
        stream would let a forged stream that out-progresses the honest
        one capture the client's single RESEND slot and livelock it; with
        the union, the honest stream's gaps are always named too, its
        retransmits merge into it, and it completes regardless of what an
        interloper does."""
        group = self._groups.get(client_id)
        if not group:
            return ()
        have_all = set.intersection(*(s.have for s in group))
        return tuple(i for i in range(group[0].header.n_chunks)
                     if i not in have_all)

    def high_water(self, client_id: int) -> int:
        """Cumulative-ack value for a client: the largest contiguous-from-
        zero chunk count across its open streams (0 when none are open;
        the server acks a completed client at the full chunk count)."""
        group = self._groups.get(client_id)
        if not group:
            return 0
        return max(s.prefix for s in group)

    def incomplete(self) -> "dict[int, tuple]":
        """client_id -> (attempt, missing indices) of every open client."""
        return {cid: (g[0].header.attempt, self.missing(cid))
                for cid, g in sorted(self._groups.items())}

    def open_clients(self) -> frozenset:
        """Client ids with at least one open (incomplete) stream — the
        reassembly half of the server's bounded pending store."""
        return frozenset(self._groups)

    def discard(self, client_id: int) -> None:
        """Drop a client's open streams (accepted / gave-up clients)."""
        group = list(self._groups.get(client_id, []))
        if group and _obs.tracing_enabled():
            # idempotent: already-completed streams ended their span above
            _obs.tracer().end(("reassembly", self.spec.round_id, client_id),
                              discarded=True)
        for s in group:
            self._drop(client_id, s)

    @property
    def open_sessions(self) -> int:
        return sum(len(g) for g in self._groups.values())
