"""Server side: streaming integer-space accumulator + batched drain.

Arrival path (:meth:`AggServer.receive`): parse/validate one transport
frame (framing errors and spec mismatches are counted and REJECTed —
including truncated, corrupt, version-mismatched, anchor-digest-mismatched
and MTU-geometry-violating frames), dedupe by client id, and route it by
its chunk coordinates: a single-frame payload is buffered directly, a chunk
of a larger payload goes through the transport session layer
(:class:`repro.agg.transport.session.Reassembler`) — out-of-order and
duplicate tolerant, committing each validated chunk in place so the
transport never stages more than one frame (header + MTU) of unvalidated
bytes, independent of d.  Either way the server buffers the *packed words*
— the 8x-compressed form — until a drain; a completed reassembly hands the
drain the same zero-copy Payload view a single frame would have.

**Streaming drain** (v5, on when ``RoundSpec.window > 0``): the
seal-then-stage path above is replaced for multi-chunk payloads.  The
session emits each stream's validated contiguous word prefix range by range
(``on_range_validated``) and frees the chunk bytes; the server
residual-folds every range on arrival (:func:`repro.kernels.ops.
lattice_residuals_range` about the round's decode-reference coordinates
``k0`` — the same integer identity the tree tiers use, so ``k0 + r`` is
bit-for-bit what the batched decode would have produced) into a
*speculative* per-stream record keyed by ``(client, attempt, payload_crc)``:
int16 residuals, an incrementally-accumulated §5 checksum (h(k) is linear,
so partial sums of ``w_i * k_i`` compose exactly), and per-bucket distance
telemetry.  Nothing touches the round accumulator until the stream
completes AND its payload-CRC seal + checksum verify — so "rollback" on a
seal failure, escalation reset, eviction or expiry is simply dropping the
record (``on_stream_discarded``), and the published mean stays
bit-identical to the sealed drain under any arrival order, loss,
duplication or escalation.  ``RoundStats.peak_pending_store_bytes`` gauges
what the old path buffered: staged bodies + reassembly bytes — with the
window holding senders near-in-order it stays far below one body per
pending client.  Single-chunk payloads keep the batched path (they never
had a body-sized backlog).

Chunked rounds add one response status: a drain that finds a client's
reassembly still incomplete emits ``STATUS_RESEND`` naming exactly the
missing chunk indices, so a lost or corrupt chunk costs one chunk frame on
the retransmit wire — never the payload (asserted byte-for-byte in
``repro.agg.sim.run_chunked_lossy``).

Drain path (:meth:`AggServer.drain`): all pending payloads of one color
space q are decoded against the server's decode reference in ONE batched
Pallas launch (repro.kernels.ops.lattice_decode_batched), their §5
coordinate checksums verified vectorized, and the accepted senders' integer
lattice coordinates summed into the round accumulator.  Integer addition is
exact and commutative, so the accumulated sum — and therefore the round
mean — is bit-identical under any arrival order, any receive/drain
interleaving, and any drain batching.

Anchored rounds (RoundSpec v2, ``anchor_digest != 0``): clients encoded
``x - anchor``, so the server operates entirely in anchor-relative space —
its decode reference is the zero vector (the server's anchor *is* the round
anchor, digest-checked at construction) and the anchor is added back once
at finalize.  Coordinates and the accumulator stay ~y/s-sized however large
the drifting mean grows; with a zero anchor (digest 0) the path is
bit-identical to the historical server.

Per-bucket telemetry: every drain updates ``RoundStats.dist_b`` (max
|decoded - ref|_inf per bucket over accepted senders) and
``RoundStats.fails_b`` (decode failures attributed per bucket via the
distance surrogate on checksum-failed senders) — the inputs the multi-round
service feeds to :func:`repro.core.qstate.update_y` to produce round k+1's
per-bucket ``y``.

Decode failures (checksum mismatch: the §5 detection event) are NACKed with
the next escalation level — RobustAgreement's r <- r^2 with the per-bucket
lattice granularity pinned, so a retried client's coordinates land on the
same lattice and stay summable; the NACK carries the per-bucket margins at
the directed level (v2).  When the color space is already at the 2^16
packing cap (or max_attempts is reached) the client is REJECTed and
excluded from the round.

Continuous-round intake (ISSUE 6): the server is no longer a lockstep batch
— :meth:`AggServer.seal` closes the round to NEW clients at cutover while
already-admitted clients keep full service (outstanding chunks, selective
retransmits, escalation retries — the overlapping drain), and ``max_pending``
bounds the pending store (staged payloads + open reassembly streams).  A
frame past the seal or the cap draws a non-terminal ``STATUS_RETRY`` naming
the round currently open for admission — never a verdict, so admission
timing can never flip an honest client to gave-up.
:meth:`AggServer.expire_client` lets the engine's straggler deadline drop an
unresolved client's state without a verdict, and :attr:`AggServer.unresolved`
is the drain condition the engine's round life-cycle machine watches.

Finalize: mean = ((ksum / count) + u) * s_b (+ anchor), unbucketized — the
same integer-space averaging expression as ``allgather_allreduce_mean``,
against which the acceptance test pins bit-identity.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as _obs
from repro.agg import rounds
from repro.agg.api import PublishedRound
from repro.agg.transport import frame as wire
from repro.agg.transport import session as S
from repro.core import error_detect as ED
from repro.core import lattice as L
from repro.kernels import ops as K
from repro.kernels.lattice_decode import DEFAULT_BLOCK_SENDERS

Array = jax.Array


@dataclasses.dataclass
class RoundStats:
    """Per-round service telemetry."""
    received: int = 0
    queued: int = 0
    accepted: int = 0
    duplicates: int = 0
    rejected_wire: int = 0       # framing: truncated / corrupt / bad version
    rejected_spec: int = 0       # well-formed but wrong round/config/anchor
    decode_failures: int = 0     # §5 checksum detections across all drains
    nacks_sent: int = 0
    resends_sent: int = 0        # chunk-level RESEND responses (v3)
    retried: int = 0             # non-terminal RETRY responses (sealed round
                                 # / pending store full — admission control)
    expired: int = 0             # admitted clients dropped by the engine's
                                 # straggler deadline (state discarded; no
                                 # terminal verdict was sent)
    gave_up: int = 0             # clients dropped after escalation exhausted
    drains: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    peak_unvalidated_bytes: int = 0   # largest frame staged before its CRC
    peak_pending_store_bytes: int = 0  # high-water of staged payload bodies
                                       # + reassembly-retained bytes (the
                                       # streaming drain shrinks this far
                                       # below one body per pending client)
    max_dist: float = 0.0        # max |decoded - ref|_inf over accepts
    dist_b: Optional[np.ndarray] = None    # (nb,) per-bucket max distance
    fails_b: Optional[np.ndarray] = None   # (nb,) per-bucket failure counts


def _reject(spec: wire.RoundSpec, client_id: int,
            round_id: "int | None" = None) -> wire.Response:
    """``round_id`` defaults to the server's round; spec-mismatch rejects
    echo the offending frame's round instead, so a REJECT provoked by a
    delayed previous-round frame is ignored by the same client's
    current-round protocol object (round_id filter) rather than read as a
    terminal verdict on the live round."""
    return wire.Response(status=wire.STATUS_REJECT,
                         round_id=spec.round_id if round_id is None
                         else round_id,
                         client_id=client_id, attempt_next=0, q_next=0,
                         y_next=0.0)


def _retry(round_id: int, client_id: int, attempt: int,
           open_round_id: int) -> wire.Response:
    """The non-terminal admission verdict: round sealed to new clients or
    pending store full.  Echoes the frame's round (so the sender's protocol
    object sees it) and names the round currently open for admission in
    ``q_next`` (0 = unknown) — the client re-sends after backoff or
    re-enrolls there.  NEVER terminal: ``gave_up`` cannot be provoked by
    timing, only by the client's own escalation exhausting (PR 5's
    invariant, extended to admission)."""
    return wire.Response(status=wire.STATUS_RETRY, round_id=round_id,
                         client_id=client_id, attempt_next=attempt,
                         q_next=open_round_id, y_next=0.0)


class _StreamFold:
    """Speculative per-stream fold for the streaming drain.

    One per open ``(client, attempt, payload_crc)`` stream identity: the
    int16 residuals folded so far (|r| <= q/2 <= 2^15 at the q=2^16 packing
    cap, so int16 always fits), the incrementally-accumulated §5 coordinate
    checksum (h(k) is linear in k, so per-range partial sums of ``w_i *
    k_i`` compose exactly mod 2^32), and per-bucket distance telemetry.
    Nothing here has touched the round accumulator — dropping the record IS
    the rollback."""
    __slots__ = ("r", "check", "dist_b", "coords")

    def __init__(self, padded: int, nb: int):
        self.r = np.zeros((padded,), np.int16)
        self.check = 0          # the uint32 value, carried as a python int
        self.dist_b = np.zeros((nb,), np.float32)
        self.coords = 0


@partial(jax.jit, static_argnames=("q", "bucket"))
def _drain_math(words: Array, sides: Array, checks: Array, valid: Array,
                anchor: Array, u: Array, weights: Array, y_col: Array,
                m: Array, k0: Array, *, q: int, bucket: int):
    """Decode S payloads, verify checksums, sum accepted integer coords.

    words: (S, nw) uint32; sides: (S, nb) f32 sidecars; checks: (S,) uint32;
    valid: (S,) bool (False for the block-size padding rows the server adds
    so drain sizes hit a bounded set of compiled shapes); anchor/u/weights:
    (n,); y_col: (nb,) decode margins at this q; m: (S,) int32 n_summed of
    each payload (1 for an ordinary client); k0: (n,) int32 the round's
    decode-reference coordinates (:func:`repro.agg.rounds.decode_ref_coords`).

    A combined payload from a tree tier (m > 1) carries ``K' = k0 + sum_i
    r_i`` — the tier folded m clients' residuals about k0 — so the true
    integer sum it contributes is ``K' + (m-1) * k0`` (each of the m clients
    would have contributed its own ``k0 + r_i``).  For m == 1 the correction
    is identically zero and the math is bit-for-bit the flat server's.

    Returns (ok (S,), ksum_delta (n,) int32, count_delta () int32,
    max_dist () f32, dist_b (nb,), fails_b (nb,), max_abs_k () int32).
    The distance telemetry (max_dist/dist_b/fails_b) is masked to unit
    payloads (m == 1): a combined payload's distance-to-reference scales
    like m*y and would poison the y-tracking margins.
    """
    s_sender = jnp.repeat(sides, bucket, axis=-1)          # (S, n)
    k = K.lattice_decode_batched(words, anchor, u, s_sender, q=q,
                                 mode="coords")            # (S, n) int32
    # pin the integer coords (like the collectives): everything below is
    # exact integer math or order-free, keeping the drain bit-deterministic
    k = jax.lax.optimization_barrier(k)
    ok = (ED.coord_checksum(k, weights, axis=-1) == checks) & valid
    k_eff = k + (m[:, None] - 1) * k0[None]                # (S, n) int32
    ksum_delta = jnp.sum(jnp.where(ok[:, None], k_eff, 0), axis=0,
                         dtype=jnp.int32)
    count_delta = jnp.sum(jnp.where(ok, m, 0), dtype=jnp.int32)
    # the largest accepted |effective coordinate|: the server bounds the
    # int32 accumulator with it (count * max|k| < 2^31) and fails loudly
    # instead of silently wrapping — only reachable with huge-norm
    # *unanchored* rounds, where raw coords scale like |x|/s; anchored
    # coords stay ~y/s
    max_abs_k = jnp.max(jnp.where(ok[:, None], jnp.abs(k_eff), 0))
    unit = ok & (m == 1)
    z = (k.astype(jnp.float32) + u[None]) * s_sender
    dist = jnp.abs(z - anchor[None]).reshape(z.shape[0], -1, bucket)
    dist_bk = jnp.max(dist, axis=-1)                       # (S, nb)
    max_dist = jnp.max(jnp.where(unit[:, None], dist_bk, 0.0))
    dist_b = jnp.max(jnp.where(unit[:, None], dist_bk, 0.0), axis=0)
    # failure attribution: for checksum-failed unit senders, buckets whose
    # decoded distance exceeds the margin carry the blame (the §5 distance
    # surrogate, per bucket)
    failed = valid & ~ok & (m == 1)
    over = dist_bk > 1.5 * y_col[None]
    fails_b = jnp.sum(jnp.where(failed[:, None] & over, 1.0, 0.0), axis=0)
    return (ok, ksum_delta, count_delta, max_dist, dist_b, fails_b,
            max_abs_k)


@jax.jit
def _mean_math(ksum: Array, count: Array, u: Array, s_col: Array) -> Array:
    """(nb, bucket) integer sum -> round mean in bucket space.

    Identical float structure to allgather_allreduce_mean's epilogue:
    pinned integer sum, one divide (a *runtime* count always compiles to a
    true IEEE division), add dither, scale by the pinned sides.
    """
    ksum = jax.lax.optimization_barrier(ksum)
    return (ksum.astype(jnp.float32) / count.astype(jnp.float32) + u) * s_col


class AggServer:
    """One aggregation round's coordinator.

    ``anchor`` doubles as the decode reference and — in anchored rounds —
    the round anchor itself (validated against ``spec.anchor_digest``).
    """

    def __init__(self, spec: wire.RoundSpec, anchor,
                 max_pending: "int | None" = None,
                 streaming: "bool | None" = None):
        """``max_pending``: admission cap — the largest number of distinct
        un-drained clients allowed to hold buffered server state (pending
        payloads + open reassembly streams) at once.  A frame from a NEW
        client beyond the cap draws a non-terminal ``STATUS_RETRY``
        (backpressure), never a verdict; ``None`` = unbounded (the
        historical lockstep behavior).

        ``streaming``: enable the streaming drain for multi-chunk payloads
        (fold validated chunk ranges on arrival, commit at stream
        completion).  ``None`` (the default) resolves to ``spec.window >
        0`` — a windowed round streams, anything else keeps the historical
        seal-then-stage path bit-for-bit."""
        if np.shape(anchor) != (spec.d,):
            raise ValueError(
                f"anchor has shape {np.shape(anchor)}, spec.d={spec.d}")
        rounds.check_anchor(spec, anchor if spec.anchored else None)
        self.spec = spec
        self.max_pending = max_pending
        self._sealed = False
        self._next_round_id = 0     # admission hint for RETRY after seal
        self._admitted: set[int] = set()
        self._anchor_b = rounds.bucketize(jnp.asarray(anchor), spec)
        if spec.anchored:
            # clients encoded x - anchor: decode in anchor-relative space
            # (reference 0), add the anchor back at finalize
            self._ref_flat = jnp.zeros((spec.padded,), jnp.float32)
        else:
            self._ref_flat = self._anchor_b.reshape(-1)
        self._u = rounds.dither(spec)                     # (nb, bucket)
        self._weights = rounds.checksum_weights(spec)     # (padded,)
        self._sides = rounds.sides(spec)                  # (nb,)
        # the decode's reference coordinates (padded,) int32 — the lift
        # point tree tiers sum residuals about; (m-1)*k0 corrects their
        # combined payloads back to a per-client sum in _drain_math
        self._k0 = rounds.decode_ref_coords(
            spec, None if spec.anchored else anchor)
        self._anchor_raw = np.asarray(anchor, np.float32).copy()
        self._published: list[PublishedRound] = []
        self._pending: dict[int, wire.Payload] = {}
        self._pending_bytes = 0   # bodies staged for the batched drain
        self._folds: "dict[tuple, _StreamFold]" = {}
        self._ksum_st: "Optional[np.ndarray]" = None  # (padded,) int64 —
        #   the streamed commits, merged with _ksum at finalize
        self._streaming = ((spec.window > 0) if streaming is None
                           else bool(streaming)) and spec.mtu > 0
        if self._streaming:
            # host-side mirrors of the decode context for per-range folds
            self._k0_np = np.asarray(self._k0, np.int64)
            self._w_np = np.asarray(self._weights)
            self._u_np = np.asarray(self._u, np.float32).reshape(-1)
            self._s_np = np.repeat(np.asarray(self._sides, np.float32),
                                   spec.cfg.bucket)
            self._ref_np = np.asarray(self._ref_flat, np.float32)
            self._rx = S.Reassembler(spec,
                                     on_range_validated=self._fold_range,
                                     on_stream_discarded=self._drop_stream)
        else:
            self._rx = S.Reassembler(spec)  # chunked-payload session layer
        self._accepted: set[int] = set()
        self._gave_up: set[int] = set()
        # per-client minimum live attempt (bumped by every NACK): a late
        # duplicate chunk of a NACKed attempt must not re-open a dead
        # reassembly stream it would then carry to the round's end
        self._attempt_floor: dict[int, int] = {}
        self._ksum = jnp.zeros((spec.nb, spec.cfg.bucket), jnp.int32)
        self._count = 0
        self._max_abs_k = 0
        # per-attempt per-bucket margin tuples for QUEUED/NACK responses
        # (attempts are bounded by max_attempts; don't rebuild per message)
        self._margins: dict[int, tuple] = {}
        # the round's accounting lives in an obs scope (registry counters
        # when metrics are enabled, a detached registry otherwise); the
        # RoundStats dataclass every caller reads is filled from it on
        # access.  Only the numpy per-bucket telemetry stays direct.
        self._obs = _obs.scope("agg_round", round=spec.round_id)
        self._stats = RoundStats(dist_b=np.zeros((spec.nb,), np.float32),
                                 fails_b=np.zeros((spec.nb,), np.float32))
        self._publish_traced = False

    @property
    def stats(self) -> RoundStats:
        """Per-round telemetry, materialized from the obs scope."""
        self._obs.fill(self._stats)
        return self._stats

    def _margin_tuple(self, attempt: int) -> tuple:
        t = self._margins.get(attempt)
        if t is None:
            t = tuple(float(v) for v in
                      wire.y_buckets_at_attempt(self.spec, attempt))
            self._margins[attempt] = t
        return t

    # ------------------------------------------------------------------ RX
    def receive(self, data: bytes) -> bytes:
        """Handle one arriving frame; returns the response bytes."""
        self._obs.inc("received")
        self._obs.inc("bytes_in", len(data))
        # the only bytes ever held before a CRC has vouched for them: this
        # one frame (<= header + MTU in a chunked round, whatever the d)
        self._obs.set_max("peak_unvalidated_bytes", len(data))
        try:
            h, chunk = wire.decode_frame(data)
        except wire.WireError:
            self._obs.inc("rejected_wire")
            return self._respond(_reject(self.spec, 0xFFFFFFFF))
        try:
            wire.check_frame_against_spec(h, self.spec, len(chunk))
        except wire.HeaderMismatchError:
            self._obs.inc("rejected_spec")
            return self._respond(_reject(self.spec, h.client_id,
                                         round_id=h.round_id))
        if _obs.tracing_enabled():
            _obs.tracer().event("chunk",
                                parent=("client", h.round_id, h.client_id),
                                round=h.round_id, client=h.client_id,
                                chunk=h.chunk_index, n_chunks=h.n_chunks)
        if h.client_id in self._gave_up:
            return self._respond(_reject(self.spec, h.client_id))
        if h.client_id in self._accepted:
            # duplicate delivery of an already-accumulated client: ACK
            # idempotently, never double-count
            self._obs.inc("duplicates")
            return self._respond(self._ack(
                h.client_id, ack=h.n_chunks if self.spec.window else 0))
        if h.client_id not in self._admitted:
            # intake gate — BEFORE any buffered state is created for the
            # client, so a sealed or saturated round never opens a
            # reassembly stream it would have to carry
            if self._sealed:
                self._obs.inc("retried")
                return self._respond(_retry(h.round_id, h.client_id,
                                            h.attempt, self._next_round_id))
            if (self.max_pending is not None
                    and self.occupancy >= self.max_pending):
                self._obs.inc("retried")
                return self._respond(_retry(h.round_id, h.client_id,
                                            h.attempt, self.spec.round_id))
            self._admitted.add(h.client_id)
        if h.n_chunks == 1:
            p = wire.payload_from_body(h, chunk)
        else:
            if h.attempt < self._attempt_floor.get(h.client_id, 0):
                # stale chunk of an attempt this server already NACKed
                self._obs.inc("duplicates")
                return self._respond(self._queued(h, slim=True))
            event, p = self._rx.add(h, chunk)
            if event == S.REJECT:
                # the reassembled body failed its payload-CRC seal (a
                # forged chunk shared the stream's header): the stream is
                # dropped but the verdict is NOT terminal — direct a full
                # rebuild; a REJECT would flip the honest client to gave_up
                self._obs.inc("resends_sent")
                return self._respond(wire.Response(
                    status=wire.STATUS_RESEND,
                    round_id=self.spec.round_id, client_id=h.client_id,
                    attempt_next=h.attempt, q_next=h.q,
                    y_next=wire.y_at_attempt(self.spec, h.attempt),
                    missing=tuple(range(h.n_chunks)),
                    credit=self.spec.window))
            if p is None:                   # PROGRESS / DUPLICATE / STALE
                if event in (S.DUPLICATE, S.STALE):
                    self._obs.inc("duplicates")
                self._note_pending_store()
                # slim ack: mid-reassembly nobody consumes the per-bucket
                # margins or a missing list, so don't pay O(nb + n_chunks)
                # response bytes per chunk
                return self._respond(self._queued(h, slim=True))
            if p.streamed:
                # stream complete + payload-CRC sealed: verify and commit
                # the speculative fold NOW — no staged body, nothing for
                # the drain to carry
                out = self._respond(self._finish_streamed(h, p))
                self._note_pending_store()
                return out
        try:
            # body-level spec check only — every header field was already
            # validated per frame by check_frame_against_spec
            wire.check_sides_against_spec(p, self.spec)
        except wire.HeaderMismatchError:
            self._obs.inc("rejected_spec")
            return self._respond(_reject(self.spec, p.client_id))
        prev = self._pending.get(p.client_id)
        if prev is not None and prev.attempt >= p.attempt:
            self._obs.inc("duplicates")
        else:
            if prev is not None:
                self._pending_bytes -= prev.words.nbytes + prev.sides.nbytes
            self._pending[p.client_id] = p
            self._pending_bytes += p.words.nbytes + p.sides.nbytes
            self._note_pending_store()
            self._obs.inc("queued")
            if _obs.tracing_enabled():
                # the payload's end-to-end CRC has vouched for the body and
                # it is staged for the drain: the client's seal point
                _obs.tracer().event(
                    "seal", parent=("client", h.round_id, p.client_id),
                    round=h.round_id, client=p.client_id, attempt=p.attempt)
        return self._respond(self._queued(h))

    def _queued(self, h: wire.FrameHeader,
                slim: bool = False) -> wire.Response:
        # no `missing` list here: only STATUS_RESEND consumes it, and
        # including it per chunk ack would cost O(n_chunks^2) per client
        # windowed rounds piggyback flow control on every response: the
        # cumulative contiguous-chunk ack + the static credit grant, so
        # RESEND recovery and window advance share one response path
        return wire.Response(
            status=wire.STATUS_QUEUED, round_id=self.spec.round_id,
            client_id=h.client_id, attempt_next=h.attempt, q_next=h.q,
            y_next=wire.y_at_attempt(self.spec, h.attempt),
            y_buckets=() if slim else self._margin_tuple(h.attempt),
            ack=self._rx.high_water(h.client_id) if self.spec.window else 0,
            credit=self.spec.window)

    def _ack(self, client_id: int, ack: int = 0) -> wire.Response:
        return wire.Response(status=wire.STATUS_ACK,
                             round_id=self.spec.round_id,
                             client_id=client_id, attempt_next=0, q_next=0,
                             y_next=0.0, ack=ack, credit=self.spec.window)

    def _respond(self, r: wire.Response) -> bytes:
        out = wire.encode_response(r)
        self._obs.inc("bytes_out", len(out))
        return out

    # -------------------------------------------------------- STREAMING RX
    def _note_pending_store(self) -> None:
        """The pending-store byte gauge: staged drain bodies + everything
        the reassembly layer is holding (carry, held out-of-order chunks,
        sides, sealed-mode buffers).  The streaming drain's whole point is
        keeping this far below one body per pending client."""
        self._obs.set_max("peak_pending_store_bytes",
                          self._pending_bytes + self._rx.stats.buffer_bytes)

    def _fold_range(self, h: wire.FrameHeader, word_start: int,
                    words: np.ndarray) -> None:
        """``on_range_validated``: residual-fold one contiguous validated
        word range into the stream's speculative record; the session frees
        the chunk bytes as soon as this returns."""
        key = (h.client_id, h.attempt, h.payload_crc)
        rec = self._folds.get(key)
        if rec is None:
            rec = self._folds[key] = _StreamFold(self.spec.padded,
                                                 self.spec.nb)
        c0 = word_start * (32 // L.bits_for_q(h.q))
        r = np.asarray(K.lattice_residuals_range(
            jnp.asarray(words), self._k0, q=h.q, word_start=word_start))
        n = r.shape[0]
        rec.r[c0:c0 + n] = r.astype(np.int16)
        rec.coords += n
        k = r.astype(np.int64) + self._k0_np[c0:c0 + n]
        part = np.sum(k.astype(np.uint32) * self._w_np[c0:c0 + n],
                      dtype=np.uint32)
        rec.check = (rec.check + int(part)) & 0xFFFFFFFF
        if h.n_summed == 1:
            # distance telemetry, masked to unit payloads like _drain_math
            z = (k.astype(np.float32) + self._u_np[c0:c0 + n]) \
                * self._s_np[c0:c0 + n]
            dist = np.abs(z - self._ref_np[c0:c0 + n])
            b = self.spec.cfg.bucket
            bidx = np.arange(c0 // b, (c0 + n - 1) // b + 1)
            mx = np.maximum.reduceat(dist, np.maximum(bidx * b - c0, 0))
            rec.dist_b[bidx] = np.maximum(rec.dist_b[bidx], mx)

    def _drop_stream(self, h: wire.FrameHeader) -> None:
        """``on_stream_discarded``: the rollback.  The record never touched
        the round accumulator, so dropping it IS the undo (seal failure,
        escalation reset, eviction, expiry)."""
        self._folds.pop((h.client_id, h.attempt, h.payload_crc), None)

    def _finish_streamed(self, h: wire.FrameHeader,
                         p: wire.Payload) -> wire.Response:
        """A stream completed and its payload-CRC seal held: verify the
        fold's §5 checksum and commit — the streaming path's per-client
        drain, minus the body that no longer exists."""
        rec = self._folds.pop((h.client_id, h.attempt, h.payload_crc), None)
        try:
            wire.check_sides_against_spec(p, self.spec)
        except wire.HeaderMismatchError:
            self._obs.inc("rejected_spec")
            return _reject(self.spec, p.client_id)
        if rec is None or rec.coords != self.spec.padded:
            # a fold record that never materialized (stream evicted and
            # rebuilt mid-flight): direct a full rebuild, non-terminal
            self._obs.inc("resends_sent")
            return wire.Response(
                status=wire.STATUS_RESEND, round_id=self.spec.round_id,
                client_id=h.client_id, attempt_next=h.attempt, q_next=h.q,
                y_next=wire.y_at_attempt(self.spec, h.attempt),
                missing=tuple(range(h.n_chunks)), credit=self.spec.window)
        if _obs.tracing_enabled():
            # the completed stream's checksum-verified fold is the
            # streaming path's seal point
            _obs.tracer().event(
                "seal", parent=("client", h.round_id, h.client_id),
                round=h.round_id, client=h.client_id, attempt=h.attempt)
        if rec.check != (h.check & 0xFFFFFFFF):
            return self._nack_streamed(h, rec)
        m = h.n_summed
        k_eff = rec.r.astype(np.int64) + m * self._k0_np
        self._max_abs_k = max(self._max_abs_k, int(np.abs(k_eff).max()))
        if (self._count + m) * self._max_abs_k >= 2 ** 31:
            raise OverflowError(
                f"round {self.spec.round_id}: accumulating a streamed "
                f"sender with |coords| up to {self._max_abs_k} can "
                f"overflow the int32 sum ({self._count} accepted so far); "
                f"anchor the round (RoundSpec.anchor_digest) so "
                f"coordinates stay ~y/s instead of ~|x|/s")
        if self._ksum_st is None:
            self._ksum_st = np.zeros((self.spec.padded,), np.int64)
        self._ksum_st += k_eff
        self._count += m
        self._obs.inc("queued")
        self._obs.inc("accepted")
        if m == 1:
            self._obs.set_max("max_dist", float(rec.dist_b.max()))
            self._stats.dist_b = np.maximum(self._stats.dist_b, rec.dist_b)
        self._accepted.add(h.client_id)
        return self._ack(h.client_id, ack=h.n_chunks)

    def _nack_streamed(self, h: wire.FrameHeader,
                       rec: _StreamFold) -> wire.Response:
        """§5 checksum mismatch on a completed stream: the same escalation
        verdict the batched drain would have produced."""
        self._obs.inc("decode_failures")
        if h.n_summed == 1:
            y_col = np.asarray(wire.y_buckets_at_attempt(self.spec,
                                                         h.attempt))
            self._stats.fails_b = self._stats.fails_b + \
                (rec.dist_b > 1.5 * y_col).astype(np.float32)
        nxt = h.attempt + 1
        if h.q >= wire.Q_CAP or nxt >= self.spec.max_attempts:
            self._gave_up.add(h.client_id)
            self._obs.inc("gave_up")
            return _reject(self.spec, h.client_id)
        self._obs.inc("nacks_sent")
        self._attempt_floor[h.client_id] = nxt
        return wire.Response(
            status=wire.STATUS_NACK, round_id=self.spec.round_id,
            client_id=h.client_id, attempt_next=nxt,
            q_next=wire.q_at_attempt(self.spec.cfg.q, nxt),
            y_next=wire.y_at_attempt(self.spec, nxt),
            y_buckets=self._margin_tuple(nxt), credit=self.spec.window)

    # ------------------------------------------------------------ AggNode
    def ingest_frame(self, data: bytes, now: float = 0.0) -> "list[bytes]":
        """AggNode verb: one frame in, its response out (``now`` unused —
        the flat server is purely event-driven)."""
        return [self.receive(data)]

    def tick(self, now: float = 0.0) -> "list[bytes]":
        """AggNode verb: drain pending payloads + chunk-level RESENDs."""
        return self.drain()

    def published(self) -> "list[PublishedRound]":
        """AggNode verb: the round's outcome, once it has one.

        Empty until the round is sealed and every admitted client is
        resolved; then the round finalizes lazily on first call and the
        :class:`~repro.agg.api.PublishedRound` is cached (timestamps are
        zero — the flat server keeps no clock; the engine's records carry
        real open/seal/publish times)."""
        if self._published:
            return list(self._published)
        if not self._sealed or self.unresolved:
            return []
        mean, stats = self.finalize()
        self._published.append(PublishedRound(
            round_id=self.spec.round_id, spec=self.spec,
            anchor=self._anchor_raw if self.spec.anchored else None,
            mean=mean, stats=stats, accepted=self.accepted_clients,
            opened_at=0.0, sealed_at=0.0, published_at=0.0,
            anchor_round=0, staleness=0.0))
        return list(self._published)

    # ----------------------------------------------------------- LIFECYCLE
    def seal(self, next_round_id: int = 0) -> None:
        """Stop admitting NEW clients (round cutover).

        Already-admitted clients keep full service — outstanding chunks,
        selective retransmits and escalation retries all still land (the
        overlapping drain); a frame from anyone else draws a non-terminal
        ``STATUS_RETRY`` pointing at ``next_round_id`` (the round now open
        for admission).  Idempotent."""
        self._sealed = True
        self._next_round_id = next_round_id

    @property
    def sealed(self) -> bool:
        return self._sealed

    @property
    def admitted_count(self) -> int:
        """Distinct clients admitted into the round (quorum input)."""
        return len(self._admitted)

    @property
    def unresolved(self) -> frozenset:
        """Admitted clients with no outcome yet (not accepted, not
        escalation-exhausted) — empty means the round is fully drained."""
        return frozenset(self._admitted - self._accepted - self._gave_up)

    @property
    def occupancy(self) -> int:
        """Distinct clients currently holding buffered server state (the
        bounded pending store: staged payloads + open reassembly streams).
        Accepted clients have been folded into the integer accumulator and
        hold nothing."""
        return len(set(self._pending) | self._rx.open_clients())

    def expire_client(self, client_id: int) -> None:
        """Drop a straggler's state without a verdict (engine deadline).

        The client's pending payload / reassembly streams are discarded and
        its admission slot freed, so the round can drain without it.  No
        response is generated — expiry is not a protocol outcome, and the
        client is free to enroll in a later round."""
        if (client_id not in self._admitted or client_id in self._accepted
                or client_id in self._gave_up):
            return                  # only unresolved stragglers expire
        prev = self._pending.pop(client_id, None)
        if prev is not None:
            self._pending_bytes -= prev.words.nbytes + prev.sides.nbytes
        self._rx.discard(client_id)   # fires the stream-fold rollback too
        self._admitted.discard(client_id)
        self._obs.inc("expired")
        if _obs.tracing_enabled():
            _obs.tracer().event("expire",
                                parent=("round", self.spec.round_id),
                                round=self.spec.round_id, client=client_id)

    # --------------------------------------------------------------- DRAIN
    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def transport_stats(self) -> S.ReassemblyStats:
        """The session layer's reassembly telemetry (chunked rounds)."""
        return self._rx.stats

    @property
    def accepted_clients(self) -> frozenset:
        return frozenset(self._accepted)

    def drain(self) -> list[bytes]:
        """Decode everything pending; returns ACK/NACK/REJECT responses.

        One batched kernel launch per distinct color space q among the
        pending payloads (a round at a single escalation level — the common
        case — drains in exactly one launch).
        """
        if not self._pending:
            return self._resend_requests()
        self._obs.inc("drains")
        drain_sp = _obs.tracer().begin(
            "drain", parent=("round", self.spec.round_id),
            round=self.spec.round_id, payloads=len(self._pending)) \
            if _obs.tracing_enabled() else None
        by_q: dict[int, list[wire.Payload]] = {}
        for p in self._pending.values():
            by_q.setdefault(p.q, []).append(p)
        self._pending.clear()
        self._pending_bytes = 0
        responses = []
        for q, plist in sorted(by_q.items()):
            plist.sort(key=lambda p: p.client_id)
            # pad the sender axis to the kernel's block size so drain sizes
            # map onto a bounded set of compiled shapes (padding rows carry
            # valid=False and never enter the sum)
            S = len(plist)
            pad = (-S) % DEFAULT_BLOCK_SENDERS
            attempt0 = plist[0].attempt
            words = jnp.asarray(np.pad(
                np.stack([p.words for p in plist]), ((0, pad), (0, 0))))
            sides = jnp.asarray(np.pad(
                np.stack([p.sides for p in plist]), ((0, pad), (0, 0)),
                constant_values=1.0))
            checks = jnp.asarray(np.pad(
                np.array([p.check for p in plist], np.uint32), (0, pad)))
            valid = jnp.asarray(np.arange(S + pad) < S)
            m = jnp.asarray(np.pad(
                np.array([p.n_summed for p in plist], np.int32), (0, pad),
                constant_values=1))
            y_col = jnp.asarray(wire.y_buckets_at_attempt(self.spec,
                                                          attempt0))
            (ok, ksum_delta, count_delta, max_dist, dist_b, fails_b,
             max_abs_k) = \
                _drain_math(words, sides, checks, valid, self._ref_flat,
                            self._u.reshape(-1), self._weights, y_col, m,
                            self._k0, q=q, bucket=self.spec.cfg.bucket)
            ok = np.asarray(ok)[:S]
            n_ok = int(ok.sum())
            n_clients = int(count_delta)    # n_ok plus tier fan-in (m > 1)
            # int32 accumulator guard: sum_i |k_i| <= count * max|k| must
            # stay below 2^31 or the exact integer sum may have wrapped —
            # fail loudly (an anchored round is the fix: coords stay ~y/s)
            self._max_abs_k = max(self._max_abs_k, int(max_abs_k))
            if (self._count + n_clients) * self._max_abs_k >= 2 ** 31:
                raise OverflowError(
                    f"round {self.spec.round_id}: accumulating {n_ok} more "
                    f"senders with |coords| up to {self._max_abs_k} can "
                    f"overflow the int32 sum ({self._count} accepted so "
                    f"far); anchor the round (RoundSpec.anchor_digest) so "
                    f"coordinates stay ~y/s instead of ~|x|/s")
            self._ksum = self._ksum + ksum_delta.reshape(self._ksum.shape)
            self._count += n_clients
            self._obs.inc("accepted", n_ok)
            self._obs.set_max("max_dist", float(max_dist))
            self._stats.dist_b = np.maximum(self._stats.dist_b,
                                            np.asarray(dist_b))
            self._stats.fails_b = self._stats.fails_b + np.asarray(fails_b)
            for p, good in zip(plist, ok):
                if good:
                    self._accepted.add(p.client_id)
                    self._rx.discard(p.client_id)   # stale chunk sessions
                    responses.append(self._respond(self._ack(p.client_id)))
                    continue
                self._obs.inc("decode_failures")
                nxt = p.attempt + 1
                if p.q >= wire.Q_CAP or nxt >= self.spec.max_attempts:
                    self._gave_up.add(p.client_id)
                    self._rx.discard(p.client_id)
                    self._obs.inc("gave_up")
                    responses.append(
                        self._respond(_reject(self.spec, p.client_id)))
                    continue
                self._obs.inc("nacks_sent")
                self._attempt_floor[p.client_id] = nxt
                responses.append(self._respond(wire.Response(
                    status=wire.STATUS_NACK, round_id=self.spec.round_id,
                    client_id=p.client_id, attempt_next=nxt,
                    q_next=wire.q_at_attempt(self.spec.cfg.q, nxt),
                    y_next=wire.y_at_attempt(self.spec, nxt),
                    y_buckets=self._margin_tuple(nxt),
                    credit=self.spec.window)))
        if drain_sp is not None:
            _obs.tracer().end(drain_sp, accepted=len(self._accepted))
        return responses + self._resend_requests()

    def _resend_for(self, cid: int, attempt: int, missing: tuple) -> bytes:
        self._obs.inc("resends_sent")
        if _obs.metrics_enabled():
            _obs.counter("chunk_retransmits",
                         round=self.spec.round_id).inc(len(missing))
        return self._respond(wire.Response(
            status=wire.STATUS_RESEND, round_id=self.spec.round_id,
            client_id=cid, attempt_next=attempt,
            q_next=wire.q_at_attempt(self.spec.cfg.q, attempt),
            y_next=wire.y_at_attempt(self.spec, attempt),
            y_buckets=self._margin_tuple(attempt), missing=missing,
            ack=self._rx.high_water(cid) if self.spec.window else 0,
            credit=self.spec.window))

    def _resend_requests(self) -> list[bytes]:
        """Chunk-level NACKs for every still-incomplete reassembly: each
        names exactly the missing chunk indices, so the retransmit wire
        cost is per lost chunk, never per payload."""
        return [self._resend_for(cid, attempt, missing)
                for cid, (attempt, missing) in self._rx.incomplete().items()]

    def resend_request(self, client_id: int) -> "Optional[bytes]":
        """A targeted RESEND for ONE client's incomplete reassembly — the
        engine's straggler deadline taps the RESEND budget per client
        without re-NACKing everyone else mid-drain.  None when the client
        has no open incomplete stream (a staged payload just needs a
        drain; a NACKed-and-silent client has nothing to retransmit)."""
        info = self._rx.incomplete().get(client_id)
        if info is None:
            return None
        return self._resend_for(client_id, *info)

    # ------------------------------------------------------------ FINALIZE
    def finalize(self) -> tuple[np.ndarray, RoundStats]:
        """Drain anything still pending and return (mean (d,), stats).

        The mean is over the accepted senders; with zero accepts it is the
        all-zeros vector (the round anchor in anchored rounds — the best
        available estimate when nobody reported).  Bit-identical for any
        arrival order of the same accepted payload set.
        """
        self.drain()
        if _obs.tracing_enabled() and not self._publish_traced:
            self._publish_traced = True
            tr = _obs.tracer()
            tr.event("publish", parent=("round", self.spec.round_id),
                     round=self.spec.round_id, accepted=len(self._accepted))
            # close the round span (the engine opened it; a standalone flat
            # server gets a synthetic one from the parent fallback above)
            tr.end(("round", self.spec.round_id))
        if self._count == 0:
            if not self.spec.anchored:
                return np.zeros((self.spec.d,), np.float32), self.stats
            return (np.asarray(rounds.unbucketize(self._anchor_b, self.spec)),
                    self.stats)
        ksum = self._ksum
        if self._ksum_st is not None:
            # merge the streamed commits — exact int64 -> int32, safe under
            # the same count * max|k| < 2^31 bound as the batched drain
            ksum = ksum + jnp.asarray(
                self._ksum_st.reshape(ksum.shape).astype(np.int32))
        mean_b = _mean_math(ksum, jnp.int32(self._count), self._u,
                            self._sides[:, None])
        if self.spec.anchored:
            mean_b = mean_b + self._anchor_b
        return np.asarray(rounds.unbucketize(mean_b, self.spec)), self.stats