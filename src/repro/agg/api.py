"""The unified aggregation-node surface (ISSUE 7 API redesign).

Every aggregation endpoint in the repo — the single-round flat server
(:class:`repro.agg.server.AggServer`), the continuous-round engine
(:class:`repro.agg.engine.AggEngine`) and the hierarchical tree
(:class:`repro.agg.tree.TierAggregator` / :class:`repro.agg.tree.AggTree`)
— speaks the same three-verb protocol:

* ``ingest_frame(data, now)`` — feed one transport message (a client frame,
  or — for a tier — an upstream response); returns the response bytes the
  node wants sent.
* ``tick(now)`` — fire time/batch-based policy (drains, deadlines,
  retransmit requests, upstream forwarding); returns outbound bytes.
* ``published()`` — the in-order list of :class:`PublishedRound` outcomes.

A driver written against :class:`AggNode` cannot tell a flat star from a
two-level tree from the overlapping-round engine: the sim and the examples
drive all three through these verbs and assert bit-identical means.

:class:`AggConfig` is the one composed knob surface: the round-contract
fields that :class:`~repro.agg.service.ServiceConfig` owns, the
cutover/drain policy that :class:`~repro.agg.engine.EngineConfig` owns, and
the tree topology (``fanout``/``tiers``) in a single dataclass.  The
``service_config()`` / ``engine_config()`` builders project it onto the
layer configs; field defaults are asserted drift-free against the layer
configs by ``tests/test_tree.py::test_config_defaults_no_drift``.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Protocol, runtime_checkable

import numpy as np

from repro.agg.transport import frame as wire

if TYPE_CHECKING:                                    # no import cycle at
    from repro.agg.server import RoundStats          # runtime: hints only


@dataclasses.dataclass
class PublishedRound:
    """One published round's outcome + latency/staleness telemetry.

    Produced by every :class:`AggNode` implementation; historically defined
    in :mod:`repro.agg.engine` (which still re-exports it).
    """
    round_id: int
    spec: wire.RoundSpec
    anchor: Optional[np.ndarray]    # what clients encoded against (None:
                                    # unanchored round)
    mean: np.ndarray
    stats: "RoundStats"
    accepted: frozenset             # client ids in the published mean
    opened_at: float
    sealed_at: float
    published_at: float
    anchor_round: int               # round whose mean this round anchored
                                    # against (0 = warm start)
    staleness: float                # published_at - anchor's publish time
                                    # (0.0 for warm-start anchors): how old
                                    # the anchor was when this mean shipped

    @property
    def latency(self) -> float:
        """Open -> published round latency (driver clock units)."""
        return self.published_at - self.opened_at

    @property
    def staleness_rounds(self) -> int:
        """Anchor lag in rounds (0 for warm-start anchors)."""
        return self.round_id - self.anchor_round if self.anchor_round else 0


class PublishedLog(list):
    """A list of :class:`PublishedRound` that is also callable.

    :class:`~repro.agg.engine.AggEngine` predates the :class:`AggNode`
    protocol and exposes its history as the attribute ``engine.published``
    (indexed and iterated all over the tests and the sim).  The protocol
    verb is the *call* ``node.published()``.  This list subclass satisfies
    both spellings without breaking either caller.
    """

    def __call__(self) -> "list[PublishedRound]":
        return list(self)


@runtime_checkable
class AggNode(Protocol):
    """The structural protocol every aggregation endpoint implements.

    ``ingest_frame`` / ``tick`` return *outbound transport bytes* — each
    item is a complete frame or response message; the driver owns routing
    (responses go back to the sender named in their header, frames go to
    the node's upstream).  ``now`` is whatever monotonic clock the driver
    uses (the sim passes virtual seconds); nodes are clock-agnostic and
    fire all policy from these two entry points — no threads, no timers.
    """

    def ingest_frame(self, data: bytes, now: float = 0.0) -> "list[bytes]":
        """Feed one arriving transport message; returns responses/frames."""
        ...

    def tick(self, now: float = 0.0) -> "list[bytes]":
        """Fire due time-based policy; returns responses/frames."""
        ...

    def published(self) -> "list[PublishedRound]":
        """In-order outcomes of every round this node has published."""
        ...


@dataclasses.dataclass(frozen=True)
class AggConfig:
    """One composed config for every aggregation topology.

    The round-contract knobs (mirroring
    :class:`~repro.agg.service.ServiceConfig`), the engine's cutover /
    drain / admission policy (mirroring
    :class:`~repro.agg.engine.EngineConfig`) and the tree topology live in
    one place, so a tree tier is configured exactly like the root and a
    knob can never drift silently between layers (defaults are asserted
    equal field-by-field in the test suite).
    """
    # ---- round contract (ServiceConfig) ----
    d: int
    q: int = 16
    bucket: int = 512
    rotate: bool = False
    y0: float = 1.0
    seed: int = 0
    max_attempts: int = 4
    anchored: bool = True
    mtu: int = 0
    window: int = 0
    y_decay: float = 0.75
    y_escalate: float = 2.0
    y_floor: float = 1e-6
    # ---- cutover / drain / admission policy (EngineConfig) ----
    quorum: int = 64
    round_deadline: float = 1.0
    min_clients: int = 1
    straggler_deadline: float = 0.25
    max_resends: int = 2
    drain_deadline: float = 1.0
    max_pending: Optional[int] = None
    max_live_rounds: int = 3
    # ---- tree topology (AggTree) ----
    fanout: int = 8               # max children per aggregation node
    tiers: int = 1                # tier layers between clients and the root

    _SERVICE_FIELDS = ("d", "q", "bucket", "rotate", "y0", "seed",
                       "max_attempts", "anchored", "mtu", "window",
                       "y_decay", "y_escalate", "y_floor")
    _ENGINE_FIELDS = ("quorum", "round_deadline", "min_clients",
                      "straggler_deadline", "max_resends", "drain_deadline",
                      "max_pending", "max_live_rounds")

    def service_config(self):
        """Project onto :class:`repro.agg.service.ServiceConfig`."""
        from repro.agg.service import ServiceConfig
        return ServiceConfig(
            **{f: getattr(self, f) for f in self._SERVICE_FIELDS})

    def engine_config(self):
        """Project onto :class:`repro.agg.engine.EngineConfig`."""
        from repro.agg.engine import EngineConfig
        return EngineConfig(
            **{f: getattr(self, f) for f in self._ENGINE_FIELDS})
