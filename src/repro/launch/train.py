"""End-to-end training driver.

Examples:
  # CPU sanity (smoke config, 1 device):
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke --steps 20

  # ~100M LM for a few hundred steps (examples/train_lm.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300

  # production pod (on real hardware; mesh axes = data x model):
  python -m repro.launch.train --arch qwen3-32b --mesh 16x16 --steps 1000
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx
from repro.dist.collectives import QSyncConfig
from repro.launch.mesh import make_mesh
from repro.train.trainer import Trainer, TrainConfig
from repro.train.optim import OptConfig
from repro.train.data import DataConfig


PRESETS = {
    # ~100M-parameter decoder LM (examples/train_lm.py)
    "100m": ModelConfig(arch="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv=4, head_dim=64,
                        d_ff=2048, vocab=32768, act="swiglu"),
    "25m": ModelConfig(arch="lm-25m", family="dense", n_layers=8,
                       d_model=384, n_heads=6, n_kv=2, head_dim=64,
                       d_ff=1024, vocab=16384, act="swiglu"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=list(PRESETS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1", help="DPxTP, e.g. 16x16")
    ap.add_argument("--grad-sync", default="lq",
                    choices=["lq", "fp32"])
    ap.add_argument("--q", type=int, default=16)
    ap.add_argument("--bucket", type=int, default=4096)
    ap.add_argument("--rotate", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.preset:
        cfg = PRESETS[args.preset]
    elif args.arch:
        cfg = (registry.smoke_config(args.arch) if args.smoke
               else registry.config(args.arch))
    else:
        raise SystemExit("pass --arch or --preset")

    dp, tp = (int(v) for v in args.mesh.split("x"))
    if dp * tp > len(jax.devices()):
        raise SystemExit(f"mesh {args.mesh} needs {dp*tp} devices, "
                         f"have {len(jax.devices())}")
    mesh = make_mesh((dp, tp), ("data", "model"))
    ctx = ShardCtx(tp=tp, dp=dp,
                   qcfg=QSyncConfig(q=args.q, bucket=args.bucket,
                                    rotate=args.rotate),
                   grad_sync=args.grad_sync,
                   seq_parallel=tp > 1 and cfg.family != "encdec")
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, log_every=args.log_every,
                     microbatch=args.microbatch)
    opt = OptConfig(lr=args.lr, warmup=min(50, args.steps // 10 + 1),
                    decay_steps=args.steps)
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    extra = None
    if cfg.family == "vlm":
        from repro.train.data import frames_at
        extra = lambda step: {"img": frames_at(data, step, cfg.img_tokens,
                                               cfg.d_model)}
    if cfg.family == "encdec":
        from repro.train.data import frames_at
        extra = lambda step: {"frames": frames_at(data, step, cfg.enc_seq,
                                                  cfg.d_model)}
        raise SystemExit("encdec training driver: use tests/benchmarks "
                         "(frames batch wiring differs)")

    print(f"[train] arch={cfg.arch} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={args.mesh} sync={args.grad_sync}(q={args.q}) "
          f"steps={args.steps}", flush=True)
    tr = Trainer(cfg, ctx, mesh, opt, tc, data, extra_batch=extra)
    state = tr.train()
    if tr.history:
        first, last = tr.history[0], tr.history[-1]
        print(f"[train] loss {first['loss']:.4f} -> {last['loss']:.4f} over "
              f"{int(state['step'])} steps", flush=True)


if __name__ == "__main__":
    main()
