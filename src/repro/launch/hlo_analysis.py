"""Recursive HLO cost accounting with loop trip-count expansion.

``compiled.cost_analysis()`` counts every computation ONCE — a while loop
(jax.lax.scan over layers / microbatches / attention chunks) contributes a
single body execution, so a 96-layer scanned transformer looks 96x cheaper
than it is, and the collectives inside the scan body disappear from the
bytes count.  This module re-derives, from ``compiled.as_text()``:

  * dot_flops        — 2 * prod(out dims) * prod(contracted dims), every
                       while body multiplied by its trip count (parsed from
                       the loop-condition constant);
  * collective_bytes — per-kind operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       trip-count-expanded;
  * traffic_bytes    — an HBM-traffic proxy: inputs+outputs of fusion / dot /
                       copy / scatter-gather / collective ops (the fusion-
                       boundary model of memory traffic).

All three feed benchmarks/roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")
# NOTE: standalone layout/convert ops (reshape / transpose / convert / copy /
# bitcast) are EXCLUDED: the TPU backend fuses them into producer/consumer
# kernels, so counting them as separate HBM round-trips (as the CPU pipeline
# executes them) would overstate the memory term for the TPU target.
TRAFFIC_KINDS = ("fusion", "dot", "gather", "scatter", "convolution",
                 "dynamic-slice", "dynamic-update-slice",
                 "broadcast", "reduce", "select-and-scatter", "concatenate",
                 "slice", "pad", "reverse", "sort", "iota") + COLL_KINDS

_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\)\s*->.*)?\{\s*$")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?)\s+"
                  r"([a-z][\w\-]*)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")
_TOAPPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_OPERANDS = re.compile(r"%?([\w][\w.\-]*)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _type_dims(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Costs:
    dot_flops: float = 0.0
    traffic: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.traffic += other.traffic * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    line: str


def parse_computations(hlo: str):
    comps: dict[str, list[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        # strip /*index=N*/ comments — they break attribute/type parsing on
        # large tuple types
        line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
        h = _HDR.match(line.strip())
        if h and cur is None:
            cur = h.group(2)
            comps[cur] = []
            if h.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        d = _DEF.match(line)
        if d:
            comps[cur].append(_Op(d.group(1), d.group(2), d.group(3), line))
        else:
            # parameters like "%p = s32[] parameter(0)" match _DEF; anything
            # else (comments) is ignored
            pass
    return comps, entry


def operand_names(op: _Op) -> list:
    """Operand names of `op`, robust to current XLA HLO text.

    Operands carry inline types with commas/braces/parens inside them —
    ``dot(f32[8,8]{1,0} %Arg_0.1, f32[8,8]{1,0} %Arg_1.2)`` or tuple
    types ``while((s32[], f32[8,8]{1,0}) %tuple)`` — so the argument
    list must be extracted with bracket-aware scanning, not split(",").
    """
    start = op.line.find(f"{op.kind}(")
    if start < 0:
        return []
    i = start + len(op.kind)           # at the opening "("
    depth = 0
    j = i
    for j in range(i, len(op.line)):
        ch = op.line[j]
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                break
    inner = op.line[i + 1:j]
    out = []
    cur: list[str] = []
    depth = 0
    for ch in inner + ",":
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            tok = "".join(cur).strip()
            cur = []
            if tok:
                # drop the inline type prefix: the name is the last
                # whitespace-separated token, with its % sigil stripped
                out.append(tok.split()[-1].lstrip("%"))
        else:
            cur.append(ch)
    return out


_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}|"
                       r"true_computation=%?([\w.\-]+)|"
                       r"false_computation=%?([\w.\-]+)")


def _called_comps(op: _Op) -> list:
    """Names of every sub-computation an op references (fusion calls=,
    while body=/condition=, reduce/map to_apply=, conditional branches)."""
    names = []
    for rx in (_CALLS, _BODY, _COND, _TOAPPLY):
        m = rx.search(op.line)
        if m:
            names.append(m.group(1))
    for m in _BRANCHES.finditer(op.line):
        for g in m.groups():
            if g:
                names += [nm.lstrip("%") for nm in re.split(r"[,\s]+", g)
                          if nm.lstrip("%")]
    return names


def analyze(hlo: str, default_trip: int = 1) -> Costs:
    comps, entry = parse_computations(hlo)
    memo: dict[str, Costs] = {}
    symtab: dict[str, dict[str, str]] = {
        cname: {op.name: op.type_str for op in ops}
        for cname, ops in comps.items()
    }

    def trip_count(cond_name: str) -> int:
        consts = []
        for op in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST.findall(op.line)]
        return max(consts) if consts else default_trip

    def eff_bytes(type_str: str, trip) -> float:
        """Bytes of one access.  Inside a while body with trip count t, a
        buffer whose LEADING dim equals t is a scan-stacked xs/ys buffer —
        the iteration touches one slice, so charge 1/t of it."""
        total = 0.0
        for dt, dims in _SHAPE.findall(type_str):
            n = 1
            dd = [int(d) for d in dims.split(",") if d]
            for d in dd:
                n *= d
            b = n * DTYPE_BYTES.get(dt, 4)
            if trip and dd and dd[0] == trip and trip > 1:
                b /= trip
            total += b
        return total

    def operand_bytes(op: _Op, syms: dict[str, str], trip=None) -> float:
        return float(sum(eff_bytes(syms[nm], trip) for nm in operand_names(op)
                         if nm in syms))

    def dot_flops(op: _Op, syms: dict[str, str]) -> float:
        out_dims = _type_dims(op.type_str)
        out_n = 1
        for d in out_dims:
            out_n *= d
        ops_ = operand_names(op)
        if not ops_ or ops_[0] not in syms:
            return 0.0
        lhs_dims = _type_dims(syms[ops_[0]])
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        contracted = 1
        if cm and cm.group(1):
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    contracted *= lhs_dims[i]
        return 2.0 * out_n * contracted

    def cost_of(name: str, stack=(), trip=None) -> Costs:
        mk = (name, trip)
        if mk in memo:
            return memo[mk]
        if name in stack:
            return Costs()
        c = Costs()
        syms = symtab.get(name, {})
        for op in comps.get(name, []):
            k = op.kind
            if k == "dot":
                c.dot_flops += dot_flops(op, syms)
                c.traffic += (eff_bytes(op.type_str, trip)
                              + operand_bytes(op, syms, trip))
            elif k == "while":
                bm, cm_ = _BODY.search(op.line), _COND.search(op.line)
                if bm:
                    t = trip_count(cm_.group(1)) if cm_ else default_trip
                    c.add(cost_of(bm.group(1), stack + (name,), max(t, 1)),
                          max(t, 1))
            elif k == "fusion":
                fm = _CALLS.search(op.line)
                called = fm.group(1) if fm else None
                if called:
                    sub = cost_of(called, stack + (name,), trip)
                    # inner ops live in registers/VMEM: count flops and
                    # collectives from inside, but traffic only at the
                    # fusion BOUNDARY (inputs+outputs)
                    c.dot_flops += sub.dot_flops
                    for kk, vv in sub.coll.items():
                        c.coll[kk] = c.coll.get(kk, 0.0) + vv
                out_b = eff_bytes(op.type_str, trip)
                in_b = operand_bytes(op, syms, trip)
                # fusions rooted in dynamic-update-slice write IN-PLACE into
                # a donated buffer (scan ys-append / cache update): count the
                # touched slice, not the whole carried buffer
                root = None
                for o2 in comps.get(called or "", []):
                    if "ROOT" in o2.line:
                        root = o2
                        break
                if root is not None and root.kind.startswith(
                        "dynamic-update-slice"):
                    c.traffic += 2.0 * max(in_b - out_b, 0.0)
                elif root is not None and (root.kind.startswith("dynamic-slice")
                                           or root.kind == "slice"):
                    # gather-a-slice-from-a-big-buffer fusion: the big buffer
                    # is indexed, not streamed
                    c.traffic += 2.0 * out_b
                else:
                    c.traffic += out_b + in_b
            elif k == "conditional":  # noqa: branch traffic approximate
                for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"true_computation=%?([\w.\-]+)|"
                                     r"false_computation=%?([\w.\-]+))",
                                     op.line):
                    for grp in br:
                        for nm in _OPERANDS.findall("%" + grp if grp and
                                                    not grp.startswith("%")
                                                    else grp or ""):
                            if nm in comps:
                                c.add(cost_of(nm, stack + (name,)))
            elif any(k.startswith(ck) for ck in COLL_KINDS):
                base = next(ck for ck in COLL_KINDS if k.startswith(ck))
                if k.endswith("-done"):
                    continue               # counted at -start
                # ring-model wire bytes per device:
                #   all-gather: ~output bytes; all-reduce: ~2x input;
                #   reduce-scatter / all-to-all / permute: ~input bytes
                inb = operand_bytes(op, syms)
                outb = _type_bytes(op.type_str)
                wire = (outb if base == "all-gather"
                        else 2 * inb if base == "all-reduce" else inb)
                c.coll[base] = c.coll.get(base, 0.0) + wire
                c.coll[base + "_count"] = c.coll.get(base + "_count", 0) + 1
                c.traffic += outb + inb
            elif k in ("call", "custom-call", "reduce", "sort", "map",
                       "reduce-window"):
                fm = _TOAPPLY.search(op.line) or _CALLS.search(op.line)
                if fm and fm.group(1) in comps:
                    if k == "call" or k == "custom-call":
                        # real computation bodies (pre-opt closed_call /
                        # shard_map): include everything
                        c.add(cost_of(fm.group(1), stack + (name,), trip))
                    else:
                        # reduce/sort lambdas are scalar: flops/coll only
                        sub = cost_of(fm.group(1), stack + (name,), trip)
                        c.dot_flops += sub.dot_flops
                        for kk, vv in sub.coll.items():
                            c.coll[kk] = c.coll.get(kk, 0.0) + vv
                if k != "call":
                    c.traffic += (eff_bytes(op.type_str, trip)
                                  + operand_bytes(op, syms, trip))
            elif k.startswith("dynamic-update-slice"):
                # in-place update: read+write of the touched slice only
                names = operand_names(op)
                upd = (eff_bytes(syms[names[1]], trip)
                       if len(names) > 1 and names[1] in syms else 0)
                c.traffic += 2 * upd
            elif k.startswith("dynamic-slice") or k in ("slice", "broadcast",
                                                        "iota"):
                c.traffic += eff_bytes(op.type_str, trip)  # output only
            elif any(k.startswith(tk) for tk in TRAFFIC_KINDS):
                c.traffic += (eff_bytes(op.type_str, trip)
                              + operand_bytes(op, syms, trip))
        memo[name] = c
        return c

    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n])) if comps else ""
    return cost_of(entry)


# ---------------------------------------------------------------------------
# Collective/compute overlap auditor (the FSDP prefetch proof)
# ---------------------------------------------------------------------------

_COMPUTE_KINDS = ("dot", "convolution")


@dataclasses.dataclass
class OverlapAudit:
    """Per-while-body report of whether loop collectives are *exposed*
    (their result feeds compute in the same iteration — latency on the
    critical path) or *overlapped* (the result only escapes into the loop
    carry, so the next iteration consumes it and the collective runs
    concurrently with this iteration's dominant compute).

    ``bodies``: one dict per audited while body — {"body", "trip_weight",
    "total_bytes", "exposed_bytes", "collectives": [{"op", "kind", "bytes",
    "exposed"}]}.  Bytes use the same ring wire model as :func:`analyze`
    and are trip-count weighted.
    """
    bodies: list = dataclasses.field(default_factory=list)
    total_bytes: float = 0.0
    exposed_bytes: float = 0.0

    @property
    def exposed_fraction(self) -> float:
        """Fraction of loop-collective wire bytes on the critical path
        (1.0 = fully serialized, as the serial layer scan; the prefetched
        double-buffered scan must come out strictly lower).  0.0 when no
        while body contains collectives."""
        return (self.exposed_bytes / self.total_bytes
                if self.total_bytes else 0.0)


def audit_overlap(hlo: str, default_trip: int = 1) -> OverlapAudit:
    """Walk every while body of the lowered HLO and classify each loop
    collective as exposed vs overlapped (see :class:`OverlapAudit`).

    A collective is *overlapped* when every consumer chain of its result
    reaches only the body root (the loop carry) — possibly escaping
    through sub-computations (the prefetched scan issues next-layer
    gathers inside a ``conditional`` branch, whose root value flows to
    the caller).  It is *exposed* as soon as any chain reaches a compute
    op: a dot / convolution / custom-call, or a call-like op (fusion,
    call, nested while, conditional, reduce, ...) whose sub-computation
    transitively contains one.
    """
    comps, entry = parse_computations(hlo)
    symtab = {cn: {op.name: op.type_str for op in ops}
              for cn, ops in comps.items()}
    opmap = {cn: {op.name: op for op in ops} for cn, ops in comps.items()}

    roots: dict = {}
    for cname, ops in comps.items():
        for op in ops:
            if "ROOT" in op.line:
                roots[cname] = op.name

    _consumers: dict = {}

    def consumers_of(cname: str) -> dict:
        if cname not in _consumers:
            mp: dict = {}
            for op in comps.get(cname, []):
                for nm in operand_names(op):
                    mp.setdefault(nm, []).append(op)
            _consumers[cname] = mp
        return _consumers[cname]

    hc_memo: dict = {}

    def comp_has_compute(cname: str, stack=()) -> bool:
        if cname in hc_memo:
            return hc_memo[cname]
        if cname in stack or cname not in comps:
            return False
        out = any(is_compute(op, stack + (cname,)) for op in comps[cname])
        hc_memo[cname] = out
        return out

    def is_compute(op: _Op, stack=()) -> bool:
        if op.kind in _COMPUTE_KINDS or op.kind.startswith("custom-call"):
            return True
        return any(comp_has_compute(c, stack) for c in _called_comps(op)
                   if c in comps)

    def trip_count(cond_name: str) -> int:
        consts = []
        for op in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST.findall(op.line)]
        return max(consts) if consts else default_trip

    def coll_wire(op: _Op, cname: str) -> float:
        # same ring wire model as analyze(): all-gather ~ output bytes,
        # all-reduce ~ 2x input, everything else ~ input bytes
        base = next(ck for ck in COLL_KINDS if op.kind.startswith(ck))
        syms = symtab.get(cname, {})
        inb = sum(_type_bytes(syms[nm]) for nm in operand_names(op)
                  if nm in syms)
        outb = _type_bytes(op.type_str)
        return float(outb if base == "all-gather"
                     else 2 * inb if base == "all-reduce" else inb)

    def collect_colls(cname: str, chain, seen):
        """(collective op, containing comp, call chain) triples reachable
        from a while body without crossing into nested whiles (those are
        audited as their own bodies)."""
        out = []
        if cname in seen:
            return out
        for op in comps.get(cname, []):
            k = op.kind
            if any(k.startswith(ck) for ck in COLL_KINDS):
                if not k.endswith("-done"):     # count async pairs at -start
                    out.append((op, cname, chain))
            elif k == "while":
                continue
            else:
                for c in _called_comps(op):
                    if c in comps:
                        out += collect_colls(c, chain + ((cname, op),),
                                             seen | {cname})
        return out

    def is_exposed(coll_op: _Op, cname: str, chain) -> bool:
        """BFS over consumer edges from the collective's result.  Reaching
        compute => exposed; reaching the body root (depth 0) => that chain
        is overlapped (value parked in the loop carry); reaching a
        sub-computation's root resumes from the calling op's consumers."""
        comp_at = [c for c, _ in chain] + [cname]
        call_at = [op for _, op in chain]
        frontier = [(len(comp_at) - 1, u.name)
                    for u in consumers_of(cname).get(coll_op.name, [])]
        visited = set()
        while frontier:
            d, nm = frontier.pop()
            if (d, nm) in visited:
                continue
            visited.add((d, nm))
            comp = comp_at[d]
            op = opmap.get(comp, {}).get(nm)
            if op is None:
                continue
            if is_compute(op):
                return True
            frontier += [(d, u.name)
                         for u in consumers_of(comp).get(nm, [])]
            if roots.get(comp) == nm and d > 0:
                # escaped the sub-computation: resume from the call site
                # (skip the compute check on the call op itself — the
                # collective lives inside it)
                frontier += [(d - 1, u.name)
                             for u in consumers_of(comp_at[d - 1]).get(
                                 call_at[d - 1].name, [])]
        return False

    audit = OverlapAudit()
    seen_bodies = set()

    def walk(cname: str, mult: float, stack=()):
        if cname in stack:
            return
        for op in comps.get(cname, []):
            if op.kind == "while":
                bm, cm_ = _BODY.search(op.line), _COND.search(op.line)
                if not bm:
                    continue
                body = bm.group(1)
                t = max(trip_count(cm_.group(1)) if cm_ else default_trip, 1)
                if body not in seen_bodies:
                    seen_bodies.add(body)
                    rec = {"body": body, "trip_weight": mult * t,
                           "total_bytes": 0.0, "exposed_bytes": 0.0,
                           "collectives": []}
                    for cop, ccomp, chain in collect_colls(body, (),
                                                           frozenset()):
                        b = coll_wire(cop, ccomp) * mult * t
                        ex = is_exposed(cop, ccomp, chain)
                        rec["collectives"].append(
                            {"op": cop.name, "kind": cop.kind,
                             "bytes": b, "exposed": ex})
                        rec["total_bytes"] += b
                        if ex:
                            rec["exposed_bytes"] += b
                    audit.bodies.append(rec)
                    audit.total_bytes += rec["total_bytes"]
                    audit.exposed_bytes += rec["exposed_bytes"]
                walk(body, mult * t, stack + (cname,))
            else:
                for c in _called_comps(op):
                    if c in comps:
                        walk(c, mult, stack + (cname,))

    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n])) if comps else ""
    if entry:
        walk(entry, 1.0)
    return audit
