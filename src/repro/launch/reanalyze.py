"""Re-derive traffic_bytes in dry-run JSONs from the archived post-opt HLO
(results/hlo/*.hlo.zst) without recompiling.  Run after analyzer fixes."""
import glob
import json
import os
import sys

import zstandard as zstd

from repro.launch.hlo_analysis import analyze


def main(results="results/dryrun", hlo_dir="results/hlo"):
    n = 0
    for jp in sorted(glob.glob(os.path.join(results, "*.json"))):
        rec = json.load(open(jp))
        if rec.get("skipped"):
            continue
        name = os.path.basename(jp)[:-5]
        hp = os.path.join(hlo_dir, name + ".hlo.zst")
        if not os.path.exists(hp):
            print(f"reanalyze: no HLO for {name}", file=sys.stderr)
            continue
        txt = zstd.ZstdDecompressor().decompress(open(hp, "rb").read(),
                                                 max_output_size=1 << 31)
        post = analyze(txt.decode())
        rec["traffic_bytes"] = post.traffic
        json.dump(rec, open(jp, "w"), indent=1)
        n += 1
    print(f"reanalyzed {n} cells")


if __name__ == "__main__":
    main(*sys.argv[1:])
