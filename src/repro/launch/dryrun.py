import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import side effect: 512 placeholder CPU devices so
``jax.make_mesh`` can build the production meshes (16x16 single-pod,
2x16x16 multi-pod).  Do not move the os.environ lines.

Per cell, records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits),
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the compiled HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    sizes) — the roofline's third term,
to JSON under --out (default results/dryrun).

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--shapes train_4k,...]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import registry, shapes as SH
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as ST
from repro.launch.hlo_analysis import analyze, audit_overlap
from repro.dist.collectives import QSyncConfig


COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\S+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,1024]{1,0}' -> byte count (per participating device)."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op, by kind.

    Tuple-shaped outputs ((f32[...], f32[...])) are summed over elements.
    This counts bytes *entering the interconnect* once per device (the
    standard roofline convention).
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.match(
            r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]+?)\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        shape_str, kind = m.groups()
        total = sum(_shape_bytes(s) for s in
                    re.findall(r"\w+\[[\d,]*\](?:\{[\d,]*\})?", shape_str))
        out[kind] = out.get(kind, 0) + total
        out[f"{kind}_count"] = out.get(f"{kind}_count", 0) + 1
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             grad_sync: str = "lq", qcfg=None, seq_parallel=None,
             microbatch: int = 0, tag: str = "",
             kv_quant: bool = False) -> dict:
    cfg0 = registry.config(arch)
    if not SH.applicable(cfg0.family, shape_name):
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "skipped": True,
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step_fn, args, cfg, ctx = ST.build_cell(
        arch, shape_name, mesh, grad_sync=grad_sync, qcfg=qcfg,
        seq_parallel=seq_parallel, microbatch=microbatch) \
        if SH.SHAPES[shape_name].kind == "train" else ST.build_cell(
            arch, shape_name, mesh, kv_quant=kv_quant)
    lowered = step_fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    # flops/collectives from the PRE-optimization HLO (dots are still dots;
    # the CPU backend rewrites big matmuls into oneDNN custom-calls in the
    # post-opt text); HBM-traffic proxy from the POST-opt (fused) HLO.
    pre_txt = lowered.compiler_ir(dialect="hlo").as_hlo_text()
    pre = analyze(pre_txt)            # loop-trip-expanded (hlo_analysis.py)
    post_txt = compiled.as_text()
    post = analyze(post_txt)
    # overlap audit on the post-opt (scheduled) HLO: fraction of loop-
    # collective wire bytes whose result feeds same-iteration compute
    # (1.0 = fully serialized; the prefetched scan should sit well below)
    overlap = audit_overlap(post_txt)
    coll = pre.coll
    if os.environ.get("DRYRUN_SAVE_HLO"):
        import zstandard as zstd
        hdir = os.environ["DRYRUN_SAVE_HLO"]
        os.makedirs(hdir, exist_ok=True)
        nm = f"{arch}__{shape_name}__{'2pod' if multi_pod else '1pod'}"
        if tag:
            nm += f"__{tag}"
        with open(os.path.join(hdir, nm + ".hlo.zst"), "wb") as f:
            f.write(zstd.ZstdCompressor(level=6).compress(post_txt.encode()))

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "tag": tag, "grad_sync": grad_sync, "skipped": False,
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "flops_raw": float(cost.get("flops", 0.0)),
        "bytes_accessed_raw": float(cost.get("bytes accessed", 0.0)),
        "flops": pre.dot_flops,             # trip-expanded dot flops
        "traffic_bytes": post.traffic,      # trip-expanded HBM proxy (fused)
        "traffic_bytes_pre": pre.traffic,
        "collectives": coll,
        "collective_exposed_fraction": overlap.exposed_fraction,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
                          + getattr(mem, "argument_size_in_bytes", 0),
        },
        "params_B": cfg.param_count() / 1e9,
        "active_params_B": cfg.active_param_count() / 1e9,
        "seq_parallel": ctx.seq_parallel,
        "mesh": dict(mesh.shape),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--shapes", default="")
    ap.add_argument("--archs", default="")
    ap.add_argument("--grad-sync", default="lq")
    ap.add_argument("--q", type=int, default=16)
    ap.add_argument("--bucket", type=int, default=4096)
    ap.add_argument("--rotate", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    qcfg = QSyncConfig(q=args.q, bucket=args.bucket, rotate=args.rotate)
    sp = False if args.no_seq_parallel else None

    cells = []
    archs = (args.archs.split(",") if args.archs
             else ([args.arch] if args.arch else list(registry.ARCHS)))
    shape_list = (args.shapes.split(",") if args.shapes
                  else ([args.shape] if args.shape else list(SH.SHAPES)))
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        for a in archs:
            for s in shape_list:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    ok = fail = 0
    for arch, shape, mp in cells:
        name = f"{arch}__{shape}__{'2pod' if mp else '1pod'}"
        if args.tag:
            name += f"__{args.tag}"
        path = os.path.join(args.out, name + ".json")
        if os.path.exists(path):
            print(f"[dryrun] {name}: cached", flush=True)
            ok += 1
            continue
        print(f"[dryrun] {name}: lowering...", flush=True)
        try:
            rec = run_cell(arch, shape, mp, grad_sync=args.grad_sync,
                           qcfg=qcfg, seq_parallel=sp,
                           microbatch=args.microbatch, tag=args.tag,
                           kv_quant=args.kv_quant)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("skipped"):
                print(f"[dryrun] {name}: SKIP ({rec['reason']})", flush=True)
            else:
                print(f"[dryrun] {name}: OK flops={rec['flops']:.3e} "
                      f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                      f"coll={ {k: round(v/2**20, 1) for k, v in rec['collectives'].items() if not k.endswith('_count')} }MiB "
                      f"compile={rec['compile_s']}s", flush=True)
            ok += 1
        except Exception as e:
            fail += 1
            print(f"[dryrun] {name}: FAIL {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
            with open(path + ".fail", "w") as f:
                f.write(traceback.format_exc())
    print(f"[dryrun] done: {ok} ok, {fail} failed", flush=True)
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
