"""Step builders + input_specs for every (arch x shape x mesh) cell.

``input_specs``-style builders return ShapeDtypeStruct stand-ins (weak-type-
correct, shardable, no device allocation) for every input of the lowered
step — train batches, serve token batches, KV caches, parameter/optimizer
state trees — plus the jitted step function ready for
``jit(step).lower(*structs).compile()``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry, shapes as SH
from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx, storage_spec
from repro.models import transformer as T
from repro.models import encdec as ED
from repro.models import serve as SV
from repro.dist.collectives import QSyncConfig
from repro.train import optim as O
from repro.train import trainer as TR
from repro.launch.mesh import mesh_axes


def make_ctx(cfg: ModelConfig, mesh, *, grad_sync: str = "lq",
             qcfg: Optional[QSyncConfig] = None,
             seq_parallel: Optional[bool] = None) -> ShardCtx:
    dp_axes, tp_axis = mesh_axes(mesh)
    tp = mesh.shape[tp_axis]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    if seq_parallel is None:
        # SP everywhere except encoder-decoder (short decoder sequences)
        seq_parallel = cfg.family != "encdec" and tp > 1
    return ShardCtx(tp_axis=tp_axis, dp_axes=dp_axes, tp=tp, dp=dp,
                    qcfg=qcfg or QSyncConfig(), grad_sync=grad_sync,
                    seq_parallel=seq_parallel)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _is_meta(x):
    return hasattr(x, "local_shape")


def _dpa(ctx: ShardCtx):
    return ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]


def _arch_cfg(arch: str, smoke: bool) -> ModelConfig:
    return registry.smoke_config(arch) if smoke else registry.config(arch)


def _metas_shapes(cfg: ModelConfig, ctx: ShardCtx):
    if cfg.family == "encdec":
        return ED.encdec_metas(cfg, ctx), ED.encdec_param_shapes(cfg, ctx)
    return T.all_metas(cfg, ctx), T.param_shapes(cfg, ctx)


# ---------------------------------------------------------------------------
# train cell
# ---------------------------------------------------------------------------

def train_cell(arch: str, shape_name: str, mesh, *, grad_sync: str = "lq",
               qcfg: Optional[QSyncConfig] = None, microbatch: int = 0,
               seq_parallel: Optional[bool] = None, smoke: bool = False):
    """Returns (jitted_step, arg_structs, cfg, ctx)."""
    cfg = _arch_cfg(arch, smoke)
    sh = SH.SHAPES[shape_name]
    assert sh.kind == "train"
    ctx = make_ctx(cfg, mesh, grad_sync=grad_sync, qcfg=qcfg,
                   seq_parallel=seq_parallel)
    ov = registry.train_overrides(arch)
    opt_cfg = O.OptConfig(name=ov.get("opt_name", "adamw"),
                          state_dtype=ov.get("opt_state_dtype", "float32"))
    mb = microbatch or ov.get("microbatch", 0)
    tc = TR.TrainConfig(microbatch=0 if smoke else mb)

    if cfg.family == "encdec":
        step_fn = _make_encdec_train_step(cfg, ctx, mesh, opt_cfg, tc)
    else:
        step_fn, _, _ = TR.make_train_step(cfg, ctx, mesh, opt_cfg, tc)

    metas, pshapes = _metas_shapes(cfg, ctx)
    dt = jnp.dtype(opt_cfg.state_dtype)
    mom = jax.tree.map(lambda s: _sds(s.shape, dt), pshapes)
    opt = {"m": mom, "v": mom} if opt_cfg.name == "adamw" else {"m": mom}
    if cfg.family == "encdec":
        y = jax.eval_shape(lambda: ED.encdec_y_init(cfg, ctx))
    else:
        y = jax.eval_shape(lambda: T.y_init(cfg, ctx))
    state = {"params": pshapes, "opt": opt, "y": y,
             "step": _sds((), jnp.int32), "key": _sds((2,), jnp.uint32)}

    B = sh.global_batch if not smoke else min(sh.global_batch, 8)
    S = sh.seq_len if not smoke else 64
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "targets": _sds((B, S), jnp.int32),
        "mask": _sds((B, S), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["img"] = _sds((B, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return step_fn, (state, batch), cfg, ctx


def _make_encdec_train_step(cfg, ctx, mesh, opt_cfg, tc):
    metas = ED.encdec_metas(cfg, ctx)
    loss_fn = ED.make_encdec_loss_fn(cfg, ctx)
    pspec = jax.tree.map(lambda m: storage_spec(m, ctx), metas, is_leaf=_is_meta)
    opt_spec = ({"m": pspec, "v": pspec} if opt_cfg.name == "adamw"
                else {"m": pspec})
    state_spec = {"params": pspec, "opt": opt_spec, "y": P(), "step": P(),
                  "key": P()}
    dpa = _dpa(ctx)

    def per_device(state, batch):
        params, opt, y, step, key = (state["params"], state["opt"], state["y"],
                                     state["step"], state["key"])
        kstep = jax.random.fold_in(key, step)
        tele0 = ED.encdec_tele_zeros(cfg, ctx)
        (l, metrics), (gp, gt) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(params, tele0, batch,
                                                   kstep, y)
        sq = jnp.zeros((), jnp.float32)
        for grp in gp:
            for name, g in gp[grp].items():
                s = jnp.sum(g.astype(jnp.float32) ** 2)
                for ax in ctx.dp_axes:
                    s = jax.lax.psum(s, ax)
                if not metas[grp][name].tp_replicated and ctx.tp > 1:
                    s = jax.lax.psum(s, ctx.tp_axis)
                sq = sq + s
        gnorm = jnp.sqrt(sq)
        params2, opt2 = O.apply_update(params, gp, opt, step, opt_cfg, gnorm)
        y2 = jax.tree.map(lambda yy, tt: TR._y_update(yy, tt, tc), y, gt)
        loss_rep = metrics["loss"]
        for ax in ctx.dp_axes:
            loss_rep = jax.lax.psum(loss_rep, ax)
        new_state = {"params": params2, "opt": opt2, "y": y2,
                     "step": step + 1, "key": key}
        return new_state, {"loss": loss_rep / ctx.dp, "gnorm": gnorm}

    def step_fn(state, batch):
        bspec = {k: P(dpa) for k in batch}
        f = jax.shard_map(per_device, mesh=mesh,
                          in_specs=(state_spec, bspec),
                          out_specs=(state_spec, P()), check_vma=False)
        return f(state, batch)

    return jax.jit(step_fn)


# ---------------------------------------------------------------------------
# serve cells
# ---------------------------------------------------------------------------

def _cache_global(cfg, ctx, cstruct, B_global, replicate_batch):
    # dtype per leaf from SV.cache_dtype (int8 k/v when quantized; the
    # kv_quant flag is implied by the presence of *_scale leaves)
    """Local cache shapes -> global structs (+specs): leading tp axis,
    batch dim sharded over dp unless replicated."""
    dpa = _dpa(ctx)
    structs, specs = {}, {}
    quant = "k_scale" in cstruct
    for k, s in cstruct.items():
        bpos = 0 if k.startswith("tail") else 1   # (L, B, ...) vs (B, ...)
        gs = list(s)
        if not replicate_batch:
            gs[bpos] = B_global
        structs[k] = _sds((ctx.tp, *gs), SV.cache_dtype(k, quant))
        spec = [None] * (len(gs) + 1)
        spec[0] = ctx.tp_axis
        if not replicate_batch:
            spec[bpos + 1] = dpa
        specs[k] = P(*spec)
    return structs, specs


def decode_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False,
                kv_quant: bool = False):
    """serve_step: one new token against a seq_len-deep cache."""
    cfg = _arch_cfg(arch, smoke)
    sh = SH.SHAPES[shape_name]
    assert sh.kind in ("decode", "long_decode")
    if not SH.applicable(cfg.family, shape_name):
        raise ValueError(f"{arch} skips {shape_name} (full attention)")
    if kv_quant and cfg.family in ("ssm", "hybrid", "encdec"):
        kv_quant = False                 # no full-context KV cache to quantize
    ctx = make_ctx(cfg, mesh, seq_parallel=False)
    dpa = _dpa(ctx)

    B = sh.global_batch if not smoke else min(sh.global_batch, 4)
    S = sh.seq_len if not smoke else 64
    replicate_batch = B < ctx.dp
    B_loc = B if replicate_batch else B // ctx.dp

    step = SV.make_serve_step(cfg, ctx, kv_quant=kv_quant)
    cstruct = SV.cache_struct(cfg, ctx, B_loc, S, kv_quant=kv_quant)
    cache_structs, cache_specs = _cache_global(cfg, ctx, cstruct, B,
                                               replicate_batch)
    bspec = P(None) if replicate_batch else P(dpa)

    def serve(params, cache, tokens, pos, key):
        cache = jax.tree.map(lambda v: v[0], cache)      # strip tp lead axis
        nxt, nc = step(params, cache, tokens, pos, key)
        return nxt, jax.tree.map(lambda v: v[None], nc)

    metas, pshapes = _metas_shapes(cfg, ctx)
    pshapes = jax.tree.map(lambda s: _sds(s.shape, jnp.bfloat16), pshapes)
    pspec = jax.tree.map(lambda m: storage_spec(m, ctx), metas, is_leaf=_is_meta)

    def step_fn(params, cache, tokens, pos, key):
        f = jax.shard_map(serve, mesh=mesh,
                          in_specs=(pspec, cache_specs, bspec, P(), P()),
                          out_specs=(bspec, cache_specs), check_vma=False)
        return f(params, cache, tokens, pos, key)

    args = (pshapes, cache_structs, _sds((B, 1), jnp.int32),
            _sds((), jnp.int32), _sds((2,), jnp.uint32))
    return jax.jit(step_fn), args, cfg, ctx


def prefill_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False):
    cfg = _arch_cfg(arch, smoke)
    sh = SH.SHAPES[shape_name]
    assert sh.kind == "prefill"
    ctx = make_ctx(cfg, mesh, seq_parallel=False)
    dpa = _dpa(ctx)
    B = sh.global_batch if not smoke else 4
    S = sh.seq_len if not smoke else 64
    replicate_batch = B < ctx.dp
    bspec = P(None) if replicate_batch else P(dpa)

    metas, pshapes = _metas_shapes(cfg, ctx)
    pshapes = jax.tree.map(lambda s: _sds(s.shape, jnp.bfloat16), pshapes)
    pspec = jax.tree.map(lambda m: storage_spec(m, ctx), metas, is_leaf=_is_meta)

    if cfg.family == "encdec":
        pf = SV.make_encdec_prefill(cfg, ctx)

        def prefill(params, frames, tokens, key):
            last, cache = pf(params, frames, tokens, key)
            return last, jax.tree.map(lambda v: v[None], cache)

        def step_fn(params, frames, tokens, key):
            f = jax.shard_map(
                prefill, mesh=mesh,
                in_specs=(pspec, bspec, bspec, P()),
                out_specs=(bspec, P(ctx.tp_axis)),   # prefix spec: all leaves
                check_vma=False)
            return f(params, frames, tokens, key)

        args = (pshapes, _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32),
                _sds((B, S), jnp.int32), _sds((2,), jnp.uint32))
        return jax.jit(step_fn), args, cfg, ctx

    pf = SV.make_prefill(cfg, ctx)
    is_vlm = cfg.family == "vlm"

    def prefill(params, tokens, key, img=None):
        last, cache = pf(params, tokens, key, img) if is_vlm else pf(
            params, tokens, key)
        return last, jax.tree.map(lambda v: v[None], cache)

    def step_fn(params, tokens, key, img=None):
        in_specs = [pspec, bspec, P()]
        if is_vlm:
            in_specs.append(bspec)
        f = jax.shard_map(prefill, mesh=mesh, in_specs=tuple(in_specs),
                          out_specs=(bspec, P(ctx.tp_axis)),
                          check_vma=False)
        return f(params, tokens, key, img) if is_vlm else f(params, tokens, key)

    if is_vlm:
        args = (pshapes, _sds((B, S - cfg.img_tokens), jnp.int32),
                _sds((2,), jnp.uint32),
                _sds((B, cfg.img_tokens, cfg.d_model), jnp.float32))
    else:
        args = (pshapes, _sds((B, S), jnp.int32), _sds((2,), jnp.uint32))
    return jax.jit(step_fn), args, cfg, ctx


def build_cell(arch: str, shape_name: str, mesh, **kw):
    kind = SH.SHAPES[shape_name].kind
    if kind == "train":
        return train_cell(arch, shape_name, mesh, **kw)
    if kind == "prefill":
        return prefill_cell(arch, shape_name, mesh,
                            smoke=kw.get("smoke", False))
    return decode_cell(arch, shape_name, mesh, smoke=kw.get("smoke", False),
                       kv_quant=kw.get("kv_quant", False))
