"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required by the dry-run contract).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / smoke / single host)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axes(mesh) -> tuple[tuple[str, ...], str]:
    """(dp_axes, tp_axis) for a mesh built by make_production_mesh/make_mesh."""
    names = tuple(mesh.axis_names)
    assert names[-1] == "model", names
    return names[:-1], "model"
