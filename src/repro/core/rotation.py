"""Structured random rotation HD (paper §6, RLQSGD).

H is the normalized Walsh-Hadamard matrix, D a random ±1 diagonal generated
from shared randomness.  ``rotate(x) = H @ (D * x)``; the inverse is
``D * (H @ x)`` since H^-1 = H and D^-1 = D.

For non-power-of-two d we pad with zeros to the next power of two (standard
practice; unbiasedness and the ℓ∞/ℓ2 bound of Lemma 24 are preserved on the
embedded subspace).

The O(d log d) transform is implemented three ways:
  * ``fwht_jnp``: pure-jnp reference (oracle for the Pallas kernel);
  * ``repro.kernels.ops.fwht``: Pallas TPU kernel (VMEM-tiled butterflies);
  * ``rotate(..., use_kernel=True)`` dispatches to the kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n - 1).bit_length())


def fwht_jnp(x: Array) -> Array:
    """Normalized fast Walsh-Hadamard transform over the last axis.

    Last axis length must be a power of two.  O(d log d) adds; orthonormal
    (preserves l2 norm), involutive.
    """
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"fwht needs power-of-two dim, got {d}"
    orig_dtype = x.dtype
    v = x.astype(jnp.float32)
    h = 1
    while h < d:
        v = v.reshape(x.shape[:-1] + (d // (2 * h), 2, h))
        a = v[..., 0, :]
        b = v[..., 1, :]
        v = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    v = v.reshape(x.shape[:-1] + (d,)) * jnp.float32(1.0 / np.sqrt(d))
    return v.astype(orig_dtype)


def rademacher_diag(key: Array, d: int) -> Array:
    """Shared-randomness ±1 diagonal D (costs d bits to agree on; paper §6)."""
    return jax.random.rademacher(key, (d,), jnp.float32)


def _fwht(x: Array, use_kernel: bool) -> Array:
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.fwht(x)
    return fwht_jnp(x)


def rotate(x: Array, diag: Array, *, use_kernel: bool = False) -> Array:
    """Apply HD to the last axis (zero-padding to a power of two)."""
    d = x.shape[-1]
    dp = next_pow2(d)
    v = x.astype(jnp.float32) * diag[:d]
    if dp != d:
        v = jnp.pad(v, [(0, 0)] * (x.ndim - 1) + [(0, dp - d)])
    return _fwht(v, use_kernel)


def unrotate(x: Array, diag: Array, d: int, *, use_kernel: bool = False) -> Array:
    """Apply (HD)^-1 = D H; returns the first d coordinates."""
    v = _fwht(x, use_kernel)
    return v[..., :d] * diag[:d]


def rotation_keypair(key: Array, d: int) -> Array:
    """Generate the diagonal once per run (shared across machines)."""
    return rademacher_diag(key, next_pow2(d))


def rotated_coord_bound(l2, d: int, beta: float = 1e-3) -> float:
    """Paper §6 (Lemma 24) rotated-space coordinate bound.

    With probability >= 1 - beta over the shared HD rotation,

        |HD x|_inf  <=  ||x||_2 * sqrt(2 * ln(2d / beta) / d)

    — the ℓ2/√d bound (up to the log factor) that makes the cubic-lattice
    scheme's per-coordinate distance bound depend on the *Euclidean*
    distance between inputs rather than their coordinate-wise worst case.
    Used to seed the trainer's per-leaf ``y`` state when rotation is on.
    """
    return float(l2) * float(np.sqrt(2.0 * np.log(2.0 * d / beta) / d))
