"""Paper algorithms: MeanEstimation / VarianceReduction (§4).

These are the *faithful* reference implementations over a stacked input
``xs: (n, d)`` — n machines' vectors — used by tests and by the paper-table
benchmarks.  The production path (quantized collectives inside shard_map)
lives in repro/dist and is validated against these.

Algorithm 3 (star):   random leader gathers colors, decodes against its own
input, averages, re-broadcasts quantized; everyone decodes against their own
input.

Algorithm 4 (tree):   sample T = min(m, n) machines; binary tree over them;
average + re-quantize with Q_{y/m^2, m^3} at every internal node; broadcast.

VarianceReduction reduces to MeanEstimation with y = 2*sigma*sqrt(alpha*n)
(Theorem 17).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressors import Compressor, CompressorCtx, LatticeQ

Array = jax.Array


@dataclasses.dataclass
class DMEResult:
    est: Array                 # (n, d) per-machine outputs (identical on success)
    bits_per_machine: Array    # (n,) wire bits sent by each machine
    decode_ok: Array           # scalar bool: all decodes consistent


def _keys(key: Array, n: int):
    return jax.random.split(key, n)


def mean_estimation_star(xs: Array, y, comp: Compressor, key: Array,
                         ctx: Optional[CompressorCtx] = None,
                         leader: Optional[int] = None) -> DMEResult:
    """Paper Algorithm 3 on inputs xs: (n, d)."""
    n, d = xs.shape
    ctx = dataclasses.replace(ctx or CompressorCtx(), y=y)
    kl, kb, *ks = _keys(key, n + 2)
    if leader is None:
        leader = int(jax.random.randint(kl, (), 0, n))
    x_leader = xs[leader]

    # Phase 1: everyone -> leader; leader decodes against its own input.
    decoded = []
    for v in range(n):
        payload = comp.encode(xs[v], ctx, ks[v])
        decoded.append(comp.decode(payload, x_leader, ctx))
    mu_hat = jnp.mean(jnp.stack(decoded), axis=0)

    # Phase 2: leader -> everyone; each decodes against its own input.
    payload = comp.encode(mu_hat, ctx, kb)
    outs = jnp.stack([comp.decode(payload, xs[v], ctx) for v in range(n)])

    per_machine = comp.wire_bytes(d) * 8
    bits = jnp.full((n,), per_machine, jnp.int32)
    # Leader additionally broadcasts (n-1 sends in a naive star; a broadcast
    # tree makes it O(1) per machine — we report the per-machine payload).
    ok = jnp.all(jnp.abs(outs - outs[0]) <= 1e-6 * (1.0 + jnp.abs(outs[0])))
    return DMEResult(outs, bits, ok)


def mean_estimation_tree(xs: Array, y, m: int, key: Array,
                         q_override: Optional[int] = None,
                         ctx: Optional[CompressorCtx] = None) -> DMEResult:
    """Paper Algorithm 4: binary-tree aggregation with Q_{y/m^2, m^3}.

    For practicality q = m^3 is capped at 2^16 colors per coordinate (the
    paper's asymptotic statement allows any q = Omega(1); the cap only
    affects constants).
    """
    n, d = xs.shape
    t = min(m, n)
    # power-of-two leaf count (paper: "we may assume it is a power of 2")
    t = 1 << int(np.floor(np.log2(max(t, 1))))
    kperm, key = jax.random.split(key)
    perm = jax.random.permutation(kperm, n)[:t]
    leaves = xs[perm]

    # Paper: Q_{y/m^2, m^3} — lattice granularity eps = y/m^2, q = m^3 colors.
    # On the cubic lattice (side s = 2*y/(q-1), decode margin (q-1)s/2 = y)
    # q = m^3 already gives per-hop error s/2 = y/(m^3-1) <= paper's 7y/m^2
    # while the decode margin stays the full distance bound y.
    q = q_override or min(int(m) ** 3, 1 << 16)
    comp = LatticeQ(q=q)
    ctx = dataclasses.replace(ctx or CompressorCtx(), y=y)

    bits_total = np.zeros((n,), np.int64)
    level = leaves
    depth = 0
    while level.shape[0] > 1:
        key, *ks = _keys(key, level.shape[0] // 2 + 1)
        nxt = []
        for i in range(level.shape[0] // 2):
            a, b = level[2 * i], level[2 * i + 1]
            payload = comp.encode(a, ctx, ks[i])
            a_dec = comp.decode(payload, b, ctx)   # child a -> parent (anchored at b)
            nxt.append((a_dec + b) * 0.5)
        level = jnp.stack(nxt)
        depth += 1
    root = level[0]

    key, kb = jax.random.split(key)
    payload = comp.encode(root, ctx, kb)
    outs = jnp.stack([comp.decode(payload, xs[v], ctx) for v in range(n)])
    per_machine = comp.wire_bytes(d) * 8
    bits = jnp.full((n,), per_machine, jnp.int32)
    ok = jnp.all(jnp.abs(outs - outs[0]) <= 1e-6 * (1.0 + jnp.abs(outs[0])))
    return DMEResult(outs, bits, ok)


def variance_reduction(xs: Array, sigma: float, comp: Compressor, key: Array,
                       alpha: float = 4.0,
                       ctx: Optional[CompressorCtx] = None,
                       topology: str = "star") -> DMEResult:
    """Theorem 17 reduction: VR via ME with y = 2*sigma*sqrt(alpha*n)."""
    n = xs.shape[0]
    y = 2.0 * sigma * float(np.sqrt(alpha * n))
    if topology == "star":
        return mean_estimation_star(xs, y, comp, key, ctx)
    return mean_estimation_tree(xs, y, m=n, key=key, ctx=ctx)


def butterfly_mean(xs: Array, y, comp: Compressor, key: Array,
                   ctx: Optional[CompressorCtx] = None) -> DMEResult:
    """TPU-native analogue of the tree (DESIGN §2): recursive doubling.

    log2(n) rounds; in round k, machine i exchanges quantized estimates with
    machine i XOR 2^k and averages.  Error accumulates O(eps log n) like the
    paper's tree; per-machine bits are log2(n) * d * log2(q) — the price of
    every machine learning the mean with no broadcast phase.

    Reference implementation of dist/collectives.py:quantized_butterfly.
    """
    n, d = xs.shape
    assert n & (n - 1) == 0, "butterfly needs power-of-two n"
    cur = xs
    rounds = int(np.log2(n))
    bits = 0
    for r in range(rounds):
        # Shared-randomness dither (paper §9.1): encode is *deterministic*
        # given (x, u), so machines holding equal values produce identical
        # lattice points — after log n rounds all outputs are bitwise equal
        # (the paper's common-output requirement), with unbiasedness coming
        # from the shared offset u.
        key, ku = jax.random.split(key)
        from repro.core.lattice import shared_offset
        u = shared_offset(ku, (d,))
        rctx = dataclasses.replace(ctx or CompressorCtx(), y=y, u=u)
        stride = 1 << r
        payloads = [comp.encode(cur[i], rctx) for i in range(n)]
        nxt = []
        for i in range(n):
            j = i ^ stride
            zii = comp.decode(payloads[i], cur[i], rctx)   # own lattice point
            zij = comp.decode(payloads[j], cur[i], rctx)   # partner's
            nxt.append((zii + zij) * 0.5)
        cur = jnp.stack(nxt)
        bits += comp.wire_bytes(d) * 8
        # distances shrink every round; a production impl may shrink y too.
    outs = cur
    ok = jnp.all(jnp.abs(outs - outs[0]) <= 1e-5 * (1.0 + jnp.abs(outs[0])))
    return DMEResult(outs, jnp.full((n,), bits, jnp.int32), ok)
