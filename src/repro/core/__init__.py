"""Core library: the paper's lattice quantization + DME/VR algorithms."""
from repro.core.lattice import (LatticeSpec, lattice_encode, lattice_decode,
                                pack_colors, unpack_colors, bits_for_q,
                                shared_offset, wire_bytes)
from repro.core.compressors import (Compressor, CompressorCtx, LatticeQ,
                                    RotatedLatticeQ, QSGD, HadamardUniform,
                                    TernGrad, EFSign, TopK, PowerSGDLike, FP32,
                                    make_compressor, ef_roundtrip,
                                    ALL_COMPRESSORS)
from repro.core.dme import (mean_estimation_star, mean_estimation_tree,
                            variance_reduction, butterfly_mean, DMEResult)
from repro.core import rotation
from repro.core import error_detect
from repro.core import sublinear
from repro.core import bucketing
from repro.core import qstate
from repro.core import wire_accounting
from repro.core.qstate import QState
