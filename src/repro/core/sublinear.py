"""Sublinear-communication quantization (paper §7).

Two pieces:

1. ``SublinearLattice`` — an *exact* small-d implementation of Algorithms 7/8
   on the cubic lattice: random offset theta ~ U(Vor(0)) = U[-s/2,s/2)^d,
   nearest-point rounding, random coloring with ``n_colors = (1+2q)^{3d}``
   realized as a shared-randomness hash over lattice coordinates, and the
   rejection loop ("successful coloring") with a fixed iteration budget.
   Decoding searches the lattice points whose Voronoi regions intersect
   B_{q eps}(x_v + theta) — exhaustive over the +-1 coordinate neighborhood,
   hence small-d only.  Used by tests to certify unbiasedness + the error
   bound; the paper itself states a naive implementation is infeasible in
   high d (§9.2 Exp 4).

2. ``simulated_variance`` — the paper's Experiment-4 protocol: for a bit
   budget b = d*log2(1+4y/s), the coordinate-wise dither gives variance
   d*s^2/12; used by benchmarks/bench_sublinear.py to reproduce Figures 7-8.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


_M64 = (1 << 64) - 1


def _hash_color(k: np.ndarray, seed: int, n_colors: int) -> int:
    """Deterministic shared-randomness coloring of an integer lattice point."""
    h = (seed * 0x9E3779B97F4A7C15 + 0xDA3E39CB94B95BDB) & _M64
    for v in k.astype(np.int64).tolist():
        h = ((h ^ ((v * 0xBF58476D1CE4E5B9) & _M64)) * 0x94D049BB133111EB) & _M64
    return int(h % n_colors)


@dataclasses.dataclass(frozen=True)
class SublinearLattice:
    """Exact cubic-lattice instance of paper Algorithms 7/8 (small d)."""
    s: float                  # lattice side (2*eps with eps = packing radius)
    q: float                  # decode radius parameter (ball radius q*eps)
    d: int
    max_iters: int = 64

    @property
    def eps(self) -> float:
        return self.s / 2.0

    @property
    def n_colors(self) -> int:
        # (1 + 2q)^{3d} capped for practicality
        return int(min(float(1 + 2 * self.q) ** (3 * self.d), 2 ** 62))

    def bits(self) -> float:
        return 3 * self.d * float(np.log2(1 + 2 * self.q))

    # -- encode -------------------------------------------------------------
    def encode(self, x: np.ndarray, rng: np.random.Generator):
        """Returns (color, i, theta_seed) and diagnostics."""
        for i in range(self.max_iters):
            theta = rng.uniform(-self.s / 2, self.s / 2, self.d)
            z = np.round((x + theta) / self.s).astype(np.int64)
            seed = int(rng.integers(0, 2 ** 31))
            col = _hash_color(z, seed, self.n_colors)
            # success check: no other lattice point z' with x+theta in
            # Vor+(z') shares the color.  Vor+(z') within l2 distance
            # (sqrt(d)/2 + 2q) * s of z' — enumerate the integer box.
            if self._color_unique(x + theta, z, col, seed):
                return {"color": col, "iter": i, "seed": seed,
                        "theta": theta, "z": z}
        raise RuntimeError("sublinear encode: iteration budget exhausted")

    def _neighbors(self, center: np.ndarray, radius_cells: int):
        rngs = [range(int(c) - radius_cells, int(c) + radius_cells + 1)
                for c in center]
        return itertools.product(*rngs)

    def _color_unique(self, point: np.ndarray, z: np.ndarray, col: int,
                      seed: int) -> bool:
        # expanded Voronoi region of z' contains `point` iff
        # dist_inf(point, Vor(z')) small; for the cubic lattice
        # Vor(z') = z'*s + [-s/2, s/2)^d, expansion by 2*q*eps = q*s in l2.
        rad = int(np.ceil(0.5 + self.q))
        kc = np.round(point / self.s).astype(np.int64)
        for cand in self._neighbors(kc, rad):
            kz = np.array(cand, np.int64)
            if np.array_equal(kz, z):
                continue
            # l2 distance from point to the Voronoi cell of kz
            delta = np.abs(point - kz * self.s) - self.s / 2
            dist = np.linalg.norm(np.clip(delta, 0, None))
            if dist <= 2 * self.q * self.eps and \
                    _hash_color(kz, seed, self.n_colors) == col:
                return False
        return True

    # -- decode -------------------------------------------------------------
    def decode(self, payload, x_v: np.ndarray) -> np.ndarray:
        theta, seed, col = payload["theta"], payload["seed"], payload["color"]
        target = x_v + theta
        rad = int(np.ceil(0.5 + self.q))
        kc = np.round(target / self.s).astype(np.int64)
        best = None
        for cand in self._neighbors(kc, rad):
            kz = np.array(cand, np.int64)
            delta = np.abs(target - kz * self.s) - self.s / 2
            dist = np.linalg.norm(np.clip(delta, 0, None))
            if dist <= self.q * self.eps and \
                    _hash_color(kz, seed, self.n_colors) == col:
                if best is not None and not np.array_equal(best, kz):
                    raise RuntimeError("ambiguous decode (coloring failed)")
                best = kz
        if best is None:
            raise RuntimeError("decode failed: no matching color in range")
        return best * self.s - theta


def simulated_variance(d: int, y: float, bits_per_coord: float) -> float:
    """Paper Exp. 4: variance of the sublinear scheme at a given bit budget.

    bits = d*log2(1 + 4y/s)  =>  s = 4y / (2^{bits/d} - 1); dither variance
    d * s^2 / 12 (uniform over [-s/2, s/2] per coordinate).
    """
    s = 4.0 * y / (2.0 ** bits_per_coord - 1.0)
    return d * s * s / 12.0


def vqsgd_cross_polytope_variance(d: int, norm: float, reps: int) -> float:
    """vQSGD [Gandikota+] cross-polytope baseline variance (Exp 4 comparison).

    Cross-polytope quantization maps x to one of 2d scaled basis vectors
    +-sqrt(d)*||x||*e_i; with R independent repetitions averaged, the
    variance is (d*||x||^2 - ||x||^2)/R <= d*||x||^2/R, at R*ceil(log2 2d)
    bits.  We report the standard upper bound.
    """
    return d * norm * norm / max(reps, 1)
