"""The one wire-byte arithmetic for the whole repo.

Every layer that moves (or accounts for) the paper's packed lattice payload
used to carry its own copy of the byte math: the shard_map collectives
(``dist/collectives._payload_bytes`` and the per-topology ``wire_bytes_*``),
the FSDP gradient sync (``dist/fsdp.wire_bytes_bwd``), and the aggregation
protocol's header constants (``agg/transport/frame``).  This module is the
single definition they all delegate to; the tests cross-check it against the
``len()`` of actual payload bytes and the actual collective transfer shapes.

Three vocabularies, one body format:

* **body bytes** — the packed payload itself: ``ceil(n/per)`` uint32 words of
  ``bits``-bit mod-q colors (``per = 32 // bits`` colors per word, see
  :func:`repro.core.lattice.packed_len`) plus one f32 lattice side per
  bucket (the sides sidecar).  The unpacked debugging path moves raw uint32
  color buffers instead (4 bytes/coordinate, no sidecar).
* **collective bytes** — bytes *sent per rank* by a topology: recursive
  doubling (butterfly) sends ``log2(world)`` full payloads, the ring
  all-gather forwards ``world - 1`` payloads, recursive halving sends a
  halving sequence of segment payloads, and the fp32 ring reduce-scatter
  moves ``(world-1)/world`` of the segment per axis.
* **framed bytes** — the aggregation service's on-the-wire cost: each
  transport frame (``agg/transport/frame``) prepends a fixed
  :data:`FRAME_HEADER_BYTES` header, and a body larger than the round's MTU
  is split into :func:`n_chunks` independently-framed chunks (the chunk
  layer), so one client payload costs ``n_chunks * FRAME_HEADER_BYTES +
  body`` bytes.
"""
from __future__ import annotations

import numpy as np

from repro.core import lattice as L

# one f32 lattice side per bucket rides along with the packed words
SIDE_BYTES = 4
WORD_BYTES = 4

# agg transport frame layout (v5; unchanged since v4), see
# repro.agg.transport.frame:
#   magic 4s | version u16 | flags u16 | 16 x u32 fields | crc u32
# The frame module asserts its struct sizes against these at import time —
# the constants live here so the header math is auditable next to the body
# math it frames.
FRAME_FIXED_FIELDS = 16
FRAME_HEADER_BYTES = 4 + 2 + 2 + 4 * FRAME_FIXED_FIELDS + 4        # 76
# response head: magic 4s | version u16 | status u16 | 4 x u32 | f32 | 2 x u32
# | ack u32 | credit u32 (the v5 additive flow-control fields: cumulative
# contiguous-chunk ack + send-window credit)
RESPONSE_HEAD_BYTES = 4 + 2 + 2 + 4 * 4 + 4 + 4 * 2 + 4 * 2        # 44
RESPONSE_CRC_BYTES = 4


# ---------------------------------------------------------------------------
# Body bytes (one full-vector message, no framing)
# ---------------------------------------------------------------------------

def packed_words_bytes(n: int, bits: int) -> int:
    """Bytes of the packed color words for n coordinates at ``bits`` each."""
    return WORD_BYTES * L.packed_len(n, bits)


def sides_bytes(nb: int) -> int:
    """Bytes of the f32 sides sidecar for ``nb`` buckets."""
    return SIDE_BYTES * nb


def packed_body_bytes(padded: int, bits: int, nb: int) -> int:
    """Packed words + sides sidecar: the payload body every layer moves."""
    return packed_words_bytes(padded, bits) + sides_bytes(nb)


def unpacked_body_bytes(padded: int) -> int:
    """The jnp fallback's raw uint32 color buffer (no sidecar)."""
    return 4 * padded


def collective_payload_bytes(padded: int, bits: int, nb: int,
                             packed: bool = True) -> int:
    """One full-vector collective message (packed or the unpacked oracle)."""
    if not packed:
        return unpacked_body_bytes(padded)
    return packed_body_bytes(padded, bits, nb)


# ---------------------------------------------------------------------------
# Collective bytes (per-topology, bytes sent per rank)
# ---------------------------------------------------------------------------

def _log2_rounds(world: int) -> int:
    return max(int(np.log2(world)), 0) if world > 1 else 0


def butterfly_bytes(padded: int, bits: int, nb: int, world: int,
                    packed: bool = True) -> int:
    """Recursive doubling: log2(world) rounds, one full payload each."""
    return _log2_rounds(world) * collective_payload_bytes(padded, bits, nb,
                                                          packed)


def allgather_bytes(padded: int, bits: int, nb: int, world: int,
                    packed: bool = True) -> int:
    """Ring all-gather of every rank's payload: world-1 forwarded chunks."""
    return max(world - 1, 0) * collective_payload_bytes(padded, bits, nb,
                                                        packed)


def rh_bytes(padded: int, bits: int, nb: int, world: int,
             packed: bool = True) -> int:
    """Recursive halving: round r sends the (padded/2^{r+1})-coordinate half
    of the working segment (packed: its words + its share of the sides
    sidecar; unpacked: the raw color buffer); the payload halves every
    round, summing to ~one full payload."""
    total = 0
    for r in range(_log2_rounds(world)):
        seg, seg_nb = padded >> (r + 1), nb >> (r + 1)
        total += collective_payload_bytes(seg, bits, seg_nb, packed)
    return total


def fp32_ring_reduce_scatter_bytes(seg: int, world: int) -> int:
    """Ring psum_scatter of an f32 segment: (world-1)/world of it moves."""
    return 4 * (seg - seg // world)


def anchor_gather_bytes(m: int, world: int) -> int:
    """Per-rank wire bytes of rebuilding a *sharded* anchor by tiled f32
    ring all-gather: (world-1)/world of the (m,) vector.  This rides the
    FSDP forward weight-gather slot (dist/fsdp.py), so it overlaps compute
    under prefetch rather than serializing the backward sync."""
    w = max(world, 1)
    return 4 * (m - m // w)


def anchor_state_bytes(m: int, world: int, sharded: bool) -> int:
    """Per-rank bytes of next-step anchor state one anchored gradient sync
    materializes *beyond the rank's own ZeRO-3 shard* of the (m,) mean.

    Legacy replicated anchors write the full f32 vector into every rank's
    telemetry — ``4 * (m - m/world)`` bytes beyond the shard the rank
    would keep anyway.  Sharded anchors (``FSDPConfig.anchor_sharded``)
    write only the rank's own ``(m/world,)`` slice: zero extra.  Either
    way the backward *wire* cost is unchanged (``fsdp.wire_bytes_bwd``) —
    the butterfly's common output doubles as the anchor, and the sharded
    anchor's rebuild is :func:`anchor_gather_bytes` on the forward."""
    if sharded:
        return 0
    w = max(world, 1)
    return 4 * (m - m // w)


# ---------------------------------------------------------------------------
# Framed bytes (the agg transport stack: frame + chunk layers)
# ---------------------------------------------------------------------------

def n_chunks(body_len: int, mtu: int) -> int:
    """Chunk count for a body under an MTU (0 = unchunked single frame)."""
    if mtu <= 0 or body_len <= mtu:
        return 1
    return -(-body_len // mtu)


def chunk_span(body_len: int, mtu: int, index: int) -> "tuple[int, int]":
    """(offset, length) of chunk ``index`` in the body.  Every chunk except
    the last carries exactly ``mtu`` bytes, so a receiver can place any
    chunk at ``index * mtu`` without seeing the others first."""
    nc = n_chunks(body_len, mtu)
    if not 0 <= index < nc:
        raise ValueError(f"chunk {index} out of range for {nc} chunks")
    if nc == 1:
        return 0, body_len
    off = index * mtu
    return off, min(mtu, body_len - off)


def frame_bytes(chunk_len: int) -> int:
    """On-the-wire size of one transport frame carrying ``chunk_len`` body
    bytes (fixed v4 header + per-frame CRC included in the header size)."""
    return FRAME_HEADER_BYTES + chunk_len


def framed_payload_bytes(body_len: int, mtu: int) -> int:
    """Total wire bytes to deliver one payload body under an MTU: every
    chunk repeats the self-describing frame header."""
    return n_chunks(body_len, mtu) * FRAME_HEADER_BYTES + body_len


def chunk_overhead_pct(body_len: int, mtu: int) -> float:
    """Extra header bytes of chunking as a percentage of the single-frame
    wire size (0.0 when the body fits one frame)."""
    single = frame_bytes(body_len)
    return 100.0 * (framed_payload_bytes(body_len, mtu) - single) / single


def agg_payload_bytes(padded: int, bits: int, nb: int, mtu: int = 0) -> int:
    """Exact wire bytes of one aggregation-protocol client payload: the
    packed body framed (and, under an MTU, chunked) by the transport."""
    return framed_payload_bytes(packed_body_bytes(padded, bits, nb), mtu)
