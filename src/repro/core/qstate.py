"""Anchored quantization state (the paper's distance-dependent regime).

The paper's headline bound says DME error need only depend on the *distance*
between encoder and decoder inputs, never their norm.  Decoding against a
nearby anchor already realizes the distance dependence; what breaks in the
drifting large-norm regime (mean ``mu`` advancing each round with
``|mu| >> spread``) is the *arithmetic*: raw-space lattice coordinates
``k = round(x/s - u)`` grow like ``|x|/s``, blowing past f32's 24-bit
mantissa (the dither — and eventually the rounding itself — is lost) and
toward int32 range.  Encoding ``x - anchor`` with the anchor pinned to the
previous round/step mean keeps ``|k| ~ y/s ~ q`` regardless of ``|x|`` —
the shared-state flavor of correlated quantization (Suresh et al. 2022).

:class:`QState` bundles that anchor with the per-bucket granularity state:

  * ``y``      — (nb,) distance bound per bucket; lattice side
                 ``s_b = 2 y_b / (q-1)``;
  * ``anchor`` — flat (n,) anchor vector, or ``None`` for the zero anchor
                 (bit-identical to the historical raw-input path — asserted
                 in tests).

:func:`update_y` is the per-bucket state transition driven by decode
telemetry: buckets implicated in a detected decode failure escalate
(RobustAgreement's ``r <- r^2`` analogue, applied to the bound), clean
buckets relax toward the measured distance so the granularity tightens as
inputs concentrate across rounds.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Array = jax.Array


class QState(NamedTuple):
    """Anchored quantization state carried through every layer of the stack.

    y:      (nb,) f32 per-bucket distance bounds.
    anchor: flat (n,) f32 anchor vector (raw space, pre-bucketize), or None
            for the zero anchor.
    """
    y: Array
    anchor: Optional[Array] = None


def as_qstate(state: Union[QState, Array], *, anchor: Optional[Array] = None
              ) -> QState:
    """Promote a bare per-bucket ``y`` array to a :class:`QState`.

    Every collective accepts either form, so the historical
    ``(x, y_buckets, ...)`` call sites keep working unchanged (zero anchor).
    """
    if isinstance(state, QState):
        return state
    return QState(y=jnp.asarray(state, jnp.float32), anchor=anchor)


def uniform(nb: int, y: Union[float, Array],
            anchor: Optional[Array] = None) -> QState:
    """Uniform per-bucket bounds (the scalar-y compatibility constructor)."""
    return QState(y=jnp.full((nb,), y, jnp.float32), anchor=anchor)


def update_y(y: Array, fails_b: Array, dist_b: Array, *,
             decay: float = 0.99, escalate: float = 2.0,
             margin: float = 2.5, floor: float = 1e-8) -> Array:
    """Per-bucket distance-bound transition from one round's telemetry.

    y:       (..., nb) current bounds.
    fails_b: (..., nb) detected decode failures attributed to each bucket.
    dist_b:  (..., nb) max observed |decoded - anchor|_inf per bucket.

    Buckets with failures escalate ``y <- y * escalate`` (the bound-space
    form of RobustAgreement's color-space squaring); clean buckets relax
    toward ``margin * dist_b`` — clipped to [y/4, 4y] per step so one noisy
    round cannot collapse or explode the state — which *shrinks* y as the
    inputs concentrate around the anchor.  ``dist_b == 0`` (nothing
    measured, e.g. world size 1) leaves the bucket's bound unchanged.
    """
    y = jnp.asarray(y, jnp.float32)
    candidate = jnp.where(dist_b > floor,
                          jnp.clip(margin * dist_b, 0.25 * y, 4.0 * y),
                          y)
    relaxed = decay * y + (1.0 - decay) * candidate
    return jnp.maximum(jnp.where(fails_b > 0, y * escalate, relaxed), floor)
