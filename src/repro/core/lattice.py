"""Cubic-lattice quantization (paper §3, §6, §9.1).

The practical scheme from the paper ("The Algorithm in Practice", §9.1):

* The lattice is the scaled cubic lattice ``s·Z^d`` (optionally offset by a
  shared-random shift ``u·s`` with ``u ~ U[-1/2, 1/2)^d``; with shared
  randomness, *nearest-point* rounding after the shift is already unbiased).
* Encoding a vector ``x``: find lattice coordinates ``k = round(x/s - u)``
  (or stochastic rounding when no shared offset is available), and transmit
  the *color* ``c = k mod q`` — ``log2(q)`` bits per coordinate.
* Decoding against an anchor ``a`` (the receiver's own input): the unique
  lattice point with color ``c`` nearest to ``a``:
      k_a   = round(a/s - u)
      k_hat = k_a + centered_mod(c - k_a, q)
      z     = (k_hat + u) * s
  Correct whenever ``|x - a|_inf <= (q-1)s/2`` coordinate-wise (the cubic-
  lattice sharpening of Lemma 15; see §9.1: side length s = 2y/(q-1)).

Bit accounting: a color in ``[0, q)`` takes ``ceil(log2 q)`` bits; colors are
bit-packed into uint32 words by :mod:`repro.kernels` on the wire.

Everything here is pure jnp (jit/vjp/shard_map-safe).  The Pallas kernels in
``repro/kernels`` implement the fused HBM-bandwidth-optimal versions of
``encode``/``decode``; ``repro/kernels/ref.py`` delegates to this module as
the oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Supported color bit-widths for packing (colors per uint32 word).
PACK_BITS = (1, 2, 4, 8, 16)


def bits_for_q(q: int) -> int:
    """Bits per coordinate for q color classes, rounded up to a packable width."""
    raw = max(1, int(np.ceil(np.log2(q))))
    for b in PACK_BITS:
        if b >= raw:
            return b
    raise ValueError(f"q={q} needs {raw} bits/coord; max supported is 16")


@dataclasses.dataclass(frozen=True)
class LatticeSpec:
    """Static parameters of a cubic-lattice quantizer.

    Attributes:
      q: number of color classes (mod-q coloring).  The wire cost is
         ``bits_for_q(q)`` bits per coordinate.
      scale_rule: how the lattice side ``s`` is derived from the distance
         bound ``y``:  s = 2*y / (q-1)   (paper §9.1).
    """

    q: int

    def __post_init__(self):
        if self.q < 2:
            raise ValueError("q must be >= 2")

    @property
    def bits(self) -> int:
        return bits_for_q(self.q)

    def side(self, y: Array | float) -> Array:
        """Lattice side length s for distance bound y (paper: s = 2y/(q-1))."""
        return jnp.asarray(y, jnp.float32) * (2.0 / (self.q - 1))

    def wire_bits(self, d: int) -> int:
        """Payload bits for a d-dim vector (excl. the O(1) scalar y)."""
        return d * self.bits


def shared_offset(key: Array, shape: tuple[int, ...]) -> Array:
    """Shared-randomness lattice offset u ~ U[-1/2, 1/2)^d (paper §9.1)."""
    return jax.random.uniform(key, shape, jnp.float32, -0.5, 0.5)


def _to_f32(x: Array) -> Array:
    return x.astype(jnp.float32)


def encode_coords(x: Array, s: Array | float, u: Optional[Array] = None,
                  *, rbits: Optional[Array] = None) -> Array:
    """Map x to integer lattice coordinates, unbiasedly.

    Two unbiasedness mechanisms (paper §3.2 / §9.1):
      * shared offset ``u`` (dithering): k = round(x/s - u); decoded point
        (k+u)s has E[.] = x over u.  Preferred: deterministic given (x, u).
      * stochastic rounding with explicit random bits ``rbits`` in [0,1):
        k = floor(x/s) + (frac > rbits).  Used when no shared randomness.

    Exactly one of ``u`` / ``rbits`` may be given; with neither, plain
    nearest-rounding (biased; for tests only).
    """
    t = _to_f32(x) / jnp.asarray(s, jnp.float32)
    if u is not None and rbits is not None:
        raise ValueError("pass at most one of u, rbits")
    if u is not None:
        return jnp.round(t - u).astype(jnp.int32)
    if rbits is not None:
        lo = jnp.floor(t)
        frac = t - lo
        return (lo + (frac > rbits)).astype(jnp.int32)
    return jnp.round(t).astype(jnp.int32)


def color_of(k: Array, q: int) -> Array:
    """Mod-q color class of integer lattice coordinates (paper §3.1)."""
    return jnp.mod(k, q).astype(jnp.uint32)


def centered_mod(delta: Array, q: int) -> Array:
    """Map integers to the representative in [-q/2, q/2) of their mod-q class."""
    half = q // 2
    return jnp.mod(delta + half, q) - half


def decode_coords(colors: Array, anchor: Array, s: Array | float,
                  u: Optional[Array] = None, *, q: int) -> Array:
    """Nearest lattice point to ``anchor`` whose color matches (paper Alg. 2)."""
    t = _to_f32(anchor) / jnp.asarray(s, jnp.float32)
    if u is not None:
        t = t - u
    k_a = jnp.round(t).astype(jnp.int32)
    delta = centered_mod(colors.astype(jnp.int32) - k_a, q)
    return k_a + delta


def coords_to_point(k: Array, s: Array | float, u: Optional[Array] = None,
                    dtype=jnp.float32) -> Array:
    t = k.astype(jnp.float32)
    if u is not None:
        t = t + u
    return (t * jnp.asarray(s, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# One-call encode/decode API (unpacked colors; packing lives in kernels/)
# ---------------------------------------------------------------------------

def lattice_encode(x: Array, y: Array | float, spec: LatticeSpec,
                   key: Optional[Array] = None,
                   u: Optional[Array] = None) -> tuple[Array, Array]:
    """Encode x given distance bound y.  Returns (colors uint32, side s).

    If ``u`` is given it is the shared offset; else if ``key`` is given,
    stochastic rounding is used; else nearest rounding.
    """
    s = spec.side(y)
    rbits = None
    if u is None and key is not None:
        rbits = jax.random.uniform(key, x.shape, jnp.float32)
    k = encode_coords(x, s, u, rbits=rbits)
    return color_of(k, spec.q), s


def lattice_decode(colors: Array, anchor: Array, y: Array | float,
                   spec: LatticeSpec, u: Optional[Array] = None,
                   dtype=jnp.float32) -> Array:
    """Decode colors against the receiver's anchor vector."""
    s = spec.side(y)
    k = decode_coords(colors, anchor, s, u, q=spec.q)
    return coords_to_point(k, s, u, dtype)


def decode_failure(z: Array, anchor: Array, y: Array | float) -> Array:
    """Error-detection surrogate (paper §5, step-level policy; DESIGN §2).

    If the decoded point is farther from the anchor than the distance bound
    plus one lattice cell, the mod-q class wrapped: the true point cannot be
    recovered.  Returns a scalar bool (any coordinate failed).
    """
    yv = jnp.asarray(y, jnp.float32)
    return jnp.any(jnp.abs(_to_f32(z) - _to_f32(anchor)) > 1.5 * yv)


# ---------------------------------------------------------------------------
# Bit packing (jnp reference; the Pallas kernel fuses this with encode)
# ---------------------------------------------------------------------------

def packed_len(n: int, bits: int) -> int:
    per = 32 // bits
    return (n + per - 1) // per


def pack_colors(colors: Array, bits: int) -> Array:
    """Pack uint32 colors (< 2**bits) into uint32 words, little-endian lanes."""
    assert bits in PACK_BITS, bits
    per = 32 // bits
    n = colors.shape[-1]
    pad = (-n) % per
    c = jnp.pad(colors.astype(jnp.uint32), [(0, 0)] * (colors.ndim - 1) + [(0, pad)])
    c = c.reshape(c.shape[:-1] + (c.shape[-1] // per, per))
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)
    return jnp.bitwise_or.reduce(c << shifts, axis=-1)


def unpack_colors(words: Array, n: int, bits: int) -> Array:
    """Inverse of pack_colors; returns first n colors."""
    assert bits in PACK_BITS, bits
    per = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)
    c = (words[..., :, None] >> shifts) & mask
    c = c.reshape(words.shape[:-1] + (words.shape[-1] * per,))
    return c[..., :n]


def wire_bytes(n: int, bits: int) -> int:
    """Bytes on the wire for n coordinates at `bits` bits each (packed)."""
    return packed_len(n, bits) * 4
