"""Unified compressor zoo: the paper's method + every baseline it compares to.

All compressors implement the same pure-functional interface so the DME
algorithms (core/dme.py), the distributed collectives (dist/collectives.py)
and the benchmarks can swap them freely:

    payload, aux = comp.encode(x, ctx, key)        # what goes on the wire
    x_hat        = comp.decode(payload, anchor, ctx)
    nbytes       = comp.wire_bytes(d)              # exact bytes on the wire

``ctx`` is a CompressorCtx carrying the distance bound y (LQ family), the
shared rotation diagonal, and the shared lattice offset.  ``anchor`` is the
*decoder's own vector* — only the lattice family uses it (the paper's core
idea); norm-based baselines ignore it.

Implemented (paper §9 comparisons):
  lq       — cubic-lattice quantization, LQSGD           (the paper)
  rlq      — + Walsh-Hadamard rotation, RLQSGD           (the paper, §6)
  qsgd_l2  — QSGD with l2-norm scaling [Alistarh+ 17]
  qsgd_linf— QSGD variant scaled by (max-min)/2 around the coordinate mean
  hadamard — Suresh+ 17: rotate, then uniform stochastic quantization
  terngrad — Wen+ 17: ternary {-1,0,1}·max|x|
  efsign   — Seide/Karimireddy sign-SGD with error feedback (stateful)
  topk     — magnitude top-k sparsification (indices+values)
  powersgd — Vogels+ 19 rank-r (stateful; benchmark-only, for matrices)
  fp32     — identity (naive averaging baseline)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lattice as L
from repro.core import rotation as R

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressorCtx:
    """Per-step shared context (same values on every machine)."""
    y: Any = 1.0                      # distance bound (LQ family)
    diag: Optional[Array] = None      # shared rotation diagonal (rlq/hadamard)
    u: Optional[Array] = None         # shared lattice offset (dithering)


class Compressor:
    """Base: stateless pure-functional compressor."""

    name: str = "base"
    needs_anchor: bool = False

    def encode(self, x: Array, ctx: CompressorCtx, key: Optional[Array] = None):
        raise NotImplementedError

    def decode(self, payload, anchor: Optional[Array], ctx: CompressorCtx) -> Array:
        raise NotImplementedError

    def wire_bytes(self, d: int) -> int:
        raise NotImplementedError

    def roundtrip(self, x: Array, ctx: CompressorCtx, key: Optional[Array] = None,
                  anchor: Optional[Array] = None) -> Array:
        """encode+decode locally (benchmark convenience)."""
        payload = self.encode(x, ctx, key)
        return self.decode(payload, x if anchor is None else anchor, ctx)


# ---------------------------------------------------------------------------
# The paper's method
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LatticeQ(Compressor):
    """LQSGD: cubic lattice, mod-q colors (paper §3/§9.1)."""
    q: int = 16
    pack: bool = True

    name = "lq"
    needs_anchor = True

    @property
    def spec(self) -> L.LatticeSpec:
        return L.LatticeSpec(self.q)

    def encode(self, x, ctx, key=None):
        colors, _ = L.lattice_encode(x, ctx.y, self.spec, key=key, u=ctx.u)
        if self.pack:
            return L.pack_colors(colors, self.spec.bits)
        return colors

    def decode(self, payload, anchor, ctx):
        colors = payload
        if self.pack:
            colors = L.unpack_colors(payload, anchor.shape[-1], self.spec.bits)
        return L.lattice_decode(colors, anchor, ctx.y, self.spec, u=ctx.u,
                                dtype=anchor.dtype)

    def wire_bytes(self, d):
        return L.wire_bytes(d, self.spec.bits) + 4   # + y scalar


@dataclasses.dataclass(frozen=True)
class RotatedLatticeQ(Compressor):
    """RLQSGD: Walsh-Hadamard rotation + cubic lattice (paper §6).

    ctx.y must be the post-rotation l-inf bound y_R (paper §9.1); encode/
    decode operate in the rotated space and the decode anchor is rotated
    symmetrically, so communication cost is identical to LatticeQ on the
    padded dimension.
    """
    q: int = 16
    pack: bool = True
    use_kernel: bool = False

    name = "rlq"
    needs_anchor = True

    @property
    def spec(self) -> L.LatticeSpec:
        return L.LatticeSpec(self.q)

    def encode(self, x, ctx, key=None):
        assert ctx.diag is not None, "rlq needs ctx.diag"
        xr = R.rotate(x, ctx.diag, use_kernel=self.use_kernel)
        colors, _ = L.lattice_encode(xr, ctx.y, self.spec, key=key, u=ctx.u)
        if self.pack:
            return L.pack_colors(colors, self.spec.bits)
        return colors

    def decode(self, payload, anchor, ctx):
        assert ctx.diag is not None
        d = anchor.shape[-1]
        ar = R.rotate(anchor, ctx.diag, use_kernel=self.use_kernel)
        colors = payload
        if self.pack:
            colors = L.unpack_colors(payload, ar.shape[-1], self.spec.bits)
        zr = L.lattice_decode(colors, ar, ctx.y, self.spec, u=ctx.u)
        return R.unrotate(zr, ctx.diag, d, use_kernel=self.use_kernel).astype(anchor.dtype)

    def wire_bytes(self, d):
        return L.wire_bytes(R.next_pow2(d), self.spec.bits) + 4


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def _stochastic_levels(t: Array, levels: int, key: Optional[Array]) -> Array:
    """Stochastically round t in [0, levels] to an integer level."""
    lo = jnp.floor(t)
    if key is None:
        return jnp.round(t)
    frac = t - lo
    return lo + (jax.random.uniform(key, t.shape) < frac)


@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    """QSGD [4]: x_hat = ||x|| * sign(x) * level/qlevel, stochastic levels.

    norm="l2" is the original; norm="linf" scales by max|x| (the QSGD-LInf
    variant from the paper's experiments).
    """
    qlevel: int = 8
    norm: str = "l2"

    needs_anchor = False

    @property
    def name(self):  # type: ignore[override]
        return f"qsgd_{self.norm}"

    def encode(self, x, ctx, key=None):
        xf = x.astype(jnp.float32)
        if self.norm == "l2":
            scale = jnp.linalg.norm(xf, axis=-1, keepdims=True)
        else:
            scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
        scale = jnp.maximum(scale, 1e-30)
        t = jnp.abs(xf) / scale * self.qlevel
        lev = _stochastic_levels(t, self.qlevel, key)
        return {"scale": scale, "sign": jnp.sign(xf), "lev": lev}

    def decode(self, payload, anchor, ctx):
        out = payload["scale"] * payload["sign"] * payload["lev"] / self.qlevel
        return out.astype(anchor.dtype if anchor is not None else jnp.float32)

    def wire_bytes(self, d):
        bits = int(np.ceil(np.log2(self.qlevel + 1))) + 1   # level + sign
        return (d * bits + 7) // 8 + 8                      # + float64 norm (paper §9.2)


@dataclasses.dataclass(frozen=True)
class HadamardUniform(Compressor):
    """Suresh et al. 17: rotate with HD, uniform stochastic k-level quantize."""
    levels: int = 8

    name = "hadamard"
    needs_anchor = False

    def encode(self, x, ctx, key=None):
        assert ctx.diag is not None, "hadamard needs ctx.diag"
        xr = R.rotate(x, ctx.diag)
        mn = jnp.min(xr, axis=-1, keepdims=True)
        mx = jnp.max(xr, axis=-1, keepdims=True)
        span = jnp.maximum(mx - mn, 1e-30)
        t = (xr - mn) / span * (self.levels - 1)
        lev = _stochastic_levels(t, self.levels - 1, key)
        return {"mn": mn, "span": span, "lev": lev, "d": x.shape[-1]}

    def decode(self, payload, anchor, ctx):
        xr = payload["mn"] + payload["lev"] / (self.levels - 1) * payload["span"]
        out = R.unrotate(xr, ctx.diag, payload["d"])
        return out.astype(anchor.dtype if anchor is not None else jnp.float32)

    def wire_bytes(self, d):
        bits = int(np.ceil(np.log2(self.levels)))
        return (R.next_pow2(d) * bits + 7) // 8 + 16


@dataclasses.dataclass(frozen=True)
class TernGrad(Compressor):
    name = "terngrad"
    needs_anchor = False

    def encode(self, x, ctx, key=None):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True), 1e-30)
        t = jnp.abs(xf) / scale
        b = (jax.random.uniform(key, xf.shape) < t) if key is not None else jnp.round(t)
        return {"scale": scale, "t": jnp.sign(xf) * b}

    def decode(self, payload, anchor, ctx):
        out = payload["scale"] * payload["t"]
        return out.astype(anchor.dtype if anchor is not None else jnp.float32)

    def wire_bytes(self, d):
        return (d * 2 + 7) // 8 + 4


@dataclasses.dataclass(frozen=True)
class EFSign(Compressor):
    """EF-SignSGD [Karimireddy+ 19].  Stateful: call via ef_roundtrip."""
    name = "efsign"
    needs_anchor = False

    def encode(self, x, ctx, key=None):
        xf = x.astype(jnp.float32)
        scale = jnp.mean(jnp.abs(xf), axis=-1, keepdims=True)
        return {"scale": scale, "sign": jnp.sign(xf)}

    def decode(self, payload, anchor, ctx):
        out = payload["scale"] * payload["sign"]
        return out.astype(anchor.dtype if anchor is not None else jnp.float32)

    def wire_bytes(self, d):
        return (d + 7) // 8 + 4


def ef_roundtrip(comp: Compressor, x: Array, err: Array, ctx: CompressorCtx,
                 key: Optional[Array] = None) -> tuple[Array, Array]:
    """Error-feedback wrapper: compress (x + err), carry the residual."""
    corrected = x + err
    x_hat = comp.roundtrip(corrected, ctx, key)
    return x_hat, corrected - x_hat


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    frac: float = 0.01
    name = "topk"
    needs_anchor = False

    def k_of(self, d: int) -> int:
        return max(1, int(d * self.frac))

    def encode(self, x, ctx, key=None):
        xf = x.astype(jnp.float32)
        k = self.k_of(x.shape[-1])
        vals, idx = jax.lax.top_k(jnp.abs(xf), k)
        sel = jnp.take_along_axis(xf, idx, axis=-1)
        return {"idx": idx, "vals": sel, "d": x.shape[-1]}

    def decode(self, payload, anchor, ctx):
        d = payload["d"]
        shape = payload["vals"].shape[:-1] + (d,)
        out = jnp.zeros(shape, jnp.float32)
        out = jnp.put_along_axis(out, payload["idx"], payload["vals"], axis=-1,
                                 inplace=False)
        return out.astype(anchor.dtype if anchor is not None else jnp.float32)

    def wire_bytes(self, d):
        return self.k_of(d) * 8   # 4B idx + 4B val


@dataclasses.dataclass(frozen=True)
class PowerSGDLike(Compressor):
    """Rank-r one-power-iteration compressor for (m, n) matrices.

    Benchmark-only (paper Exp. 7 table comparison); operates on a 2D shape
    hint via ctx-free reshape of the flat vector to (m, d//m).
    """
    rank: int = 4
    rows: int = 64
    name = "powersgd"
    needs_anchor = False

    def _shape(self, d: int) -> tuple[int, int]:
        m = min(self.rows, d)
        while d % m:
            m -= 1
        return m, d // m

    def encode(self, x, ctx, key=None):
        d = x.shape[-1]
        m, n = self._shape(d)
        M = x.astype(jnp.float32).reshape(x.shape[:-1] + (m, n))
        if key is None:
            key = jax.random.PRNGKey(0)
        Q = jax.random.normal(key, x.shape[:-1] + (n, self.rank), jnp.float32)
        P = M @ Q
        P, _ = jnp.linalg.qr(P)
        Qt = jnp.swapaxes(M, -1, -2) @ P
        return {"P": P, "Q": Qt, "d": d}

    def decode(self, payload, anchor, ctx):
        M = payload["P"] @ jnp.swapaxes(payload["Q"], -1, -2)
        out = M.reshape(M.shape[:-2] + (payload["d"],))
        return out.astype(anchor.dtype if anchor is not None else jnp.float32)

    def wire_bytes(self, d):
        m, n = self._shape(d)
        return (m + n) * self.rank * 4


@dataclasses.dataclass(frozen=True)
class FP32(Compressor):
    name = "fp32"
    needs_anchor = False

    def encode(self, x, ctx, key=None):
        return x.astype(jnp.float32)

    def decode(self, payload, anchor, ctx):
        return payload.astype(anchor.dtype if anchor is not None else jnp.float32)

    def wire_bytes(self, d):
        return d * 4


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def make_compressor(name: str, **kw) -> Compressor:
    name = name.lower()
    table = {
        "lq": LatticeQ,
        "rlq": RotatedLatticeQ,
        "qsgd_l2": partial(QSGD, norm="l2"),
        "qsgd_linf": partial(QSGD, norm="linf"),
        "hadamard": HadamardUniform,
        "terngrad": TernGrad,
        "efsign": EFSign,
        "topk": TopK,
        "powersgd": PowerSGDLike,
        "fp32": FP32,
    }
    if name not in table:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(table)}")
    return table[name](**kw)


ALL_COMPRESSORS = ("lq", "rlq", "qsgd_l2", "qsgd_linf", "hadamard", "terngrad",
                   "efsign", "topk", "powersgd", "fp32")
