"""Error detection in quantization (paper §5) — practical realization.

The paper's §5 construction replaces the mod-q coloring with a *random*
coloring such that, whenever encoder and decoder inputs are too far apart,
the decoded color is (w.h.p.) unused near the decoder — so the failure is
*detected* rather than silent, enabling RobustAgreement (Alg. 5): retry with
r <- r^2 until decoding succeeds.  Expected bits become O(d log q + log n)
(Theorem 4).

TPU-practical adaptation (DESIGN §2): we keep the cheap mod-q coloring for
the payload and add a 32-bit *coordinate checksum* — an affine hash of the
integer lattice coordinates under shared randomness:

    h(k) = sum_i a_i * k_i  mod 2^32,   a_i ~ shared uniform uint32

The receiver decodes k_hat by mod-q proximity and verifies h(k_hat) == h(k).
A wrong decode flips at least one k_i by a nonzero multiple of q, so the
checksum mismatches unless the a-weighted sum collides: probability 2^-32
per decode (a is invertible mod 2^32 for odd a_i contributions — we draw a_i
odd).  This is exactly the paper's "color unused nearby w.h.p." guarantee at
+32 bits per message instead of a super-constant color space, and it is SPMD-
friendly: detection is in-graph; escalation (q <- q^2, the paper's r <- r^2)
happens at step granularity in the trainer.

RobustAgreement (host-side reference, paper Alg. 5) is provided for the DME
benchmarks; expected-bits accounting follows Lemma 23.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lattice as L

Array = jax.Array


def checksum_weights(key: Array, d: int) -> Array:
    """Shared-randomness odd uint32 weights for the coordinate checksum."""
    w = jax.random.bits(key, (d,), jnp.uint32)
    return jnp.bitwise_or(w, jnp.uint32(1))


def coord_checksum(k: Array, weights: Array, axis=None) -> Array:
    """h(k) = <a, k> mod 2^32.

    axis=None sums over all of k (one message); an explicit axis computes
    batched checksums (the aggregation server verifies every sender of a
    drain in one shot: k (S, n), weights (n,), axis=-1 -> (S,))."""
    kk = k.astype(jnp.uint32) * weights
    if axis is None:
        kk = kk.reshape(-1)
        axis = 0
    return jnp.sum(kk, axis=axis, dtype=jnp.uint32)


@dataclasses.dataclass(frozen=True)
class DetectingEncoder:
    """Lattice encoder whose messages carry the §5-style detection checksum."""
    q: int = 16

    @property
    def spec(self) -> L.LatticeSpec:
        return L.LatticeSpec(self.q)

    def encode(self, x: Array, y, weights: Array,
               key: Optional[Array] = None, u: Optional[Array] = None):
        s = self.spec.side(y)
        rbits = None
        if u is None and key is not None:
            rbits = jax.random.uniform(key, x.shape, jnp.float32)
        k = L.encode_coords(x, s, u, rbits=rbits)
        return {
            "words": L.pack_colors(L.color_of(k, self.q), self.spec.bits),
            "check": coord_checksum(k, weights),
        }

    def decode(self, payload, anchor: Array, y, weights: Array,
               u: Optional[Array] = None):
        """Returns (z, ok).  ok=False <=> decode failure detected (FAR)."""
        s = self.spec.side(y)
        colors = L.unpack_colors(payload["words"], anchor.shape[-1], self.spec.bits)
        k = L.decode_coords(colors, anchor, s, u, q=self.q)
        ok = coord_checksum(k, weights) == payload["check"]
        z = L.coords_to_point(k, s, u, anchor.dtype)
        return z, ok

    def wire_bits(self, d: int) -> int:
        return L.wire_bytes(d, self.spec.bits) * 8 + 32


def robust_agreement(x_u: Array, x_v: Array, y0, q0: int, key: Array,
                     max_iters: int = 6):
    """Paper Algorithm 5 (host-side reference): escalate q <- q^2 on FAR.

    Returns dict(z, iters, bits, ok).  y0 is the (possibly wrong) initial
    distance estimate; escalating q widens the decode margin (q-1)*s/2 with
    s held at the *initial* granularity, exactly mirroring the paper where
    the lattice eps stays fixed and the color space r grows.
    """
    kw, key = jax.random.split(key)
    weights = checksum_weights(kw, x_u.shape[-1])
    s0 = L.LatticeSpec(q0).side(y0)          # granularity fixed across retries
    q, bits, it = q0, 0, 0
    z, ok = None, False
    while it < max_iters:
        enc = DetectingEncoder(q=min(q, 1 << 16))
        key, ke = jax.random.split(key)
        # keep side fixed: pass y_eff with side(y_eff) = s0
        y_eff = s0 * (enc.q - 1) / 2.0
        payload = enc.encode(x_u, y_eff, weights, key=ke)
        bits += enc.wire_bits(x_u.shape[-1])
        z, ok_dev = enc.decode(payload, x_v, y_eff, weights)
        it += 1
        if bool(ok_dev):
            ok = True
            break
        q = q * q                              # r <- r^2
        bits += 1                              # the FAR message
    return {"z": z, "iters": it, "bits": bits, "ok": ok}
