"""Bucket-space layout shared by the collectives and the agg protocol.

One definition of the flat-vector <-> (n_buckets, bucket) mapping — padding
to a whole number of buckets, plus the optional per-bucket shared-randomness
Hadamard rotation (paper §6).  ``repro.dist.collectives`` and
``repro.agg.rounds`` used to hand-mirror these; the agg-server-vs-star
bit-parity acceptance test depends on them staying identical, so they now
both delegate here.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import rotation as R

Array = jax.Array


def padded_size(n: int, bucket: int) -> int:
    """Smallest multiple of the bucket size >= n (flat wire length)."""
    b = int(bucket)
    return -(-int(n) // b) * b


def bucketize(x: Array, bucket: int, *, diag: Optional[Array] = None,
              use_kernel: bool = True) -> Array:
    """Flat (n,) -> (n_buckets, bucket) f32, zero-padded.

    ``diag`` (a ±1 Hadamard diagonal from :func:`rotation.rotation_keypair`)
    enables the per-bucket HD rotation — block-diagonal, inverted exactly by
    :func:`unbucketize` with the same diagonal.  ``use_kernel`` routes the
    rotation through the Pallas FWHT kernel (the packed wire path).
    """
    n = x.shape[0]
    pad = padded_size(n, bucket) - n
    v = jnp.pad(x.astype(jnp.float32), (0, pad))
    v = v.reshape(-1, bucket)
    if diag is not None:
        v = R.rotate(v, diag, use_kernel=use_kernel)
    return v


def unbucketize(b: Array, n: int, *, diag: Optional[Array] = None,
                use_kernel: bool = True) -> Array:
    """Inverse of :func:`bucketize`: (n_buckets, bucket) -> flat (n,)."""
    if diag is not None:
        b = R.unrotate(b, diag, b.shape[-1], use_kernel=use_kernel)
    return b.reshape(-1)[:int(n)]
