"""Deterministic synthetic data pipeline.

Stateless-seeded: ``batch_at(step)`` is a pure function of (seed, step,
shape), so a restarted job resumes mid-epoch bit-identically (fault
tolerance) and any DP shard can be regenerated on any host (elasticity,
straggler re-assignment).  The "dataset" is a Zipf-ish token stream with
Markov structure so the LM loss actually decreases (unlike uniform noise).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"      # markov | uniform


def _zipf_logits(vocab: int) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / r
    return np.log(p / p.sum()).astype(np.float32)


def batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Global batch for one step: {"tokens","targets","mask"} (B, S)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    if cfg.kind == "uniform":
        toks = jax.random.randint(key, (B, S + 1), 0, V, jnp.int32)
    else:
        # order-1 Markov chain: next = (a*cur + noise) % V with Zipf resets
        k1, k2, k3 = jax.random.split(key, 3)
        base = jax.random.categorical(k1, jnp.asarray(_zipf_logits(V)),
                                      shape=(B, S + 1))
        drift = jnp.cumsum(jax.random.randint(k2, (B, S + 1), 0, 7), axis=1)
        reset = jax.random.bernoulli(k3, 0.1, (B, S + 1))
        toks = jnp.where(reset, base, (base[:, :1] * 31 + drift) % V).astype(jnp.int32)
    return {
        "tokens": np.asarray(toks[:, :-1]),
        "targets": np.asarray(toks[:, 1:]),
        "mask": np.ones((B, S), np.float32),
    }


def local_batch_at(cfg: DataConfig, step: int, dp_rank: int, dp_size: int
                   ) -> dict[str, np.ndarray]:
    """The dp_rank-th slice of the global batch (per-host loading)."""
    g = batch_at(cfg, step)
    b_loc = cfg.global_batch // dp_size
    sl = slice(dp_rank * b_loc, (dp_rank + 1) * b_loc)
    return {k: v[sl] for k, v in g.items()}


def frames_at(cfg: DataConfig, step: int, n_frames: int, d_model: int
              ) -> np.ndarray:
    """Stub modality frontend (whisper frames / vlm patches): deterministic
    pseudo-embeddings (B, n_frames, d_model)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 7_777), step)
    return np.asarray(jax.random.normal(key, (cfg.global_batch, n_frames,
                                               d_model), jnp.float32))
