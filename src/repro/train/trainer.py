"""Fault-tolerant trainer: jitted shard_map train step + restartable loop.

The train step (one compiled program, runs on every device):
  1. local loss -> grads; the FSDP gather's custom vjp reduce-scatters
     gradients over DP with the paper's lattice quantization;
  2. telemetry (decode failures / measured distances, now *per bucket*)
     arrives as the cotangent of the dummy ``tele`` input;
  3. global grad-norm clip (one scalar all-reduce), ZeRO-local optimizer;
  4. the per-bucket ``y`` distance-bound state is updated from telemetry
     via :func:`repro.core.qstate.update_y`: buckets implicated in a
     detected decode failure *escalate* (the SPMD version of
     RobustAgreement's r <- r^2, DESIGN §2), clean buckets relax toward
     their measured distances.

Anchored gradients (``ShardCtx.anchor_grads``): each leaf's y-state carries
``{"y": (nb,), "anchor": (m,)}``; the FSDP backward encodes ``g - anchor``
through the butterfly (dist/fsdp.py) and returns the decoded full mean in
the tele cotangent, which becomes the next step's anchor — cross-step
variance reduction: consecutive gradients are correlated, so
``|g_t - mean_{t-1}|`` (what y must cover) shrinks well below ``|g_t|``.

Fault tolerance: checkpoint every ``ckpt_every`` steps (atomic, logical
layout => restores onto a different mesh); the loop catches device/runtime
failures, restores the last checkpoint and replays — data is stateless-
seeded so the replay is deterministic.  Stragglers cannot desync state:
every step is a single SPMD program (implicit barrier).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import qstate as QS
from repro.dist import fsdp as F
from repro.models.config import ModelConfig
from repro.models.sharding import (ShardCtx, anchor_spec, shard_len,
                                   storage_spec)
from repro.models import transformer as T
from repro.train import optim as O
from repro.train import data as D
from repro.train import checkpoint as C

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatch: int = 0            # 0 = no accumulation
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    keep: int = 3
    max_restarts: int = 3
    y0: float = 1.0                # per-coordinate distance guess; with
                                   # qcfg.rotate each leaf seeds from the §6
                                   # rotated-space bound (sharding.leaf_y0)
    y_decay: float = 0.99          # relax y toward measured distance
    y_escalate: float = 2.0        # on detected decode failure


def _y_update(y, tele: Array, tc: TrainConfig):
    """Per-leaf distance-bound state transition from the tele cotangent.

    y: legacy scalar state ((), (L,)), per-bucket state ((nb,), (L, nb)),
    or an anchored dict leaf {"y": (..., nb), "anchor": (..., m)}.
    tele: (..., width) — [max_dist, fails, y_next | dist_b | fails_b
    | anchor_next] per dist/fsdp.py's layout.
    """
    if isinstance(y, dict):
        nb = y["y"].shape[-1]
        m = y["anchor"].shape[-1]
        lo = F.TELE_WIDTH + 2 * nb
        # the tele slice is the rank's anchor row; reshape restores the
        # stored layout (sharded anchors live as (L?, 1, 1, shard) local
        # views of the ZeRO-3 storage array — legacy (L?, m) is a no-op)
        return {"y": _y_update(y["y"], tele, tc),
                "anchor": tele[..., lo:lo + m].reshape(y["anchor"].shape)}
    if y.ndim == tele.ndim and \
            tele.shape[-1] >= F.TELE_WIDTH + 2 * y.shape[-1]:
        nb = y.shape[-1]
        dist_b = tele[..., F.TELE_WIDTH:F.TELE_WIDTH + nb]
        fails_b = tele[..., F.TELE_WIDTH + nb:F.TELE_WIDTH + 2 * nb]
        return QS.update_y(y, fails_b, dist_b, decay=tc.y_decay,
                           escalate=tc.y_escalate)
    # legacy scalar leaf: one bound per leaf from the scalar telemetry
    max_dist, fails, y_next = tele[..., 0], tele[..., 1], tele[..., 2]
    candidate = jnp.where(y_next > 1e-11,
                          jnp.clip(y_next, 0.25 * y, 4.0 * y),
                          y)
    relaxed = tc.y_decay * y + (1 - tc.y_decay) * candidate
    return jnp.where(fails > 0, y * tc.y_escalate, relaxed)


def make_train_step(cfg: ModelConfig, ctx: ShardCtx, mesh, opt_cfg: O.OptConfig,
                    tc: TrainConfig):
    """Returns jitted step(state, batch) -> (state, metrics)."""
    metas = T.all_metas(cfg, ctx)
    loss_fn = T.make_loss_fn(cfg, ctx)
    L = T.n_scan_steps(cfg)
    if ctx.anchor_grads and tc.microbatch > 1:
        # the anchor rides the tele cotangent, which accumulation combines
        # with jnp.maximum — meaningless for a mean vector
        raise ValueError("anchor_grads is incompatible with microbatch > 1")

    pspec = {"layers": {k: storage_spec(m, ctx) for k, m in metas["layers"].items()},
             "top": {k: storage_spec(m, ctx) for k, m in metas["top"].items()}}
    dpa = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    bspec_leaf = P(dpa)
    opt_spec = ({"m": pspec, "v": pspec} if opt_cfg.name == "adamw"
                else {"m": pspec})

    # anchored leaves are {"y", "anchor"} dicts whose anchor may live in
    # ZeRO-3 storage layout (sharded over tp x dp like the weights); the y
    # spec is then a per-leaf tree instead of one replicated P()
    def _y_leaf_spec(meta):
        if not ctx.anchor_grads:
            return P()
        return {"y": P(), "anchor": anchor_spec(meta, ctx, meta.scanned)}

    y_spec = {"layers": {k: _y_leaf_spec(m) for k, m in metas["layers"].items()},
              "top": {k: _y_leaf_spec(m) for k, m in metas["top"].items()}}
    state_spec = {"params": pspec, "opt": opt_spec, "y": y_spec, "step": P(),
                  "key": P()}

    def batch_spec(batch):
        return {k: bspec_leaf for k in batch}

    def per_device(state, batch):
        params, opt, y, step, key = (state["params"], state["opt"], state["y"],
                                     state["step"], state["key"])
        kstep = jax.random.fold_in(key, step)
        tele0 = T.tele_zeros(cfg, ctx)

        def lg(batch_mb):
            (l, metrics), (gp, gt) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(
                    params, tele0, batch_mb, kstep, y)
            return metrics, gp, gt

        if tc.microbatch > 1:
            mb = tc.microbatch
            def split(v):
                b = v.shape[0]
                return v.reshape(mb, b // mb, *v.shape[1:])
            batch_mb = {k: split(v) for k, v in batch.items()}

            def acc(carry, xs):
                metrics, gp, gt = lg(xs)
                cg, ct, cm = carry
                cg = jax.tree.map(lambda a, b: a + b, cg, gp)
                ct = jax.tree.map(lambda a, b: jnp.maximum(a, b), ct, gt)
                cm = jax.tree.map(lambda a, b: a + b, cm, metrics)
                return (cg, ct, cm), None

            zg = jax.tree.map(jnp.zeros_like, params)
            zt = T.tele_zeros(cfg, ctx)
            zm = {"loss": jnp.zeros(()), "aux": jnp.zeros(())}
            (gp, gt, metrics), _ = jax.lax.scan(acc, (zg, zt, zm), batch_mb)
            gp = jax.tree.map(lambda a: a / mb, gp)
            metrics = jax.tree.map(lambda a: a / mb, metrics)
        else:
            metrics, gp, gt = lg(batch)

        # ---- global grad norm (count each logical element once) ----
        sq = jnp.zeros((), jnp.float32)
        for grp in ("layers", "top"):
            for name, g in gp[grp].items():
                s = jnp.sum(g.astype(jnp.float32) ** 2)
                for ax in ctx.dp_axes:
                    s = jax.lax.psum(s, ax)
                if not metas[grp][name].tp_replicated and ctx.tp > 1:
                    s = jax.lax.psum(s, ctx.tp_axis)
                sq = sq + s
        gnorm = jnp.sqrt(sq)

        params2, opt2 = O.apply_update(params, gp, opt, step, opt_cfg, gnorm)

        # ---- y state from telemetry ----
        y2 = {"layers": {k: _y_update(y["layers"][k], gt["layers"][k], tc)
                         for k in y["layers"]},
              "top": {k: _y_update(y["top"][k], gt["top"][k], tc)
                      for k in y["top"]}}
        fails = sum(jnp.sum(t[..., 1]) for t in jax.tree.leaves(gt))

        loss_rep = metrics["loss"]
        for ax in ctx.dp_axes:
            loss_rep = jax.lax.psum(loss_rep, ax)
        loss_rep = loss_rep / ctx.dp

        new_state = {"params": params2, "opt": opt2, "y": y2,
                     "step": step + 1, "key": key}
        out_metrics = {"loss": loss_rep, "gnorm": gnorm, "fails": fails}
        return new_state, out_metrics

    def step_fn(state, batch):
        f = jax.shard_map(per_device, mesh=mesh,
                          in_specs=(state_spec, batch_spec(batch)),
                          out_specs=(state_spec, P()),
                          check_vma=False)
        return f(state, batch)

    return jax.jit(step_fn), state_spec, pspec


def init_state(cfg: ModelConfig, ctx: ShardCtx, opt_cfg: O.OptConfig,
               tc: TrainConfig, key: Array) -> dict:
    params = T.init_params(cfg, ctx, key)
    return {
        "params": params,
        "opt": O.init_opt_state(params, opt_cfg),
        "y": T.y_init(cfg, ctx, tc.y0),
        "step": jnp.zeros((), jnp.int32),
        "key": key,
    }


class Trainer:
    """Host-side loop with checkpoint/restart fault tolerance."""

    def __init__(self, cfg: ModelConfig, ctx: ShardCtx, mesh,
                 opt_cfg: O.OptConfig, tc: TrainConfig, data_cfg: D.DataConfig,
                 extra_batch: Optional[Callable[[int], dict]] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.cfg, self.ctx, self.mesh = cfg, ctx, mesh
        self.opt_cfg, self.tc, self.data_cfg = opt_cfg, tc, data_cfg
        self.extra_batch = extra_batch
        self.failure_hook = failure_hook
        self.step_fn, self.state_spec, self.pspec = make_train_step(
            cfg, ctx, mesh, opt_cfg, tc)
        self.metas = T.all_metas(cfg, ctx)
        self.history: list[dict] = []
        self.wire_bytes_step = self._wire_bytes_step()
        print(f"[train] grad sync wire: "
              f"{self.wire_bytes_step / 2**20:.2f} MiB/step per rank "
              f"({self.ctx.fsdp_config().sync}, "
              f"packed={self.ctx.qcfg.packed})", flush=True)
        # anchor-state + prefetch banner, from the one wire_accounting
        # definition (core/wire_accounting via fsdp.anchor_bytes_step):
        # sharded anchors materialize zero bytes beyond the rank's shard
        cur_a = self._anchor_bytes_step(self.ctx.anchor_sharded)
        repl_a = self._anchor_bytes_step(False)
        print(f"[train] anchor state: {cur_a / 2**20:.2f} MiB/step per rank "
              f"(anchored={self.ctx.anchor_grads}, "
              f"sharded={self.ctx.anchor_sharded}; replicated equivalent "
              f"{repl_a / 2**20:.2f} MiB) "
              f"prefetch={'on' if self.ctx.prefetch else 'off'}", flush=True)

    def _wire_bytes_step(self) -> int:
        """Static per-rank wire bytes of one step's DP gradient sync
        (packed lattice payload accounting; fsdp.wire_bytes_bwd)."""
        fcfg = self.ctx.fsdp_config()
        sizes = [int(self.mesh.shape[ax]) for ax in self.ctx.dp_axes]
        per_group = {
            grp: sum(F.wire_bytes_bwd(shard_len(m, self.ctx) * self.ctx.dp,
                                      sizes, fcfg)
                     for m in self.metas[grp].values())
            for grp in ("layers", "top")}
        n_mb = max(self.tc.microbatch, 1)
        layers = T.n_scan_steps(self.cfg) * per_group["layers"]
        return n_mb * (layers + per_group["top"])

    def _anchor_bytes_step(self, sharded: bool) -> int:
        """Static per-rank anchor-state bytes one step materializes beyond
        each rank's own shard (0 unanchored; 0 sharded; the legacy
        replicated layout re-materializes full (m,) anchors —
        fsdp.anchor_bytes_step / core.wire_accounting.anchor_state_bytes)."""
        if not self.ctx.anchor_grads:
            return 0
        fcfg = dataclasses.replace(self.ctx.fsdp_config(),
                                   anchor_sharded=sharded)
        sizes = [int(self.mesh.shape[ax]) for ax in self.ctx.dp_axes]
        per_group = {
            grp: sum(F.anchor_bytes_step(shard_len(m, self.ctx) * self.ctx.dp,
                                         sizes, fcfg)
                     for m in self.metas[grp].values())
            for grp in ("layers", "top")}
        return (T.n_scan_steps(self.cfg) * per_group["layers"]
                + per_group["top"])

    def _batch(self, step: int) -> dict:
        b = D.batch_at(self.data_cfg, step)
        if self.extra_batch is not None:
            b.update(self.extra_batch(step))
        dpa = (self.ctx.dp_axes if len(self.ctx.dp_axes) > 1
               else self.ctx.dp_axes[0])
        return {k: jax.device_put(v, NamedSharding(self.mesh, P(dpa)))
                for k, v in b.items()}

    def save(self, state):
        step = int(state["step"])
        logical = C.params_to_logical(state["params"], self.metas, self.ctx)
        opt_logical = {k: C.params_to_logical(v, self.metas, self.ctx)
                       for k, v in state["opt"].items()}
        y_np = jax.tree.map(np.asarray, state["y"])
        C.save(self.tc.ckpt_dir, step,
               {"params": logical, "opt": opt_logical, "y": y_np},
               {"arch": self.cfg.arch}, keep=self.tc.keep)

    def restore(self) -> Optional[dict]:
        step = C.latest_step(self.tc.ckpt_dir)
        if step is None:
            return None
        tree, meta = C.load(self.tc.ckpt_dir)
        params = C.logical_to_params(tree["params"], self.metas, self.ctx)
        state = init_state(self.cfg, self.ctx, self.opt_cfg, self.tc,
                           jax.random.PRNGKey(0))
        state["params"] = params
        if "opt" in tree:
            state["opt"] = {k: C.logical_to_params(v, self.metas, self.ctx)
                            for k, v in tree["opt"].items()}
        # y/anchor shapes depend on the mesh (per-bucket nb, gathered m);
        # an elastic restore onto a different mesh keeps the fresh init —
        # telemetry state re-converges within a few steps.  A checkpoint
        # *missing* the y entry is corrupt and still raises loudly.
        # Checkpoints from before sharded anchors hold replicated (L?, m)
        # anchor leaves: reshard them into the current storage layout first.
        restored_y = jax.tree.map(jnp.asarray, tree["y"])
        restored_y = C.reshard_y(restored_y, state["y"])
        if (jax.tree.structure(restored_y) == jax.tree.structure(state["y"])
                and all(a.shape == b.shape for a, b in
                        zip(jax.tree.leaves(restored_y),
                            jax.tree.leaves(state["y"])))):
            state["y"] = restored_y
        state["step"] = jnp.asarray(step, jnp.int32)
        return state

    def train(self, state: Optional[dict] = None) -> dict:
        if state is None:
            state = self.restore() or init_state(
                self.cfg, self.ctx, self.opt_cfg, self.tc,
                jax.random.PRNGKey(0))
        restarts = 0
        while int(state["step"]) < self.tc.steps:
            step = int(state["step"])
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                batch = self._batch(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                if step % self.tc.log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step
                    m["dt"] = time.perf_counter() - t0
                    m["wire_mb"] = self.wire_bytes_step / 2**20
                    self.history.append(m)
                    print(f"[train] step={step} loss={m['loss']:.4f} "
                          f"gnorm={m['gnorm']:.3f} fails={m['fails']:.0f} "
                          f"dt={m['dt']:.2f}s", flush=True)
                if (step + 1) % self.tc.ckpt_every == 0:
                    self.save(state)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:  # device loss
                restarts += 1
                print(f"[train] step {step} failed ({type(e).__name__}: {e}); "
                      f"restart {restarts}/{self.tc.max_restarts}", flush=True)
                if restarts > self.tc.max_restarts:
                    raise
                restored = self.restore()
                if restored is None:
                    state = init_state(self.cfg, self.ctx, self.opt_cfg,
                                       self.tc, jax.random.PRNGKey(0))
                else:
                    state = restored
        self.save(state)
        return state
