"""ZeRO-sharded optimizers (AdamW / SGD-momentum / Adafactor-lite).

Optimizer states live in the same flat FSDP-sharded storage layout as the
parameters (models/sharding.py): every update is purely local to the shard —
the only cross-device communication in the optimizer path is the quantized
gradient reduce-scatter that happened in backward (the paper's technique).

``state_dtype`` controls the moment dtype (f32 default, bf16 ``low_mem`` for
the 340B-class configs); master weights are always f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | momentum
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    state_dtype: str = "float32"   # "bfloat16" => low-mem mode
    grad_clip: float = 1.0         # global-norm clip (0 disables)
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup) / jnp.maximum(cfg.decay_steps - cfg.warmup, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_opt_state(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.state_dtype)
    if cfg.name == "adamw":
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
        }
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)}


def apply_update(params, grads, opt_state, step: Array, cfg: OptConfig,
                 global_grad_norm: Optional[Array] = None):
    """Pure shard-local update.  params/grads/opt_state share one layout.

    global_grad_norm: pass the psum'd global norm when clipping across
    shards (the trainer computes it with one scalar all-reduce).
    """
    lr = lr_at(cfg, step)
    clip = jnp.float32(1.0)
    if cfg.grad_clip > 0 and global_grad_norm is not None:
        clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(global_grad_norm, 1e-12))

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 / (1.0 - b1 ** t)
        c2 = 1.0 / (1.0 - b2 ** t)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32) * clip
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            u = (m2 * c1) / (jnp.sqrt(v2 * c2) + cfg.eps)
            p2 = p - lr * (u + cfg.weight_decay * p)
            return p2, m2.astype(m.dtype), v2.astype(v.dtype)

        out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
        flat, tree = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        p2 = jax.tree.unflatten(tree, [t[0] for t in flat])
        m2 = jax.tree.unflatten(tree, [t[1] for t in flat])
        v2 = jax.tree.unflatten(tree, [t[2] for t in flat])
        return p2, {"m": m2, "v": v2}

    def upd(p, g, m):
        gf = g.astype(jnp.float32) * clip
        m2 = cfg.momentum * m.astype(jnp.float32) + gf
        p2 = p - lr * (m2 + cfg.weight_decay * p)
        return p2, m2.astype(m.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"])
    flat, tree = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    p2 = jax.tree.unflatten(tree, [t[0] for t in flat])
    m2 = jax.tree.unflatten(tree, [t[1] for t in flat])
    return p2, {"m": m2}


def local_sq_norm(grads) -> Array:
    """Sum of squares of the local shards (psum over mesh for global norm)."""
    return sum(jnp.sum(g.astype(jnp.float32) ** 2)
               for g in jax.tree.leaves(grads))
