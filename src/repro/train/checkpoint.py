"""Mesh-independent, atomic checkpointing (no external deps).

Format: one ``.npz`` of *logical* tensors (storage layout undone via
models/sharding converters) + a msgpack sidecar with step/config/y-state.
Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash mid-write
never corrupts the latest checkpoint.  ``keep`` bounds disk usage.

Because tensors are stored *logically*, a restore may target a different
mesh (tp/dp change) — elastic scaling across restarts (DESIGN §3).
"""
from __future__ import annotations

import dataclasses
import io
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    for k in sorted(tree):
        v = tree[k]
        name = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, name + "/"))
        else:
            out[name] = np.asarray(v)
    return out


def _unflatten(flat):
    tree: dict = {}
    for name, v in flat.items():
        parts = name.split("/")
        cur = tree
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return tree


def save(ckpt_dir: str, step: int, logical_tree: dict, meta: dict,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(logical_tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb({"step": step, **meta}))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def load(ckpt_dir: str, step: Optional[int] = None) -> tuple[dict, dict]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    return _unflatten(flat), meta


# ---------------------------------------------------------------------------
# y-state migration: replicated -> sharded anchor leaves
# ---------------------------------------------------------------------------

def reshard_anchor(arr, target_shape: tuple) -> Any:
    """Migrate one anchor leaf from a pre-sharding checkpoint.

    Old checkpoints hold replicated anchors of shape ``(L?, m)``; the
    sharded layout stores ``(L?, tp, dp, shard)`` with ``m = dp * shard``
    (models/sharding.anchor_shape).  When the shapes correspond, reshape
    the replicated vector into its dp x shard slices and broadcast over
    tp — the values are identical, only the layout changes.  Anything else
    (already matching, or a genuinely different mesh) passes through
    untouched and falls into the trainer's elastic fresh-init fallback.
    """
    a = np.asarray(arr)
    t = tuple(target_shape)
    if (len(t) >= 3 and a.ndim == len(t) - 2
            and a.shape[:-1] == t[:-3] and a.shape[-1] == t[-2] * t[-1]):
        sliced = a.reshape(a.shape[:-1] + (1, t[-2], t[-1]))
        return np.broadcast_to(sliced, t).copy()
    return arr


def reshard_y(tree, target):
    """Recursively migrate a restored y-state tree toward ``target``'s
    layout (anchor leaves only; everything else passes through)."""
    if isinstance(tree, dict) and isinstance(target, dict):
        return {k: (reshard_anchor(tree[k], np.shape(target[k]))
                    if k == "anchor" and not isinstance(target[k], dict)
                    else reshard_y(tree[k], target[k]))
                for k in tree if k in target}
    return tree


# ---------------------------------------------------------------------------
# storage <-> logical round trips for whole parameter trees
# ---------------------------------------------------------------------------

def params_to_logical(params: dict, metas: dict, ctx) -> dict:
    """Storage tree {"layers": {...}, "top": {...}} -> logical numpy tree."""
    from repro.models.sharding import storage_to_logical
    out: dict = {}
    for grp, leaves in params.items():
        out[grp] = {}
        for name, arr in leaves.items():
            meta = metas[grp][name]
            a = np.asarray(arr)
            if meta.scanned:
                out[grp][name] = np.stack(
                    [np.asarray(storage_to_logical(a[l], meta, ctx))
                     for l in range(a.shape[0])])
            else:
                out[grp][name] = np.asarray(storage_to_logical(a, meta, ctx))
    return out


def logical_to_params(logical: dict, metas: dict, ctx) -> dict:
    """Logical tree -> storage layout for the (possibly different) ctx."""
    from repro.models.sharding import logical_to_storage
    out: dict = {}
    for grp, leaves in logical.items():
        out[grp] = {}
        for name, arr in leaves.items():
            meta = metas[grp][name]
            if meta.scanned:
                out[grp][name] = jnp.stack(
                    [logical_to_storage(arr[l], meta, ctx)
                     for l in range(arr.shape[0])])
            else:
                out[grp][name] = logical_to_storage(arr, meta, ctx)
    return out
