"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These delegate to repro.core — the same code paths the DME algorithms and
tests use — so a kernel<->ref allclose check certifies the kernel against
the whole library's semantics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lattice as L
from repro.core import rotation as R


def fwht_ref(x: jax.Array) -> jax.Array:
    """Normalized Walsh-Hadamard transform over the last axis."""
    return R.fwht_jnp(x)


def lattice_encode_ref(x: jax.Array, u: jax.Array, s, *, q: int,
                       bits: int, return_coords: bool = False,
                       anchor: Optional[jax.Array] = None):
    """Packed mod-q colors of round((x - anchor)/s - u); s is scalar or
    per-coordinate, anchor the optional QState anchor (None = zero)."""
    xv = x.astype(jnp.float32) - anchor if anchor is not None else x
    k = L.encode_coords(xv, s, u)
    colors = L.color_of(k, q)
    words = L.pack_colors(colors, bits)
    return (words, k) if return_coords else words


def lattice_decode_ref(words: jax.Array, anchor: jax.Array, u: jax.Array, s,
                       *, q: int, bits: int, n: int,
                       avg_cnt: Optional[int] = None,
                       mode: str = "point",
                       ref: Optional[jax.Array] = None) -> jax.Array:
    colors = L.unpack_colors(words, n, bits)
    av = anchor.astype(jnp.float32) - ref if ref is not None else anchor
    k = L.decode_coords(colors, av, s, u, q=q)
    if mode == "coords":
        return k
    z = L.coords_to_point(k, s, u, jnp.float32)
    if ref is not None:
        z = z + ref
    if avg_cnt is not None:
        z = (z + anchor.astype(jnp.float32) * avg_cnt) / (avg_cnt + 1)
    return z


def lattice_residuals_ref(words: jax.Array, k0: jax.Array, *, q: int,
                          bits: int, n: int) -> jax.Array:
    """Centered mod-q residuals of packed colors about reference coords k0.

    The integer-only half of proximity decode: unpack the colors and lift
    each to the representative nearest k0 — ``r = centered_mod(c - k0, q)``
    — WITHOUT the float anchor/side/dither math.  ``k0 + r`` equals
    :func:`lattice_decode_batched_ref`'s mode="coords" output exactly, so a
    tree tier can sum residuals (and verify §5 checksums over ``k0 + r``)
    while never decoding.  words: (..., n_words); k0: (n,) int32 ->
    (..., n) int32."""
    colors = L.unpack_colors(words, n, bits)
    return L.centered_mod(colors.astype(jnp.int32) - k0.astype(jnp.int32), q)


def lattice_pack_coords_ref(k: jax.Array, *, q: int, bits: int) -> jax.Array:
    """Packed mod-q colors of int32 lattice coordinates (the inverse of the
    unpack+lift in :func:`lattice_residuals_ref`): the tier's repack after
    the in-place integer sum.  k: (..., n) int32 -> (..., n_words) uint32."""
    return L.pack_colors(L.color_of(k, q), bits)


def lattice_decode_batched_ref(words: jax.Array, anchor: jax.Array,
                               u: jax.Array, s, *, q: int, bits: int, n: int,
                               mode: str = "coords",
                               ref: Optional[jax.Array] = None) -> jax.Array:
    """(senders, n_words) payloads vs one (n,) anchor -> (senders, n)."""
    colors = L.unpack_colors(words, n, bits)            # (senders, n)
    sa = jnp.asarray(s, jnp.float32)
    av = anchor.astype(jnp.float32) - ref if ref is not None else anchor
    k = L.decode_coords(colors, av[None], sa, u[None], q=q)
    if mode == "coords":
        return k
    z = L.coords_to_point(k, sa, u[None], jnp.float32)
    if ref is not None:
        z = z + ref[None]
    return z


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """Plain-softmax oracle.  q: (BH, Sq, D); k/v: (BH, Sk, D)."""
    import numpy as np
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
