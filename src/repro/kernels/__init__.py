"""Pallas TPU kernels for the paper's hot spots (validated via interpret mode).

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ref.py (jnp
oracle), ops.py (jit'd public wrappers with fallbacks).
"""
from repro.kernels import ops
