"""Public jit'd wrappers over the Pallas kernels.

On this CPU container kernels run in interpret mode (the Pallas body executes
under the interpreter); on a real TPU backend they compile to Mosaic.  The
``interpret`` decision is made once per call from the default backend, and
every wrapper falls back to the jnp reference for shapes the kernels don't
cover (non-power-of-two FWHT dims, q not a power of two, tiny inputs).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

import repro.obs as _obs
from repro.core import lattice as L
from repro.kernels import ref as _ref
from repro.kernels.fwht import fwht_pallas, MAX_D
from repro.kernels.lattice_encode import lattice_encode_pallas
from repro.kernels.lattice_decode import (lattice_decode_pallas,
                                          lattice_decode_batched_pallas)
from repro.kernels.flash_attention import flash_attention_pallas

# Kernel-dispatch telemetry: how many decode launches each wrapper has issued
# (counted at trace time — one entry per kernel launch in the compiled
# program).  tests/test_agg.py asserts the star collective and the agg
# server drain stay single-dispatch however many senders they decode.
#
# The counts live in the repro.obs registry (always-registered counters, so
# they are exported whenever metrics are enabled); DISPATCH_COUNTS is kept
# as a read-only dict-shaped view over those counters for the existing
# callers and tests.
_DISPATCH = {
    "lattice_decode": _obs.registry().counter("kernel_dispatch",
                                              kernel="lattice_decode"),
    "lattice_decode_batched": _obs.registry().counter(
        "kernel_dispatch", kernel="lattice_decode_batched"),
}


class _DispatchCounts:
    """Dict-shaped live view over the registry dispatch counters."""
    __slots__ = ()

    def __getitem__(self, k: str) -> int:
        return _DISPATCH[k].value

    def get(self, k: str, default=None):
        c = _DISPATCH.get(k)
        return default if c is None else c.value

    def __contains__(self, k) -> bool:
        return k in _DISPATCH

    def __iter__(self):
        return iter(_DISPATCH)

    def __len__(self) -> int:
        return len(_DISPATCH)

    def keys(self):
        return _DISPATCH.keys()

    def values(self):
        return [c.value for c in _DISPATCH.values()]

    def items(self):
        return [(k, c.value) for k, c in _DISPATCH.items()]

    def __eq__(self, other):
        return dict(self.items()) == other

    def __repr__(self) -> str:
        return repr(dict(self.items()))


DISPATCH_COUNTS = _DispatchCounts()


def reset_dispatch_counts() -> None:
    for c in _DISPATCH.values():
        c.reset()


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def fwht(x: jax.Array) -> jax.Array:
    """Normalized Walsh-Hadamard over the last axis (kernel when possible)."""
    d = x.shape[-1]
    if not _pow2(d) or d < 4 or d > MAX_D:
        return _ref.fwht_ref(x)
    return fwht_pallas(x, interpret=_interpret())


def lattice_encode(x: jax.Array, u: jax.Array, s, *, q: int,
                   return_coords: bool = False,
                   anchor: Optional[jax.Array] = None):
    """Fused encode of flat x -> packed uint32 words (+ coords if asked).

    s is a scalar side or a per-coordinate (N,) array (per-bucket sides
    broadcast by the collectives).  ``anchor`` (N,), when given, is the
    QState anchor subtracted in-kernel: k = round((x - anchor)/s - u)."""
    bits = L.bits_for_q(q)
    if not _pow2(q) or bits not in (2, 4, 8, 16) or x.size < 32:
        return _ref.lattice_encode_ref(x, u, s, q=q, bits=bits,
                                       return_coords=return_coords,
                                       anchor=anchor)
    return lattice_encode_pallas(x, u, jnp.asarray(s), anchor, q=q, bits=bits,
                                 return_coords=return_coords,
                                 interpret=_interpret())


def lattice_decode(words: jax.Array, anchor: jax.Array, u: jax.Array, s,
                   *, q: int, avg_cnt: Optional[int] = None,
                   mode: str = "point",
                   ref: Optional[jax.Array] = None) -> jax.Array:
    """Fused decode: mode="point" (z, optional running-average epilogue)
    or mode="coords" (int32 lattice coordinates).  ``ref`` (N,) is the
    QState anchor the sender subtracted (fused anchor-relative frame)."""
    bits = L.bits_for_q(q)
    n = anchor.shape[0]
    _DISPATCH["lattice_decode"].inc()
    if not _pow2(q) or bits not in (2, 4, 8, 16) or n < 32:
        return _ref.lattice_decode_ref(words, anchor, u, s, q=q, bits=bits,
                                       n=n, avg_cnt=avg_cnt, mode=mode,
                                       ref=ref)
    return lattice_decode_pallas(words, anchor, u, jnp.asarray(s), ref, q=q,
                                 bits=bits, n=n, avg_cnt=avg_cnt, mode=mode,
                                 interpret=_interpret())


def lattice_decode_batched(words: jax.Array, anchor: jax.Array, u: jax.Array,
                           s, *, q: int, mode: str = "coords",
                           ref: Optional[jax.Array] = None) -> jax.Array:
    """One fused launch decoding (senders, n_words) payloads of the same
    vector against a shared anchor (n,) -> (senders, n).

    ``s`` is a scalar side, a shared per-coordinate (n,) array, or a
    per-sender (senders, n) array (each sender's sides sidecar); ``ref``
    (n,) the shared QState anchor all senders subtracted.  Used by the star
    collective (the gathered wire) and the aggregation server's drain
    (repro.agg.server) instead of one kernel call per sender.
    """
    bits = L.bits_for_q(q)
    n = anchor.shape[0]
    _DISPATCH["lattice_decode_batched"].inc()
    if not _pow2(q) or bits not in (2, 4, 8, 16) or n < 32:
        return _ref.lattice_decode_batched_ref(words, anchor, u,
                                               jnp.asarray(s), q=q, bits=bits,
                                               n=n, mode=mode, ref=ref)
    return lattice_decode_batched_pallas(words, anchor, u, jnp.asarray(s),
                                         ref, q=q, bits=bits, n=n, mode=mode,
                                         interpret=_interpret())


@partial(jax.jit, static_argnames=("q", "n"))
def _residuals_jit(words, k0, *, q: int, n: int):
    return _ref.lattice_residuals_ref(words, k0, q=q,
                                      bits=L.bits_for_q(q), n=n)


def lattice_residuals(words: jax.Array, k0: jax.Array, *,
                      q: int) -> jax.Array:
    """Centered mod-q residuals of packed payloads about reference coords.

    The integer-only half of proximity decode: ``r = centered_mod(c - k0,
    q)`` per coordinate, so ``k0 + r`` is EXACTLY what the batched decode's
    mode="coords" would produce for the same payload — without touching the
    float anchor/side/dither math and without a decode dispatch.  This is
    the tree tier's sum-without-decode primitive (repro.agg.tree): tiers
    sum residuals in int space and the root alone decodes.  words:
    (..., n_words) uint32; k0: (n,) int32 -> (..., n) int32.  Deliberately
    NOT counted in DISPATCH_COUNTS — the acceptance gate asserts tiers
    issue zero decode dispatches."""
    return _residuals_jit(words, k0, q=q, n=k0.shape[0])


def lattice_residuals_range(words: jax.Array, k0: jax.Array, *, q: int,
                            word_start: int = 0) -> jax.Array:
    """Residuals of a word-aligned SLICE of a packed payload: the streaming
    drain's range-fold primitive (repro.agg.server / repro.agg.tree).

    ``words`` is the contiguous run of packed uint32 words
    ``[word_start, word_start + words.shape[-1])`` of the full payload —
    e.g. the validated chunk prefix a reassembly session just committed —
    and ``k0`` the FULL (n,) int32 reference-coordinate vector; the slice
    arithmetic (word w covers coordinates ``[w*per, (w+1)*per)``) lives
    here so every caller folds against the identical reference window.
    Returns (..., m) int32 residuals for coordinates
    ``[word_start*per, word_start*per + m)`` with ``m`` clipped to n, such
    that concatenating the ranges of a whole payload reproduces
    :func:`lattice_residuals` of that payload bit for bit.  Like the
    full-payload fold it is deliberately NOT a counted decode dispatch."""
    per = 32 // L.bits_for_q(q)
    c0 = word_start * per
    if c0 >= k0.shape[0]:
        raise ValueError(f"word_start {word_start} starts at coordinate "
                         f"{c0}, past the {k0.shape[0]}-coordinate vector")
    m = min(words.shape[-1] * per, k0.shape[0] - c0)
    return _residuals_jit(words, k0[c0:c0 + m], q=q, n=m)


@partial(jax.jit, static_argnames=("q",))
def _pack_coords_jit(k, *, q: int):
    return _ref.lattice_pack_coords_ref(k, q=q, bits=L.bits_for_q(q))


def lattice_pack_coords(k: jax.Array, *, q: int) -> jax.Array:
    """Pack int32 lattice coordinates as mod-q color words (the inverse of
    the unpack+lift in :func:`lattice_residuals`): the tier's repack after
    its in-place integer sum.  k: (..., n) int32 -> (..., n_words) uint32."""
    return _pack_coords_jit(k, q=q)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True) -> jax.Array:
    """Flash attention fwd over (BH, S, D) tensors (pads to block multiples)."""
    BH, sq, d = q.shape
    sk = k.shape[1]
    bq = min(256, sq)
    bk = min(256, sk)
    if sq % bq or sk % bk or sq < 16:
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=_interpret())
