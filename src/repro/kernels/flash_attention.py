"""Pallas TPU kernel: flash attention forward (online softmax).

The §Roofline analysis shows the memory term of every train/prefill cell is
dominated by materialized (Sq, Sk) attention scores; this kernel keeps them
in VMEM: per (batch*head, q-block) grid cell, it streams K/V blocks and
maintains the running (max, sum, output) triple — O(Sq*D) HBM traffic
instead of O(Sq*Sk).

Forward-only (inference/prefill; the training path keeps the jnp attention
whose backward autodiffs — a bwd kernel is the natural next perf iteration).
Validated against ref.flash_attention_ref in interpret mode
(tests/test_flash_attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 256
DEFAULT_BK = 256


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, sk: int,
                  scale: float, causal: bool):
    j = pl.program_id(1)                         # q-block index
    q = q_ref[0].astype(jnp.float32) * scale     # (bq, d)
    d = q.shape[-1]
    nkb = sk // bk

    def body(kb, carry):
        m_i, l_i, acc = carry
        k = k_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)   # (bk, d)
        v = v_ref[0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                               # (bq, bk)
        if causal:
            qpos = j * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, -1e30)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    # causal: skip key blocks entirely above the diagonal
    upper = nkb if not causal else jnp.minimum(
        nkb, (j + 1) * bq // bk + (1 if bq % bk or True else 0))
    m_i, l_i, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, D); k/v: (BH, Sk, D).  Returns (BH, Sq, D).

    Sq must be divisible by bq and Sk by bk (callers pad; repro.kernels.ops
    handles it).
    """
    BH, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = float(1.0 / np.sqrt(d))
    grid = (BH, sq // bq)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, sk=sk, scale=scale,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
