"""Pallas TPU kernel: fast Walsh-Hadamard transform (paper §6 rotation).

TPU adaptation (DESIGN §2/§5): instead of the GPU butterfly-shuffle FWHT, we
use the Kronecker factorization of Sylvester-Hadamard matrices

    H_d = H_a (x) H_b          (d = a*b, a,b <= 128 powers of two)

so the transform of a (rows, d) tile becomes two small MXU matmuls on the
reshaped (rows, a, b) tensor:

    Y = H_a @ X @ H_b    (per row)

This keeps the whole tile in VMEM, feeds the 128x128 MXU with dense
H-matrices, and needs no cross-lane shuffles — the TPU-native way to spend
O(d*(a+b)) MXU FLOPs instead of O(d log d) serial VPU stages.

Supported: d a power of two, 4 <= d <= 16384 (a,b <= 128).  Larger d is
handled by the caller (repro.kernels.ops) via bucketing — which the RLQ
compressor does anyway (paper §6 note on coordinate buckets).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8
MAX_D = 16384


def hadamard_matrix(n: int) -> np.ndarray:
    """Unnormalized Sylvester-Hadamard matrix H_n (n power of two)."""
    assert n & (n - 1) == 0 and n >= 1
    i = np.arange(n)
    # H[i,j] = (-1)^{popcount(i & j)}
    pc = np.vectorize(lambda v: bin(v).count("1"))(i[:, None] & i[None, :])
    return np.where(pc % 2 == 0, 1.0, -1.0).astype(np.float32)


def factor_d(d: int) -> tuple[int, int]:
    """Split d = a*b with a, b <= 128, both powers of two."""
    assert d & (d - 1) == 0 and 4 <= d <= MAX_D, f"bad fwht dim {d}"
    b = min(d, 128)
    a = d // b
    assert a <= 128
    return a, b


def _fwht_kernel(x_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int, scale: float):
    x = x_ref[...].astype(jnp.float32)           # (bm, d)
    bm = x.shape[0]
    x3 = x.reshape(bm, a, b)
    # right-multiply by H_b  : (bm, a, b) x (b, b) -> (bm, a, b)
    t = jax.lax.dot_general(x3, hb_ref[...],
                            (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # left-multiply by H_a   : contract axis 1 (H symmetric) -> (bm, b, a)
    t = jax.lax.dot_general(t, ha_ref[...],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    t = jnp.swapaxes(t, 1, 2)                    # (bm, a, b)
    o_ref[...] = (t.reshape(bm, a * b) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _fwht_2d(x: jax.Array, ha: jax.Array, hb: jax.Array,
             block_rows: int = DEFAULT_BLOCK_ROWS,
             interpret: bool = True) -> jax.Array:
    rows, d = x.shape
    a, b = ha.shape[0], hb.shape[0]
    assert a * b == d
    bm = min(block_rows, rows)
    pad = (-rows) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (x.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, a=a, b=b, scale=float(1.0 / np.sqrt(d))),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, ha, hb)
    return out[:rows]


def fwht_pallas(x: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True) -> jax.Array:
    """Normalized FWHT over the last axis via the Pallas kernel.

    x: (..., d), d a power of two in [4, 16384].
    """
    d = x.shape[-1]
    a, b = factor_d(d)
    ha = jnp.asarray(hadamard_matrix(a))
    hb = jnp.asarray(hadamard_matrix(b))
    lead = x.shape[:-1]
    x2 = x.reshape((-1, d)) if lead else x.reshape((1, d))
    out = _fwht_2d(x2, ha, hb, block_rows=block_rows, interpret=interpret)
    return out.reshape(lead + (d,)) if lead else out.reshape((d,))
