"""Pallas TPU kernel: fused lattice encode (paper §3.2 / §9.1 hot path).

Fuses: scale -> dither -> round -> mod-q color -> bit-pack, in one pass over
HBM.  Input x is read once; the packed output is d*log2(q)/32 uint32 words —
an 8x (q=16) to 32x (q=2) write-traffic reduction versus materializing f32
colors, and the exact payload that goes on the ICI wire.

Layout: the flat vector is viewed as (rows, COLS) tiles; each grid step
processes (BM, COLS) in VMEM and writes (BM, COLS/per) packed words, where
per = 32/bits colors per word.  COLS=2048 keeps the packed lanes >= 128 for
every supported bit-width (2,4,8,16).

The lattice side ``s`` is either a scalar (one bound for the whole vector)
or a per-coordinate (N,) array — the broadcast of per-*bucket* sides used by
the quantized collectives (repro.dist.collectives), whose buckets each carry
their own distance bound y and side s = 2y/(q-1).

With ``return_coords=True`` the kernel additionally writes the int32 lattice
coordinates ``k = round(x/s - u)`` — the butterfly collective needs both the
wire words (to send) and the local coordinates (to average in exact integer
space) from a single fused pass over x.

With ``anchor`` (the :class:`repro.core.qstate.QState` anchor, bucketized
and flattened like x) the subtraction is fused into the same pass:
``k = round((x - anchor)/s - u)``.  The wire still carries only the packed
mod-q colors; anchoring keeps ``|k| ~ y/s`` however large ``|x|`` grows
(the drifting large-norm regime), at zero extra HBM traffic beyond reading
the anchor once.  ``anchor=None`` is byte-for-byte the historical kernel.

q must be a power of two (the paper's experiments use q in {8, 16, 64});
mod-q of the two's-complement coordinate is a bitwise AND with q-1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLS = 2048
DEFAULT_BLOCK_ROWS = 8


def _encode_kernel(x_ref, u_ref, s_ref, *refs, q: int, bits: int,
                   scalar_s: bool, with_coords: bool, with_anchor: bool):
    if with_anchor:
        a_ref, *o_refs = refs
        xv = x_ref[...].astype(jnp.float32) - a_ref[...]
    else:
        o_refs = refs
        xv = x_ref[...].astype(jnp.float32)
    s = s_ref[0, 0] if scalar_s else s_ref[...]
    t = xv / s - u_ref[...]
    k = jnp.round(t).astype(jnp.int32)
    c = jnp.bitwise_and(k, q - 1).astype(jnp.uint32)      # mod q (q = 2^bits')
    bm, ccols = c.shape
    per = 32 // bits
    c = c.reshape(bm, ccols // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(bits))
    # fields are disjoint -> sum == bitwise OR, and sum reduces cleanly on TPU
    o_refs[0][...] = jnp.sum(c << shifts, axis=-1, dtype=jnp.uint32)
    if with_coords:
        o_refs[1][...] = k


@functools.partial(jax.jit,
                   static_argnames=("q", "bits", "return_coords",
                                    "block_rows", "interpret"))
def lattice_encode_pallas(x: jax.Array, u: jax.Array, s: jax.Array,
                          anchor: jax.Array = None,
                          *, q: int, bits: int, return_coords: bool = False,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = True):
    """Encode flat x (N,) with dither u (N,) and side s (scalar or (N,)).

    Returns packed uint32 words of length ceil(N/per) where per=32/bits —
    plus the int32 coordinates (N,) when ``return_coords``.  N is padded
    internally to a (rows, COLS) view; callers slice via
    repro.core.lattice.packed_len(N, bits).  ``anchor`` (N,), when given,
    is subtracted in-kernel: ``k = round((x - anchor)/s - u)``.
    """
    assert q & (q - 1) == 0 and 2 <= q <= (1 << bits), (q, bits)
    assert bits in (2, 4, 8, 16)
    n = x.shape[0]
    per = 32 // bits
    tile = block_rows * COLS
    pad = (-n) % tile
    xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    uf = jnp.pad(u.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    scalar_s = jnp.ndim(s) == 0
    if scalar_s:
        sf = jnp.asarray(s, jnp.float32).reshape(1, 1)
        s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    else:
        # pad sides with ones so the padded tail encodes deterministic zeros
        sf = jnp.pad(s.astype(jnp.float32), (0, pad),
                     constant_values=1.0).reshape(-1, COLS)
        s_spec = pl.BlockSpec((block_rows, COLS), lambda i: (i, 0))
    rows = xf.shape[0]
    bm = block_rows
    grid = (rows // bm,)
    with_anchor = anchor is not None
    in_arrays = [xf, uf, sf]
    in_specs = [
        pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
        pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
        s_spec,
    ]
    if with_anchor:
        af = jnp.pad(anchor.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
        in_arrays.append(af)
        in_specs.append(pl.BlockSpec((bm, COLS), lambda i: (i, 0)))
    out_shape = [jax.ShapeDtypeStruct((rows, COLS // per), jnp.uint32)]
    out_specs = [pl.BlockSpec((bm, COLS // per), lambda i: (i, 0))]
    if return_coords:
        out_shape.append(jax.ShapeDtypeStruct((rows, COLS), jnp.int32))
        out_specs.append(pl.BlockSpec((bm, COLS), lambda i: (i, 0)))
    out = pl.pallas_call(
        functools.partial(_encode_kernel, q=q, bits=bits, scalar_s=scalar_s,
                          with_coords=return_coords, with_anchor=with_anchor),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*in_arrays)
    n_words = (n + per - 1) // per
    words = out[0].reshape(-1)[:n_words]
    if return_coords:
        return words, out[1].reshape(-1)[:n]
    return words
