"""Pallas TPU kernel: fused lattice encode (paper §3.2 / §9.1 hot path).

Fuses: scale -> dither -> round -> mod-q color -> bit-pack, in one pass over
HBM.  Input x is read once; the packed output is d*log2(q)/32 uint32 words —
an 8x (q=16) to 32x (q=2) write-traffic reduction versus materializing f32
colors, and the exact payload that goes on the ICI wire.

Layout: the flat vector is viewed as (rows, COLS) tiles; each grid step
processes (BM, COLS) in VMEM and writes (BM, COLS/per) packed words, where
per = 32/bits colors per word.  COLS=2048 keeps the packed lanes >= 128 for
every supported bit-width (2,4,8,16).

q must be a power of two (the paper's experiments use q in {8, 16, 64});
mod-q of the two's-complement coordinate is a bitwise AND with q-1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

COLS = 2048
DEFAULT_BLOCK_ROWS = 8


def _encode_kernel(x_ref, u_ref, s_ref, o_ref, *, q: int, bits: int):
    s = s_ref[0, 0]
    t = x_ref[...].astype(jnp.float32) / s - u_ref[...]
    k = jnp.round(t).astype(jnp.int32)
    c = jnp.bitwise_and(k, q - 1).astype(jnp.uint32)      # mod q (q = 2^bits')
    bm, ccols = c.shape
    per = 32 // bits
    c = c.reshape(bm, ccols // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(bits))
    # fields are disjoint -> sum == bitwise OR, and sum reduces cleanly on TPU
    o_ref[...] = jnp.sum(c << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit,
                   static_argnames=("q", "bits", "block_rows", "interpret"))
def lattice_encode_pallas(x: jax.Array, u: jax.Array, s: jax.Array,
                          *, q: int, bits: int,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = True) -> jax.Array:
    """Encode flat x (N,) with dither u (N,) and side s (scalar).

    Returns packed uint32 words of length ceil(N/per) where per=32/bits.
    N is padded internally to a (rows, COLS) view; callers slice via
    repro.core.lattice.packed_len(N, bits).
    """
    assert q & (q - 1) == 0 and 2 <= q <= (1 << bits), (q, bits)
    assert bits in (2, 4, 8, 16)
    n = x.shape[0]
    per = 32 // bits
    tile = block_rows * COLS
    pad = (-n) % tile
    xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    uf = jnp.pad(u.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    s2 = jnp.asarray(s, jnp.float32).reshape(1, 1)
    rows = xf.shape[0]
    bm = block_rows
    grid = (rows // bm,)
    out = pl.pallas_call(
        functools.partial(_encode_kernel, q=q, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
            pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, COLS // per), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS // per), jnp.uint32),
        interpret=interpret,
    )(xf, uf, s2)
    n_words = (n + per - 1) // per
    return out.reshape(-1)[:n_words]
