"""Pallas TPU kernel: fused lattice decode (paper Alg. 2 / §9.1 hot path).

Fuses: unpack -> anchor coordinates -> centered-mod nearest-color match ->
lattice point, in one pass.  Reads the packed uint32 words (the wire payload)
plus the anchor once, writes the decoded vector once.

    k_a   = round(anchor/s - u)
    k     = k_a + ((c - k_a + q/2) mod q) - q/2     [mod via AND, q = 2^bits']
    z     = (k + u) * s

The side ``s`` is a scalar or a per-coordinate (N,) array (the broadcast of
the collectives' per-bucket sides sidecar that rides the wire next to the
packed words).

Output modes:
  * mode="point"  — the decoded lattice point z (f32), optionally with the
    running-average epilogue ``out = (z + anchor*avg_cnt)/(avg_cnt+1)`` used
    by the ring reduce-scatter;
  * mode="coords" — the int32 coordinates k.  The butterfly collective
    averages own+partner coordinates in exact integer space (bit-identical
    outputs across ranks, the paper's common-output requirement), so it
    needs k rather than z.

Batched variant (:func:`lattice_decode_batched_pallas`): decodes ``senders``
independently-encoded payloads of the *same* vector length against one
shared anchor in a single ``pallas_call`` over a ``(senders, row_tiles)``
grid — the star collective's gathered wire words and the aggregation
server's drain path (repro.agg.server), which previously needed one kernel
launch per sender.  Each sender may carry its own per-coordinate sides (the
per-sender sidecar that rides the wire), while the anchor and the shared
dither ``u`` are read once per row tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLS = 2048
DEFAULT_BLOCK_ROWS = 8


def _decode_math(w, anchor, u, s, *, q: int, bits: int,
                 avg_cnt: Optional[int], coords: bool, ref=None):
    """Shared decode body: packed words (..., COLS//per) -> k or z (..., COLS).

    anchor/u/s broadcast against the unpacked colors (the batched kernel
    passes (bs, bm, COLS) words against a (bm, COLS) anchor block).  ``ref``
    is the QState anchor the sender subtracted before encoding: the
    coordinate frame becomes anchor-relative, ``k_a = round((a - ref)/s - u)``
    and the decoded point gets ``ref`` added back."""
    shifts = (jnp.arange(per := 32 // bits, dtype=jnp.uint32)
              * jnp.uint32(bits))
    c = ((w[..., :, None] >> shifts) & jnp.uint32(q - 1)).astype(jnp.int32)
    c = c.reshape(w.shape[:-1] + (w.shape[-1] * per,))  # (..., COLS) colors
    av = anchor - ref if ref is not None else anchor
    t = av / s - u
    k_a = jnp.round(t).astype(jnp.int32)
    delta = jnp.bitwise_and(c - k_a + (q // 2), q - 1) - (q // 2)
    k = k_a + delta
    if coords:
        return k
    z = (k.astype(jnp.float32) + u) * s
    if ref is not None:
        z = z + ref
    if avg_cnt is not None:
        z = (z + anchor * avg_cnt) * (1.0 / (avg_cnt + 1))
    return z


def _decode_kernel(w_ref, a_ref, u_ref, s_ref, *refs, q: int, bits: int,
                   avg_cnt: Optional[int], scalar_s: bool, coords: bool,
                   with_ref: bool):
    if with_ref:
        r_ref, o_ref = refs
        rv = r_ref[...]
    else:
        (o_ref,) = refs
        rv = None
    s = s_ref[0, 0] if scalar_s else s_ref[...]
    out = _decode_math(w_ref[...], a_ref[...].astype(jnp.float32), u_ref[...],
                       s, q=q, bits=bits, avg_cnt=avg_cnt, coords=coords,
                       ref=rv)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q", "bits", "n", "avg_cnt",
                                             "mode", "block_rows",
                                             "interpret"))
def lattice_decode_pallas(words: jax.Array, anchor: jax.Array, u: jax.Array,
                          s: jax.Array, ref: jax.Array = None,
                          *, q: int, bits: int, n: int,
                          avg_cnt: Optional[int] = None, mode: str = "point",
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = True) -> jax.Array:
    """Decode packed words against flat anchor (N,).

    mode="point": returns z (N,) f32; avg_cnt, if given, fuses the
    running-average epilogue out = (z + anchor*avg_cnt)/(avg_cnt+1).
    mode="coords": returns the int32 coordinates k (N,).
    ``ref`` (N,) is the QState anchor fused into the coordinate frame
    (the sender encoded x - ref); see :func:`_decode_math`.
    """
    assert q & (q - 1) == 0 and bits in (2, 4, 8, 16)
    assert mode in ("point", "coords")
    assert avg_cnt is None or mode == "point"
    per = 32 // bits
    tile = block_rows * COLS
    pad = (-n) % tile
    af = jnp.pad(anchor.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    uf = jnp.pad(u.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    rows = af.shape[0]
    wpad = rows * (COLS // per) - words.shape[0]
    wf = jnp.pad(words, (0, wpad)).reshape(rows, COLS // per)
    scalar_s = jnp.ndim(s) == 0
    if scalar_s:
        sf = jnp.asarray(s, jnp.float32).reshape(1, 1)
        s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    else:
        sf = jnp.pad(s.astype(jnp.float32), (0, pad),
                     constant_values=1.0).reshape(-1, COLS)
        s_spec = pl.BlockSpec((block_rows, COLS), lambda i: (i, 0))
    bm = block_rows
    out_dtype = jnp.int32 if mode == "coords" else jnp.float32
    with_ref = ref is not None
    in_arrays = [wf, af, uf, sf]
    in_specs = [
        pl.BlockSpec((bm, COLS // per), lambda i: (i, 0)),
        pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
        pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
        s_spec,
    ]
    if with_ref:
        rf = jnp.pad(ref.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
        in_arrays.append(rf)
        in_specs.append(pl.BlockSpec((bm, COLS), lambda i: (i, 0)))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, q=q, bits=bits, avg_cnt=avg_cnt,
                          scalar_s=scalar_s, coords=(mode == "coords"),
                          with_ref=with_ref),
        grid=(rows // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), out_dtype),
        interpret=interpret,
    )(*in_arrays)
    return out.reshape(-1)[:n]


DEFAULT_BLOCK_SENDERS = 16


def _decode_batched_kernel(w_ref, a_ref, u_ref, s_ref, *refs, q: int,
                           bits: int, s_kind: str, coords: bool,
                           with_ref: bool):
    if with_ref:
        r_ref, o_ref = refs
        rv = r_ref[...]                     # (bm, COLS), broadcasts over bs
    else:
        (o_ref,) = refs
        rv = None
    if s_kind == "scalar":
        s = s_ref[0, 0]
    elif s_kind == "shared":
        s = s_ref[...]                      # (bm, COLS), broadcasts over bs
    else:                                   # per-sender: (bs, bm, COLS)
        s = s_ref[...]
    out = _decode_math(w_ref[...], a_ref[...].astype(jnp.float32), u_ref[...],
                       s, q=q, bits=bits, avg_cnt=None, coords=coords,
                       ref=rv)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q", "bits", "n", "mode",
                                             "block_rows", "block_senders",
                                             "interpret"))
def lattice_decode_batched_pallas(words: jax.Array, anchor: jax.Array,
                                  u: jax.Array, s: jax.Array,
                                  ref: jax.Array = None, *, q: int,
                                  bits: int, n: int, mode: str = "coords",
                                  block_rows: int = DEFAULT_BLOCK_ROWS,
                                  block_senders: int = DEFAULT_BLOCK_SENDERS,
                                  interpret: bool = True) -> jax.Array:
    """Decode (senders, n_words) packed payloads against one anchor (n,).

    One pallas_call over a (sender_tiles, row_tiles) grid; each step holds a
    (block_senders, block_rows, COLS) tile in VMEM (~2.5 MiB at the
    defaults), decoding ``block_senders`` payloads against one anchor block
    read once per tile.  The per-sender words (the 8x-compressed payload)
    dominate HBM traffic.  ``s`` is a scalar, a shared (n,) per-coordinate
    array, or a per-sender (senders, n) array (each sender's sides
    sidecar).  ``ref`` (n,) is the shared QState anchor all senders
    subtracted before encoding (fused like the anchor block, read once per
    row tile).  Returns (senders, n) int32 coords (mode="coords") or f32
    points (mode="point").
    """
    assert q & (q - 1) == 0 and bits in (2, 4, 8, 16)
    assert mode in ("point", "coords")
    senders = words.shape[0]
    per = 32 // bits
    tile = block_rows * COLS
    pad = (-n) % tile
    bs = min(block_senders, senders)
    spad = (-senders) % bs
    af = jnp.pad(anchor.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    uf = jnp.pad(u.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    rows = af.shape[0]
    wpad = rows * (COLS // per) - words.shape[1]
    wf = jnp.pad(words, ((0, spad), (0, wpad))).reshape(senders + spad, rows,
                                                        COLS // per)
    bm = block_rows
    if jnp.ndim(s) == 0:
        s_kind = "scalar"
        sf = jnp.asarray(s, jnp.float32).reshape(1, 1)
        s_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    elif jnp.ndim(s) == 1:
        s_kind = "shared"
        sf = jnp.pad(s.astype(jnp.float32), (0, pad),
                     constant_values=1.0).reshape(-1, COLS)
        s_spec = pl.BlockSpec((bm, COLS), lambda i, j: (j, 0))
    else:
        s_kind = "sender"
        sf = jnp.pad(s.astype(jnp.float32), ((0, spad), (0, pad)),
                     constant_values=1.0).reshape(senders + spad, rows, COLS)
        s_spec = pl.BlockSpec((bs, bm, COLS), lambda i, j: (i, j, 0))
    out_dtype = jnp.int32 if mode == "coords" else jnp.float32
    with_ref = ref is not None
    in_arrays = [wf, af, uf, sf]
    in_specs = [
        pl.BlockSpec((bs, bm, COLS // per), lambda i, j: (i, j, 0)),
        pl.BlockSpec((bm, COLS), lambda i, j: (j, 0)),
        pl.BlockSpec((bm, COLS), lambda i, j: (j, 0)),
        s_spec,
    ]
    if with_ref:
        rf = jnp.pad(ref.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
        in_arrays.append(rf)
        in_specs.append(pl.BlockSpec((bm, COLS), lambda i, j: (j, 0)))
    out = pl.pallas_call(
        functools.partial(_decode_batched_kernel, q=q, bits=bits,
                          s_kind=s_kind, coords=(mode == "coords"),
                          with_ref=with_ref),
        grid=((senders + spad) // bs, rows // bm),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bs, bm, COLS), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((senders + spad, rows, COLS),
                                       out_dtype),
        interpret=interpret,
    )(*in_arrays)
    return out.reshape(senders + spad, -1)[:senders, :n]
