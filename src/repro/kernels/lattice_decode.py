"""Pallas TPU kernel: fused lattice decode (paper Alg. 2 / §9.1 hot path).

Fuses: unpack -> anchor coordinates -> centered-mod nearest-color match ->
lattice point, in one pass.  Reads the packed uint32 words (the wire payload)
plus the anchor once, writes the decoded vector once.

    k_a   = round(anchor/s - u)
    k     = k_a + ((c - k_a + q/2) mod q) - q/2     [mod via AND, q = 2^bits']
    z     = (k + u) * s

The side ``s`` is a scalar or a per-coordinate (N,) array (the broadcast of
the collectives' per-bucket sides sidecar that rides the wire next to the
packed words).

Output modes:
  * mode="point"  — the decoded lattice point z (f32), optionally with the
    running-average epilogue ``out = (z + anchor*avg_cnt)/(avg_cnt+1)`` used
    by the ring reduce-scatter;
  * mode="coords" — the int32 coordinates k.  The butterfly collective
    averages own+partner coordinates in exact integer space (bit-identical
    outputs across ranks, the paper's common-output requirement), so it
    needs k rather than z.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLS = 2048
DEFAULT_BLOCK_ROWS = 8


def _decode_kernel(w_ref, a_ref, u_ref, s_ref, o_ref, *, q: int, bits: int,
                   avg_cnt: Optional[int], scalar_s: bool, coords: bool):
    s = s_ref[0, 0] if scalar_s else s_ref[...]
    per = 32 // bits
    w = w_ref[...]                                    # (bm, COLS//per) uint32
    bm = w.shape[0]
    shifts = (jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(bits))
    c = ((w[:, :, None] >> shifts) & jnp.uint32(q - 1)).astype(jnp.int32)
    c = c.reshape(bm, -1)                             # (bm, COLS) colors
    anchor = a_ref[...].astype(jnp.float32)
    u = u_ref[...]
    t = anchor / s - u
    k_a = jnp.round(t).astype(jnp.int32)
    delta = jnp.bitwise_and(c - k_a + (q // 2), q - 1) - (q // 2)
    k = k_a + delta
    if coords:
        o_ref[...] = k
        return
    z = (k.astype(jnp.float32) + u) * s
    if avg_cnt is not None:
        z = (z + anchor * avg_cnt) * (1.0 / (avg_cnt + 1))
    o_ref[...] = z.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q", "bits", "n", "avg_cnt",
                                             "mode", "block_rows",
                                             "interpret"))
def lattice_decode_pallas(words: jax.Array, anchor: jax.Array, u: jax.Array,
                          s: jax.Array, *, q: int, bits: int, n: int,
                          avg_cnt: Optional[int] = None, mode: str = "point",
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = True) -> jax.Array:
    """Decode packed words against flat anchor (N,).

    mode="point": returns z (N,) f32; avg_cnt, if given, fuses the
    running-average epilogue out = (z + anchor*avg_cnt)/(avg_cnt+1).
    mode="coords": returns the int32 coordinates k (N,).
    """
    assert q & (q - 1) == 0 and bits in (2, 4, 8, 16)
    assert mode in ("point", "coords")
    assert avg_cnt is None or mode == "point"
    per = 32 // bits
    tile = block_rows * COLS
    pad = (-n) % tile
    af = jnp.pad(anchor.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    uf = jnp.pad(u.astype(jnp.float32), (0, pad)).reshape(-1, COLS)
    rows = af.shape[0]
    wpad = rows * (COLS // per) - words.shape[0]
    wf = jnp.pad(words, (0, wpad)).reshape(rows, COLS // per)
    scalar_s = jnp.ndim(s) == 0
    if scalar_s:
        sf = jnp.asarray(s, jnp.float32).reshape(1, 1)
        s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    else:
        sf = jnp.pad(s.astype(jnp.float32), (0, pad),
                     constant_values=1.0).reshape(-1, COLS)
        s_spec = pl.BlockSpec((block_rows, COLS), lambda i: (i, 0))
    bm = block_rows
    out_dtype = jnp.int32 if mode == "coords" else jnp.float32
    out = pl.pallas_call(
        functools.partial(_decode_kernel, q=q, bits=bits, avg_cnt=avg_cnt,
                          scalar_s=scalar_s, coords=(mode == "coords")),
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, COLS // per), lambda i: (i, 0)),
            pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
            pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
            s_spec,
        ],
        out_specs=pl.BlockSpec((bm, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), out_dtype),
        interpret=interpret,
    )(wf, af, uf, sf)
    return out.reshape(-1)[:n]
