"""recurrentgemma-9b  [hybrid] 38L d4096 16H (MQA kv=1) ff12288 V256000 —
RG-LRU + local attention 1:2 (window 2048).  [arXiv:2402.19427]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="recurrentgemma-9b", family="hybrid", n_layers=38,
                       d_model=4096, n_heads=16, n_kv=1, head_dim=256,
                       d_ff=12288, vocab=256000, act="swiglu",
                       window=2048, lru_width=4096, conv_width=4,
                       pattern=("rec", "rec", "attn"))


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="recurrentgemma-smoke", family="hybrid",
                       n_layers=5, d_model=64, n_heads=4, n_kv=1, head_dim=16,
                       d_ff=128, vocab=257, act="swiglu", window=16,
                       lru_width=64, conv_width=4,
                       pattern=("rec", "rec", "attn"))
