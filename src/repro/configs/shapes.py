"""Assigned input-shape grid (same four cells for every LM arch)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode | long_decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (DESIGN.md section Arch-applicability); every assigned arch has a decoder,
# so decode shapes run everywhere.
LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(family: str, shape: str) -> bool:
    if shape == "long_500k":
        return family in LONG_OK_FAMILIES
    return True
