"""Per-arch configs (--arch <id>); see registry.py."""
