"""mamba2-1.3b  [ssm] 48L d2048 attn-free V50280, SSD state=128.
[arXiv:2405.21060]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="mamba2-1.3b", family="ssm", n_layers=48,
                       d_model=2048, n_heads=0, n_kv=0, head_dim=0,
                       d_ff=0, vocab=50280, ssm_state=128, ssm_expand=2,
                       ssm_headdim=64, ssm_chunk=256, conv_width=4)


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="mamba2-smoke", family="ssm", n_layers=2,
                       d_model=64, n_heads=0, n_kv=0, head_dim=0, d_ff=0,
                       vocab=257, ssm_state=16, ssm_expand=2, ssm_headdim=8,
                       ssm_chunk=16, conv_width=4)
