"""nemotron-4-340b  [dense] 96L d18432 96H (GQA kv=8) ff73728 V256000 —
squared-ReLU MLP.  [arXiv:2402.16819]"""
from repro.models.config import ModelConfig

# 340B-class: bf16 optimizer moments + microbatching (see launch/dryrun.py)
TRAIN_OVERRIDES = {"opt_state_dtype": "bfloat16", "microbatch": 8,
                   "opt_name": "momentum"}


def config() -> ModelConfig:
    return ModelConfig(arch="nemotron-4-340b", family="dense", n_layers=96,
                       d_model=18432, n_heads=96, n_kv=8, head_dim=192,
                       d_ff=73728, vocab=256000, act="squared_relu",
                       rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="nemotron-smoke", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv=2, head_dim=16,
                       d_ff=256, vocab=257, act="squared_relu")
