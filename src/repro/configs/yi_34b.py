"""yi-34b  [dense] 60L d7168 56H (GQA kv=8) ff20480 V64000 — llama-arch.
56 heads on tp=16 exercises the partial head-replication path (8 shards x 2).
[arXiv:2403.04652]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="yi-34b", family="dense", n_layers=60,
                       d_model=7168, n_heads=56, n_kv=8, head_dim=128,
                       d_ff=20480, vocab=64000, act="swiglu",
                       rope_theta=5_000_000.0)


def smoke_config() -> ModelConfig:
    # 6 heads on tp>1 keeps the replication path exercised in smoke tests
    return ModelConfig(arch="yi-34b-smoke", family="dense", n_layers=2,
                       d_model=64, n_heads=6, n_kv=2, head_dim=16,
                       d_ff=128, vocab=257, act="swiglu")
