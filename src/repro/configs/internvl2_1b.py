"""internvl2-1b  [vlm] InternViT (stub) + InternLM2 24L d896 14H (kv=2)
ff4864 V151655.  Patch embeddings precomputed by input_specs.
[arXiv:2404.16821]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="internvl2-1b", family="vlm", n_layers=24,
                       d_model=896, n_heads=14, n_kv=2, head_dim=64,
                       d_ff=4864, vocab=151655, act="swiglu",
                       rope_theta=1_000_000.0, img_tokens=256)


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="internvl2-smoke", family="vlm", n_layers=2,
                       d_model=64, n_heads=4, n_kv=2, head_dim=16,
                       d_ff=128, vocab=257, act="swiglu", img_tokens=8)
