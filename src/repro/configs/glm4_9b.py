"""glm4-9b  [dense] 40L d4096 32H (GQA kv=2) ff13696 V151552 — RoPE, GQA.
[hf:THUDM/glm-4-9b]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="glm4-9b", family="dense", n_layers=40,
                       d_model=4096, n_heads=32, n_kv=2, head_dim=128,
                       d_ff=13696, vocab=151552, act="swiglu",
                       rope_theta=10_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="glm4-9b-smoke", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv=2, head_dim=16,
                       d_ff=128, vocab=257, act="swiglu")
