"""whisper-small  [audio] enc-dec 12L each, d768 12H MHA ff3072 V51865.
Conv frontend STUBBED: input_specs feeds precomputed frame embeddings.
[arXiv:2212.04356]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="whisper-small", family="encdec", n_layers=12,
                       d_model=768, n_heads=12, n_kv=12, head_dim=64,
                       d_ff=3072, vocab=51865, act="gelu",
                       enc_layers=12, enc_seq=1500)


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="whisper-smoke", family="encdec", n_layers=2,
                       d_model=64, n_heads=4, n_kv=4, head_dim=16,
                       d_ff=128, vocab=257, act="gelu",
                       enc_layers=2, enc_seq=24)
