"""Arch registry: --arch <id> -> config module."""
import importlib

ARCHS = {
    "glm4-9b": "glm4_9b",
    "qwen3-32b": "qwen3_32b",
    "nemotron-4-340b": "nemotron_4_340b",
    "yi-34b": "yi_34b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "whisper-small": "whisper_small",
    "mamba2-1.3b": "mamba2_13b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-1b": "internvl2_1b",
}


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[name]}")


def config(name: str):
    return get(name).config()


def smoke_config(name: str):
    return get(name).smoke_config()


def train_overrides(name: str) -> dict:
    return getattr(get(name), "TRAIN_OVERRIDES", {})
