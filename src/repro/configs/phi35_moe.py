"""phi3.5-moe-42b-a6.6b  [moe] 32L d4096 32H (GQA kv=8) ff6400 V32064,
16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32,
                       d_model=4096, n_heads=32, n_kv=8, head_dim=128,
                       d_ff=6400, vocab=32064, act="swiglu",
                       n_experts=16, top_k=2)


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="phi35-moe-smoke", family="moe", n_layers=2,
                       d_model=64, n_heads=4, n_kv=2, head_dim=16,
                       d_ff=64, vocab=257, act="swiglu", n_experts=4, top_k=2)
