"""qwen3-32b  [dense] 64L d5120 64H (GQA kv=8) ff25600 V151936 — qk_norm.
[hf:Qwen/Qwen3-32B family]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(arch="qwen3-32b", family="dense", n_layers=64,
                       d_model=5120, n_heads=64, n_kv=8, head_dim=128,
                       d_ff=25600, vocab=151936, act="swiglu", qk_norm=True,
                       rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(arch="qwen3-32b-smoke", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv=2, head_dim=16,
                       d_ff=128, vocab=257, act="swiglu", qk_norm=True)
