"""Reproduction of "New Bounds For Distributed Mean Estimation and Variance
Reduction" (ICLR 2021) grown into a jax_pallas training/serving system.

Importing ``repro`` installs small jax forward-compat aliases (see
:mod:`repro._compat`) so the sources — written against the current
``jax.shard_map`` / ``jax.sharding.AxisType`` API — also run on the pinned
0.4.x jax in the CI image.
"""
from repro import _compat as _compat  # noqa: F401  (side-effect: jax shims)
