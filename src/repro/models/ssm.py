"""Mamba-2 SSD (state-space duality) layer — chunked train/prefill + O(1) decode.

Follows the minimal SSD algorithm (Mamba-2 paper, Listing 1), adapted to
manual TP: heads and the inner dim are sharded over ``model``; the shared
B/C projections (ngroups=1) are tp-replicated; the gated RMSNorm over the
sharded inner dim psums its sum-of-squares over tp.

Shapes (per rank): inner = expand*D / tp channels, H_loc = inner/headdim
heads, state N = cfg.ssm_state, chunk Q = cfg.ssm_chunk.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx, psum_tp

Array = jax.Array


def _segsum(a: Array) -> Array:
    """a: (..., q) -> (..., q, q) lower-tri segment sums: S[i,j]=sum_{j<k<=i} a_k."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int) -> tuple[Array, Array]:
    """SSD over a full sequence.

    xh: (B, S, H, P)   per-head inputs (already includes dt weighting below)
    dt: (B, S, H)      positive step sizes
    A:  (H,)           negative decay rates (A = -exp(A_log))
    Bm: (B, S, N)      shared input maps (ngroups=1)
    Cm: (B, S, N)      shared output maps
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q

    xc = xh.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = Bm.reshape(b, nc, q, n)
    Cc = Cm.reshape(b, nc, q, n)

    da = dtc * A[None, None, None, :]            # (b,nc,q,h)  log-decay per step
    da_cs = jnp.cumsum(da, axis=2)               # within-chunk cumulative

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(jnp.moveaxis(da, 2, 3)))           # (b,nc,h,q,q)
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc,
                   preferred_element_type=jnp.float32)      # (b,nc,q,q)
    xdt = xc * dtc[..., None]                               # (b,nc,q,h,p)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", G, L, xdt,
                        preferred_element_type=jnp.float32)

    # 2) chunk end-states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)     # (b,nc,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_states, xdt,
                        preferred_element_type=jnp.float32)  # (b,nc,h,p,n)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])               # (b,nc,h)

    def step(carry, inp):
        st, dec = inp                                       # (b,h,p,n),(b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                   # emit state *before* chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                # (b,nc,h,p,n)

    # 4) inter-chunk output
    state_decay_out = jnp.exp(da_cs)                        # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, prev_states,
                       state_decay_out, preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    return y.astype(xh.dtype), final


def ssd_decode_step(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                    state: Array) -> tuple[Array, Array]:
    """One-token recurrent update.  x: (B,H,P), dt: (B,H), Bm/Cm: (B,N),
    state: (B,H,P,N) -> (y (B,H,P), new_state)."""
    dec = jnp.exp(dt * A[None, :])                          # (B,H)
    upd = jnp.einsum("bhp,bn,bh->bhpn", x, Bm, dt,
                     preferred_element_type=jnp.float32)
    new = state * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, Cm,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype), new


def _dw_conv(x: Array, kernel: Array, cache: Optional[Array] = None):
    """Depthwise causal conv over seq.  x: (B,S,C), kernel: (W,C).

    With cache (B, W-1, C): single-step mode (S==1), returns updated cache.
    """
    w = kernel.shape[0]
    if cache is not None:
        buf = jnp.concatenate([cache, x], axis=1)           # (B, W, C)
        y = jnp.einsum("bwc,wc->bc", buf, kernel)[:, None, :]
        return y.astype(x.dtype), buf[:, 1:]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(w))
    return y.astype(x.dtype), None


def mamba2_block(x: Array, wts: dict, cfg: ModelConfig, ctx: ShardCtx,
                 state: Optional[dict] = None):
    """Full Mamba-2 mixer.  x: (B, S, D) -> (out partial (B,S,D), new_state).

    wts: {"wz": (D, I_loc), "wx": (D, I_loc), "wbc": (D, 2N), "wdt": (D, Hl),
          "conv_x": (W, I_loc), "conv_bc": (W, 2N), "A_log": (Hl,),
          "D": (Hl,), "dt_bias": (Hl,), "norm": (I_loc,)}
    state: {"ssm": (B,Hl,P,N), "conv_x": (B,W-1,I_loc), "conv_bc": (B,W-1,2N)}
    """
    B_, S, D = x.shape
    P = cfg.ssm_headdim
    N = cfg.ssm_state
    i_loc = wts["wx"].shape[1]
    h_loc = i_loc // P

    z = x @ wts["wz"]                                       # (B,S,I_loc)
    xi = x @ wts["wx"]
    bc = x @ wts["wbc"]                                     # (B,S,2N)
    dt = jax.nn.softplus((x @ wts["wdt"]).astype(jnp.float32)
                         + wts["dt_bias"].astype(jnp.float32))  # (B,S,Hl)
    A = -jnp.exp(wts["A_log"].astype(jnp.float32))          # (Hl,)

    decode = state is not None and S == 1
    if decode:
        xi, cx = _dw_conv(xi, wts["conv_x"], state["conv_x"])
        bc, cb = _dw_conv(bc, wts["conv_bc"], state["conv_bc"])
    else:
        xi, _ = _dw_conv(xi, wts["conv_x"])
        bc, _ = _dw_conv(bc, wts["conv_bc"])
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x.dtype)
    Bm, Cm = bc[..., :N], bc[..., N:]

    xh = xi.reshape(B_, S, h_loc, P)
    if decode:
        y, new_ssm = ssd_decode_step(xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                     state["ssm"])
        y = y[:, None]
        new_state = {"ssm": new_ssm, "conv_x": cx, "conv_bc": cb}
    else:
        y, final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
        new_state = {"ssm": final,
                     "conv_x": None, "conv_bc": None}
    y = y + xh * wts["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B_, S, i_loc)

    # gated RMSNorm over the (sharded) inner dim: psum the sum-of-squares
    yf = (y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)).astype(jnp.float32)
    ss = psum_tp(jnp.sum(yf * yf, axis=-1, keepdims=True), ctx)
    inner_total = i_loc * ctx.tp
    yn = yf * jax.lax.rsqrt(ss / inner_total + cfg.norm_eps)
    yn = (yn * wts["norm"].astype(jnp.float32)).astype(x.dtype)

    out = yn @ wts["wo"]                                    # partial over tp
    return out, new_state
