"""RG-LRU recurrent block (RecurrentGemma / Griffin) + local-attention hybrid.

The RG-LRU recurrence (per channel c):
    r_t = sigmoid(w_r * x_t + b_r)            (recurrence gate, diagonal)
    i_t = sigmoid(w_i * x_t + b_i)            (input gate, diagonal)
    log a_t = -c0 * softplus(lambda) * r_t    (c0 = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

computed with an associative scan over the sequence (parallel prefix — the
TPU-friendly replacement for the GPU linear-scan kernel).  Channels are
sharded over
tp; the gates are diagonal (channel-local), a documented simplification of
RecurrentGemma's block-diagonal gates that keeps the recurrence exactly
channel-parallel.

The hybrid block pattern (2 recurrent : 1 local attention) is assembled in
models/transformer.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx
from repro.models.ssm import _dw_conv

Array = jax.Array

C0 = 8.0


def rg_lru(x: Array, wts: dict, state: Optional[Array] = None):
    """x: (B, S, C_loc).  state: (B, C_loc) hidden.  Returns (y, new_state).

    wts: {"w_r","b_r","w_i","b_i","lam": (C_loc,)}
    """
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * wts["w_r"] + wts["b_r"])
    i = jax.nn.sigmoid(xf * wts["w_i"] + wts["b_i"])
    log_a = -C0 * jax.nn.softplus(wts["lam"]) * r            # (B,S,C)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)

    if state is not None and x.shape[1] == 1:
        h = a[:, 0] * state + gated[:, 0]
        return h.astype(x.dtype)[:, None], h

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, a2 * b1 + b2

    if state is not None:
        gated = gated.at[:, 0].add(a[:, 0] * state)
    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return hh.astype(x.dtype), hh[:, -1]


def recurrent_block(x: Array, wts: dict, cfg: ModelConfig, ctx: ShardCtx,
                    state: Optional[dict] = None):
    """Griffin recurrent block.  x: (B,S,D) -> (partial out (B,S,D), state).

    wts: {"wy": (D, C_loc), "wx": (D, C_loc), "conv": (W, C_loc),
          gates..., "wo": (C_loc, D)}
    state: {"lru": (B, C_loc), "conv": (B, W-1, C_loc)}
    """
    ybr = jax.nn.gelu((x @ wts["wy"]).astype(jnp.float32)).astype(x.dtype)
    xbr = x @ wts["wx"]
    if state is not None and x.shape[1] == 1:
        xbr, conv_cache = _dw_conv(xbr, wts["conv"], state["conv"])
        h, lru_state = rg_lru(xbr, wts, state["lru"])
        new_state = {"lru": lru_state, "conv": conv_cache}
    else:
        xbr, _ = _dw_conv(xbr, wts["conv"])
        init = state["lru"] if state is not None else None
        h, lru_state = rg_lru(xbr, wts, init)
        new_state = {"lru": lru_state, "conv": None}
    out = (h * ybr) @ wts["wo"]                              # partial over tp
    return out, new_state
