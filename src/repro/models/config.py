"""Architecture configuration dataclass shared by every model family."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"         # swiglu | squared_relu | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    conv_width: int = 4
    # hybrid (RG-LRU + local attention)
    window: int = 0             # local-attention window (0 = full)
    pattern: tuple[str, ...] = ()   # block pattern, e.g. ("rec","rec","attn")
    lru_width: int = 0
    # encoder-decoder
    enc_layers: int = 0
    enc_seq: int = 0            # e.g. whisper 1500 frames
    # vlm
    img_tokens: int = 0
    norm_eps: float = 1e-5
    emb_scale: float = 1.0
    tie_embeddings: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv, 1)

    def kv_groups(self, tp: int) -> int:
        """g1 for decode: largest divisor of tp that divides n_kv."""
        g = 1
        k = 2
        while k <= tp:
            if tp % k == 0 and self.n_kv % k == 0:
                g = k
            k *= 2
        return g

    def param_count(self) -> int:
        """Approximate dense-equivalent parameter count (global)."""
        D, H, KV, hd, F, V, L = (self.d_model, self.n_heads, self.n_kv,
                                 self.head_dim, self.d_ff, self.vocab,
                                 self.n_layers)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.family == "ssm":
            inner = self.ssm_expand * D
            per_layer = D * (2 * inner + 2 * self.ssm_groups * self.ssm_state
                             + inner // self.ssm_headdim) + inner * D
        elif self.family == "moe":
            mlp = self.n_experts * (3 * D * F if self.act == "swiglu" else 2 * D * F)
            per_layer = attn + mlp
        else:
            mlp = 3 * D * F if self.act == "swiglu" else 2 * D * F
            per_layer = attn + mlp
        total = L * per_layer + 2 * V * D
        if self.family == "encdec":
            total += self.enc_layers * (attn + per_layer - attn) + L * attn  # cross-attn
        return int(total)

    def active_param_count(self) -> int:
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        attn = (D * self.n_heads * self.head_dim + 2 * D * self.n_kv * self.head_dim
                + self.n_heads * self.head_dim * D)
        mlp = self.top_k * (3 * D * F if self.act == "swiglu" else 2 * D * F)
        return int(L * (attn + mlp) + 2 * self.vocab * D)
