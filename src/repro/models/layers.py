"""Manually tensor-parallel transformer layers (inside shard_map).

Conventions:
  * activations: bf16, reductions/norms in f32;
  * weights arrive *gathered* (TP-local logical shapes from sharding.py);
  * attention shards query heads over tp; KV projections are replicated
    (n_kv < tp for every assigned config), so K/V are computed redundantly
    — the flops are negligible and the replicated-weight gradients are
    psum'd over tp by the gather's custom vjp;
  * with ``ctx.seq_parallel`` the residual stream is sharded over tokens
    (sequence dim); blocks all-gather tokens on entry and reduce-scatter
    partial outputs on exit — same bytes as the psum they replace, but
    activation memory drops by 1/tp (Megatron-SP).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.sharding import (ShardCtx, psum_tp, all_gather_tp,
                                   reduce_scatter_tp, tp_index)

Array = jax.Array

ATTN_CHUNK = 512          # query-chunk length for memory-bounded attention


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_angles(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """positions: (...,) int32 -> cos/sin (..., head_dim/2) f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., S, n, head_dim); cos/sin: (S, head_dim/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # cos/sin need a heads axis: (..., S, 1, half)
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


def head_shards(cfg: ModelConfig, ctx: ShardCtx) -> int:
    """Distinct query-head shards: the largest power-of-two divisor of tp
    that divides n_heads (yi-34b 56H -> 8, whisper 12H -> 4, internvl
    14H -> 2, everything else -> tp)."""
    g = 1
    k = 2
    while k <= ctx.tp:
        if ctx.tp % k == 0 and cfg.n_heads % k == 0:
            g = k
        k *= 2
    return g


def head_repl(cfg: ModelConfig, ctx: ShardCtx) -> int:
    """Replication factor of the attention weights across tp."""
    return ctx.tp // head_shards(cfg, ctx)


def local_heads(cfg: ModelConfig, ctx: ShardCtx) -> int:
    return cfg.n_heads // head_shards(cfg, ctx)


def _kv_map_local(cfg: ModelConfig, ctx: ShardCtx) -> Array:
    """kv-head index for each local query head (GQA grouping)."""
    h_loc = local_heads(cfg, ctx)
    repl = head_repl(cfg, ctx)
    shard = tp_index(ctx) // repl
    heads = shard * h_loc + jnp.arange(h_loc)
    return heads // cfg.q_per_kv


def _softmax_attend(q: Array, k: Array, v: Array, mask: Array,
                    scale: float) -> Array:
    """q: (B,Sq,h,d) k/v: (B,Sk,h,d) mask: (Sq,Sk) bool -> (B,Sq,h,d)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention(xg: Array, w: dict, cfg: ModelConfig, ctx: ShardCtx, *,
              positions: Array, causal: bool = True, window: int = 0,
              kv_out: bool = False):
    """Training/prefill attention over gathered tokens.

    xg: (B, S, D); returns partial output (B, S, D) — caller psums/scatters.
    w: {"wq": (D, Hl*hd), "wk": (D, KV*hd), "wv": ..., "wo": (Hl*hd, D),
        optional "qn","kn": (hd,)}
    """
    B, S, D = xg.shape
    hd = cfg.head_dim
    h_loc = local_heads(cfg, ctx)
    kv = cfg.n_kv

    q = (xg @ w["wq"]).reshape(B, S, h_loc, hd)
    k = (xg @ w["wk"]).reshape(B, S, kv, hd)
    v = (xg @ w["wv"]).reshape(B, S, kv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, w["qn"], cfg.norm_eps)
        k = rms_norm(k, w["kn"], cfg.norm_eps)

    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    kv_idx = _kv_map_local(cfg, ctx)                  # (h_loc,)
    k_h = jnp.take(k, kv_idx, axis=2)                 # (B,S,h_loc,hd)
    v_h = jnp.take(v, kv_idx, axis=2)
    scale = 1.0 / np.sqrt(hd)

    if S <= ATTN_CHUNK:
        qpos = positions
        kpos = positions
        mask = jnp.ones((S, S), bool)
        if causal:
            mask = qpos[:, None] >= kpos[None, :]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        out = _softmax_attend(q, k_h, v_h, mask, scale)
    else:
        # query-chunked attention (memory-bounded); scan over chunks
        C = ATTN_CHUNK
        pad = (-S) % C
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pp = jnp.pad(positions, (0, pad), constant_values=-1)
        nchunk = qp.shape[1] // C
        qc = qp.reshape(B, nchunk, C, h_loc, hd).swapaxes(0, 1)
        pc = pp.reshape(nchunk, C)

        def body(carry, inp):
            qi, pi = inp
            mask = jnp.ones((C, S), bool)
            if causal:
                mask = pi[:, None] >= positions[None, :]
            if window:
                mask &= (pi[:, None] - positions[None, :]) < window
            return carry, _softmax_attend(qi, k_h, v_h, mask, scale)

        _, oc = jax.lax.scan(body, None, (qc, pc))
        out = oc.swapaxes(0, 1).reshape(B, nchunk * C, h_loc, hd)[:, :S]

    out = out.reshape(B, S, h_loc * hd) @ w["wo"]     # partial over tp
    if kv_out:
        return out, (k, v)
    return out


def mlp(xg: Array, w: dict, cfg: ModelConfig) -> Array:
    """Gathered-token MLP; returns partial output (psum over tp by caller).

    swiglu: w = {wg (D,Fl), wu (D,Fl), wd (Fl,D)}
    squared_relu / gelu: w = {wi (D,Fl), wd (Fl,D)}
    """
    if cfg.act == "swiglu":
        h = jax.nn.silu((xg @ w["wg"]).astype(jnp.float32))
        h = (h * (xg @ w["wu"]).astype(jnp.float32)).astype(xg.dtype)
    elif cfg.act == "squared_relu":
        h = jax.nn.relu((xg @ w["wi"]).astype(jnp.float32))
        h = (h * h).astype(xg.dtype)
    else:
        h = jax.nn.gelu((xg @ w["wi"]).astype(jnp.float32)).astype(xg.dtype)
    return h @ w["wd"]


# ---------------------------------------------------------------------------
# Sequence-parallel entry/exit
# ---------------------------------------------------------------------------

def sp_enter(x: Array, ctx: ShardCtx) -> Array:
    """(B, S/tp, D) -> (B, S, D)."""
    return all_gather_tp(x, ctx, axis=1) if ctx.seq_parallel else x


def sp_exit(partial_out: Array, ctx: ShardCtx) -> Array:
    """partial (B, S, D) -> reduced (B, S/tp, D) [SP] or psum (B,S,D)."""
    if ctx.seq_parallel:
        return reduce_scatter_tp(partial_out, ctx, axis=1)
    return psum_tp(partial_out, ctx)


def token_slice(x: Array, ctx: ShardCtx) -> Array:
    """(B, S, D) -> this rank's (B, S/tp, D) token slice."""
    if ctx.tp == 1:
        return x
    s_loc = x.shape[1] // ctx.tp
    return jax.lax.dynamic_slice_in_dim(x, tp_index(ctx) * s_loc, s_loc, 1)


def attn_exit(att: Array, cfg: ModelConfig, ctx: ShardCtx) -> Array:
    """Exit for attention partials.  When heads are partially replicated
    (repl > 1), every replica contributes an identical copy of its shard's
    partial, so the psum / reduce-scatter over-counts by exactly repl —
    divide it back out."""
    repl = head_repl(cfg, ctx)
    out = sp_exit(att, ctx)
    if repl > 1:
        out = out / repl
    return out


# ---------------------------------------------------------------------------
# Vocab-parallel embedding / logits / cross-entropy
# ---------------------------------------------------------------------------

def vp_embed(tokens: Array, emb: Array, ctx: ShardCtx) -> Array:
    """tokens (B,S) int32; emb (V/tp, D) local vocab slice -> (B,S,D)."""
    v_loc = emb.shape[0]
    off = tp_index(ctx) * v_loc
    local = tokens - off
    ok = (local >= 0) & (local < v_loc)
    safe = jnp.clip(local, 0, v_loc - 1)
    out = jnp.take(emb, safe, axis=0)
    out = jnp.where(ok[..., None], out, 0)
    return psum_tp(out, ctx)


def vp_ce_loss(x: Array, emb_out: Array, targets: Array, ctx: ShardCtx,
               mask: Optional[Array] = None) -> Array:
    """Vocab-parallel cross entropy without materializing full logits.

    x: (T, D) final hidden; emb_out: (V/tp, D); targets: (T,) int32.
    Returns mean NLL over masked tokens (replicated over tp).
    """
    logits = (x.astype(jnp.float32) @ emb_out.astype(jnp.float32).T)  # (T, V/tp)
    m_loc = jnp.max(logits, axis=-1)
    m_loc = jax.lax.stop_gradient(m_loc)
    m = jax.lax.pmax(m_loc, ctx.tp_axis) if ctx.tp > 1 else m_loc
    z = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
    z = psum_tp(z, ctx)
    v_loc = emb_out.shape[0]
    off = tp_index(ctx) * v_loc
    local = targets - off
    ok = (local >= 0) & (local < v_loc)
    safe = jnp.clip(local, 0, v_loc - 1)
    tgt_logit = jnp.take_along_axis(logits, safe[:, None], axis=1)[:, 0]
    tgt_logit = psum_tp(jnp.where(ok, tgt_logit, 0.0), ctx)
    nll = jnp.log(z) + m - tgt_logit
    if mask is not None:
        mf = mask.astype(jnp.float32)
        return jnp.sum(nll * mf) / jnp.maximum(jnp.sum(mf), 1.0)
    return jnp.mean(nll)
