"""Serving path: prefill + single-token decode with sharded KV caches.

Decode cache sharding (DESIGN §3 "SP"): the ``model`` axis is factored into
``g1`` kv-head groups x ``g2`` sequence shards (g1 = largest power-of-two
divisor of tp that divides n_kv).  Rank r = (i, j) holds

    cache[k|v]: (B_loc, n_kv/g1, S_max/g2, head_dim)

i.e. kv-head group i, sequence chunk j.  A decode step:

  1. gathers its head-group's query projection over the g2-subgroup
     (weights stay in the training TP layout — no serving-specific copy),
  2. attends its query group against its local seq chunk,
  3. merges partial softmax stats with psum/pmax over the g2-subgroup
     (flash-decoding combine, via ``axis_index_groups``),
  4. projects out through its own wo shard and psums over the full tp axis.

Window attention (recurrentgemma local blocks) uses a replicated ring-buffer
cache instead (W << S so replication is cheap) with head-sharded queries.

SSM / RG-LRU decode carry O(1) recurrent state; no KV growth.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as LY
from repro.models import ssm as SSM
from repro.models import rglru as RG
from repro.models import moe as MOE
from repro.models.sharding import (ShardCtx, gather_param, make_gathers,
                                   psum_tp, tp_index)
from repro.models.transformer import (all_metas, n_scan_steps, _gather_tree,
                                      _leaf_key, _sub)

Array = jax.Array


def groups_of(cfg: ModelConfig, ctx: ShardCtx) -> tuple[int, int]:
    g1 = cfg.kv_groups(ctx.tp)
    return g1, ctx.tp // g1


def seq_groups(cfg: ModelConfig, ctx: ShardCtx) -> list[list[int]]:
    g1, g2 = groups_of(cfg, ctx)
    return [[i * g2 + j for j in range(g2)] for i in range(g1)]


# ---------------------------------------------------------------------------
# Cache shapes (ShapeDtypeStruct builders for the dry-run + init for tests)
# ---------------------------------------------------------------------------

def cache_struct(cfg: ModelConfig, ctx: ShardCtx, batch_local: int,
                 s_max: int, dtype=jnp.bfloat16, kv_quant: bool = False) -> dict:
    """Local (per-device) cache pytree shapes.

    kv_quant: store k/v as int8 with per-(layer,batch,head) scales — a
    beyond-paper application of the quantization idea to the decode memory
    term (halves KV-cache HBM traffic vs bf16; see EXPERIMENTS.md "Perf").
    """
    L = n_scan_steps(cfg)
    B = batch_local
    if cfg.family == "ssm":
        inner = cfg.ssm_expand * cfg.d_model // ctx.tp
        h_loc = inner // cfg.ssm_headdim
        return {
            "ssm": (L, B, h_loc, cfg.ssm_headdim, cfg.ssm_state),
            "conv_x": (L, B, cfg.conv_width - 1, inner),
            "conv_bc": (L, B, cfg.conv_width - 1, 2 * cfg.ssm_state),
        }
    if cfg.family == "hybrid":
        c_loc = (cfg.lru_width or cfg.d_model) // ctx.tp
        W = cfg.window
        d = {
            "lru1": (L, B, c_loc), "conv1": (L, B, cfg.conv_width - 1, c_loc),
            "lru2": (L, B, c_loc), "conv2": (L, B, cfg.conv_width - 1, c_loc),
            # replicated ring-buffer window cache for the local-attn block
            "wk": (L, B, W, cfg.n_kv, cfg.head_dim),
            "wv": (L, B, W, cfg.n_kv, cfg.head_dim),
        }
        for t in range(cfg.n_layers % 3):          # unscanned tail rec layers
            d[f"tail{t}_lru"] = (B, c_loc)
            d[f"tail{t}_conv"] = (B, cfg.conv_width - 1, c_loc)
        return d
    g1, g2 = groups_of(cfg, ctx)
    kv_loc = cfg.n_kv // g1
    s_loc = -(-s_max // g2)
    shapes = {
        "k": (L, B, kv_loc, s_loc, cfg.head_dim),
        "v": (L, B, kv_loc, s_loc, cfg.head_dim),
    }
    if kv_quant:
        # per-POSITION scales: old entries are immutable (a running per-head
        # scale would silently inflate previously written entries)
        shapes["k_scale"] = (L, B, kv_loc, s_loc)
        shapes["v_scale"] = (L, B, kv_loc, s_loc)
    if cfg.family == "encdec":
        shapes["xk"] = (cfg.n_layers, B, cfg.enc_seq, cfg.n_kv, cfg.head_dim)
        shapes["xv"] = (cfg.n_layers, B, cfg.enc_seq, cfg.n_kv, cfg.head_dim)
    return shapes


def cache_dtype(name: str, kv_quant: bool):
    if kv_quant and name in ("k", "v"):
        return jnp.int8
    if name.endswith("_scale"):
        return jnp.float32
    return jnp.bfloat16


def cache_zeros(cfg: ModelConfig, ctx: ShardCtx, batch_local: int,
                s_max: int, dtype=jnp.bfloat16, kv_quant: bool = False) -> dict:
    return {k: jnp.zeros(s, cache_dtype(k, kv_quant))
            for k, s in cache_struct(cfg, ctx, batch_local, s_max,
                                     kv_quant=kv_quant).items()}


# ---------------------------------------------------------------------------
# Decode attention (full-context, 2D-sharded cache)
# ---------------------------------------------------------------------------

def decode_attention(x: Array, wts: dict, ck: Array, cv: Array, pos: Array,
                     cfg: ModelConfig, ctx: ShardCtx,
                     kscale: Optional[Array] = None,
                     vscale: Optional[Array] = None):
    """x: (B, D) one token per sequence.  ck/cv: (B, kv_loc, S_loc, hd).

    With kscale/vscale given, ck/cv are int8 and are dequantized on the fly
    (absmax/127 per (batch, kv head, position); scales fold into the logits
    and probabilities post-einsum so the cache is read in int8).
    Returns (out (B,D) partial, new ck, new cv[, new kscale, new vscale]).
    """
    B, D = x.shape
    hd = cfg.head_dim
    g1, g2 = groups_of(cfg, ctx)
    kv_loc = cfg.n_kv // g1
    hg = cfg.n_heads // g1                       # query heads in my group
    h_loc = LY.local_heads(cfg, ctx)
    repl = LY.head_repl(cfg, ctx)
    shards = LY.head_shards(cfg, ctx)
    assert shards % g1 == 0, (shards, g1)
    s_loc = ck.shape[2]

    r = tp_index(ctx)
    i = r // g2 if g2 > 0 else r
    j = jnp.mod(r, g2) if g2 > 1 else jnp.zeros((), jnp.int32)
    sg = seq_groups(cfg, ctx)

    # -- group query projection: gather wq over the seq-subgroup --
    if h_loc == hg:
        wq_g = wts["wq"]                          # shard already covers group
    else:
        wq_g = jax.lax.all_gather(wts["wq"], ctx.tp_axis, axis=1, tiled=True,
                                  axis_index_groups=sg)
        if repl > 1:
            # dedupe replicated shard runs: keep every repl-th block
            wq_g = wq_g.reshape(D, g2, h_loc * hd)[:, ::repl].reshape(D, hg * hd)
    q = (x @ wq_g).reshape(B, hg, hd)

    # -- new k/v for my kv group (wk/wv replicated; slice group i) --
    k_all = (x @ wts["wk"]).reshape(B, cfg.n_kv, hd)
    v_all = (x @ wts["wv"]).reshape(B, cfg.n_kv, hd)
    if g1 > 1:
        k_new = jax.lax.dynamic_slice_in_dim(k_all, i * kv_loc, kv_loc, 1)
        v_new = jax.lax.dynamic_slice_in_dim(v_all, i * kv_loc, kv_loc, 1)
    else:
        k_new, v_new = k_all, v_all

    if cfg.qk_norm:
        q = LY.rms_norm(q, wts["qn"], cfg.norm_eps)
        k_new = LY.rms_norm(k_new, wts["kn"], cfg.norm_eps)
    cos, sin = LY.rope_angles(pos[None], hd, cfg.rope_theta)   # (1, hd/2)
    q = LY.apply_rope(q[:, None], cos, sin)[:, 0]
    k_new = LY.apply_rope(k_new[:, None], cos, sin)[:, 0]

    # -- write into my seq chunk if I own position pos --
    owner = (pos // s_loc)
    local_pos = jnp.mod(pos, s_loc)
    quant = kscale is not None
    if quant:
        # fresh per-position scale for the new entry (old entries immutable)
        ks_new = jnp.max(jnp.abs(k_new.astype(jnp.float32)), axis=-1)  # (B,kv)
        vs_new = jnp.max(jnp.abs(v_new.astype(jnp.float32)), axis=-1)
        kq = jnp.round(k_new.astype(jnp.float32)
                       / jnp.maximum(ks_new, 1e-9)[..., None] * 127.0)
        vq = jnp.round(v_new.astype(jnp.float32)
                       / jnp.maximum(vs_new, 1e-9)[..., None] * 127.0)
        k_w = jnp.clip(kq, -127, 127).astype(jnp.int8)
        v_w = jnp.clip(vq, -127, 127).astype(jnp.int8)
        upd_ks = jax.lax.dynamic_update_slice(kscale, ks_new[:, :, None],
                                              (0, 0, local_pos))
        upd_vs = jax.lax.dynamic_update_slice(vscale, vs_new[:, :, None],
                                              (0, 0, local_pos))
    else:
        k_w = k_new.astype(ck.dtype)
        v_w = v_new.astype(cv.dtype)
    upd_k = jax.lax.dynamic_update_slice(ck, k_w[:, :, None],
                                         (0, 0, local_pos, 0))
    upd_v = jax.lax.dynamic_update_slice(cv, v_w[:, :, None],
                                         (0, 0, local_pos, 0))
    mine = (owner == j) if g2 > 1 else jnp.array(True)
    ck = jnp.where(mine, upd_k, ck)
    cv = jnp.where(mine, upd_v, cv)
    if quant:
        kscale = jnp.where(mine, upd_ks, kscale)
        vscale = jnp.where(mine, upd_vs, vscale)

    # -- partial attention over my chunk --
    # GQA-batched: group-local head t shares kv head t // q_per_kv; instead
    # of materializing an expanded (B, hg, S, hd) copy of the cache (q_per_kv
    # x duplication, the decode memory hog), reshape q to (B, kv_loc, qpk,
    # hd) and batch the contraction per kv head — the cache is read once, in
    # its stored dtype (int8 dequant fuses into the dot on TPU).
    qpk = hg // max(kv_loc, 1)
    q4 = q.reshape(B, kv_loc, qpk, hd).astype(jnp.bfloat16)
    kf = ck.astype(jnp.bfloat16)
    vf = cv.astype(jnp.bfloat16)
    logits = jnp.einsum("bkqd,bksd->bkqs", q4, kf,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    if quant:
        logits = logits * (kscale / 127.0)[:, :, None, :]
    gpos = (j * s_loc if g2 > 1 else 0) + jnp.arange(s_loc)
    valid = gpos <= pos
    logits = jnp.where(valid[None, None, None], logits, -1e30)

    m_loc = jnp.max(logits, axis=-1)             # (B, kv_loc, qpk)
    if g2 > 1:
        m = jax.lax.pmax(m_loc, ctx.tp_axis, axis_index_groups=sg)
    else:
        m = m_loc
    p = jnp.exp(logits - m[..., None])
    l_loc = jnp.sum(p, axis=-1)
    if quant:
        p = p * (vscale / 127.0)[:, :, None, :]   # fold v scales into probs
    o_loc = jnp.einsum("bkqs,bksd->bkqd", p.astype(jnp.bfloat16), vf,
                       preferred_element_type=jnp.float32)
    if g2 > 1:
        l = jax.lax.psum(l_loc, ctx.tp_axis, axis_index_groups=sg)
        o = jax.lax.psum(o_loc, ctx.tp_axis, axis_index_groups=sg)
    else:
        l, o = l_loc, o_loc
    out_g = (o / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    out_g = out_g.reshape(B, hg, hd)             # (B, hg, hd)

    # -- my wo shard covers my h_loc heads: locate my shard within group --
    if h_loc < hg:
        off = (r // repl) * h_loc - i * hg
        out_mine = jax.lax.dynamic_slice_in_dim(out_g, off, h_loc, 1)
    else:
        out_mine = out_g
    out = out_mine.reshape(B, h_loc * hd) @ wts["wo"]  # partial over tp
    if quant:
        return out, ck, cv, kscale, vscale
    return out, ck, cv


def window_decode_attention(x: Array, wts: dict, ck: Array, cv: Array,
                            pos: Array, cfg: ModelConfig, ctx: ShardCtx):
    """Ring-buffer window cache, replicated across tp; heads sharded.

    ck/cv: (B, W, n_kv, hd).  Returns (out partial, ck, cv).
    """
    B, D = x.shape
    hd = cfg.head_dim
    W = ck.shape[1]
    h_loc = LY.local_heads(cfg, ctx)

    q = (x @ wts["wq"]).reshape(B, h_loc, hd)
    k_new = (x @ wts["wk"]).reshape(B, cfg.n_kv, hd)
    v_new = (x @ wts["wv"]).reshape(B, cfg.n_kv, hd)
    cos, sin = LY.rope_angles(pos[None], hd, cfg.rope_theta)
    q = LY.apply_rope(q[:, None], cos, sin)[:, 0]
    k_new = LY.apply_rope(k_new[:, None], cos, sin)[:, 0]

    slot = jnp.mod(pos, W)
    ck = jax.lax.dynamic_update_slice(ck, k_new[:, None].astype(ck.dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v_new[:, None].astype(cv.dtype),
                                      (0, slot, 0, 0))
    kv_map = LY._kv_map_local(cfg, ctx)
    k_h = jnp.take(ck, kv_map, axis=2)           # (B, W, h_loc, hd)
    v_h = jnp.take(cv, kv_map, axis=2)
    logits = jnp.einsum("bhd,bwhd->bhw", q.astype(jnp.float32),
                        k_h.astype(jnp.float32)) / np.sqrt(hd)
    # ring-buffer validity: slot w holds position p_w = pos - ((slot - w) mod W)
    wids = jnp.arange(W)
    p_w = pos - jnp.mod(slot - wids, W)
    valid = (p_w >= 0) & (p_w <= pos) & (pos - p_w < cfg.window)
    logits = jnp.where(valid[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhw,bwhd->bhd", probs, v_h.astype(jnp.float32))
    out = o.astype(x.dtype).reshape(B, h_loc * hd) @ wts["wo"]
    return out, ck, cv


# ---------------------------------------------------------------------------
# serve_step builders
# ---------------------------------------------------------------------------

def _moe_decode(x: Array, wts: dict, cfg: ModelConfig, ctx: ShardCtx) -> Array:
    """MoE for (B, D) decode tokens: pad tokens to a tp multiple, slice."""
    B, D = x.shape
    if ctx.tp == 1:
        out, _ = MOE.moe_mlp(x, wts, cfg, ctx)
        return out
    Bp = -(-B // ctx.tp) * ctx.tp
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    t_loc = Bp // ctx.tp
    sl = jax.lax.dynamic_slice_in_dim(xp, tp_index(ctx) * t_loc, t_loc, 0)
    out, _ = MOE.moe_mlp(sl, wts, cfg, ctx)
    full = jax.lax.all_gather(out, ctx.tp_axis, axis=0, tiled=True)
    return full[:B]


def make_encdec_serve_step(cfg: ModelConfig, ctx: ShardCtx):
    """Whisper-style decoder step: self-attn decode + cross-attn against the
    precomputed encoder K/V cache (xk/xv, built once per audio segment by
    prefill).  cache: {"k","v" (L,B,kv_loc,S_loc,hd), "xk","xv"
    (L,B,Se,KV,hd)}."""
    from repro.models import encdec as ED
    metas = ED.encdec_metas(cfg, ctx)
    gathers = make_gathers(ctx)
    L = cfg.n_layers

    def zero_y():
        return jnp.ones((), jnp.float32)

    def serve_step(params, cache, tokens, pos, key):
        from repro.dist.fsdp import TELE_WIDTH
        B = tokens.shape[0]
        tz = jnp.zeros((TELE_WIDTH,), jnp.float32)
        kt = jax.random.fold_in(key, 0)
        emb = gather_param(params["top"]["embed"], metas["top"]["embed"], ctx,
                           zero_y(), _leaf_key(kt, "embed"), tz, gathers)
        x = LY.vp_embed(tokens[:, 0], emb, ctx)

        def body(carry, xs):
            xc = carry
            lp, lc, idx = xs
            kl = jax.random.fold_in(key, idx + 1)
            ly = {k: zero_y() for k in metas["dec"]}
            lt = {k: tz for k in metas["dec"]}
            wts = _gather_tree(lp, metas["dec"], ctx, ly, kl, lt, gathers)
            a = LY.rms_norm(xc, wts["ln1"], cfg.norm_eps)
            att, ck, cv = decode_attention(a, wts, lc["k"], lc["v"], pos,
                                           cfg, ctx)
            xc = xc + psum_tp(att, ctx) / LY.head_repl(cfg, ctx)
            c = LY.rms_norm(xc, wts["ln2"], cfg.norm_eps)
            xa = ED.cross_attention(c[:, None], lc["xk"], lc["xv"],
                                    wts, cfg, ctx)[:, 0]
            xc = xc + psum_tp(xa, ctx) / LY.head_repl(cfg, ctx)
            m = LY.rms_norm(xc, wts["ln3"], cfg.norm_eps)
            xc = xc + psum_tp(LY.mlp(m[:, None], wts, cfg)[:, 0], ctx)
            return xc, {"k": ck, "v": cv, "xk": lc["xk"], "xv": lc["xv"]}

        x, new_cache = jax.lax.scan(
            body, x, (params["dec"], cache, jnp.arange(L, dtype=jnp.int32)))

        fn = gather_param(params["top"]["final_norm"],
                          metas["top"]["final_norm"], ctx, zero_y(),
                          _leaf_key(kt, "fn"), tz, gathers)
        x = LY.rms_norm(x, fn, cfg.norm_eps)
        head = gather_param(params["top"]["lm_head"], metas["top"]["lm_head"],
                            ctx, zero_y(), _leaf_key(kt, "head"), tz, gathers)
        logits = x.astype(jnp.float32) @ head.astype(jnp.float32).T
        loc_max = jnp.max(logits, axis=-1)
        loc_arg = jnp.argmax(logits, axis=-1) + tp_index(ctx) * head.shape[0]
        if ctx.tp > 1:
            gmax = jax.lax.pmax(loc_max, ctx.tp_axis)
            cand = jnp.where(loc_max >= gmax, loc_arg, 0)
            nxt = jax.lax.pmax(cand, ctx.tp_axis)
        else:
            nxt = loc_arg
        return nxt.astype(jnp.int32), new_cache

    return serve_step


def make_serve_step(cfg: ModelConfig, ctx: ShardCtx, kv_quant: bool = False):
    """Returns serve_step(params, cache, tokens (B,1), pos ()) ->
    (next_token (B,), new_cache).  Runs inside shard_map."""
    if cfg.family == "encdec":
        return make_encdec_serve_step(cfg, ctx)
    metas = all_metas(cfg, ctx)
    gathers = make_gathers(ctx)
    L = n_scan_steps(cfg)

    def zero_y():
        return jnp.ones((), jnp.float32)

    def serve_step(params, cache, tokens, pos, key):
        from repro.dist.fsdp import TELE_WIDTH
        B = tokens.shape[0]
        tz = jnp.zeros((TELE_WIDTH,), jnp.float32)
        kt = jax.random.fold_in(key, 0)
        emb = gather_param(params["top"]["embed"], metas["top"]["embed"], ctx,
                           zero_y(), _leaf_key(kt, "embed"), tz, gathers)
        x = LY.vp_embed(tokens[:, 0], emb, ctx) * cfg.emb_scale   # (B, D)

        def body(carry, xs):
            xc = carry
            lp, lc, idx = xs
            kl = jax.random.fold_in(key, idx + 1)
            ly = {k: zero_y() for k in metas["layers"]}
            lt = {k: tz for k in metas["layers"]}
            wts = _gather_tree(lp, metas["layers"], ctx, ly, kl, lt, gathers)
            nc = dict(lc)
            if cfg.family == "ssm":
                a = LY.rms_norm(xc, wts["ln1"], cfg.norm_eps)
                st = {"ssm": lc["ssm"], "conv_x": lc["conv_x"],
                      "conv_bc": lc["conv_bc"]}
                out, ns = SSM.mamba2_block(a[:, None], wts, cfg, ctx, state=st)
                xc = xc + psum_tp(out[:, 0], ctx)
                nc = {"ssm": ns["ssm"], "conv_x": ns["conv_x"],
                      "conv_bc": ns["conv_bc"]}
            elif cfg.family == "hybrid":
                xc, nc = _hybrid_decode_unit(xc, wts, lc, pos, cfg, ctx)
            else:
                a = LY.rms_norm(xc, wts["ln1"], cfg.norm_eps)
                if kv_quant:
                    att, ck, cv, ks, vs = decode_attention(
                        a, wts, lc["k"], lc["v"], pos, cfg, ctx,
                        kscale=lc["k_scale"], vscale=lc["v_scale"])
                    nc["k_scale"], nc["v_scale"] = ks, vs
                else:
                    att, ck, cv = decode_attention(a, wts, lc["k"], lc["v"],
                                                   pos, cfg, ctx)
                xc = xc + psum_tp(att, ctx) / LY.head_repl(cfg, ctx)
                nc["k"], nc["v"] = ck, cv
                m = LY.rms_norm(xc, wts["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    xc = xc + _moe_decode(m, wts, cfg, ctx)
                else:
                    xc = xc + psum_tp(LY.mlp(m[:, None], wts, cfg)[:, 0], ctx)
            return xc, nc

        # scan over layers, cache as stacked xs/ys
        def sbody(carry, xs):
            lp = {k: xs[0][k] for k in xs[0]}
            lc = {k: xs[1][k] for k in xs[1]}
            out, nc = body(carry, (lp, lc, xs[2]))
            return out, nc

        cache_scan = {k: v for k, v in cache.items() if not k.startswith("tail")}
        x, new_cache = jax.lax.scan(
            sbody, x, (params["layers"], cache_scan,
                       jnp.arange(L, dtype=jnp.int32)))

        # hybrid unscanned tail recurrent layers
        if cfg.family == "hybrid" and cfg.n_layers % 3:
            for t in range(cfg.n_layers % 3):
                p = f"tail{t}_"
                names = [k for k in metas["top"] if k.startswith(p)]
                kl = jax.random.fold_in(key, 10_000 + t)
                sw = {k[len(p):]: gather_param(
                    params["top"][k], metas["top"][k], ctx, zero_y(),
                    _leaf_key(kl, k), tz, gathers) for k in names}
                a = LY.rms_norm(x, sw["ln1"], cfg.norm_eps)
                st = {"lru": cache[f"{p}lru"], "conv": cache[f"{p}conv"]}
                out, ns = RG.recurrent_block(a[:, None], sw, cfg, ctx, state=st)
                x = x + psum_tp(out[:, 0], ctx)
                new_cache[f"{p}lru"] = ns["lru"]
                new_cache[f"{p}conv"] = ns["conv"]
                m = LY.rms_norm(x, sw["ln2"], cfg.norm_eps)
                x = x + psum_tp(LY.mlp(m[:, None], sw, cfg)[:, 0], ctx)

        fn = gather_param(params["top"]["final_norm"],
                          metas["top"]["final_norm"], ctx, zero_y(),
                          _leaf_key(kt, "fn"), tz, gathers)
        x = LY.rms_norm(x, fn, cfg.norm_eps)
        if cfg.tie_embeddings:
            head = emb
        else:
            head = gather_param(params["top"]["lm_head"], metas["top"]["lm_head"],
                                ctx, zero_y(), _leaf_key(kt, "head"), tz, gathers)
        logits = x.astype(jnp.float32) @ head.astype(jnp.float32).T  # (B, V/tp)
        # vocab-parallel greedy sampling
        loc_max = jnp.max(logits, axis=-1)
        loc_arg = jnp.argmax(logits, axis=-1) + tp_index(ctx) * head.shape[0]
        if ctx.tp > 1:
            gmax = jax.lax.pmax(loc_max, ctx.tp_axis)
            cand = jnp.where(loc_max >= gmax, loc_arg, 0)
            nxt = jax.lax.pmax(cand, ctx.tp_axis)
        else:
            nxt = loc_arg
        return nxt.astype(jnp.int32), new_cache

    return serve_step


def _hybrid_decode_unit(x: Array, wts: dict, lc: dict, pos: Array,
                        cfg: ModelConfig, ctx: ShardCtx):
    nc = dict(lc)
    for n, p in ((1, "r1_"), (2, "r2_")):
        sw = _sub(wts, p)
        a = LY.rms_norm(x, sw["ln1"], cfg.norm_eps)
        st = {"lru": lc[f"lru{n}"], "conv": lc[f"conv{n}"]}
        out, ns = RG.recurrent_block(a[:, None], sw, cfg, ctx, state=st)
        x = x + psum_tp(out[:, 0], ctx)
        nc[f"lru{n}"], nc[f"conv{n}"] = ns["lru"], ns["conv"]
        m = LY.rms_norm(x, sw["ln2"], cfg.norm_eps)
        x = x + psum_tp(LY.mlp(m[:, None], sw, cfg)[:, 0], ctx)
    sw = _sub(wts, "at_")
    a = LY.rms_norm(x, sw["ln1"], cfg.norm_eps)
    att, ck, cv = window_decode_attention(a, sw, lc["wk"], lc["wv"], pos,
                                          cfg, ctx)
    x = x + psum_tp(att, ctx) / LY.head_repl(cfg, ctx)
    nc["wk"], nc["wv"] = ck, cv
    m = LY.rms_norm(x, sw["ln2"], cfg.norm_eps)
    x = x + psum_tp(LY.mlp(m[:, None], sw, cfg)[:, 0], ctx)
    return x, nc


# ---------------------------------------------------------------------------
# Prefill (forward pass writing the cache; used by prefill_32k dry-runs)
# ---------------------------------------------------------------------------

def make_prefill(cfg: ModelConfig, ctx: ShardCtx):
    """prefill(params, tokens (B,S), key) -> (last_hidden (B,D), cache).

    Uses the training forward (head-sharded attention) and re-shards the
    computed K/V into the decode layout (kv-group x seq-chunk local slices).
    """
    metas = all_metas(cfg, ctx)
    gathers = make_gathers(ctx)
    L = n_scan_steps(cfg)
    g1, g2 = groups_of(cfg, ctx)

    def zero_y():
        return jnp.ones((), jnp.float32)

    def prefill(params, tokens, key, img=None):
        from repro.dist.fsdp import TELE_WIDTH
        B, S = tokens.shape
        tz = jnp.zeros((TELE_WIDTH,), jnp.float32)
        kt = jax.random.fold_in(key, 0)
        emb = gather_param(params["top"]["embed"], metas["top"]["embed"], ctx,
                           zero_y(), _leaf_key(kt, "embed"), tz, gathers)
        x = LY.vp_embed(tokens, emb, ctx) * cfg.emb_scale
        if img is not None:                      # vlm: patch embeds prefix
            x = jnp.concatenate([img.astype(x.dtype), x], axis=1)
            S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        r = tp_index(ctx)
        i = r // g2 if g2 > 0 else r
        j = jnp.mod(r, g2) if g2 > 1 else jnp.zeros((), jnp.int32)
        kv_loc = max(cfg.n_kv // g1, 1)
        s_loc = -(-S // g2)

        def body(carry, xs):
            xc = carry
            lp, idx = xs
            kl = jax.random.fold_in(key, idx + 1)
            ly = {k: zero_y() for k in metas["layers"]}
            lt = {k: tz for k in metas["layers"]}
            wts = _gather_tree(lp, metas["layers"], ctx, ly, kl, lt, gathers)
            if cfg.family == "ssm":
                a = LY.rms_norm(xc, wts["ln1"], cfg.norm_eps)
                out, ns = SSM.mamba2_block(a, wts, cfg, ctx)
                xc = xc + psum_tp(out, ctx)
                conv_in_x = a @ wts["wx"]
                conv_in_bc = a @ wts["wbc"]
                Wc = cfg.conv_width - 1
                return xc, {"ssm": ns["ssm"].astype(jnp.bfloat16),
                            "conv_x": conv_in_x[:, -Wc:].astype(jnp.bfloat16),
                            "conv_bc": conv_in_bc[:, -Wc:].astype(jnp.bfloat16)}
            if cfg.family == "hybrid":
                piece = {}
                Wc = cfg.conv_width - 1
                for nsub, p in ((1, "r1_"), (2, "r2_")):
                    sw = _sub(wts, p)
                    a = LY.rms_norm(xc, sw["ln1"], cfg.norm_eps)
                    xbr_raw = a @ sw["wx"]
                    out, ns = RG.recurrent_block(a, sw, cfg, ctx)
                    xc = xc + psum_tp(out, ctx)
                    piece[f"lru{nsub}"] = ns["lru"].astype(jnp.bfloat16)
                    piece[f"conv{nsub}"] = xbr_raw[:, -Wc:].astype(jnp.bfloat16)
                    m = LY.rms_norm(xc, sw["ln2"], cfg.norm_eps)
                    xc = xc + psum_tp(LY.mlp(m, sw, cfg), ctx)
                sw = _sub(wts, "at_")
                a = LY.rms_norm(xc, sw["ln1"], cfg.norm_eps)
                att, (k, v) = LY.attention(a, sw, cfg, ctx, positions=positions,
                                           causal=True, window=cfg.window,
                                           kv_out=True)
                xc = xc + LY.attn_exit(att, cfg, ctx)
                m = LY.rms_norm(xc, sw["ln2"], cfg.norm_eps)
                xc = xc + psum_tp(LY.mlp(m, sw, cfg), ctx)
                # window ring buffer: last W positions (slot = pos mod W)
                Wn = cfg.window
                kw_ = k[:, -Wn:] if k.shape[1] >= Wn else jnp.pad(
                    k, ((0, 0), (Wn - k.shape[1], 0), (0, 0), (0, 0)))
                vw_ = v[:, -Wn:] if v.shape[1] >= Wn else jnp.pad(
                    v, ((0, 0), (Wn - v.shape[1], 0), (0, 0), (0, 0)))
                # roll so that position p lands in slot p mod W
                shift = jnp.mod(S, Wn)
                kw_ = jnp.roll(kw_, shift, axis=1)
                vw_ = jnp.roll(vw_, shift, axis=1)
                piece["wk"] = kw_.astype(jnp.bfloat16)
                piece["wv"] = vw_.astype(jnp.bfloat16)
                return xc, piece
            a = LY.rms_norm(xc, wts["ln1"], cfg.norm_eps)
            att, (k, v) = LY.attention(a, wts, cfg, ctx, positions=positions,
                                       causal=True, kv_out=True)
            xc = xc + psum_tp(att, ctx)
            m = LY.rms_norm(xc, wts["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                from repro.models.transformer import _moe_apply
                out, _ = _moe_apply(m, wts, cfg, ctx)
                xc = xc + out
            else:
                xc = xc + psum_tp(LY.mlp(m, wts, cfg), ctx)
            # re-shard k/v (B,S,KV,hd) -> decode layout (B,kv_loc,s_loc,hd)
            kk = jnp.swapaxes(k, 1, 2)                       # (B,KV,S,hd)
            vv = jnp.swapaxes(v, 1, 2)
            if g1 > 1:
                kk = jax.lax.dynamic_slice_in_dim(kk, i * kv_loc, kv_loc, 1)
                vv = jax.lax.dynamic_slice_in_dim(vv, i * kv_loc, kv_loc, 1)
            if g2 > 1:
                pad = g2 * s_loc - S
                kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vv = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0)))
                kk = jax.lax.dynamic_slice_in_dim(kk, j * s_loc, s_loc, 2)
                vv = jax.lax.dynamic_slice_in_dim(vv, j * s_loc, s_loc, 2)
            return xc, {"k": kk.astype(jnp.bfloat16),
                        "v": vv.astype(jnp.bfloat16)}

        x, cache = jax.lax.scan(body, x,
                                (params["layers"], jnp.arange(L, dtype=jnp.int32)))
        fn = gather_param(params["top"]["final_norm"],
                          metas["top"]["final_norm"], ctx, zero_y(),
                          _leaf_key(kt, "fn"), tz, gathers)
        last = LY.rms_norm(x[:, -1], fn, cfg.norm_eps)
        return last, cache

    return prefill


def make_encdec_prefill(cfg: ModelConfig, ctx: ShardCtx):
    """Whisper prefill: run the encoder over the (stub) frames, build the
    per-decoder-layer cross K/V cache, and prefill the decoder self-attn
    cache over the prompt tokens."""
    from repro.models import encdec as ED
    metas = ED.encdec_metas(cfg, ctx)
    gathers = make_gathers(ctx)
    g1, g2 = groups_of(cfg, ctx)

    def zero_y():
        return jnp.ones((), jnp.float32)

    def prefill(params, frames, tokens, key):
        from repro.dist.fsdp import TELE_WIDTH
        B, S = tokens.shape
        Se = frames.shape[1]
        tz = jnp.zeros((TELE_WIDTH,), jnp.float32)
        kt = jax.random.fold_in(key, 0)
        x = frames.astype(jnp.bfloat16)
        pos_e = jnp.arange(Se, dtype=jnp.int32)

        def ebody(carry, xs):
            xc = carry
            lp, idx = xs
            kl = jax.random.fold_in(key, idx + 1)
            ly = {k: zero_y() for k in metas["enc"]}
            lt = {k: tz for k in metas["enc"]}
            wts = _gather_tree(lp, metas["enc"], ctx, ly, kl, lt, gathers)
            a = LY.rms_norm(xc, wts["ln1"], cfg.norm_eps)
            att = LY.attention(a, wts, cfg, ctx, positions=pos_e, causal=False)
            xc = xc + LY.attn_exit(att, cfg, ctx)
            m = LY.rms_norm(xc, wts["ln2"], cfg.norm_eps)
            xc = xc + psum_tp(LY.mlp(m, wts, cfg), ctx)
            return xc, None

        x, _ = jax.lax.scan(ebody, x, (params["enc"],
                                       jnp.arange(cfg.enc_layers, dtype=jnp.int32)))
        en = gather_param(params["top"]["enc_norm"], metas["top"]["enc_norm"],
                          ctx, zero_y(), _leaf_key(kt, "en"), tz, gathers)
        memory = LY.rms_norm(x, en, cfg.norm_eps)

        emb = gather_param(params["top"]["embed"], metas["top"]["embed"], ctx,
                           zero_y(), _leaf_key(kt, "embed"), tz, gathers)
        h = LY.vp_embed(tokens, emb, ctx)
        pos_d = jnp.arange(S, dtype=jnp.int32)
        r = tp_index(ctx)
        i = r // g2 if g2 > 0 else r
        j = jnp.mod(r, g2) if g2 > 1 else jnp.zeros((), jnp.int32)
        kv_loc = max(cfg.n_kv // g1, 1)
        s_loc = -(-S // g2)

        def dbody(carry, xs):
            hc = carry
            lp, idx = xs
            kl = jax.random.fold_in(key, 1000 + idx)
            ly = {k: zero_y() for k in metas["dec"]}
            lt = {k: tz for k in metas["dec"]}
            wts = _gather_tree(lp, metas["dec"], ctx, ly, kl, lt, gathers)
            a = LY.rms_norm(hc, wts["ln1"], cfg.norm_eps)
            att, (k, v) = LY.attention(a, wts, cfg, ctx, positions=pos_d,
                                       causal=True, kv_out=True)
            hc = hc + LY.attn_exit(att, cfg, ctx)
            c = LY.rms_norm(hc, wts["ln2"], cfg.norm_eps)
            mk = (memory @ wts["x_wk"]).reshape(B, Se, cfg.n_kv, cfg.head_dim)
            mv = (memory @ wts["x_wv"]).reshape(B, Se, cfg.n_kv, cfg.head_dim)
            xa = ED.cross_attention(c, mk, mv, wts, cfg, ctx)
            hc = hc + LY.attn_exit(xa, cfg, ctx)
            m = LY.rms_norm(hc, wts["ln3"], cfg.norm_eps)
            hc = hc + psum_tp(LY.mlp(m, wts, cfg), ctx)
            # decode-layout self KV
            kk = jnp.swapaxes(k, 1, 2)
            vv = jnp.swapaxes(v, 1, 2)
            if g1 > 1:
                kk = jax.lax.dynamic_slice_in_dim(kk, i * kv_loc, kv_loc, 1)
                vv = jax.lax.dynamic_slice_in_dim(vv, i * kv_loc, kv_loc, 1)
            if g2 > 1:
                pad = g2 * s_loc - S
                kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vv = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0)))
                kk = jax.lax.dynamic_slice_in_dim(kk, j * s_loc, s_loc, 2)
                vv = jax.lax.dynamic_slice_in_dim(vv, j * s_loc, s_loc, 2)
            return hc, {"k": kk.astype(jnp.bfloat16),
                        "v": vv.astype(jnp.bfloat16),
                        "xk": mk.astype(jnp.bfloat16),
                        "xv": mv.astype(jnp.bfloat16)}

        h, cache = jax.lax.scan(dbody, h,
                                (params["dec"], jnp.arange(cfg.n_layers,
                                                           dtype=jnp.int32)))
        fn = gather_param(params["top"]["final_norm"],
                          metas["top"]["final_norm"], ctx, zero_y(),
                          _leaf_key(kt, "fn"), tz, gathers)
        return LY.rms_norm(h[:, -1], fn, cfg.norm_eps), cache

    return prefill
