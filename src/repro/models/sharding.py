"""Sharding context + parameter metadata for manually-sharded models.

Every model in repro/models is written *manually sharded* inside
``jax.shard_map`` (DESIGN §3): tensor-parallel over the ``model`` axis,
data-parallel + FSDP over the DP axes (``("data",)`` or ``("pod","data")``).

Parameter storage layout (ZeRO-3):
  each logical leaf has a TP-local shape ``local_shape`` (already sliced over
  the ``model`` axis when ``tp_dim is not None``); it is stored *flat*,
  padded, and sharded over the DP axes:

      global array:   (L?, T, P, shard_len)   (L only for scanned stacks)
      in_spec:        P(None, "model", dp_axes, None)
      local view:     (L?, 1, 1, shard_len)

  Inside the layer body, ``gather_param`` runs the custom-vjp FSDP gather
  (dist/fsdp.py): forward all-gathers bf16 weights over DP, backward
  reduce-scatters gradients with the paper's lattice quantization.

``tp_replicated`` leaves (KV projections when kv_heads < tp, norm scales,
routers) hold identical values on every TP rank; their backward psums the
gradient over ``model`` (optionally via the quantized butterfly) inside the
gather's bwd before the DP reduce-scatter.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import (QSyncConfig, butterfly_allreduce_mean,
                                    flat_size_padded)
from repro.dist import fsdp as F

Array = jax.Array

# Seed of the shared dither used by the quantized TP gradient psum (every
# rank derives the same offsets without communication, like the
# collectives' rotation seed).
_TP_SYNC_SEED = 20210508


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Static parallelism context threaded through every model function."""
    tp_axis: str = "model"
    dp_axes: tuple[str, ...] = ("data",)
    tp: int = 1                       # size of the model axis
    dp: int = 1                       # product of dp axis sizes
    qcfg: QSyncConfig = QSyncConfig()
    grad_sync: str = "lq"             # "lq" | "fp32"  (DP gradient reduce-scatter)
    quantize_tp_grads: bool = False   # butterfly-quantize psum('model') of replicated grads
    gather_dtype: str = "bfloat16"
    seq_parallel: bool = False        # residual stream sharded over tp
    remat: bool = True
    anchor_grads: bool = False        # anchored DP sync: encode g - anchor with
                                      # anchor = previous step's decoded mean
                                      # (butterfly topology; requires "lq")
    anchor_sharded: bool = True       # anchored: store anchors in ZeRO-3
                                      # storage layout (tp, dp, shard) beside
                                      # w; fwd rebuilds them via a piggybacked
                                      # f32 all-gather.  False = legacy
                                      # replicated (m,) anchors.
    prefetch: bool = False            # double-buffer the layer scan: issue
                                      # layer k+1's FSDP gather while layer k
                                      # computes (bit-identical to serial)

    def __post_init__(self):
        if self.anchor_grads and self.grad_sync != "lq":
            raise ValueError("anchor_grads requires grad_sync='lq'")

    @property
    def world(self) -> int:
        return self.tp * self.dp

    def fsdp_config(self) -> F.FSDPConfig:
        return F.FSDPConfig(axes=self.dp_axes, qcfg=self.qcfg,
                            sync=self.grad_sync, gather_dtype=self.gather_dtype,
                            anchored=self.anchor_grads,
                            anchor_sharded=self.anchor_sharded,
                            prefetch=self.prefetch)


# ---------------------------------------------------------------------------
# Parameter metadata
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafMeta:
    """Static description of one parameter leaf.

    local_shape: TP-local logical shape (model-axis slicing already applied).
    tp_dim:      which *global* dim was sliced over tp (None = replicated).
    scanned:     True if stacked over layers (leading L dim in storage).
    init:        initializer name ("normal", "zeros", "ones", "embed").
    init_scale:  stddev multiplier for "normal".
    """
    local_shape: tuple[int, ...]
    tp_dim: Optional[int] = None
    scanned: bool = True
    init: str = "normal"
    init_scale: float = 1.0
    tp_repl: int = 1      # replication factor: tp/tp_repl distinct shards
                          # (heads that don't divide tp, e.g. yi-34b 56H/16tp)

    @property
    def tp_replicated(self) -> bool:
        return self.tp_dim is None

    def numel(self) -> int:
        return int(np.prod(self.local_shape))


def shard_len(meta: LeafMeta, ctx: ShardCtx) -> int:
    """Flat per-device length (padded to dp*bucket granularity)."""
    n = meta.numel()
    bucket = effective_bucket(n, ctx)
    return F.pad_to_shardable(n, ctx.dp, bucket) // ctx.dp


def effective_bucket(n: int, ctx: ShardCtx) -> int:
    """Bucket size for quantized RS, shrunk for small leaves."""
    b = ctx.qcfg.bucket
    while b > 32 and n < ctx.dp * b:
        b //= 2
    return b


def leaf_gathered_len(meta: LeafMeta, ctx: ShardCtx) -> int:
    """Flat gathered length of one leaf (dp * shard_len)."""
    return shard_len(meta, ctx) * ctx.dp


def leaf_nb(meta: LeafMeta, ctx: ShardCtx) -> int:
    """Bucket count of one leaf's DP gradient sync (per-bucket y length)."""
    return F.leaf_nb(leaf_gathered_len(meta, ctx), ctx.dp, ctx.qcfg)


def leaf_anchor_len(meta: LeafMeta, ctx: ShardCtx) -> int:
    """Anchor length one leaf's y-state stores (and its tele cotangent
    carries back): the rank's shard when the anchor is sharded with the
    weights, the full gathered length for legacy replicated anchors, 0
    when unanchored."""
    if not ctx.anchor_grads:
        return 0
    return (shard_len(meta, ctx) if ctx.anchor_sharded
            else leaf_gathered_len(meta, ctx))


def leaf_tele_width(meta: LeafMeta, ctx: ShardCtx) -> int:
    """Tele-leaf length: scalars + per-bucket maps (+ anchor when anchored)."""
    return F.tele_width(leaf_nb(meta, ctx), leaf_anchor_len(meta, ctx),
                        ctx.anchor_grads)


def anchor_shape(meta: LeafMeta, ctx: ShardCtx, n_layers: int = 0
                 ) -> tuple[int, ...]:
    """Shape of one leaf's anchor state.  Sharded (default): the ZeRO-3
    storage layout ``(tp, dp, shard_len)`` — the anchor lives beside ``w``
    with the same in_spec (:func:`anchor_spec`), each (tp, dp) cell holding
    its own slice of that cell's gathered-leaf mean.  Legacy replicated:
    a single ``(m,)`` f32 vector.  ``n_layers > 0`` prepends the scan dim."""
    if ctx.anchor_sharded:
        s: tuple[int, ...] = (ctx.tp, ctx.dp, shard_len(meta, ctx))
    else:
        s = (leaf_gathered_len(meta, ctx),)
    return ((n_layers,) + s) if n_layers else s


def anchor_spec(meta: LeafMeta, ctx: ShardCtx, scanned: bool):
    """PartitionSpec of one leaf's anchor state (see :func:`anchor_shape`)."""
    from jax.sharding import PartitionSpec as P
    if not ctx.anchor_sharded:
        return P()
    s = (ctx.tp_axis,
         ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0], None)
    return P(*(((None,) + s) if scanned else s))


def leaf_y0(meta: LeafMeta, ctx: ShardCtx, value: float) -> float:
    """Initial distance bound for one leaf's quantized gradient sync.

    Raw space: ``value`` itself (the trainer's per-coordinate guess).  With
    ``qcfg.rotate`` the reduce-scatter quantizes HD-rotated buckets, so the
    seed comes from the paper's §6 bound instead (Lemma 24: rotated
    coordinates are at most ||delta||_2 * sqrt(2 ln(2b/beta)/b) w.h.p.),
    applied with the l2 distance the raw guess implies for a b-coordinate
    bucket (value * sqrt(b)).  A spiky gradient's raw l_inf understates its
    rotated coordinates by up to ~sqrt(b), so seeding rotated runs with the
    raw guess triggers a first-steps escalation storm; telemetry then
    tracks measured rotated-space distances from this calibrated start.
    """
    if not ctx.qcfg.rotate:
        return value
    from repro.core import rotation as R
    b = effective_bucket(meta.numel(), ctx)
    return R.rotated_coord_bound(value * math.sqrt(b), b)


def storage_shape(meta: LeafMeta, ctx: ShardCtx, n_layers: int) -> tuple[int, ...]:
    s = (ctx.tp, ctx.dp, shard_len(meta, ctx))
    return ((n_layers,) + s) if meta.scanned else s


def storage_spec(meta: LeafMeta, ctx: ShardCtx):
    """PartitionSpec for the storage array."""
    from jax.sharding import PartitionSpec as P
    s = (ctx.tp_axis, ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0], None)
    return P(*(((None,) + s) if meta.scanned else s))


# ---------------------------------------------------------------------------
# Initialization (host-side; used by smoke tests & the real trainer)
# ---------------------------------------------------------------------------

def init_leaf(key: Array, meta: LeafMeta, ctx: ShardCtx, n_layers: int,
              dtype=jnp.float32) -> Array:
    """Initialize the *global* storage array for one leaf.

    TP slices get distinct values along the tp dim of the storage array
    (they are different slices of the logical tensor); tp-replicated leaves
    get identical values across the tp dim.
    """
    L = n_layers if meta.scanned else 1
    sl = shard_len(meta, ctx)
    n = meta.numel()

    def one(key) -> Array:   # one (tp, flat) logical layer
        rows = 1 if meta.tp_replicated else ctx.tp // meta.tp_repl
        if meta.init == "zeros":
            flat = jnp.zeros((rows, n), dtype)
        elif meta.init == "ones":
            flat = jnp.ones((rows, n), dtype)
        elif meta.init == "a_log":
            # mamba2 A_log / RG-LRU lambda: log of U[1, 16]
            flat = jnp.log(jax.random.uniform(key, (rows, n), dtype, 1.0, 16.0))
        elif meta.init == "dt_bias":
            # softplus^-1 of U[1e-3, 1e-1]
            dt = jax.random.uniform(key, (rows, n), dtype, 1e-3, 1e-1)
            flat = dt + jnp.log(-jnp.expm1(-dt))
        elif meta.init == "embed":
            flat = jax.random.normal(key, (rows, n), dtype) * meta.init_scale * 0.02
        else:
            scale = meta.init_scale / math.sqrt(max(meta.local_shape[0], 1))
            flat = jax.random.normal(key, (rows, n), dtype) * scale
        if rows < ctx.tp:
            flat = jnp.repeat(flat, ctx.tp // rows, axis=0)
        pad = ctx.dp * sl - n
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
        return flat.reshape(ctx.tp, ctx.dp, sl)

    keys = jax.random.split(key, L)
    out = jax.vmap(one)(keys)          # (L, tp, dp, sl)
    return out if meta.scanned else out[0]


# ---------------------------------------------------------------------------
# Logical <-> storage converters (checkpointing / elastic re-sharding / tests)
#
# Both converters are jit-compiled (meta/ctx static).  This is not merely a
# speed choice: on jax 0.4.x, dispatching these reshape/split/concat chains
# *eagerly* on an array that is already sharded over a multi-axis mesh (the
# storage grads a shard_map step returns, spec P(None, tp, dp, None)) yields
# values silently scaled by the model-axis size, while the same ops under jit
# — or on a host copy — are exact.  Keeping the whole conversion inside one
# jit makes the result independent of the input's placement.
# ---------------------------------------------------------------------------

def logical_shape(meta: LeafMeta, ctx: ShardCtx) -> tuple[int, ...]:
    """Global logical tensor shape (undo the tp slicing)."""
    if meta.tp_replicated:
        return meta.local_shape
    s = list(meta.local_shape)
    s[meta.tp_dim] *= ctx.tp // meta.tp_repl
    return tuple(s)


@partial(jax.jit, static_argnums=(1, 2))
def logical_to_storage(x, meta: LeafMeta, ctx: ShardCtx):
    """One logical layer tensor -> (tp, dp, shard_len) storage layout."""
    x = jnp.asarray(x, jnp.float32)
    n = meta.numel()
    sl = shard_len(meta, ctx)
    if meta.tp_replicated:
        flat = jnp.broadcast_to(x.reshape(1, n), (ctx.tp, n))
    else:
        shards = ctx.tp // meta.tp_repl
        parts = jnp.split(x, shards, axis=meta.tp_dim)
        flat = jnp.stack([p.reshape(-1) for p in parts])
        if meta.tp_repl > 1:
            flat = jnp.repeat(flat, meta.tp_repl, axis=0)
    flat = jnp.pad(flat, ((0, 0), (0, ctx.dp * sl - n)))
    return flat.reshape(ctx.tp, ctx.dp, sl)


@partial(jax.jit, static_argnums=(1, 2))
def storage_to_logical(st, meta: LeafMeta, ctx: ShardCtx):
    """(tp, dp, shard_len) storage -> one logical layer tensor.

    The shard axis is merged into ``tp_dim`` with moveaxis+reshape rather
    than per-shard integer indexing: indexing a model-sharded axis shard by
    shard miscompiles on jax 0.4.x (values scaled by the axis size), while
    the pure relayout formulation is handled exactly.
    """
    n = meta.numel()
    flat = st.reshape(ctx.tp, -1)[:, :n]
    if meta.tp_replicated:
        return flat[0].reshape(meta.local_shape)
    shards = ctx.tp // meta.tp_repl
    if meta.tp_repl > 1:
        flat = flat.reshape(shards, meta.tp_repl, n)[:, 0]
    tp_dim = meta.tp_dim % len(meta.local_shape)
    x = flat.reshape((shards,) + meta.local_shape)
    x = jnp.moveaxis(x, 0, tp_dim)
    shp = list(meta.local_shape)
    shp[tp_dim] *= shards
    return x.reshape(tuple(shp))


# ---------------------------------------------------------------------------
# In-graph gather: storage -> usable weight (inside shard_map, per layer)
# ---------------------------------------------------------------------------

def make_gathers(ctx: ShardCtx):
    """FSDP gather fns: (plain, full-tp-psum, groups-psum-factory)."""
    g_plain = F.make_fsdp_gather(ctx.fsdp_config())

    def g_tp(bundle):
        # Replicated leaf: same forward; a custom-vjp identity injects the
        # psum over the tp axis into the gradient before the DP
        # reduce-scatter (true grad of a logically-shared tensor).
        return _tp_psum_grad(g_plain(bundle), ctx, None)

    def g_groups(repl: int):
        groups = tuple(tuple(s * repl + j for j in range(repl))
                       for s in range(ctx.tp // repl))

        def g(bundle):
            return _tp_psum_grad(g_plain(bundle), ctx, groups)
        return g

    return g_plain, g_tp, g_groups


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _tp_psum_grad(x, ctx: ShardCtx, groups):
    return x


def _tp_psum_grad_fwd(x, ctx, groups):
    return x, None


def _tp_quantized_psum(g: Array, ctx: ShardCtx) -> Array:
    """psum('model') of a replicated-leaf gradient via the quantized
    butterfly: mean over the tp axis through butterfly_allreduce_mean
    (packed lattice wire, bits_for_q(q) bits/coord) scaled back by tp.

    The distance bound is derived at runtime — twice the tp-max absolute
    gradient entry, a bound on |own - partner| for any pair — with pmax so
    every rank uses the same y (the collectives' common-output requirement).
    The dither key is a shared constant; all ranks derive identical offsets.
    """
    gf = g.astype(jnp.float32).reshape(-1)
    n = gf.shape[0]
    b = ctx.qcfg.bucket
    while b > 32 and n < b:
        b //= 2
    qc = dataclasses.replace(ctx.qcfg, bucket=b)
    nb = flat_size_padded(n, qc) // b
    y = 2.0 * jax.lax.pmax(jnp.max(jnp.abs(gf)), ctx.tp_axis) + 1e-20
    y_b = jnp.full((nb,), 1.0, jnp.float32) * y
    mean, _aux = butterfly_allreduce_mean(
        gf, y_b, jax.random.PRNGKey(_TP_SYNC_SEED), ctx.tp_axis, qc)
    return (mean * ctx.tp).reshape(g.shape).astype(g.dtype)


def _tp_psum_grad_bwd(ctx, groups, _, g):
    if (groups is None and ctx.quantize_tp_grads and ctx.tp > 1
            and (ctx.tp & (ctx.tp - 1)) == 0):
        return (_tp_quantized_psum(g, ctx),)
    gl = None if groups is None else [list(t) for t in groups]
    return (jax.lax.psum(g, ctx.tp_axis, axis_index_groups=gl),)


_tp_psum_grad.defvjp(_tp_psum_grad_fwd, _tp_psum_grad_bwd)


def gather_param(storage: Array, meta: LeafMeta, ctx: ShardCtx,
                 y: Array, key: Array, tele: Array,
                 gathers, compute_dtype=jnp.bfloat16) -> Array:
    """storage local view (1, 1, shard) -> full TP-local weight.

    y: this leaf's distance-bound state — () f32 (legacy scalar), (nb,) f32
    per-bucket bounds, or {"y": (nb,), "anchor": (m,)} in anchored mode
    (see dist/fsdp.py); tele: (leaf_tele_width(meta, ctx),) zeros whose
    cotangent carries back the per-bucket decode telemetry.
    """
    g_plain, g_tp, g_groups = gathers
    w_shard = storage.reshape(-1)
    bundle = {"w": w_shard, "y": y, "key": key, "tele": tele}
    if meta.tp_replicated:
        fn = g_tp
    elif meta.tp_repl > 1 and ctx.tp > 1:
        fn = g_groups(meta.tp_repl)
    else:
        fn = g_plain
    w_full = fn(bundle)
    n = meta.numel()
    w = w_full[:n].reshape(meta.local_shape)
    return w.astype(compute_dtype)


# ---------------------------------------------------------------------------
# Split (prefetch-pipelined) gather: issue in iteration k-1, consume in k
# ---------------------------------------------------------------------------

def make_split_gathers(ctx: ShardCtx):
    """``(gather_async, wait)`` pair for the double-buffered layer scan
    (``ctx.prefetch``; see dist/fsdp.make_fsdp_gather_split).  Use with
    :func:`gather_param_async` / :func:`gather_param_wait`."""
    return F.make_fsdp_gather_split(ctx.fsdp_config())


def gather_param_async(storage: Array, meta: LeafMeta, ctx: ShardCtx,
                       y: Array, key: Array, tele: Array, split) -> Array:
    """Issue one leaf's FSDP all-gather; returns the in-flight ``(m,)``
    handle (pinned — carry it through the scan and consume with
    :func:`gather_param_wait`).  Same bundle contract as
    :func:`gather_param`."""
    gather_async, _ = split
    bundle = {"w": storage.reshape(-1), "y": y, "key": key, "tele": tele}
    return gather_async(bundle)


def gather_param_wait(handle: Array, meta: LeafMeta, ctx: ShardCtx, split,
                      compute_dtype=jnp.bfloat16) -> Array:
    """Consume a prefetched handle -> full TP-local weight.

    The TP psum-grad wrapper attaches here, at the consumption point, so
    the backward runs slice-transpose -> tp psum -> (through the carry)
    the issued gather's DP reduce-scatter — the same collective order as
    the monolithic :func:`gather_param`."""
    _, wait = split
    w_full = wait(handle)
    if meta.tp_replicated:
        w_full = _tp_psum_grad(w_full, ctx, None)
    elif meta.tp_repl > 1 and ctx.tp > 1:
        groups = tuple(tuple(s * meta.tp_repl + j for j in range(meta.tp_repl))
                       for s in range(ctx.tp // meta.tp_repl))
        w_full = _tp_psum_grad(w_full, ctx, groups)
    n = meta.numel()
    return w_full[:n].reshape(meta.local_shape).astype(compute_dtype)


# ---------------------------------------------------------------------------
# Common collective helpers used by the layers
#
# Every differentiated TP collective is wrapped in a custom_vjp that pins the
# adjoint to the *same-axis* collective (transpose(psum) = psum, transpose
# (all_gather) = reduce-scatter-sum, and vice versa).  The whole manual-
# sharding scheme assumes exactly this rule — make_loss_fn scales the loss
# by 1/tp to compensate — while shard_map's built-in transpose machinery
# derives the adjoint from its replication tracking of the operands, which
# has changed across jax versions (check_rep rewriting vs. literal
# transposes).  Pinning the adjoint here makes the intended semantics
# explicit and jax-version-independent.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_pinned(x, axis_name):
    return jax.lax.psum(x, axis_name)


def _psum_pinned_fwd(x, axis_name):
    return jax.lax.psum(x, axis_name), None


def _psum_pinned_bwd(axis_name, _, g):
    return (jax.lax.psum(g, axis_name),)


_psum_pinned.defvjp(_psum_pinned_fwd, _psum_pinned_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _all_gather_pinned(x, axis_name, axis):
    return jax.lax.all_gather(x, axis_name, axis=axis, tiled=True)


def _all_gather_pinned_fwd(x, axis_name, axis):
    return _all_gather_pinned(x, axis_name, axis), None


def _all_gather_pinned_bwd(axis_name, axis, _, g):
    return (jax.lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                                 tiled=True),)


_all_gather_pinned.defvjp(_all_gather_pinned_fwd, _all_gather_pinned_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _reduce_scatter_pinned(x, axis_name, axis):
    return jax.lax.psum_scatter(x, axis_name, scatter_dimension=axis,
                                tiled=True)


def _reduce_scatter_pinned_fwd(x, axis_name, axis):
    return _reduce_scatter_pinned(x, axis_name, axis), None


def _reduce_scatter_pinned_bwd(axis_name, axis, _, g):
    return (jax.lax.all_gather(g, axis_name, axis=axis, tiled=True),)


_reduce_scatter_pinned.defvjp(_reduce_scatter_pinned_fwd,
                              _reduce_scatter_pinned_bwd)


def psum_tp(x: Array, ctx: ShardCtx) -> Array:
    return _psum_pinned(x, ctx.tp_axis) if ctx.tp > 1 else x


def pmax_tp(x: Array, ctx: ShardCtx) -> Array:
    return jax.lax.pmax(x, ctx.tp_axis) if ctx.tp > 1 else x


def all_gather_tp(x: Array, ctx: ShardCtx, axis: int = 0) -> Array:
    if ctx.tp == 1:
        return x
    return _all_gather_pinned(x, ctx.tp_axis, axis)


def reduce_scatter_tp(x: Array, ctx: ShardCtx, axis: int = 0) -> Array:
    if ctx.tp == 1:
        return x
    return _reduce_scatter_pinned(x, ctx.tp_axis, axis)


def tp_index(ctx: ShardCtx) -> Array:
    return jax.lax.axis_index(ctx.tp_axis) if ctx.tp > 1 else jnp.zeros((), jnp.int32)
