"""Encoder-decoder backbone (Whisper-style) on the shared substrate.

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, enc_seq, D).  The backbone is faithful:
bidirectional encoder self-attention, causal decoder self-attention, decoder
cross-attention over encoder outputs, GELU MLPs, MHA (n_kv == n_heads).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as LY
from repro.models.sharding import (LeafMeta, ShardCtx, gather_param,
                                   make_gathers, psum_tp, tp_index)
from repro.models.transformer import (_attn_metas, _mlp_metas, _gather_tree,
                                      _leaf_key, _ce_sum, _prefetch_layer_scan,
                                      tele_zeros, y_init)

Array = jax.Array


def enc_block_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict[str, LeafMeta]:
    D = cfg.d_model
    ln = lambda: LeafMeta((D,), tp_dim=None, init="ones")
    return {"ln1": ln(), "ln2": ln(),
            **_attn_metas(cfg, ctx), **_mlp_metas(cfg, ctx)}


def dec_block_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict[str, LeafMeta]:
    D = cfg.d_model
    ln = lambda: LeafMeta((D,), tp_dim=None, init="ones")
    return {"ln1": ln(), "ln2": ln(), "ln3": ln(),
            **_attn_metas(cfg, ctx),
            **_attn_metas(cfg, ctx, prefix="x_"),
            **_mlp_metas(cfg, ctx)}


def encdec_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict:
    V, D = cfg.vocab, cfg.d_model
    v_loc = -(-V // ctx.tp)
    return {
        "enc": enc_block_metas(cfg, ctx),
        "dec": dec_block_metas(cfg, ctx),
        "top": {
            "embed": LeafMeta((v_loc, D), tp_dim=0, scanned=False, init="embed"),
            "enc_norm": LeafMeta((D,), tp_dim=None, scanned=False, init="ones"),
            "final_norm": LeafMeta((D,), tp_dim=None, scanned=False, init="ones"),
            "lm_head": LeafMeta((v_loc, D), tp_dim=0, scanned=False, init="embed"),
        },
    }


def init_encdec_params(cfg: ModelConfig, ctx: ShardCtx, key: Array) -> dict:
    from repro.models.sharding import init_leaf
    metas = encdec_metas(cfg, ctx)
    out: dict = {"enc": {}, "dec": {}, "top": {}}
    i = 0
    ks = jax.random.split(key, sum(len(v) for v in metas.values()))
    for grp, L in (("enc", cfg.enc_layers), ("dec", cfg.n_layers), ("top", 1)):
        for name, meta in sorted(metas[grp].items()):
            out[grp][name] = init_leaf(ks[i], meta, ctx, L)
            i += 1
    return out


def encdec_param_shapes(cfg: ModelConfig, ctx: ShardCtx) -> dict:
    from repro.models.sharding import storage_shape
    metas = encdec_metas(cfg, ctx)
    out: dict = {"enc": {}, "dec": {}, "top": {}}
    for grp, L in (("enc", cfg.enc_layers), ("dec", cfg.n_layers), ("top", 1)):
        for name, meta in metas[grp].items():
            out[grp][name] = jax.ShapeDtypeStruct(storage_shape(meta, ctx, L),
                                                  jnp.float32)
    return out


def encdec_y_init(cfg: ModelConfig, ctx: ShardCtx, value: float = 1.0) -> dict:
    """Per-leaf, per-bucket initial distance bounds (rotated-space-seeded
    like transformer.y_init; see repro.models.sharding.leaf_y0/leaf_nb).
    With ``ctx.anchor_grads`` each leaf carries ``{"y", "anchor"}`` with the
    anchor laid out per :func:`repro.models.sharding.anchor_shape` (sharded
    ZeRO-3 storage by default, legacy replicated ``(m,)`` otherwise)."""
    from repro.models.sharding import anchor_shape, leaf_nb, leaf_y0
    metas = encdec_metas(cfg, ctx)

    def leaf(m, L):
        shape = (L, leaf_nb(m, ctx)) if L else (leaf_nb(m, ctx),)
        yv = jnp.full(shape, leaf_y0(m, ctx, value), jnp.float32)
        if not ctx.anchor_grads:
            return yv
        return {"y": yv,
                "anchor": jnp.zeros(anchor_shape(m, ctx, L), jnp.float32)}

    return {
        "enc": {k: leaf(m, cfg.enc_layers) for k, m in metas["enc"].items()},
        "dec": {k: leaf(m, cfg.n_layers) for k, m in metas["dec"].items()},
        "top": {k: leaf(m, 0) for k, m in metas["top"].items()},
    }


def encdec_tele_zeros(cfg: ModelConfig, ctx: ShardCtx) -> dict:
    from repro.models.sharding import leaf_tele_width
    metas = encdec_metas(cfg, ctx)
    return {
        "enc": {k: jnp.zeros((cfg.enc_layers, leaf_tele_width(m, ctx)),
                             jnp.float32) for k, m in metas["enc"].items()},
        "dec": {k: jnp.zeros((cfg.n_layers, leaf_tele_width(m, ctx)),
                             jnp.float32) for k, m in metas["dec"].items()},
        "top": {k: jnp.zeros((leaf_tele_width(m, ctx),), jnp.float32)
                for k, m in metas["top"].items()},
    }


def cross_attention(xg: Array, mem_k: Array, mem_v: Array, w: dict,
                    cfg: ModelConfig, ctx: ShardCtx) -> Array:
    """Decoder cross-attn.  xg: (B,Sd,D); mem_k/v: (B,Se,KV,hd) precomputed."""
    B, Sd, D = xg.shape
    hd = cfg.head_dim
    from repro.models.layers import _kv_map_local, _softmax_attend, local_heads
    import numpy as np
    h_loc = local_heads(cfg, ctx)
    q = (xg @ w["x_wq"]).reshape(B, Sd, h_loc, hd)
    kv_idx = _kv_map_local(cfg, ctx)
    k_h = jnp.take(mem_k, kv_idx, axis=2)
    v_h = jnp.take(mem_v, kv_idx, axis=2)
    mask = jnp.ones((Sd, mem_k.shape[1]), bool)
    out = _softmax_attend(q, k_h, v_h, mask, 1.0 / np.sqrt(hd))
    return out.reshape(B, Sd, h_loc * hd) @ w["x_wo"]


def make_encdec_loss_fn(cfg: ModelConfig, ctx: ShardCtx):
    """batch: {"frames": (B, Se, D) f32, "tokens"/"targets"/"mask": (B, Sd)}."""
    from repro.models.sharding import make_split_gathers
    metas = encdec_metas(cfg, ctx)
    gathers = make_gathers(ctx)
    split = make_split_gathers(ctx) if ctx.prefetch else None

    def loss_fn(params, tele, batch, key, y):
        frames = batch["frames"].astype(jnp.bfloat16)
        tokens = batch["tokens"]
        B, Sd = tokens.shape
        Se = frames.shape[1]
        kt = jax.random.fold_in(key, 0)

        # ---- encoder (bidirectional) ----
        x = frames
        pos_e = jnp.arange(Se, dtype=jnp.int32)

        def enc_apply(xc, wts):
            a = LY.rms_norm(xc, wts["ln1"], cfg.norm_eps)
            att = LY.attention(a, wts, cfg, ctx, positions=pos_e, causal=False)
            xc = xc + LY.attn_exit(att, cfg, ctx)
            m = LY.rms_norm(xc, wts["ln2"], cfg.norm_eps)
            return xc + psum_tp(LY.mlp(m, wts, cfg), ctx)

        if ctx.prefetch:
            x, _ = _prefetch_layer_scan(
                x, params["enc"], metas["enc"], ctx, y["enc"], tele["enc"],
                cfg.enc_layers, split,
                lambda i: jax.random.fold_in(key, i + 1),
                lambda xc, wts: (enc_apply(xc, wts),
                                 jnp.zeros((), jnp.float32)),
                ctx.remat)
        else:
            def ebody(carry, xs):
                xc = carry
                lp, ly, lt, idx = xs
                kl = jax.random.fold_in(key, idx + 1)
                wts = _gather_tree(lp, metas["enc"], ctx, ly, kl, lt, gathers)
                return enc_apply(xc, wts), None

            ebody = jax.checkpoint(ebody) if ctx.remat else ebody
            xs_e = (params["enc"], y["enc"], tele["enc"],
                    jnp.arange(cfg.enc_layers, dtype=jnp.int32))
            x, _ = jax.lax.scan(ebody, x, xs_e)

        en = gather_param(params["top"]["enc_norm"], metas["top"]["enc_norm"],
                          ctx, y["top"]["enc_norm"], _leaf_key(kt, "en"),
                          tele["top"]["enc_norm"], gathers)
        memory = LY.rms_norm(x, en, cfg.norm_eps)

        # ---- decoder ----
        emb = gather_param(params["top"]["embed"], metas["top"]["embed"], ctx,
                           y["top"]["embed"], _leaf_key(kt, "embed"),
                           tele["top"]["embed"], gathers)
        h = LY.vp_embed(tokens, emb, ctx)
        pos_d = jnp.arange(Sd, dtype=jnp.int32)

        def dec_apply(hc, wts):
            a = LY.rms_norm(hc, wts["ln1"], cfg.norm_eps)
            att = LY.attention(a, wts, cfg, ctx, positions=pos_d, causal=True)
            hc = hc + LY.attn_exit(att, cfg, ctx)
            c = LY.rms_norm(hc, wts["ln2"], cfg.norm_eps)
            # cross K/V from memory (per-layer projections, replicated kv)
            mk = (memory @ wts["x_wk"]).reshape(B, Se, cfg.n_kv, cfg.head_dim)
            mv = (memory @ wts["x_wv"]).reshape(B, Se, cfg.n_kv, cfg.head_dim)
            xa = cross_attention(c, mk, mv, wts, cfg, ctx)
            hc = hc + LY.attn_exit(xa, cfg, ctx)
            m = LY.rms_norm(hc, wts["ln3"], cfg.norm_eps)
            return hc + psum_tp(LY.mlp(m, wts, cfg), ctx)

        if ctx.prefetch:
            h, _ = _prefetch_layer_scan(
                h, params["dec"], metas["dec"], ctx, y["dec"], tele["dec"],
                cfg.n_layers, split,
                lambda i: jax.random.fold_in(key, 1000 + i),
                lambda hc, wts: (dec_apply(hc, wts),
                                 jnp.zeros((), jnp.float32)),
                ctx.remat)
        else:
            def dbody(carry, xs):
                hc = carry
                lp, ly, lt, idx = xs
                kl = jax.random.fold_in(key, 1000 + idx)
                wts = _gather_tree(lp, metas["dec"], ctx, ly, kl, lt, gathers)
                return dec_apply(hc, wts), None

            dbody = jax.checkpoint(dbody) if ctx.remat else dbody
            xs_d = (params["dec"], y["dec"], tele["dec"],
                    jnp.arange(cfg.n_layers, dtype=jnp.int32))
            h, _ = jax.lax.scan(dbody, h, xs_d)

        fn = gather_param(params["top"]["final_norm"], metas["top"]["final_norm"],
                          ctx, y["top"]["final_norm"], _leaf_key(kt, "fn"),
                          tele["top"]["final_norm"], gathers)
        h = LY.rms_norm(h, fn, cfg.norm_eps)
        head = gather_param(params["top"]["lm_head"], metas["top"]["lm_head"],
                            ctx, y["top"]["lm_head"], _leaf_key(kt, "head"),
                            tele["top"]["lm_head"], gathers)
        mask = batch.get("mask")
        nll, cnt = _ce_sum(h.reshape(-1, cfg.d_model), head,
                           batch["targets"].reshape(-1), ctx,
                           None if mask is None else mask.reshape(-1))
        loss = nll / jnp.maximum(cnt, 1.0)
        # see transformer.make_loss_fn: shard_map grads are summed over
        # devices; the tp-replicated loss needs 1/tp scaling.
        return loss / ctx.tp, {"loss": loss}

    return loss_fn
