"""Model assembly: parameter metas, init, and the training forward pass.

Families covered here: dense, moe, ssm, hybrid (RG-LRU), vlm.
Encoder-decoder (whisper) lives in models/encdec.py on the same substrate.

Everything below executes *inside* ``jax.shard_map``; parameters arrive in
ZeRO-3 storage layout (see models/sharding.py) and each layer re-gathers its
weights through the custom-vjp FSDP gather whose backward runs the paper's
quantized reduce-scatter.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as LY
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import rglru as RG
from repro.models.sharding import (LeafMeta, ShardCtx, gather_param,
                                   gather_param_async, gather_param_wait,
                                   make_gathers, make_split_gathers,
                                   init_leaf, tp_index, psum_tp,
                                   all_gather_tp)

Array = jax.Array


# ---------------------------------------------------------------------------
# Leaf metas per family
# ---------------------------------------------------------------------------

def _attn_metas(cfg: ModelConfig, ctx: ShardCtx, prefix: str = "",
                kv: Optional[int] = None) -> dict[str, LeafMeta]:
    from repro.models.layers import head_repl, local_heads
    D, hd = cfg.d_model, cfg.head_dim
    h_loc = local_heads(cfg, ctx)
    repl = head_repl(cfg, ctx)
    kv = cfg.n_kv if kv is None else kv
    m = {
        f"{prefix}wq": LeafMeta((D, h_loc * hd), tp_dim=1, tp_repl=repl),
        f"{prefix}wk": LeafMeta((D, kv * hd), tp_dim=None),
        f"{prefix}wv": LeafMeta((D, kv * hd), tp_dim=None),
        f"{prefix}wo": LeafMeta((h_loc * hd, D), tp_dim=0, tp_repl=repl),
    }
    if cfg.qk_norm:
        m[f"{prefix}qn"] = LeafMeta((hd,), tp_dim=None, init="ones")
        m[f"{prefix}kn"] = LeafMeta((hd,), tp_dim=None, init="ones")
    return m


def _mlp_metas(cfg: ModelConfig, ctx: ShardCtx, prefix: str = "") -> dict[str, LeafMeta]:
    D, F = cfg.d_model, cfg.d_ff
    f_loc = F // ctx.tp
    if cfg.act == "swiglu":
        return {
            f"{prefix}wg": LeafMeta((D, f_loc), tp_dim=1),
            f"{prefix}wu": LeafMeta((D, f_loc), tp_dim=1),
            f"{prefix}wd": LeafMeta((f_loc, D), tp_dim=0),
        }
    return {
        f"{prefix}wi": LeafMeta((D, f_loc), tp_dim=1),
        f"{prefix}wd": LeafMeta((f_loc, D), tp_dim=0),
    }


def _moe_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict[str, LeafMeta]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    e_loc = E // ctx.tp if E >= ctx.tp else E
    m = {
        "router": LeafMeta((D, E), tp_dim=None),
        "w1": LeafMeta((e_loc, D, F), tp_dim=0),
        "w2": LeafMeta((e_loc, F, D), tp_dim=0),
    }
    if cfg.act == "swiglu":
        m["w3"] = LeafMeta((e_loc, D, F), tp_dim=0)
    return m


def _ssm_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict[str, LeafMeta]:
    D = cfg.d_model
    inner = cfg.ssm_expand * D
    i_loc = inner // ctx.tp
    P = cfg.ssm_headdim
    h_loc = i_loc // P
    N = cfg.ssm_state
    W = cfg.conv_width
    return {
        "wz": LeafMeta((D, i_loc), tp_dim=1),
        "wx": LeafMeta((D, i_loc), tp_dim=1),
        "wbc": LeafMeta((D, 2 * N), tp_dim=None),
        "wdt": LeafMeta((D, h_loc), tp_dim=1),
        "conv_x": LeafMeta((W, i_loc), tp_dim=1, init="normal", init_scale=0.5),
        "conv_bc": LeafMeta((W, 2 * N), tp_dim=None, init="normal", init_scale=0.5),
        "A_log": LeafMeta((h_loc,), tp_dim=0, init="a_log"),
        "D": LeafMeta((h_loc,), tp_dim=0, init="ones"),
        "dt_bias": LeafMeta((h_loc,), tp_dim=0, init="dt_bias"),
        "norm": LeafMeta((i_loc,), tp_dim=0, init="ones"),
        "wo": LeafMeta((i_loc, D), tp_dim=0),
    }


def _rec_metas(cfg: ModelConfig, ctx: ShardCtx, prefix: str) -> dict[str, LeafMeta]:
    D = cfg.d_model
    C = (cfg.lru_width or cfg.d_model) // ctx.tp
    W = cfg.conv_width
    return {
        f"{prefix}wy": LeafMeta((D, C), tp_dim=1),
        f"{prefix}wx": LeafMeta((D, C), tp_dim=1),
        f"{prefix}conv": LeafMeta((W, C), tp_dim=1, init="normal", init_scale=0.5),
        f"{prefix}w_r": LeafMeta((C,), tp_dim=0, init="normal", init_scale=8.0),
        f"{prefix}b_r": LeafMeta((C,), tp_dim=0, init="zeros"),
        f"{prefix}w_i": LeafMeta((C,), tp_dim=0, init="normal", init_scale=8.0),
        f"{prefix}b_i": LeafMeta((C,), tp_dim=0, init="zeros"),
        f"{prefix}lam": LeafMeta((C,), tp_dim=0, init="a_log"),
        f"{prefix}wo": LeafMeta((C, D), tp_dim=0),
    }


def block_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict[str, LeafMeta]:
    """Metas of one scanned layer (or super-unit for hybrid)."""
    D = cfg.d_model
    ln = lambda: LeafMeta((D,), tp_dim=None, init="ones")
    if cfg.family in ("dense", "vlm"):
        return {"ln1": ln(), "ln2": ln(),
                **_attn_metas(cfg, ctx), **_mlp_metas(cfg, ctx)}
    if cfg.family == "moe":
        return {"ln1": ln(), "ln2": ln(),
                **_attn_metas(cfg, ctx), **_moe_metas(cfg, ctx)}
    if cfg.family == "ssm":
        return {"ln1": ln(), **_ssm_metas(cfg, ctx)}
    if cfg.family == "hybrid":
        # super-unit = (rec, rec, local-attn), each with its own MLP
        m: dict[str, LeafMeta] = {}
        for p in ("r1_", "r2_"):
            m[f"{p}ln1"] = ln()
            m[f"{p}ln2"] = ln()
            m.update(_rec_metas(cfg, ctx, p))
            m.update({f"{p}{k}": v for k, v in _mlp_metas(cfg, ctx).items()})
        m["at_ln1"] = ln()
        m["at_ln2"] = ln()
        m.update(_attn_metas(cfg, ctx, "at_"))
        m.update({f"at_{k}": v for k, v in _mlp_metas(cfg, ctx).items()})
        return m
    raise ValueError(cfg.family)


def top_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict[str, LeafMeta]:
    V, D = cfg.vocab, cfg.d_model
    v_loc = -(-V // ctx.tp)       # ceil; vocab padded to tp multiple
    m = {
        "embed": LeafMeta((v_loc, D), tp_dim=0, scanned=False, init="embed"),
        "final_norm": LeafMeta((D,), tp_dim=None, scanned=False, init="ones"),
    }
    if not cfg.tie_embeddings:
        m["lm_head"] = LeafMeta((v_loc, D), tp_dim=0, scanned=False, init="embed")
    if cfg.family == "hybrid":
        # unscanned tail recurrent layers (n_layers % 3)
        tail = cfg.n_layers % 3
        for t in range(tail):
            p = f"tail{t}_"
            m[f"{p}ln1"] = LeafMeta((D,), tp_dim=None, scanned=False, init="ones")
            m[f"{p}ln2"] = LeafMeta((D,), tp_dim=None, scanned=False, init="ones")
            for k, v in _rec_metas(cfg, ctx, p).items():
                m[k] = dataclasses.replace(v, scanned=False)
            for k, v in _mlp_metas(cfg, ctx, p).items():
                m[k] = dataclasses.replace(v, scanned=False)
    return m


def n_scan_steps(cfg: ModelConfig) -> int:
    return cfg.n_layers // 3 if cfg.family == "hybrid" else cfg.n_layers


def all_metas(cfg: ModelConfig, ctx: ShardCtx) -> dict[str, dict[str, LeafMeta]]:
    return {"layers": block_metas(cfg, ctx), "top": top_metas(cfg, ctx)}


# ---------------------------------------------------------------------------
# Init (host-side global arrays) + shape-only variant for the dry-run
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, ctx: ShardCtx, key: Array) -> dict:
    metas = all_metas(cfg, ctx)
    L = n_scan_steps(cfg)
    out: dict[str, dict[str, Array]] = {"layers": {}, "top": {}}
    ks = jax.random.split(key, len(metas["layers"]) + len(metas["top"]))
    i = 0
    for name, meta in sorted(metas["layers"].items()):
        out["layers"][name] = init_leaf(ks[i], meta, ctx, L)
        i += 1
    for name, meta in sorted(metas["top"].items()):
        out["top"][name] = init_leaf(ks[i], meta, ctx, L)
        i += 1
    return out


def param_shapes(cfg: ModelConfig, ctx: ShardCtx) -> dict:
    """ShapeDtypeStructs of the storage pytree (dry-run; no allocation)."""
    from repro.models.sharding import storage_shape
    metas = all_metas(cfg, ctx)
    L = n_scan_steps(cfg)
    out: dict[str, dict[str, jax.ShapeDtypeStruct]] = {"layers": {}, "top": {}}
    for name, meta in metas["layers"].items():
        out["layers"][name] = jax.ShapeDtypeStruct(
            storage_shape(meta, ctx, L), jnp.float32)
    for name, meta in metas["top"].items():
        out["top"][name] = jax.ShapeDtypeStruct(
            storage_shape(meta, ctx, L), jnp.float32)
    return out


def y_init(cfg: ModelConfig, ctx: ShardCtx, value: float = 1.0) -> dict:
    """Initial distance-bound state, one per-bucket vector per leaf (per
    layer): shape (L, nb) for scanned leaves, (nb,) for top-level ones,
    with nb = sharding.leaf_nb — the QState y the FSDP gradient sync
    consumes and the trainer updates bucket by bucket from telemetry.

    With ``ctx.qcfg.rotate`` each leaf is seeded from the paper's §6
    rotated-space bound instead of the raw-space guess — see
    :func:`repro.models.sharding.leaf_y0`.  With ``ctx.anchor_grads`` each
    leaf carries ``{"y": ..., "anchor": ...}`` — the anchor (the previous
    step's decoded gradient mean) starts at zero, which is bit-identical to
    the unanchored path on step 0.  Its layout follows
    :func:`repro.models.sharding.anchor_shape`: ZeRO-3 storage
    ``(tp, dp, shard)`` beside the weights when ``ctx.anchor_sharded``
    (rebuilt by the forward gather), legacy replicated ``(m,)`` otherwise.
    """
    from repro.models.sharding import anchor_shape, leaf_nb, leaf_y0
    metas = all_metas(cfg, ctx)
    L = n_scan_steps(cfg)

    def leaf(meta, scanned):
        nb = leaf_nb(meta, ctx)
        shape = (L, nb) if scanned else (nb,)
        y = jnp.full(shape, leaf_y0(meta, ctx, value), jnp.float32)
        if not ctx.anchor_grads:
            return y
        a_shape = anchor_shape(meta, ctx, L if scanned else 0)
        return {"y": y, "anchor": jnp.zeros(a_shape, jnp.float32)}

    return {
        "layers": {k: leaf(m, True) for k, m in metas["layers"].items()},
        "top": {k: leaf(m, False) for k, m in metas["top"].items()},
    }


def tele_zeros(cfg: ModelConfig, ctx: ShardCtx) -> dict:
    """Zero tele inputs, one per leaf, sized to carry the scalar telemetry
    plus the per-bucket maps (and the next anchor when ctx.anchor_grads) —
    see dist/fsdp.py's tele layout."""
    from repro.models.sharding import leaf_tele_width
    metas = all_metas(cfg, ctx)
    L = n_scan_steps(cfg)
    return {
        "layers": {k: jnp.zeros((L, leaf_tele_width(m, ctx)), jnp.float32)
                   for k, m in metas["layers"].items()},
        "top": {k: jnp.zeros((leaf_tele_width(m, ctx),), jnp.float32)
                for k, m in metas["top"].items()},
    }


# ---------------------------------------------------------------------------
# Blocks (operating on gathered weights)
# ---------------------------------------------------------------------------

def _moe_apply(x_norm: Array, wts: dict, cfg: ModelConfig, ctx: ShardCtx):
    """Token-sliced MoE; returns (full out matching x_norm layout, aux)."""
    B, S, D = x_norm.shape
    if ctx.seq_parallel or ctx.tp == 1:
        flat = x_norm.reshape(B * S, D)
        out, aux = MOE.moe_mlp(flat, wts, cfg, ctx)
        return out.reshape(B, S, D), aux
    # non-SP: slice tokens over tp, compute, gather back
    T = B * S
    t_loc = T // ctx.tp
    flat = x_norm.reshape(T, D)
    sl = jax.lax.dynamic_slice_in_dim(flat, tp_index(ctx) * t_loc, t_loc, 0)
    out, aux = MOE.moe_mlp(sl, wts, cfg, ctx)
    full = all_gather_tp(out, ctx, axis=0)
    return full.reshape(B, S, D), aux


def dense_block(x: Array, wts: dict, cfg: ModelConfig, ctx: ShardCtx,
                positions: Array, window: int = 0) -> tuple[Array, Array]:
    a_in = LY.rms_norm(x, wts["ln1"], cfg.norm_eps)
    xg = LY.sp_enter(a_in, ctx)
    att = LY.attention(xg, wts, cfg, ctx, positions=positions,
                       causal=True, window=window)
    x = x + LY.attn_exit(att, cfg, ctx)
    m_in = LY.rms_norm(x, wts["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        out, aux = _moe_apply(m_in, wts, cfg, ctx)
        x = x + out
    else:
        mg = LY.sp_enter(m_in, ctx)
        x = x + LY.sp_exit(LY.mlp(mg, wts, cfg), ctx)
    return x, aux


def ssm_block(x: Array, wts: dict, cfg: ModelConfig, ctx: ShardCtx) -> Array:
    a_in = LY.rms_norm(x, wts["ln1"], cfg.norm_eps)
    xg = LY.sp_enter(a_in, ctx)
    out, _ = SSM.mamba2_block(xg, wts, cfg, ctx)
    return x + LY.sp_exit(out, ctx)


def _sub(wts: dict, prefix: str) -> dict:
    n = len(prefix)
    return {k[n:]: v for k, v in wts.items() if k.startswith(prefix)}


def hybrid_unit(x: Array, wts: dict, cfg: ModelConfig, ctx: ShardCtx,
                positions: Array) -> Array:
    for p in ("r1_", "r2_"):
        sw = _sub(wts, p)
        a_in = LY.rms_norm(x, sw["ln1"], cfg.norm_eps)
        xg = LY.sp_enter(a_in, ctx)
        out, _ = RG.recurrent_block(xg, sw, cfg, ctx)
        x = x + LY.sp_exit(out, ctx)
        m_in = LY.rms_norm(x, sw["ln2"], cfg.norm_eps)
        mg = LY.sp_enter(m_in, ctx)
        x = x + LY.sp_exit(LY.mlp(mg, sw, cfg), ctx)
    sw = _sub(wts, "at_")
    x, _ = dense_block(x, sw, dataclasses.replace(cfg, family="dense"), ctx,
                       positions, window=cfg.window)
    return x


# ---------------------------------------------------------------------------
# Training forward + loss (inside shard_map)
# ---------------------------------------------------------------------------

def _leaf_key(key: Array, name: str) -> Array:
    # deterministic across processes (never Python hash(): it is salted)
    import zlib
    return jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)


def _gather_tree(params: dict, metas: dict, ctx: ShardCtx, y: dict, key: Array,
                 tele: dict, gathers, dtype=jnp.bfloat16) -> dict:
    out = {}
    for name in params:
        out[name] = gather_param(params[name], metas[name], ctx, y[name],
                                 _leaf_key(key, name), tele[name], gathers,
                                 dtype)
    return out


def _prefetch_layer_scan(x0: Array, params_l: dict, metas_l: dict,
                         ctx: ShardCtx, y_l, tele_l, L: int, split,
                         key_fn, apply_fn, remat: bool):
    """Double-buffered layer scan (``ctx.prefetch``): layer i+1's FSDP
    gather is *issued* while layer i computes.

    The carry holds the in-flight handle dict for the layer about to run;
    the body first issues layer i+1 (``lax.cond``-gated off on the last
    iteration), then consumes the carried handles through the pinned
    :func:`repro.models.sharding.gather_param_wait` and runs
    ``apply_fn(x, wts) -> (x', aux)``.  ``key_fn(i)`` must reproduce the
    serial body's per-layer key fold exactly — the split gather shares
    every internal with the monolithic one, so with matching keys the scan
    is bit-identical to the serial formulation (values and grads).
    """
    def issue(i):
        sl = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
        lp = jax.tree.map(sl, params_l)
        ly = jax.tree.map(sl, y_l)
        lt = jax.tree.map(sl, tele_l)
        kl = key_fn(i)
        return {name: gather_param_async(lp[name], metas_l[name], ctx,
                                         ly[name], _leaf_key(kl, name),
                                         lt[name], split)
                for name in lp}

    def body(carry, idx):
        xcur, auxsum, bufs = carry
        nxt = jax.lax.cond(idx < L - 1,
                           lambda i: issue(i + 1),
                           lambda i: jax.tree.map(jnp.zeros_like, bufs),
                           idx)
        wts = {name: gather_param_wait(bufs[name], metas_l[name], ctx, split)
               for name in bufs}
        xnew, aux = apply_fn(xcur, wts)
        return (xnew, auxsum + aux, nxt), None

    body_fn = jax.checkpoint(body) if remat else body
    (xf, aux, _), _ = jax.lax.scan(
        body_fn, (x0, jnp.zeros((), jnp.float32), issue(0)),
        jnp.arange(L, dtype=jnp.int32))
    return xf, aux


def make_loss_fn(cfg: ModelConfig, ctx: ShardCtx) -> Callable:
    """Returns loss_fn(params, tele, batch, key, y) -> (loss, metrics).

    batch: {"tokens": (B, S) int32, "targets": (B, S) int32,
            "mask": (B, S) f32/bool; vlm additionally "img": (B, Timg, D)}
    loss is tp-global / dp-local (DESIGN: the FSDP gather's bwd performs the
    DP mean).
    """
    metas = all_metas(cfg, ctx)
    gathers = make_gathers(ctx)
    split = make_split_gathers(ctx) if ctx.prefetch else None
    L = n_scan_steps(cfg)

    def loss_fn(params, tele, batch, key, y):
        tokens = batch["tokens"]
        B, S = tokens.shape
        kt = jax.random.fold_in(key, 0)

        emb = gather_param(params["top"]["embed"], metas["top"]["embed"], ctx,
                           y["top"]["embed"], _leaf_key(kt, "embed"),
                           tele["top"]["embed"], gathers)
        x = LY.vp_embed(tokens, emb, ctx) * cfg.emb_scale
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["img"].astype(x.dtype), x], axis=1)
        S_full = x.shape[1]
        positions = jnp.arange(S_full, dtype=jnp.int32)

        if ctx.seq_parallel and ctx.tp > 1:
            s_loc = S_full // ctx.tp
            x = jax.lax.dynamic_slice_in_dim(x, tp_index(ctx) * s_loc, s_loc, 1)

        def apply_block(xcur, wts):
            if cfg.family == "ssm":
                return ssm_block(xcur, wts, cfg, ctx), jnp.zeros((), jnp.float32)
            if cfg.family == "hybrid":
                return (hybrid_unit(xcur, wts, cfg, ctx, positions),
                        jnp.zeros((), jnp.float32))
            return dense_block(xcur, wts, cfg, ctx, positions)

        if ctx.prefetch:
            x, aux = _prefetch_layer_scan(
                x, params["layers"], metas["layers"], ctx, y["layers"],
                tele["layers"], L, split,
                lambda i: jax.random.fold_in(key, i + 1), apply_block,
                ctx.remat)
        else:
            def body(carry, xs):
                xcur, auxsum = carry
                lp, ly, lt, idx = xs
                kl = jax.random.fold_in(key, idx + 1)
                wts = _gather_tree(lp, metas["layers"], ctx, ly, kl, lt,
                                   gathers)
                xnew, aux = apply_block(xcur, wts)
                return (xnew, auxsum + aux), None

            body_fn = jax.checkpoint(body) if ctx.remat else body
            xs = (params["layers"],
                  y["layers"],
                  tele["layers"],
                  jnp.arange(L, dtype=jnp.int32))
            (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                       xs)

        # hybrid tail layers (unscanned)
        if cfg.family == "hybrid" and cfg.n_layers % 3:
            for t in range(cfg.n_layers % 3):
                p = f"tail{t}_"
                names = [k for k in metas["top"] if k.startswith(p)]
                kl = jax.random.fold_in(key, 10_000 + t)
                sw = {k[len(p):]: gather_param(
                    params["top"][k], metas["top"][k], ctx, y["top"][k],
                    _leaf_key(kl, k), tele["top"][k], gathers)
                    for k in names}
                a_in = LY.rms_norm(x, sw["ln1"], cfg.norm_eps)
                xg = LY.sp_enter(a_in, ctx)
                out, _ = RG.recurrent_block(xg, sw, cfg, ctx)
                x = x + LY.sp_exit(out, ctx)
                m_in = LY.rms_norm(x, sw["ln2"], cfg.norm_eps)
                mg = LY.sp_enter(m_in, ctx)
                x = x + LY.sp_exit(LY.mlp(mg, sw, cfg), ctx)

        fn = gather_param(params["top"]["final_norm"], metas["top"]["final_norm"],
                          ctx, y["top"]["final_norm"], _leaf_key(kt, "fn"),
                          tele["top"]["final_norm"], gathers)
        x = LY.rms_norm(x, fn, cfg.norm_eps)

        if cfg.tie_embeddings:
            head = emb
        else:
            head = gather_param(params["top"]["lm_head"], metas["top"]["lm_head"],
                                ctx, y["top"]["lm_head"], _leaf_key(kt, "head"),
                                tele["top"]["lm_head"], gathers)

        targets = batch["targets"]
        mask = batch.get("mask")
        if cfg.family == "vlm":
            timg = batch["img"].shape[1]
            pad_t = jnp.zeros((B, timg), targets.dtype)
            targets = jnp.concatenate([pad_t, targets], axis=1)
            pad_m = jnp.zeros((B, timg), jnp.float32)
            m0 = jnp.ones((B, S), jnp.float32) if mask is None else mask.astype(jnp.float32)
            mask = jnp.concatenate([pad_m, m0], axis=1)

        if ctx.seq_parallel and ctx.tp > 1:
            # vocab-parallel CE needs every rank to see every token (the
            # vocab axis is sharded over tp too) — gather tokens back,
            # Megatron-style, before the head.
            x = LY.sp_enter(x, ctx)
        nll_sum, cnt = _ce_sum(x.reshape(-1, cfg.d_model), head,
                               targets.reshape(-1), ctx,
                               None if mask is None else mask.reshape(-1))
        loss = nll_sum / jnp.maximum(cnt, 1.0)

        loss = loss + 0.01 * aux
        metrics = {"loss": loss, "aux": aux}
        # shard_map autodiff computes d(sum over devices of the returned
        # scalar)/dw (transpose(psum) = psum); the loss here is replicated
        # over tp, so scale by 1/tp so per-device grads are exact.
        return loss / ctx.tp, metrics

    return loss_fn


def _ce_sum(x: Array, head: Array, targets: Array, ctx: ShardCtx,
            mask: Optional[Array]):
    """Vocab-parallel CE; returns (sum nll, token count) over given tokens."""
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32).T
    m_loc = jnp.max(logits, axis=-1)
    # stop_gradient: the max-shift cancels in CE's gradient; pmax itself has
    # no differentiation rule.
    m_loc = jax.lax.stop_gradient(m_loc)
    m = jax.lax.pmax(m_loc, ctx.tp_axis) if ctx.tp > 1 else m_loc
    zed = psum_tp(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1), ctx)
    v_loc = head.shape[0]
    off = tp_index(ctx) * v_loc
    local = targets - off
    ok = (local >= 0) & (local < v_loc)
    safe = jnp.clip(local, 0, v_loc - 1)
    tgt = jnp.take_along_axis(logits, safe[:, None], axis=1)[:, 0]
    tgt = psum_tp(jnp.where(ok, tgt, 0.0), ctx)
    nll = jnp.log(zed) + m - tgt
    if mask is not None:
        mf = mask.astype(jnp.float32)
        return jnp.sum(nll * mf), jnp.sum(mf)
    return jnp.sum(nll), jnp.float32(nll.shape[0])
