"""Expert-parallel MoE MLP (top-k router, capacity-bounded, all_to_all).

Experts are sharded over the ``model`` axis (E_loc = E/tp per rank).  Each tp
rank routes a disjoint token slice (the sequence-parallel slice), dispatches
via tiled ``all_to_all``, computes its local experts, and returns tokens with
a second all_to_all.  The router weight is tp-replicated (its gradient is
psum'd by the gather vjp).

Dispatch layout:
  disp  (E = tp*E_loc, C, D)  --a2a(split 0, concat 1)-->  (E_loc, tp*C, D)
  out   (E_loc, tp*C, D)      --a2a(split 1, concat 0)-->  (E, C, D)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx

Array = jax.Array


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)


def moe_mlp(x: Array, w: dict, cfg: ModelConfig, ctx: ShardCtx
            ) -> tuple[Array, Array]:
    """x: (T, D) this rank's token slice.  Returns (out (T,D), aux_loss ()).

    w: {"router": (D, E), "w1": (E_loc, D, F), "w3": (E_loc, D, F) [swiglu],
        "w2": (E_loc, F, D)}
    """
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    e_loc = E // ctx.tp
    C = capacity(T, cfg)

    logits = x.astype(jnp.float32) @ w["router"].astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                                # (T,K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch):  E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                       # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # positions within each expert's capacity buffer
    e_flat = idx.reshape(-1)                                           # (T*K,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)  # (T*K,)
    keep = (pos < C).astype(x.dtype)
    dest = e_flat * C + jnp.minimum(pos, C - 1)

    x_rep = jnp.repeat(x, K, axis=0)                                   # (T*K, D)
    disp = jnp.zeros((E * C, D), x.dtype).at[dest].add(
        x_rep * keep[:, None]).reshape(E, C, D)

    if ctx.tp > 1:
        recv = jax.lax.all_to_all(disp, ctx.tp_axis, split_axis=0,
                                  concat_axis=1, tiled=True)           # (E_loc, tp*C, D)
    else:
        recv = disp

    # expert FFN
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, w["w1"],
                                   preferred_element_type=jnp.float32))
        h = (h * jnp.einsum("ecd,edf->ecf", recv, w["w3"],
                            preferred_element_type=jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", recv, w["w1"],
                                   preferred_element_type=jnp.float32)
                        ).astype(x.dtype)
    eo = jnp.einsum("ecf,efd->ecd", h, w["w2"])                        # (E_loc, tp*C, D)

    if ctx.tp > 1:
        back = jax.lax.all_to_all(eo, ctx.tp_axis, split_axis=1,
                                  concat_axis=0, tiled=True)           # (E, C, D)
    else:
        back = eo

    flat = back.reshape(E * C, D)
    tok = jnp.take(flat, dest, axis=0) * (keep * gate.reshape(-1).astype(x.dtype))[:, None]
    out = tok.reshape(T, K, D).sum(axis=1)
    return out, aux
