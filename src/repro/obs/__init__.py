"""repro.obs — unified observability for kernels → transport → engine → tree.

Zero-dependency (stdlib-only) metrics + tracing + flight recorder +
exporters, OFF by default.  The switchboard:

    import repro.obs as obs
    obs.enable()                      # metrics + tracing + flight recorder
    ... run rounds ...
    open("trace.json", "w").write(obs.export.chrome_trace(obs.tracer()))
    print(obs.export.prometheus_text(obs.registry()))
    obs.disable()

Cost model (the ≤5% acceptance bound): when disabled, instrumented hot
paths either hold a :data:`~repro.obs.registry.NOOP` instrument or check
one module-level boolean — no allocation, no string formatting.  Tracing
and the recorder are strictly opt-in; metrics *scopes* (the per-round
``RoundStats``/``TierStats`` accounting) are always live because the stack
always kept those counts — ``scope()`` merely decides whether they land in
the process registry (exported) or in a detached private registry
(invisible, exactly the old cost).

Clock injection: ``enable(clock=time.monotonic)`` stamps spans with wall
time; with no clock the tracer runs on virtual time fed by the open-loop
sim's event loop (``tracer().feed_time(t)``), so exported traces share the
event-time axis of the latency metrics.
"""
from __future__ import annotations

import itertools
from typing import Callable, Optional

from . import export  # noqa: F401  (re-exported submodule)
from .recorder import DEFAULT_CAPACITY, Dump, FlightRecorder  # noqa: F401
from .registry import (DEFAULT_BOUNDS, NOOP, Counter, Gauge,  # noqa: F401
                       Histogram, Registry, Scope, quantile)
from .trace import Span, Tracer, check_round  # noqa: F401

_metrics_on = False
_trace_on = False
_record_on = False

_registry = Registry()
_tracer = Tracer()
_recorder = FlightRecorder()
_scope_serial = itertools.count(1)


def metrics_enabled() -> bool:
    return _metrics_on


def tracing_enabled() -> bool:
    return _trace_on


def recording_enabled() -> bool:
    return _record_on


def enabled() -> bool:
    return _metrics_on or _trace_on or _record_on


def enable(metrics: bool = True, trace: bool = True, record: bool = True,
           recorder_capacity: Optional[int] = None,
           clock: Optional[Callable[[], float]] = None) -> None:
    """Switch observability on.  ``clock=None`` puts the tracer on fed
    virtual time (the sim's event loop feeds it); pass ``time.monotonic``
    or similar for wall-clock spans.  ``recorder_capacity`` rebuilds the
    flight-recorder ring at that size."""
    global _metrics_on, _trace_on, _record_on, _recorder
    _metrics_on = metrics
    _trace_on = trace
    _record_on = record
    _tracer.clock = clock
    if recorder_capacity is not None and \
            recorder_capacity != _recorder.capacity:
        _recorder = FlightRecorder(recorder_capacity)
    # stream completed spans into the ring so an anomaly dump shows the
    # last N pipeline events, not just the anomaly itself
    _tracer.sink = _recorder.record if (trace and record) else None


def disable() -> None:
    global _metrics_on, _trace_on, _record_on
    _metrics_on = _trace_on = _record_on = False
    _tracer.sink = None


def reset() -> None:
    """Zero all collected state (values, spans, ring) without breaking
    instrument identity — cached counter references stay valid."""
    _registry.reset()
    _tracer.reset()
    _recorder.reset()


def registry() -> Registry:
    return _registry


def tracer() -> Tracer:
    return _tracer


def recorder() -> FlightRecorder:
    return _recorder


def counter(name: str, **labels):
    """A live registry counter when metrics are on, else the no-op stub."""
    return _registry.counter(name, **labels) if _metrics_on else NOOP


def gauge(name: str, **labels):
    return _registry.gauge(name, **labels) if _metrics_on else NOOP


def histogram(name: str, bounds=DEFAULT_BOUNDS, **labels):
    return _registry.histogram(name, bounds=bounds, **labels) \
        if _metrics_on else NOOP


def scope(prefix: str, **labels) -> Scope:
    """An always-live instrument scope for one server/tier instance.

    The per-instance accounting behind ``RoundStats``/``TierStats`` must
    exist whether or not observability is on (the stack has always kept
    those counts), so this never returns a no-op: with metrics enabled the
    scope binds into the process registry (visible to the exporters) under
    a unique ``inst`` serial label; disabled, it binds a detached private
    registry — same cost, invisible."""
    if _metrics_on:
        return _registry.scope(prefix, inst=next(_scope_serial), **labels)
    return Registry().scope(prefix, **labels)


def trigger(reason: str, at: float = 0.0, **attrs):
    """Record an anomaly dump if the flight recorder is on (else None)."""
    if not _record_on:
        return None
    return _recorder.trigger(reason, at=at, **attrs)
