"""Span-based tracing for the aggregation pipeline.

One :class:`Tracer` (usually the process singleton owned by
:mod:`repro.obs`) collects :class:`Span` records driven by an injectable
clock: pass ``clock=time.monotonic`` for wall time, or no clock at all and
feed the sim's virtual heapq time through :meth:`Tracer.feed_time` — the
open-loop event loop does exactly that, so span timestamps are the same
deterministic event times the latency metrics are computed from.

Spans are causally linked per published round.  Instrumented sites address
spans by *key* (a small tuple like ``("round", rid)`` or
``("client", rid, cid)``) rather than by passing span objects through
layer boundaries — the client encoder, the transport reassembler and the
server drain never hold references to each other, so the keyspace is the
only practical join point.  The canonical tree for one round:

    round #rid                        ("round", rid)        [engine/server]
      encode cid                      ("client", rid, cid)  [client/sim]
        chunk (instant, per frame)                          [server/tier]
        reassembly cid                ("reassembly", rid, cid) [session]
        seal (instant)                                      [server/tier]
      fold tier=t                                           [tree tier]
      drain                                                 [server]
      publish (instant)                                     [finalize]

:func:`check_round` audits that tree for causal completeness — the
acceptance criterion every published round must meet in tests and the CI
smoke.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(slots=True)
class Span:
    """One timed (or instant) region.  ``end`` is None while open;
    ``instant`` marks zero-duration point events ("chunk", "seal",
    "publish", state transitions).  Slotted: the tracer creates one of
    these per chunk on the hot receive path, and instance-dict-free
    construction is what keeps enabled tracing inside the <= 5%
    overhead budget at mtu-forced chunk counts."""
    span_id: int
    name: str
    start: float
    end: Optional[float] = None
    parent_id: Optional[int] = None
    attrs: dict = field(default_factory=dict)
    instant: bool = False

    @property
    def duration(self) -> float:
        return (self.end if self.end is not None else self.start) - self.start


# a runaway-trace backstop far above any CI-sized round trace
MAX_SPANS = 200_000


class Tracer:
    """Ordered span store with key-addressed begin/end.

    ``begin(name, key=..., parent=<key or span_id>)`` opens a span;
    ``end(key)`` closes it (idempotent — a second end is a no-op, which is
    what makes ``finalize()`` safe to call from every publish path).
    ``event(...)`` records an instant span.  Keys stay resolvable after
    the span ends so late children (a straggler's seal after the round
    span closed) still attach to the right parent.

    If ``sink`` is set (the flight recorder's ``record``), every completed
    or instant span is also streamed there.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 max_spans: int = MAX_SPANS,
                 sink: Optional[Callable[["Span"], None]] = None):
        self.clock = clock
        self.max_spans = max_spans
        self.sink = sink
        self.spans: "list[Span]" = []
        self.dropped = 0
        self._vt = 0.0                       # fed virtual time (monotonic)
        self._by_key: dict = {}              # key -> Span (latest per key)
        self._ids = itertools.count(1)

    # -- time ------------------------------------------------------------
    def now(self) -> float:
        return self.clock() if self.clock is not None else self._vt

    def feed_time(self, t: float) -> None:
        """Advance the virtual clock (no-op when a real clock is set);
        monotonic — stale feeds never move time backwards."""
        if t > self._vt:
            self._vt = t

    # -- spans -----------------------------------------------------------
    def _resolve_parent(self, parent) -> Optional[int]:
        if parent is None:
            return None
        if isinstance(parent, int):
            return parent
        sp = self._by_key.get(parent)
        if sp is None:
            # auto-create the missing ancestor so late/odd orderings (a
            # frame landing before the round span opened in a replay)
            # never orphan a child; the synthetic parent is an instant
            sp = self.begin(parent[0], key=parent, instant=True)
            sp.end = sp.start
        return sp.span_id

    def begin(self, name: str, key=None, parent=None, t: Optional[float] = None,
              instant: bool = False, **attrs) -> Optional[Span]:
        if len(self.spans) >= self.max_spans:
            self.dropped += 1
            return None
        sp = Span(span_id=next(self._ids), name=name,
                  start=self.now() if t is None else t,
                  parent_id=self._resolve_parent(parent),
                  attrs=attrs, instant=instant)
        self.spans.append(sp)
        if key is not None:
            self._by_key[key] = sp
        return sp

    def end(self, span_or_key, t: Optional[float] = None, **attrs) -> None:
        sp = span_or_key if isinstance(span_or_key, Span) \
            else self._by_key.get(span_or_key)
        if sp is None or sp.end is not None:
            return
        sp.end = self.now() if t is None else t
        if attrs:
            sp.attrs.update(attrs)
        if self.sink is not None:
            self.sink(sp)

    def event(self, name: str, parent=None, t: Optional[float] = None,
              **attrs) -> Optional[Span]:
        sp = self.begin(name, parent=parent, t=t, instant=True, **attrs)
        if sp is not None:
            sp.end = sp.start
            if self.sink is not None:
                self.sink(sp)
        return sp

    def get(self, key) -> Optional[Span]:
        return self._by_key.get(key)

    def children(self, span_id: int) -> "list[Span]":
        return [s for s in self.spans if s.parent_id == span_id]

    def reset(self) -> None:
        self.spans = []
        self.dropped = 0
        self._vt = 0.0
        self._by_key = {}
        self._ids = itertools.count(1)


def _under(tracer: Tracer, root_id: int) -> "list[Span]":
    """All spans in the subtree rooted at ``root_id``."""
    kids: dict = {}
    for s in tracer.spans:
        kids.setdefault(s.parent_id, []).append(s)
    out, stack = [], [root_id]
    while stack:
        sid = stack.pop()
        for s in kids.get(sid, ()):
            out.append(s)
            stack.append(s.span_id)
    return out


def check_round(tracer: Tracer, round_id: int, accepted=(),
                require_fold: bool = False) -> "list[str]":
    """Audit one published round's span tree for causal completeness.

    Returns a list of problems (empty = complete): the round span must
    exist and be closed; no span under it may have a dangling parent_id;
    a "publish" instant must be present; a "drain" span must be present
    when any client was accepted; a "fold" span when ``require_fold`` (the
    tree path); and every accepted client must show encode → >=1 chunk →
    seal.  Extra spans (e.g. from a parity replay of the same round) are
    tolerated — completeness, not exclusivity, is the contract.
    """
    problems: "list[str]" = []
    root = tracer.get(("round", round_id))
    if root is None:
        return [f"round {round_id}: no round span"]
    if root.end is None:
        problems.append(f"round {round_id}: round span never ended")

    ids = {s.span_id for s in tracer.spans}
    sub = _under(tracer, root.span_id)
    for s in sub:
        if s.parent_id is not None and s.parent_id not in ids:
            problems.append(f"round {round_id}: span {s.name}#{s.span_id} "
                            f"has orphan parent {s.parent_id}")

    names = {}
    for s in sub:
        names.setdefault(s.name, []).append(s)
    if "publish" not in names:
        problems.append(f"round {round_id}: no publish event")
    if accepted and "drain" not in names:
        problems.append(f"round {round_id}: no drain span")
    if require_fold and "fold" not in names:
        problems.append(f"round {round_id}: no fold span")

    for cid in accepted:
        enc = tracer.get(("client", round_id, cid))
        if enc is None:
            problems.append(f"round {round_id}: client {cid} has no "
                            f"encode span")
            continue
        client_sub = _under(tracer, enc.span_id)
        kinds = {s.name for s in client_sub}
        if "chunk" not in kinds:
            problems.append(f"round {round_id}: client {cid} has no chunk "
                            f"events")
        if "seal" not in kinds:
            problems.append(f"round {round_id}: client {cid} was never "
                            f"sealed")
    return problems
