"""Exporters: Chrome trace-event JSON (Perfetto-viewable) and Prometheus
text exposition.

``chrome_trace`` turns a :class:`~repro.obs.trace.Tracer`'s spans into the
Chrome trace-event format (the JSON array flavour) that
https://ui.perfetto.dev opens directly: complete ("X") events for timed
spans, instant ("i") events for point events, one pid lane per round and
one tid lane per client/stage so a round's pipeline reads left-to-right.

``prometheus_text`` renders a :class:`~repro.obs.registry.Registry` in the
text exposition format (# HELP/# TYPE + samples; histograms as cumulative
``_bucket{le=...}`` series plus ``_sum``/``_count``).
``parse_prometheus_text`` is the minimal inverse used by the round-trip
test — samples back to ``{(name, labels): value}``.
"""
from __future__ import annotations

import json

from .registry import Registry
from .trace import Span, Tracer


def _lane(span: Span) -> "tuple[int, str]":
    """(pid, tid name) for one span: pid = round id (0 when unknown), tid
    groups the per-client subtrees apart from the round-level stages."""
    rid = span.attrs.get("round", 0)
    cid = span.attrs.get("client")
    tid = f"client {cid}" if cid is not None else span.name \
        if span.name in ("round", "encode") else "stages"
    return int(rid), tid


def chrome_trace(tracer: Tracer) -> str:
    """The tracer's spans as a Chrome trace-event JSON string (µs
    timestamps, as the format requires)."""
    tids: dict = {}

    def tid_of(pid: int, name: str) -> int:
        return tids.setdefault((pid, name), len(tids) + 1)

    events = []
    for sp in tracer.spans:
        pid, lane = _lane(sp)
        tid = tid_of(pid, lane)
        args = {k: v for k, v in sp.attrs.items()}
        args["span_id"] = sp.span_id
        if sp.parent_id is not None:
            args["parent_id"] = sp.parent_id
        if sp.instant:
            events.append({"name": sp.name, "ph": "i", "s": "t",
                           "ts": sp.start * 1e6, "pid": pid, "tid": tid,
                           "args": args})
        else:
            end = sp.end if sp.end is not None else sp.start
            events.append({"name": sp.name, "ph": "X",
                           "ts": sp.start * 1e6,
                           "dur": max(0.0, (end - sp.start) * 1e6),
                           "pid": pid, "tid": tid, "args": args})
    # name the lanes so Perfetto shows "round 7 / client 3" not bare ints
    for (pid, name), tid in sorted(tids.items(), key=lambda kv: kv[1]):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    return json.dumps(events)


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt(v) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def prometheus_text(reg: Registry) -> str:
    """The registry in Prometheus text exposition format."""
    lines: "list[str]" = []
    typed: set = set()
    for inst in reg.instruments():
        if inst.name not in typed:
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            typed.add(inst.name)
        if inst.kind == "histogram":
            cum = 0
            for edge, c in zip(inst.bounds, inst.counts):
                cum += c
                lab = dict(inst.labels, le=repr(float(edge)))
                lines.append(f"{inst.name}_bucket{_label_str(lab)} {cum}")
            lab = dict(inst.labels, le="+Inf")
            lines.append(f"{inst.name}_bucket{_label_str(lab)} {inst.count}")
            lines.append(f"{inst.name}_sum{_label_str(inst.labels)} "
                         f"{_fmt(inst.total)}")
            lines.append(f"{inst.name}_count{_label_str(inst.labels)} "
                         f"{inst.count}")
        else:
            lines.append(f"{inst.name}{_label_str(inst.labels)} "
                         f"{_fmt(inst.value)}")
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """Minimal exposition-format parser: ``{(name, ((k, v), ...)): float}``
    for every sample line.  Enough to verify the exporter round-trips; not
    a general Prometheus client."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        metric, val = line.rsplit(" ", 1)
        if "{" in metric:
            name, rest = metric.split("{", 1)
            body = rest.rsplit("}", 1)[0]
            labels = []
            for part in _split_labels(body):
                k, v = part.split("=", 1)
                labels.append((k, json.loads(v)))   # v is a quoted string
            key = (name, tuple(sorted(labels)))
        else:
            key = (metric, ())
        out[key] = float(val)
    return out


def _split_labels(body: str) -> "list[str]":
    """Split `k1="v1",k2="v2"` on commas outside quotes."""
    parts, cur, inq = [], [], False
    for ch in body:
        if ch == '"':
            inq = not inq
            cur.append(ch)
        elif ch == "," and not inq:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts
