"""Bounded flight recorder: the last N events, dumped on anomaly.

The tracer streams every completed span into :meth:`FlightRecorder.record`
(plus any instrumented site can record ad-hoc events).  The ring buffer
keeps only the most recent ``capacity`` records — constant memory however
long the run — and :meth:`trigger` snapshots them the moment an anomaly
fires: a tier saturation REJECT, a payload-CRC seal failure, a forced
publish past the drain deadline.  The dump answers "what were the last N
things that happened before it went wrong" without tracing everything to
disk all the time.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Dump:
    """One anomaly snapshot: the reason plus the (oldest-first) last-N
    event records at trigger time."""
    reason: str
    at: float
    events: list
    attrs: dict = field(default_factory=dict)


DEFAULT_CAPACITY = 256


class FlightRecorder:
    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity <= 0:
            raise ValueError("flight recorder capacity must be positive")
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self.dumps: "list[Dump]" = []
        self.recorded = 0

    def record(self, event) -> None:
        """Append one record (a Span or any small event object)."""
        self._ring.append(event)
        self.recorded += 1

    def snapshot(self) -> list:
        """The current ring contents, oldest first."""
        return list(self._ring)

    def trigger(self, reason: str, at: float = 0.0, **attrs) -> Dump:
        """Anomaly: freeze the ring into a :class:`Dump` (the ring keeps
        rolling afterwards — back-to-back anomalies each get their own
        window)."""
        d = Dump(reason=reason, at=at, events=self.snapshot(), attrs=attrs)
        self.dumps.append(d)
        return d

    def last_dump(self) -> Optional[Dump]:
        return self.dumps[-1] if self.dumps else None

    def reset(self) -> None:
        self._ring.clear()
        self.dumps = []
        self.recorded = 0
