"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The registry is the single accounting store of the aggregation stack
(ISSUE 8): the per-round ``RoundStats`` / ``TierStats`` surfaces and the
kernel ``DISPATCH_COUNTS`` dict are all thin views over instruments that
live here, instead of parallel hand-rolled increments.  Zero dependencies
beyond the stdlib (numpy never enters this module), so the hot-path cost
of an increment is one dict hit plus an integer add.

Three instrument kinds, all label-keyed — ``registry.counter(
"chunk_retransmits", round=7, tier=3)`` names one time series per distinct
label set:

* :class:`Counter` — monotonically increasing integer (``inc``).
* :class:`Gauge` — last-written value (``set``) with a max-tracking mode
  (``set_max``) for high-water marks like ``peak_staging_bytes``.
* :class:`Histogram` — fixed-bucket counts (mergeable across registries,
  Prometheus-exportable) plus an exact sample reservoir (up to
  :data:`SAMPLE_CAP` observations) so ``quantile`` reproduces
  ``np.percentile`` bit-for-bit on CI-sized traces and only falls back to
  bucket interpolation beyond the cap.

When observability is globally disabled, the convenience constructors in
:mod:`repro.obs` hand out :data:`NOOP` — a do-nothing singleton with the
full instrument surface — so instrumented call sites pay one truthiness
check and nothing else.

:class:`Scope` bundles the instruments of one server/tier instance under a
shared label set; ``Scope.fill`` materializes them back onto a stats
dataclass (the registry-read path the per-round telemetry now takes).
"""
from __future__ import annotations

import math
from typing import Optional

# exact-quantile reservoir size; past this the histogram stops retaining
# raw samples and quantile() interpolates within buckets instead
SAMPLE_CAP = 4096

# generic log-spaced ladder covering seconds-scale latencies through
# byte/count-scale magnitudes (1-2.5-5 per decade)
DEFAULT_BOUNDS = tuple(m * 10.0 ** e for e in range(-6, 7)
                       for m in (1.0, 2.5, 5.0))


def quantile(values, p: float) -> float:
    """The p-th percentile (0..100) with ``np.percentile``'s default
    linear interpolation, including its two-sided lerp form — the ONE
    quantile implementation the sim and the benchmarks share (ISSUE 8
    satellite; previously each open-coded its own percentile/median).
    """
    vals = sorted(float(v) for v in values)
    if not vals:
        raise ValueError("quantile of an empty sequence")
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile {p} outside [0, 100]")
    pos = (p / 100.0) * (len(vals) - 1)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    a, b = vals[lo], vals[hi]
    t = pos - lo
    # numpy's _lerp switches forms at t=0.5 for monotonicity; mirror it so
    # the old-vs-new p50/p99 agreement is exact, not approximate
    if t >= 0.5:
        return b - (b - a) * (1.0 - t)
    return a + (b - a) * t


class Counter:
    """A monotonically increasing scalar."""
    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """A last-written (or max-tracked) scalar."""
    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def set_max(self, v) -> None:
        if v > self.value:
            self.value = v

    def reset(self) -> None:
        self.value = 0


class Histogram:
    """Fixed-bucket histogram + exact reservoir for small-N quantiles.

    ``bounds`` are upper bucket edges (ascending); observations above the
    last edge land in the implicit +Inf bucket.  ``merge`` adds another
    histogram's buckets (and reservoir, while both fit under the cap) —
    the mergeable/fleet-reducible shape Prometheus-style histograms have.
    """
    __slots__ = ("name", "labels", "bounds", "counts", "count", "total",
                 "vmin", "vmax", "samples", "exact")
    kind = "histogram"

    def __init__(self, name: str = "", labels: Optional[dict] = None,
                 bounds: "tuple[float, ...]" = DEFAULT_BOUNDS):
        self.name = name
        self.labels = {} if labels is None else labels
        self.bounds = tuple(bounds)
        if any(nxt <= prev for nxt, prev in zip(self.bounds[1:], self.bounds)):
            raise ValueError("histogram bounds must be strictly ascending")
        self.counts = [0] * (len(self.bounds) + 1)   # [..., +Inf]
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.samples: "list[float]" = []
        self.exact = True

    @classmethod
    def from_values(cls, values, bounds: "tuple[float, ...]" = DEFAULT_BOUNDS
                    ) -> "Histogram":
        """An unregistered histogram over a finished sample set."""
        h = cls(bounds=bounds)
        for v in values:
            h.observe(v)
        return h

    def _bucket(self, v: float) -> int:
        lo, hi = 0, len(self.bounds)
        while lo < hi:                       # first edge >= v
            mid = (lo + hi) // 2
            if self.bounds[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, v) -> None:
        v = float(v)
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if self.exact:
            if len(self.samples) < SAMPLE_CAP:
                self.samples.append(v)
            else:
                self.samples.clear()         # reservoir overflowed: buckets
                self.exact = False           # are the record from here on

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, p: float) -> float:
        """Exact (np.percentile-identical) while the reservoir holds every
        observation; bucket-interpolated beyond :data:`SAMPLE_CAP`."""
        if self.count == 0:
            raise ValueError("quantile of an empty histogram")
        if self.exact:
            return quantile(self.samples, p)
        # cumulative-bucket interpolation, clamped to the observed range
        target = (p / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.vmin if i == 0 else max(self.vmin, self.bounds[i - 1])
            hi = self.vmax if i >= len(self.bounds) \
                else min(self.vmax, self.bounds[i])
            if cum + c >= target:
                frac = (target - cum) / c
                return lo + (hi - lo) * frac
            cum += c
        return self.vmax

    def merge(self, other: "Histogram") -> None:
        if other.bounds != self.bounds:
            raise ValueError("cannot merge histograms with different bounds")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        if (self.exact and other.exact
                and len(self.samples) + len(other.samples) <= SAMPLE_CAP):
            self.samples.extend(other.samples)
        else:
            self.samples.clear()
            self.exact = False

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.samples = []
        self.exact = True


class _Noop:
    """The disabled-path instrument: full surface, no state, no cost
    beyond the call."""
    __slots__ = ()
    kind = "noop"
    name = ""
    labels: dict = {}
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def set_max(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass

    def reset(self) -> None:
        pass


NOOP = _Noop()


class Registry:
    """Label-keyed instrument store; one per process by default
    (:func:`repro.obs.registry_`), standalone instances for tests."""

    def __init__(self):
        self._instruments: dict = {}     # (name, sorted labelitems) -> inst

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())))

    def _get(self, cls, name: str, labels: dict, **kw):
        key = self._key(name, labels)
        inst = self._instruments.get(key)
        if inst is None:
            inst = cls(name, labels, **kw)
            self._instruments[key] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"{name}{labels} already registered as {inst.kind}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: "tuple[float, ...]" =
                  DEFAULT_BOUNDS, **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def instruments(self) -> list:
        """Every registered instrument, sorted by (name, labels) — the
        exporters' stable iteration order."""
        return [self._instruments[k] for k in sorted(self._instruments)]

    def value(self, name: str, **labels):
        inst = self._instruments.get(self._key(name, labels))
        return None if inst is None else inst.value

    def reset(self) -> None:
        """Zero every instrument's state, keeping instrument identity (so
        cached references — e.g. the kernel dispatch counters — survive)."""
        for inst in self._instruments.values():
            inst.reset()

    def clear(self) -> None:
        self._instruments.clear()

    def scope(self, prefix: str, **labels) -> "Scope":
        return Scope(self, prefix, labels)

    def __len__(self) -> int:
        return len(self._instruments)


class Scope:
    """One instance's instrument bundle under a shared label set.

    The per-round stats dedupe (ISSUE 8 satellite): an
    :class:`~repro.agg.server.AggServer` or tree tier increments ONLY its
    scope — ``scope.inc("accepted")`` is the registry counter
    ``{prefix}_accepted{labels}`` — and ``fill`` materializes the counters
    back onto the legacy ``RoundStats``/``TierStats`` dataclass, so the
    dataclass surface every test and caller reads is a registry read, not
    a parallel account.
    """
    __slots__ = ("_reg", "_prefix", "_labels", "_insts")

    def __init__(self, reg: Registry, prefix: str, labels: dict):
        self._reg = reg
        self._prefix = prefix
        self._labels = labels
        self._insts: dict = {}           # field -> instrument

    def inc(self, field: str, n: int = 1) -> None:
        inst = self._insts.get(field)
        if inst is None:
            inst = self._reg.counter(f"{self._prefix}_{field}",
                                     **self._labels)
            self._insts[field] = inst
        inst.value += n

    def set_max(self, field: str, v) -> None:
        inst = self._insts.get(field)
        if inst is None:
            inst = self._reg.gauge(f"{self._prefix}_{field}", **self._labels)
            self._insts[field] = inst
        inst.set_max(v)

    def value(self, field: str):
        inst = self._insts.get(field)
        return 0 if inst is None else inst.value

    def fill(self, obj) -> None:
        """Write every touched instrument's value onto ``obj``'s field of
        the same name (untouched fields keep the dataclass defaults)."""
        for field, inst in self._insts.items():
            setattr(obj, field, inst.value)
