"""ZeRO-3 parameter gather with quantized gradient reduce-scatter.

Storage layout (models/sharding.py): every parameter leaf lives *flat*,
padded to ``dp * bucket`` granularity and sharded over the DP mesh axes.
Inside the layer body :func:`make_fsdp_gather` rebuilds the full flat weight:

  forward:   w_full = all_gather(cast(w_shard, gather_dtype))  over DP axes
  backward:  g_shard = quantized reduce-scatter-mean of the DP cotangents
             (``sync="lq"``: repro.dist.collectives.rh_reduce_scatter_mean,
             the paper's lattice quantization; ``sync="fp32"``: exact
             psum_scatter / dp).  With ``qcfg.packed`` (default) every
             recursive-halving hop moves the fused-Pallas packed payload
             (bits_for_q(q) bits per coordinate + the per-bucket sides
             sidecar) instead of 32-bit color buffers; see
             :func:`wire_bytes_bwd` for the per-leaf accounting.

Telemetry rides the cotangent of a dummy ``tele`` input: the backward pass
writes ``[max_dist, fails, y_next]`` (TELE_WIDTH columns) as the "gradient"
of ``tele``, so ``jax.grad`` w.r.t. the tele pytree delivers per-leaf decode
statistics to the trainer, which escalates the distance bound ``y`` on
detected failures (the SPMD form of the paper's RobustAgreement retry).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import (QSyncConfig, flat_size_padded,
                                    rh_reduce_scatter_mean, wire_bytes_rh)

Array = jax.Array

# tele rows: [max observed distance, decode failures, suggested next y]
TELE_WIDTH = 3


@dataclasses.dataclass(frozen=True)
class FSDPConfig:
    """Static config of the FSDP gather (derived from ShardCtx)."""
    axes: tuple[str, ...] = ("data",)
    qcfg: QSyncConfig = QSyncConfig()
    sync: str = "lq"                    # "lq" | "fp32"
    gather_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.sync not in ("lq", "fp32"):
            raise ValueError(f"sync must be 'lq' or 'fp32', got {self.sync!r}")


def pad_to_shardable(n: int, dp: int, bucket: int) -> int:
    """Smallest multiple of dp*bucket >= n (flat storage size of a leaf)."""
    g = max(dp * bucket, 1)
    return -(-max(n, 1) // g) * g


def _dp_sizes(axes) -> list[int]:
    return [jax.lax.psum(1, ax) for ax in axes]


def _effective_bucket(cfg: QSyncConfig, m: int, dp: int) -> int:
    """Largest power-of-two bucket <= cfg.bucket that tiles m over dp ranks.

    Mirrors models/sharding.effective_bucket: small leaves are padded at a
    shrunken-bucket granularity, so the gradient reduce-scatter must pick a
    bucket size b with m % (dp*b) == 0.  Halving from cfg.bucket always
    terminates because the storage padding used some cfg.bucket / 2^j.
    """
    b = cfg.bucket
    while b > 1 and m % (dp * b):
        b //= 2
    return b


def wire_bytes_bwd(m: int, sizes: "list[int]", cfg: FSDPConfig) -> int:
    """Bytes *sent per rank* by one gradient sync of a gathered leaf.

    m: gathered flat length (dp * shard); sizes: DP mesh axis sizes in the
    order of cfg.axes (the bwd reduce-scatters over them outermost first,
    the working segment shrinking by each axis size).

    sync="lq": recursive-halving rounds carry the packed payload
    (wire_bytes_rh: bits_for_q(q) bits/coord + the per-bucket sides
    sidecar).  sync="fp32": ring psum_scatter moving (ws-1)/ws of the
    segment as f32 per axis.
    """
    dp = int(np.prod(sizes))
    total, cur = 0, m
    if cfg.sync == "fp32":
        for ws in sizes:
            total += 4 * (cur - cur // ws)
            cur //= ws
        return total
    b = _effective_bucket(cfg.qcfg, m, dp)
    qc = dataclasses.replace(cfg.qcfg, bucket=b)
    for ws in sizes:
        total += wire_bytes_rh(cur, ws, qc)
        cur //= ws
    return total


def make_fsdp_gather(cfg: FSDPConfig):
    """Returns gather(bundle) -> w_full.

    bundle: {"w": (shard,) storage shard, "y": () f32 distance bound,
             "key": PRNG key, "tele": (TELE_WIDTH,) zeros}.
    w_full: (dp * shard,) in cfg.gather_dtype.
    """
    gdt = jnp.dtype(cfg.gather_dtype)

    def _gather_fwd_value(w: Array) -> Array:
        w = w.astype(gdt)
        # innermost axis first so the concatenation order matches the
        # (outer, ..., inner)-major flat storage layout
        for ax in reversed(cfg.axes):
            w = jax.lax.all_gather(w, ax, axis=0, tiled=True)
        return w

    @jax.custom_vjp
    def gather(bundle):
        return _gather_fwd_value(bundle["w"])

    def fwd(bundle):
        res = (bundle["w"], bundle["y"], bundle["key"])
        return _gather_fwd_value(bundle["w"]), res

    def bwd(res, g):
        w_shard, y, key = res
        g = g.astype(jnp.float32)
        sizes = _dp_sizes(cfg.axes)
        dp = int(np.prod(sizes))

        if cfg.sync == "fp32":
            gs = g
            for ax in cfg.axes:          # outermost first: keep rank's segment
                gs = jax.lax.psum_scatter(gs, ax, scatter_dimension=0,
                                          tiled=True)
            g_shard = gs / dp
            tele = jnp.zeros((TELE_WIDTH,), jnp.float32)
        else:
            b = _effective_bucket(cfg.qcfg, g.shape[0], dp)
            qc = dataclasses.replace(cfg.qcfg, bucket=b)
            fails = jnp.zeros((), jnp.float32)
            max_dist = jnp.zeros((), jnp.float32)
            y_next = jnp.zeros((), jnp.float32)
            g_shard = g
            for i, ax in enumerate(cfg.axes):   # outermost first
                nb = g_shard.shape[0] // b
                y_b = jnp.full((nb,), y, jnp.float32)
                g_shard, aux = rh_reduce_scatter_mean(
                    g_shard, y_b, jax.random.fold_in(key, i), ax, qc)
                fails = fails + aux.fails
                max_dist = jnp.maximum(max_dist, aux.max_dist)
                y_next = jnp.maximum(y_next, aux.y_next)
            tele = jnp.stack([max_dist, fails, y_next])

        ct = {
            "w": g_shard.astype(w_shard.dtype),
            "y": jnp.zeros_like(y),
            "key": np.zeros(np.shape(key), jax.dtypes.float0),
            "tele": tele,
        }
        return (ct,)

    gather.defvjp(fwd, bwd)
    return gather
