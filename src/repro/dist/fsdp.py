"""ZeRO-3 parameter gather with quantized gradient reduce-scatter.

Storage layout (models/sharding.py): every parameter leaf lives *flat*,
padded to ``dp * bucket`` granularity and sharded over the DP mesh axes.
Inside the layer body :func:`make_fsdp_gather` rebuilds the full flat weight:

  forward:   w_full = all_gather(cast(w_shard, gather_dtype))  over DP axes
  backward:  g_shard = quantized reduce-scatter-mean of the DP cotangents
             (``sync="lq"``: repro.dist.collectives.rh_reduce_scatter_mean,
             the paper's lattice quantization; ``sync="fp32"``: exact
             psum_scatter / dp).  With ``qcfg.packed`` (default) every
             recursive-halving hop moves the fused-Pallas packed payload
             (bits_for_q(q) bits per coordinate + the per-bucket sides
             sidecar) instead of 32-bit color buffers; see
             :func:`wire_bytes_bwd` for the per-leaf accounting.

Per-bucket state: the bundle's ``y`` entry is either a () scalar (legacy)
or a per-bucket ``(nb,)`` vector with ``nb = m / bucket`` of the *gathered*
leaf.  Multi-axis DP meshes thread the per-bucket bounds across the rh
chain via ``QSyncAux.y_seg`` (each axis' reduce-scatter consumes the kept
segment's bounds from the previous axis) instead of broadcasting one scalar
per leaf, and the backward all-gathers the final segment's per-bucket
telemetry so every rank reports identical ``(nb,)`` failure/distance maps.

Anchored mode (``FSDPConfig.anchored``): the ``y`` entry is a dict
``{"y": (nb,), "anchor": ...}`` — the anchor is the previous step's decoded
gradient mean.  The anchor arrives either *replicated* (legacy, shape
``(m,)``) or *sharded* like the weights (``FSDPConfig.anchor_sharded``:
shape ``(shard,)`` = ``m // dp``, the rank's own slice): the forward then
rebuilds the full anchor with a second tiled all-gather in the same
prefetch slot as the weight gather (f32 — the anchor must stay exact), so
anchoring stops costing a replicated ``(m,)`` vector of state per leaf and
the *backward sync moves zero extra anchor bytes* either way.  The DP sync
runs the *butterfly* topology with a :class:`repro.core.qstate.QState`
(encode ``g - anchor``): the butterfly's common full-length output is
simultaneously this rank's shard (sliced locally) and the next step's
anchor, maintained with zero extra communication.  With a sharded anchor
the telemetry carries back only the rank's ``(shard,)`` slice of that
output.  Cross-step gradient correlation makes ``|g_t - mean_{t-1}|``
much smaller than ``|g_t|``, so ``y`` tightens across steps (the paper's
distance-dependent bound, realized step over step).  The butterfly moves
log2(world) full payloads where rh moves ~1 — still ~8x under fp32 at
q=16 for world <= 256.

Prefetch pipelining (``FSDPConfig.prefetch``, consumed by the model scan —
see models/transformer.py): :func:`make_fsdp_gather_split` splits the
monolithic ``gather(bundle)`` custom-vjp into an *issue* half
(``gather_async``: the same collective + quantized-RS vjp, its output
pinned behind an ``optimization_barrier``) and a *consume* half
(:func:`gather_wait`: a custom-vjp identity barrier).  The model's layer
scan carries the issued handle for layer k+1 while layer k computes, so
the all-gather overlaps forward compute — and, transposed, layer k's
quantized reduce-scatter overlaps layer k-1's cotangent compute.  The
barriers pin the consumption subgraph to the same fusion context as the
serial formulation, keeping prefetched training bit-identical to serial
(XLA CPU FMA-contracts mul-add chains per fusion context otherwise).

Telemetry rides the cotangent of a dummy ``tele`` input: the backward pass
writes ``[max_dist, fails, y_next]`` (TELE_WIDTH columns), then the
per-bucket maps ``dist_b`` / ``fails_b`` (nb columns each) when the caller
sized the tele leaf for them (:func:`tele_width`), then the next-step anchor
(m columns) in anchored mode — so ``jax.grad`` w.r.t. the tele pytree
delivers per-leaf, per-bucket decode statistics (and the new anchor) to the
trainer, which runs :func:`repro.core.qstate.update_y` per bucket (escalate
failed buckets, relax clean ones — the SPMD form of the paper's
RobustAgreement retry).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire_accounting as WA
from repro.core.qstate import QState
from repro.dist.collectives import (QSyncConfig, butterfly_allreduce_mean,
                                    flat_size_padded, rh_reduce_scatter_mean,
                                    wire_bytes_butterfly, wire_bytes_rh)

Array = jax.Array

# tele scalar rows: [max observed distance, decode failures, suggested next y]
TELE_WIDTH = 3


@dataclasses.dataclass(frozen=True)
class FSDPConfig:
    """Static config of the FSDP gather (derived from ShardCtx)."""
    axes: tuple[str, ...] = ("data",)
    qcfg: QSyncConfig = QSyncConfig()
    sync: str = "lq"                    # "lq" | "fp32"
    gather_dtype: str = "bfloat16"
    anchored: bool = False              # butterfly sync anchored on the
                                        # previous step's decoded mean
    anchor_sharded: bool = True         # anchored: store (shard,) anchors and
                                        # rebuild via a fwd all-gather (f32)
                                        # instead of replicating (m,) state
    prefetch: bool = False              # model scans double-buffer the gather
                                        # (issue layer k+1 while k computes)

    def __post_init__(self):
        if self.sync not in ("lq", "fp32"):
            raise ValueError(f"sync must be 'lq' or 'fp32', got {self.sync!r}")


def pad_to_shardable(n: int, dp: int, bucket: int) -> int:
    """Smallest multiple of dp*bucket >= n (flat storage size of a leaf)."""
    g = max(dp * bucket, 1)
    return -(-max(n, 1) // g) * g


def _dp_sizes(axes) -> list[int]:
    return [jax.lax.psum(1, ax) for ax in axes]


def _effective_bucket(cfg: QSyncConfig, m: int, dp: int) -> int:
    """Largest power-of-two bucket <= cfg.bucket that tiles m over dp ranks.

    Mirrors models/sharding.effective_bucket: small leaves are padded at a
    shrunken-bucket granularity, so the gradient reduce-scatter must pick a
    bucket size b with m % (dp*b) == 0.  Halving from cfg.bucket always
    terminates because the storage padding used some cfg.bucket / 2^j.
    """
    b = cfg.bucket
    while b > 1 and m % (dp * b):
        b //= 2
    return b


def leaf_nb(m: int, dp: int, qcfg: QSyncConfig) -> int:
    """Bucket count of a gathered leaf's DP gradient sync (static)."""
    return m // _effective_bucket(qcfg, m, dp)


def tele_width(nb: int, m: int = 0, anchored: bool = False) -> int:
    """Tele-leaf length carrying per-bucket maps (+ the anchor if asked):
    [3 scalars | dist_b (nb) | fails_b (nb) | anchor_next (m, anchored)].

    ``m`` is the anchor length the telemetry carries back: the full
    gathered length for legacy replicated anchors, the rank's *shard*
    length (``m // dp``) when the anchor is stored sharded
    (``FSDPConfig.anchor_sharded`` — see models/sharding.leaf_anchor_len).
    """
    return TELE_WIDTH + 2 * nb + (m if anchored else 0)


def wire_bytes_bwd(m: int, sizes: "list[int]", cfg: FSDPConfig) -> int:
    """Bytes *sent per rank* by one gradient sync of a gathered leaf.

    m: gathered flat length (dp * shard); sizes: DP mesh axis sizes in the
    order of cfg.axes (the bwd reduce-scatters over them outermost first,
    the working segment shrinking by each axis size).

    sync="lq": recursive-halving rounds carry the packed payload
    (wire_bytes_rh: bits_for_q(q) bits/coord + the per-bucket sides
    sidecar); anchored mode runs the full-length butterfly per axis
    (log2(ws) full payloads each — the common output doubles as the next
    anchor).  sync="fp32": ring psum_scatter moving (ws-1)/ws of the
    segment as f32 per axis.  All byte arithmetic delegates to
    repro.core.wire_accounting (the repo's one definition).
    """
    dp = int(np.prod(sizes))
    total, cur = 0, m
    if cfg.sync == "fp32":
        for ws in sizes:
            total += WA.fp32_ring_reduce_scatter_bytes(cur, ws)
            cur //= ws
        return total
    b = _effective_bucket(cfg.qcfg, m, dp)
    qc = dataclasses.replace(cfg.qcfg, bucket=b)
    if cfg.anchored:
        # NOTE the sync itself carries zero anchor bytes regardless of
        # anchor_sharded: the butterfly's common output doubles as the next
        # anchor, and a sharded anchor's rebuild rides the *forward* gather
        # slot (anchor_bytes_step / WA.anchor_state_bytes account for the
        # per-step anchor state beyond the rank's own shard).
        return sum(wire_bytes_butterfly(m, ws, qc) for ws in sizes)
    for ws in sizes:
        total += wire_bytes_rh(cur, ws, qc)
        cur //= ws
    return total


def anchor_bytes_step(m: int, sizes: "list[int]", cfg: FSDPConfig) -> int:
    """Per-rank anchor-state bytes one step materializes *beyond the rank's
    own ZeRO-3 shard* for a gathered leaf of length m — 0 unless anchored;
    0 with a sharded anchor (each rank keeps only its ``(m/dp,)`` slice and
    the full anchor is rebuilt by the forward gather); the legacy
    replicated anchor re-materializes the full ``(m,)`` f32 vector on every
    rank every step.  Delegates to
    :func:`repro.core.wire_accounting.anchor_state_bytes`."""
    if not (cfg.anchored and cfg.sync == "lq"):
        return 0
    return WA.anchor_state_bytes(m, int(np.prod(sizes)), cfg.anchor_sharded)


def anchor_gather_bytes_fwd(m: int, sizes: "list[int]", cfg: FSDPConfig) -> int:
    """Per-rank forward wire bytes of rebuilding a sharded anchor (the f32
    tiled all-gather that piggybacks on the weight-gather slot).  0 for the
    legacy replicated anchor (nothing to rebuild) and in unanchored mode."""
    if not (cfg.anchored and cfg.sync == "lq" and cfg.anchor_sharded):
        return 0
    return WA.anchor_gather_bytes(m, int(np.prod(sizes)))


def _split_y(y_entry):
    """bundle['y'] -> (y scalar-or-(nb,), anchor-or-None)."""
    if isinstance(y_entry, dict):
        return y_entry["y"], y_entry.get("anchor")
    return y_entry, None


def _y_per_bucket(y: Array, nb: int) -> Array:
    """Promote a scalar distance bound to the per-bucket vector."""
    y = jnp.asarray(y, jnp.float32)
    if y.ndim == 0:
        return jnp.full((nb,), 1.0, jnp.float32) * y
    if y.shape[0] != nb:
        raise ValueError(f"per-bucket y has {y.shape[0]} entries, leaf has "
                         f"{nb} buckets")
    return y


def _pack_tele(tele_like: Array, max_dist, fails, y_next, dist_b, fails_b,
               anchor_next=None) -> Array:
    """Fill the tele cotangent up to whatever width the caller allotted.

    Callers passing a legacy (TELE_WIDTH,) tele get the scalars only; a
    tele sized by :func:`tele_width` additionally receives the per-bucket
    maps (and the next anchor in anchored mode).
    """
    parts = [jnp.stack([max_dist, fails, y_next])]
    width = tele_like.shape[0]
    if dist_b is not None and width >= TELE_WIDTH + 2 * dist_b.shape[0]:
        parts += [dist_b, fails_b]
    if anchor_next is not None and width >= sum(p.shape[0] for p in parts) \
            + anchor_next.shape[0]:
        parts.append(anchor_next)
    flat = jnp.concatenate(parts).astype(jnp.float32)   # always <= width
    return jnp.zeros_like(tele_like).at[: flat.shape[0]].set(flat)


def _rank_linear(axes) -> Array:
    """Linear DP rank in (outer, ..., inner)-major order (the storage
    layout's shard index)."""
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx


@jax.custom_vjp
def gather_wait(handle: Array) -> Array:
    """Consume a prefetched gather handle (the *wait* half of the split
    gather).  Value-wise the identity; an ``optimization_barrier`` on both
    the value and the cotangent pins the consumption point so (a) XLA
    cannot sink the issued collective back into the consuming layer's
    fusion context, and (b) the compute subgraph downstream sees exactly
    the pinned operand the serial formulation sees (bit-identity).  A
    plain ``optimization_barrier`` is *not differentiable* on jax 0.4.x —
    hence the custom-vjp wrapper."""
    return jax.lax.optimization_barrier(handle)


def _wait_fwd(handle):
    return jax.lax.optimization_barrier(handle), None


def _wait_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


gather_wait.defvjp(_wait_fwd, _wait_bwd)


def make_fsdp_gather(cfg: FSDPConfig):
    """Returns gather(bundle) -> w_full.

    bundle: {"w": (shard,) storage shard,
             "y": () f32 | (nb,) f32 per-bucket bounds
                  | {"y": (nb,), "anchor": (m,) or (shard,)} (anchored
                    mode; any leading singleton dims are flattened),
             "key": PRNG key, "tele": (>=TELE_WIDTH,) zeros}.
    w_full: (dp * shard,) in cfg.gather_dtype, pinned behind an
    ``optimization_barrier`` (the serial and prefetched formulations must
    hand downstream compute an identically-pinned operand — XLA CPU
    FMA-contracts per fusion context, so an unpinned gather output can
    drift by ulps between the two programs).
    """
    gdt = jnp.dtype(cfg.gather_dtype)

    def _gather_fwd_value(w: Array) -> Array:
        w = w.astype(gdt)
        # innermost axis first so the concatenation order matches the
        # (outer, ..., inner)-major flat storage layout
        for ax in reversed(cfg.axes):
            w = jax.lax.all_gather(w, ax, axis=0, tiled=True)
        return jax.lax.optimization_barrier(w)

    def _anchor_full(anchor, shard: int) -> Array:
        """Full-length f32 anchor: gathered from (shard,) slices when the
        anchor is stored sharded (the second tiled gather in the same
        prefetch slot as the weight gather — f32, the anchor must be
        exact), passed through when already replicated.  Pinned either way
        so both layouts feed the butterfly an identical fusion boundary."""
        a = anchor.reshape(-1).astype(jnp.float32)
        if a.shape[0] == shard:
            for ax in reversed(cfg.axes):
                a = jax.lax.all_gather(a, ax, axis=0, tiled=True)
        return jax.lax.optimization_barrier(a)

    @jax.custom_vjp
    def gather(bundle):
        return _gather_fwd_value(bundle["w"])

    def fwd(bundle):
        w_full = _gather_fwd_value(bundle["w"])
        _, anchor = _split_y(bundle["y"])
        anchor_full = None
        if cfg.anchored and anchor is not None:
            anchor_full = _anchor_full(anchor, bundle["w"].shape[0])
            if anchor_full.shape[0] != w_full.shape[0]:
                raise ValueError(
                    f"anchor length {anchor_full.shape[0]} matches neither "
                    f"the shard ({bundle['w'].shape[0]}) nor the gathered "
                    f"leaf ({w_full.shape[0]})")
        res = (bundle["w"], bundle["y"], bundle["key"], bundle["tele"],
               anchor_full)
        return w_full, res

    def _bwd_rh(g, y_val, anchor, key):
        """Quantized reduce-scatter chain (rh per axis; butterfly when
        anchored).  Returns (g_shard, tele fields)."""
        sizes = _dp_sizes(cfg.axes)
        dp = int(np.prod(sizes))
        m = g.shape[0]
        b = _effective_bucket(cfg.qcfg, m, dp)
        qc = dataclasses.replace(cfg.qcfg, bucket=b)
        nb = m // b
        y_b = _y_per_bucket(y_val, nb)
        fails = jnp.zeros((), jnp.float32)
        max_dist = jnp.zeros((), jnp.float32)
        y_next = jnp.zeros((), jnp.float32)

        if cfg.anchored and anchor is not None:
            # butterfly per axis: every rank ends with the full-length mean
            # (bit-identical — the paper's common-output requirement), which
            # is both this rank's shard and the next step's anchor
            cur = g
            fails_b = jnp.zeros((nb,), jnp.float32)
            dist_b = jnp.zeros((nb,), jnp.float32)
            for i, ax in enumerate(cfg.axes):
                cur, aux = butterfly_allreduce_mean(
                    cur, QState(y=y_b, anchor=anchor),
                    jax.random.fold_in(key, i), ax, qc)
                fails = fails + aux.fails
                max_dist = jnp.maximum(max_dist, aux.max_dist)
                y_next = jnp.maximum(y_next, aux.y_next)
                fails_b = fails_b + aux.fails_b
                dist_b = jnp.maximum(dist_b, aux.dist_b)
            shard = m // dp
            g_shard = jax.lax.dynamic_slice(
                cur, (_rank_linear(cfg.axes) * shard,), (shard,))
            return g_shard, (max_dist, fails, y_next, dist_b, fails_b, cur)

        g_shard = g
        y_cur = y_b
        fails_seg = dist_seg = None
        for i, ax in enumerate(cfg.axes):   # outermost first
            g_shard, aux = rh_reduce_scatter_mean(
                g_shard, y_cur, jax.random.fold_in(key, i), ax, qc)
            fails = fails + aux.fails
            max_dist = jnp.maximum(max_dist, aux.max_dist)
            y_next = jnp.maximum(y_next, aux.y_next)
            # thread the kept segment's per-bucket bounds into the next axis
            y_cur = aux.y_seg
            nb_new = aux.fails_b.shape[0]
            if fails_seg is None:
                fails_seg, dist_seg = aux.fails_b, aux.dist_b
            else:
                # this axis kept chunk axis_index(ax) of the previous
                # segment's per-bucket maps; fold its counts in
                off = jax.lax.axis_index(ax) * nb_new
                fails_seg = jax.lax.dynamic_slice(
                    fails_seg, (off,), (nb_new,)) + aux.fails_b
                dist_seg = jnp.maximum(jax.lax.dynamic_slice(
                    dist_seg, (off,), (nb_new,)), aux.dist_b)
        # re-assemble the full-leaf per-bucket maps from every rank's final
        # segment (tiny: nb f32 per leaf), so all ranks report — and the
        # trainer updates y from — identical maps
        if fails_seg is not None and dp > 1:
            fails_b, dist_b = fails_seg, dist_seg
            for ax in reversed(cfg.axes):
                fails_b = jax.lax.all_gather(fails_b, ax, axis=0, tiled=True)
                dist_b = jax.lax.all_gather(dist_b, ax, axis=0, tiled=True)
        elif fails_seg is not None:         # dp == 1: already full-leaf
            fails_b, dist_b = fails_seg, dist_seg
        else:
            fails_b = jnp.zeros((nb,), jnp.float32)
            dist_b = jnp.zeros((nb,), jnp.float32)
        return g_shard, (max_dist, fails, y_next, dist_b, fails_b, None)

    def bwd(res, g):
        w_shard, y_entry, key, tele_in, anchor_full = res
        y_val, anchor_stored = _split_y(y_entry)
        # pin the cotangent: the serial and prefetched programs' RS chains
        # must start from an identically-pinned boundary (bit-identity)
        g = jax.lax.optimization_barrier(g.astype(jnp.float32))
        sizes = _dp_sizes(cfg.axes)
        dp = int(np.prod(sizes))

        if cfg.sync == "fp32":
            gs = g
            for ax in cfg.axes:          # outermost first: keep rank's segment
                gs = jax.lax.psum_scatter(gs, ax, scatter_dimension=0,
                                          tiled=True)
            g_shard = gs / dp
            tele = jnp.zeros_like(tele_in)
        else:
            g_shard, (max_dist, fails, y_next, dist_b, fails_b,
                      anchor_next) = _bwd_rh(g, y_val, anchor_full, key)
            if anchor_next is not None and anchor_stored is not None:
                stored_len = int(np.prod(np.shape(anchor_stored)))
                if stored_len < anchor_next.shape[0]:
                    # sharded anchor: the tele carries back only this
                    # rank's slice of the butterfly's common output
                    anchor_next = jax.lax.dynamic_slice(
                        anchor_next,
                        (_rank_linear(cfg.axes) * stored_len,),
                        (stored_len,))
            tele = _pack_tele(tele_in, max_dist, fails, y_next, dist_b,
                              fails_b, anchor_next)

        ct = {
            "w": g_shard.astype(w_shard.dtype),
            "y": jax.tree.map(jnp.zeros_like, y_entry),
            "key": np.zeros(np.shape(key), jax.dtypes.float0),
            "tele": tele,
        }
        return (ct,)

    gather.defvjp(fwd, bwd)
    return gather


def make_fsdp_gather_split(cfg: FSDPConfig):
    """``gather_async / gather_wait`` split of the monolithic gather.

    Returns ``(gather_async, wait)``:

      * ``gather_async(bundle) -> handle`` — *issues* the tiled all-gather
        (and, sharded-anchored, the piggybacked anchor gather) and returns
        the in-flight ``(m,)`` handle, pinned behind an
        ``optimization_barrier``.  Its custom vjp is the *same* quantized
        reduce-scatter as the monolithic gather — the two halves share
        every internal, so split-vs-monolithic is bitwise identical.
      * ``wait(handle) -> w_full`` — :func:`gather_wait`, the pinned
        custom-vjp identity consuming the handle.

    The caller (models/transformer.py's double-buffered scan) places the
    issue in the *previous* loop iteration's carry and the wait at the
    consumption point, so layer k+1's gather overlaps layer k's compute —
    and, transposed, layer k's reduce-scatter overlaps layer k-1's
    cotangent compute.
    """
    return make_fsdp_gather(cfg), gather_wait
