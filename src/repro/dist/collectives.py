"""Quantized mean collectives inside ``jax.shard_map`` (paper §4, §9.1).

This is the production counterpart of the reference algorithms in
:mod:`repro.core.dme`, mapped onto SPMD collectives:

* :func:`allgather_allreduce_mean` — **Algorithm 3 (star) analogue**.  In the
  paper a random leader gathers everyone's colors, decodes against its own
  input, averages and re-broadcasts.  On an accelerator mesh the "leader" is
  every rank at once: each rank all-gathers the mod-q colors, decodes each
  sender against its *own* vector as the anchor and averages the decoded
  lattice points.  A successful decode recovers the sender's exact lattice
  point (Lemma 15 / §9.1), so all ranks compute bit-identical means without a
  second broadcast phase.

* :func:`butterfly_allreduce_mean` — **Algorithm 4 (tree) analogue**:
  recursive doubling.  In round ``r`` rank ``i`` exchanges quantized running
  averages with rank ``i XOR 2^r`` and averages; after ``log2(n)`` rounds all
  ranks hold the mean.  Because encoding is deterministic given the shared
  dither ``u`` (paper §9.1), ranks holding equal values emit identical
  colors, so outputs stay bit-identical — the paper's common-output
  requirement — while the per-hop error accumulates like the tree's
  ``O(eps log n)``.

* :func:`rh_reduce_scatter_mean` — recursive-halving reduce-scatter of the
  mean (the FSDP gradient path, :mod:`repro.dist.fsdp`).  Round ``r``
  exchanges the half of the working segment the partner keeps; the receiver
  decodes against its own half (inputs are within the distance bound by
  assumption — the paper's "concentrated but possibly large norm" regime
  where these input-norm-independent bounds beat norm-dependent schemes).

All three operate per *bucket*: the flat vector is padded to a whole number
of ``cfg.bucket``-sized buckets, each with its own distance bound
``y_buckets[b]`` and lattice side ``s = 2*y/(q-1)``.  With
``cfg.rotate=True`` each bucket is pre-rotated by the shared-randomness
randomized Hadamard transform HD (paper §6, RLQSGD) — see
:func:`_bucketize` / :func:`_unbucketize`.

Decode-failure detection follows :func:`repro.core.lattice.decode_failure`
(the §5 error-detection policy, realized as the distance surrogate; the
checksum variant lives in :mod:`repro.core.error_detect`): failures are
*counted* into ``aux.fails`` and escalation happens at step granularity in
the trainer (y <- y * escalate, the SPMD form of RobustAgreement's
``r <- r^2``).

Wire accounting (:func:`wire_bytes_butterfly`, :func:`wire_bytes_allgather`)
is built on :func:`repro.core.lattice.wire_bytes` — packed colors at
``bits_for_q(q)`` bits per coordinate.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lattice as L
from repro.core import rotation as R

Array = jax.Array

# Fixed seed for the shared-randomness Hadamard diagonal: every rank derives
# the same D without communication (one agreed constant stands in for the d
# shared bits of §6).
_ROTATION_SEED = 20210507


class QSyncAux(NamedTuple):
    """Telemetry emitted by every collective (consumed by dist/fsdp.py).

    fails:    () f32 — number of detected decode failures (0 on success).
    max_dist: () f32 — max observed |decoded - anchor|_inf (bucket space).
    y_next:   () f32 — suggested distance bound for the next step
                       (0 when nothing was measured, e.g. world size 1).
    """
    fails: Array
    max_dist: Array
    y_next: Array


@dataclasses.dataclass(frozen=True)
class QSyncConfig:
    """Static config of the quantized sync path.

    q:      number of mod-q color classes; wire cost bits_for_q(q) bits/coord
            and lattice side s = 2*y/(q-1) for distance bound y.
    bucket: coordinates per bucket (power of two); each bucket has its own
            y / s and (optionally) its own Hadamard rotation block.
    rotate: pre-rotate buckets with the shared-randomness HD transform
            (paper §6) so adversarially-concentrated coordinates spread out.
    """
    q: int = 16
    bucket: int = 4096
    rotate: bool = False

    def __post_init__(self):
        if self.q < 2:
            raise ValueError("q must be >= 2")
        b = self.bucket
        if b < 1 or (b & (b - 1)) != 0:
            raise ValueError(f"bucket must be a power of two, got {b}")

    @property
    def bits(self) -> int:
        return L.bits_for_q(self.q)

    @property
    def spec(self) -> L.LatticeSpec:
        return L.LatticeSpec(self.q)


def flat_size_padded(n: int, cfg: Union[QSyncConfig, int]) -> int:
    """Smallest multiple of the bucket size >= n (flat wire length)."""
    b = cfg.bucket if isinstance(cfg, QSyncConfig) else int(cfg)
    return -(-n // b) * b


def _bucket_diag(bucket: int) -> Array:
    """Shared-randomness ±1 diagonal for the per-bucket HD rotation."""
    return R.rotation_keypair(jax.random.PRNGKey(_ROTATION_SEED), bucket)


def _bucketize(x: Array, cfg: QSyncConfig) -> Array:
    """Flat (n,) -> (n_buckets, bucket) f32, zero-padded; HD-rotated per
    bucket when cfg.rotate (block-diagonal, invertible by _unbucketize)."""
    n = x.shape[0]
    pad = flat_size_padded(n, cfg) - n
    v = jnp.pad(x.astype(jnp.float32), (0, pad))
    v = v.reshape(-1, cfg.bucket)
    if cfg.rotate:
        v = R.rotate(v, _bucket_diag(cfg.bucket))
    return v


def _unbucketize(b: Array, n: int, cfg: QSyncConfig) -> Array:
    """Inverse of _bucketize: (n_buckets, bucket) -> flat (n,)."""
    if cfg.rotate:
        b = R.unrotate(b, _bucket_diag(cfg.bucket), cfg.bucket)
    return b.reshape(-1)[:n]


def _sides(y_buckets: Array, cfg: QSyncConfig) -> Array:
    """(nb,) distance bounds -> (nb, 1) lattice sides s = 2y/(q-1)."""
    return cfg.spec.side(y_buckets.astype(jnp.float32))[:, None]


def _bucket_fails(z: Array, anchor: Array, y_col: Array):
    """Vectorized lattice.decode_failure over buckets.

    z, anchor: (..., nb, bucket); y_col: (nb, 1).  Returns (count, max_dist)
    where count sums per-(sender, bucket) failure flags.
    """
    dist = jnp.abs(z - anchor)
    failed = jnp.any(dist > 1.5 * y_col, axis=-1)
    return jnp.sum(failed.astype(jnp.float32)), jnp.max(dist)


def _encode(xb: Array, s: Array, u: Array) -> Array:
    """Deterministic dithered encode: integer coords of every bucket."""
    return L.encode_coords(xb, s, u)


def _decode(colors: Array, anchor: Array, s: Array, u: Array,
            cfg: QSyncConfig) -> Array:
    """Nearest-point decode of mod-q colors against the local anchor."""
    k = L.decode_coords(colors, anchor, s, u, q=cfg.q)
    return L.coords_to_point(k, s, u)


def _axis_size(axis_name) -> int:
    # psum of a python int is computed statically from the mesh
    return jax.lax.psum(1, axis_name)


def _check_buckets(xb: Array, y_buckets: Array):
    if y_buckets.shape[0] != xb.shape[0]:
        raise ValueError(
            f"y_buckets has {y_buckets.shape[0]} entries for {xb.shape[0]} "
            f"buckets (vector padded to a whole number of buckets)")


# ---------------------------------------------------------------------------
# Star analogue (paper Algorithm 3): all-gather colors, decode locally
# ---------------------------------------------------------------------------

def allgather_allreduce_mean(x_local: Array, y_buckets: Array, key: Array,
                             axis_name, cfg: QSyncConfig
                             ) -> tuple[Array, QSyncAux]:
    """Mean over `axis_name` of per-rank vectors, star-style.

    Every rank sends mod-q colors once (all-gather) and decodes every sender
    against its *own* vector; successful decodes recover the senders' exact
    lattice points, so outputs are bit-identical across ranks.

    Returns (mean (n,), QSyncAux).
    """
    n = x_local.shape[0]
    xb = _bucketize(x_local, cfg)
    _check_buckets(xb, y_buckets)
    s = _sides(y_buckets, cfg)
    u = L.shared_offset(key, xb.shape)

    k_own = _encode(xb, s, u)
    colors = L.color_of(k_own, cfg.q)
    all_colors = jax.lax.all_gather(colors, axis_name)      # (world, nb, b)

    z = _decode(all_colors, xb[None], s, u, cfg)            # (world, nb, b)
    fails, max_dist = _bucket_fails(z, xb[None],
                                    y_buckets.astype(jnp.float32)[:, None])
    mean_b = jnp.mean(z, axis=0)

    dev = jnp.max(jnp.abs(z - mean_b[None]))
    aux = QSyncAux(fails=fails, max_dist=max_dist, y_next=2.5 * dev)
    return _unbucketize(mean_b, n, cfg), aux


# ---------------------------------------------------------------------------
# Tree analogue (paper Algorithm 4): recursive doubling
# ---------------------------------------------------------------------------

def butterfly_allreduce_mean(x_local: Array, y_buckets: Array, key: Array,
                             axis_name, cfg: QSyncConfig
                             ) -> tuple[Array, QSyncAux]:
    """Mean over `axis_name`, butterfly (recursive-doubling) topology.

    log2(world) rounds; round r pairs rank i with i XOR 2^r.  Both partners
    average the *quantized* points (own + partner's), so pairs — and after
    all rounds, every rank — hold bit-identical values.  Per-round error is
    at most s/2 per coordinate (dithered nearest rounding), accumulating to
    O(s log world) like the paper's tree aggregation.

    Returns (mean (n,), QSyncAux).
    """
    n = x_local.shape[0]
    world = _axis_size(axis_name)
    if world & (world - 1):
        raise ValueError(f"butterfly needs a power-of-two world, got {world}")
    cur = _bucketize(x_local, cfg)
    _check_buckets(cur, y_buckets)
    s = _sides(y_buckets, cfg)
    y_col = y_buckets.astype(jnp.float32)[:, None]

    fails = jnp.zeros((), jnp.float32)
    max_dist = jnp.zeros((), jnp.float32)
    rounds = int(np.log2(world)) if world > 1 else 0
    for r in range(rounds):
        u = L.shared_offset(jax.random.fold_in(key, r), cur.shape)
        k_own = _encode(cur, s, u)
        colors = L.color_of(k_own, cfg.q)
        perm = [(i, i ^ (1 << r)) for i in range(world)]
        c_partner = jax.lax.ppermute(colors, axis_name, perm)
        k_partner = L.decode_coords(c_partner, cur, s, u, q=cfg.q)
        f, d = _bucket_fails(L.coords_to_point(k_partner, s, u), cur, y_col)
        fails = fails + f
        max_dist = jnp.maximum(max_dist, d)
        # average in integer coordinate space: int adds are exact and
        # commutative, and the single float expression below is the same
        # fusion on every rank — so partners produce bit-identical values
        # (averaging the two float points instead lets XLA round the encode-
        # and decode-side fusions differently by 1 ulp, breaking the paper's
        # common-output requirement)
        cur = (0.5 * (k_own + k_partner).astype(jnp.float32) + u) * s

    aux = QSyncAux(fails=fails, max_dist=max_dist, y_next=2.5 * max_dist)
    return _unbucketize(cur, n, cfg), aux


# ---------------------------------------------------------------------------
# Recursive-halving reduce-scatter (the FSDP gradient path)
# ---------------------------------------------------------------------------

def rh_reduce_scatter_mean(x_local: Array, y_buckets: Array, key: Array,
                           axis_name, cfg: QSyncConfig
                           ) -> tuple[Array, QSyncAux]:
    """Reduce-scatter of the mean via quantized recursive halving.

    Round r pairs rank i with i XOR (world >> (r+1)); each sends (quantized)
    the half of its working segment the partner keeps, decodes the received
    half against its own (the anchor) and averages.  After log2(world)
    rounds rank i holds bucket-aligned segment i of the mean:
    shape (padded_n / world,).

    Requires the padded bucket count to divide evenly by the world size
    (guaranteed by fsdp.pad_to_shardable).
    """
    n = x_local.shape[0]
    world = _axis_size(axis_name)
    if world & (world - 1):
        raise ValueError(f"recursive halving needs power-of-two world, "
                         f"got {world}")
    cur = _bucketize(x_local, cfg)
    _check_buckets(cur, y_buckets)
    nb = cur.shape[0]
    if nb % world:
        raise ValueError(f"{nb} buckets not divisible by world={world}; "
                         f"pad with fsdp.pad_to_shardable first")
    y_cur = y_buckets.astype(jnp.float32)
    rank = jax.lax.axis_index(axis_name) if world > 1 else jnp.zeros((), jnp.int32)

    fails = jnp.zeros((), jnp.float32)
    max_dist = jnp.zeros((), jnp.float32)
    rounds = int(np.log2(world)) if world > 1 else 0
    for r in range(rounds):
        dist = world >> (r + 1)
        half = cur.shape[0] // 2
        lo, hi = cur[:half], cur[half:]
        y_lo, y_hi = y_cur[:half], y_cur[half:]
        u_full = L.shared_offset(jax.random.fold_in(key, r), cur.shape)
        u_lo, u_hi = u_full[:half], u_full[half:]
        # bit==0: keep the low half, send the high half (and vice versa);
        # the msb-first sweep leaves rank i with segment i of the vector.
        bit = ((rank // dist) % 2).astype(jnp.bool_)
        keep = jnp.where(bit, hi, lo)
        send = jnp.where(bit, lo, hi)
        y_keep = jnp.where(bit, y_hi, y_lo)
        y_send = jnp.where(bit, y_lo, y_hi)
        u_keep = jnp.where(bit, u_hi, u_lo)
        u_send = jnp.where(bit, u_lo, u_hi)
        s_keep = cfg.spec.side(y_keep)[:, None]
        s_send = cfg.spec.side(y_send)[:, None]

        k_send = _encode(send, s_send, u_send)
        colors = L.color_of(k_send, cfg.q)
        perm = [(i, i ^ dist) for i in range(world)]
        c_recv = jax.lax.ppermute(colors, axis_name, perm)
        # the partner encoded *its* copy of the coordinates we keep, with the
        # same (u, s) — decode against our own half as the anchor
        z = _decode(c_recv, keep, s_keep, u_keep, cfg)
        f, d = _bucket_fails(z, keep, y_keep[:, None])
        fails = fails + f
        max_dist = jnp.maximum(max_dist, d)
        cur = 0.5 * (keep + z)
        y_cur = y_keep

    if cfg.rotate:
        cur = R.unrotate(cur, _bucket_diag(cfg.bucket), cfg.bucket)
    out = cur.reshape(-1)
    aux = QSyncAux(fails=fails, max_dist=max_dist, y_next=2.5 * max_dist)
    return out, aux


# ---------------------------------------------------------------------------
# Wire accounting (ring model, bytes *sent per rank*)
# ---------------------------------------------------------------------------

def _payload_bytes(n: int, cfg: QSyncConfig) -> int:
    """Packed-color bytes of one full-vector message (+4B/bucket for y)."""
    padded = flat_size_padded(n, cfg)
    return L.wire_bytes(padded, cfg.bits) + 4 * (padded // cfg.bucket)


def wire_bytes_butterfly(n: int, world: int, cfg: QSyncConfig) -> int:
    """Recursive doubling: log2(world) rounds, one full payload each."""
    rounds = max(int(np.log2(world)), 0) if world > 1 else 0
    return rounds * _payload_bytes(n, cfg)


def wire_bytes_allgather(n: int, world: int, cfg: QSyncConfig) -> int:
    """Ring all-gather of every rank's payload: (world-1) forwarded chunks."""
    return max(world - 1, 0) * _payload_bytes(n, cfg)
