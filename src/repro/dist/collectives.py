"""Quantized mean collectives inside ``jax.shard_map`` (paper §4, §9.1).

This is the production counterpart of the reference algorithms in
:mod:`repro.core.dme`, mapped onto SPMD collectives:

* :func:`allgather_allreduce_mean` — **Algorithm 3 (star) analogue**.  In the
  paper a random leader gathers everyone's colors, decodes against its own
  input, averages and re-broadcasts.  On an accelerator mesh the "leader" is
  every rank at once: each rank all-gathers the mod-q colors, decodes each
  sender against its *own* vector as the anchor and averages the decoded
  lattice points.  A successful decode recovers the sender's exact lattice
  point (Lemma 15 / §9.1), so all ranks compute bit-identical means without a
  second broadcast phase.

* :func:`butterfly_allreduce_mean` — **Algorithm 4 (tree) analogue**:
  recursive doubling.  In round ``r`` rank ``i`` exchanges quantized running
  averages with rank ``i XOR 2^r`` and averages; after ``log2(n)`` rounds all
  ranks hold the mean.  Because encoding is deterministic given the shared
  dither ``u`` (paper §9.1), ranks holding equal values emit identical
  colors, so outputs stay bit-identical — the paper's common-output
  requirement — while the per-hop error accumulates like the tree's
  ``O(eps log n)``.

* :func:`rh_reduce_scatter_mean` — recursive-halving reduce-scatter of the
  mean (the FSDP gradient path, :mod:`repro.dist.fsdp`).  Round ``r``
  exchanges the half of the working segment the partner keeps; the receiver
  decodes against its own half (inputs are within the distance bound by
  assumption — the paper's "concentrated but possibly large norm" regime
  where these input-norm-independent bounds beat norm-dependent schemes) and
  averages own + received *quantized coordinates*, butterfly-style.

All three operate per *bucket*: the flat vector is padded to a whole number
of ``cfg.bucket``-sized buckets, each with its own distance bound
``y_buckets[b]`` and lattice side ``s = 2*y/(q-1)``.  With
``cfg.rotate=True`` each bucket is pre-rotated by the shared-randomness
randomized Hadamard transform HD (paper §6, RLQSGD) — see
:func:`_bucketize` / :func:`_unbucketize` (thin wrappers over
:mod:`repro.core.bucketing`, the one bucket-layout definition shared with
the agg protocol).

Anchored state (:class:`repro.core.qstate.QState`): every collective takes
either a bare per-bucket ``y`` array (zero anchor — bit-identical to the
historical signature) or a ``QState`` whose ``anchor`` is subtracted before
encoding (fused into the Pallas encode/decode for the star's single-shot
wire; the iterating butterfly/rh convert to anchor-relative space once at
entry so per-round state never re-absorbs the large-norm anchor).  The wire
still carries only packed coords; anchoring pins the integer coordinates to
``|k| ~ y/s`` however large the inputs' common mean grows — the paper's
distance-dependent regime, where a drifting large-norm mean would otherwise
push ``round(x/s - u)`` past f32's mantissa (losing the dither) and toward
int32 range.

Telemetry is per bucket: ``QSyncAux.fails_b`` / ``dist_b`` attribute decode
failures and observed distances to individual buckets (feeding the
per-bucket ``y`` update in :func:`repro.core.qstate.update_y`), and
``rh_reduce_scatter_mean`` additionally returns ``y_seg`` — the kept
segment's per-bucket bounds — so multi-axis FSDP chains thread per-bucket
``y`` from axis to axis instead of broadcasting one scalar per leaf.  The
split ``gather_async``/``gather_wait`` FSDP path (dist/fsdp.py, prefetch
pipelining) reuses the exact same backward chain — the y-threading below is
shared by both formulations, which is what makes split-vs-monolithic
bitwise identical.

Wire format (``cfg.packed=True``, the default): what crosses the
``all_gather``/``ppermute`` boundary is the *packed* payload produced by the
fused Pallas kernels (:mod:`repro.kernels.lattice_encode` /
``lattice_decode``) —

  * ``words``: uint32 words holding ``bits_for_q(q)``-bit colors, 32/bits
    per word, little-endian lanes, ``ceil(n/per)`` words for n coordinates
    (the kernels tile the flat vector as (rows, 2048) in VMEM);
  * ``sides``: one f32 lattice side per bucket (the per-bucket distance
    bound's sidecar) — the receiver decodes with the *received* sides.

That is ``d*log2(q)`` bits per machine plus 4 bytes per bucket — the
paper's §3.2 wire cost, 8x smaller than f32 at q=16 — instead of the
materialized 32-bit color buffers the ``packed=False`` jnp fallback moves.
Both paths produce bit-identical means (asserted in
tests/test_dist_collectives.py).

Decode-failure detection follows :func:`repro.core.lattice.decode_failure`
(the §5 error-detection policy, realized as the distance surrogate; the
checksum variant lives in :mod:`repro.core.error_detect`): failures are
*counted* into ``aux.fails`` and escalation happens at step granularity in
the trainer (y <- y * escalate, the SPMD form of RobustAgreement's
``r <- r^2``).

Wire accounting (:func:`wire_bytes_butterfly`, :func:`wire_bytes_allgather`,
:func:`wire_bytes_rh`) delegates to :mod:`repro.core.wire_accounting` — the
repo's single wire-byte definition (packed colors at ``bits_for_q(q)`` bits
per coordinate plus the per-bucket sides sidecar), shared with the FSDP
accounting and the agg transport framing, and matches the actual packed
payload byte-for-byte (asserted in tests).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing as B
from repro.core import lattice as L
from repro.core import qstate as QS
from repro.core import rotation as R
from repro.core import wire_accounting as WA
from repro.core.qstate import QState
from repro.kernels import ops as K

Array = jax.Array

# Fixed seed for the shared-randomness Hadamard diagonal: every rank derives
# the same D without communication (one agreed constant stands in for the d
# shared bits of §6).
_ROTATION_SEED = 20210507


class QSyncAux(NamedTuple):
    """Telemetry emitted by every collective (consumed by dist/fsdp.py).

    fails:    () f32 — number of detected decode failures (0 on success).
    max_dist: () f32 — max observed |decoded - anchor|_inf (bucket space).
    y_next:   () f32 — suggested distance bound for the next step
                       (0 when nothing was measured, e.g. world size 1).
    fails_b:  (nb,) f32 — decode failures attributed per bucket (None when
                       the collective measured nothing, e.g. world size 1).
    dist_b:   (nb,) f32 — per-bucket max |decoded - anchor|_inf.
    y_seg:    rh only: the kept segment's per-bucket y (nb/world,), for
                       threading per-bucket bounds across FSDP axis chains.
    """
    fails: Array
    max_dist: Array
    y_next: Array
    fails_b: Optional[Array] = None
    dist_b: Optional[Array] = None
    y_seg: Optional[Array] = None


@dataclasses.dataclass(frozen=True)
class QSyncConfig:
    """Static config of the quantized sync path.

    q:      number of mod-q color classes; wire cost bits_for_q(q) bits/coord
            and lattice side s = 2*y/(q-1) for distance bound y.
    bucket: coordinates per bucket (power of two); each bucket has its own
            y / s and (optionally) its own Hadamard rotation block.
    rotate: pre-rotate buckets with the shared-randomness HD transform
            (paper §6) so adversarially-concentrated coordinates spread out.
    packed: carry packed uint32 words (bits_for_q(q) bits/coord, fused
            Pallas encode/decode) plus the per-bucket sides sidecar on the
            wire.  False falls back to unpacked 32-bit color buffers through
            the pure-jnp lattice ops (same bits semantically, 8x the bytes
            at q=16; kept as the oracle path).
    """
    q: int = 16
    bucket: int = 4096
    rotate: bool = False
    packed: bool = True

    def __post_init__(self):
        if self.q < 2:
            raise ValueError("q must be >= 2")
        b = self.bucket
        if b < 1 or (b & (b - 1)) != 0:
            raise ValueError(f"bucket must be a power of two, got {b}")

    @property
    def bits(self) -> int:
        return L.bits_for_q(self.q)

    @property
    def spec(self) -> L.LatticeSpec:
        return L.LatticeSpec(self.q)


def flat_size_padded(n: int, cfg: Union[QSyncConfig, int]) -> int:
    """Smallest multiple of the bucket size >= n (flat wire length)."""
    b = cfg.bucket if isinstance(cfg, QSyncConfig) else int(cfg)
    return B.padded_size(n, b)


def _bucket_diag(bucket: int) -> Array:
    """Shared-randomness ±1 diagonal for the per-bucket HD rotation."""
    return R.rotation_keypair(jax.random.PRNGKey(_ROTATION_SEED), bucket)


def _bucketize(x: Array, cfg: QSyncConfig) -> Array:
    """Flat (n,) -> (n_buckets, bucket) f32, zero-padded; HD-rotated per
    bucket when cfg.rotate (block-diagonal, invertible by _unbucketize).
    The packed path rotates through the Pallas FWHT kernel.  Delegates to
    :mod:`repro.core.bucketing` (shared with repro.agg)."""
    diag = _bucket_diag(cfg.bucket) if cfg.rotate else None
    return B.bucketize(x, cfg.bucket, diag=diag, use_kernel=cfg.packed)


def _unbucketize(b: Array, n: int, cfg: QSyncConfig) -> Array:
    """Inverse of _bucketize: (n_buckets, bucket) -> flat (n,)."""
    diag = _bucket_diag(cfg.bucket) if cfg.rotate else None
    return B.unbucketize(b, n, diag=diag, use_kernel=cfg.packed)


def _sides(y_buckets: Array, cfg: QSyncConfig) -> Array:
    """(nb,) distance bounds -> (nb, 1) lattice sides s = 2y/(q-1).

    The sides are pinned behind an optimization barrier: when y_buckets is a
    compile-time constant XLA rewrites ``x / s`` into a reciprocal multiply
    that is *not* exactly rounded (and does so per fusion context), flipping
    round()s at halfway points — which would let the packed Pallas wire path
    and the unpacked jnp path decode to different lattice points.  A runtime
    divisor always compiles to a true IEEE division in both.
    """
    s = cfg.spec.side(y_buckets.astype(jnp.float32))[:, None]
    return jax.lax.optimization_barrier(s)


def _bucket_fails(k: Array, k_ref: Array, s_col: Array, y_col: Array):
    """Vectorized lattice.decode_failure over buckets, in coordinate space.

    k, k_ref: int32 lattice coordinates (..., nb, bucket) — the decoded
    sender and the local reference point on the *same* (u, s) lattice;
    s_col, y_col: (nb, 1) per-bucket sides / distance bounds.  Returns
    (fails_b (nb,), dist_b (nb,)) — per-bucket failure counts and max
    distances ``|k - k_ref| * s``, reduced over any leading (sender/round)
    axes.  The scalar telemetry is ``fails_b.sum()`` / ``dist_b.max()``.

    Distances are computed from the *integer* coordinate deltas, never from
    the decoded float points: ``(k + u) * s - anchor`` is a mul-add chain
    that LLVM FMA-contracts per fusion context (XLA CPU strips
    ``optimization_barrier`` during HLO optimization, so barriers cannot
    prevent it), which made the telemetry drift by ulps between structurally
    different programs — e.g. the serial vs the prefetch-pipelined FSDP
    backward — and, through the y-state feedback, eventually diverged
    training.  An int subtract, exact f32 convert, and one correctly-rounded
    multiply have no contractible pattern: every program computes bit-equal
    telemetry from bit-equal coords (the same discipline as the
    integer-space averaging of the mean path).
    """
    dist = jnp.abs(k - k_ref).astype(jnp.float32) * s_col
    failed = jnp.any(dist > 1.5 * y_col, axis=-1).astype(jnp.float32)
    dist_b = jnp.max(dist, axis=-1)
    lead = tuple(range(failed.ndim - 1))
    return jnp.sum(failed, axis=lead), jnp.max(dist_b, axis=lead)


def _encode(xb: Array, s: Array, u: Array) -> Array:
    """Deterministic dithered encode: integer coords of every bucket."""
    return L.encode_coords(xb, s, u)


# ---------------------------------------------------------------------------
# Packed wire path (fused Pallas kernels; repro.kernels.ops)
# ---------------------------------------------------------------------------

def _sides_per_coord(sides: Array, bucket: int) -> Array:
    """(nb,) per-bucket sides -> (nb*bucket,) per-coordinate sides."""
    return jnp.repeat(sides.astype(jnp.float32), bucket)


def _encode_packed(xb: Array, sides: Array, u: Array, cfg: QSyncConfig,
                   return_coords: bool = False,
                   anchor: Optional[Array] = None):
    """Fused encode of bucketized xb -> packed uint32 wire words.

    xb, u: (nb, bucket); sides: (nb,); anchor: optional (nb, bucket) QState
    anchor subtracted in-kernel.  Returns words (packed_len(n, bits),) —
    plus the int32 coords (nb, bucket) when return_coords.
    """
    s_flat = _sides_per_coord(sides, xb.shape[-1])
    a_flat = anchor.reshape(-1) if anchor is not None else None
    out = K.lattice_encode(xb.reshape(-1), u.reshape(-1), s_flat, q=cfg.q,
                           return_coords=return_coords, anchor=a_flat)
    if return_coords:
        return out[0], out[1].reshape(xb.shape)
    return out


def _decode_packed(words: Array, anchor: Array, sides: Array, u: Array,
                   cfg: QSyncConfig, mode: str = "point",
                   ref: Optional[Array] = None) -> Array:
    """Fused decode of wire words against the local anchor.

    anchor, u: (nb, bucket); sides: (nb,) — the *received* sidecar; ref:
    optional (nb, bucket) QState anchor the sender subtracted (fused).
    Returns the decoded points (mode="point") or int32 coords
    (mode="coords"), shaped like anchor.
    """
    s_flat = _sides_per_coord(sides, anchor.shape[-1])
    r_flat = ref.reshape(-1) if ref is not None else None
    out = K.lattice_decode(words, anchor.reshape(-1), u.reshape(-1), s_flat,
                           q=cfg.q, mode=mode, ref=r_flat)
    return out.reshape(anchor.shape)


def _axis_size(axis_name) -> int:
    # psum of a python int is computed statically from the mesh
    return jax.lax.psum(1, axis_name)


def _check_buckets(xb: Array, y_buckets: Array):
    if y_buckets.shape[0] != xb.shape[0]:
        raise ValueError(
            f"y_buckets has {y_buckets.shape[0]} entries for {xb.shape[0]} "
            f"buckets (vector padded to a whole number of buckets)")


# ---------------------------------------------------------------------------
# Star analogue (paper Algorithm 3): all-gather colors, decode locally
# ---------------------------------------------------------------------------

def allgather_allreduce_mean(x_local: Array, state: Union[QState, Array],
                             key: Array, axis_name, cfg: QSyncConfig
                             ) -> tuple[Array, QSyncAux]:
    """Mean over `axis_name` of per-rank vectors, star-style.

    Every rank sends mod-q colors once (all-gather) and decodes every sender
    against its *own* vector; successful decodes recover the senders' exact
    lattice points, so outputs are bit-identical across ranks.  With
    cfg.packed the gathered payload is the packed words + sides sidecar.

    ``state`` is a :class:`QState` (per-bucket y + optional shared anchor,
    subtracted/added inside the fused Pallas encode/decode) or a bare (nb,)
    per-bucket y array (zero anchor — bit-identical to the historical path).

    Returns (mean (n,), QSyncAux).
    """
    qs = QS.as_qstate(state)
    y_buckets = qs.y
    n = x_local.shape[0]
    xb = _bucketize(x_local, cfg)
    _check_buckets(xb, y_buckets)
    ab = _bucketize(qs.anchor, cfg) if qs.anchor is not None else None
    # anchor-relative telemetry/averaging frame (xr == xb when unanchored):
    # distances and decoded points stay ~y-sized however large the raw norm
    xr = xb if ab is None else xb - ab
    s = _sides(y_buckets, cfg)
    u = L.shared_offset(key, xb.shape)

    world = _axis_size(axis_name)
    if cfg.packed:
        sides = s[:, 0]
        words = _encode_packed(xb, sides, u, cfg, anchor=ab)
        all_words = jax.lax.all_gather(words, axis_name)    # (world, nw)
        all_sides = jax.lax.all_gather(sides, axis_name)    # (world, nb)
        # one batched kernel launch over all senders' gathered words (each
        # decoded with *its* sides sidecar), instead of `world` per-sender
        # pallas_calls — same integer coords bit-for-bit
        s_sender = jnp.repeat(all_sides, cfg.bucket, axis=-1)  # (world, n)
        k = K.lattice_decode_batched(all_words, xb.reshape(-1),
                                     u.reshape(-1), s_sender, q=cfg.q,
                                     mode="coords",
                                     ref=None if ab is None
                                     else ab.reshape(-1))
        k = k.reshape((world,) + xb.shape)                  # (world, nb, b)
    else:
        k_own = _encode(xr, s, u)
        colors = L.color_of(k_own, cfg.q)
        all_colors = jax.lax.all_gather(colors, axis_name)  # (world, nb, b)
        k = L.decode_coords(all_colors, xr[None], s, u, q=cfg.q)

    # pin the (exact) integer coords: the producers differ between the packed
    # kernel and jnp wire paths, and XLA's fusion/reduce-order/FMA choices
    # downstream of each would otherwise drift by 1 ulp — everything below the
    # barrier is an identical subgraph in both, so outputs stay bit-identical
    k = jax.lax.optimization_barrier(k)
    z = L.coords_to_point(k, s, u)                          # (world, nb, b)
    # own decode is exact, so k[rank] is this rank's own lattice point —
    # the coordinate-space reference for the distance telemetry
    k_own = jax.lax.dynamic_index_in_dim(k, jax.lax.axis_index(axis_name),
                                         0, keepdims=True)
    fails_b, dist_b = _bucket_fails(k, k_own, s,
                                    y_buckets.astype(jnp.float32)[:, None])
    # average in integer coordinate space (as the butterfly does): the int
    # sum over senders is exact and order-free, so the mean is bit-identical
    # however XLA reduces, and every rank computes the same value
    ksum = jnp.sum(k, axis=0)
    kmean = ksum.astype(jnp.float32) / world
    mean_b = (kmean + u) * s

    # coordinate-space deviation (see _bucket_fails: float `z - mean_b` is
    # an FMA-contractible mul-add; the coord delta times s is not)
    dev = jnp.max(jnp.abs(k.astype(jnp.float32) - kmean[None]) * s)
    if ab is not None:
        mean_b = mean_b + ab
    aux = QSyncAux(fails=jnp.sum(fails_b), max_dist=jnp.max(dist_b),
                   y_next=2.5 * dev, fails_b=fails_b, dist_b=dist_b)
    return _unbucketize(mean_b, n, cfg), aux


# ---------------------------------------------------------------------------
# Tree analogue (paper Algorithm 4): recursive doubling
# ---------------------------------------------------------------------------

def butterfly_allreduce_mean(x_local: Array, state: Union[QState, Array],
                             key: Array, axis_name, cfg: QSyncConfig
                             ) -> tuple[Array, QSyncAux]:
    """Mean over `axis_name`, butterfly (recursive-doubling) topology.

    log2(world) rounds; round r pairs rank i with i XOR 2^r.  Both partners
    average the *quantized* points (own + partner's), so pairs — and after
    all rounds, every rank — hold bit-identical values.  Per-round error is
    at most s/2 per coordinate (dithered nearest rounding), accumulating to
    O(s log world) like the paper's tree aggregation.  With cfg.packed each
    hop carries packed words + the sides sidecar; the fused encode also
    returns the local coords so the exact integer-space average needs no
    second pass over the vector.

    ``state``: :class:`QState` or bare (nb,) y array.  With an anchor the
    rounds iterate in anchor-relative space (subtracted once at entry, added
    back at exit): re-absorbing a large-norm anchor into the running value
    every round would re-lose the f32 precision the anchor buys.

    Returns (mean (n,), QSyncAux).
    """
    qs = QS.as_qstate(state)
    y_buckets = qs.y
    n = x_local.shape[0]
    world = _axis_size(axis_name)
    if world & (world - 1):
        raise ValueError(f"butterfly needs a power-of-two world, got {world}")
    cur = _bucketize(x_local, cfg)
    _check_buckets(cur, y_buckets)
    ab = _bucketize(qs.anchor, cfg) if qs.anchor is not None else None
    if ab is not None:
        cur = cur - ab
    s = _sides(y_buckets, cfg)
    y_col = y_buckets.astype(jnp.float32)[:, None]

    nb = cur.shape[0]
    fails_b = jnp.zeros((nb,), jnp.float32)
    dist_b = jnp.zeros((nb,), jnp.float32)
    rounds = int(np.log2(world)) if world > 1 else 0
    for r in range(rounds):
        u = L.shared_offset(jax.random.fold_in(key, r), cur.shape)
        perm = [(i, i ^ (1 << r)) for i in range(world)]
        if cfg.packed:
            sides = s[:, 0]
            words, k_own = _encode_packed(cur, sides, u, cfg,
                                          return_coords=True)
            w_partner = jax.lax.ppermute(words, axis_name, perm)
            sides_partner = jax.lax.ppermute(sides, axis_name, perm)
            k_partner = _decode_packed(w_partner, cur, sides_partner, u, cfg,
                                       mode="coords")
        else:
            k_own = _encode(cur, s, u)
            colors = L.color_of(k_own, cfg.q)
            c_partner = jax.lax.ppermute(colors, axis_name, perm)
            k_partner = L.decode_coords(c_partner, cur, s, u, q=cfg.q)
        # pin the (exact) integer coords so the float math below compiles
        # from identical subgraphs whichever wire path produced them
        k_own, k_partner = jax.lax.optimization_barrier((k_own, k_partner))
        f_b, d_b = _bucket_fails(k_partner, k_own, s, y_col)
        fails_b = fails_b + f_b
        dist_b = jnp.maximum(dist_b, d_b)
        # average in integer coordinate space: int adds are exact and
        # commutative, and the single float expression below is the same
        # fusion on every rank — so partners produce bit-identical values
        # (averaging the two float points instead lets XLA round the encode-
        # and decode-side fusions differently by 1 ulp, breaking the paper's
        # common-output requirement)
        cur = (0.5 * (k_own + k_partner).astype(jnp.float32) + u) * s
        # pin the round boundary: XLA otherwise re-fuses this expression into
        # the next round's wire-path-specific consumers with different
        # roundings, so packed and unpacked runs would drift
        cur = jax.lax.optimization_barrier(cur)

    if ab is not None:
        cur = cur + ab
    aux = QSyncAux(fails=jnp.sum(fails_b), max_dist=jnp.max(dist_b),
                   y_next=2.5 * jnp.max(dist_b), fails_b=fails_b,
                   dist_b=dist_b)
    return _unbucketize(cur, n, cfg), aux


# ---------------------------------------------------------------------------
# Recursive-halving reduce-scatter (the FSDP gradient path)
# ---------------------------------------------------------------------------

def rh_reduce_scatter_mean(x_local: Array, state: Union[QState, Array],
                           key: Array, axis_name, cfg: QSyncConfig
                           ) -> tuple[Array, QSyncAux]:
    """Reduce-scatter of the mean via quantized recursive halving.

    Round r pairs rank i with i XOR (world >> (r+1)); each sends (quantized)
    the half of its working segment the partner keeps, decodes the received
    half against its own (the anchor), and averages own + received lattice
    coordinates in exact integer space (see the in-loop comment; the same
    quantized-average and common-output discipline as the butterfly).  After
    log2(world) rounds rank i holds bucket-aligned segment i of the mean:
    shape (padded_n / world,).  With cfg.packed the sent half is packed
    words + its sides sidecar (the payload halves every round).

    ``state``: :class:`QState` or bare (nb,) y array.  An anchor is
    subtracted once at entry (the rounds then iterate anchor-relative, like
    the butterfly) and the kept segment's slice is added back at exit.
    ``aux.y_seg`` / ``aux.fails_b`` / ``aux.dist_b`` describe the kept
    segment per bucket — multi-axis FSDP chains feed ``y_seg`` straight into
    the next axis' call instead of re-broadcasting one scalar y.

    Requires the padded bucket count to divide evenly by the world size
    (guaranteed by fsdp.pad_to_shardable).
    """
    qs = QS.as_qstate(state)
    y_buckets = qs.y
    n = x_local.shape[0]
    world = _axis_size(axis_name)
    if world & (world - 1):
        raise ValueError(f"recursive halving needs power-of-two world, "
                         f"got {world}")
    cur = _bucketize(x_local, cfg)
    _check_buckets(cur, y_buckets)
    nb = cur.shape[0]
    if nb % world:
        raise ValueError(f"{nb} buckets not divisible by world={world}; "
                         f"pad with fsdp.pad_to_shardable first")
    ab = _bucketize(qs.anchor, cfg) if qs.anchor is not None else None
    if ab is not None:
        cur = cur - ab
    # pinned for the same reason as _sides: constant-derived lattice sides
    # otherwise compile into context-dependent non-exact reciprocal multiplies
    y_cur = jax.lax.optimization_barrier(y_buckets.astype(jnp.float32))
    rank = jax.lax.axis_index(axis_name) if world > 1 else jnp.zeros((), jnp.int32)

    fails_b = jnp.zeros((nb,), jnp.float32)
    dist_b = jnp.zeros((nb,), jnp.float32)
    # scalar telemetry covers every decode this rank performed (the old
    # semantics); the per-bucket maps follow the kept lineage only
    fails = jnp.zeros((), jnp.float32)
    max_dist = jnp.zeros((), jnp.float32)
    rounds = int(np.log2(world)) if world > 1 else 0
    for r in range(rounds):
        dist = world >> (r + 1)
        half = cur.shape[0] // 2
        lo, hi = cur[:half], cur[half:]
        y_lo, y_hi = y_cur[:half], y_cur[half:]
        u_full = L.shared_offset(jax.random.fold_in(key, r), cur.shape)
        u_lo, u_hi = u_full[:half], u_full[half:]
        # bit==0: keep the low half, send the high half (and vice versa);
        # the msb-first sweep leaves rank i with segment i of the vector.
        bit = ((rank // dist) % 2).astype(jnp.bool_)
        keep = jnp.where(bit, hi, lo)
        send = jnp.where(bit, lo, hi)
        y_keep = jnp.where(bit, y_hi, y_lo)
        y_send = jnp.where(bit, y_lo, y_hi)
        u_keep = jnp.where(bit, u_hi, u_lo)
        u_send = jnp.where(bit, u_lo, u_hi)
        s_keep = cfg.spec.side(y_keep)[:, None]
        s_send = cfg.spec.side(y_send)[:, None]
        if ab is not None:
            ab = jnp.where(bit, ab[half:], ab[:half])
        # the running per-bucket telemetry follows the kept half (every
        # bucket of the final segment was inside the working segment of
        # every round, so its counts/distances are complete)
        fails_b = jnp.where(bit, fails_b[half:], fails_b[:half])
        dist_b = jnp.where(bit, dist_b[half:], dist_b[:half])

        perm = [(i, i ^ dist) for i in range(world)]
        if cfg.packed:
            sides_send = s_send[:, 0]
            words = _encode_packed(send, sides_send, u_send, cfg)
            w_recv = jax.lax.ppermute(words, axis_name, perm)
            sides_recv = jax.lax.ppermute(sides_send, axis_name, perm)
            # the partner encoded *its* copy of the coordinates we keep; the
            # received sidecar equals our s_keep (same replicated y_buckets)
            k_recv = _decode_packed(w_recv, keep, sides_recv, u_keep, cfg,
                                    mode="coords")
        else:
            k_send = _encode(send, s_send, u_send)
            colors = L.color_of(k_send, cfg.q)
            c_recv = jax.lax.ppermute(colors, axis_name, perm)
            # the partner encoded *its* copy of the coordinates we keep, with
            # the same (u, s) — decode against our own half as the anchor
            k_recv = L.decode_coords(c_recv, keep, s_keep, u_keep, q=cfg.q)
        # the wire-path boundary hands over *integer* coords only (like the
        # butterfly): int values cannot FMA-contract into float consumers, so
        # the shared float math below compiles identically for the packed and
        # unpacked paths and the reduce-scatter stays bit-identical
        k_recv = jax.lax.optimization_barrier(k_recv)
        # quantize our own half onto the same (u, s) lattice: the reference
        # for the coordinate-space telemetry and for the exact average below
        k_own = L.encode_coords(keep, s_keep, u_keep)
        f_b, d_b = _bucket_fails(k_recv, k_own, s_keep, y_keep[:, None])
        fails_b = fails_b + f_b
        dist_b = jnp.maximum(dist_b, d_b)
        fails = fails + jnp.sum(f_b)
        max_dist = jnp.maximum(max_dist, jnp.max(d_b))
        # average in integer coordinate space, exactly as the butterfly does:
        # average the *coordinates* of our own quantized half and the
        # received half.  A float average 0.5*(keep + z) is not
        # compilation-stable — XLA CPU FMA-contracts/reassociates the mul-add
        # chain per fusion context (even across optimization_barrier), which
        # made the packed and unpacked wire paths drift by 1 ulp; the int sum
        # is exact and the remaining (0.5*k + u) * s has no contractible
        # add-of-product, so both paths stay bit-identical.  The extra s/2
        # dithered rounding on our own half is the paper's Algorithm 4
        # error model (unbiased, O(s log n) accumulated).
        cur = (0.5 * (k_own + k_recv).astype(jnp.float32) + u_keep) * s_keep
        y_cur = y_keep

    if ab is not None:
        cur = cur + ab
    if cfg.rotate:
        cur = R.unrotate(cur, _bucket_diag(cfg.bucket), cfg.bucket,
                         use_kernel=cfg.packed)
    out = cur.reshape(-1)
    aux = QSyncAux(fails=fails, max_dist=max_dist, y_next=2.5 * max_dist,
                   fails_b=fails_b, dist_b=dist_b, y_seg=y_cur)
    return out, aux


# ---------------------------------------------------------------------------
# Wire accounting (ring model, bytes *sent per rank*)
# ---------------------------------------------------------------------------

def _payload_bytes(n: int, cfg: QSyncConfig) -> int:
    """Bytes of one full-vector message.

    packed=True: packed-color words + 4B/bucket sides sidecar — the *actual*
    collective payload (words.nbytes + sides.nbytes), asserted in tests.
    packed=False: the unpacked uint32 color buffer the jnp fallback moves
    (no sidecar; sides stay local).  Delegates to the repo's one wire-byte
    definition (repro.core.wire_accounting), like the agg transport."""
    padded = flat_size_padded(n, cfg)
    return WA.collective_payload_bytes(padded, cfg.bits,
                                       padded // cfg.bucket, cfg.packed)


def wire_bytes_butterfly(n: int, world: int, cfg: QSyncConfig) -> int:
    """Recursive doubling: log2(world) rounds, one full payload each."""
    padded = flat_size_padded(n, cfg)
    return WA.butterfly_bytes(padded, cfg.bits, padded // cfg.bucket, world,
                              cfg.packed)


def wire_bytes_allgather(n: int, world: int, cfg: QSyncConfig) -> int:
    """Ring all-gather of every rank's payload: (world-1) forwarded chunks."""
    padded = flat_size_padded(n, cfg)
    return WA.allgather_bytes(padded, cfg.bits, padded // cfg.bucket, world,
                              cfg.packed)


def wire_bytes_rh(n: int, world: int, cfg: QSyncConfig) -> int:
    """Recursive halving: round r sends the (padded/2^{r+1})-coordinate half
    of the working segment (packed: words + its sides sidecar; unpacked:
    the uint32 color buffer); the payload halves every round, summing to
    ~one full payload."""
    padded = flat_size_padded(n, cfg)
    return WA.rh_bytes(padded, cfg.bits, padded // cfg.bucket, world,
                       cfg.packed)


def wire_bytes_anchor_gather(n: int, world: int) -> int:
    """Forward f32 tiled all-gather rebuilding a *sharded* anchor (the
    second gather in the FSDP prefetch slot — see dist/fsdp.py).  Note
    this is a forward-path cost: the anchored backward sync itself moves
    zero anchor bytes (the butterfly's common output doubles as the next
    anchor) regardless of anchor layout."""
    return WA.anchor_gather_bytes(n, world)
