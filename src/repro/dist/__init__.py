"""Production distributed layer: quantized collectives + ZeRO-3 FSDP gather.

``collectives``  — the paper's mean-estimation algorithms as shard_map
                   collectives (Alg. 3 star / Alg. 4 tree analogues) with
                   lattice quantization from :mod:`repro.core.lattice`.
``fsdp``         — custom-vjp parameter gather: bf16 all-gather forward,
                   lattice-quantized reduce-scatter backward, telemetry via
                   the cotangent of a dummy ``tele`` input.
"""
from repro.dist import collectives
from repro.dist import fsdp
