#!/usr/bin/env bash
# Tier-1 pre-merge gate (see README.md / ROADMAP.md).
#
#   1. the fast test suite (everything not marked `slow`), fail-fast;
#   2. a smoke run of the production quantized collectives on 8 emulated
#      devices (examples/distributed_dme.py).
#
# The `slow` suite (tests/test_multidevice.py, tests/test_trainer.py) runs
# the same way without `-m "not slow"`; it is required before releases but
# too heavy for every push.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: fast suite =="
python -m pytest -x -q -m "not slow"

echo "== tier-1: distributed DME smoke (8 emulated devices) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python examples/distributed_dme.py

echo "== tier-1 gate passed =="
