#!/usr/bin/env bash
# Tier-1 pre-merge gate (see README.md / ROADMAP.md; run by
# .github/workflows/ci.yml on every push/PR):
#
#   1. lint (scripts/lint.sh: ruff check; ruff format gates once the
#      one-time --migrate-format pass is recorded in ruff.toml);
#   2. the fast test suite (everything not marked `slow`), fail-fast —
#      includes the 8-device packed-vs-unpacked wire parity subprocess test;
#   3. a smoke run of the production quantized collectives on 8 emulated
#      devices (examples/distributed_dme.py) — asserts the packed Pallas
#      wire path is bit-identical to the jnp oracle;
#   4. a smoke run of the federated aggregation service
#      (examples/federated_dme.py) — a 256-client round over the repro.agg
#      byte protocol with drops/duplicates/corruption/escalation, asserting
#      arrival-order bit-determinism; a CHUNKED round (v3 transport, MTU
#      forcing >= 4 chunks/client) asserting bit-identity with the
#      single-frame round, the bounded transport staging, and the
#      selective-retransmit wire cost of a lossy round; a WINDOWED
#      streaming round (v5: window=2, 10% loss) asserting ack/credit
#      convergence with window stalls, a pending store below the sealed
#      path's high-water, and bit-identity with the sealed batched-decode
#      drain; PLUS three anchored
#      multi-round service rounds asserting that round k+1's anchor digest
#      matches round k's published mean and no clients are lost; and the
#      HIERARCHICAL topology (--topology tree): 96 chunked clients through
#      a 2-tier fanout-8 sum-without-decode AggTree, asserted bit-identical
#      to the flat server with every decode dispatch at the root and root
#      ingress bounded by the fanout;
#   5. a smoke run of the continuous-round engine under open-loop load
#      (examples/open_loop_agg.py) — Poisson arrivals + flash crowd +
#      churn/loss/stragglers on a virtual clock: >= 3 rounds concurrently
#      live, every published mean bit-identical to a lockstep replay of
#      that round's accepted clients, no terminal verdict for any benign
#      client, and engine rounds/sec strictly above the lockstep
#      coordinator on the identical arrival trace; the same smoke then
#      reruns the trace with repro.obs fully enabled and asserts every
#      published round's span tree is causally complete (check_round) and
#      both exporters render (OBS_SMOKE_OK);
#   6. a smoke of the prefetch-pipelined FSDP trainer on 8 emulated devices
#      (benchmarks/fsdp_overlap_probe.py --check) — 3 steps of the tiny
#      anchored trainer, serial vs double-buffered prefetch, asserting
#      bitwise-identical losses/params, a strictly lower HLO
#      collective_exposed_fraction for the prefetched program, and zero
#      sharded-anchor state bytes per step;
#   7. with CI_BENCH=1, the benchmark regression gate (scripts/bench_ci.py:
#      kernel_lattice_* timings + bench_dme accuracy + agg_* service
#      throughput + the engine's virtual-clock latency/staleness/speedup
#      vs the last committed BENCH_*.json baseline, plus the absolute
#      obs_overhead_pct <= 10% enabled-observability budget).
#
# The `slow` suite (tests/test_multidevice.py, tests/test_trainer.py) runs
# the same way without `-m "not slow"`; it is required before releases and
# runs nightly in CI, but is too heavy for every push.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: lint =="
./scripts/lint.sh

echo "== tier-1: fast suite =="
python -m pytest -x -q -m "not slow"

echo "== tier-1: distributed DME smoke (8 emulated devices) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python examples/distributed_dme.py

echo "== tier-1: federated aggregation smoke (repro.agg protocol) =="
python examples/federated_dme.py

echo "== tier-1: hierarchical aggregation smoke (sum-without-decode tree) =="
python examples/federated_dme.py --topology tree

echo "== tier-1: open-loop continuous-round engine smoke =="
python examples/open_loop_agg.py

echo "== tier-1: FSDP prefetch-overlap smoke (8 emulated devices) =="
python benchmarks/fsdp_overlap_probe.py --check

if [[ "${CI_BENCH:-0}" == "1" ]]; then
    echo "== tier-1: benchmark regression gate =="
    python scripts/bench_ci.py
fi

echo "== tier-1 gate passed =="
