#!/usr/bin/env bash
# Lint gate (first CI step; see .github/workflows/ci.yml).
#
#   1. `ruff check` over src/ tests/ benchmarks/ scripts/ — the rule set is
#      pinned in ruff.toml to the correctness-critical classes (syntax
#      errors, undefined names, misused comparisons);
#   2. `ruff format --check` — advisory for now: the codebase predates the
#      formatter, so drift is reported but does not fail the gate.
#
# Skips cleanly when ruff is not installed (the hermetic test container does
# not ship it; CI installs it).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v ruff >/dev/null 2>&1; then
    echo "lint: ruff not installed; skipping (pip install ruff)"
    exit 0
fi

echo "== ruff check =="
ruff check src tests benchmarks scripts

echo "== ruff format --check (advisory) =="
if ! ruff format --check src tests benchmarks scripts; then
    echo "lint: formatting drift (advisory only — not failing the gate)"
fi

echo "== lint passed =="
