#!/usr/bin/env bash
# Lint gate (first CI step; see .github/workflows/ci.yml).
#
#   1. `ruff check` over src/ tests/ benchmarks/ scripts/ — the rule set is
#      pinned in ruff.toml to the correctness-critical classes (syntax
#      errors, undefined names, misused comparisons);
#   2. `ruff format --check` — GATING once the one-time format pass has
#      been recorded (the `format-migrated` flag in ruff.toml).  The pass
#      and the flag flip are one atomic step:
#
#          ./scripts/lint.sh --migrate-format   # runs `ruff format`,
#                                               # arms the gate; commit both
#
#      Until then the check is advisory with a loud nag — arming the gate
#      without the pass would turn CI permanently red (the hermetic test
#      container does not ship ruff and has no network, so the pass must
#      run on a ruff-equipped machine; CI installs ruff).
#
# Skips cleanly when ruff is not installed.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v ruff >/dev/null 2>&1; then
    echo "lint: ruff not installed; skipping (pip install ruff)"
    exit 0
fi

PATHS=(src tests benchmarks scripts)

if [[ "${1:-}" == "--migrate-format" ]]; then
    echo "== one-time ruff format pass =="
    ruff format "${PATHS[@]}"
    # portable in-place edit (BSD/macOS sed needs a suffix with -i)
    sed -i.bak 's/^# format-migrated: no$/# format-migrated: yes/' ruff.toml
    rm -f ruff.toml.bak
    echo "lint: formatted tree and armed the format gate in ruff.toml;"
    echo "      review + commit both (the gate fails on drift from now on)"
    exit 0
fi

echo "== ruff check =="
ruff check "${PATHS[@]}"

if grep -q '^# format-migrated: yes$' ruff.toml; then
    echo "== ruff format --check (gating) =="
    ruff format --check "${PATHS[@]}"
else
    echo "== ruff format --check (advisory until --migrate-format) =="
    if ! ruff format --check "${PATHS[@]}"; then
        echo "lint: formatting drift (advisory only — run" \
             "'./scripts/lint.sh --migrate-format' once to format the" \
             "tree and arm the gate)"
    fi
fi

echo "== lint passed =="
