#!/usr/bin/env python
"""CI benchmark gate: run benchmarks, record a dated baseline, fail on
regression.

Runs ``benchmarks/run.py`` (the ``bench_kernels`` + ``bench_dme`` +
``bench_agg`` + ``bench_nn`` gate set by default, ``--all`` for every
module), parses its
``BENCH_JSON`` summary line, writes ``BENCH_<YYYY-MM-DD>.json`` at the repo
root (us_per_call + wire_compression + derived metrics per benchmark), and
compares the guarded entries against the most recent committed
``BENCH_*.json``:

  * ``kernel_lattice_*`` and ``agg_*`` (the aggregation-service round /
    receive paths): fails if us_per_call regresses more than REGRESSION
    (20%) plus a small absolute slack (interpret-mode CPU timings jitter),
    if the derived wire_compression drops, or if bytes_per_client,
    chunk_overhead_pct, peak_staging_bytes, reassembly_amplification,
    pending_store_bytes or window_stalls grow (the chunked-transport and
    streaming-decode rows of bench_agg).
    The wall-clock gate only applies when the baseline was recorded on the
    same machine class (arch + cpu count) — absolute timings are not
    comparable across hardware; the compression/MSE/bytes gates always
    apply;
  * ``bench_dme`` rows: fails if any ``*mse*`` metric grows more than
    REGRESSION — the accuracy side of the communication/variance trade-off.

Wired into scripts/ci.sh behind ``CI_BENCH=1``.
"""
from __future__ import annotations

import argparse
import datetime
import glob
import json
import os
import platform
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GATE_MODULES = "bench_dme,bench_kernels,bench_agg,bench_nn"
REGRESSION = 0.20          # >20% worse than baseline fails
US_SLACK = 10_000.0        # absolute us slack: interpret-mode CPU timings
                           # jitter by ~10ms under co-located load
OBS_OVERHEAD_MAX_PCT = 10.0  # ISSUE 8 acceptance: full observability
                             # (metrics+tracing+recording) enabled must stay
                             # a small constant cost on the open-loop trace.
                             # Intrinsic cost measures ~2-5%; the budget
                             # carries headroom because the paired min-of-5
                             # estimate still swings several points under
                             # co-tenant scheduler noise on a 2-cpu
                             # container (the old 5% line flapped on
                             # known-good commits).  A real regression —
                             # tracing going superlinear in chunk count —
                             # blows far past 10%.
# wall-clock + wire-compression guarded rows: the fused lattice kernels and
# the aggregation-service round/receive paths (repro.agg throughput)
GUARD_PREFIXES = ("kernel_lattice_", "agg_")


def parse_derived(derived: str) -> dict:
    """'n=1048576;wire_compression=8x;star_mse=1.2e-3' -> float metrics."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        m = re.fullmatch(r"(-?[\d.eE+-]+)x?", v.strip())
        if m:
            try:
                out[k.strip()] = float(m.group(1))
            except ValueError:
                pass
    return out


def run_benchmarks(modules: "str | None") -> dict:
    env = dict(os.environ)
    # ROOT for `import benchmarks`, src/ for `import repro`
    env["PYTHONPATH"] = os.pathsep.join(
        [ROOT, os.path.join(ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, os.path.join(ROOT, "benchmarks", "run.py")]
    if modules:
        cmd += ["--modules", modules]
    r = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT, env=env)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr)
    summary = None
    for line in r.stdout.splitlines():
        if line.startswith("BENCH_JSON "):
            summary = json.loads(line[len("BENCH_JSON "):])
    if summary is None:
        print("bench_ci: no BENCH_JSON line from benchmarks/run.py",
              file=sys.stderr)
        sys.exit(1)
    if r.returncode != 0 or not summary["ok"]:
        print(f"bench_ci: benchmark modules failed: {summary['failed']}",
              file=sys.stderr)
        sys.exit(1)
    return summary


def to_entries(summary: dict) -> dict:
    entries = {}
    for name, row in summary["results"].items():
        metrics = parse_derived(row["derived"])
        entries[name] = {
            "module": row["module"],
            "us_per_call": row["us_per_call"],
            "wire_compression": metrics.get("wire_compression"),
            "metrics": metrics,
        }
    return entries


def machine_id() -> str:
    return f"{platform.machine()}-{os.cpu_count()}cpu"


def latest_baseline() -> "tuple[str, dict] | tuple[None, None]":
    """Most recent *committed* BENCH_*.json (so a same-day rerun, or an
    uncommitted file carrying a sub-threshold regression, never becomes the
    reference the gate ratchets against).  Falls back to the newest file on
    disk outside a git checkout."""
    try:
        tracked = subprocess.run(
            ["git", "ls-files", "BENCH_*.json"], cwd=ROOT,
            capture_output=True, text=True, check=True).stdout.split()
        paths = sorted(os.path.join(ROOT, p) for p in tracked)
    except (subprocess.CalledProcessError, OSError):
        paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not paths:
        return None, None
    # compare against the committed *content*, not the working-tree file a
    # previous same-day run may have overwritten
    rel = os.path.relpath(paths[-1], ROOT)
    try:
        blob = subprocess.run(["git", "show", f"HEAD:{rel}"], cwd=ROOT,
                              capture_output=True, text=True, check=True
                              ).stdout
        return paths[-1], json.loads(blob)
    except (subprocess.CalledProcessError, OSError, json.JSONDecodeError):
        with open(paths[-1]) as f:
            return paths[-1], json.load(f)


def compare(entries: dict, base: dict, same_machine: bool = True
            ) -> "list[str]":
    """Regression problems vs the baseline.  Wall-clock (us_per_call) is
    only gated when the baseline came from the same machine class —
    absolute interpret-mode timings are not comparable across hardware;
    wire_compression and the bench_dme MSEs are gated unconditionally."""
    problems = []
    base_entries = base.get("entries", {})
    for name, e in entries.items():
        # absolute gate, needs no baseline: bench_agg measures the same
        # open-loop trace with observability fully enabled vs disabled
        ov = e.get("metrics", {}).get("obs_overhead_pct")
        if ov is not None and ov > OBS_OVERHEAD_MAX_PCT:
            problems.append(
                f"{name}: obs_overhead_pct {ov:.1f} exceeds the "
                f"{OBS_OVERHEAD_MAX_PCT:.0f}% enabled-observability budget")
        # absolute gates for the fsdp_overlap row (bench_nn): the prefetched
        # program's loop collectives must be structurally overlapped (HLO
        # auditor exposed fraction strictly below the serial baseline) and
        # the sharded anchor must add zero per-step state bytes — both are
        # properties of the lowered program, not of the machine
        if name == "fsdp_overlap":
            es = e.get("metrics", {}).get("exposed_serial")
            ep = e.get("metrics", {}).get("exposed_prefetch")
            if es is None or ep is None or not ep < es:
                problems.append(
                    f"{name}: exposed_prefetch ({ep}) is not strictly below "
                    f"exposed_serial ({es})")
            ab = e.get("metrics", {}).get("anchor_state_bytes")
            if ab != 0:
                problems.append(
                    f"{name}: sharded anchor moved {ab} state bytes/step "
                    f"(must be 0)")
        b = base_entries.get(name)
        if b is None:
            continue
        if name == "fsdp_overlap":
            # ratchet vs the committed baseline: the exposed fraction (a
            # structural property, deterministic per commit — small absolute
            # slack for lowering drift) and the prefetch/serial step-time
            # ratio (noisy interpret-mode CPU timing: policy tolerance)
            for k, tol, slack in (("exposed_prefetch", 0.0, 0.05),
                                  ("step_ratio", REGRESSION, 0.0)):
                bv = b.get("metrics", {}).get(k)
                ev = e.get("metrics", {}).get(k)
                if bv is not None and ev is not None and \
                        ev > bv * (1 + tol) + slack:
                    problems.append(f"{name}: {k} {ev:g} grew past baseline "
                                    f"{bv:g}")
        if name.startswith(GUARD_PREFIXES):
            if (same_machine and b["us_per_call"] > 0 and
                    e["us_per_call"] > b["us_per_call"] * (1 + REGRESSION)
                    + US_SLACK):
                problems.append(
                    f"{name}: {e['us_per_call']:.1f}us vs baseline "
                    f"{b['us_per_call']:.1f}us (> +{REGRESSION:.0%})")
            bw, ew = b.get("wire_compression"), e.get("wire_compression")
            if bw and ew and ew < bw:
                problems.append(f"{name}: wire_compression {ew}x dropped "
                                f"below baseline {bw}x")
            bb = b.get("metrics", {}).get("bytes_per_client")
            eb = e.get("metrics", {}).get("bytes_per_client")
            if bb and eb and eb > bb:
                problems.append(f"{name}: bytes_per_client {eb:.0f} grew "
                                f"past baseline {bb:.0f}")
            # chunked-transport rows: the header-overhead share, the
            # transport's peak pre-CRC staging (bounded by one frame,
            # independent of d — asserted inside bench_agg), the
            # reassembly-buffer amplification (1.0 = the transport holds
            # exactly the pending payload store), and the streaming rows'
            # pending-store high-water / window-stall count (v5: chunk
            # bytes are freed as ranges fold, so the store — and the
            # lossless-trace stall count — must never creep back up)
            for k in ("chunk_overhead_pct", "peak_staging_bytes",
                      "reassembly_amplification", "pending_store_bytes",
                      "store_vs_sealed", "window_stalls"):
                bv = b.get("metrics", {}).get(k)
                ev = e.get("metrics", {}).get(k)
                # `is not None`, not truthiness: a 0.0 baseline (body fits
                # one MTU) must still gate a regression to positive
                if bv is not None and ev is not None and ev > bv:
                    problems.append(f"{name}: {k} {ev:g} grew past "
                                    f"baseline {bv:g}")
            # virtual-clock engine metrics (agg_engine_openloop): event-time
            # quantities, deterministic for the trace and identical on any
            # machine, so they gate regardless of same_machine.  Latency/
            # staleness must not grow, throughput/speedup must not drop,
            # beyond the policy-tuning tolerance.
            for k in ("p50_round_ms", "p99_round_ms", "staleness_ms"):
                bv = b.get("metrics", {}).get(k)
                ev = e.get("metrics", {}).get(k)
                if bv is not None and ev is not None and \
                        ev > bv * (1 + REGRESSION):
                    problems.append(f"{name}: {k} {ev:g}ms grew past "
                                    f"baseline {bv:g}ms (> +{REGRESSION:.0%})")
            for k in ("rounds_per_s", "speedup"):
                bv = b.get("metrics", {}).get(k)
                ev = e.get("metrics", {}).get(k)
                if bv is not None and ev is not None and \
                        ev < bv * (1 - REGRESSION):
                    problems.append(f"{name}: {k} {ev:g} dropped below "
                                    f"baseline {bv:g} (> -{REGRESSION:.0%})")
        if e["module"] == "bench_dme":
            for k, v in e["metrics"].items():
                if "mse" not in k:
                    continue
                bv = b.get("metrics", {}).get(k)
                if bv is not None and v > bv * (1 + REGRESSION) + 1e-12:
                    problems.append(f"{name}.{k}: {v:.3e} vs baseline "
                                    f"{bv:.3e} (> +{REGRESSION:.0%})")
    return problems


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--all", action="store_true",
                   help="run every benchmark module, not just the gate set")
    p.add_argument("--no-write", action="store_true",
                   help="compare only; do not write a new BENCH_<date>.json")
    args = p.parse_args(argv)

    summary = run_benchmarks(None if args.all else GATE_MODULES)
    entries = to_entries(summary)

    base_path, base = latest_baseline()
    same_machine = bool(base) and base.get("machine", machine_id()) == \
        machine_id()
    problems = compare(entries, base or {}, same_machine)

    if not args.no_write:
        today = datetime.date.today().isoformat()
        out_path = os.path.join(ROOT, f"BENCH_{today}.json")
        with open(out_path, "w") as f:
            json.dump({"date": today, "machine": machine_id(),
                       "modules": sorted(
                           {e["module"] for e in entries.values()}),
                       "entries": entries}, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"bench_ci: wrote {os.path.relpath(out_path, ROOT)} "
              f"({len(entries)} entries)")

    if base_path:
        print(f"bench_ci: baseline {os.path.relpath(base_path, ROOT)}"
              + ("" if same_machine else
                 " (different machine class: wall-clock gate skipped, "
                 "compression/MSE gates enforced)"))
    else:
        print("bench_ci: no committed baseline yet; gate passes vacuously")
    if problems:
        print("bench_ci: REGRESSIONS DETECTED", file=sys.stderr)
        for pr in problems:
            print(f"  - {pr}", file=sys.stderr)
        sys.exit(1)
    print("bench_ci: gate passed")


if __name__ == "__main__":
    main()
