"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as L
from repro.kernels import ops, ref


@pytest.mark.parametrize("d", [4, 16, 128, 512, 2048, 8192, 16384])
@pytest.mark.parametrize("rows", [1, 3, 8])
def test_fwht_matches_ref(d, rows):
    x = jax.random.normal(jax.random.PRNGKey(d + rows), (rows, d), jnp.float32)
    got = ops.fwht(x)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024)).astype(dtype)
    got = ops.fwht(x)
    assert got.dtype == dtype
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fwht_orthonormal_involutive():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4096))
    y = ops.fwht(x)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)
    back = ops.fwht(y)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q", [4, 16, 256])
@pytest.mark.parametrize("n", [64, 1000, 40000])
def test_encode_matches_ref_exactly(q, n):
    bits = L.bits_for_q(q)
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 50
    u = jax.random.uniform(jax.random.PRNGKey(n + 1), (n,), minval=-.5,
                           maxval=.5)
    s = 0.173
    got = ops.lattice_encode(x, u, s, q=q)
    want = ref.lattice_encode_ref(x, u, s, q=q, bits=bits)
    assert jnp.array_equal(got, want)


@pytest.mark.parametrize("q", [4, 16, 256])
@pytest.mark.parametrize("avg_cnt", [None, 3])
def test_decode_matches_ref_exactly(q, avg_cnt):
    n, s = 30000, 0.08
    bits = L.bits_for_q(q)
    x = jax.random.normal(jax.random.PRNGKey(7), (n,)) * 20
    u = jax.random.uniform(jax.random.PRNGKey(8), (n,), minval=-.5, maxval=.5)
    w = ops.lattice_encode(x, u, s, q=q)
    # provable exact-decode margin: |x-anchor| <= (q/2 - 1) * s (rounding of
    # both x and the anchor can each move the coordinate by 1/2 a cell)
    margin = max((q / 2 - 1), 0.4) * s
    anchor = x + jax.random.uniform(jax.random.PRNGKey(9), (n,), minval=-1,
                                    maxval=1) * 0.9 * margin
    got = ops.lattice_decode(w, anchor, u, s, q=q, avg_cnt=avg_cnt)
    want = ref.lattice_decode_ref(w, anchor, u, s, q=q, bits=bits, n=n,
                                  avg_cnt=avg_cnt)
    if avg_cnt is None:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=0)
    else:
        # the fused running-average epilogue may differ by FMA-contraction
        # ULPs from the two-step reference
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_encode_decode_roundtrip_recovers_lattice_point():
    n, q, s = 10000, 16, 0.05
    x = jax.random.normal(jax.random.PRNGKey(3), (n,)) * 100
    u = jax.random.uniform(jax.random.PRNGKey(4), (n,), minval=-.5, maxval=.5)
    w = ops.lattice_encode(x, u, s, q=q)
    z = ops.lattice_decode(w, x, u, s, q=q)       # anchor = x itself
    k = L.encode_coords(x, s, u)
    zt = L.coords_to_point(k, s, u)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zt), rtol=1e-6,
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(z - x))) <= 0.5 * s + 1e-6


def test_bfloat16_input_encode():
    n, q, s = 4096, 16, 0.1
    x = (jax.random.normal(jax.random.PRNGKey(5), (n,)) * 10).astype(jnp.bfloat16)
    u = jax.random.uniform(jax.random.PRNGKey(6), (n,), minval=-.5, maxval=.5)
    got = ops.lattice_encode(x, u, s, q=q)
    want = ref.lattice_encode_ref(x, u, s, q=q, bits=4)
    assert jnp.array_equal(got, want)
