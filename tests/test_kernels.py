"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as L
from repro.kernels import ops, ref


@pytest.mark.parametrize("d", [4, 16, 128, 512, 2048, 8192, 16384])
@pytest.mark.parametrize("rows", [1, 3, 8])
def test_fwht_matches_ref(d, rows):
    x = jax.random.normal(jax.random.PRNGKey(d + rows), (rows, d), jnp.float32)
    got = ops.fwht(x)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024)).astype(dtype)
    got = ops.fwht(x)
    assert got.dtype == dtype
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fwht_orthonormal_involutive():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4096))
    y = ops.fwht(x)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)
    back = ops.fwht(y)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q", [4, 16, 256])
@pytest.mark.parametrize("n", [64, 1000, 40000])
def test_encode_matches_ref_exactly(q, n):
    bits = L.bits_for_q(q)
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 50
    u = jax.random.uniform(jax.random.PRNGKey(n + 1), (n,), minval=-.5,
                           maxval=.5)
    s = 0.173
    got = ops.lattice_encode(x, u, s, q=q)
    want = ref.lattice_encode_ref(x, u, s, q=q, bits=bits)
    assert jnp.array_equal(got, want)


@pytest.mark.parametrize("q", [4, 16, 256])
@pytest.mark.parametrize("avg_cnt", [None, 3])
def test_decode_matches_ref_exactly(q, avg_cnt):
    n, s = 30000, 0.08
    bits = L.bits_for_q(q)
    x = jax.random.normal(jax.random.PRNGKey(7), (n,)) * 20
    u = jax.random.uniform(jax.random.PRNGKey(8), (n,), minval=-.5, maxval=.5)
    w = ops.lattice_encode(x, u, s, q=q)
    # provable exact-decode margin: |x-anchor| <= (q/2 - 1) * s (rounding of
    # both x and the anchor can each move the coordinate by 1/2 a cell)
    margin = max((q / 2 - 1), 0.4) * s
    anchor = x + jax.random.uniform(jax.random.PRNGKey(9), (n,), minval=-1,
                                    maxval=1) * 0.9 * margin
    got = ops.lattice_decode(w, anchor, u, s, q=q, avg_cnt=avg_cnt)
    want = ref.lattice_decode_ref(w, anchor, u, s, q=q, bits=bits, n=n,
                                  avg_cnt=avg_cnt)
    if avg_cnt is None:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=0)
    else:
        # the fused running-average epilogue may differ by FMA-contraction
        # ULPs from the two-step reference
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_encode_decode_roundtrip_recovers_lattice_point():
    n, q, s = 10000, 16, 0.05
    x = jax.random.normal(jax.random.PRNGKey(3), (n,)) * 100
    u = jax.random.uniform(jax.random.PRNGKey(4), (n,), minval=-.5, maxval=.5)
    w = ops.lattice_encode(x, u, s, q=q)
    z = ops.lattice_decode(w, x, u, s, q=q)       # anchor = x itself
    k = L.encode_coords(x, s, u)
    zt = L.coords_to_point(k, s, u)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zt), rtol=1e-6,
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(z - x))) <= 0.5 * s + 1e-6


@pytest.mark.parametrize("n", [1000, 12, 40960])
def test_encode_per_coordinate_sides_matches_ref(n):
    """Per-bucket sides broadcast to per-coordinate (the collectives' wire
    layout) — packed words, coords and decode must match the jnp oracle
    exactly, including non-tile-aligned n (ones-padded sides)."""
    q, bits, bucket = 16, 4, 4
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 20
    u = jax.random.uniform(jax.random.PRNGKey(n + 1), (n,), minval=-.5,
                           maxval=.5)
    nb = -(-n // bucket)
    sb = 0.01 + 0.05 * jax.random.uniform(jax.random.PRNGKey(n + 2), (nb,))
    s = jnp.repeat(sb, bucket)[:n]
    w, k = ops.lattice_encode(x, u, s, q=q, return_coords=True)
    w_ref, k_ref = ref.lattice_encode_ref(x, u, s, q=q, bits=bits,
                                          return_coords=True)
    assert jnp.array_equal(w, w_ref)
    assert jnp.array_equal(k, k_ref)
    assert w.shape[0] == L.packed_len(n, bits)
    z = ops.lattice_decode(w, x, u, s, q=q)
    z_ref = ref.lattice_decode_ref(w, x, u, s, q=q, bits=bits, n=n)
    assert jnp.array_equal(z, z_ref)


def test_decode_coords_mode_matches_ref():
    n, q, s = 20000, 16, 0.07
    x = jax.random.normal(jax.random.PRNGKey(11), (n,)) * 30
    u = jax.random.uniform(jax.random.PRNGKey(12), (n,), minval=-.5, maxval=.5)
    w = ops.lattice_encode(x, u, s, q=q)
    anchor = x + 0.3 * s
    k = ops.lattice_decode(w, anchor, u, s, q=q, mode="coords")
    k_ref = ref.lattice_decode_ref(w, anchor, u, s, q=q, bits=4, n=n,
                                   mode="coords")
    assert k.dtype == jnp.int32
    assert jnp.array_equal(k, k_ref)
    # anchor = x: the coords are exactly the encoder's
    k_self = ops.lattice_decode(w, x, u, s, q=q, mode="coords")
    assert jnp.array_equal(k_self, L.encode_coords(x, s, u))


def test_encode_return_coords_consistent_with_words():
    n, q = 5000, 16
    x = jax.random.normal(jax.random.PRNGKey(13), (n,)) * 10
    u = jax.random.uniform(jax.random.PRNGKey(14), (n,), minval=-.5, maxval=.5)
    w_only = ops.lattice_encode(x, u, 0.05, q=q)
    w, k = ops.lattice_encode(x, u, 0.05, q=q, return_coords=True)
    assert jnp.array_equal(w, w_only)
    assert jnp.array_equal(L.color_of(k, q),
                           L.unpack_colors(w, n, 4))


def test_bfloat16_input_encode():
    n, q, s = 4096, 16, 0.1
    x = (jax.random.normal(jax.random.PRNGKey(5), (n,)) * 10).astype(jnp.bfloat16)
    u = jax.random.uniform(jax.random.PRNGKey(6), (n,), minval=-.5, maxval=.5)
    got = ops.lattice_encode(x, u, s, q=q)
    want = ref.lattice_encode_ref(x, u, s, q=q, bits=4)
    assert jnp.array_equal(got, want)
