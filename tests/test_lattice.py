"""Property tests for the core lattice quantizer (paper §3, Theorem 1).

Offline-safe: when ``hypothesis`` is not installed (air-gapped CI images),
the ``@given`` property tests fall back to a deterministic grid of examples
drawn from the same strategies instead of erroring the whole collection.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # deterministic fallback path
    class _GridStrategies:
        """Stand-ins returning small deterministic example lists."""

        @staticmethod
        def integers(lo, hi):
            return sorted({lo, (lo + hi) // 2, hi})

        @staticmethod
        def sampled_from(xs):
            return list(xs)

        @staticmethod
        def floats(lo, hi):
            return [lo, (lo + hi) / 2, hi]

    st = _GridStrategies()

    def settings(**_kw):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            import inspect
            names = ",".join(inspect.signature(f).parameters)
            cases = list(itertools.islice(
                itertools.product(*strategies), 64))
            return pytest.mark.parametrize(names, cases)(f)
        return deco

from repro.core import lattice as L


@given(st.integers(2, 65536), st.sampled_from([2, 3, 4, 5, 8, 9, 16, 64, 256]))
def test_bits_for_q_packable(n, q):
    b = L.bits_for_q(q)
    assert b in L.PACK_BITS
    assert (1 << b) >= q


@settings(deadline=None, max_examples=25)
@given(st.integers(1, 3000), st.sampled_from([1, 2, 4, 8, 16]),
       st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(n, bits, seed):
    colors = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                1 << bits).astype(jnp.uint32)
    words = L.pack_colors(colors, bits)
    assert words.shape[-1] == L.packed_len(n, bits)
    back = L.unpack_colors(words, n, bits)
    assert jnp.array_equal(colors, back)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 1000), st.sampled_from([4, 8, 16, 64, 256]),
       st.floats(0.01, 100.0))
def test_decode_recovers_exact_lattice_point_within_margin(seed, q, y):
    """Lemma 15 (cubic form): decode exact iff |x - anchor|_inf <= (q-1)s/2."""
    d = 64
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (d,)) * y * 10      # large norm, paper regime
    spec = L.LatticeSpec(q)
    s = spec.side(y)
    u = L.shared_offset(jax.random.fold_in(key, 1), (d,))
    k = L.encode_coords(x, s, u)
    colors = L.color_of(k, q)
    # provable margin: rounding x and anchor each contribute half a cell
    margin = max(q / 2 - 1, 0.4) * float(s)
    anchor = x + jax.random.uniform(jax.random.fold_in(key, 2), (d,),
                                    minval=-1, maxval=1) * 0.9 * margin
    k2 = L.decode_coords(colors, anchor, s, u, q=q)
    assert jnp.array_equal(k, k2), "decode must recover the exact point"


def test_decode_fails_beyond_margin():
    d, q, y = 32, 8, 1.0
    spec = L.LatticeSpec(q)
    s = float(spec.side(y))
    x = jnp.zeros((d,))
    u = jnp.zeros((d,))
    k = L.encode_coords(x, s, u)
    colors = L.color_of(k, q)
    anchor = x + jnp.full((d,), q * s)          # far beyond the margin
    k2 = L.decode_coords(colors, anchor, s, u, q=q)
    assert not jnp.array_equal(k, k2)
    z = L.coords_to_point(k2, s, u)
    assert bool(L.decode_failure(z, x, y)) or jnp.max(jnp.abs(z - x)) > y


def test_unbiasedness_with_shared_offset():
    """E_u[(round(x/s - u) + u) * s] == x (dithered quantizer)."""
    d, q, y = 8, 16, 2.0
    x = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 30
    spec = L.LatticeSpec(q)
    s = spec.side(y)
    acc = jnp.zeros((d,))
    n = 4000
    for i in range(n):
        u = L.shared_offset(jax.random.PRNGKey(i + 1), (d,))
        k = L.encode_coords(x, s, u)
        acc = acc + L.coords_to_point(k, s, u)
    dev = jnp.max(jnp.abs(acc / n - x))
    # std of the mean ~ s/sqrt(12 n); allow 5 sigma
    assert float(dev) < 5 * float(s) / np.sqrt(12 * n)


def test_quantization_error_bounded_by_half_cell():
    d, q, y = 512, 16, 1.0
    x = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 100
    spec = L.LatticeSpec(q)
    s = float(spec.side(y))
    u = L.shared_offset(jax.random.PRNGKey(1), (d,))
    k = L.encode_coords(x, s, u)
    z = L.coords_to_point(k, s, u)
    assert float(jnp.max(jnp.abs(z - x))) <= 0.5 * s + 1e-5


def test_wire_bytes_accounting():
    assert L.wire_bytes(4096, 4) == 4096 // 8 * 4
    assert L.wire_bytes(4096, 8) == 4096 // 4 * 4
    assert L.wire_bytes(5, 4) == 4          # one word
