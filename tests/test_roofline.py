"""benchmarks/roofline.py report plumbing: tag filtering and the
exposed-fraction column."""
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from benchmarks import roofline as R  # noqa: E402


def _write(d, name, rec):
    with open(os.path.join(d, name), "w") as f:
        json.dump(rec, f)


def test_load_applies_tag_filter_to_skipped_records(tmp_path):
    """Regression: skipped records were appended before the tag check, so
    skip rows from every tag leaked into every report."""
    d = str(tmp_path)
    _write(d, "a.json", {"tag": "", "skipped": False, "arch": "x"})
    _write(d, "b.json", {"tag": "exp2", "skipped": True, "arch": "y",
                         "reason": "r"})
    _write(d, "c.json", {"tag": "exp2", "skipped": False, "arch": "z"})
    _write(d, "d.json", {"skipped": True, "arch": "w", "reason": "r"})

    default = R.load(d, tag="")
    assert {r["arch"] for r in default} == {"x", "w"}
    exp2 = R.load(d, tag="exp2")
    assert {r["arch"] for r in exp2} == {"y", "z"}


def _rec(exposed=None):
    rec = {
        "arch": "a", "shape": "train_4k", "multi_pod": False,
        "skipped": False, "flops": 1e15, "traffic_bytes": 1e12,
        "collectives": {"all-gather": 1e9, "all-gather_count": 4},
        "memory": {"peak_bytes": 2 ** 30},
        "active_params_B": 1.0, "mesh": {"data": 16, "model": 16},
    }
    if exposed is not None:
        rec["collective_exposed_fraction"] = exposed
    return rec


def test_terms_carries_exposed_fraction():
    assert R.terms(_rec(0.25))["exposed_fraction"] == 0.25
    # records predating the auditor read as None and format as "-"
    t = R.terms(_rec())
    assert t["exposed_fraction"] is None
    assert R._fmt_exposed(t) == "-"
    assert R._fmt_exposed(R.terms(_rec(0.5))) == "0.50"


def test_fmt_row_has_exposed_column():
    row = R.fmt_row(_rec(0.37))
    assert "| 0.37 |" in row
    assert row.count("|") == R.HEADER.splitlines()[0].count("|")
