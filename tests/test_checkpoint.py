"""Checkpoint: atomic save/load + elastic re-sharding via logical layout."""
import os
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models.sharding import (ShardCtx, logical_to_storage,
                                   storage_to_logical, logical_shape)
from repro.models import transformer as T
from repro.train import checkpoint as C


def test_save_load_roundtrip(tmp_path):
    tree = {"a": {"b": np.arange(6.0).reshape(2, 3)}, "c": np.ones((4,))}
    C.save(str(tmp_path), 7, tree, {"arch": "x"})
    got, meta = C.load(str(tmp_path))
    assert meta["step"] == 7 and meta["arch"] == "x"
    np.testing.assert_array_equal(got["a"]["b"], tree["a"]["b"])


def test_keep_k_gc(tmp_path):
    for s in range(5):
        C.save(str(tmp_path), s, {"x": np.ones(2)}, {}, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("04")


def test_elastic_reshard_tp1_to_tp4():
    """Storage layout round-trips through logical across different tp/dp —
    restoring onto a different mesh (elastic scaling)."""
    cfg = registry.smoke_config("qwen3-32b")
    ctx1 = ShardCtx(tp=1, dp=1)
    ctx4 = ShardCtx(tp=4, dp=2)
    m1 = T.all_metas(cfg, ctx1)["layers"]
    m4 = T.all_metas(cfg, ctx4)["layers"]
    for name in m1:
        shp = logical_shape(m1[name], ctx1)
        x = jax.random.normal(jax.random.PRNGKey(hash(name) % 2**31), shp)
        st1 = logical_to_storage(x, m1[name], ctx1)
        back1 = storage_to_logical(st1, m1[name], ctx1)
        np.testing.assert_allclose(np.asarray(back1), np.asarray(x), rtol=1e-6)
        # cross-shard: logical -> tp4 storage -> logical
        st4 = logical_to_storage(x, m4[name], ctx4)
        back4 = storage_to_logical(st4, m4[name], ctx4)
        np.testing.assert_allclose(np.asarray(back4), np.asarray(x), rtol=1e-6,
                                   err_msg=name)


def test_yi_partial_replication_roundtrip():
    cfg = registry.smoke_config("yi-34b")      # 6 heads: repl path on tp=4
    ctx = ShardCtx(tp=4, dp=2)
    metas = T.all_metas(cfg, ctx)["layers"]
    wq = metas["wq"]
    assert wq.tp_repl == 2
    shp = logical_shape(wq, ctx)
    x = jax.random.normal(jax.random.PRNGKey(0), shp)
    st = logical_to_storage(x, wq, ctx)
    back = storage_to_logical(st, wq, ctx)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)
