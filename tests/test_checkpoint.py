"""Checkpoint: atomic save/load + elastic re-sharding via logical layout."""
import os
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models.sharding import (ShardCtx, logical_to_storage,
                                   storage_to_logical, logical_shape)
from repro.models import transformer as T
from repro.train import checkpoint as C


def test_save_load_roundtrip(tmp_path):
    tree = {"a": {"b": np.arange(6.0).reshape(2, 3)}, "c": np.ones((4,))}
    C.save(str(tmp_path), 7, tree, {"arch": "x"})
    got, meta = C.load(str(tmp_path))
    assert meta["step"] == 7 and meta["arch"] == "x"
    np.testing.assert_array_equal(got["a"]["b"], tree["a"]["b"])


def test_keep_k_gc(tmp_path):
    for s in range(5):
        C.save(str(tmp_path), s, {"x": np.ones(2)}, {}, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("04")


def test_elastic_reshard_tp1_to_tp4():
    """Storage layout round-trips through logical across different tp/dp —
    restoring onto a different mesh (elastic scaling)."""
    cfg = registry.smoke_config("qwen3-32b")
    ctx1 = ShardCtx(tp=1, dp=1)
    ctx4 = ShardCtx(tp=4, dp=2)
    m1 = T.all_metas(cfg, ctx1)["layers"]
    m4 = T.all_metas(cfg, ctx4)["layers"]
    for name in m1:
        shp = logical_shape(m1[name], ctx1)
        x = jax.random.normal(jax.random.PRNGKey(hash(name) % 2**31), shp)
        st1 = logical_to_storage(x, m1[name], ctx1)
        back1 = storage_to_logical(st1, m1[name], ctx1)
        np.testing.assert_allclose(np.asarray(back1), np.asarray(x), rtol=1e-6)
        # cross-shard: logical -> tp4 storage -> logical
        st4 = logical_to_storage(x, m4[name], ctx4)
        back4 = storage_to_logical(st4, m4[name], ctx4)
        np.testing.assert_allclose(np.asarray(back4), np.asarray(x), rtol=1e-6,
                                   err_msg=name)


def test_yi_partial_replication_roundtrip():
    cfg = registry.smoke_config("yi-34b")      # 6 heads: repl path on tp=4
    ctx = ShardCtx(tp=4, dp=2)
    metas = T.all_metas(cfg, ctx)["layers"]
    wq = metas["wq"]
    assert wq.tp_repl == 2
    shp = logical_shape(wq, ctx)
    x = jax.random.normal(jax.random.PRNGKey(0), shp)
    st = logical_to_storage(x, wq, ctx)
    back = storage_to_logical(st, wq, ctx)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)


def test_reshard_anchor_replicated_to_sharded():
    """Old checkpoints hold replicated (L?, m) anchors; the sharded layout
    wants (L?, tp, dp, shard) with m = dp * shard — reshard_anchor slices
    the vector and broadcasts over tp, bitwise preserving the values."""
    m, tp, dp = 32, 2, 4
    shard = m // dp
    flat = np.arange(m, dtype=np.float32)
    out = C.reshard_anchor(flat, (tp, dp, shard))
    assert out.shape == (tp, dp, shard)
    for t in range(tp):
        for d in range(dp):
            np.testing.assert_array_equal(out[t, d],
                                          flat[d * shard:(d + 1) * shard])
    # scanned leaf: leading L dim passes through
    L = 3
    stacked = np.stack([flat + 100 * i for i in range(L)])
    out_l = C.reshard_anchor(stacked, (L, tp, dp, shard))
    assert out_l.shape == (L, tp, dp, shard)
    np.testing.assert_array_equal(out_l[2, 1, 3],
                                  stacked[2, 3 * shard:])


def test_reshard_anchor_passthrough_on_mismatch():
    """Already-sharded or genuinely incompatible anchors pass through
    untouched (the trainer's elastic fresh-init fallback handles them)."""
    a = np.ones((2, 4, 8), np.float32)
    assert C.reshard_anchor(a, (2, 4, 8)) is a            # already matches
    b = np.ones((33,), np.float32)                        # m != dp * shard
    assert C.reshard_anchor(b, (2, 4, 8)) is b


def test_reshard_y_rewrites_only_anchor_leaves():
    m, tp, dp = 16, 1, 2
    shard = m // dp
    old = {"layers": {"wq": {"y": np.ones((3,)),
                             "anchor": np.arange(m, dtype=np.float32)}},
           "top": {"head": np.zeros((5,))}}
    target = {"layers": {"wq": {"y": np.ones((3,)),
                                "anchor": np.zeros((tp, dp, shard))}},
              "top": {"head": np.zeros((5,))}}
    out = C.reshard_y(old, target)
    assert out["layers"]["wq"]["anchor"].shape == (tp, dp, shard)
    np.testing.assert_array_equal(out["layers"]["wq"]["y"], old["layers"]["wq"]["y"])
    np.testing.assert_array_equal(out["top"]["head"], old["top"]["head"])
