"""Error detection / RobustAgreement (paper §5, Theorem 4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.error_detect import (DetectingEncoder, robust_agreement,
                                     checksum_weights)


def test_checksum_detects_wrapped_decode():
    d, q, y = 128, 8, 1.0
    key = jax.random.PRNGKey(0)
    w = checksum_weights(key, d)
    enc = DetectingEncoder(q=q)
    x = jax.random.normal(key, (d,)) * 5
    payload = enc.encode(x, y, w, key=jax.random.PRNGKey(1))
    # near anchor: decode ok
    z, ok = enc.decode(payload, x + 0.1 * y, y, w)
    assert bool(ok)
    # far anchor: wrapped decode must be FLAGGED, not silent
    z2, ok2 = enc.decode(payload, x + 50 * y, y, w)
    assert not bool(ok2)


def test_robust_agreement_escalates_until_success():
    d = 64
    key = jax.random.PRNGKey(3)
    xu = jax.random.normal(key, (d,)) * 10
    xv = xu + jax.random.normal(jax.random.PRNGKey(4), (d,)) * 0.5
    y_true = float(2 * jnp.max(jnp.abs(xu - xv)))
    # correct estimate: one iteration
    r1 = robust_agreement(xu, xv, y_true, 16, jax.random.PRNGKey(5))
    assert r1["ok"] and r1["iters"] == 1
    # 100x underestimate: must escalate yet still converge, with more bits
    r2 = robust_agreement(xu, xv, y_true / 100, 16, jax.random.PRNGKey(6))
    assert r2["ok"] and r2["iters"] > 1
    assert r2["bits"] > r1["bits"]
    # and the final estimate is accurate (fine lattice from the underestimate)
    assert float(jnp.max(jnp.abs(r2["z"] - xu))) < y_true


def test_expected_bits_match_theorem4_shape():
    """bits ~ O(d log q) when the estimate is right; grows by ~d per doubling."""
    d, q = 256, 16
    xu = jnp.ones((d,))
    xv = xu + 0.01
    r = robust_agreement(xu, xv, 1.0, q, jax.random.PRNGKey(0))
    assert r["bits"] <= d * 4 + 32 + 64
