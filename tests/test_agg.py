"""repro.agg: wire-codec fuzzing + rejection, batched-decode parity and
single-dispatch guarantees, server determinism/escalation, and the >=512-
client simulation round (ISSUE 3 acceptance).  The server-vs-star bit-parity
check runs on 8 emulated devices in a subprocess (XLA_FLAGS must be set
before jax initializes), like tests/test_multidevice.py."""
import dataclasses
import os
import struct
import subprocess
import sys
import textwrap
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import rounds, sim
from repro.agg.transport import frame as wire
from repro.agg.client import AggClient
from repro.agg.server import AggServer
from repro.core import lattice as L
from repro.dist.collectives import QSyncConfig
from repro.kernels import ops as K
from repro.kernels import ref


def _spec(d=2048, q=16, bucket=256, rotate=False, y0=1.0, seed=3,
          round_id=7, max_attempts=4):
    return wire.RoundSpec(round_id=round_id, d=d,
                          cfg=QSyncConfig(q=q, bucket=bucket, rotate=rotate),
                          y0=y0, seed=seed, max_attempts=max_attempts)


# ---------------------------------------------------------------------------
# Wire codec: round-trip fuzz + rejection of damaged frames
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,q,bucket", [
    (2048, 16, 256),      # aligned
    (1000, 16, 128),      # odd d, partial final bucket
    (4096, 256, 512),     # 8-bit colors
    (96, 2, 32),          # 1-bit colors packed at 2 bits, tiny buckets
    (5000, 65536, 1024),  # the q cap (16-bit colors)
])
def test_wire_roundtrip_fuzz(d, q, bucket):
    rng = np.random.RandomState(d + q)
    spec = wire.RoundSpec(round_id=rng.randint(1 << 31), d=d,
                          cfg=QSyncConfig(q=min(q, 256), bucket=bucket),
                          seed=rng.randint(1 << 31))
    nb = spec.nb
    nw = L.packed_len(spec.padded, L.bits_for_q(q))
    for trial in range(5):
        words = rng.randint(0, 1 << 32, nw, dtype=np.uint64).astype(np.uint32)
        sides = rng.rand(nb).astype(np.float32) + 1e-3
        check = int(rng.randint(0, 1 << 32, dtype=np.uint64))
        attempt = int(rng.randint(0, 4))
        cid = int(rng.randint(0, 1 << 31))
        data = wire.encode_payload(spec, cid, attempt, q, words, sides, check)
        assert len(data) == 76 + 4 * nw + 4 * nb      # 72B header + 4B CRC
        if attempt == 0 and q == spec.cfg.q:
            assert len(data) == wire.payload_bytes(spec, 0)
        p = wire.decode_payload(data)
        assert (p.round_id, p.client_id, p.attempt, p.q) == \
            (spec.round_id, cid, attempt, q)
        assert (p.d, p.bucket, p.seed, p.rotate) == \
            (d, bucket, spec.seed, False)
        assert p.check == check
        np.testing.assert_array_equal(p.words, words)
        np.testing.assert_array_equal(p.sides, sides)


def _payload():
    spec = _spec()
    x = np.random.RandomState(0).randn(spec.d).astype(np.float32)
    return spec, AggClient(spec, 5, x).payload()


def test_wire_rejects_truncation():
    _, data = _payload()
    for cut in (0, 10, 51, 75, 76, len(data) - 1):
        with pytest.raises(wire.TruncatedPayloadError):
            wire.decode_payload(data[:cut])


def test_wire_rejects_trailing_garbage():
    _, data = _payload()
    with pytest.raises(wire.CorruptPayloadError):
        wire.decode_payload(data + b"\x00")


def test_wire_rejects_corruption():
    _, data = _payload()
    rng = np.random.RandomState(1)
    for _ in range(20):                       # random single-byte flips
        b = bytearray(data)
        b[rng.randint(4, len(b))] ^= 1 + rng.randint(255)
        with pytest.raises(wire.WireError):
            wire.decode_payload(bytes(b))


def test_wire_rejects_bad_magic_and_version():
    _, data = _payload()
    with pytest.raises(wire.BadMagicError):
        wire.decode_payload(b"XXXX" + data[4:])
    bad = bytearray(data)
    bad[4:6] = struct.pack("<H", wire.WIRE_VERSION + 1)
    with pytest.raises(wire.VersionMismatchError):
        wire.decode_payload(bytes(bad))


def test_wire_rejects_inconsistent_header():
    spec, data = _payload()
    # lie about n_words (offset 40 in the 72-byte header), recomputing the
    # CRC so only the header consistency check can catch it
    b = bytearray(data)
    b[40:44] = struct.pack("<I", 7)
    body = bytes(b[76:])
    crc = zlib.crc32(body, zlib.crc32(bytes(b[:72])))
    b[72:76] = struct.pack("<I", crc)
    with pytest.raises(wire.CorruptPayloadError):
        wire.decode_payload(bytes(b))


def test_wire_rejects_anchored_flag_digest_mismatch():
    """The anchored flag and the anchor digest must agree: a digest with no
    flag (or vice versa) is a corrupt header even if the CRC is fixed up."""
    spec, data = _payload()
    b = bytearray(data)
    b[52:56] = struct.pack("<I", 0xDEADBEEF)      # digest without the flag
    body = bytes(b[76:])
    crc = zlib.crc32(body, zlib.crc32(bytes(b[:72])))
    b[72:76] = struct.pack("<I", crc)
    with pytest.raises(wire.CorruptPayloadError):
        wire.decode_payload(bytes(b))


def test_check_against_spec_mismatches():
    spec, data = _payload()
    p = wire.decode_payload(data)
    wire.check_against_spec(p, spec)          # no raise
    for other in (dataclasses.replace(spec, round_id=8),
                  dataclasses.replace(spec, d=1024),
                  dataclasses.replace(spec, seed=99),
                  dataclasses.replace(spec, y0=5.0),   # sides != round s0
                  dataclasses.replace(spec,
                                      cfg=QSyncConfig(q=16, bucket=512))):
        with pytest.raises(wire.HeaderMismatchError):
            wire.check_against_spec(p, other)


def test_server_rejects_y0_mismatched_client():
    """A client built against a different y0 encodes on a different lattice;
    its checksum is self-consistent, so only the sidecar-vs-round-s0 check
    keeps it from silently corrupting the mean."""
    spec = _spec(y0=1.0)
    x = np.random.RandomState(0).randn(spec.d).astype(np.float32)
    server = AggServer(spec, x)
    bad = AggClient(dataclasses.replace(spec, y0=5.0), 1, x)
    r = wire.decode_response(server.receive(bad.payload()))
    assert r.status == wire.STATUS_REJECT
    assert server.stats.rejected_spec == 1


def test_response_roundtrip_and_crc():
    r = wire.Response(status=wire.STATUS_NACK, round_id=7, client_id=12,
                      attempt_next=2, q_next=65536, y_next=3.5)
    data = wire.encode_response(r)
    assert wire.decode_response(data) == r
    bad = bytearray(data)
    bad[8] ^= 0xFF
    with pytest.raises(wire.CorruptPayloadError):
        wire.decode_response(bytes(bad))


def test_escalation_schedule():
    assert [wire.q_at_attempt(16, a) for a in range(4)] == \
        [16, 256, 65536, 65536]
    spec = _spec(q=16, y0=1.0)
    assert spec.side == pytest.approx(2.0 / 15.0)
    # margins grow like (q_a - 1) * s0 / 2 with s0 fixed
    ys = [wire.y_at_attempt(spec, a) for a in range(3)]
    assert ys[0] == pytest.approx(1.0)
    assert ys[1] == pytest.approx((256 - 1) / 15.0)
    assert ys[2] == pytest.approx((65536 - 1) / 15.0)
    assert wire.payload_bytes(spec, 1) > wire.payload_bytes(spec, 0)


# ---------------------------------------------------------------------------
# Batched decode: bit-parity with the per-sender kernel and the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,q,S", [
    (5000, 16, 6),        # odd n
    (4096, 256, 17),      # 8-bit colors, sender count not a block multiple
    (2048, 16, 1),        # single sender
    (1024, 65536, 3),     # 16-bit colors (the escalation cap)
])
def test_batched_decode_parity(n, q, S):
    bits = L.bits_for_q(q)
    anchor = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 10
    u = L.shared_offset(jax.random.PRNGKey(1), (n,))
    xs = anchor[None] + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                                 (S, n))
    sides = jnp.stack([jnp.full((n,), 0.01 * (i + 1)) for i in range(S)])
    words = jnp.stack([K.lattice_encode(xs[i], u, sides[i], q=q)
                       for i in range(S)])
    for mode in ("coords", "point"):
        kb = K.lattice_decode_batched(words, anchor, u, sides, q=q,
                                      mode=mode)
        kr = ref.lattice_decode_batched_ref(words, anchor, u, sides, q=q,
                                            bits=bits, n=n, mode=mode)
        kloop = jnp.stack([K.lattice_decode(words[i], anchor, u, sides[i],
                                            q=q, mode=mode)
                           for i in range(S)])
        np.testing.assert_array_equal(np.asarray(kb), np.asarray(kr))
        np.testing.assert_array_equal(np.asarray(kb), np.asarray(kloop))


def test_star_collective_single_batched_dispatch():
    """allgather_allreduce_mean's packed path must issue exactly one
    (batched) decode launch, not one per sender."""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import allgather_allreduce_mean
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = QSyncConfig(q=16, bucket=256, packed=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (512,))
    y_b = jnp.full((2,), 1.0)

    @partial(jax.shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
             check_vma=False)
    def f(xl):
        out, _ = allgather_allreduce_mean(xl, y_b, jax.random.PRNGKey(7),
                                          "data", cfg)
        return out

    K.reset_dispatch_counts()
    jax.jit(f).lower(x)                      # trace: wrappers run once
    assert K.DISPATCH_COUNTS["lattice_decode_batched"] == 1
    assert K.DISPATCH_COUNTS["lattice_decode"] == 0


def test_server_drain_single_batched_dispatch():
    # a d/bucket/sender-count combination no other test uses, so the jitted
    # drain must trace here — and the trace issues exactly one batched
    # decode launch for the whole pending set
    spec = _spec(d=2560, bucket=256)
    rng = np.random.RandomState(0)
    base = rng.randn(spec.d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(40, spec.d).astype(np.float32)
    payloads = sim.fleet_payloads(spec, xs)
    server = AggServer(spec, base)
    for p in payloads:
        server.receive(p)
    K.reset_dispatch_counts()
    server.drain()
    assert K.DISPATCH_COUNTS["lattice_decode_batched"] == 1
    assert K.DISPATCH_COUNTS["lattice_decode"] == 0
    assert sorted(server.accepted_clients) == list(range(40))
    # drain sizes are padded to the kernel's sender-block multiple, so a
    # nearby client count reuses the compiled drain (no retrace at all)
    server2 = AggServer(spec, base)
    for p in payloads[:39]:
        server2.receive(p)
    K.reset_dispatch_counts()
    server2.drain()
    assert K.DISPATCH_COUNTS["lattice_decode_batched"] == 0
    assert sorted(server2.accepted_clients) == list(range(39))


# ---------------------------------------------------------------------------
# Server semantics
# ---------------------------------------------------------------------------

def _fleet(spec, S, seed=0, spread=0.02):
    rng = np.random.RandomState(seed)
    base = rng.randn(spec.d).astype(np.float32)
    xs = base[None] + spread * rng.randn(S, spec.d).astype(np.float32)
    return base, xs, sim.fleet_payloads(spec, xs)


def test_server_mean_invariant_to_arrival_order_and_drain_batching():
    spec = _spec(d=2048, bucket=256)
    base, xs, payloads = _fleet(spec, 24)
    means = []
    for order_seed, drain_every in ((0, 100), (1, 5), (2, 1)):
        server = AggServer(spec, base)
        order = np.random.RandomState(order_seed).permutation(24)
        for j, i in enumerate(order):
            server.receive(payloads[i])
            if (j + 1) % drain_every == 0:
                server.drain()
        means.append(server.finalize()[0])
    assert np.array_equal(means[0], means[1])
    assert np.array_equal(means[0], means[2])
    exact = xs.astype(np.float64).mean(0)
    assert float(np.abs(means[0] - exact).max()) <= spec.y0


def test_server_duplicates_never_double_count():
    spec = _spec(d=1024, bucket=128)
    base, xs, payloads = _fleet(spec, 8)
    server = AggServer(spec, base)
    for p in payloads:
        server.receive(p)
    server.drain()
    for p in payloads[:5]:                  # post-accept duplicates: ACKed
        r = wire.decode_response(server.receive(p))
        assert r.status == wire.STATUS_ACK
    server.receive(payloads[6])             # pre-drain duplicate window
    mean, stats = server.finalize()
    ref_server = AggServer(spec, base)
    for p in payloads:
        ref_server.receive(p)
    mean_ref, _ = ref_server.finalize()
    assert np.array_equal(mean, mean_ref)
    assert stats.duplicates == 6
    assert stats.accepted == 8


def test_server_escalation_recovers_and_gives_up():
    spec = _spec(d=1024, bucket=128, y0=1.0, max_attempts=4)
    rng = np.random.RandomState(0)
    base = rng.randn(spec.d).astype(np.float32)
    clients = {
        0: AggClient(spec, 0, base + 0.01),
        1: AggClient(spec, 1, base + 8.0),     # needs q=256 (margin 17*y0)
        2: AggClient(spec, 2, base + 1e6),     # beyond the q cap: dropped
    }
    server = AggServer(spec, base)
    for c in clients.values():
        server.receive(c.payload())
    resps = server.drain()
    while resps:
        retries = [p for rb in resps
                   for p in clients[wire.decode_response(rb).client_id]
                   .handle_response(rb)]
        if not retries:
            break
        for p in retries:
            server.receive(p)
        resps = server.drain()
    mean, stats = server.finalize()
    assert sorted(server.accepted_clients) == [0, 1]
    assert clients[1].attempt == 1 and not clients[1].gave_up
    assert clients[2].gave_up and stats.gave_up == 1
    assert stats.decode_failures >= 2 and stats.nacks_sent >= 1
    exact = (np.asarray(base + 0.01, np.float64)
             + np.asarray(base + 8.0, np.float64)) / 2
    # attempt-1 margin is ~17*y0; the lattice cell is still s0
    assert float(np.abs(mean - exact).max()) <= spec.y0


def test_server_zero_accepts_returns_zeros():
    spec = _spec(d=512, bucket=64)
    server = AggServer(spec, np.zeros(512, np.float32))
    mean, stats = server.finalize()
    assert mean.shape == (512,)
    assert np.all(mean == 0) and stats.accepted == 0


def test_client_payload_matches_fleet_encoder():
    for rotate in (False, True):
        spec = _spec(d=1000, bucket=128, rotate=rotate)
        _, xs, payloads = _fleet(spec, 4)
        assert AggClient(spec, 2, xs[2]).payload() == payloads[2]


def test_client_handles_ack_nack_reject():
    spec = _spec(max_attempts=3)
    x = np.zeros(spec.d, np.float32)
    c = AggClient(spec, 9, x)

    def resp(status, attempt_next=0, nb=None):
        nb = spec.nb if nb is None else nb
        return wire.encode_response(wire.Response(
            status=status, round_id=spec.round_id, client_id=9,
            attempt_next=attempt_next,
            q_next=wire.q_at_attempt(16, attempt_next),
            y_next=wire.y_at_attempt(spec, attempt_next),
            y_buckets=tuple(
                float(v) for v in
                wire.y_buckets_at_attempt(spec, attempt_next))[:nb]))

    assert c.handle_response(resp(wire.STATUS_ACK)) == [] and c.acked
    c.acked = False
    retry = c.handle_response(resp(wire.STATUS_NACK, 1))
    assert len(retry) == 1 and c.attempt == 1
    assert wire.decode_payload(retry[0]).q == 256
    # a duplicated/stale NACK must not flip gave_up: its retry is in flight
    assert c.handle_response(resp(wire.STATUS_NACK, 1)) == []
    assert not c.gave_up and c.attempt == 1
    assert c.handle_response(resp(wire.STATUS_NACK, 3)) == []  # >= max
    assert c.gave_up


def test_client_rejects_nack_with_wrong_y_vector_length():
    """ISSUE 4 satellite fix: a NACK whose per-bucket y vector length does
    not match the round's nb is corrupt — the client re-sends its current
    payload instead of truncating/broadcasting and escalating off it."""
    spec = _spec(max_attempts=4)
    x = np.zeros(spec.d, np.float32)
    c = AggClient(spec, 9, x)
    current = c.payload()

    def nack(attempt_next, nb):
        return wire.encode_response(wire.Response(
            status=wire.STATUS_NACK, round_id=spec.round_id, client_id=9,
            attempt_next=attempt_next,
            q_next=wire.q_at_attempt(16, attempt_next),
            y_next=wire.y_at_attempt(spec, attempt_next),
            y_buckets=(1.0,) * nb))

    for bad_nb in (0, spec.nb - 1, spec.nb + 3):
        out = c.handle_response(nack(1, bad_nb))
        assert out == [current]               # retransmit, don't escalate
        assert c.attempt == 0 and not c.gave_up
    # a well-formed NACK still escalates
    out = c.handle_response(nack(1, spec.nb))
    assert len(out) == 1 and c.attempt == 1


# ---------------------------------------------------------------------------
# The simulation acceptance: >=512 clients with escalation + drops
# ---------------------------------------------------------------------------

def test_sim_512_client_round():
    cfg = sim.SimConfig(clients=512, d=4096, bucket=512, drop=0.02,
                        duplicate=0.05, straggle=0.25, corrupt=2, truncate=1,
                        adversarial=4, extreme=1, seed=0)
    rep = sim.run_round(cfg)
    s = rep.stats
    n_drop = int(round(cfg.drop * cfg.clients))
    assert len(rep.accepted_clients) == cfg.clients - n_drop - cfg.extreme
    assert len(rep.escalated_clients) == cfg.adversarial   # all recovered
    assert s.gave_up == cfg.extreme
    assert s.rejected_wire == cfg.corrupt + cfg.truncate
    assert s.duplicates >= int(round(cfg.duplicate * cfg.clients))
    assert s.drains >= 2                                   # straggler wave
    assert rep.max_err <= 2 * cfg.y0
    # wire cost: ~d/2 bytes at q=16 plus sidecar/header overhead
    assert rep.bytes_per_client < 4 * cfg.d / 7


# ---------------------------------------------------------------------------
# Multi-round anchored service (ISSUE 4): convergence + per-bucket y
# ---------------------------------------------------------------------------

def test_per_bucket_y_uniform_matches_scalar_y_bitwise():
    """RoundSpec v2 with y_buckets=(y0,)*nb must produce bit-identical
    payloads, responses and round mean as the scalar-y0 spec."""
    base_spec = _spec(d=2048, bucket=256, y0=0.75)
    vec_spec = dataclasses.replace(
        base_spec, y_buckets=(0.75,) * base_spec.nb)
    rng = np.random.RandomState(0)
    anchor = rng.randn(base_spec.d).astype(np.float32)
    xs = anchor[None] + 0.02 * rng.randn(12, base_spec.d).astype(np.float32)
    p_scalar = sim.fleet_payloads(base_spec, xs)
    p_vec = sim.fleet_payloads(vec_spec, xs)
    assert p_scalar == p_vec
    means = []
    for spec, payloads in ((base_spec, p_scalar), (vec_spec, p_vec)):
        server = AggServer(spec, anchor)
        for p in payloads:
            server.receive(p)
        means.append(server.finalize()[0])
    assert np.array_equal(means[0], means[1])
    # the per-client protocol object agrees too
    assert AggClient(base_spec, 3, xs[3]).payload() == \
        AggClient(vec_spec, 3, xs[3]).payload()


def test_server_rejects_anchor_digest_mismatch():
    """An anchored round REJECTs payloads built against a different anchor
    (self-consistent checksum, wrong lattice frame)."""
    rng = np.random.RandomState(0)
    d = 1024
    anchor = rng.randn(d).astype(np.float32)
    stale = anchor + 1.0
    spec = wire.RoundSpec(round_id=3, d=d,
                          cfg=QSyncConfig(q=16, bucket=128), y0=1.0,
                          anchor_digest=rounds.anchor_digest(anchor))
    stale_spec = dataclasses.replace(
        spec, anchor_digest=rounds.anchor_digest(stale))
    server = AggServer(spec, anchor)
    bad = AggClient(stale_spec, 1, anchor + 0.01, anchor=stale)
    r = wire.decode_response(server.receive(bad.payload()))
    assert r.status == wire.STATUS_REJECT
    assert server.stats.rejected_spec == 1
    # constructing a client/server with the wrong anchor vector raises
    with pytest.raises(ValueError):
        AggClient(spec, 2, anchor + 0.01, anchor=stale)
    with pytest.raises(ValueError):
        AggServer(spec, stale)


def test_multi_round_convergence_256_clients():
    """ISSUE 4 satellite: 256 clients, 8 anchored rounds over a
    concentrating population — per-round MSE shrinks as the tracked
    per-bucket y tightens, and the anchor digest chain holds."""
    cfg = sim.MultiRoundConfig(clients=256, d=1024, bucket=128, rounds=8,
                               anchored=True, norm_scale=100.0, y0=1.0,
                               spread0=0.3, concentrate=0.6, y_decay=0.5,
                               drift=0.0, seed=1)
    outs = sim.run_rounds(cfg)
    assert len(outs) == 8
    assert all(o.accepted == cfg.clients for o in outs)
    # inputs concentrate => the tracked y tightens round over round once
    # the round-1 escalation transient settles, and MSE comes down with it:
    # strictly decreasing over the closing rounds and well below the peak
    assert outs[-1].y_mean < 0.5 * max(o.y_mean for o in outs)
    mses = [o.mse for o in outs]
    assert mses[-1] < mses[-2] < mses[-3], [f"{m:.3e}" for m in mses]
    assert mses[-1] < 0.5 * max(mses), [f"{m:.3e}" for m in mses]
    # every anchored round pins a (changing) anchor digest
    assert all(o.anchor_digest != 0 for o in outs)
    assert outs[0].anchor_digest != outs[1].anchor_digest


def test_multi_round_anchored_beats_unanchored_at_equal_bytes():
    """The acceptance criterion's protocol-level form: over a drifting
    large-norm population, anchored rounds achieve strictly lower MSE than
    unanchored rounds at identical attempt-0 wire bytes."""
    kw = dict(clients=32, d=2048, bucket=256, rounds=4, norm_scale=1e6,
              y0=0.5, spread0=0.05, concentrate=0.7, seed=0)
    anchored = sim.run_rounds(sim.MultiRoundConfig(anchored=True, **kw))
    plain = sim.run_rounds(sim.MultiRoundConfig(anchored=False, **kw))
    for a, u in zip(anchored, plain):
        assert a.bytes_per_client == u.bytes_per_client
        assert a.mse < u.mse, (a.round_id, a.mse, u.mse)


def test_server_overflow_guard_unanchored_large_norm():
    """Unanchored huge-norm rounds produce raw coords ~|x|/s; enough
    accepted senders would wrap the int32 accumulator — the server must
    fail loudly (pointing at anchoring) instead of silently corrupting the
    mean.  The equivalent anchored round accumulates fine."""
    rng = np.random.RandomState(0)
    d, bucket, S = 512, 64, 40
    mu = 2e6 * np.abs(rng.randn(d)).astype(np.float32) + 1e6
    xs = mu[None] + 0.01 * rng.randn(S, d).astype(np.float32)
    spec = wire.RoundSpec(round_id=1, d=d,
                          cfg=QSyncConfig(q=16, bucket=bucket), y0=0.5)
    # coords ~ |mu|/s ~ 1e6/(1/15) = 1.5e7..4.5e7; 40 senders * 4.5e7 > 2^31
    server = AggServer(spec, mu)
    with pytest.raises(OverflowError, match="anchor the round"):
        for p in sim.fleet_payloads(spec, xs):
            server.receive(p)
        server.finalize()
    a_spec = dataclasses.replace(spec,
                                 anchor_digest=rounds.anchor_digest(mu))
    a_server = AggServer(a_spec, mu)
    for p in sim.fleet_payloads(a_spec, xs, anchor=mu):
        a_server.receive(p)
    mean, stats = a_server.finalize()
    assert stats.accepted == S
    exact = xs.astype(np.float64).mean(0)
    assert float(np.abs(mean - exact).max()) <= 2 * spec.y0


def test_service_anchor_chain_digests():
    """Round k+1's spec digest == digest of round k's published mean."""
    from repro.agg.service import AggService, ServiceConfig
    rng = np.random.RandomState(0)
    d = 512
    svc = AggService(ServiceConfig(d=d, bucket=64, y0=1.0),
                     anchor0=np.zeros(d, np.float32))
    means = []
    for _ in range(3):
        spec, anchor = svc.begin_round()
        if means:
            assert spec.anchor_digest == rounds.anchor_digest(means[-1])
        server = svc.make_server()
        xs = 0.1 * rng.randn(4, d).astype(np.float32)
        if anchor is not None:
            xs = xs + anchor[None]
        for i, p in enumerate(sim.fleet_payloads(spec, xs, anchor=anchor)):
            server.receive(p)
        mean, _ = svc.end_round(server)
        means.append(mean)


# ---------------------------------------------------------------------------
# Server mean == star collective, bit for bit (8 emulated devices)
# ---------------------------------------------------------------------------

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_8dev(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_server_mean_bit_identical_to_star_8dev():
    """ISSUE 3 acceptance: the aggregation server's round mean equals
    allgather_allreduce_mean bitwise for the same inputs/seeds (rotated and
    unrotated), invariant to client arrival order — and (ISSUE 5) the
    mtu-chunked transport is bit-identical to both: the same round carried
    as out-of-order interleaved chunk frames yields the same mean — as does
    (v5) the streaming server folding credit-windowed chunk ranges on
    arrival."""
    out = _run_8dev("""
        import dataclasses
        from functools import partial
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import (QSyncConfig,
            allgather_allreduce_mean, flat_size_padded)
        from repro.agg import rounds
        from repro.agg.transport import frame as wire
        from repro.agg.client import AggClient
        from repro.agg.server import AggServer
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        for rotate in (False, True):
            n, bucket = 8192, 1024
            cfg = QSyncConfig(q=16, bucket=bucket, rotate=rotate)
            spec = wire.RoundSpec(round_id=11, d=n, cfg=cfg, y0=2.0, seed=5)
            base = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 50.0
            xs = base + 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                                 (8, n))
            nb = flat_size_padded(n, cfg) // bucket
            y_b = jnp.full((nb,), spec.y0)
            key = rounds.round_key(spec)
            @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"), check_vma=False)
            def f(xl):
                out, _ = allgather_allreduce_mean(xl.reshape(-1), y_b, key,
                                                  "data", cfg)
                return out.reshape(1, -1)
            star = np.asarray(jax.jit(f)(xs))
            assert np.all(star == star[0]), rotate
            server = AggServer(spec, np.asarray(xs[3]))
            for i in np.random.RandomState(1).permutation(8):
                server.receive(AggClient(spec, int(i),
                                         np.asarray(xs[i])).payload())
            mean, _ = server.finalize()
            assert np.array_equal(mean, star[0]), rotate
            # the same round over the chunked transport (>= 4 chunks per
            # client, frames interleaved across clients and shuffled)
            cspec = dataclasses.replace(spec, mtu=1024)
            frames = [(int(i), f) for i in range(8)
                      for f in AggClient(cspec, int(i),
                                         np.asarray(xs[i])).frames()]
            assert len(frames) >= 4 * 8, len(frames)
            cserver = AggServer(cspec, np.asarray(xs[3]))
            for j in np.random.RandomState(2).permutation(len(frames)):
                cserver.receive(frames[int(j)][1])
            cmean, cstats = cserver.finalize()
            assert cstats.accepted == 8, cstats
            assert np.array_equal(cmean, star[0]), rotate
            # (v5) the same round again through the streaming server:
            # credit-windowed clients, ranges folded on arrival — still
            # bit-identical to the star collective
            sspec = dataclasses.replace(spec, mtu=1024, window=2)
            sserver = AggServer(sspec, np.asarray(xs[3]))
            scli = [AggClient(sspec, i, np.asarray(xs[i])) for i in range(8)]
            outbox = [(c, f) for c in scli for f in c.send_frames()]
            while outbox:
                nxt = []
                for c, f in outbox:
                    for rb in sserver.ingest_frame(f):
                        nxt.extend((c, g) for g in c.handle_response(rb))
                outbox = nxt
            assert all(c.acked for c in scli)
            sserver.drain()
            smean, sstats = sserver.finalize()
            assert sstats.accepted == 8, sstats
            assert np.array_equal(smean, star[0]), rotate
            assert sstats.peak_pending_store_bytes < \
                cstats.peak_pending_store_bytes, (sstats, cstats)
        print("SERVER_STAR_PARITY_OK")
    """)
    assert "SERVER_STAR_PARITY_OK" in out


def test_anchored_server_mean_bit_identical_to_anchored_star_8dev():
    """The anchored acceptance: with the same QState anchor (round k-1's
    mean), the v2 server's round mean equals the anchored star collective
    bitwise — in the drifting large-norm regime where the unanchored frames
    could not even represent the coordinates."""
    out = _run_8dev("""
        from functools import partial
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.qstate import QState
        from repro.dist.collectives import (QSyncConfig,
            allgather_allreduce_mean, flat_size_padded)
        from repro.agg import rounds
        from repro.agg.transport import frame as wire
        from repro.agg.client import AggClient
        from repro.agg.server import AggServer
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n, bucket = 8192, 1024
        cfg = QSyncConfig(q=16, bucket=bucket)
        anchor = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (n,)) * 1e6, np.float32)
        spec = wire.RoundSpec(round_id=11, d=n, cfg=cfg, y0=2.0, seed=5,
                              anchor_digest=rounds.anchor_digest(anchor))
        xs = jnp.asarray(anchor) + 0.05 * jax.random.normal(
            jax.random.PRNGKey(1), (8, n))
        nb = flat_size_padded(n, cfg) // bucket
        qs = QState(y=jnp.full((nb,), spec.y0), anchor=jnp.asarray(anchor))
        key = rounds.round_key(spec)
        @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                 out_specs=P("data"), check_vma=False)
        def f(xl):
            out, _ = allgather_allreduce_mean(xl.reshape(-1), qs, key,
                                              "data", cfg)
            return out.reshape(1, -1)
        star = np.asarray(jax.jit(f)(xs))
        assert np.all(star == star[0])
        server = AggServer(spec, anchor)
        for i in np.random.RandomState(1).permutation(8):
            server.receive(AggClient(spec, int(i), np.asarray(xs[i]),
                                     anchor=anchor).payload())
        mean, stats = server.finalize()
        assert stats.accepted == 8, stats
        assert np.array_equal(mean, star[0])
        print("ANCHORED_PARITY_OK")
    """)
    assert "ANCHORED_PARITY_OK" in out


# ---------------------------------------------------------------------------
# Streaming tiers (v5): windowed tree == flat sealed server, bit for bit
# ---------------------------------------------------------------------------

def test_streaming_tree_windowed_bit_identical_to_flat_sealed():
    """A windowed round through a 2-tier AggTree (every edge tier folding
    validated chunk ranges as they land) publishes the same accepted set
    and a bit-identical mean as the flat SEALED server — under a fully
    permuted chunk blast AND under credit-paced windowed clients."""
    from repro.agg.tree import AggTree

    d, n_clients = 2048, 12
    spec = dataclasses.replace(_spec(d=d, seed=11, round_id=9),
                               mtu=300, window=2)
    rng = np.random.RandomState(11)
    base = 2.0 * rng.randn(d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(n_clients, d).astype(np.float32)
    clients = [AggClient(spec, cid, xs[cid]) for cid in range(n_clients)]
    all_frames = [c.frames() for c in clients]
    assert len(all_frames[0]) >= 3

    flat = AggServer(spec, base, streaming=False)
    for fs in all_frames:
        for f in fs:
            flat.ingest_frame(f)
    flat.tick()
    flat.seal()
    pf = flat.published()[0]
    assert len(pf.accepted) == n_clients

    # permuted blast: tiers stream ranges out of order, roll nothing back
    tree = AggTree(spec, base, fanout=4, tiers=2)
    deliveries = [f for fs in all_frames for f in fs]
    for i in rng.permutation(len(deliveries)):
        tree.ingest_frame(deliveries[int(i)])
    tree.tick()
    tree.seal()
    for _ in range(8):
        tree.tick()
        if tree.published():
            break
    pt = tree.published()[0]
    assert pt.accepted == pf.accepted
    assert np.array_equal(np.asarray(pt.mean).view(np.uint32),
                          np.asarray(pf.mean).view(np.uint32))
    assert all(t._streaming for t in tree.layers[0])

    # credit-paced windowed clients against the streaming tree
    tree2 = AggTree(spec, base, fanout=4, tiers=2)
    cl2 = [AggClient(spec, cid, xs[cid]) for cid in range(n_clients)]
    outbox = [(c, f) for c in cl2 for f in c.send_frames()]
    for _ in range(60):
        nxt = []
        for c, f in outbox:
            for rb in tree2.ingest_frame(f):
                nxt.extend((c, g) for g in c.handle_response(rb))
        for m in tree2.tick():
            r = wire.decode_response(m)
            for c in cl2:
                if c.client_id == r.client_id:
                    nxt.extend((c, g) for g in c.handle_response(m))
        outbox = nxt
        if all(c.acked for c in cl2):
            break
    assert all(c.acked for c in cl2)
    tree2.seal()
    for _ in range(8):
        tree2.tick()
        if tree2.published():
            break
    pt2 = tree2.published()[0]
    assert pt2.accepted == pf.accepted
    assert np.array_equal(np.asarray(pt2.mean).view(np.uint32),
                          np.asarray(pf.mean).view(np.uint32))


def test_streaming_server_expire_rolls_back_fold_and_store():
    """expire_client on a half-streamed client drops its speculative fold
    and its held bytes: the published mean is over the others only, and
    the pending store returns to zero."""
    spec = dataclasses.replace(_spec(d=2048, seed=4), mtu=300, window=2)
    rng = np.random.RandomState(0)
    base = rng.randn(spec.d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(3, spec.d).astype(np.float32)
    fleets = [AggClient(spec, i, xs[i]).frames() for i in range(3)]
    server = AggServer(spec, base)
    for f in fleets[0]:
        server.receive(f)
    for f in fleets[1]:
        server.receive(f)
    for f in fleets[2][:2]:                  # client 2: half a stream
        server.receive(f)
    assert server._folds                     # its speculative fold is open
    server.expire_client(2)
    assert not any(k[0] == 2 for k in server._folds)
    server.drain()
    mean, stats = server.finalize()
    assert server.accepted_clients == frozenset({0, 1})
    ref_srv = AggServer(spec, base, streaming=False)
    for f in fleets[0] + fleets[1]:
        ref_srv.receive(f)
    mean_ref, _ = ref_srv.finalize()
    assert np.array_equal(mean.view(np.uint32), mean_ref.view(np.uint32))
