"""Layered transport stack (ISSUE 5): chunk-layer fuzzing, reassembly
state machine, selective retransmit cost, and the one-wire-accounting
cross-checks against actual payload/collective byte sizes."""
import dataclasses
import struct
import zlib

import numpy as np
import pytest

from repro.agg import rounds, sim
from repro.agg.transport import frame as wire
from repro.agg.client import AggClient
from repro.agg.server import AggServer
from repro.agg.transport import chunks as C
from repro.agg.transport import frame as F
from repro.agg.transport import session as S
from repro.core import lattice as L
from repro.core import wire_accounting as WA
from repro.dist.collectives import (QSyncConfig, _payload_bytes,
                                    flat_size_padded, wire_bytes_allgather,
                                    wire_bytes_butterfly, wire_bytes_rh)
from repro.dist.fsdp import FSDPConfig, wire_bytes_bwd


def _spec(d=2048, q=16, bucket=256, mtu=300, y0=1.0, seed=3, round_id=7,
          max_attempts=4, **kw):
    return wire.RoundSpec(round_id=round_id, d=d,
                          cfg=QSyncConfig(q=q, bucket=bucket), y0=y0,
                          seed=seed, max_attempts=max_attempts, mtu=mtu,
                          **kw)


def _fleet(spec, n, seed=0, spread=0.02):
    rng = np.random.RandomState(seed)
    base = rng.randn(spec.d).astype(np.float32)
    xs = base[None] + spread * rng.randn(n, spec.d).astype(np.float32)
    return base, xs, sim.fleet_frames(spec, xs)


# ---------------------------------------------------------------------------
# Wire accounting: the one definition, cross-checked against len()
# ---------------------------------------------------------------------------

def test_agg_payload_bytes_match_actual_frames():
    """payload_bytes == sum(len(frame)) for chunked AND unchunked rounds,
    at every escalation level."""
    for mtu in (0, 300, 1024):
        spec = _spec(d=2000, bucket=256, mtu=mtu)
        x = np.random.RandomState(0).randn(spec.d).astype(np.float32)
        c = AggClient(spec, 1, x)
        for attempt in range(3):
            frames = c.frames(attempt)
            assert sum(len(f) for f in frames) == \
                wire.payload_bytes(spec, attempt), (mtu, attempt)
            assert len(frames) == spec.n_chunks(attempt), (mtu, attempt)


def test_frame_header_constant_matches_struct():
    spec = _spec(mtu=0, d=512, bucket=64)
    x = np.zeros(512, np.float32)
    data = AggClient(spec, 1, x).payload()
    body = WA.packed_body_bytes(spec.padded, spec.cfg.bits, spec.nb)
    assert len(data) == WA.FRAME_HEADER_BYTES + body
    assert WA.frame_bytes(body) == len(data)


def test_chunk_span_geometry():
    assert WA.n_chunks(1000, 0) == 1
    assert WA.n_chunks(1000, 300) == 4
    assert WA.n_chunks(900, 300) == 3
    spans = [WA.chunk_span(1000, 300, i) for i in range(4)]
    assert spans == [(0, 300), (300, 300), (600, 300), (900, 100)]
    assert sum(ln for _, ln in spans) == 1000
    with pytest.raises(ValueError):
        WA.chunk_span(1000, 300, 4)
    assert WA.framed_payload_bytes(1000, 300) == 4 * 76 + 1000
    assert WA.chunk_overhead_pct(1000, 300) == pytest.approx(
        100.0 * 3 * 76 / 1076)


def test_collective_accounting_delegates_to_wire_accounting():
    """collectives.wire_bytes_* and fsdp.wire_bytes_bwd agree with the
    core.wire_accounting formulas they delegate to."""
    n, world = 5000, 8
    cfg = QSyncConfig(q=16, bucket=512)
    padded = flat_size_padded(n, cfg)
    nb = padded // cfg.bucket
    assert _payload_bytes(n, cfg) == \
        WA.collective_payload_bytes(padded, cfg.bits, nb, True) == \
        L.wire_bytes(padded, cfg.bits) + 4 * nb
    assert wire_bytes_butterfly(n, world, cfg) == \
        WA.butterfly_bytes(padded, cfg.bits, nb, world)
    assert wire_bytes_allgather(n, world, cfg) == \
        WA.allgather_bytes(padded, cfg.bits, nb, world)
    assert wire_bytes_rh(n, world, cfg) == \
        WA.rh_bytes(padded, cfg.bits, nb, world)
    m = 1 << 16
    fp32 = FSDPConfig(sync="fp32")
    assert wire_bytes_bwd(m, [8], fp32) == \
        WA.fp32_ring_reduce_scatter_bytes(m, 8)
    # the agg body is byte-for-byte the collective payload
    spec = _spec(d=n, bucket=512, mtu=0)
    assert spec.body_bytes() == _payload_bytes(n, cfg)


# ---------------------------------------------------------------------------
# Chunk-layer fuzzing: damaged / duplicated / reordered / stale chunks
# ---------------------------------------------------------------------------

def test_chunk_frames_are_self_describing_and_idempotent():
    spec = _spec()
    _, xs, fleets = _fleet(spec, 1)
    frames = fleets[0]
    assert len(frames) == spec.n_chunks() >= 3
    pcrc = None
    for i, f in enumerate(frames):
        h, chunk = wire.decode_frame(f)
        assert (h.n_chunks, h.chunk_index) == (len(frames), i)
        assert h.body_len == spec.body_bytes()
        pcrc = h.payload_crc if pcrc is None else pcrc
        assert h.payload_crc == pcrc            # all chunks seal one body
        wire.check_frame_against_spec(h, spec, len(chunk))
    # re-encoding yields byte-identical frames (idempotent retransmit)
    c = AggClient(spec, 0, np.asarray(xs[0]))
    assert c.frames() == frames


def test_truncated_and_corrupt_chunks_rejected():
    spec = _spec()
    _, _, fleets = _fleet(spec, 1)
    rng = np.random.RandomState(0)
    for f in fleets[0]:
        for cut in (0, 10, 75, 76, len(f) - 1):
            with pytest.raises(wire.WireError):
                wire.decode_frame(f[:cut])
        with pytest.raises(wire.CorruptPayloadError):
            wire.decode_frame(f + b"\\x00")
        for _ in range(10):
            b = bytearray(f)
            b[rng.randint(4, len(b))] ^= 1 + rng.randint(255)
            with pytest.raises(wire.WireError):
                wire.decode_frame(bytes(b))


def test_server_counts_damaged_chunks_as_wire_rejects():
    spec = _spec(d=1024, bucket=128, mtu=200)
    base, _, fleets = _fleet(spec, 2)
    server = AggServer(spec, base)
    bad = bytearray(fleets[0][1])
    bad[-1] ^= 0xFF
    r = wire.decode_response(server.receive(bytes(bad)))
    assert r.status == wire.STATUS_REJECT
    assert server.stats.rejected_wire == 1
    assert server.transport_stats.chunks == 0    # never reached the session


def test_chunk_mtu_geometry_enforced_per_spec():
    """A client chunking with a foreign MTU violates the round contract:
    every frame is self-consistent but n_chunks/chunk length disagree with
    the spec's geometry -> HeaderMismatch, counted as a spec reject."""
    spec = _spec(d=1024, bucket=128, mtu=200)
    foreign = dataclasses.replace(spec, mtu=400)
    base, xs, _ = _fleet(spec, 1)
    server = AggServer(spec, base)
    for f in AggClient(foreign, 0, np.asarray(xs[0])).frames():
        r = wire.decode_response(server.receive(f))
        assert r.status == wire.STATUS_REJECT
    assert server.stats.rejected_spec >= 1
    assert server.transport_stats.chunks == 0


def test_cross_round_stale_chunks_rejected():
    """Chunks of round k must never enter round k+1's reassembly."""
    old = _spec(round_id=7)
    new = dataclasses.replace(old, round_id=8)
    base, xs, old_fleet = _fleet(old, 2)
    server = AggServer(new, base)
    cur = AggClient(new, 0, np.asarray(xs[0]))
    for f in old_fleet[0]:
        rb = server.receive(f)
        r = wire.decode_response(rb)
        assert r.status == wire.STATUS_REJECT
        assert r.round_id == old.round_id    # echoes the stale frame's round
        assert cur.handle_response(rb) == []
        assert not cur.gave_up               # current round unharmed
    assert server.stats.rejected_spec == len(old_fleet[0])
    assert server.transport_stats.chunks == 0
    # the current round's chunks still assemble fine afterwards
    for f in sim.fleet_frames(new, xs)[1]:
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({1})


def test_duplicate_and_reordered_chunks_reassemble():
    spec = _spec(d=2048, bucket=256, mtu=300)
    base, xs, fleets = _fleet(spec, 4)
    # reference: in-order, no duplicates
    ref = AggServer(spec, base)
    for fs in fleets:
        for f in fs:
            ref.receive(f)
    mean_ref, _ = ref.finalize()
    rng = np.random.RandomState(1)
    flat = [(c, k) for c, fs in enumerate(fleets) for k in range(len(fs))]
    # interleave across clients, shuffle order, duplicate ~half the chunks
    order = [flat[i] for i in rng.permutation(len(flat))]
    order += [flat[i] for i in
              rng.choice(len(flat), len(flat) // 2, replace=False)]
    server = AggServer(spec, base)
    for c, k in order:
        server.receive(fleets[c][k])
    mean, stats = server.finalize()
    assert np.array_equal(mean, mean_ref)
    assert stats.accepted == 4
    ts = server.transport_stats
    assert ts.chunks == len(order)       # every frame reached the session
    assert ts.buffer_bytes == 0          # ... and every session was closed
    # duplicate deliveries were absorbed at some layer (identical-index
    # chunks in an open session, or whole-payload dedupe at the server)
    assert ts.duplicates + stats.duplicates > 0 or stats.accepted == 4


def test_any_chunk_arrival_permutation_bit_identical_mean():
    """Property: ANY permutation of the round's chunk frames (interleaved
    across clients, duplicates included) yields a bit-identical mean."""
    spec = _spec(d=1024, bucket=128, mtu=128, seed=11)
    base, _, fleets = _fleet(spec, 3)
    flat = [f for fs in fleets for f in fs]
    means = []
    for trial in range(6):
        rng = np.random.RandomState(trial)
        order = list(rng.permutation(len(flat)))
        if trial % 2:                       # mix in duplicate deliveries
            order += list(rng.choice(len(flat), 5))
        server = AggServer(spec, base)
        for i in order:
            server.receive(flat[i])
        server.drain()
        assert server.accepted_clients == frozenset(range(3)), trial
        means.append(server.finalize()[0])
    for m in means[1:]:
        assert np.array_equal(means[0], m)


def test_chunked_round_bit_identical_to_single_frame_round():
    """The acceptance bit-parity: chunked == v3 single-frame for the same
    inputs/seeds (the 8-dev suite additionally pins both to the star
    collective)."""
    plain = _spec(d=2048, bucket=256, mtu=0)
    chunked = dataclasses.replace(plain, mtu=256)
    base, xs, _ = _fleet(plain, 6)
    means = []
    for spec in (plain, chunked):
        server = AggServer(spec, base)
        for fs in sim.fleet_frames(spec, xs):
            for f in fs:
                server.receive(f)
        mean, stats = server.finalize()
        assert stats.accepted == 6
        means.append(mean)
    assert np.array_equal(means[0], means[1])


def test_conflicting_payload_never_merges():
    """Two CRC-valid chunk streams for the same client with different
    payload bodies must not be spliced together."""
    spec = _spec(d=1024, bucket=128, mtu=200)
    base, xs, fleets = _fleet(spec, 2)
    # re-key client 1's frames as client 0 (a CRC-valid foreign stream)
    foreign = []
    for f in fleets[1]:
        h, chunk = wire.decode_frame(f)
        foreign.append(wire.encode_frame(
            dataclasses.replace(h, client_id=0), chunk))
    server = AggServer(spec, base)
    server.receive(fleets[0][0])
    for f in foreign[1:]:
        r = wire.decode_response(server.receive(f))
        # its own doomed stream, NOT terminal: must not kill client 0
        assert r.status == wire.STATUS_QUEUED
    assert server.transport_stats.conflicts >= 1
    # the original stream still completes
    for f in fleets[0][1:]:
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0})


def test_forged_first_frame_cannot_capture_session():
    """Regression (review finding): a forged frame arriving BEFORE the
    honest client's chunks must not capture the client's reassembly —
    payload_crc keys the streams, so the honest stream merges into its
    own and completes regardless of arrival order."""
    spec = _spec(d=1024, bucket=128, mtu=200)
    base, xs, fleets = _fleet(spec, 2)
    h1, chunk1 = wire.decode_frame(fleets[1][0])
    forged_first = wire.encode_frame(
        dataclasses.replace(h1, client_id=0), chunk1)
    server = AggServer(spec, base)
    server.receive(forged_first)          # imposter opens a doomed stream
    for f in fleets[0]:                   # honest stream still completes
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0})
    assert server.transport_stats.conflicts >= 1


def test_forged_outprogressing_stream_cannot_capture_resend():
    """Regression (review finding): RESEND names the UNION of missing
    indices across a client's open streams — a forged same-attempt stream
    with more progress than the honest one must not monopolize the
    client's RESEND slot (the honest gaps would never be requested)."""
    spec = _spec(d=2048, bucket=256, mtu=300)
    base, xs, fleets = _fleet(spec, 1)
    frames = fleets[0]
    nc = len(frames)
    assert nc >= 4
    lost = {2, 3}
    # forged stream under the same header but a fabricated payload_crc,
    # missing only index 0 — more complete than the honest stream
    forged = []
    for f in frames[1:]:
        h, chunk = wire.decode_frame(f)
        forged.append(wire.encode_frame(
            dataclasses.replace(h, payload_crc=h.payload_crc ^ 1),
            bytes(len(chunk))))
    c = AggClient(spec, 0, np.asarray(xs[0]))
    server = AggServer(spec, base)
    for f in forged:
        server.receive(f)
    for k, f in enumerate(frames):
        if k not in lost:
            server.receive(f)
    for _ in range(4):                    # RESEND loop must converge
        resend = [rb for rb in server.drain()
                  if wire.decode_response(rb).status == wire.STATUS_RESEND]
        if not resend:
            break
        (rb,) = resend
        assert set(lost) <= set(wire.decode_response(rb).missing)
        for f in c.handle_response(rb):
            server.receive(f)
    assert server.accepted_clients == frozenset({0})
    assert not c.gave_up


def test_fleet_payloads_refuses_chunked_spec():
    spec = _spec(d=2048, bucket=256, mtu=300)
    xs = np.zeros((2, spec.d), np.float32)
    with pytest.raises(ValueError, match="fleet_frames"):
        sim.fleet_payloads(spec, xs)


def test_multi_round_service_runs_chunked():
    """ServiceConfig.mtu threads the chunked transport through the
    anchored multi-round service without losing clients."""
    cfg = sim.MultiRoundConfig(clients=8, d=1024, bucket=128, rounds=2,
                               norm_scale=10.0, y0=1.0, spread0=0.05,
                               mtu=200, seed=0)
    outs = sim.run_rounds(cfg)
    assert [o.accepted for o in outs] == [cfg.clients] * 2
    # bytes_per_client accounts the per-chunk headers
    spec = wire.RoundSpec(round_id=1, d=cfg.d,
                          cfg=QSyncConfig(q=cfg.q, bucket=cfg.bucket),
                          y0=cfg.y0, mtu=cfg.mtu)
    assert outs[0].bytes_per_client == wire.payload_bytes(spec)


def test_payload_crc_seal_failure_is_retryable():
    """Regression (review finding): a forged chunk that shares the honest
    stream's exact header and poisons the body draws a RESEND-all, never a
    terminal REJECT — the honest client rebuilds and is accepted."""
    spec = _spec(d=1024, bucket=128, mtu=200)
    base, xs, fleets = _fleet(spec, 1)
    frames = fleets[0]
    h1, chunk1 = wire.decode_frame(frames[1])
    poisoned = wire.encode_frame(h1, bytes(len(chunk1)))   # garbage body
    c = AggClient(spec, 0, np.asarray(xs[0]))
    server = AggServer(spec, base)
    server.receive(poisoned)              # commits garbage at index 1
    last = None
    for f in frames:                      # honest index 1 drops as dup
        last = server.receive(f)
    r = wire.decode_response(last)
    assert r.status == wire.STATUS_RESEND
    assert r.missing == tuple(range(len(frames)))
    assert server.transport_stats.rejects == 1
    resend = c.handle_response(last)      # not terminal: full rebuild
    assert not c.gave_up and len(resend) == len(frames)
    for f in resend:
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0})


def test_escalated_attempt_resets_partial_session():
    """A higher-attempt chunk supersedes a partial lower-attempt session;
    stale lower-attempt chunks afterwards are dropped, not merged."""
    spec = _spec(d=1024, bucket=128, mtu=200)
    base, xs, _ = _fleet(spec, 1)
    c = AggClient(spec, 0, np.asarray(xs[0]))
    f0, f1 = c.frames(0), c.frames(1)
    server = AggServer(spec, base)
    server.receive(f0[0])                      # partial attempt 0
    server.receive(f1[0])                      # escalation supersedes
    r = wire.decode_response(server.receive(f0[1]))   # stale: dropped
    assert r.status == wire.STATUS_QUEUED      # ... but never terminal
    ts = server.transport_stats
    assert ts.resets == 1 and ts.stale == 1
    for f in f1[1:]:
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0})
    assert wire.decode_frame(f1[0])[0].q == 256


def test_stale_chunks_cannot_capture_resend_targeting():
    """Regression (review finding): network-duplicated attempt-0 chunks
    arriving after escalation must not open a live stream — an
    out-progressing stale stream would capture the client's RESEND slot
    (attempt_next=0, which the attempt-1 client ignores) and deadlock it
    out of the round."""
    spec = _spec(d=2048, bucket=256, mtu=300)
    base, xs, _ = _fleet(spec, 1)
    c = AggClient(spec, 0, np.asarray(xs[0]))
    f0, f1 = c.frames(0), c.frames(1)
    c.attempt = 1
    server = AggServer(spec, base)
    server.receive(f1[0])                     # attempt-1 partial: 1 chunk
    for f in f0:                              # a full stale replay arrives
        server.receive(f)
    assert server.transport_stats.stale == len(f0)
    resend = [wire.decode_response(rb) for rb in server.drain()]
    assert len(resend) == 1
    assert resend[0].attempt_next == 1        # targets the LIVE attempt
    out = c.handle_response(wire.encode_response(resend[0]))
    assert out                                # client answers; no deadlock
    for f in out:
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0})


def test_stale_duplicate_chunk_never_kills_escalating_client():
    """Regression (review finding): a network-duplicated attempt-0 chunk
    arriving after the client escalated must not draw a terminal REJECT —
    the honest client would set gave_up and drop out of the round."""
    spec = _spec(d=1024, bucket=128, mtu=200)
    base, xs, _ = _fleet(spec, 1)
    c = AggClient(spec, 0, np.asarray(xs[0]))
    f0, f1 = c.frames(0), c.frames(1)
    c.attempt = 1                              # escalated (NACK handled)
    server = AggServer(spec, base)
    server.receive(f1[0])                      # attempt-1 reassembly open
    rb = server.receive(f0[0])                 # duplicated stale chunk
    assert c.handle_response(rb) == []
    assert not c.gave_up                       # still in the round
    for f in f1[1:]:
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0})


# ---------------------------------------------------------------------------
# Selective retransmit: RESEND carries exactly the missing chunks
# ---------------------------------------------------------------------------

def test_drain_emits_resend_with_missing_indices():
    spec = _spec(d=2048, bucket=256, mtu=300)
    base, xs, fleets = _fleet(spec, 2)
    server = AggServer(spec, base)
    lost = {1, 3}
    for k, f in enumerate(fleets[0]):
        if k not in lost:
            server.receive(f)
    for f in fleets[1]:
        server.receive(f)
    resps = [wire.decode_response(rb) for rb in server.drain()]
    by_status = {r.status for r in resps}
    assert wire.STATUS_ACK in by_status        # client 1 decoded
    resend = [r for r in resps if r.status == wire.STATUS_RESEND]
    assert len(resend) == 1
    assert resend[0].client_id == 0
    assert resend[0].missing == tuple(sorted(lost))
    # the client answers with exactly those frames, nothing more
    c = AggClient(spec, 0, np.asarray(xs[0]))
    out = c.handle_response(wire.encode_response(resend[0]))
    assert [wire.decode_frame(f)[0].chunk_index for f in out] == \
        sorted(lost)
    for f in out:
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0, 1})


def test_client_ignores_stale_resend_and_bad_missing():
    spec = _spec(d=1024, bucket=128, mtu=200)
    _, xs, _ = _fleet(spec, 1)
    c = AggClient(spec, 0, np.asarray(xs[0]))
    nc = len(c.frames())

    def resend(attempt_next, missing):
        return wire.encode_response(wire.Response(
            status=wire.STATUS_RESEND, round_id=spec.round_id, client_id=0,
            attempt_next=attempt_next, q_next=16, y_next=1.0,
            missing=missing))

    assert c.handle_response(resend(1, (0,))) == []     # foreign attempt
    # out-of-range indices: fall back to the full (idempotent) sequence
    assert len(c.handle_response(resend(0, (0, nc + 5)))) == nc
    assert len(c.handle_response(resend(0, (2,)))) == 1


def test_run_chunked_lossy_wire_delta():
    """ISSUE 5 satellite: the lossy scenario's wire-byte delta is exactly
    the lost chunks' frames (the asserts live inside run_chunked_lossy)."""
    rep = sim.run_chunked_lossy(clients=6, d=2048, bucket=256, mtu=300,
                                n_drop=2, n_corrupt=1, seed=2)
    assert rep.n_chunks_per_client >= 4
    assert rep.retransmit_bytes == rep.lost_frame_bytes
    assert rep.retransmit_bytes < rep.full_resend_bytes / 3
    assert np.array_equal(rep.mean, rep.mean_clean)


def test_sim_full_failure_mix_chunked():
    """The 512-client acceptance scenario runs chunked too, with the same
    recovery guarantees."""
    cfg = sim.SimConfig(clients=128, d=2048, bucket=256, drop=0.02,
                        duplicate=0.05, straggle=0.25, corrupt=2, truncate=1,
                        adversarial=2, extreme=1, seed=0, mtu=300)
    rep = sim.run_round(cfg)
    n_drop = int(round(cfg.drop * cfg.clients))
    assert len(rep.accepted_clients) == cfg.clients - n_drop - cfg.extreme
    assert len(rep.escalated_clients) == cfg.adversarial
    assert rep.stats.gave_up == cfg.extreme
    assert rep.stats.rejected_wire == cfg.corrupt + cfg.truncate
    assert rep.max_err <= 2 * cfg.y0


# ---------------------------------------------------------------------------
# Session-layer memory: transport staging bounded by one frame, not d
# ---------------------------------------------------------------------------

def test_peak_unvalidated_bytes_bounded_by_mtu_not_d():
    """The transport never stages more than one frame (header + MTU) of
    unvalidated bytes, whatever the vector length — the acceptance bound
    (bench_agg asserts the same across inflight clients at large d)."""
    mtu = 256
    peaks = []
    for d in (1 << 11, 1 << 13):
        spec = _spec(d=d, bucket=256, mtu=mtu)
        base, _, fleets = _fleet(spec, 3)
        server = AggServer(spec, base)
        # worst-case interleave: every client's session open at once
        for k in range(len(fleets[0])):
            for fs in fleets:
                server.receive(fs[k])
        server.drain()
        assert server.accepted_clients == frozenset(range(3))
        peaks.append(server.stats.peak_unvalidated_bytes)
        assert server.stats.peak_unvalidated_bytes <= \
            WA.FRAME_HEADER_BYTES + mtu
    assert peaks[0] == peaks[1]                 # independent of d
    # v2's monolithic frame would have staged the whole payload
    assert peaks[0] < wire.payload_bytes(_spec(d=1 << 13, bucket=256,
                                               mtu=0)) / 10


def test_reassembly_buffer_accounting():
    spec = _spec(d=2048, bucket=256, mtu=300)
    base, _, fleets = _fleet(spec, 2)
    server = AggServer(spec, base)
    body = spec.body_bytes()
    server.receive(fleets[0][0])
    ts = server.transport_stats
    assert ts.buffer_bytes == body              # one open session
    server.receive(fleets[1][0])
    assert ts.buffer_bytes == 2 * body
    for f in fleets[0][1:]:
        server.receive(f)
    assert ts.buffer_bytes == body              # client 0 completed
    assert ts.peak_buffer_bytes == 2 * body


# ---------------------------------------------------------------------------
# Response codec v3 (missing list) and facade compatibility
# ---------------------------------------------------------------------------

def test_response_roundtrip_with_missing():
    r = wire.Response(status=wire.STATUS_RESEND, round_id=7, client_id=12,
                      attempt_next=1, q_next=256, y_next=3.5,
                      y_buckets=(1.0, 2.0), missing=(0, 5, 7))
    data = wire.encode_response(r)
    assert wire.decode_response(data) == r
    assert len(data) == WA.RESPONSE_HEAD_BYTES + 4 * 2 + 4 * 3 + 4
    bad = bytearray(data)
    bad[10] ^= 0xFF
    with pytest.raises(wire.CorruptPayloadError):
        wire.decode_response(bytes(bad))


def test_v2_frames_are_refused():
    """Migration contract: a v2 (version=2) frame gets a clean
    VersionMismatchError, never a silent partial parse."""
    spec = _spec(mtu=0, d=512, bucket=64)
    data = bytearray(AggClient(spec, 1, np.zeros(512, np.float32)).payload())
    data[4:6] = struct.pack("<H", 2)
    with pytest.raises(wire.VersionMismatchError):
        wire.decode_payload(bytes(data))


def test_wire_facade_is_removed():
    """The deprecated ``repro.agg.wire`` facade is GONE (its deprecation
    window closed in this wire revision): importing it must fail loudly,
    and the layered transport remains the one surface."""
    import importlib
    import sys

    sys.modules.pop("repro.agg.wire", None)      # force a fresh import
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.agg.wire")
    assert wire.WIRE_VERSION == 5
    assert C.encode_chunks is not None and S.Reassembler is not None
    # single-frame chunk encode is byte-identical to encode_payload
    spec = _spec(mtu=0, d=512, bucket=64)
    w = np.arange(L.packed_len(spec.padded, 4), dtype=np.uint32)
    sides = spec.sides_np()
    a = wire.encode_payload(spec, 3, 0, 16, w, sides, 99)
    b = C.encode_chunks(spec, 3, 0, 16, w, sides, 99)
    assert b == [a]
    crc = zlib.crc32(a)                       # exports stay live
    assert isinstance(crc, int) and rounds is not None and F is not None


# ---------------------------------------------------------------------------
# Streaming decode + windowed flow control (v5)
# ---------------------------------------------------------------------------

def test_response_ack_credit_roundtrip():
    """The v5 additive flow-control fields survive the codec and default
    to zero (a v4-shaped response decodes with ack=credit=0)."""
    r = wire.Response(status=wire.STATUS_QUEUED, round_id=7, client_id=3,
                      attempt_next=0, q_next=16, y_next=0.5,
                      missing=(1, 4), y_buckets=(0.5, 0.25),
                      ack=2, credit=4)
    got = wire.decode_response(wire.encode_response(r))
    assert (got.ack, got.credit) == (2, 4)
    assert got == r
    plain = wire.Response(status=wire.STATUS_ACK, round_id=7, client_id=3,
                          attempt_next=0, q_next=0, y_next=0.0)
    got = wire.decode_response(wire.encode_response(plain))
    assert (got.ack, got.credit) == (0, 0)


def test_roundspec_window_requires_mtu():
    with pytest.raises(ValueError):
        _spec(mtu=0, window=4)
    with pytest.raises(ValueError):
        _spec(window=-1)
    assert _spec(window=4).window == 4


def test_streaming_bit_parity_any_permutation_with_duplicates():
    """Property (the tentpole's correctness gate): the streaming server's
    published mean is bit-identical to the SEALED batched-decode drain
    under any chunk arrival permutation, duplicate storms included — and
    its pending store never approaches one body per in-flight client."""
    spec = _spec(d=2048, bucket=256, mtu=300, window=3)
    base, _, fleets = _fleet(spec, 4)
    sealed = AggServer(spec, base, streaming=False)
    for fs in fleets:
        for f in fs:
            sealed.receive(f)
    mean_ref, _ = sealed.finalize()
    body = spec.body_bytes()
    assert sealed.stats.peak_pending_store_bytes >= 4 * body  # one body each
    flat = [f for fs in fleets for f in fs]
    for trial in range(6):
        rng = np.random.RandomState(trial)
        order = list(rng.permutation(len(flat)))
        if trial % 2:                        # duplicate storm
            order += list(rng.choice(len(flat), len(flat)))
        server = AggServer(spec, base)       # window>0 => streaming on
        assert server._streaming
        for i in order:
            server.receive(flat[i])
        server.drain()
        mean, stats = server.finalize()
        assert server.accepted_clients == frozenset(range(4)), trial
        assert np.array_equal(mean.view(np.uint32),
                              mean_ref.view(np.uint32)), trial
        # chunk bytes are freed as ranges fold: even under an adversarial
        # arrival permutation (held out-of-order chunks can approach one
        # body) the store stays strictly below the sealed path's staged
        # bodies; the windowed mostly-in-order regime — where it drops to
        # ~one chunk — is pinned by the loop test below and the bench's
        # < 0.5x gate
        assert stats.peak_pending_store_bytes < \
            sealed.stats.peak_pending_store_bytes, \
            (trial, stats.peak_pending_store_bytes)


def test_streaming_seal_failure_rolls_back_speculative_fold():
    """A stream whose payload-CRC seal fails (forged body byte under a
    recomputed frame CRC) must contribute NOTHING: the speculative fold is
    dropped, the client is RESENT the whole sequence, and the rebuilt
    stream commits a mean bit-identical to the clean round."""
    spec = _spec(d=1024, bucket=128, mtu=200, window=2)
    base, _, fleets = _fleet(spec, 2)
    clean = AggServer(spec, base, streaming=False)
    for fs in fleets:
        for f in fs:
            clean.receive(f)
    mean_ref, _ = clean.finalize()
    h1, chunk1 = wire.decode_frame(fleets[0][1])
    forged_body = bytearray(chunk1)
    forged_body[3] ^= 0xFF
    forged = wire.encode_frame(h1, bytes(forged_body))  # valid frame CRC,
    server = AggServer(spec, base)                      # lying body
    server.receive(fleets[0][0])
    server.receive(forged)
    for f in fleets[0][2:]:
        r = wire.decode_response(server.receive(f))
    # stream complete but seal failed: RESEND everything, nothing folded
    assert r.status == wire.STATUS_RESEND
    assert tuple(r.missing) == tuple(range(len(fleets[0])))
    assert r.credit == spec.window
    assert not server._folds                 # speculative record dropped
    assert server.accepted_clients == frozenset()
    for f in fleets[0]:                      # honest rebuild commits
        server.receive(f)
    for f in fleets[1]:
        server.receive(f)
    server.drain()
    mean, _ = server.finalize()
    assert server.accepted_clients == frozenset(range(2))
    assert np.array_equal(mean.view(np.uint32), mean_ref.view(np.uint32))


def test_streaming_mid_stream_escalation_resets_fold():
    """Chunks of a half-delivered attempt are abandoned when the client
    escalates: the session discards the stale stream, the stream-fold
    rollback fires, and the escalated attempt alone is committed —
    bit-identical to the clean round (coordinates are attempt-invariant)."""
    spec = _spec(d=1024, bucket=128, mtu=200, window=2)
    base, xs, fleets = _fleet(spec, 1)
    clean = AggServer(spec, base, streaming=False)
    for f in fleets[0]:
        clean.receive(f)
    mean_ref, _ = clean.finalize()
    c = AggClient(spec, 0, xs[0])
    a0, a1 = c.frames(0), c.frames(1)
    server = AggServer(spec, base)
    for f in a0[: len(a0) // 2]:             # half of attempt 0 ...
        server.receive(f)
    assert server._folds                     # speculative fold is open
    for f in a1:                             # ... then the escalation
        server.receive(f)
    server.drain()
    assert server.accepted_clients == frozenset({0})
    # only the attempt-1 stream's record remains committed; the abandoned
    # attempt-0 fold was dropped by the discard callback
    assert not server._folds
    mean, _ = server.finalize()
    assert np.array_equal(mean.view(np.uint32), mean_ref.view(np.uint32))


def test_send_window_paces_and_counts_stalls():
    """SendWindow unit behavior: at most ``window`` in flight, cumulative
    acks release more, RESENDs below the sent prefix are the lost set,
    and a response that releases nothing counts a stall."""
    frames = [bytes([i]) * 8 for i in range(5)]
    w = C.SendWindow(frames, 2)
    assert w.sendable() == frames[:2] and w.in_flight == 2
    assert w.sendable() == [] and w.stalls == 1      # blocked: no credit
    w.note_ack(1)
    assert w.sendable() == [frames[2]]
    w.note_ack(1)                                     # stale ack: no rewind
    assert w.ack == 1 and w.unacked() == frames[1:3]
    w.note_ack(3)
    assert w.sendable() == frames[3:5]
    assert w.done and w.sendable() == []              # done: no stall
    assert w.stalls == 1


def test_windowed_client_loop_lossy_bit_parity():
    """End-to-end windowed rounds under loss: credit-paced clients against
    the streaming server converge via ack/credit + RESEND + timeout
    recovery, exercise window stalls, and publish a mean bit-identical to
    the sealed drain over the same accepted clients."""
    spec = _spec(d=2048, bucket=256, mtu=300, window=2)
    base, xs, fleets = _fleet(spec, 6)
    rng = np.random.RandomState(5)
    server = AggServer(spec, base)
    clients = [AggClient(spec, cid, xs[cid]) for cid in range(6)]
    outbox = [(c, f) for c in clients for f in c.send_frames()]
    for step in range(300):
        nxt = []
        for c, f in outbox:
            if rng.rand() < 0.25:
                continue                     # lost on the wire
            rb = server.receive(f)
            nxt.extend((c, g) for g in c.handle_response(rb))
        outbox = nxt
        if all(c.acked for c in clients):
            break
        if not outbox:                       # quiet: timeout recovery
            for c in clients:
                rr = server.resend_request(c.client_id)
                if rr is not None:
                    outbox.extend((c, g) for g in c.handle_response(rr))
                else:
                    outbox.extend((c, f) for f in c.retransmit_frames())
    assert all(c.acked for c in clients), \
        [c.client_id for c in clients if not c.acked]
    assert sum(c.window_stalls for c in clients) > 0
    server.drain()
    mean, stats = server.finalize()
    acc = server.accepted_clients
    assert acc == frozenset(range(6))
    sealed = AggServer(spec, base, streaming=False)
    for cid in sorted(acc):
        for f in fleets[cid]:
            sealed.receive(f)
    mean_ref, _ = sealed.finalize()
    assert np.array_equal(mean.view(np.uint32), mean_ref.view(np.uint32))
    # the DRAINED state carries no body-sized backlog in streaming mode
    assert stats.peak_pending_store_bytes < spec.body_bytes() * 6
