"""Collective/compute overlap auditor (launch/hlo_analysis.audit_overlap).

Hand-written HLO programs exercise the classifier directly: a serial loop
body (gather feeds the same iteration's dot) must read fully exposed, a
prefetch-style body (gather result parked in the loop carry, issued from a
conditional branch) fully overlapped, and async -start/-done pairs must be
counted once."""
import textwrap

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import audit_overlap


def _hlo(body_ops: str, extra_comps: str = "", trip: int = 4) -> str:
    return textwrap.dedent(f"""\
        HloModule m

        {extra_comps}
        %body (p: (s32[], f32[8,8], f32[8,8])) -> (s32[], f32[8,8], f32[8,8]) {{
          %p = (s32[], f32[8,8], f32[8,8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %w0 = f32[8,8] get-tuple-element(%p), index=1
          %x = f32[8,8] get-tuple-element(%p), index=2
          %one = s32[] constant(1)
          %ip = s32[] add(%i, %one)
        {textwrap.indent(textwrap.dedent(body_ops), '  ')}
        }}

        %cond (cp: (s32[], f32[8,8], f32[8,8])) -> pred[] {{
          %cp = (s32[], f32[8,8], f32[8,8]) parameter(0)
          %ci = s32[] get-tuple-element(%cp), index=0
          %lim = s32[] constant({trip})
          ROOT %lt = pred[] compare(%ci, %lim), direction=LT
        }}

        ENTRY %main (a: f32[8,8], b: f32[8,8]) -> f32[8,8] {{
          %a = f32[8,8] parameter(0)
          %b = f32[8,8] parameter(1)
          %zero = s32[] constant(0)
          %init = (s32[], f32[8,8], f32[8,8]) tuple(%zero, %a, %b)
          %w = (s32[], f32[8,8], f32[8,8]) while(%init), condition=%cond, body=%body
          ROOT %out = f32[8,8] get-tuple-element(%w), index=2
        }}
        """)


def test_serial_body_fully_exposed():
    """Gather result feeds the same iteration's dot: 100% of the loop's
    collective bytes sit on the critical path."""
    hlo = _hlo("""\
        %ag = f32[8,8] all-gather(%w0), dimensions={0}
        %mm = f32[8,8] dot(%ag, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        ROOT %t = (s32[], f32[8,8], f32[8,8]) tuple(%ip, %w0, %mm)
    """)
    a = audit_overlap(hlo)
    assert len(a.bodies) == 1
    assert a.exposed_fraction == 1.0
    # trip-weighted: f32[8,8] all-gather output = 256 bytes, 4 trips
    assert a.total_bytes == 256 * 4


def test_prefetch_body_fully_overlapped():
    """Gather result only escapes into the loop carry (next iteration
    consumes it); this iteration's dot reads the previous gather: 0%."""
    hlo = _hlo("""\
        %ag = f32[8,8] all-gather(%w0), dimensions={0}
        %mm = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        ROOT %t = (s32[], f32[8,8], f32[8,8]) tuple(%ip, %ag, %mm)
    """)
    a = audit_overlap(hlo)
    assert a.total_bytes == 256 * 4
    assert a.exposed_fraction == 0.0


def test_conditional_issue_escaping_to_carry_is_overlapped():
    """The prefetched scan issues the next layer's gather inside a
    conditional branch; the branch root flows to the carry only."""
    branches = textwrap.dedent("""\
        %issue (bp: f32[8,8]) -> f32[8,8] {
          %bp = f32[8,8] parameter(0)
          %bag = f32[8,8] all-gather(%bp), dimensions={0}
          ROOT %bc = f32[8,8] copy(%bag)
        }

        %skip (sp: f32[8,8]) -> f32[8,8] {
          %sp = f32[8,8] parameter(0)
          ROOT %sz = f32[8,8] copy(%sp)
        }
        """)
    hlo = _hlo("""\
        %pr = pred[] compare(%ip, %one), direction=LT
        %nxt = f32[8,8] conditional(%pr, %w0, %w0), true_computation=%issue, false_computation=%skip
        %mm = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        ROOT %t = (s32[], f32[8,8], f32[8,8]) tuple(%ip, %nxt, %mm)
    """, extra_comps=branches)
    a = audit_overlap(hlo)
    assert a.total_bytes == 256 * 4
    assert a.exposed_fraction == 0.0


def test_conditional_issue_feeding_dot_is_exposed():
    """Same conditional shape, but the branch result feeds this
    iteration's dot — the escape must resume at the call site and find
    the compute."""
    branches = textwrap.dedent("""\
        %issue (bp: f32[8,8]) -> f32[8,8] {
          %bp = f32[8,8] parameter(0)
          %bag = f32[8,8] all-gather(%bp), dimensions={0}
          ROOT %bc = f32[8,8] copy(%bag)
        }

        %skip (sp: f32[8,8]) -> f32[8,8] {
          %sp = f32[8,8] parameter(0)
          ROOT %sz = f32[8,8] copy(%sp)
        }
        """)
    hlo = _hlo("""\
        %pr = pred[] compare(%ip, %one), direction=LT
        %nxt = f32[8,8] conditional(%pr, %w0, %w0), true_computation=%issue, false_computation=%skip
        %mm = f32[8,8] dot(%nxt, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        ROOT %t = (s32[], f32[8,8], f32[8,8]) tuple(%ip, %w0, %mm)
    """, extra_comps=branches)
    a = audit_overlap(hlo)
    assert a.exposed_fraction == 1.0


def test_async_start_done_counted_once():
    """-start/-done pairs: bytes counted at -start only; exposure follows
    the chain through -done into the dot."""
    hlo = _hlo("""\
        %ags = f32[8,8] all-gather-start(%w0), dimensions={0}
        %agd = f32[8,8] all-gather-done(%ags)
        %mm = f32[8,8] dot(%agd, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        ROOT %t = (s32[], f32[8,8], f32[8,8]) tuple(%ip, %w0, %mm)
    """)
    a = audit_overlap(hlo)
    assert len(a.bodies) == 1
    assert len(a.bodies[0]["collectives"]) == 1
    assert a.total_bytes == 256 * 4
    assert a.exposed_fraction == 1.0


def test_mixed_bodies_weighted_fraction():
    """One exposed + one overlapped collective in the same body: the
    fraction is byte-weighted."""
    hlo = _hlo("""\
        %ag1 = f32[8,8] all-gather(%w0), dimensions={0}
        %ag2 = f32[8,8] all-gather(%x), dimensions={0}
        %mm = f32[8,8] dot(%ag1, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        ROOT %t = (s32[], f32[8,8], f32[8,8]) tuple(%ip, %ag2, %mm)
    """)
    a = audit_overlap(hlo)
    assert a.total_bytes == 2 * 256 * 4
    assert a.exposed_fraction == 0.5


def test_no_loop_collectives_reads_zero():
    """A collective-free loop (or no loop at all) is trivially 0.0."""
    hlo = _hlo("""\
        %mm = f32[8,8] dot(%w0, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        ROOT %t = (s32[], f32[8,8], f32[8,8]) tuple(%ip, %w0, %mm)
    """)
    a = audit_overlap(hlo)
    assert a.total_bytes == 0.0
    assert a.exposed_fraction == 0.0


def test_audit_on_real_lowered_scan():
    """Smoke on genuinely lowered HLO: a scanned matmul compiles and the
    auditor runs without tripping on real attribute syntax."""
    def step(c, _):
        return jnp.tanh(c @ c), None

    def g(x):
        return jax.lax.scan(step, x, None, length=4)[0]

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    a = audit_overlap(comp.as_text())
    # single-device program: no collectives, nothing exposed
    assert a.exposed_fraction == 0.0
