"""Hierarchical sum-without-decode tree (ISSUE 7): tree-vs-flat bit-parity
under chunk loss / reordering / duplicates / straggling tiers, saturation
rejection at the q cap, the no-tier-decodes dispatch gate, the AggNode
protocol surface, and AggConfig default-drift protection."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.agg import sim
from repro.agg.api import AggConfig, AggNode, PublishedRound
from repro.agg.client import AggClient
from repro.agg.engine import AggEngine, EngineConfig
from repro.agg.server import AggServer
from repro.agg.service import AggService, ServiceConfig
from repro.agg.transport import frame as wire
from repro.agg.tree import TIER_ID_BASE, AggTree, TierAggregator
from repro.dist.collectives import QSyncConfig
from repro.kernels import ops as K

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spec(d=1024, bucket=128, q=16, mtu=0, y0=0.5, seed=3, round_id=1,
          max_attempts=4):
    return wire.RoundSpec(round_id=round_id, d=d,
                          cfg=QSyncConfig(q=q, bucket=bucket), y0=y0,
                          seed=seed, max_attempts=max_attempts, mtu=mtu)


def _fleet(spec, n, seed=0, spread=0.02, scale=2.0):
    rng = np.random.RandomState(seed)
    base = scale * rng.randn(spec.d).astype(np.float32)
    xs = base[None] + spread * rng.randn(n, spec.d).astype(np.float32)
    return base, xs, sim.fleet_frames(spec, xs)


def _flat_publish(spec, base, frames):
    srv = AggServer(spec, base)
    for fs in frames:
        for f in fs:
            srv.ingest_frame(f)
    srv.tick()
    srv.seal()
    return srv.published()[0]


def _run_tree(tree, frames, max_ticks=16):
    for fs in frames:
        for f in fs:
            tree.ingest_frame(f)
    tree.tick()
    tree.seal()
    for _ in range(max_ticks):
        tree.tick()
        prs = tree.published()
        if prs:
            return prs[0]
    raise AssertionError("tree never published within the tick budget")


def _assert_parity(pt: PublishedRound, pf: PublishedRound):
    assert pt.accepted == pf.accepted
    assert np.array_equal(pt.mean.view(np.uint32), pf.mean.view(np.uint32))


# ---------------------------------------------------------------------------
# Bit-parity: tree mean == flat mean over the same accepted clients
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fanout,tiers,mtu", [(4, 1, 0), (4, 2, 0),
                                              (4, 2, 160), (8, 1, 256)])
def test_tree_flat_bit_parity(fanout, tiers, mtu):
    spec = _spec(mtu=mtu)
    base, _, frames = _fleet(spec, 24)
    pf = _flat_publish(spec, base, frames)
    pt = _run_tree(AggTree(spec, base, fanout=fanout, tiers=tiers), frames)
    _assert_parity(pt, pf)


def test_tree_parity_under_chunk_loss_and_reordering():
    """Drop internal frames (once each) AND deliver every client's chunks
    in reversed interleaved order; the selective-retransmit path must
    restore bit-parity with the clean flat round."""
    spec = _spec(mtu=160)
    base, _, frames = _fleet(spec, 20)
    pf = _flat_publish(spec, base, frames)

    lost = {"n": 0}

    def loss(src, dst, data):
        if data[:4] == wire.MAGIC_PAYLOAD and lost["n"] < 5:
            lost["n"] += 1
            return None
        return data

    tree = AggTree(spec, base, fanout=4, tiers=2, loss=loss)
    # reordered: chunk-interleaved, reversed client order
    nc = len(frames[0])
    for k in range(nc - 1, -1, -1):
        for i in range(len(frames) - 1, -1, -1):
            tree.ingest_frame(frames[i][k])
    tree.tick()
    tree.seal()
    for _ in range(24):
        tree.tick()
        if tree.published():
            break
    assert lost["n"] == 5, "loss hook never fired"
    assert tree.published(), "tree did not recover from internal loss"
    _assert_parity(tree.published()[0], pf)


def test_tree_parity_with_duplicate_clients():
    """Every frame delivered twice (client retransmit storm) plus a late
    full replay: duplicates ACK idempotently at the edge and are never
    double-counted in any tier's fold."""
    spec = _spec(mtu=0)
    base, _, frames = _fleet(spec, 16)
    pf = _flat_publish(spec, base, frames)
    tree = AggTree(spec, base, fanout=4, tiers=1)
    for fs in frames:
        for f in fs:
            tree.ingest_frame(f)
            tree.ingest_frame(f)           # immediate duplicate
    for fs in frames:                      # and a late full replay
        for f in fs:
            tree.ingest_frame(f)
    pt = _run_tree(tree, [])
    _assert_parity(pt, pf)
    assert sum(t.duplicates for t in tree.tier_stats()) > 0


def test_tree_straggling_tier_resend_path():
    """A tier whose ENTIRE combined payload is lost upstream (every chunk,
    first transmissions) must recover via its idle re-send timer plus the
    parent's RESEND chase — the straggling-tier drain path."""
    spec = _spec(mtu=160)
    base, _, frames = _fleet(spec, 12)
    pf = _flat_publish(spec, base, frames)

    victim = {"id": None, "dropped": 0}

    def loss(src, dst, data):
        if data[:4] != wire.MAGIC_PAYLOAD:
            return data
        if victim["id"] is None:
            victim["id"] = src
        if src == victim["id"] and victim["dropped"] < 6:
            victim["dropped"] += 1
            return None                      # black-hole the whole payload
        return data

    tree = AggTree(spec, base, fanout=4, tiers=1, loss=loss)
    pt = _run_tree(tree, frames, max_ticks=32)
    assert victim["dropped"] >= 1
    _assert_parity(pt, pf)
    resends = sum(t.up_resends + t.resends_sent for t in tree.tier_stats())
    assert resends >= 1, "straggling tier never exercised a resend path"


def test_tree_parity_with_escalating_clients():
    """An out-of-bound client escalates against its EDGE tier with the same
    q <- q^2 handshake it would run against a flat server, and the
    recovered round stays bit-identical to flat."""
    spec = _spec(mtu=0)
    rng = np.random.RandomState(4)
    base = 2.0 * rng.randn(spec.d).astype(np.float32)
    xs = base[None] + 0.02 * rng.randn(10, spec.d).astype(np.float32)
    xs[7] += 6.0 * spec.y0 * rng.choice([-1.0, 1.0], spec.d
                                        ).astype(np.float32)

    def drive(node):
        clients = [AggClient(spec, i, xs[i]) for i in range(len(xs))]
        inflight = [f for c in clients for f in c.frames()]
        for _ in range(2 * spec.max_attempts):
            outs = []
            for f in inflight:
                outs.extend(node.ingest_frame(f))
            outs.extend(node.tick())
            inflight = []
            for rb in outs:
                r = wire.decode_response(rb)
                if r.client_id < len(clients):
                    inflight.extend(clients[r.client_id].handle_response(rb))
            if not inflight:
                break
        node.seal()
        for _ in range(16):
            node.tick()
            if node.published():
                return node.published()[0]
        raise AssertionError("did not publish")

    pf = drive(AggServer(spec, base))
    pt = drive(AggTree(spec, base, fanout=4, tiers=1))
    assert 7 in pt.accepted                  # escalation recovered it
    _assert_parity(pt, pf)


# ---------------------------------------------------------------------------
# Saturation: the overflow guard at the widest color space
# ---------------------------------------------------------------------------

def test_tier_saturation_rejects_at_q_cap():
    """With q0 = 2^16 and no escalation headroom, a fold that would push
    |R| past q_max/2 draws a terminal REJECT and is counted saturated —
    never a silent wraparound of the combined coordinates."""
    spec = _spec(d=256, bucket=64, q=1 << 16, y0=0.5, max_attempts=1)
    rng = np.random.RandomState(0)
    base = np.zeros(spec.d, np.float32)
    # every client ~0.3 * (q/2) coordinate units from the anchor with the
    # SAME sign: the 4th fold would exceed the centered q_max/2 range
    side = float(np.max(spec.sides_np()))
    xs = np.full((6, spec.d), 0.3 * side * float(1 << 15), np.float32)
    xs += 0.01 * side * rng.randn(6, spec.d).astype(np.float32)
    frames = sim.fleet_frames(spec, xs)
    tier = TierAggregator(spec, base, TIER_ID_BASE)
    outs = []
    for fs in frames:
        for f in fs:
            outs.extend(tier.ingest_frame(f))
    outs.extend(tier.tick())
    tier.seal()
    outs.extend(tier.tick())
    st = tier.stats
    assert st.saturated >= 1, "no fold was saturation-rejected"
    assert st.clients_summed >= 1
    assert st.clients_summed + st.saturated == 6
    assert len(tier.accepted_clients) == st.clients_summed
    assert tier.n_summed == st.clients_summed
    # the guarded accumulator still forwards, with the honest summed count
    fwd = [o for o in outs if o[: len(wire.MAGIC_PAYLOAD)]
           == wire.MAGIC_PAYLOAD]
    assert fwd, "tier did not forward its combined payload"
    h, _ = wire.decode_frame(fwd[0])
    assert h.n_summed == st.clients_summed


# ---------------------------------------------------------------------------
# The dispatch gate: tiers never decode; the root decodes once per q
# ---------------------------------------------------------------------------

def test_no_tier_decodes_root_decodes_once_per_color_space():
    fanout = 4
    spec = _spec(mtu=0)
    base, _, frames = _fleet(spec, 24)
    tree = AggTree(spec, base, fanout=fanout, tiers=2)
    for fs in frames:
        for f in fs:
            tree.ingest_frame(f)
    tree.tick()
    import jax

    jax.clear_caches()          # the dispatch counter fires at trace time:
    K.reset_dispatch_counts()   # force the root drain to retrace here
    tree.seal()
    for _ in range(16):
        tree.tick()
        if tree.published():
            break
    decodes = K.DISPATCH_COUNTS["lattice_decode_batched"]
    spaces = {t.forwarded_q for t in tree.layers[0]
              if t.forwarded_q is not None}
    assert tree.published()
    assert decodes == len(spaces) >= 1
    assert K.DISPATCH_COUNTS["lattice_decode"] == 0
    assert tree.root.stats.drains == 1
    assert tree.root_ingress_payloads <= fanout


# ---------------------------------------------------------------------------
# AggNode protocol + config drift
# ---------------------------------------------------------------------------

def test_aggnode_protocol_is_satisfied_by_all_endpoints():
    spec = _spec(d=256, bucket=64)
    base = np.zeros(spec.d, np.float32)
    svc = AggService(ServiceConfig(d=256, bucket=64))
    eng = AggEngine(svc, EngineConfig(), now=0.0)
    for node in (AggServer(spec, base), eng,
                 TierAggregator(spec, base, TIER_ID_BASE),
                 AggTree(spec, base, fanout=2)):
        assert isinstance(node, AggNode), type(node)
        assert isinstance(node.published(), list)


def test_tree_behind_protocol_matches_flat_server_driver():
    """One driver function, two AggNode implementations, byte-for-byte the
    same outcome — the API-redesign headline."""
    spec = _spec(mtu=0)
    base, _, frames = _fleet(spec, 12)

    def drive(node):
        for fs in frames:
            for f in fs:
                node.ingest_frame(f)
        node.tick()
        node.seal()
        for _ in range(16):
            node.tick()
            if node.published():
                return node.published()[0]
        raise AssertionError("no publish")

    _assert_parity(drive(AggTree(spec, base, fanout=4)),
                   drive(AggServer(spec, base)))


def test_config_defaults_no_drift():
    """AggConfig mirrors ServiceConfig + EngineConfig field-by-field; a
    default changed in one layer but not the composed config fails here."""
    import dataclasses as dc

    svc_defaults = {f.name: f.default for f in dc.fields(ServiceConfig)
                    if f.default is not dc.MISSING}
    eng_defaults = {f.name: f.default for f in dc.fields(EngineConfig)
                    if f.default is not dc.MISSING}
    agg_defaults = {f.name: f.default for f in dc.fields(AggConfig)
                    if f.default is not dc.MISSING}
    for name in AggConfig._SERVICE_FIELDS:
        if name in svc_defaults:
            assert agg_defaults[name] == svc_defaults[name], name
    for name in AggConfig._ENGINE_FIELDS:
        assert agg_defaults[name] == eng_defaults[name], name
    # projections carry every mirrored field across verbatim
    cfg = AggConfig(d=512)
    sc, ec = cfg.service_config(), cfg.engine_config()
    for name in AggConfig._SERVICE_FIELDS:
        assert getattr(sc, name) == getattr(cfg, name), name
    for name in AggConfig._ENGINE_FIELDS:
        assert getattr(ec, name) == getattr(cfg, name), name


def test_tree_from_agg_config_topology():
    """The composed AggConfig carries tree topology alongside the round
    contract, and a tree built from it matches flat bit-for-bit."""
    cfg = AggConfig(d=512, bucket=64, fanout=4, tiers=1)
    spec = _spec(d=cfg.d, bucket=cfg.bucket, q=cfg.q)
    base, _, frames = _fleet(spec, 8)
    pf = _flat_publish(spec, base, frames)
    pt = _run_tree(AggTree(spec, base, fanout=cfg.fanout, tiers=cfg.tiers),
                   frames)
    _assert_parity(pt, pf)
    assert pt.round_id == pf.round_id == spec.round_id


# ---------------------------------------------------------------------------
# 8-device subprocess parity: tree mean == shard_map star-collective mean
# ---------------------------------------------------------------------------

def _run_8dev(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_tree_mean_bit_identical_to_star_8dev():
    """ISSUE 7 acceptance: the 2-tier tree's published mean over 8 clients
    equals the 8-device allgather_allreduce_mean star bitwise — tiers sum
    packed words without decoding, the root issues the batched decode."""
    out = _run_8dev("""
        from functools import partial
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import (QSyncConfig,
            allgather_allreduce_mean, flat_size_padded)
        from repro.agg import rounds
        from repro.agg.transport import frame as wire
        from repro.agg.client import AggClient
        from repro.agg.tree import AggTree
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n, bucket = 8192, 1024
        cfg = QSyncConfig(q=16, bucket=bucket)
        spec = wire.RoundSpec(round_id=11, d=n, cfg=cfg, y0=2.0, seed=5)
        base = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 50.0
        xs = base + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (8, n))
        nb = flat_size_padded(n, cfg) // bucket
        y_b = jnp.full((nb,), spec.y0)
        key = rounds.round_key(spec)
        @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                 out_specs=P("data"), check_vma=False)
        def f(xl):
            out, _ = allgather_allreduce_mean(xl.reshape(-1), y_b, key,
                                              "data", cfg)
            return out.reshape(1, -1)
        star = np.asarray(jax.jit(f)(xs))
        assert np.all(star == star[0])
        tree = AggTree(spec, np.asarray(xs[3]), fanout=2, tiers=2)
        for i in np.random.RandomState(1).permutation(8):
            tree.ingest_frame(AggClient(spec, int(i),
                                        np.asarray(xs[i])).payload())
        tree.tick()
        tree.seal()
        for _ in range(8):
            tree.tick()
            if tree.published():
                break
        pr = tree.published()[0]
        assert pr.accepted == frozenset(range(8)), pr.accepted
        assert np.array_equal(pr.mean, star[0])
        print("TREE_STAR_PARITY_OK")
    """)
    assert "TREE_STAR_PARITY_OK" in out
