"""Event-driven continuous-round engine (ISSUE 6): round life-cycle state
machine guards, per-round seed folding, quorum/deadline cutover, the
round-boundary races (late straggler vs late newcomer, future-round frames,
duplicates spanning rounds), admission backpressure, straggler expiry, the
open-loop Poisson sim with replay parity, the engine-vs-lockstep throughput
ordering, and the 8-device star-collective bit-parity of an engine-published
round (subprocess, like tests/test_agg.py)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.agg import rounds, sim
from repro.agg.transport import frame as wire
from repro.agg.client import AggClient
from repro.agg.engine import AggEngine, EngineConfig
from repro.agg.server import AggServer
from repro.agg.service import AggService, RoundState, ServiceConfig


D, BUCKET = 256, 64


def _svc(**kw):
    base = dict(d=D, bucket=BUCKET, y0=1.0, seed=3, anchored=True)
    base.update(kw)
    return AggService(ServiceConfig(**base))


def _eng(svc=None, **kw):
    svc = svc or _svc()
    base = dict(quorum=2, round_deadline=1.0, straggler_deadline=0.2,
                max_resends=1, drain_deadline=5.0, max_live_rounds=3)
    base.update(kw)
    return AggEngine(svc, EngineConfig(**base), now=0.0)


def _xs(n, seed=0, scale=0.1):
    return scale * np.random.RandomState(seed).randn(n, D).astype(np.float32)


def _client(rnd, cid, x):
    return AggClient(rnd.spec, cid, x, anchor=rnd.client_anchor)


def _replay(spec, anchor, xs_by_cid) -> np.ndarray:
    """Lockstep reference: same accepted set, sorted order, no engine."""
    ref = anchor if anchor is not None else np.zeros((spec.d,), np.float32)
    server = AggServer(spec, ref)
    for cid in sorted(xs_by_cid):
        for f in AggClient(spec, cid, xs_by_cid[cid], anchor=anchor).frames():
            server.receive(f)
    mean, _ = server.finalize()
    assert server.accepted_clients == frozenset(xs_by_cid)
    return mean


# ---------------------------------------------------------------------------
# Per-round seed fold (satellite: no cross-round dither reuse)
# ---------------------------------------------------------------------------

def test_fold_seed_no_reuse_and_replay_stable():
    """Consecutive rounds draw DIFFERENT wire seeds (and dithers); replaying
    the same (service seed, round id) is bit-stable."""
    assert rounds.fold_seed(3, 1) != rounds.fold_seed(3, 2)
    assert rounds.fold_seed(3, 1) == rounds.fold_seed(3, 1)
    assert rounds.fold_seed(3, 1) != rounds.fold_seed(4, 1)
    assert 0 <= rounds.fold_seed(2**32 - 1, 2**32 - 1) < 2**31
    svc = _svc()
    xs = _xs(2)
    specs = []
    for _ in range(3):
        rnd = svc.open_round()
        specs.append(rnd.spec)
        for cid in (0, 1):
            for f in _client(rnd, cid, xs[cid]).frames():
                rnd.server.receive(f)
        svc.publish_round(rnd)
    assert len({s.seed for s in specs}) == 3
    for a, b in zip(specs, specs[1:]):
        assert not np.array_equal(np.asarray(rounds.dither(a)),
                                  np.asarray(rounds.dither(b)))
    # replay: a fresh service with the same config re-derives the same
    # per-round seeds (and so the same dithers), bit for bit
    svc2 = _svc()
    for s in specs:
        rnd = svc2.open_round()
        assert rnd.spec.seed == s.seed == rounds.fold_seed(3, s.round_id)
        assert np.array_equal(np.asarray(rounds.dither(rnd.spec)),
                              np.asarray(rounds.dither(s)))
        svc2.publish_round(rnd)


# ---------------------------------------------------------------------------
# Round life-cycle state machine
# ---------------------------------------------------------------------------

def test_round_state_machine_guards():
    svc = _svc()
    rnd = svc.open_round()
    assert rnd.state is RoundState.OPEN
    with pytest.raises(RuntimeError, match="illegal transition"):
        rnd.mark_drained()                 # OPEN -> DRAINED is not a step
    rnd.seal(now=1.0, next_round_id=2)
    assert rnd.state is RoundState.SEALING and rnd.server.sealed
    with pytest.raises(RuntimeError, match="illegal transition"):
        rnd.seal()                         # seal is one-way
    rnd.mark_drained(now=2.0)              # nobody admitted: trivially drained
    mean, stats = rnd.publish(now=3.0)
    assert rnd.state is RoundState.PUBLISHED
    m2, _ = rnd.publish(now=9.0)           # idempotent, timestamps keep
    assert np.array_equal(mean, m2) and rnd.published_at == 3.0


def test_round_publish_forces_unresolved_expiry():
    """publish() from SEALING expires stragglers rather than raising, while
    mark_drained() (the engine's clean path) refuses to lie."""
    svc = _svc()
    rnd = svc.open_round()
    x = _xs(1)[0]
    rnd.server.receive(_client(rnd, 7, x).frames()[0])  # staged, undrained
    rnd.seal()
    with pytest.raises(RuntimeError, match="unresolved"):
        rnd.mark_drained()
    rnd.publish()
    # the staged payload was decodable: publish drains before expiring
    assert rnd.server.accepted_clients == frozenset({7})


def test_service_rejects_out_of_order_publish():
    svc = _svc()
    r1, r2 = svc.open_round(), svc.open_round()
    assert (r1.round_id, r2.round_id) == (1, 2)
    with pytest.raises(RuntimeError, match="out of order"):
        svc.publish_round(r2)
    svc.publish_round(r1)
    svc.publish_round(r2)
    assert svc.published_id == 2


def test_anchor_lag_recorded_for_overlapping_rounds():
    """Round k+1 opened while round k drains anchors against round k-1's
    mean — the staleness the engine reports."""
    svc = _svc()
    r1 = svc.open_round()
    r2 = svc.open_round()          # overlapping: r1 not yet published
    assert r2.anchor_round == 0    # warm start; r1's mean not available
    svc.publish_round(r1)
    r3 = svc.open_round()
    assert r3.anchor_round == 1


# ---------------------------------------------------------------------------
# Cutover: quorum-or-deadline
# ---------------------------------------------------------------------------

def test_quorum_cutover_before_deadline():
    """Quorum met long before the deadline: the round seals immediately —
    the deadline is a backstop, not a wait."""
    eng = _eng()                   # quorum=2, deadline=1.0
    xs = _xs(2)
    r1 = eng.open_round
    for cid in (0, 1):
        eng.receive(_client(r1, cid, xs[cid]).payload(), now=0.1)
    assert r1.state is RoundState.PUBLISHED and r1.sealed_at == 0.1
    assert eng.open_round.round_id == 2
    pr = eng.published[0]
    assert pr.accepted == frozenset({0, 1})
    assert np.array_equal(pr.mean, _replay(pr.spec, pr.anchor,
                                           {0: xs[0], 1: xs[1]}))


def test_deadline_cutover_and_empty_round_rearm():
    eng = _eng(quorum=5)
    xs = _xs(1)
    r1 = eng.open_round
    # empty round at the deadline: re-arms instead of publishing nothing
    eng.advance(now=1.5)
    assert r1.state is RoundState.OPEN and r1.opened_at == 1.5
    eng.receive(_client(r1, 0, xs[0]).payload(), now=1.6)
    assert r1.state is RoundState.OPEN          # quorum not met, no deadline
    eng.advance(now=2.6)                        # deadline with 1 >= min_clients
    assert r1.state is RoundState.PUBLISHED
    assert eng.published[0].accepted == frozenset({0})


# ---------------------------------------------------------------------------
# Round-boundary races (satellite 3)
# ---------------------------------------------------------------------------

def _chunked_eng(**kw):
    svc = _svc(mtu=100)            # 144B body -> 2 chunks
    return svc, _eng(svc, **kw)


def test_race_admitted_straggler_lands_after_cutover():
    """A client admitted before the seal whose last chunk arrives AFTER the
    cutover is still accepted — and the published mean (bit-identical to
    the lockstep replay) includes it."""
    svc, eng = _chunked_eng(quorum=3)
    xs = _xs(3)
    r1 = eng.open_round
    clients = {cid: _client(r1, cid, xs[cid]) for cid in range(3)}
    for cid in (0, 1):
        for f in clients[cid].frames():
            eng.receive(f, now=0.1)
    # client 2: first chunk only -> admitted (quorum!), second chunk late
    f0, f1 = clients[2].frames()
    eng.receive(f0, now=0.1)       # 3rd admission: quorum -> cutover
    assert r1.state is RoundState.SEALING
    assert r1.server.unresolved == frozenset({2})
    eng.receive(f1, now=0.15)      # lands in the SEALED round
    eng.advance(now=0.16)          # drain + in-order publish
    assert r1.state is RoundState.PUBLISHED
    pr = eng.published[0]
    assert pr.accepted == frozenset({0, 1, 2})
    assert np.array_equal(pr.mean, _replay(pr.spec, pr.anchor,
                                           {c: xs[c] for c in range(3)}))


def test_race_newcomer_after_cutover_gets_nonterminal_retry():
    """A NEW client's frame for round k arriving after the cutover draws
    STATUS_RETRY pointing at the open round — never a terminal verdict."""
    eng = _eng()
    xs = _xs(3)
    r1 = eng.open_round
    for cid in (0, 1):
        eng.receive(_client(r1, cid, xs[cid]).payload(), now=0.1)
    # round 1 published at quorum; round 2 is open.  A newcomer still
    # addressing round 1 hits the engine-level unknown-round path:
    late = _client(r1, 9, xs[2])
    out = eng.receive(late.payload(), now=0.2)
    r = wire.decode_response(out[-1])
    assert r.status == wire.STATUS_RETRY
    assert (r.round_id, r.client_id, r.q_next) == (1, 9, 2)
    assert late.handle_response(out[-1]) == []
    assert not late.gave_up and late.retry_round == 2
    # re-enrolling in the named round succeeds
    r2 = eng.open_round
    eng.receive(_client(r2, 9, xs[2]).payload(), now=0.3)
    assert 9 in r2.server.unresolved
    # sealed-but-live round, same race: server-level RETRY, same contract
    svc2, eng2 = _chunked_eng(quorum=2)
    r1b = eng2.open_round
    c0, c1 = _client(r1b, 0, xs[0]), _client(r1b, 1, xs[1])
    eng2.receive(c0.frames()[0], now=0.1)
    eng2.receive(c1.frames()[0], now=0.1)      # quorum -> seal; both unresolved
    assert r1b.state is RoundState.SEALING
    out = eng2.receive(_client(r1b, 5, xs[2]).frames()[0], now=0.12)
    r = wire.decode_response(out[-1])
    assert r.status == wire.STATUS_RETRY and r.q_next == 2
    assert r1b.server.stats.retried == 1


def test_race_future_round_frame_before_open():
    """A frame addressed to round k+1 before that round exists draws a
    non-terminal RETRY naming the currently-open round."""
    import dataclasses
    eng = _eng()
    xs = _xs(1)
    r1 = eng.open_round
    future_spec = dataclasses.replace(r1.spec, round_id=5)
    c = AggClient(future_spec, 3, xs[0], anchor=r1.client_anchor)
    out = eng.receive(c.payload(), now=0.1)
    r = wire.decode_response(out[-1])
    assert r.status == wire.STATUS_RETRY
    assert (r.round_id, r.q_next) == (5, 1)
    assert eng.retried_unknown_round == 1
    assert not c.gave_up
    assert r1.server.admitted_count == 0       # never touched round 1


def test_race_duplicate_client_spanning_two_rounds():
    """Duplicate of an accepted payload: while its round is still live ->
    idempotent ACK; after its round published -> non-terminal RETRY.  The
    published mean counts the client exactly once either way."""
    svc, eng = _chunked_eng(quorum=3)
    xs = _xs(3)
    r1 = eng.open_round
    clients = {cid: _client(r1, cid, xs[cid]) for cid in range(3)}
    for cid in (0, 1):
        for f in clients[cid].frames():
            eng.receive(f, now=0.1)
    eng.receive(clients[2].frames()[0], now=0.1)   # quorum; 2 unresolved
    eng.advance(now=0.11)                          # drain: 0,1 accepted
    assert r1.state is RoundState.SEALING
    # duplicate of accepted client 0 while round 1 still live (sealing)
    out = eng.receive(clients[0].frames()[0], now=0.12)
    r = wire.decode_response(out[-1])
    assert (r.status, r.round_id) == (wire.STATUS_ACK, 1)
    eng.receive(clients[2].frames()[1], now=0.15)
    eng.advance(now=0.16)
    assert r1.state is RoundState.PUBLISHED
    # duplicate of the same client after its round published
    out = eng.receive(clients[0].frames()[0], now=0.2)
    r = wire.decode_response(out[-1])
    assert r.status == wire.STATUS_RETRY and r.q_next == 2
    pr = eng.published[0]
    assert pr.accepted == frozenset({0, 1, 2})     # counted exactly once
    assert np.array_equal(pr.mean, _replay(pr.spec, pr.anchor,
                                           {c: xs[c] for c in range(3)}))


def test_race_quorum_met_deadline_unexpired_ordering():
    """Quorum and deadline racing: whichever fires first seals the round,
    and the other firing later is a no-op on the already-sealed round."""
    eng = _eng(quorum=2, round_deadline=1.0)
    xs = _xs(2)
    r1 = eng.open_round
    eng.receive(_client(r1, 0, xs[0]).payload(), now=0.9)
    eng.receive(_client(r1, 1, xs[1]).payload(), now=0.95)  # quorum seals
    assert r1.sealed_at == 0.95
    eng.advance(now=1.05)           # round-1 deadline passes post-publish:
    eng.advance(now=1.2)            # must not re-seal / double-publish
    assert eng.published[0].round_id == 1 and len(eng.published) == 1
    assert eng.open_round.round_id == 2


# ---------------------------------------------------------------------------
# Admission control: backpressure + straggler expiry
# ---------------------------------------------------------------------------

def test_backpressure_pending_store_cap_is_nonterminal():
    """max_pending bounds distinct clients with buffered state; the frame
    past the cap draws RETRY naming the SAME round (still open), and the
    client is admitted once the store drains — no verdict anywhere."""
    svc = _svc(mtu=100)
    spec_rnd = svc.open_round(max_pending=1)
    server = spec_rnd.server
    xs = _xs(2)
    a = AggClient(spec_rnd.spec, 0, xs[0], anchor=spec_rnd.client_anchor)
    b = AggClient(spec_rnd.spec, 1, xs[1], anchor=spec_rnd.client_anchor)
    server.receive(a.frames()[0])              # open stream: occupancy 1
    r = wire.decode_response(server.receive(b.frames()[0]))
    assert r.status == wire.STATUS_RETRY
    assert r.q_next == spec_rnd.round_id       # same round: back off, retry
    assert server.stats.retried == 1 and server.admitted_count == 1
    server.receive(a.frames()[1])              # A completes -> staged
    server.drain()                             # A accepted -> occupancy 0
    for f in b.frames():
        r = wire.decode_response(server.receive(f))
        assert r.status != wire.STATUS_RETRY
    server.drain()
    assert server.accepted_clients == frozenset({0, 1})


def test_straggler_expiry_feeds_resend_budget_then_drops():
    """An admitted client that stops mid-payload: each straggler deadline
    taps the RESEND budget (targeted retransmit request), and once spent
    the client is EXPIRED — no terminal verdict, round publishes without
    it, and the client can re-enroll in the next round."""
    svc, eng = _chunked_eng(quorum=2, straggler_deadline=0.2, max_resends=1)
    xs = _xs(2)
    r1 = eng.open_round
    good = _client(r1, 0, xs[0])
    lost = _client(r1, 1, xs[1])
    for f in good.frames():
        eng.receive(f, now=0.1)
    eng.receive(lost.frames()[0], now=0.1)     # quorum -> seal; 1 unresolved
    assert r1.server.unresolved == frozenset({1})
    out = eng.advance(now=0.35)                # 1st deadline: RESEND budget
    resends = [wire.decode_response(o) for o in out
               if wire.decode_response(o).status == wire.STATUS_RESEND]
    assert [r.client_id for r in resends] == [1]
    assert resends[0].missing == (1,)          # names exactly the lost chunk
    assert r1.state is RoundState.SEALING      # still waiting
    eng.advance(now=0.6)                       # 2nd deadline: budget spent
    assert r1.state is RoundState.PUBLISHED
    pr = eng.published[0]
    assert pr.stats.expired == 1 and pr.stats.gave_up == 0
    assert pr.accepted == frozenset({0})
    assert not lost.gave_up
    assert np.array_equal(pr.mean, _replay(pr.spec, pr.anchor, {0: xs[0]}))
    # the expired client re-enrolls in the open round and is accepted
    r2 = eng.open_round
    for f in _client(r2, 1, xs[1]).frames():
        eng.receive(f, now=0.7)
    r2.server.drain()
    assert 1 in r2.server.accepted_clients


def test_window_overflow_force_publishes_oldest():
    """max_live_rounds bounds the live window: cutover force-publishes the
    oldest sealing round instead of letting drains pile up."""
    svc, eng = _chunked_eng(quorum=1, max_live_rounds=2,
                            straggler_deadline=99.0, drain_deadline=99.0)
    xs = _xs(4)
    for k in range(3):
        rnd = eng.open_round
        # one chunk only: each round seals at quorum=1 with its client
        # unresolved, so it can never drain on its own
        eng.receive(_client(rnd, k, xs[k]).frames()[0], now=0.1 * (k + 1))
    assert len(eng.published) == 2             # forced out by the window
    assert [pr.round_id for pr in eng.published] == [1, 2]
    assert all(pr.stats.expired == 1 for pr in eng.published)
    assert eng.live_rounds == 2


# ---------------------------------------------------------------------------
# Open loop: Poisson arrivals, parity, and the lockstep comparison
# ---------------------------------------------------------------------------

def test_open_loop_sim_parity_and_overlap():
    """The acceptance scenario: Poisson arrivals + flash crowd + churn +
    stragglers + chunked lossy transport, >= 3 concurrently-live rounds,
    every published round bit-identical to its lockstep replay (asserted
    inside run_open_loop), no terminal verdict for any benign client
    (ditto) — and the engine's virtual-clock throughput beats the lockstep
    coordinator's on the identical trace."""
    cfg = sim.OpenLoopConfig()
    rep = sim.run_open_loop(cfg, check_parity=True)
    assert rep.rounds >= 3
    assert rep.max_live_rounds >= 3
    assert rep.expired_total > 0               # stragglers were expired
    assert rep.retried_total > 0               # backpressure/rollover seen
    assert rep.resends_total > 0               # loss recovered chunk-wise
    assert rep.accepted_total > 0.5 * rep.clients_arrived
    lock = sim.run_lockstep(cfg)
    assert lock.rounds >= 2
    assert rep.rounds_per_s > lock.rounds_per_s, (rep.rounds_per_s,
                                                  lock.rounds_per_s)
    assert rep.window_stalls == 0              # blast mode: no credit cap


def test_open_loop_windowed_stalls_and_replay_parity():
    """The same open-loop trace with per-client in-flight chunk caps
    (``window=2``): the 3%-loss trace makes clients sit on blocked credit
    windows (stalls observed), streaming servers fold ranges on arrival,
    and every published round is STILL bit-identical to its sealed
    lockstep replay (asserted inside run_open_loop against a
    streaming=False server)."""
    rep = sim.run_open_loop(sim.OpenLoopConfig(window=2), check_parity=True)
    assert rep.rounds >= 3
    assert rep.window_stalls > 0, "windowed trace never hit the credit cap"
    assert rep.accepted_total > 0.5 * rep.clients_arrived


# ---------------------------------------------------------------------------
# Engine-published mean == star collective, bit for bit (8 devices)
# ---------------------------------------------------------------------------

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_engine_round_bit_identical_to_star_8dev():
    """ISSUE 6 acceptance: a round published by the continuous-round engine
    — quorum cutover, shuffled arrivals, chunked transport — equals
    allgather_allreduce_mean over that round's admitted clients bitwise."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    code = textwrap.dedent("""
        from functools import partial
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.qstate import QState
        from repro.dist.collectives import (QSyncConfig,
            allgather_allreduce_mean, flat_size_padded)
        from repro.agg import rounds
        from repro.agg.client import AggClient
        from repro.agg.engine import AggEngine, EngineConfig
        from repro.agg.service import AggService, ServiceConfig
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n, bucket = 8192, 1024
        anchor = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (n,)) * 1e6, np.float32)
        xs = jnp.asarray(anchor) + 0.05 * jax.random.normal(
            jax.random.PRNGKey(1), (8, n))
        svc = AggService(ServiceConfig(d=n, bucket=bucket, y0=2.0, seed=5,
                                       anchored=True, mtu=4096),
                         anchor0=anchor)
        eng = AggEngine(svc, EngineConfig(quorum=8, round_deadline=100.0,
                                          straggler_deadline=10.0,
                                          drain_deadline=100.0,
                                          max_live_rounds=3), now=0.0)
        rnd = eng.open_round
        spec = rnd.spec
        frames = [f for i in range(8)
                  for f in AggClient(spec, int(i), np.asarray(xs[i]),
                                     anchor=rnd.client_anchor).frames()]
        assert len(frames) >= 2 * 8
        for j in np.random.RandomState(2).permutation(len(frames)):
            eng.receive(frames[int(j)], now=0.01 * int(j))
        eng.advance(now=1.0)
        assert len(eng.published) == 1, eng.published
        pr = eng.published[0]
        assert pr.accepted == frozenset(range(8)), pr.accepted
        nb = flat_size_padded(n, QSyncConfig(q=16, bucket=bucket)) // bucket
        qs = QState(y=jnp.asarray(spec.y_np()), anchor=jnp.asarray(anchor))
        key = rounds.round_key(spec)
        cfg = spec.cfg
        @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                 out_specs=P("data"), check_vma=False)
        def f(xl):
            out, _ = allgather_allreduce_mean(xl.reshape(-1), qs, key,
                                              "data", cfg)
            return out.reshape(1, -1)
        star = np.asarray(jax.jit(f)(xs))
        assert np.all(star == star[0])
        assert np.array_equal(pr.mean, star[0]), \\
            float(np.abs(pr.mean - star[0]).max())
        print("ENGINE_STAR_PARITY_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ENGINE_STAR_PARITY_OK" in r.stdout
