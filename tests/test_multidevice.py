"""Multi-device integration tests (8 fake CPU devices via subprocess —
XLA_FLAGS must be set before jax initializes, so these run out-of-process;
the main pytest process keeps its single device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_quantized_collectives_correctness():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from functools import partial
        from repro.dist.collectives import (QSyncConfig,
            butterfly_allreduce_mean, allgather_allreduce_mean,
            rh_reduce_scatter_mean)
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n = 8 * 4096
        base = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 5.0
        xs = base + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (8, n))
        mean = xs.mean(0)
        y = float(2 * jnp.max(jnp.abs(xs - mean)))
        cfg = QSyncConfig(q=16, bucket=4096)
        y_b = jnp.full((n // 4096,), y)
        key = jax.random.PRNGKey(42)
        for fn, tag in ((butterfly_allreduce_mean, "bfly"),
                        (allgather_allreduce_mean, "star")):
            @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                     out_specs=(P("data"), P("data")), check_vma=False)
            def f(xl):
                out, aux = fn(xl.reshape(-1), y_b, key, "data", cfg)
                return out.reshape(1, -1), aux.fails.reshape(1)
            out, fails = jax.jit(f)(xs)
            assert bool(jnp.all(out == out[0])), tag + " outputs must be identical"
            err = float(jnp.max(jnp.abs(out - mean[None])))
            s = 2 * y / 15
            assert err < 4 * s, (tag, err, s)
            assert int(np.asarray(fails).sum()) == 0
        @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"),),
                 out_specs=P("data"), check_vma=False)
        def frs(xl):
            out, aux = rh_reduce_scatter_mean(xl.reshape(-1), y_b, key,
                                              "data", cfg)
            return out.reshape(1, -1)
        shards = jax.jit(frs)(xs)
        err = float(jnp.max(jnp.abs(shards.reshape(-1) - mean)))
        assert err < 4 * 2 * y / 15, err
        print("COLLECTIVES_OK")
    """)
    assert "COLLECTIVES_OK" in out


@pytest.mark.slow
def test_tp_dp_sp_loss_and_grad_equivalence():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from functools import partial
        from repro.models.config import ModelConfig
        from repro.models import transformer as T
        from repro.models.sharding import (storage_spec, ShardCtx,
            logical_to_storage, storage_to_logical, logical_shape)
        from repro.dist.collectives import QSyncConfig
        kw = dict(arch="t", family="dense", n_layers=2, d_model=32, n_heads=8,
                  n_kv=4, head_dim=8, d_ff=64, vocab=96, act="swiglu")
        def lp_make(key):
            cfg = ModelConfig(**kw); c1 = ShardCtx(tp=1, dp=1)
            metas = T.all_metas(cfg, c1)
            out = {"layers": {}, "top": {}}; i = 0
            for grp in ("layers", "top"):
                L = 2 if grp == "layers" else 1
                for name, meta in sorted(metas[grp].items()):
                    k = jax.random.fold_in(key, i); i += 1
                    shp = ((L,) + logical_shape(meta, c1)) if meta.scanned else logical_shape(meta, c1)
                    out[grp][name] = jnp.ones(shp) if meta.init == "ones" else jax.random.normal(k, shp) * 0.05
            return out
        def run(tp, dp, sp, lp):
            mesh = jax.make_mesh((dp, tp), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            cfg = ModelConfig(**kw)
            ctx = ShardCtx(tp=tp, dp=dp, qcfg=QSyncConfig(q=256, bucket=32),
                           grad_sync="fp32", seq_parallel=sp)
            metas = T.all_metas(cfg, ctx)
            params = {"layers": {k: jax.vmap(lambda x: logical_to_storage(x, m, ctx))(lp["layers"][k]) for k, m in metas["layers"].items()},
                      "top": {k: logical_to_storage(lp["top"][k], m, ctx) for k, m in metas["top"].items()}}
            pspec = {"layers": {k: storage_spec(m, ctx) for k, m in metas["layers"].items()},
                     "top": {k: storage_spec(m, ctx) for k, m in metas["top"].items()}}
            loss_fn = T.make_loss_fn(cfg, ctx)
            y = T.y_init(cfg, ctx, 50.0)
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 16), 0, 96),
                     "targets": jax.random.randint(jax.random.PRNGKey(8), (4, 16), 0, 96),
                     "mask": jnp.ones((4, 16))}
            @partial(jax.shard_map, mesh=mesh,
                     in_specs=(pspec, P(), {k: P("data") for k in batch}, P()),
                     out_specs=(P(), pspec), check_vma=False)
            def step(params, key, batch, y):
                tele = T.tele_zeros(cfg, ctx)
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, tele, batch, key, y)
                return jax.lax.psum(m["loss"], ("data",)) / ctx.dp, g
            bp = {k: jax.device_put(v, NamedSharding(mesh, P("data"))) for k, v in batch.items()}
            pp = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), params, pspec)
            loss, g = jax.jit(step)(pp, jax.random.PRNGKey(3), bp, y)
            glog = {k: jax.vmap(lambda x: storage_to_logical(x, metas["layers"][k], ctx))(g["layers"][k]) for k in g["layers"]}
            return float(loss), glog
        l1, g1 = run(1, 1, False, lp_make(jax.random.PRNGKey(0)))
        l2, g2 = run(4, 2, False, lp_make(jax.random.PRNGKey(0)))
        l3, g3 = run(4, 2, True, lp_make(jax.random.PRNGKey(0)))
        assert abs(l1 - l2) < 2e-2, (l1, l2)
        assert abs(l1 - l3) < 2e-2, (l1, l3)
        for k in g1:
            a, b, c = map(np.asarray, (g1[k], g2[k], g3[k]))
            scale = np.max(np.abs(a)) + 1e-9
            assert np.max(np.abs(a - b)) / scale < 5e-2, k
            assert np.max(np.abs(a - c)) / scale < 5e-2, k
        print("TP_EQUIV_OK")
    """)
    assert "TP_EQUIV_OK" in out


@pytest.mark.slow
def test_decode_equivalence_tp4():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from functools import partial
        from repro.models.config import ModelConfig
        from repro.models.sharding import (ShardCtx, storage_spec,
            logical_to_storage, logical_shape)
        from repro.models import transformer as T
        from repro.models import serve as SV
        kw = dict(arch="t", family="dense", n_layers=2, d_model=32, n_heads=8,
                  n_kv=2, head_dim=8, d_ff=64, vocab=96, act="swiglu")
        def lp_make(key):
            cfg = ModelConfig(**kw); c1 = ShardCtx(tp=1, dp=1)
            metas = T.all_metas(cfg, c1)
            out = {"layers": {}, "top": {}}; i = 0
            for grp in ("layers", "top"):
                L = 2 if grp == "layers" else 1
                for name, meta in sorted(metas[grp].items()):
                    k = jax.random.fold_in(key, i); i += 1
                    shp = ((L,) + logical_shape(meta, c1)) if meta.scanned else logical_shape(meta, c1)
                    out[grp][name] = jnp.ones(shp) if meta.init == "ones" else jax.random.normal(k, shp) * 0.05
            return out
        def run(tp, lp):
            mesh = jax.make_mesh((1, tp), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            cfg = ModelConfig(**kw); ctx = ShardCtx(tp=tp, dp=1)
            metas = T.all_metas(cfg, ctx)
            params = {"layers": {k: jax.vmap(lambda x: logical_to_storage(x, m, ctx))(lp["layers"][k]) for k, m in metas["layers"].items()},
                      "top": {k: logical_to_storage(lp["top"][k], m, ctx) for k, m in metas["top"].items()}}
            pspec = {"layers": {k: storage_spec(m, ctx) for k, m in metas["layers"].items()},
                     "top": {k: storage_spec(m, ctx) for k, m in metas["top"].items()}}
            cache = SV.cache_zeros(cfg, ctx, 2, 16)
            step = SV.make_serve_step(cfg, ctx)
            cspec = jax.tree.map(lambda v: P("model"), cache)
            cache_g = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (tp,) + v.shape), cache)
            @partial(jax.shard_map, mesh=mesh, in_specs=(pspec, cspec, P(), P(), P()),
                     out_specs=(P("model"), cspec), check_vma=False)
            def f(params, cache, tokens, pos, key):
                cache = jax.tree.map(lambda v: v[0], cache)
                nxt, nc = step(params, cache, tokens, pos, key)
                return nxt[None], jax.tree.map(lambda v: v[None], nc)
            pp = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), params, pspec)
            toks = jnp.array([[5],[7]], jnp.int32)
            outs = []
            key = jax.random.PRNGKey(9)
            for t in range(4):
                nxt, cache_g = jax.jit(f)(pp, cache_g, toks, jnp.int32(t), key)
                toks = nxt[0][:, None]
                outs.append(np.asarray(nxt[0]))
            return np.stack(outs)
        o1, o4 = run(1, lp_make(jax.random.PRNGKey(0))), run(4, lp_make(jax.random.PRNGKey(0)))
        assert np.array_equal(o1, o4), (o1, o4)
        print("DECODE_EQUIV_OK")
    """)
    assert "DECODE_EQUIV_OK" in out
